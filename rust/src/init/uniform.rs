//! Uniform random seeding: k distinct rows, nearly free (§6 of the paper:
//! "The uniform initialization is nearly instantaneous").

use crate::sparse::CsrMatrix;
use crate::util::rng::Xoshiro256;

pub(crate) fn choose(data: &CsrMatrix, k: usize, rng: &mut Xoshiro256) -> Vec<usize> {
    rng.sample_distinct(data.rows(), k)
}
