//! Seeding methods for spherical k-means (§5.6 of the paper).
//!
//! * [`InitMethod::Uniform`] — k distinct rows uniformly at random.
//! * [`InitMethod::KMeansPP`] — spherical k-means++: sample proportional to
//!   the dissimilarity `α − max_c ⟨x, c⟩` (α = 1 is the canonical cosine
//!   adaptation; α = 1.5 is the metric-making value of Endo & Miyamoto).
//! * [`InitMethod::AfkMc2`] — AFK-MC² (Bachem et al. 2016) with the same
//!   `α` trick (Pratap et al. 2018): an MCMC approximation of k-means++
//!   that avoids the full `O(N·k)` pass per center after the first.

mod afkmc2;
mod kmeanspp;
mod uniform;

use crate::sparse::{CsrMatrix, DenseMatrix, RowSource};
use crate::util::rng::Xoshiro256;

/// Seeding method selector.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum InitMethod {
    /// k distinct rows uniformly at random.
    Uniform,
    /// Spherical k-means++ with dissimilarity `α − sim`.
    KMeansPP {
        /// Dissimilarity offset; 1.0 = canonical, 1.5 = metric variant.
        alpha: f64,
    },
    /// AFK-MC² with dissimilarity `α − sim` and a given chain length.
    AfkMc2 {
        /// Dissimilarity offset; 1.0 = canonical, 1.5 = metric variant.
        alpha: f64,
        /// Markov chain length `m` per sampled center (paper-typical: 100–200).
        chain: usize,
    },
}

impl InitMethod {
    /// Display name matching Table 2 of the paper.
    pub fn name(&self) -> String {
        match self {
            InitMethod::Uniform => "Uniform".into(),
            InitMethod::KMeansPP { alpha } => format!("k-means++ a={alpha}"),
            InitMethod::AfkMc2 { alpha, .. } => format!("AFK-MC2 a={alpha}"),
        }
    }

    /// The five initialization configurations evaluated in Table 2.
    pub fn paper_set() -> Vec<InitMethod> {
        vec![
            InitMethod::Uniform,
            InitMethod::KMeansPP { alpha: 1.0 },
            InitMethod::KMeansPP { alpha: 1.5 },
            InitMethod::AfkMc2 { alpha: 1.0, chain: 100 },
            InitMethod::AfkMc2 { alpha: 1.5, chain: 100 },
        ]
    }
}

impl std::str::FromStr for InitMethod {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "uniform" | "random" => Ok(InitMethod::Uniform),
            "kmeans++" | "kmeanspp" | "pp" => Ok(InitMethod::KMeansPP { alpha: 1.0 }),
            "kmeans++1.5" | "pp1.5" => Ok(InitMethod::KMeansPP { alpha: 1.5 }),
            "afkmc2" | "afk-mc2" => Ok(InitMethod::AfkMc2 { alpha: 1.0, chain: 100 }),
            "afkmc2-1.5" | "afk-mc2-1.5" => Ok(InitMethod::AfkMc2 { alpha: 1.5, chain: 100 }),
            other => Err(format!("unknown init method: {other}")),
        }
    }
}

/// The outcome of seeding: initial unit centers plus instrumentation.
#[derive(Debug, Clone)]
pub struct InitOutcome {
    /// k × d matrix of initial centers (unit rows).
    pub centers: DenseMatrix,
    /// Similarity computations spent during seeding.
    pub sims_computed: u64,
    /// Wall time of seeding in milliseconds.
    pub wall_ms: f64,
    /// Row indices of the chosen seeds (for reproducibility reports).
    pub chosen: Vec<usize>,
    /// Row-major `N × k` matrix of point-to-seed similarities collected
    /// *during* seeding (k-means++ computes them anyway — the §7 synergy).
    /// When present, a fit with
    /// [`ExactParams::preinit`](crate::kmeans::ExactParams) initializes
    /// all bound structures from it and skips the initial `O(N·k)`
    /// assignment pass.
    pub sim_matrix: Option<Vec<f32>>,
}

/// Seed `k` centers from `data` with `method` and `seed`.
pub fn seed_centers(data: &CsrMatrix, k: usize, method: &InitMethod, seed: u64) -> InitOutcome {
    seed_centers_impl(RowSource::Mem(data), k, method, seed, false)
}

/// [`seed_centers`] over either row backend ([`RowSource`]): the seeding
/// RNG walk and every similarity run through the same code path, so the
/// chosen rows — and therefore the initial centers — are bit-identical
/// whether the data lives in memory or in chunked disk shards.
pub fn seed_centers_source(
    src: RowSource<'_>,
    k: usize,
    method: &InitMethod,
    seed: u64,
) -> InitOutcome {
    seed_centers_impl(src, k, method, seed, false)
}

/// Like [`seed_centers`], additionally collecting the `N × k` similarity
/// matrix when the method computes those similarities anyway (k-means++) —
/// the paper's §7 "pre-initialize the bounds" synergy. Costs `N` extra
/// similarities (the last seed's column) plus `N·k·4` bytes.
pub fn seed_centers_with_bounds(
    data: &CsrMatrix,
    k: usize,
    method: &InitMethod,
    seed: u64,
) -> InitOutcome {
    seed_centers_impl(RowSource::Mem(data), k, method, seed, true)
}

/// [`seed_centers_with_bounds`] over either row backend — see
/// [`seed_centers_source`] for the bit-identity contract.
pub fn seed_centers_with_bounds_source(
    src: RowSource<'_>,
    k: usize,
    method: &InitMethod,
    seed: u64,
) -> InitOutcome {
    seed_centers_impl(src, k, method, seed, true)
}

fn seed_centers_impl(
    src: RowSource<'_>,
    k: usize,
    method: &InitMethod,
    seed: u64,
    collect: bool,
) -> InitOutcome {
    assert!(k >= 1, "k must be positive");
    assert!(
        k <= src.rows(),
        "cannot seed k={k} centers from {} rows",
        src.rows()
    );
    let sw = crate::util::timer::Stopwatch::start();
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut sim_matrix = if collect && matches!(method, InitMethod::KMeansPP { .. }) {
        Some(vec![0.0f32; src.rows() * k])
    } else {
        None
    };
    let (chosen, mut sims) = match method {
        InitMethod::Uniform => (uniform::choose(src.rows(), k, &mut rng), 0),
        InitMethod::KMeansPP { alpha } => {
            kmeanspp::choose_collecting(src, k, *alpha, &mut rng, sim_matrix.as_deref_mut())
        }
        InitMethod::AfkMc2 { alpha, chain } => afkmc2::choose(src, k, *alpha, *chain, &mut rng),
    };
    let mut rows = src.cursor();
    if let Some(m) = sim_matrix.as_deref_mut() {
        // The last chosen seed's column was never needed by the seeding
        // loop itself; fill it so the matrix is complete.
        let last = rows.row_vec(chosen[k - 1]).to_dense();
        for i in 0..src.rows() {
            m[i * k + (k - 1)] = rows.row(i).dot_dense(&last) as f32;
        }
        sims += src.rows() as u64;
    }
    let mut centers = DenseMatrix::zeros(k, src.cols());
    for (c, &row) in chosen.iter().enumerate() {
        let v = rows.row(row);
        let dst = centers.row_mut(c);
        for (t, &col) in v.indices.iter().enumerate() {
            dst[col as usize] = v.values[t];
        }
    }
    centers.normalize_rows();
    InitOutcome {
        centers,
        sims_computed: sims,
        wall_ms: sw.ms(),
        chosen,
        sim_matrix,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthConfig;

    fn dataset() -> CsrMatrix {
        SynthConfig::small_demo().generate(7).matrix
    }

    #[test]
    fn all_methods_produce_k_unit_centers() {
        let data = dataset();
        for method in InitMethod::paper_set() {
            let out = seed_centers(&data, 5, &method, 3);
            assert_eq!(out.centers.rows(), 5, "{}", method.name());
            assert_eq!(out.chosen.len(), 5);
            for j in 0..5 {
                let n: f64 = out
                    .centers
                    .row(j)
                    .iter()
                    .map(|&v| v as f64 * v as f64)
                    .sum();
                assert!((n - 1.0).abs() < 1e-4, "{} center {j} norm {n}", method.name());
            }
        }
    }

    #[test]
    fn seeding_is_deterministic_per_seed() {
        let data = dataset();
        for method in InitMethod::paper_set() {
            let a = seed_centers(&data, 4, &method, 11);
            let b = seed_centers(&data, 4, &method, 11);
            assert_eq!(a.chosen, b.chosen, "{}", method.name());
            let c = seed_centers(&data, 4, &method, 12);
            // Different seeds should (almost surely) choose differently.
            if a.chosen == c.chosen {
                let d = seed_centers(&data, 4, &method, 13);
                assert_ne!(a.chosen, d.chosen, "{}", method.name());
            }
        }
    }

    #[test]
    fn plusplus_chooses_distinct_rows() {
        let data = dataset();
        for method in [
            InitMethod::KMeansPP { alpha: 1.0 },
            InitMethod::KMeansPP { alpha: 1.5 },
            InitMethod::AfkMc2 { alpha: 1.0, chain: 20 },
        ] {
            for seed in 0..5 {
                let out = seed_centers(&data, 8, &method, seed);
                let set: std::collections::HashSet<_> = out.chosen.iter().collect();
                assert_eq!(set.len(), 8, "{} seed {seed}", method.name());
            }
        }
    }

    #[test]
    fn parse_init_methods() {
        assert_eq!("uniform".parse::<InitMethod>().unwrap(), InitMethod::Uniform);
        assert!(matches!(
            "kmeans++".parse::<InitMethod>().unwrap(),
            InitMethod::KMeansPP { .. }
        ));
        assert!(matches!(
            "afkmc2".parse::<InitMethod>().unwrap(),
            InitMethod::AfkMc2 { .. }
        ));
        assert!("bogus".parse::<InitMethod>().is_err());
    }
}
