//! Warm-start / resume integration: `fit → save → load → warm_start →
//! fit` must converge to **bit-identical** centers, assignments, and
//! objective versus an uninterrupted run with the same total iteration
//! budget — across thread counts {1, 0} and the Dense/Inverted kernels.
//!
//! This works because `FittedModel::save` persists the training state
//! (the f64 center-sum accumulators, counts, and assignments) alongside
//! the f32 centers: the exact engines maintain their sums incrementally,
//! so a resumed run restores the exact accumulator bits and replays the
//! identical floating-point sequence the uninterrupted run would have.

// Bench and test targets favour readable literal casts and exact
// (bit-level) float assertions; the workspace clippy warnings on
// those patterns are aimed at library code.
#![allow(clippy::cast_possible_truncation, clippy::float_cmp)]

use sphkm::data::synth::SynthConfig;
use sphkm::data::Dataset;
use sphkm::kmeans::{Engine, KernelChoice, MiniBatchParams, Variant};
use sphkm::{FittedModel, SphericalKMeans};

fn corpus() -> Dataset {
    let mut cfg = SynthConfig::small_demo();
    cfg.name = "warm-synth".into();
    cfg.n_docs = 700;
    cfg.generate(77)
}

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("sphkm-warm-tests-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn assert_models_bit_identical(a: &FittedModel, b: &FittedModel, what: &str) {
    assert_eq!(a.assignments(), b.assignments(), "{what}: assignments");
    assert_eq!(
        a.objective().to_bits(),
        b.objective().to_bits(),
        "{what}: objective"
    );
    assert_eq!(a.converged(), b.converged(), "{what}: converged");
    for j in 0..a.k() {
        for (c, (x, y)) in a
            .centers()
            .row(j)
            .iter()
            .zip(b.centers().row(j))
            .enumerate()
        {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: center {j} dim {c}");
        }
    }
}

#[test]
fn exact_resume_is_bit_identical_to_uninterrupted() {
    let ds = corpus();
    let k = 8;
    let interrupt_at = 2usize;
    for kernel in [KernelChoice::Dense, KernelChoice::Inverted] {
        for threads in [1usize, 0] {
            // All seven variants: each registers its own bound-state
            // closure against the resume path, so each needs coverage.
            for variant in Variant::ALL {
                let est = || {
                    SphericalKMeans::new(k)
                        .variant(variant)
                        .seed(5)
                        .threads(threads)
                        .kernel(kernel)
                };
                let what = format!("{} kernel={kernel:?} threads={threads}", variant.name());
                // Uninterrupted reference: run to convergence.
                let full = est().max_iter(200).fit(&ds.matrix).unwrap();
                assert!(full.converged(), "{what}: reference must converge");
                assert!(
                    full.iterations() > interrupt_at,
                    "{what}: corpus converges too fast for a meaningful split"
                );
                // Interrupted run: stop after `interrupt_at` iterations,
                // round-trip through disk, resume with the remaining budget.
                let part = est().max_iter(interrupt_at).fit(&ds.matrix).unwrap();
                assert!(!part.converged(), "{what}: partial run must not converge");
                let path = tmp(&format!(
                    "exact-{}-{kernel:?}-{threads}.spkm",
                    variant.name().replace('.', "_")
                ));
                part.save(&path).unwrap();
                let loaded = FittedModel::load(&path).unwrap();
                std::fs::remove_file(&path).ok();
                assert_eq!(loaded.assignments(), part.assignments());
                let resumed = est()
                    .max_iter(200)
                    .warm_start(&loaded)
                    .fit(&ds.matrix)
                    .unwrap();
                assert_models_bit_identical(&full, &resumed, &what);
                // Same total iteration budget: the split spends exactly
                // what the uninterrupted run spent.
                assert_eq!(
                    part.iterations() + resumed.iterations(),
                    full.iterations(),
                    "{what}: split budget"
                );
                // Cumulative provenance survives the round trip.
                assert_eq!(
                    resumed.meta().iterations,
                    full.iterations() as u64,
                    "{what}: cumulative steps"
                );
            }
        }
    }
}

#[test]
fn exact_resume_works_across_variants() {
    // Any exact variant continues any exact run: exactness makes the
    // assignment trajectory variant-independent, so a Standard run
    // resumed with Elkan converges to the same clustering as the
    // uninterrupted Standard reference. (Only the clustering — Elkan's
    // within-pass multi-hop move replay can perturb the f64 sums in the
    // last bits, so the *bitwise* guarantee holds per variant, which is
    // what `exact_resume_is_bit_identical_to_uninterrupted` asserts.)
    let ds = corpus();
    let k = 6;
    let est = |variant: Variant| SphericalKMeans::new(k).variant(variant).seed(9);
    let full = est(Variant::Standard).max_iter(200).fit(&ds.matrix).unwrap();
    assert!(full.converged());
    let part = est(Variant::Standard).max_iter(2).fit(&ds.matrix).unwrap();
    let path = tmp("cross-variant.spkm");
    part.save(&path).unwrap();
    let loaded = FittedModel::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let resumed = est(Variant::Elkan)
        .max_iter(200)
        .warm_start(&loaded)
        .fit(&ds.matrix)
        .unwrap();
    assert!(resumed.converged(), "standard→elkan resume converges");
    assert_eq!(
        resumed.assignments(),
        full.assignments(),
        "standard→elkan: clustering"
    );
    assert!(
        (resumed.objective() - full.objective()).abs() < 1e-6 * (1.0 + full.objective()),
        "standard→elkan: objective {} vs {}",
        resumed.objective(),
        full.objective()
    );
}

#[test]
fn minibatch_resume_is_bit_identical_to_uninterrupted() {
    let ds = corpus();
    let k = 6;
    let total_epochs = 6usize;
    let interrupt_at = 2usize;
    let mb = |epochs: usize, kernel: KernelChoice, threads: usize| {
        SphericalKMeans::new(k)
            .engine(Engine::MiniBatch(MiniBatchParams {
                batch_size: 128,
                epochs,
                tol: 0.0,
                truncate: Some(24),
            }))
            .seed(31)
            .threads(threads)
            .kernel(kernel)
    };
    for kernel in [KernelChoice::Dense, KernelChoice::Inverted] {
        for threads in [1usize, 0] {
            let what = format!("minibatch kernel={kernel:?} threads={threads}");
            let full = mb(total_epochs, kernel, threads).fit(&ds.matrix).unwrap();
            let part = mb(interrupt_at, kernel, threads).fit(&ds.matrix).unwrap();
            let path = tmp(&format!("mb-{kernel:?}-{threads}.spkm"));
            part.save(&path).unwrap();
            let loaded = FittedModel::load(&path).unwrap();
            std::fs::remove_file(&path).ok();
            assert_eq!(loaded.meta().variant, "minibatch");
            // The training schedule rides along, so a CLI resume can
            // reproduce it without re-passing the flags.
            assert_eq!(
                loaded.state().and_then(|s| s.minibatch),
                Some(MiniBatchParams {
                    batch_size: 128,
                    epochs: interrupt_at,
                    tol: 0.0,
                    truncate: Some(24),
                })
            );
            let resumed = mb(total_epochs - interrupt_at, kernel, threads)
                .warm_start(&loaded)
                .fit(&ds.matrix)
                .unwrap();
            assert_models_bit_identical(&full, &resumed, &what);
            assert_eq!(
                resumed.meta().iterations,
                total_epochs as u64,
                "{what}: cumulative epochs"
            );
        }
    }
}

#[test]
fn warm_start_without_state_is_a_plain_transfer() {
    // An exact-engine warm start from a mini-batch model (engine
    // mismatch) must not try to resume: the centers seed a fresh run.
    let ds = corpus();
    let k = 5;
    let mb = SphericalKMeans::new(k)
        .engine(Engine::MiniBatch(MiniBatchParams {
            batch_size: 128,
            epochs: 2,
            ..Default::default()
        }))
        .seed(3)
        .fit(&ds.matrix)
        .unwrap();
    let refined = SphericalKMeans::new(k)
        .variant(Variant::SimplifiedHamerly)
        .warm_start(&mb)
        .fit(&ds.matrix)
        .unwrap();
    assert!(refined.converged(), "full-batch refinement converges");
    // Refinement can only improve (or match) the mini-batch objective.
    assert!(refined.objective() <= mb.objective() + 1e-9);
    // And it matches a fresh run from the same explicit centers.
    let from_centers = SphericalKMeans::new(k)
        .variant(Variant::SimplifiedHamerly)
        .warm_start_centers(mb.centers().clone())
        .fit(&ds.matrix)
        .unwrap();
    assert_models_bit_identical(&refined, &from_centers, "transfer");
}

#[test]
fn observer_early_stop_then_resume_recovers_the_full_run() {
    // The acceptance-path combination: stop training via the observer,
    // save, resume, and land bit-identically on the uninterrupted result.
    use std::ops::ControlFlow;
    let ds = corpus();
    let k = 7;
    let est = || SphericalKMeans::new(k).variant(Variant::SimplifiedHamerly).seed(13);
    let full = est().max_iter(200).fit(&ds.matrix).unwrap();
    assert!(full.converged());
    assert!(full.iterations() > 3);
    let mut stopper = |s: &sphkm::IterSnapshot<'_>| {
        if s.iteration >= 3 {
            ControlFlow::Break(())
        } else {
            ControlFlow::Continue(())
        }
    };
    let stopped = est()
        .max_iter(200)
        .fit_observed(&ds.matrix, &mut stopper)
        .unwrap();
    assert!(!stopped.converged());
    assert_eq!(
        stopped.stats().iters.len(),
        4,
        "early stop halts within one iteration of the signal"
    );
    let path = tmp("observer-stop.spkm");
    stopped.save(&path).unwrap();
    let loaded = FittedModel::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let resumed = est()
        .max_iter(200)
        .warm_start(&loaded)
        .fit(&ds.matrix)
        .unwrap();
    assert_models_bit_identical(&full, &resumed, "observer-stop → resume");
}
