//! Sharded-executor scaling benchmark: run time of the assignment-dominated
//! clustering loop vs worker-thread count, on the synthetic 20-newsgroups
//! analogue (the acceptance target is ≥2× at 4 threads for the
//! scan-heavy variants).
//!
//! ```text
//! cargo bench --bench bench_parallel -- [--scale tiny|small|medium]
//!     [--k 50] [--threads 1,2,4,8] [--runs 5] [--max-iter 25]
//! ```
//!
//! Also spot-checks the determinism contract at the end: the parallel run
//! must produce bit-identical assignments to the serial one.

// Bench and test targets favour readable literal casts and exact
// (bit-level) float assertions; the workspace clippy warnings on
// those patterns are aimed at library code.
#![allow(clippy::cast_possible_truncation, clippy::float_cmp)]

use sphkm::data::datasets::{self, Scale};
use sphkm::init::{seed_centers, InitMethod};
use sphkm::kmeans::{SphericalKMeans, Variant};
use sphkm::util::benchkit::{bench, black_box, BenchOpts};
use sphkm::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let opts = BenchOpts::from_args(&args);
    let scale: Scale = args.get_or("scale", Scale::Small).unwrap_or(Scale::Small);
    let k: usize = args.get_or("k", 50).unwrap_or(50);
    let max_iter: usize = args.get_or("max-iter", 25).unwrap_or(25);
    let threads_grid: Vec<usize> = args
        .list::<usize>("threads")
        .unwrap_or(None)
        .unwrap_or_else(|| vec![1, 2, 4]);

    let ds = datasets::newsgroups(scale, 42);
    let k = k.min(ds.matrix.rows() / 2).max(2);
    let init = seed_centers(&ds.matrix, k, &InitMethod::Uniform, 7);
    println!(
        "# parallel assignment bench — {} ({}×{}, {:.3}% nnz), k={k}, \
         max_iter={max_iter}, cores={}",
        ds.name,
        ds.matrix.rows(),
        ds.matrix.cols(),
        ds.matrix.density() * 100.0,
        std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1),
    );

    for variant in [
        Variant::Standard,
        Variant::SimplifiedElkan,
        Variant::SimplifiedHamerly,
        Variant::Exponion,
    ] {
        let mut base_ms = f64::NAN;
        for &t in &threads_grid {
            let est = SphericalKMeans::new(k)
                .variant(variant)
                .max_iter(max_iter)
                .threads(t);
            let r = bench(
                &format!("parallel/{}/threads={t}", variant.name()),
                opts,
                || {
                    let out = est
                        .clone()
                        .warm_start_centers(init.centers.clone())
                        .fit(&ds.matrix)
                        .expect("bench configuration is valid");
                    black_box(out.objective());
                },
            );
            if t == threads_grid[0] {
                base_ms = r.stats.mean_ms;
            } else {
                println!(
                    "        speedup vs threads={}: {:.2}x",
                    threads_grid[0],
                    base_ms / r.stats.mean_ms
                );
            }
        }
    }

    // Determinism spot check (the exactness suite covers this per variant;
    // here it guards the bench itself against measuring diverging runs).
    let check = |threads: usize| {
        SphericalKMeans::new(k)
            .variant(Variant::SimplifiedHamerly)
            .max_iter(max_iter)
            .threads(threads)
            .warm_start_centers(init.centers.clone())
            .fit(&ds.matrix)
            .expect("bench configuration is valid")
    };
    let serial = check(1);
    let par = check(4);
    assert_eq!(serial.assignments(), par.assignments(), "determinism violation");
    assert_eq!(serial.objective().to_bits(), par.objective().to_bits());
    println!("# determinism check passed (threads=1 vs threads=4 bit-identical)");
}
