//! Clustering-quality metrics: the spherical k-means objective plus
//! external validation against planted labels (NMI, ARI, purity) used by
//! the examples and the end-to-end driver.

mod silhouette;

pub use silhouette::silhouette_sampled;

use crate::sparse::{CsrMatrix, DenseMatrix};

/// The spherical k-means objective `Σᵢ (1 − ⟨xᵢ, c(a(i))⟩)` (lower is
/// better) for an arbitrary assignment/centers pair.
pub fn objective(data: &CsrMatrix, assign: &[u32], centers: &DenseMatrix) -> f64 {
    assert_eq!(assign.len(), data.rows());
    let mut obj = 0.0;
    for i in 0..data.rows() {
        obj += 1.0 - data.row(i).dot_dense(centers.row(assign[i] as usize));
    }
    obj
}

/// Contingency table between two labelings.
fn contingency(a: &[u32], b: &[u32]) -> (Vec<Vec<u64>>, Vec<u64>, Vec<u64>) {
    assert_eq!(a.len(), b.len());
    let ka = a.iter().copied().max().map(|m| m as usize + 1).unwrap_or(0);
    let kb = b.iter().copied().max().map(|m| m as usize + 1).unwrap_or(0);
    let mut table = vec![vec![0u64; kb]; ka];
    let mut ra = vec![0u64; ka];
    let mut rb = vec![0u64; kb];
    for (&x, &y) in a.iter().zip(b) {
        table[x as usize][y as usize] += 1;
        ra[x as usize] += 1;
        rb[y as usize] += 1;
    }
    (table, ra, rb)
}

/// Normalized Mutual Information (arithmetic normalization), in `[0, 1]`.
pub fn nmi(a: &[u32], b: &[u32]) -> f64 {
    let n = a.len() as f64;
    if a.is_empty() {
        return 0.0;
    }
    let (table, ra, rb) = contingency(a, b);
    let mut mi = 0.0;
    for (i, row) in table.iter().enumerate() {
        for (j, &nij) in row.iter().enumerate() {
            if nij > 0 {
                let nij = nij as f64;
                mi += nij / n * ((n * nij) / (ra[i] as f64 * rb[j] as f64)).ln();
            }
        }
    }
    let h = |counts: &[u64]| -> f64 {
        counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / n;
                -p * p.ln()
            })
            .sum()
    };
    let (ha, hb) = (h(&ra), h(&rb));
    if ha == 0.0 && hb == 0.0 {
        return 1.0; // both labelings are constant ⇒ identical structure
    }
    let denom = 0.5 * (ha + hb);
    if denom == 0.0 {
        0.0
    } else {
        (mi / denom).clamp(0.0, 1.0)
    }
}

/// Adjusted Rand Index, in `[-1, 1]` (1 = identical partitions).
pub fn ari(a: &[u32], b: &[u32]) -> f64 {
    let n = a.len() as f64;
    if a.is_empty() {
        return 0.0;
    }
    let (table, ra, rb) = contingency(a, b);
    let c2 = |x: u64| -> f64 {
        let x = x as f64;
        x * (x - 1.0) / 2.0
    };
    let sum_ij: f64 = table.iter().flatten().map(|&v| c2(v)).sum();
    let sum_a: f64 = ra.iter().map(|&v| c2(v)).sum();
    let sum_b: f64 = rb.iter().map(|&v| c2(v)).sum();
    let total = c2(n as u64);
    let expected = sum_a * sum_b / total;
    let max_index = 0.5 * (sum_a + sum_b);
    if (max_index - expected).abs() < 1e-12 {
        return if (sum_ij - expected).abs() < 1e-12 { 1.0 } else { 0.0 };
    }
    (sum_ij - expected) / (max_index - expected)
}

/// Purity: fraction of points whose cluster's majority label matches theirs.
pub fn purity(pred: &[u32], truth: &[u32]) -> f64 {
    if pred.is_empty() {
        return 0.0;
    }
    let (table, _, _) = contingency(pred, truth);
    let correct: u64 = table
        .iter()
        .map(|row| row.iter().copied().max().unwrap_or(0))
        .sum();
    correct as f64 / pred.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_partitions_score_one() {
        let a = vec![0, 0, 1, 1, 2, 2];
        assert!((nmi(&a, &a) - 1.0).abs() < 1e-12);
        assert!((ari(&a, &a) - 1.0).abs() < 1e-12);
        assert!((purity(&a, &a) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn permuted_labels_still_score_one() {
        let a = vec![0, 0, 1, 1, 2, 2];
        let b = vec![2, 2, 0, 0, 1, 1];
        assert!((nmi(&a, &b) - 1.0).abs() < 1e-12);
        assert!((ari(&a, &b) - 1.0).abs() < 1e-12);
        assert!((purity(&a, &b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn independent_partitions_score_low() {
        // Balanced 2×2 independence.
        let a = vec![0, 0, 1, 1, 0, 0, 1, 1];
        let b = vec![0, 1, 0, 1, 0, 1, 0, 1];
        assert!(nmi(&a, &b).abs() < 1e-9);
        assert!(ari(&a, &b).abs() < 0.26);
        assert!((purity(&a, &b) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn ari_known_value() {
        // sklearn doctest example: ARI([0,0,1,2],[0,0,1,1]) = 0.571428…
        let a = vec![0, 0, 1, 2];
        let b = vec![0, 0, 1, 1];
        assert!((ari(&a, &b) - 0.5714285714).abs() < 1e-9);
    }

    #[test]
    fn nmi_is_symmetric() {
        let a = vec![0, 0, 1, 1, 2, 2, 0, 1];
        let b = vec![1, 1, 0, 0, 2, 1, 0, 1];
        assert!((nmi(&a, &b) - nmi(&b, &a)).abs() < 1e-12);
    }

    #[test]
    fn objective_matches_manual() {
        use crate::sparse::SparseVec;
        let rows = vec![
            SparseVec::from_pairs(2, vec![(0, 1.0)]),
            SparseVec::from_pairs(2, vec![(1, 1.0)]),
        ];
        let m = CsrMatrix::from_rows(2, &rows);
        let centers = DenseMatrix::from_vec(1, 2, vec![std::f32::consts::FRAC_1_SQRT_2; 2]);
        let obj = objective(&m, &[0, 0], &centers);
        let expect = 2.0 * (1.0 - std::f64::consts::FRAC_1_SQRT_2);
        assert!((obj - expect).abs() < 1e-6);
    }
}
