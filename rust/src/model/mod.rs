//! Persistent trained-model artifacts: save a clustering's centers and
//! training metadata, load them back bit-exactly, and serve queries from
//! them long after the training process is gone.
//!
//! Until this module existed every trained clustering died with the
//! process. A production deployment trains once and then answers "which
//! cluster does this new document belong to?" millions of times — that
//! split (train → persist → serve) is what [`Model`] enables: the bridge
//! between [`crate::kmeans`] (which produces centers) and [`crate::serve`]
//! (which answers nearest-center queries against them).
//!
//! # The `.spkm` binary format (versions 1 and 2)
//!
//! Fixed little-endian encoding on every platform, single file, designed
//! so that loading validates everything it cannot trust:
//!
//! | Section | Bytes | Contents |
//! |---|---|---|
//! | magic | 8 | `b"SPHKMDL\0"` |
//! | version | 4 | `u32` = 1 or 2 (future versions are rejected, not guessed) |
//! | flags | 4 | reserved, must be 0 |
//! | shape | 24 | `k`, `d`, center `nnz` as `u64` |
//! | training | 24 | iterations `u64`, seed `u64`, objective `f64` |
//! | variant | 2 + len | `u16` length + UTF-8 name |
//! | kernel | 2 + len | `u16` length + UTF-8 name |
//! | norms | 8·k | per-center L2 norm, `f64` bits |
//! | indptr | 8·(k+1) | CSR row pointers over the center non-zeros, `u64` |
//! | indices | 4·nnz | column (term) ids, `u32`, strictly increasing per row |
//! | values | 4·nnz | center coordinates, `f32` bits |
//! | state (v2) | 18 + 4·n + 8·k + 8·k·d (+32) | resumable training state, below |
//! | checksum | 8 | FNV-1a 64 over every preceding byte |
//!
//! **Version 2** carries the resumable [`TrainState`] between values and
//! checksum: `steps_done` (`u64`), `converged` (`u8`), `n` (`u64`), the
//! per-row assignments (`u32` each, all `< k`), per-cluster counts
//! (`u64` each), the unnormalized f64 sum accumulators (k·d `f64`
//! bits), and a schedule flag byte followed — for mini-batch states — by
//! the training schedule (`batch_size`, `epochs`, `tol`, `truncate`, 32
//! bytes) a resume must reproduce. The sums are what make a resumed run
//! **bit-identical** to an
//! uninterrupted one — the exact engines maintain them incrementally, so
//! they cannot be reconstructed from the f32 centers. Version-1 files
//! (serve-only models) remain byte-identical to what earlier builds
//! wrote and load with `state = None`.
//!
//! Centers are stored **sparse** (CSR) because converged text centers —
//! especially Knittel-style truncated ones — are mostly zeros; a coordinate
//! is stored whenever its `f32` bit pattern is non-zero, so a negative
//! zero survives the round trip and [`Model::save`] → [`Model::load`] is
//! **bit-exact** (asserted by the randomized `model` test suite).
//!
//! Loading rejects, with a typed [`ModelError`] rather than garbage data:
//! wrong magic ([`ModelError::BadMagic`]), files written by a future
//! format version ([`ModelError::UnsupportedVersion`]), files cut short
//! anywhere ([`ModelError::Truncated`]), and bodies whose checksum, CSR
//! invariants, value finiteness, or dense-reconstruction size bounds do
//! not hold ([`ModelError::Corrupt`]).

mod format;

pub use format::ModelError;

use crate::kmeans::{KMeansConfig, KMeansResult, TrainState};
use crate::sparse::DenseMatrix;
use std::path::Path;

/// How a persisted model was trained — carried verbatim through
/// save/load so a served model can always account for its provenance.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainingMeta {
    /// Algorithm variant name (e.g. `"Simp.Elkan"`, `"minibatch"`).
    pub variant: String,
    /// Resolved similarity-kernel backend the training run executed.
    pub kernel: String,
    /// Assignment iterations the run performed.
    pub iterations: u64,
    /// Final spherical k-means objective `Σᵢ (1 − ⟨xᵢ, c(a(i))⟩)`.
    pub objective: f64,
    /// RNG seed of the run.
    pub seed: u64,
}

/// A trained spherical k-means model: the unit centers plus training
/// metadata, with bit-exact binary persistence ([`Model::save`] /
/// [`Model::load`] — see the [module docs](self) for the format).
#[derive(Debug, Clone, PartialEq)]
pub struct Model {
    k: usize,
    d: usize,
    centers: DenseMatrix,
    norms: Vec<f64>,
    /// Cached count of non-zero center coordinates (by f32 bit pattern),
    /// so repeated [`Model::center_nnz`] calls never rescan the k×d
    /// matrix.
    nnz: usize,
    meta: TrainingMeta,
    /// Resumable training state (f64 sum accumulators, counts,
    /// assignments). `None` for serve-only models; persisted as the
    /// version-2 `.spkm` layout when present.
    state: Option<TrainState>,
}

impl Model {
    /// Wrap explicit centers (k×d, rows assumed unit-normalized) and
    /// metadata into a model. Per-center norms are computed here, once.
    pub fn new(centers: DenseMatrix, meta: TrainingMeta) -> Self {
        let (k, d) = (centers.rows(), centers.cols());
        let norms = (0..k)
            .map(|j| {
                centers
                    .row(j)
                    .iter()
                    .map(|&v| v as f64 * v as f64)
                    .sum::<f64>()
                    .sqrt()
            })
            .collect();
        let nnz = centers.data().iter().filter(|v| v.to_bits() != 0).count();
        Self { k, d, centers, norms, nnz, meta, state: None }
    }

    /// Attach (or remove) resumable training state. State-bearing models
    /// save in the version-2 `.spkm` layout; `None` keeps the version-1
    /// serve-only encoding.
    #[must_use]
    pub fn with_state(mut self, state: Option<TrainState>) -> Self {
        self.state = state;
        self
    }

    /// The resumable training state, when this model carries one.
    #[inline]
    pub fn state(&self) -> Option<&TrainState> {
        self.state.as_ref()
    }

    /// Build a model from a finished clustering run — what
    /// `cluster --save-model` persists. Provenance records
    /// `cfg.variant`; runs of the [`crate::kmeans::minibatch`] engine
    /// (which ignores the variant) should use [`Model::from_run_named`]
    /// with `"minibatch"` instead.
    pub fn from_run(result: &KMeansResult, cfg: &KMeansConfig) -> Self {
        Self::from_run_named(result, cfg, cfg.variant.name())
    }

    /// Like [`Model::from_run`], but recording an explicit engine name
    /// as the variant provenance — for runs whose trainer is not named
    /// by `cfg.variant` (the mini-batch engine).
    pub fn from_run_named(result: &KMeansResult, cfg: &KMeansConfig, variant: &str) -> Self {
        Self::new(
            result.centers.clone(),
            TrainingMeta {
                variant: variant.to_string(),
                kernel: result.kernel.name().to_string(),
                iterations: result.iterations as u64,
                objective: result.objective,
                seed: cfg.seed,
            },
        )
    }

    /// Number of clusters.
    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    /// Dimensionality (vocabulary size) the centers live in.
    #[inline]
    pub fn d(&self) -> usize {
        self.d
    }

    /// The unit-normalized centers (k×d).
    #[inline]
    pub fn centers(&self) -> &DenseMatrix {
        &self.centers
    }

    /// Per-center L2 norms recorded at construction (≈ 1 for unit
    /// centers; exactly 0 for a center that never received mass).
    #[inline]
    pub fn norms(&self) -> &[f64] {
        &self.norms
    }

    /// Training provenance.
    #[inline]
    pub fn meta(&self) -> &TrainingMeta {
        &self.meta
    }

    /// Total non-zero center coordinates — what the sparse CSR encoding
    /// stores, and what sizes the serving-side inverted index.
    #[inline]
    pub fn center_nnz(&self) -> usize {
        self.nnz
    }

    /// Fraction of stored center coordinates, `nnz / (k·d)`.
    pub fn center_density(&self) -> f64 {
        if self.k == 0 || self.d == 0 {
            return 0.0;
        }
        self.nnz as f64 / (self.k as f64 * self.d as f64)
    }

    /// Serialize to `path` in the `.spkm` format (see the
    /// [module docs](self)). The encoding is deterministic: saving the
    /// same model twice produces byte-identical files.
    pub fn save(&self, path: &Path) -> Result<(), ModelError> {
        std::fs::write(path, format::encode(self)?)?;
        Ok(())
    }

    /// Load a model saved by [`Model::save`], validating magic, version,
    /// structure, checksum, and CSR invariants — see [`ModelError`] for
    /// the rejection taxonomy. Center coordinates and norms round-trip
    /// bit-exactly.
    pub fn load(path: &Path) -> Result<Self, ModelError> {
        format::decode(&std::fs::read(path)?)
    }

    /// Load a model in **low-memory streaming mode**: the same format,
    /// validation order, and rejection taxonomy as [`Model::load`], but
    /// the file is decoded through a buffered reader instead of being
    /// materialized whole, and the version-2 training-state section —
    /// the dominant cost of a large state-bearing file (`4·n` assignment
    /// bytes plus `8·k·d` f64 sum bytes) — is checksum-verified and
    /// *skipped*. Peak transient memory is `O(k·d)` regardless of file
    /// size. The result is **serve-only**: [`Model::state`] is `None`,
    /// so it cannot seed a bit-identical resume — use [`Model::load`]
    /// for that. Centers, norms, and metadata are bit-identical to a
    /// full load of the same file.
    pub fn load_low_mem(path: &Path) -> Result<Self, ModelError> {
        format::decode_low_mem(path)
    }

    /// Assemble from decoded parts (crate-internal: the format layer's
    /// constructor after validation). `nnz` is the file's stored
    /// coordinate count, which by construction equals the non-zero-bit
    /// count of the reconstructed dense matrix.
    pub(crate) fn from_parts(
        k: usize,
        d: usize,
        centers: DenseMatrix,
        norms: Vec<f64>,
        nnz: usize,
        meta: TrainingMeta,
        state: Option<TrainState>,
    ) -> Self {
        Self { k, d, centers, norms, nnz, meta, state }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kmeans::{KMeansConfig, SphericalKMeans, Variant};

    #[test]
    fn from_run_records_provenance() {
        let ds = crate::data::synth::SynthConfig::small_demo().generate(7);
        let cfg = KMeansConfig::new(5).variant(Variant::SimplifiedElkan).seed(11).max_iter(20);
        let r = SphericalKMeans::new(5)
            .variant(Variant::SimplifiedElkan)
            .seed(11)
            .max_iter(20)
            .fit(&ds.matrix)
            .unwrap()
            .into_result();
        let m = Model::from_run(&r, &cfg);
        assert_eq!(m.k(), 5);
        assert_eq!(m.d(), ds.matrix.cols());
        assert_eq!(m.meta().variant, "Simp.Elkan");
        assert_eq!(m.meta().kernel, r.kernel.name());
        assert_eq!(m.meta().seed, 11);
        assert_eq!(m.meta().iterations, r.iterations as u64);
        assert_eq!(m.meta().objective.to_bits(), r.objective.to_bits());
        // Unit centers ⇒ norms ≈ 1 (or exactly 0 for empty clusters).
        for &n in m.norms() {
            assert!(n == 0.0 || (n - 1.0).abs() < 1e-3, "norm {n}");
        }
        assert!(m.center_nnz() <= 5 * ds.matrix.cols());
        assert!(m.center_density() <= 1.0);
    }
}
