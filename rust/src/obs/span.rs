//! Phase-scoped timing spans: where each iteration's wall-clock goes.
//!
//! A span is opened with [`span_start`] at an existing iteration barrier
//! (no new synchronization is introduced) and closed by charging its
//! elapsed time to one [`Phase`] of a [`PhaseTimes`] table. With the
//! `trace` feature off, [`span_start`] const-folds to `None` and every
//! `record` call compiles to nothing; with it on, the only added work is
//! a monotonic-clock read at each barrier — far outside the per-point
//! hot loops, so results stay bit-identical either way.
//!
//! The per-iteration tables live on
//! [`IterStats::phases`](crate::kmeans::IterStats); run-level totals
//! (plus the pre-loop seeding span) come from
//! [`RunStats::phase_totals`](crate::kmeans::RunStats). Phase timings
//! are measured on the coordinating thread between barriers, so the
//! barrier phases (seeding, assignment, bounds, update, index refresh)
//! of one fit are disjoint and sum to fit wall-clock minus loop
//! overhead. [`Phase::ShardIo`] is the exception: chunk loads happen
//! *inside* the sharded assignment pass across worker threads, so its
//! time overlaps [`Phase::Assignment`] and is reported separately (see
//! [`crate::obs::metrics`]) rather than added to the disjoint sum.

use std::time::Instant;

use super::TRACE_ENABLED;
use crate::util::json::Json;

/// The phases of a fit whose wall-clock is tracked separately. Ordered
/// as reported; [`Phase::name`] gives the stable snake_case key used in
/// trace and metrics JSON.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Initial center seeding (uniform, k-means++, AFK-MC²) before the
    /// first assignment pass. Charged once per run, not per iteration.
    Seeding,
    /// The sharded per-point assignment pass over the Plan/Pool
    /// executor, including the bound tests fused into it.
    Assignment,
    /// Serial per-iteration bound maintenance before the assignment
    /// pass: center-center bound recomputation, `p`-extreme scans,
    /// neighbor-list rebuilds, group extreme reductions.
    Bounds,
    /// Center update at the iteration barrier: move replay
    /// (`merge_shards`), incremental sum maintenance, and the f32
    /// center renormalization.
    Update,
    /// Refreshing the kernel's center store for dirty centers: the
    /// dense transpose columns or the inverted-file postings (including
    /// bulk rebuilds after truncation).
    IndexRefresh,
    /// Chunk loads from the on-disk shard store. Measured across worker
    /// threads inside the assignment pass, so this phase *overlaps*
    /// [`Phase::Assignment`] instead of adding to the disjoint
    /// barrier-phase sum.
    ShardIo,
}

impl Phase {
    /// All phases, in reporting order.
    pub const ALL: [Phase; 6] = [
        Phase::Seeding,
        Phase::Assignment,
        Phase::Bounds,
        Phase::Update,
        Phase::IndexRefresh,
        Phase::ShardIo,
    ];

    /// Stable snake_case name used as the JSON key in trace records and
    /// metrics dumps. Part of the [`TRACE_SCHEMA`](super::TRACE_SCHEMA)
    /// contract — do not rename without a schema version bump.
    pub fn name(self) -> &'static str {
        match self {
            Phase::Seeding => "seeding",
            Phase::Assignment => "assignment",
            Phase::Bounds => "bounds",
            Phase::Update => "update",
            Phase::IndexRefresh => "index_refresh",
            Phase::ShardIo => "shard_io",
        }
    }
}

/// Open a timing span: the capture instant under the `trace` feature,
/// `None` (const-folded, zero cost) otherwise. Close it by passing the
/// result to [`PhaseTimes::record`] or
/// [`crate::obs::metrics::record_shard_io`].
#[inline]
pub fn span_start() -> Option<Instant> {
    if TRACE_ENABLED {
        Some(Instant::now())
    } else {
        None
    }
}

/// Milliseconds elapsed since a [`span_start`] capture; `0.0` when the
/// span was disabled.
#[inline]
pub fn span_ms(span: Option<Instant>) -> f64 {
    match span {
        Some(t) => t.elapsed().as_secs_f64() * 1e3,
        None => 0.0,
    }
}

/// Accumulated wall-clock milliseconds per [`Phase`]. All-zero when the
/// `trace` feature is off (the table itself is always present so the
/// stats structs keep one shape in every build).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseTimes {
    ms: [f64; 6],
}

impl PhaseTimes {
    /// Charge the elapsed time of a span opened with [`span_start`] to
    /// `phase`. No-op (and compiled out) when the span is `None`.
    #[inline]
    pub fn record(&mut self, phase: Phase, span: Option<Instant>) {
        if let Some(t) = span {
            self.ms[phase as usize] += t.elapsed().as_secs_f64() * 1e3;
        }
    }

    /// Add `ms` milliseconds to `phase` directly.
    #[inline]
    pub fn add(&mut self, phase: Phase, ms: f64) {
        self.ms[phase as usize] += ms;
    }

    /// Reattribute `ms` milliseconds from one phase to another: used
    /// when a finer-grained sub-measurement (e.g. the index refresh
    /// inside the center update) must be carved out of an enclosing
    /// span without double counting.
    #[inline]
    pub fn shift(&mut self, from: Phase, to: Phase, ms: f64) {
        self.ms[from as usize] -= ms;
        self.ms[to as usize] += ms;
    }

    /// Accumulated milliseconds charged to `phase`.
    #[inline]
    pub fn get(&self, phase: Phase) -> f64 {
        self.ms[phase as usize]
    }

    /// Element-wise accumulate another table into this one.
    pub fn merge(&mut self, other: &PhaseTimes) {
        for (a, b) in self.ms.iter_mut().zip(other.ms.iter()) {
            *a += *b;
        }
    }

    /// Sum of the disjoint barrier phases (everything except
    /// [`Phase::ShardIo`], which overlaps the assignment pass). This is
    /// the quantity that accounts for fit wall-clock.
    pub fn barrier_ms(&self) -> f64 {
        Phase::ALL
            .iter()
            .filter(|&&p| p != Phase::ShardIo)
            .map(|&p| self.get(p))
            .sum()
    }

    /// Sum over all phases, including the overlapping shard I/O.
    pub fn total_ms(&self) -> f64 {
        self.ms.iter().sum()
    }

    /// True when no time has been charged to any phase (always the case
    /// with the `trace` feature off).
    pub fn is_zero(&self) -> bool {
        self.ms.iter().all(|&m| m == 0.0)
    }

    /// Render as a JSON object `{phase_name: ms, …}` with every phase
    /// present, in [`Phase::ALL`] order.
    pub fn to_json(&self) -> Json {
        Json::Obj(
            Phase::ALL
                .iter()
                .map(|&p| (p.name().to_string(), Json::Num(self.get(p))))
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_charges_only_under_trace() {
        let mut t = PhaseTimes::default();
        t.record(Phase::Assignment, span_start());
        if TRACE_ENABLED {
            assert!(t.get(Phase::Assignment) >= 0.0);
        } else {
            assert!(t.is_zero());
        }
    }

    #[test]
    fn add_merge_and_totals() {
        let mut a = PhaseTimes::default();
        a.add(Phase::Seeding, 1.0);
        a.add(Phase::Assignment, 2.0);
        a.add(Phase::ShardIo, 10.0);
        let mut b = PhaseTimes::default();
        b.add(Phase::Assignment, 3.0);
        b.add(Phase::Update, 4.0);
        a.merge(&b);
        assert_eq!(a.get(Phase::Assignment), 5.0);
        assert_eq!(a.get(Phase::Update), 4.0);
        assert_eq!(a.barrier_ms(), 10.0);
        assert_eq!(a.total_ms(), 20.0);
        assert!(!a.is_zero());
    }

    #[test]
    fn shift_reattributes_without_changing_total() {
        let mut t = PhaseTimes::default();
        t.add(Phase::Update, 10.0);
        t.shift(Phase::Update, Phase::IndexRefresh, 4.0);
        assert_eq!(t.get(Phase::Update), 6.0);
        assert_eq!(t.get(Phase::IndexRefresh), 4.0);
        assert_eq!(t.total_ms(), 10.0);
    }

    #[test]
    fn json_carries_every_phase_in_order() {
        let mut t = PhaseTimes::default();
        t.add(Phase::Bounds, 2.5);
        let j = t.to_json();
        let obj = j.as_obj().expect("object");
        assert_eq!(obj.len(), Phase::ALL.len());
        let names: Vec<&str> = obj.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(
            names,
            vec!["seeding", "assignment", "bounds", "update", "index_refresh", "shard_io"]
        );
        assert_eq!(j.get("bounds").and_then(Json::as_f64), Some(2.5));
    }

    #[test]
    fn phase_names_are_stable() {
        // Schema contract: these strings appear in trace JSON.
        let names: Vec<&str> = Phase::ALL.iter().map(|p| p.name()).collect();
        assert_eq!(
            names,
            vec!["seeding", "assignment", "bounds", "update", "index_refresh", "shard_io"]
        );
    }
}
