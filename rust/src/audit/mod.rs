//! Bound-certification audit layer: runtime cross-checking of every
//! pruning decision and deep data-structure invariant checking.
//!
//! Every speedup in the paper rests on one silent assumption: the adapted
//! Elkan/Hamerly cosine bounds really do bound the true similarities, so
//! every skipped dot product was safe to skip. The equivalence test suites
//! catch a wrong bound only when it happens to change a final assignment
//! on the sampled inputs; this module instead *certifies each pruning
//! decision at the moment it is taken*. Under the `audit` cargo feature,
//! every bound-based skip in the seven exact engines and in the serve-side
//! MaxScore traversal is cross-checked against the exactly recomputed
//! cosine, and the shared data structures ([`crate::sparse::CsrMatrix`],
//! [`crate::kmeans::Centers`], [`crate::sparse::InvertedIndex`]) re-verify
//! their invariants at every iteration barrier.
//!
//! # What a violation carries
//!
//! A failed check produces a typed [`AuditViolation`] with full context —
//! component, check name, iteration, point, center, the bound the engine
//! trusted, and the exactly recomputed value. Violations surface through
//! [`FitError::AuditViolation`](crate::kmeans::FitError) from
//! [`SphericalKMeans::fit`](crate::kmeans::SphericalKMeans), through the
//! [`Observer`](crate::kmeans::Observer) hook
//! ([`IterSnapshot::audit_violations`](crate::kmeans::IterSnapshot)), and
//! through the `cluster --audit` CLI flag. The serve-side traversal has no
//! error channel, so a pruning violation there panics with the violation's
//! [`Display`](std::fmt::Display) rendering (a query answer built on an
//! unsound prune must not be returned).
//!
//! # Zero cost when off
//!
//! Instrumentation is gated on the compile-time constant
//! [`AUDIT_ENABLED`] (`cfg!(feature = "audit")`) rather than on `#[cfg]`
//! blocks: the audit code type-checks in every build, and when the feature
//! is off every check sits behind `if false` and is compiled out — the
//! collection `Vec`s stay empty (an empty `Vec` never allocates) and the
//! hot loops are bit-for-bit the instructions of an unaudited build. With
//! the feature **on**, audited runs still produce bit-identical results,
//! assignments, and instrumentation counters to unaudited runs, because
//! every cross-check recomputes its reference cosine outside the counted
//! similarity paths; only wall-clock changes (an audited run does strictly
//! more floating-point work — it is a verification mode, not a production
//! mode).
//!
//! # The audit margin
//!
//! Cross-checks tolerate [`AUDIT_MARGIN`] (`1e-7`) of float slack: the
//! engines' bound maintenance accumulates rounding of that order across
//! iterations, while a genuinely broken bound — the mutation-test bar is
//! a margin loosened by `1e-3` — overshoots it by four orders of
//! magnitude. The margin separates arithmetic noise from unsound algebra.

/// True when the crate was compiled with the `audit` cargo feature —
/// the single gate every instrumentation site branches on. A constant,
/// so disabled audit code is removed at compile time.
pub const AUDIT_ENABLED: bool = cfg!(feature = "audit");

/// Float tolerance applied by every bound cross-check: an exactly
/// recomputed cosine may exceed an upper bound (or undershoot a lower
/// bound) by at most this much before the check reports a violation.
/// Large enough for accumulated f64 rounding in the bound-update chains,
/// four orders of magnitude below the `1e-3` mutation-test bar.
pub const AUDIT_MARGIN: f64 = 1e-7;

/// One failed audit check, with enough context to localize the unsound
/// bound or broken invariant: which component and check, at which
/// iteration, for which point/center pair, what the engine believed
/// (`bound`) and what is actually true (`actual`).
#[derive(Debug, Clone, PartialEq)]
pub struct AuditViolation {
    /// The component that took the audited decision: an engine name
    /// (`"elkan"`, `"yinyang"`, …), `"serve"` for the MaxScore traversal,
    /// or a data-structure name (`"csr"`, `"centers"`, `"inverted"`) for
    /// invariant checks.
    pub component: &'static str,
    /// Which check failed (e.g. `"upper-bound-prune"`, `"lower-bound"`,
    /// `"unsafe-prune"`, `"sums-centers-coherence"`).
    pub check: &'static str,
    /// Iteration (or epoch) at which the violation was detected;
    /// iteration 0 is the initial assignment pass. Zero for checks with
    /// no iteration context (ingestion-time invariants).
    pub iteration: usize,
    /// Row index of the point whose pruning decision failed, when the
    /// check concerns a specific point.
    pub point: Option<usize>,
    /// Center index the failed check concerns, when applicable.
    pub center: Option<usize>,
    /// The bound value the pruning decision trusted (`0.0` for pure
    /// invariant checks, which have no bound).
    pub bound: f64,
    /// The exactly recomputed value that contradicts the bound (`0.0`
    /// for pure invariant checks).
    pub actual: f64,
    /// Free-form context: what the invariant expected, indices involved,
    /// or which structural property broke.
    pub detail: String,
}

impl AuditViolation {
    /// A bound-certification violation: `bound` was trusted, but the
    /// exactly recomputed `actual` contradicts it beyond [`AUDIT_MARGIN`].
    pub fn bound(
        component: &'static str,
        check: &'static str,
        iteration: usize,
        point: Option<usize>,
        center: Option<usize>,
        bound: f64,
        actual: f64,
    ) -> Self {
        Self {
            component,
            check,
            iteration,
            point,
            center,
            bound,
            actual,
            detail: String::new(),
        }
    }

    /// A data-structure invariant violation (no bound/actual pair; the
    /// broken property is described by `detail`).
    pub fn invariant(component: &'static str, check: &'static str, detail: String) -> Self {
        Self {
            component,
            check,
            iteration: 0,
            point: None,
            center: None,
            bound: 0.0,
            actual: 0.0,
            detail,
        }
    }

    /// Attach the iteration at which the violation was detected.
    #[must_use]
    pub fn at_iteration(mut self, iteration: usize) -> Self {
        self.iteration = iteration;
        self
    }
}

impl std::fmt::Display for AuditViolation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "audit violation [{}/{}] at iteration {}",
            self.component, self.check, self.iteration
        )?;
        if let Some(p) = self.point {
            write!(f, ", point {p}")?;
        }
        if let Some(c) = self.center {
            write!(f, ", center {c}")?;
        }
        if self.bound != 0.0 || self.actual != 0.0 {
            write!(
                f,
                ": bound {:.9} vs exact {:.9} (excess {:.3e}, margin {AUDIT_MARGIN:.0e})",
                self.bound,
                self.actual,
                (self.actual - self.bound).abs()
            )?;
        }
        if !self.detail.is_empty() {
            write!(f, ": {}", self.detail)?;
        }
        Ok(())
    }
}

/// Does an exactly recomputed similarity `actual` contradict the upper
/// bound `bound` beyond the audit margin?
#[inline]
pub fn exceeds_upper(bound: f64, actual: f64) -> bool {
    actual > bound + AUDIT_MARGIN
}

/// Does an exactly recomputed similarity `actual` contradict the lower
/// bound `bound` beyond the audit margin?
#[inline]
pub fn below_lower(bound: f64, actual: f64) -> bool {
    actual < bound - AUDIT_MARGIN
}

/// Debug-build invariant assertion with audit context: the replacement for
/// the bare `debug_assert!`s that used to guard internal preconditions in
/// the bound algebra. On failure it panics with an [`AuditViolation`]'s
/// rendering — component, check, and a detail string built lazily — so a
/// tripped precondition says *which* invariant broke and with what values,
/// instead of pointing at an assertion line. Compiled out of release
/// builds exactly like `debug_assert!`.
#[inline]
pub fn debug_invariant<F: FnOnce() -> String>(
    cond: bool,
    component: &'static str,
    check: &'static str,
    detail: F,
) {
    if cfg!(debug_assertions) && !cond {
        let v = AuditViolation::invariant(component, check, detail());
        panic!("{v}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn margin_separates_rounding_noise_from_mutations() {
        // Accumulated float rounding (≤ ~1e-9 on these chains) passes…
        assert!(!exceeds_upper(0.5, 0.5 + 1e-9));
        assert!(!below_lower(0.5, 0.5 - 1e-9));
        assert!(!exceeds_upper(0.5, 0.5));
        // …while the mutation-test bar (a bound loosened by 1e-3) trips
        // with four orders of magnitude to spare.
        assert!(exceeds_upper(0.5, 0.5 + 1e-3));
        assert!(below_lower(0.5, 0.5 - 1e-3));
        assert!(exceeds_upper(0.5, 0.5 + 10.0 * AUDIT_MARGIN));
    }

    #[test]
    fn display_carries_full_context() {
        let v = AuditViolation::bound("elkan", "upper-bound-prune", 3, Some(17), Some(4), 0.25, 0.5);
        let s = v.to_string();
        assert!(s.contains("elkan/upper-bound-prune"), "{s}");
        assert!(s.contains("iteration 3"), "{s}");
        assert!(s.contains("point 17"), "{s}");
        assert!(s.contains("center 4"), "{s}");
        assert!(s.contains("0.250000000"), "{s}");
        assert!(s.contains("0.500000000"), "{s}");
    }

    #[test]
    fn invariant_violations_render_their_detail() {
        let v = AuditViolation::invariant("csr", "indptr-monotone", "indptr[3]=7 > indptr[4]=5".to_string());
        let s = v.to_string();
        assert!(s.contains("csr/indptr-monotone"), "{s}");
        assert!(s.contains("indptr[3]=7 > indptr[4]=5"), "{s}");
        // Clone + PartialEq: the FitError payload contract.
        assert_eq!(v.clone(), v);
    }

    #[test]
    fn at_iteration_stamps_context() {
        let v = AuditViolation::invariant("centers", "unit-norm", "norm=0.9".to_string()).at_iteration(7);
        assert_eq!(v.iteration, 7);
        assert!(v.to_string().contains("iteration 7"));
    }

    #[test]
    fn debug_invariant_passes_silently() {
        debug_invariant(true, "bounds::hamerly", "p_min<=p_max", || unreachable!());
    }

    #[test]
    #[cfg_attr(not(debug_assertions), ignore = "debug_invariant is compiled out in release")]
    fn debug_invariant_panics_with_context() {
        let err = std::panic::catch_unwind(|| {
            debug_invariant(false, "bounds::cc", "k-matches-rows", || {
                "rows=3 expected k=4".to_string()
            });
        })
        .expect_err("must panic under debug assertions");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("bounds::cc/k-matches-rows"), "{msg}");
        assert!(msg.contains("rows=3 expected k=4"), "{msg}");
    }
}
