//! Synthetic text-corpus generator with planted topic structure.
//!
//! Documents are bags of tokens drawn from a Zipfian vocabulary: a shared
//! "common word" head plus per-topic vocabulary blocks. This reproduces the
//! statistics that matter for the paper's acceleration behaviour —
//! high dimensionality, extreme sparsity, power-law token frequencies, and
//! cluster structure that spherical k-means can actually find — without the
//! original corpora. Optional anomalous documents (long, drawn from the
//! rare tail) model the base64-junk documents of 20 Newsgroups that make
//! k-means++ seeding *worse* there (Table 2).

use super::tfidf::TfIdf;
use super::Dataset;
use crate::sparse::{CsrMatrix, SparseVec};
use crate::util::rng::{Xoshiro256, Zipf};

/// Configuration for the corpus generator.
#[derive(Debug, Clone)]
pub struct SynthConfig {
    /// Dataset name for reports.
    pub name: String,
    /// Number of documents (rows).
    pub n_docs: usize,
    /// Total vocabulary size (columns).
    pub vocab: usize,
    /// Number of planted topics.
    pub topics: usize,
    /// Mean number of token draws per document.
    pub doc_len_mean: f64,
    /// Log-normal sigma of the document length distribution.
    pub doc_len_sigma: f64,
    /// Fraction of a document's tokens drawn from its topic block
    /// (the rest come from the shared head). Higher = cleaner clusters.
    pub topic_strength: f64,
    /// Fraction of the vocabulary shared across topics (the Zipf head).
    pub shared_vocab_frac: f64,
    /// Zipf exponent for token draws (≈1.1 for natural text).
    pub zipf_s: f64,
    /// Fraction of documents replaced by anomalies (rare-tail junk docs).
    pub anomaly_frac: f64,
    /// TF-IDF weighting to apply.
    pub tfidf: TfIdf,
}

impl SynthConfig {
    /// A tiny corpus for unit tests and doc examples (≈300 docs).
    pub fn small_demo() -> Self {
        Self {
            name: "small-demo".into(),
            n_docs: 300,
            vocab: 800,
            topics: 8,
            doc_len_mean: 40.0,
            doc_len_sigma: 0.4,
            topic_strength: 0.7,
            shared_vocab_frac: 0.25,
            zipf_s: 1.1,
            anomaly_frac: 0.0,
            tfidf: TfIdf::default(),
        }
    }

    /// Generate the corpus deterministically from `seed`.
    pub fn generate(&self, seed: u64) -> Dataset {
        assert!(self.topics >= 1);
        assert!(self.vocab >= self.topics + 1);
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let shared = ((self.vocab as f64 * self.shared_vocab_frac) as usize)
            .clamp(1, self.vocab - self.topics);
        let per_topic = (self.vocab - shared) / self.topics;
        assert!(per_topic >= 1, "vocabulary too small for topic count");

        let shared_zipf = Zipf::new(shared, self.zipf_s);
        let topic_zipf = Zipf::new(per_topic, self.zipf_s);
        // Anomalies draw uniformly from the rarest third of the vocabulary.
        let tail_start = self.vocab - (self.vocab / 3).max(1);

        let n_anomalies = (self.n_docs as f64 * self.anomaly_frac) as usize;
        let mut rows = Vec::with_capacity(self.n_docs);
        let mut labels = Vec::with_capacity(self.n_docs);
        for doc in 0..self.n_docs {
            let topic = rng.index(self.topics);
            let len = (self.doc_len_mean
                * (self.doc_len_sigma * rng.next_gaussian()).exp())
            .round()
            .max(3.0) as usize;
            let mut pairs: Vec<(u32, f32)> = Vec::with_capacity(len);
            if doc < n_anomalies {
                // Anomalous doc (the 20news base64-junk effect): long, and
                // drawn from a *private* window of the rare tail so
                // anomalies are near-orthogonal to the corpus AND to each
                // other — a k-means++ seed landing on one is wasted, which
                // is how the paper explains Table 2's 20news rows.
                let tail_len = self.vocab - tail_start;
                let window = (tail_len / n_anomalies.max(1)).max(8);
                let start = tail_start + (doc * window) % tail_len.max(1);
                let alen = len * 4;
                for _ in 0..alen {
                    let tok = (start + rng.index(window)).min(self.vocab - 1);
                    pairs.push((tok as u32, 1.0));
                }
                labels.push(self.topics as u32); // distinct "junk" label
            } else {
                for _ in 0..len {
                    let tok = if rng.next_f64() < self.topic_strength {
                        shared + topic * per_topic + topic_zipf.sample(&mut rng)
                    } else {
                        shared_zipf.sample(&mut rng)
                    };
                    pairs.push((tok as u32, 1.0));
                }
                labels.push(topic as u32);
            }
            rows.push(SparseVec::from_pairs(self.vocab, pairs));
        }
        let counts = CsrMatrix::from_rows(self.vocab, &rows);
        let matrix = self.tfidf.apply(&counts);
        Dataset {
            name: self.name.clone(),
            matrix,
            labels: Some(labels),
        }
    }

    /// Expected non-zero density for rough shape matching: the generator is
    /// stochastic, so this is a heuristic (distinct tokens per doc / vocab).
    pub fn approx_density(&self) -> f64 {
        // Zipf draws repeat; distinct ≈ 0.7·len for s ≈ 1.1 over a large
        // vocabulary (empirical, see tests::density_heuristic_is_close).
        0.7 * self.doc_len_mean / self.vocab as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_is_deterministic() {
        let cfg = SynthConfig::small_demo();
        let a = cfg.generate(5);
        let b = cfg.generate(5);
        assert_eq!(a.matrix, b.matrix);
        assert_eq!(a.labels, b.labels);
        let c = cfg.generate(6);
        assert_ne!(a.matrix, c.matrix);
    }

    #[test]
    fn rows_are_unit_normalized() {
        let ds = SynthConfig::small_demo().generate(1);
        for r in 0..ds.matrix.rows() {
            let n = ds.matrix.row(r).norm_sq();
            assert!((n - 1.0).abs() < 1e-5, "row {r} norm² {n}");
        }
    }

    #[test]
    fn shape_matches_config() {
        let cfg = SynthConfig::small_demo();
        let ds = cfg.generate(2);
        assert_eq!(ds.matrix.rows(), cfg.n_docs);
        assert_eq!(ds.matrix.cols(), cfg.vocab);
        assert_eq!(ds.labels.as_ref().unwrap().len(), cfg.n_docs);
    }

    #[test]
    fn topic_structure_is_present() {
        // Same-topic documents must be more similar on average than
        // cross-topic documents.
        let ds = SynthConfig::small_demo().generate(3);
        let labels = ds.labels.as_ref().unwrap();
        let mut same = (0.0, 0usize);
        let mut diff = (0.0, 0usize);
        for i in (0..ds.matrix.rows()).step_by(3) {
            for j in ((i + 1)..ds.matrix.rows()).step_by(7) {
                let s = ds.matrix.row(i).dot(&ds.matrix.row(j));
                if labels[i] == labels[j] {
                    same = (same.0 + s, same.1 + 1);
                } else {
                    diff = (diff.0 + s, diff.1 + 1);
                }
            }
        }
        let same_avg = same.0 / same.1 as f64;
        let diff_avg = diff.0 / diff.1 as f64;
        assert!(
            same_avg > diff_avg + 0.05,
            "same-topic {same_avg:.4} vs cross-topic {diff_avg:.4}"
        );
    }

    #[test]
    fn anomalies_are_near_orthogonal_to_normal_docs() {
        let mut cfg = SynthConfig::small_demo();
        cfg.anomaly_frac = 0.05;
        let ds = cfg.generate(4);
        let n_anom = (cfg.n_docs as f64 * 0.05) as usize;
        let mut max_sim = 0.0f64;
        for a in 0..n_anom {
            for i in (n_anom..cfg.n_docs).step_by(11) {
                max_sim = max_sim.max(ds.matrix.row(a).dot(&ds.matrix.row(i)));
            }
        }
        assert!(max_sim < 0.5, "anomaly too similar to corpus: {max_sim}");
        // The k-means++-wasted-seed effect needs anomalies that are also
        // dissimilar to EACH OTHER (private tail windows).
        let mut mean_aa = 0.0;
        let mut pairs = 0;
        for a in 0..n_anom {
            for b in (a + 1)..n_anom {
                mean_aa += ds.matrix.row(a).dot(&ds.matrix.row(b));
                pairs += 1;
            }
        }
        mean_aa /= pairs.max(1) as f64;
        assert!(mean_aa < 0.2, "anomalies too similar to each other: {mean_aa}");
        assert_eq!(ds.labels.as_ref().unwrap()[0], cfg.topics as u32);
    }

    #[test]
    fn density_heuristic_is_close() {
        let cfg = SynthConfig {
            n_docs: 400,
            vocab: 5000,
            doc_len_mean: 60.0,
            ..SynthConfig::small_demo()
        };
        let ds = cfg.generate(9);
        let actual = ds.matrix.density();
        let predicted = cfg.approx_density();
        assert!(
            (actual / predicted - 1.0).abs() < 0.5,
            "density {actual:.5} vs predicted {predicted:.5}"
        );
    }
}
