//! Table rendering for the experiment drivers: aligned plain-text tables
//! (paper-style) plus CSV export for plotting.

/// A simple column-aligned table builder.
#[derive(Debug, Clone, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Start a table with the given column headers.
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match the header arity).
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells);
        self
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True if no data rows have been added.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render with aligned columns (first column left-aligned, the rest
    /// right-aligned, like the paper's tables).
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (c, h) in self.header.iter().enumerate() {
            width[c] = width[c].max(h.len());
        }
        for row in &self.rows {
            for (c, cell) in row.iter().enumerate() {
                width[c] = width[c].max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            let mut line = String::new();
            for (c, cell) in cells.iter().enumerate() {
                if c > 0 {
                    line.push_str("  ");
                }
                if c == 0 {
                    line.push_str(&format!("{:<w$}", cell, w = width[c]));
                } else {
                    line.push_str(&format!("{:>w$}", cell, w = width[c]));
                }
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &width));
        out.push('\n');
        let total: usize = width.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &width));
            out.push('\n');
        }
        out
    }

    /// Render as CSV.
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(
            &self
                .header
                .iter()
                .map(|h| esc(h))
                .collect::<Vec<_>>()
                .join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Write the CSV next to stdout reporting (for plotting).
    pub fn save_csv(&self, path: &std::path::Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_csv())
    }
}

/// Format milliseconds like the paper's Table 3 (thousands separators).
pub fn fmt_ms(ms: f64) -> String {
    let v = ms.round() as i64;
    let s = v.abs().to_string();
    let mut out = String::new();
    for (i, ch) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(ch);
    }
    if v < 0 {
        format!("-{out}")
    } else {
        out
    }
}

/// Format a relative change as a signed percentage (Table 2 style).
pub fn fmt_pct(frac: f64) -> String {
    format!("{:+.2}%", frac * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["Data set", "k=2", "k=10"]);
        t.row(vec!["Simpsons".into(), "166".into(), "457".into()]);
        t.row(vec!["RCV-1".into(), "24,569".into(), "153,170".into()]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("Data set"));
        assert!(lines[3].contains("24,569"));
        // Right alignment: k=2 column values end at the same offset.
        assert_eq!(t.len(), 2);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x,y".into(), "q\"z".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"z\""));
    }

    #[test]
    fn ms_formatting() {
        assert_eq!(fmt_ms(166.4), "166");
        assert_eq!(fmt_ms(1646.0), "1,646");
        assert_eq!(fmt_ms(6064203.0), "6,064,203");
        assert_eq!(fmt_ms(-1500.0), "-1,500");
    }

    #[test]
    fn pct_formatting() {
        assert_eq!(fmt_pct(-0.0027), "-0.27%");
        assert_eq!(fmt_pct(0.0734), "+7.34%");
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
