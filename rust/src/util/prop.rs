//! A small property-based testing framework (the offline registry has no
//! `proptest`/`quickcheck`). It covers what this crate needs: run a property
//! over many deterministic pseudo-random cases, and on failure report the
//! case index and seed so the exact input can be regenerated.
//!
//! ```
//! use sphkm::util::prop::{forall, Gen};
//! forall(100, 0xC0FFEE, |g| {
//!     let x = g.f64_in(-1.0, 1.0);
//!     assert!(x.abs() <= 1.0);
//! });
//! ```

use super::rng::Xoshiro256;

/// Case generator handed to properties; wraps a seeded RNG with convenience
/// samplers for the domains used in this crate (unit vectors, sparse vectors,
/// similarities in `[-1, 1]`, …).
pub struct Gen {
    rng: Xoshiro256,
    /// Index of the current case (0-based), for shrink-free diagnostics.
    pub case: usize,
}

impl Gen {
    /// Access the underlying RNG.
    pub fn rng(&mut self) -> &mut Xoshiro256 {
        &mut self.rng
    }

    /// Uniform `f64` in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.rng.next_f64()
    }

    /// Uniform integer in `[lo, hi)`.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.rng.index(hi - lo)
    }

    /// A cosine-similarity-like value in `[-1, 1]`.
    pub fn sim(&mut self) -> f64 {
        self.f64_in(-1.0, 1.0)
    }

    /// A random dense vector of dimension `d` with standard normal entries.
    pub fn dense(&mut self, d: usize) -> Vec<f64> {
        (0..d).map(|_| self.rng.next_gaussian()).collect()
    }

    /// A random *unit* vector of dimension `d` (uniform on the sphere).
    pub fn unit(&mut self, d: usize) -> Vec<f64> {
        loop {
            let v = self.dense(d);
            let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
            if norm > 1e-9 {
                return v.into_iter().map(|x| x / norm).collect();
            }
        }
    }

    /// A random *non-negative* unit vector (TF-IDF document vectors are
    /// non-negative, which is the regime the paper's data lives in).
    pub fn nonneg_unit(&mut self, d: usize) -> Vec<f64> {
        loop {
            let v: Vec<f64> = (0..d).map(|_| self.rng.next_f64()).collect();
            let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
            if norm > 1e-9 {
                return v.into_iter().map(|x| x / norm).collect();
            }
        }
    }

    /// A random sparse pattern: `nnz` distinct sorted indices below `d`.
    pub fn sparse_pattern(&mut self, d: usize, nnz: usize) -> Vec<usize> {
        let mut idx = self.rng.sample_distinct(d, nnz.min(d));
        idx.sort_unstable();
        idx
    }
}

/// Run `property` over `cases` generated cases derived from `seed`.
/// Panics (with case/seed diagnostics) if the property panics for any case.
pub fn forall<F: Fn(&mut Gen)>(cases: usize, seed: u64, property: F) {
    for case in 0..cases {
        let mut g = Gen {
            rng: Xoshiro256::substream(seed, case as u64),
            case,
        };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| property(&mut g)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!("property failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivially() {
        forall(50, 1, |g| {
            let x = g.f64_in(0.0, 1.0);
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    #[should_panic(expected = "property failed at case")]
    fn forall_reports_failing_case() {
        forall(50, 2, |g| {
            let x = g.usize_in(0, 100);
            assert!(x < 90, "x={x}");
        });
    }

    #[test]
    fn unit_vectors_are_unit() {
        forall(100, 3, |g| {
            let d = g.usize_in(1, 64);
            let v = g.unit(d);
            let n: f64 = v.iter().map(|x| x * x).sum::<f64>().sqrt();
            assert!((n - 1.0).abs() < 1e-9, "norm {n}");
        });
    }

    #[test]
    fn sparse_pattern_sorted_distinct() {
        forall(100, 4, |g| {
            let d = g.usize_in(1, 500);
            let nnz = g.usize_in(0, d + 1);
            let p = g.sparse_pattern(d, nnz);
            assert!(p.windows(2).all(|w| w[0] < w[1]));
            assert!(p.iter().all(|&i| i < d));
        });
    }
}
