//! Center–center pruning bounds (§5.2, §5.4).
//!
//! Elkan's extra pruning rule compares the point's lower bound against half
//! the angle between its center and every other center. With similarities,
//! "half the angle" is `cos(½·arccos(s))`, which simplifies to
//! `√((s + 1)/2)` — no trigonometric calls needed. The paper derives that a
//! point with lower bound `l(i) ≥ cc(a(i), j)` cannot be reassigned to `j`,
//! and with `s(i) = max_{j≠i} cc(i,j)`, `l(i) ≥ s(a(i))` skips the whole
//! inner loop over centers.

use crate::sparse::DenseMatrix;

/// `cc(s) = cos(θ/2) = √((s+1)/2)` for a center–center similarity `s`.
#[inline(always)]
pub fn half_angle_cos(s: f64) -> f64 {
    ((super::clamp_sim(s) + 1.0) * 0.5).sqrt()
}

/// Pairwise center–center half-angle bounds plus the per-center maximum
/// `s(i) = max_{j≠i} cc(i,j)`.
///
/// Storage is a full `k × k` row-major matrix (the paper notes the
/// `O(k²)` similarity computations per iteration are exactly what makes
/// full Elkan/Hamerly expensive in high dimensions — we reproduce that
/// cost faithfully and measure it in the Fig. 2 ablation).
#[derive(Debug, Clone)]
pub struct CenterBounds {
    k: usize,
    /// Row-major `k × k` matrix of `cc(i,j)`; diagonal is 1.
    cc: Vec<f64>,
    /// `s(i) = max_{j≠i} cc(i,j)`.
    s: Vec<f64>,
}

impl CenterBounds {
    /// Allocate for `k` centers.
    pub fn new(k: usize) -> Self {
        Self { k, cc: vec![0.0; k * k], s: vec![0.0; k] }
    }

    /// Number of centers.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Recompute all pairwise bounds from the (unit-normalized) centers.
    /// Returns the number of center–center similarity computations
    /// performed, `k·(k−1)/2`, so callers can account for them (Fig. 1a).
    pub fn recompute(&mut self, centers: &DenseMatrix) -> u64 {
        let k = self.k;
        crate::audit::debug_invariant(centers.rows() == k, "bounds::cc", "center-count", || {
            format!("table sized for k = {k} but {} centers supplied", centers.rows())
        });
        let mut sims = 0u64;
        for i in 0..k {
            self.cc[i * k + i] = 1.0;
            for j in (i + 1)..k {
                let s = centers.row_dot(i, centers, j);
                let b = half_angle_cos(s);
                self.cc[i * k + j] = b;
                self.cc[j * k + i] = b;
                sims += 1;
            }
        }
        for i in 0..k {
            let mut m = -1.0f64;
            for j in 0..k {
                if j != i {
                    m = m.max(self.cc[i * k + j]);
                }
            }
            self.s[i] = m;
        }
        sims
    }

    /// `cc(i, j)`.
    #[inline(always)]
    pub fn cc(&self, i: usize, j: usize) -> f64 {
        self.cc[i * self.k + j]
    }

    /// Row `i` of the cc matrix (for tight inner loops).
    #[inline(always)]
    pub fn cc_row(&self, i: usize) -> &[f64] {
        &self.cc[i * self.k..(i + 1) * self.k]
    }

    /// `s(i) = max_{j≠i} cc(i,j)`.
    #[inline(always)]
    pub fn s(&self, i: usize) -> f64 {
        self.s[i]
    }
}

/// Nearest-other-center half-angle bounds only (`s(i)`), as used by
/// (non-simplified) Hamerly §5.4 — same semantics as [`CenterBounds::s`]
/// but computed without storing the `k×k` matrix.
pub fn nearest_center_bounds(centers: &DenseMatrix, out: &mut Vec<f64>) -> u64 {
    let k = centers.rows();
    out.clear();
    out.resize(k, -1.0);
    let mut sims = 0u64;
    for i in 0..k {
        for j in (i + 1)..k {
            let b = half_angle_cos(centers.row_dot(i, centers, j));
            sims += 1;
            if b > out[i] {
                out[i] = b;
            }
            if b > out[j] {
                out[j] = b;
            }
        }
    }
    sims
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn half_angle_identities() {
        // cos(0/2)=1 at s=1; cos(π/2)=0 at s=−1; cos(π/4)=√2/2 at s=0.
        assert!((half_angle_cos(1.0) - 1.0).abs() < 1e-12);
        assert!(half_angle_cos(-1.0).abs() < 1e-12);
        assert!((half_angle_cos(0.0) - std::f64::consts::FRAC_1_SQRT_2).abs() < 1e-12);
    }

    #[test]
    fn half_angle_matches_trig() {
        forall(200, 0xCC01, |g| {
            let s = g.sim();
            let trig = (0.5 * s.acos()).cos();
            assert!((half_angle_cos(s) - trig).abs() < 1e-9, "s={s}");
        });
    }

    fn unit_centers(g: &mut crate::util::prop::Gen, k: usize, d: usize) -> DenseMatrix {
        let mut m = DenseMatrix::zeros(k, d);
        for i in 0..k {
            let u = g.unit(d);
            for (x, v) in m.row_mut(i).iter_mut().zip(&u) {
                *x = *v as f32;
            }
        }
        m
    }

    #[test]
    fn recompute_symmetry_and_s() {
        forall(50, 0xCC02, |g| {
            let k = g.usize_in(2, 8);
            let d = g.usize_in(2, 16);
            let centers = unit_centers(g, k, d);
            let mut b = CenterBounds::new(k);
            let sims = b.recompute(&centers);
            assert_eq!(sims, (k * (k - 1) / 2) as u64);
            for i in 0..k {
                assert!((b.cc(i, i) - 1.0).abs() < 1e-12);
                for j in 0..k {
                    assert_eq!(b.cc(i, j), b.cc(j, i));
                }
                let m = (0..k)
                    .filter(|&j| j != i)
                    .map(|j| b.cc(i, j))
                    .fold(f64::MIN, f64::max);
                assert_eq!(b.s(i), m);
            }
        });
    }

    #[test]
    fn nearest_center_bounds_agrees_with_full() {
        forall(50, 0xCC03, |g| {
            let k = g.usize_in(2, 8);
            let d = g.usize_in(2, 16);
            let centers = unit_centers(g, k, d);
            let mut full = CenterBounds::new(k);
            full.recompute(&centers);
            let mut s = Vec::new();
            nearest_center_bounds(&centers, &mut s);
            for i in 0..k {
                assert!((s[i] - full.s(i)).abs() < 1e-12);
            }
        });
    }

    #[test]
    fn elkan_pruning_rule_is_safe() {
        // The paper's §5.2 derivation: if cc(a,j) ≤ l and l ≥ 0, then
        // ⟨x, c(j)⟩ ≤ l. Verify empirically on random geometry.
        forall(400, 0xCC04, |g| {
            let d = g.usize_in(2, 24);
            let x = g.unit(d);
            let ca = g.unit(d);
            let cj = g.unit(d);
            let dot = |a: &[f64], b: &[f64]| a.iter().zip(b).map(|(p, q)| p * q).sum::<f64>();
            let l = dot(&x, &ca); // tight bound
            if l < 0.0 {
                return;
            }
            let ccaj = half_angle_cos(dot(&ca, &cj));
            if ccaj <= l {
                let sxj = dot(&x, &cj);
                assert!(
                    sxj <= l + 1e-9,
                    "pruned center was actually better: sxj={sxj} l={l} cc={ccaj}"
                );
            }
        });
    }
}
