//! # spherical-kmeans
//!
//! A production-quality reproduction of **"Accelerating Spherical k-Means"**
//! (Schubert, Lang, Feher; SISAP 2021, DOI 10.1007/978-3-030-89657-7_17).
//!
//! Spherical k-means clusters unit-normalized vectors by maximizing cosine
//! similarity. This crate implements the paper's contribution — adapting the
//! Elkan and Hamerly acceleration families to work *directly on cosine
//! similarities* via the cosine triangle inequality of Schubert (2021) —
//! plus every substrate it needs: sparse linear algebra, TF-IDF text
//! pipelines, synthetic corpus generators, seeding algorithms
//! (uniform, k-means++, AFK-MC²), cluster-quality metrics, a PJRT runtime
//! that executes AOT-compiled JAX/Pallas dense kernels, an experiment
//! coordinator that regenerates every table and figure of the paper, and a
//! train → persist → serve pipeline: bit-exact model persistence
//! ([`model`]) plus a high-throughput nearest-center query engine with a
//! MaxScore-pruned inverted-file traversal ([`serve`]).
//!
//! ## Layers
//!
//! * **L3 (this crate)** — the coordinator: sparse data structures, the five
//!   (plus extensions) k-means variants with cosine-bound pruning, seeding,
//!   experiment drivers, CLI. The assignment hot loop of every variant runs
//!   on the sharded parallel executor ([`runtime::parallel`]) with a
//!   bit-for-bit thread-count-invariance guarantee (see [`kmeans`]).
//! * **L2/L1 (python/, build time only)** — a JAX assignment-step graph
//!   calling a Pallas tiled similarity kernel, AOT-lowered to HLO text in
//!   `artifacts/`, loaded at runtime by [`runtime`] via the PJRT C API
//!   (behind the off-by-default `pjrt` cargo feature).
//!
//! ## Quickstart
//!
//! The front door is the [`SphericalKMeans`] estimator: one builder for
//! every engine (the seven exact accelerated variants and the mini-batch
//! optimizer), a fallible [`SphericalKMeans::fit`], and a [`FittedModel`]
//! that persists (`.spkm`), serves ([`FittedModel::query_engine`]), and
//! resumes ([`SphericalKMeans::warm_start`]).
//!
//! ```no_run
//! use sphkm::data::synth::SynthConfig;
//! use sphkm::{Engine, ExactParams, SphericalKMeans};
//! use sphkm::kmeans::Variant;
//!
//! let ds = SynthConfig::small_demo().generate(42);
//! let fitted = SphericalKMeans::new(8)
//!     .engine(Engine::Exact(ExactParams {
//!         variant: Variant::SimplifiedElkan,
//!         ..Default::default()
//!     }))
//!     .seed(1)
//!     .fit(&ds.matrix)
//!     .expect("valid configuration");
//! println!("objective = {}", fitted.objective());
//! fitted.save(std::path::Path::new("model.spkm")).unwrap();
//! ```
#![deny(missing_docs)]

pub mod bounds;
pub mod coordinator;
pub mod data;
pub mod init;
pub mod kmeans;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod serve;
pub mod sparse;
pub mod util;

pub use kmeans::{
    Engine, ExactParams, FitError, FittedModel, IterSnapshot, MiniBatchParams, Observer,
    SphericalKMeans,
};
