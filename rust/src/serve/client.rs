//! Blocking `sphkm.rpc.v1` client over a [`TcpStream`] — what the
//! `sphkm query` CLI mode, the daemon tests, and the swap-under-load
//! bench all use to drive a [`Daemon`](crate::serve::Daemon).

use std::io;
use std::net::{TcpStream, ToSocketAddrs};

use crate::serve::rpc::{self, FrameReader, Reply, Request};
use crate::util::json::Json;

/// Why a client call failed.
#[derive(Debug, thiserror::Error)]
pub enum ClientError {
    /// The transport failed (connect, read, or write).
    #[error("transport error: {0}")]
    Io(#[from] io::Error),
    /// The peer's bytes were not a valid `sphkm.rpc.v1` reply, or the
    /// connection closed mid-call.
    #[error("protocol error: {0}")]
    Protocol(String),
    /// The daemon answered with an error frame; the connection remains
    /// usable.
    #[error("daemon error: {0}")]
    Remote(String),
}

/// One connection to a serving daemon. Calls are strictly
/// request-then-reply; the client is not thread-safe (open one per
/// thread — connections are cheap and the daemon handles each on its own
/// thread).
#[derive(Debug)]
pub struct Client {
    reader: FrameReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    /// Connect to a daemon at `addr` (e.g. `"127.0.0.1:7171"`).
    pub fn connect<A: ToSocketAddrs>(addr: A) -> io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(Client { reader: FrameReader::new(stream), writer })
    }

    /// Send one request and read its reply. An error *frame* is returned
    /// as [`Reply::Error`], not `Err` — the typed helpers below map it.
    pub fn call(&mut self, req: &Request) -> Result<Reply, ClientError> {
        rpc::write_frame(&mut self.writer, &req.to_json())?;
        self.read_reply()
    }

    /// Send one raw pre-framed line (no trailing newline) and read the
    /// reply — lets tests and debugging tools speak malformed frames.
    pub fn call_raw(&mut self, line: &str) -> Result<Reply, ClientError> {
        let mut framed = line.to_string();
        framed.push('\n');
        io::Write::write_all(&mut self.writer, framed.as_bytes())?;
        io::Write::flush(&mut self.writer)?;
        self.read_reply()
    }

    fn read_reply(&mut self) -> Result<Reply, ClientError> {
        let line = self
            .reader
            .read_frame()?
            .ok_or_else(|| ClientError::Protocol("connection closed mid-call".to_string()))?;
        let doc = Json::parse_bounded(&line, rpc::MAX_FRAME_BYTES)
            .map_err(|e| ClientError::Protocol(format!("bad reply frame: {e}")))?;
        Reply::from_json(&doc).map_err(ClientError::Protocol)
    }

    /// Top-`top` query for a batch of `(indices, values)` rows; returns
    /// the serving epoch and per-row `(center, similarity)` lists.
    #[allow(clippy::type_complexity)]
    pub fn query(
        &mut self,
        top: usize,
        rows: &[(Vec<u32>, Vec<f32>)],
    ) -> Result<(u64, Vec<Vec<(u32, f64)>>), ClientError> {
        match self.call(&Request::Query { top, rows: rows.to_vec() })? {
            Reply::Query { epoch, results } => Ok((epoch, results)),
            other => Err(unexpected("query", &other)),
        }
    }

    /// Liveness probe; returns the current epoch.
    pub fn ping(&mut self) -> Result<u64, ClientError> {
        match self.call(&Request::Ping)? {
            Reply::Pong { epoch } => Ok(epoch),
            other => Err(unexpected("ping", &other)),
        }
    }

    /// Fetch `(epoch, swaps, per-epoch query counts, metrics document)`.
    #[allow(clippy::type_complexity)]
    pub fn stats(&mut self) -> Result<(u64, u64, Vec<(u64, u64)>, Json), ClientError> {
        match self.call(&Request::Stats)? {
            Reply::Stats { epoch, swaps, epoch_queries, metrics } => {
                Ok((epoch, swaps, epoch_queries, metrics))
            }
            other => Err(unexpected("stats", &other)),
        }
    }

    /// Hot-swap to the model at `path` (`None` = the daemon's watched
    /// path); returns the new epoch.
    pub fn reload(&mut self, path: Option<&str>) -> Result<u64, ClientError> {
        match self.call(&Request::Reload { path: path.map(str::to_string) })? {
            Reply::Reload { epoch } => Ok(epoch),
            other => Err(unexpected("reload", &other)),
        }
    }

    /// Run one background refit round now; returns the new epoch.
    pub fn refit(&mut self) -> Result<u64, ClientError> {
        match self.call(&Request::Refit)? {
            Reply::Refit { epoch } => Ok(epoch),
            other => Err(unexpected("refit", &other)),
        }
    }

    /// Ask the daemon to stop (acknowledged before it exits).
    pub fn shutdown(&mut self) -> Result<(), ClientError> {
        match self.call(&Request::Shutdown)? {
            Reply::Shutdown => Ok(()),
            other => Err(unexpected("shutdown", &other)),
        }
    }
}

fn unexpected(op: &str, reply: &Reply) -> ClientError {
    match reply {
        Reply::Error { message } => ClientError::Remote(message.clone()),
        other => ClientError::Protocol(format!("unexpected reply to {op}: {other:?}")),
    }
}
