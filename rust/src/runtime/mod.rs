//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`) and executes them from Rust via the `xla` crate.

mod engine;

pub use engine::{artifacts_available, AssignEngine, EngineError, Manifest};
