//! Sparse and dense linear algebra substrate.
//!
//! The paper (§2) stores documents as sorted `(index, value)` pairs and
//! computes dot products by merging; cluster centers are dense because they
//! aggregate many sparse rows (§5.2). This module provides exactly those
//! representations plus the CSR matrix that holds a dataset and the
//! [`InvertedIndex`] — a CSC-style postings file over the centers that
//! backs the sparse similarity kernel of [`crate::kmeans::kernel`].

pub mod chunked;
pub mod csr;
mod dense;
pub mod inverted;
mod ops;
mod vec;

pub use chunked::{ChunkCursor, RowCursor, RowSource, ShardError, ShardStore};
pub use csr::{CsrMatrix, RowView};
pub use dense::DenseMatrix;
pub use inverted::InvertedIndex;
pub use ops::{
    dense_dot, normalize_dense, normalize_row_values, sparse_dense_dot, sparse_sparse_dot,
};
pub use vec::SparseVec;
