"""Pure-jnp reference oracle for the L1 Pallas kernels.

This module defines the *semantics* the kernels must match; pytest asserts
`kernels.similarity` and `kernels.bound_update` against it with
hypothesis-driven shape sweeps (see python/tests/).
"""

import jax
import jax.numpy as jnp


def similarity_ref(x, c):
    """Dense cosine-similarity matrix: ``x[B,D] @ c[K,D]^T -> [B,K]``.

    Inputs are assumed unit-normalized, so the dot product *is* the cosine
    similarity (paper §2).
    """
    return jnp.dot(x, c.T, preferred_element_type=jnp.float32)


def assign_ref(x, c):
    """The assignment step every bound-based variant needs to seed its
    bounds: best center index, best similarity, second-best similarity.

    Returns ``(best_idx i32[B], best f32[B], second f32[B])``.
    """
    sims = similarity_ref(x, c)
    k = sims.shape[1]
    if k == 1:
        best_idx = jnp.zeros(sims.shape[0], dtype=jnp.int32)
        best = sims[:, 0]
        second = jnp.full(sims.shape[0], -1.0, dtype=sims.dtype)
        return best_idx, best, second
    top2, idx2 = jax.lax.top_k(sims, 2)
    return idx2[:, 0].astype(jnp.int32), top2[:, 0], top2[:, 1]


def bound_update_ref(l, u, p_a, p_min_sq_comp):
    """Elementwise bound maintenance (Eq. 6 + Eq. 9 with saturation guards).

    ``l``            lower bounds to the assigned center, per point
    ``u``            single Hamerly upper bounds, per point
    ``p_a``          movement self-similarity of the assigned center
    ``p_min_sq_comp``  ``1 - p'(a)^2`` for the assigned center's min-other

    Returns the updated ``(l, u)``.
    """
    l = jnp.clip(l, -1.0, 1.0)
    u = jnp.clip(u, -1.0, 1.0)
    p_a = jnp.clip(p_a, -1.0, 1.0)
    sin_l = jnp.sqrt(jnp.maximum(1.0 - l * l, 0.0))
    sin_p = jnp.sqrt(jnp.maximum(1.0 - p_a * p_a, 0.0))
    l_new = l * p_a - sin_l * sin_p  # Eq. 6
    # Saturation guard: if the center moved past the bound angle, no
    # information remains (see rust/src/bounds/mod.rs).
    l_new = jnp.where(p_a <= -l, -1.0, l_new)
    sin_u_sq = jnp.maximum(1.0 - u * u, 0.0)
    u_new = u + jnp.sqrt(sin_u_sq * jnp.maximum(p_min_sq_comp, 0.0))  # Eq. 9
    return jnp.clip(l_new, -1.0, 1.0), jnp.clip(u_new, -1.0, 1.0)


def cc_bounds_ref(c):
    """Center–center half-angle matrix ``cc(i,j) = sqrt((<ci,cj>+1)/2)``
    plus ``s(i) = max_{j != i} cc(i,j)`` (§5.2)."""
    sims = jnp.clip(similarity_ref(c, c), -1.0, 1.0)
    cc = jnp.sqrt((sims + 1.0) * 0.5)
    k = cc.shape[0]
    masked = jnp.where(jnp.eye(k, dtype=bool), -jnp.inf, cc)
    s = jnp.max(masked, axis=1)
    return cc, s
