//! Support substrates that would normally come from crates.io but are
//! unavailable in this offline environment: PRNG, CLI parsing, a
//! micro-benchmark harness, timing, JSON, the shared bench-report
//! schema, and a property-testing mini-framework.

pub mod benchkit;
pub mod cli;
pub mod config;
pub mod json;
pub mod mem;
pub mod prop;
pub mod report;
pub mod rng;
pub mod timer;
