//! Regenerates **Table 3** of the paper: run times (ms) of Standard,
//! Elkan, Simplified Elkan, Hamerly, and Simplified Hamerly across the six
//! dataset analogues and the k grid.
//!
//! ```text
//! cargo bench --bench bench_table3 -- [--scale tiny|small|medium]
//!     [--reps N] [--ks 2,10,20,50,100,200] [--quick] [--extended]
//! ```
//!
//! `--extended` adds the Yinyang variant (§5.5, implemented beyond the
//! paper). `--table1` prints the dataset inventory as well.

// Bench and test targets favour readable literal casts and exact
// (bit-level) float assertions; the workspace clippy warnings on
// those patterns are aimed at library code.
#![allow(clippy::cast_possible_truncation, clippy::float_cmp)]

use sphkm::coordinator::experiments::{self, ExperimentOpts};
use sphkm::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let opts = ExperimentOpts::from_args(&args);
    println!("# Table 3 bench — scale={}, reps={}", opts.scale.name(), opts.reps);
    if args.flag("table1") {
        experiments::table1(&opts);
    }
    experiments::table3(&opts, args.flag("extended"));
}
