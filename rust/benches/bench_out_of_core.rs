//! Out-of-core training benchmark: fit a corpus from the chunked on-disk
//! shard store under a resident-memory budget far below the full matrix,
//! and prove the result is **bit-identical** to the in-memory fit.
//!
//! The corpus is written to a `.sks` shard file, reopened with a small
//! reader-side chunk budget, and trained with the same seeded estimator
//! as the in-memory reference. Hard assertions: (1) assignments,
//! objective bits, and every center coordinate agree across backends;
//! (2) the peak resident point data (tracked by the chunk cursors) stays
//! **strictly below** the full in-memory matrix footprint — i.e. the run
//! really was out-of-core, not a buffered copy.
//!
//! Both fits run `--warmup` untimed + `--runs` timed repetitions;
//! results are written to `BENCH_out_of_core.json` at the repository
//! root in the shared `sphkm.report.v1` envelope (see
//! `sphkm::util::report`, validated by `sphkm report --check`).
//!
//! ```text
//! cargo bench --bench bench_out_of_core -- [--rows 20000] [--k 16]
//!     [--vocab 30000] [--max-iter 6] [--chunk-rows 256] [--threads 0]
//!     [--seed 42] [--variant simp-elkan] [--runs 1] [--warmup 0]
//! ```

// Bench and test targets favour readable literal casts and exact
// (bit-level) float assertions; the workspace clippy warnings on
// those patterns are aimed at library code.
#![allow(clippy::cast_possible_truncation, clippy::float_cmp)]

use sphkm::data::synth::SynthConfig;
use sphkm::kmeans::{SphericalKMeans, Variant};
use sphkm::sparse::chunked::{reset_resident_peak, resident_peak_bytes};
use sphkm::sparse::{RowSource, ShardStore};
use sphkm::util::benchkit::BenchOpts;
use sphkm::util::cli::Args;
use sphkm::util::json::Json;
use sphkm::util::mem::peak_rss_bytes;
use sphkm::util::report::{timing_fields, RunReport};
use sphkm::util::timer::{Stopwatch, TimingStats};

fn corpus(vocab: usize, rows: usize, k: usize, seed: u64) -> sphkm::data::Dataset {
    SynthConfig {
        name: format!("ooc-v{vocab}"),
        n_docs: rows,
        vocab,
        topics: k.max(2),
        doc_len_mean: 60.0,
        doc_len_sigma: 0.4,
        topic_strength: 0.65,
        shared_vocab_frac: 0.2,
        zipf_s: 1.05,
        anomaly_frac: 0.0,
        tfidf: Default::default(),
    }
    .generate(seed)
}

fn main() {
    let args = Args::from_env();
    let rows: usize = args.get_or("rows", 20_000).unwrap_or(20_000);
    let k: usize = args.get_or("k", 16).unwrap_or(16);
    let vocab: usize = args.get_or("vocab", 30_000).unwrap_or(30_000);
    let max_iter: usize = args.get_or("max-iter", 6).unwrap_or(6);
    let chunk_rows: usize = args.get_or("chunk-rows", 256).unwrap_or(256);
    let threads: usize = args.get_or("threads", 0).unwrap_or(0);
    let seed: u64 = args.get_or("seed", 42).unwrap_or(42);
    let variant: Variant = args
        .get("variant")
        .map(|v| v.parse().expect("valid variant name"))
        .unwrap_or(Variant::SimplifiedElkan);
    // Each run is a full fit over a 20k-row corpus: default to a single
    // timed run with no warmup (the historical behaviour); CI smoke and
    // serious measurement override with --runs / --warmup.
    let mut opts = BenchOpts::from_args(&args);
    if !args.has("runs") {
        opts.runs = 1;
    }
    if !args.has("warmup") {
        opts.warmup = 0;
    }

    println!(
        "# out-of-core bench — {}, k={k}, {rows} rows, vocab={vocab}, \
         chunk-rows={chunk_rows}, {max_iter}-iteration cap, threads={threads}, \
         runs={} (+{} warmup)",
        variant.name(),
        opts.runs,
        opts.warmup
    );

    let ds = corpus(vocab, rows, k, seed);
    let shard_path = std::env::temp_dir().join(format!(
        "sphkm-bench-ooc-{}.sks",
        std::process::id()
    ));
    let sw = Stopwatch::start();
    ShardStore::write_from_matrix(&shard_path, &ds.matrix).expect("shard write");
    let convert_ms = sw.ms();
    let store = ShardStore::open(&shard_path)
        .expect("shard open")
        .with_chunk_rows(chunk_rows);

    let est = || {
        SphericalKMeans::new(k)
            .variant(variant)
            .seed(seed ^ 1)
            .threads(threads)
            .max_iter(max_iter)
    };

    // Fits are deterministic, so repeated runs reproduce the same model
    // and only the wall-clock samples vary; the last fit of each backend
    // feeds the bit-identity assertions.
    let mut mem_samples = Vec::new();
    let mut mem = None;
    for it in 0..opts.warmup + opts.runs.max(1) {
        let sw = Stopwatch::start();
        let r = est().fit(&ds.matrix).expect("bench configuration is valid");
        let ms = sw.ms();
        if it >= opts.warmup {
            mem_samples.push(ms);
        }
        mem = Some(r);
    }
    let mem = mem.expect("at least one run");
    let mem_t = TimingStats::from_ms(&mem_samples);

    reset_resident_peak();
    let mut disk_samples = Vec::new();
    let mut disk = None;
    for it in 0..opts.warmup + opts.runs.max(1) {
        let sw = Stopwatch::start();
        let r = est()
            .fit_source(RowSource::Disk(&store))
            .expect("bench configuration is valid");
        let ms = sw.ms();
        if it >= opts.warmup {
            disk_samples.push(ms);
        }
        disk = Some(r);
    }
    let disk = disk.expect("at least one run");
    let disk_t = TimingStats::from_ms(&disk_samples);
    let peak_resident = resident_peak_bytes();
    let full_bytes = store.in_memory_bytes();
    std::fs::remove_file(&shard_path).ok();

    // Exactness across backends: bit for bit.
    assert_eq!(mem.assignments(), disk.assignments(), "assignments");
    assert_eq!(
        mem.objective().to_bits(),
        disk.objective().to_bits(),
        "objective"
    );
    for j in 0..k {
        for (x, y) in mem.centers().row(j).iter().zip(disk.centers().row(j)) {
            assert_eq!(x.to_bits(), y.to_bits(), "center {j}");
        }
    }
    // Out-of-core for real: resident point data strictly below the
    // full-matrix footprint (with room to spare at any sane chunk size).
    assert!(
        peak_resident < full_bytes,
        "peak resident point data {peak_resident} B must stay strictly below \
         the {full_bytes} B in-memory matrix"
    );

    let mib = |b: u64| b as f64 / (1024.0 * 1024.0);
    println!(
        "{:<26} {:>12} {:>12} {:>12}",
        "", "in-memory", "out-of-core", "ratio"
    );
    println!(
        "{:<26} {:>10.1}ms {:>10.1}ms {:>11.2}x",
        "train wall-clock",
        mem_t.mean_ms,
        disk_t.mean_ms,
        disk_t.mean_ms / mem_t.mean_ms.max(1e-9)
    );
    println!(
        "{:<26} {:>9.2}MiB {:>9.2}MiB {:>11.2}x",
        "resident point data",
        mib(full_bytes),
        mib(peak_resident),
        peak_resident as f64 / full_bytes.max(1) as f64
    );
    println!(
        "# convert {convert_ms:.1}ms, shard file {:.2}MiB, objective {:.6}, {} iterations",
        mib(store.file_len()),
        disk.objective(),
        disk.iterations()
    );

    let mut report = RunReport::new("out_of_core");
    report.note("bit-identical in-memory vs on-disk fits; ms are mean over --runs");
    report.config_str("variant", variant.name());
    for (key, v) in [
        ("rows", rows),
        ("vocab", vocab),
        ("k", k),
        ("max_iter", max_iter),
        ("chunk_rows", chunk_rows),
        ("threads", threads),
        ("runs", opts.runs),
        ("warmup", opts.warmup),
    ] {
        report.config_num(key, v as f64);
    }
    report.config_num("seed", seed as f64);
    let mut row = vec![
        ("convert_ms".to_string(), Json::Num(convert_ms)),
        ("full_matrix_bytes".to_string(), Json::Num(full_bytes as f64)),
        (
            "peak_resident_bytes".to_string(),
            Json::Num(peak_resident as f64),
        ),
        (
            "resident_ratio".to_string(),
            Json::Num(peak_resident as f64 / full_bytes.max(1) as f64),
        ),
        (
            "peak_rss_bytes".to_string(),
            peak_rss_bytes().map_or(Json::Null, |b| Json::Num(b as f64)),
        ),
        ("objective".to_string(), Json::Num(disk.objective())),
        (
            "iterations".to_string(),
            Json::Num(disk.iterations() as f64),
        ),
        ("bit_identical_to_in_memory".to_string(), Json::Bool(true)),
    ];
    row.extend(timing_fields("mem_train", &mem_t));
    row.extend(timing_fields("disk_train", &disk_t));
    report.push_result(row);

    let json_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_out_of_core.json");
    debug_assert!(
        RunReport::check_str(&report.to_json().pretty(2)).is_ok(),
        "emitting an invalid report"
    );
    match report.save(&json_path) {
        Ok(()) => println!("# wrote {}", json_path.display()),
        Err(e) => println!("# could not write {}: {e}", json_path.display()),
    }

    println!(
        "# acceptance: bit-identical clustering from shards at {:.1}% of the \
         in-memory footprint — OK",
        100.0 * peak_resident as f64 / full_bytes.max(1) as f64
    );
}
