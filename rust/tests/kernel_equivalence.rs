//! The similarity-kernel exactness contract (see `kmeans::kernel`): the
//! Dense (d×k transpose) and Inverted (CSC postings) backends accumulate
//! per-center contributions in the same ascending-dimension order, so
//! similarities — and therefore assignments, objectives, and pruning
//! statistics — must be **bit-identical** across backends, for every
//! thread count, at any data density, with truncated or dense centers.
//! The Gather backend shares values up to summation-order rounding (its
//! four-lane unrolled dot sums in a different tree). The Pruned backend
//! walks the same postings MaxScore-style and re-scores survivors with
//! the exact gather dot, so it joins the bit-identical family — including
//! its per-point traversal decisions, which must never change a
//! trajectory.
//!
//! This suite asserts the contract with a randomized property sweep over
//! densities (0.1%–50% nnz) plus full-run checks for all seven exact
//! variants and the mini-batch engine.

// Bench and test targets favour readable literal casts and exact
// (bit-level) float assertions; the workspace clippy warnings on
// those patterns are aimed at library code.
#![allow(clippy::cast_possible_truncation, clippy::float_cmp)]

use sphkm::data::synth::SynthConfig;
use sphkm::init::{seed_centers, InitMethod};
use sphkm::kmeans::{Centers, KMeansResult, Kernel, KernelChoice, Variant};
use sphkm::sparse::{CsrMatrix, DenseMatrix, RowSource, ShardStore, SparseVec};
use sphkm::util::prop::{forall, Gen};
use sphkm::{Engine, MiniBatchParams, SphericalKMeans};

/// Fit from shared explicit centers, unwrapped to the result view.
fn fit_from(data: &CsrMatrix, centers: DenseMatrix, est: SphericalKMeans) -> KMeansResult {
    est.warm_start_centers(centers)
        .fit(data)
        .expect("test configuration is valid")
        .into_result()
}

/// A random unit-row corpus at (approximately) the given density.
fn random_corpus(g: &mut Gen, rows: usize, d: usize, density: f64) -> CsrMatrix {
    let nnz = ((d as f64 * density).ceil() as usize).clamp(1, d);
    let mut svs = Vec::with_capacity(rows);
    for _ in 0..rows {
        let pat = g.sparse_pattern(d, nnz);
        svs.push(SparseVec::new(
            d,
            pat.iter().map(|&i| i as u32).collect(),
            pat.iter().map(|_| g.f64_in(0.05, 1.0) as f32).collect(),
        ));
    }
    let mut m = CsrMatrix::from_rows(d, &svs);
    m.normalize_rows();
    m
}

/// Initial centers: k evenly spaced data rows, densified.
fn initial_from_rows(data: &CsrMatrix, k: usize) -> DenseMatrix {
    let mut m = DenseMatrix::zeros(k, data.cols());
    for j in 0..k {
        let r = data.row(j * data.rows() / k);
        for (t, &c) in r.indices.iter().enumerate() {
            m.row_mut(j)[c as usize] = r.values[t];
        }
    }
    m
}

/// The density grid of the property sweep: 0.1% … 50% nnz.
const DENSITIES: [f64; 6] = [0.001, 0.005, 0.02, 0.1, 0.3, 0.5];

#[test]
fn raw_similarities_bit_identical_across_backends_and_densities() {
    forall(25, 0x5EED_01, |g| {
        let d = g.usize_in(60, 1200);
        let rows = g.usize_in(24, 72);
        let k = g.usize_in(1, 9);
        let density = DENSITIES[g.usize_in(0, DENSITIES.len())];
        let data = random_corpus(g, rows, d, density);
        let initial = initial_from_rows(&data, k);
        let assign: Vec<u32> = (0..rows).map(|i| (i % k) as u32).collect();

        // Drive each backend through the same lifecycle: rebuild, update,
        // an incremental move, and (sometimes) a truncation barrier.
        let truncate = if g.usize_in(0, 2) == 1 { Some(g.usize_in(1, 33)) } else { None };
        let mk = |kernel: Kernel| {
            let mut c = Centers::from_initial_for(initial.clone(), kernel);
            c.rebuild(&data, &assign);
            c.update();
            if k > 1 && rows > 1 {
                c.apply_move(data.row(1), assign[1] as usize, (assign[1] as usize + 1) % k);
                c.update();
            }
            if let Some(m) = truncate {
                c.truncate_centers(m);
            }
            c
        };
        let dense = mk(Kernel::Dense);
        let gather = mk(Kernel::Gather);
        let inverted = mk(Kernel::Inverted);
        let pruned = mk(Kernel::Pruned);

        let mut sd = vec![0.0f64; k];
        let mut sg = vec![0.0f64; k];
        let mut si = vec![0.0f64; k];
        let mut sp = vec![0.0f64; k];
        for i in 0..rows {
            let md = dense.sims_all(data.row(i), &mut sd);
            let mg = gather.sims_all(data.row(i), &mut sg);
            let mi = inverted.sims_all(data.row(i), &mut si);
            let mp = pruned.sims_all(data.row(i), &mut sp);
            assert_eq!(md, mg, "row {i}: dense and gather charge nnz·k");
            assert!(mi <= md, "row {i}: inverted must not exceed dense madds");
            assert_eq!(mi, mp, "row {i}: pruned full-row pass is the inverted walk");
            for j in 0..k {
                assert_eq!(
                    sd[j].to_bits(),
                    si[j].to_bits(),
                    "row {i} center {j} (d={d}, density={density}, truncate={truncate:?})"
                );
                assert_eq!(
                    sd[j].to_bits(),
                    sp[j].to_bits(),
                    "row {i} center {j}: dense vs pruned (d={d}, density={density})"
                );
                assert!((sd[j] - sg[j]).abs() < 1e-12, "row {i} center {j}");
            }
        }
    });
}

#[test]
fn full_runs_bit_identical_across_backends_and_densities() {
    forall(12, 0x5EED_02, |g| {
        let d = g.usize_in(80, 900);
        let rows = g.usize_in(30, 80);
        let k = g.usize_in(2, 8);
        let density = DENSITIES[g.usize_in(0, DENSITIES.len())];
        let data = random_corpus(g, rows, d, density);
        let initial = initial_from_rows(&data, k);
        for variant in [Variant::Standard, Variant::SimplifiedHamerly, Variant::Elkan] {
            let est = || SphericalKMeans::new(k).variant(variant).max_iter(20);
            let dense = fit_from(&data, initial.clone(), est().kernel(KernelChoice::Dense));
            for choice in [KernelChoice::Inverted, KernelChoice::Pruned] {
                let r = fit_from(&data, initial.clone(), est().kernel(choice));
                assert_eq!(
                    dense.assignments,
                    r.assignments,
                    "{} {choice:?} (d={d}, density={density})",
                    variant.name()
                );
                assert_eq!(
                    dense.objective.to_bits(),
                    r.objective.to_bits(),
                    "{} {choice:?}",
                    variant.name()
                );
                assert_eq!(dense.iterations, r.iterations, "{} {choice:?}", variant.name());
                assert_eq!(
                    dense.stats.total_point_center(),
                    r.stats.total_point_center(),
                    "{} {choice:?}: pruning decisions must match",
                    variant.name()
                );
                if choice == KernelChoice::Inverted {
                    assert!(
                        r.stats.total_madds() <= dense.stats.total_madds(),
                        "{}: inverted did more madds",
                        variant.name()
                    );
                }
            }
        }
    });
}

/// Two contrasting corpora: the dense-ish demo (small vocabulary, centers
/// densify) and a sparse high-dimensional one (the inverted file's home
/// regime) — Auto resolves differently across them.
fn corpora() -> Vec<sphkm::data::Dataset> {
    let sparse = SynthConfig {
        name: "sparse-synth".into(),
        n_docs: 400,
        vocab: 6_000,
        topics: 8,
        doc_len_mean: 15.0,
        doc_len_sigma: 0.4,
        topic_strength: 0.7,
        shared_vocab_frac: 0.25,
        zipf_s: 1.1,
        anomaly_frac: 0.0,
        tfidf: Default::default(),
    }
    .generate(7);
    vec![SynthConfig::small_demo().generate(3), sparse]
}

#[test]
fn auto_resolves_differently_across_the_corpora() {
    // Sanity for the suite itself: the two corpora straddle the Auto
    // heuristic, so the Auto legs above exercise both backends.
    use sphkm::kmeans::DataShape;
    let ds = corpora();
    assert_eq!(
        KernelChoice::Auto.resolve(&DataShape::of(&ds[0].matrix, 8, None)),
        Kernel::Dense,
        "small demo densifies its centers"
    );
    assert_eq!(
        KernelChoice::Auto.resolve(&DataShape::of(&ds[1].matrix, 8, None)),
        Kernel::Pruned,
        "sparse corpus stays under the density cutoff at prunable k"
    );
    assert_eq!(
        KernelChoice::Auto.resolve(&DataShape::of(&ds[1].matrix, 7, None)),
        Kernel::Inverted,
        "below the pruning k floor the plain inverted walk wins"
    );
}

#[test]
fn all_seven_variants_bit_identical_on_every_kernel_and_thread_count() {
    for ds in corpora() {
        let k = 8;
        let init = seed_centers(&ds.matrix, k, &InitMethod::Uniform, 11);
        for variant in Variant::ALL {
            let base = || SphericalKMeans::new(k).variant(variant);
            let reference = fit_from(
                &ds.matrix,
                init.centers.clone(),
                base().kernel(KernelChoice::Dense).threads(1),
            );
            for choice in [
                KernelChoice::Dense,
                KernelChoice::Inverted,
                KernelChoice::Pruned,
                KernelChoice::Auto,
            ] {
                for threads in [1usize, 0] {
                    let r = fit_from(
                        &ds.matrix,
                        init.centers.clone(),
                        base().kernel(choice).threads(threads),
                    );
                    assert_eq!(
                        r.assignments,
                        reference.assignments,
                        "{}: {} kernel={choice:?} threads={threads}",
                        ds.name,
                        variant.name()
                    );
                    assert_eq!(
                        r.objective.to_bits(),
                        reference.objective.to_bits(),
                        "{}: {} kernel={choice:?} threads={threads}",
                        ds.name,
                        variant.name()
                    );
                    assert_eq!(
                        r.stats.total_point_center(),
                        reference.stats.total_point_center(),
                        "{}: {} kernel={choice:?} threads={threads}: pruning changed",
                        ds.name,
                        variant.name()
                    );
                }
            }
            // Gather shares the clustering on these corpora (the historic
            // fast-vs-gather toggle), though only to rounding, not bitwise.
            let gather = fit_from(
                &ds.matrix,
                init.centers.clone(),
                base().kernel(KernelChoice::Gather),
            );
            assert_eq!(
                gather.assignments,
                reference.assignments,
                "{}: {} gather",
                ds.name,
                variant.name()
            );
            assert!(
                (gather.objective - reference.objective).abs()
                    < 1e-9 * (1.0 + reference.objective),
                "{}: {} gather objective",
                ds.name,
                variant.name()
            );
        }
    }
}

#[test]
fn minibatch_bit_identical_across_kernels_truncation_and_threads() {
    for ds in corpora() {
        let k = 6;
        let init = seed_centers(&ds.matrix, k, &InitMethod::Uniform, 19);
        for truncate in [None, Some(16usize)] {
            let base = || {
                SphericalKMeans::new(k)
                    .engine(Engine::MiniBatch(MiniBatchParams {
                        batch_size: 64,
                        epochs: 3,
                        truncate,
                        ..Default::default()
                    }))
                    .seed(5)
            };
            let reference = fit_from(
                &ds.matrix,
                init.centers.clone(),
                base().kernel(KernelChoice::Dense).threads(1),
            );
            for choice in [
                KernelChoice::Dense,
                KernelChoice::Inverted,
                KernelChoice::Pruned,
                KernelChoice::Auto,
            ] {
                for threads in [1usize, 0] {
                    let r = fit_from(
                        &ds.matrix,
                        init.centers.clone(),
                        base().kernel(choice).threads(threads),
                    );
                    assert_eq!(
                        r.assignments,
                        reference.assignments,
                        "{}: truncate={truncate:?} kernel={choice:?} threads={threads}",
                        ds.name
                    );
                    assert_eq!(
                        r.objective.to_bits(),
                        reference.objective.to_bits(),
                        "{}: truncate={truncate:?} kernel={choice:?} threads={threads}",
                        ds.name
                    );
                    assert_eq!(
                        r.stats.total_point_center(),
                        reference.stats.total_point_center(),
                        "{}: similarity counts are kernel-invariant",
                        ds.name
                    );
                }
            }
            // Truncated sparse centroids are where the inverted file's
            // madd advantage concentrates.
            let inv = fit_from(
                &ds.matrix,
                init.centers.clone(),
                base().kernel(KernelChoice::Inverted),
            );
            if truncate.is_some() {
                assert!(
                    inv.stats.total_madds() < reference.stats.total_madds(),
                    "{}: truncated inverted run must save madds",
                    ds.name
                );
            } else {
                assert!(inv.stats.total_madds() <= reference.stats.total_madds());
            }
        }
    }
}

#[test]
fn pruned_kernel_bit_identical_from_the_disk_shard_store() {
    // The out-of-core row source feeds the same kernels through the same
    // shard grid, so the MaxScore walk's per-point decisions — and hence
    // the whole trajectory — must survive the disk round trip untouched.
    let ds = &corpora()[1];
    let k = 8;
    let init = initial_from_rows(&ds.matrix, k);

    let dir = std::env::temp_dir().join(format!("sphkm-kernel-tests-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("pruned-disk");
    ShardStore::write_from_matrix(&path, &ds.matrix).unwrap();
    let store = ShardStore::open(&path).unwrap().with_chunk_rows(37);

    for variant in [Variant::Standard, Variant::SimplifiedHamerly] {
        let est = |choice: KernelChoice| {
            SphericalKMeans::new(k)
                .variant(variant)
                .max_iter(15)
                .kernel(choice)
                .warm_start_centers(init.clone())
        };
        let mem_dense = fit_from(&ds.matrix, init.clone(), {
            SphericalKMeans::new(k)
                .variant(variant)
                .max_iter(15)
                .kernel(KernelChoice::Dense)
        });
        let disk_pruned = est(KernelChoice::Pruned)
            .fit_source(RowSource::Disk(&store))
            .expect("disk-backed pruned fit succeeds")
            .into_result();
        assert_eq!(
            mem_dense.assignments,
            disk_pruned.assignments,
            "{}: disk+pruned vs memory+dense assignments",
            variant.name()
        );
        assert_eq!(
            mem_dense.objective.to_bits(),
            disk_pruned.objective.to_bits(),
            "{}: objective bits",
            variant.name()
        );
        assert_eq!(
            mem_dense.iterations,
            disk_pruned.iterations,
            "{}: iteration counts",
            variant.name()
        );
        assert!(
            disk_pruned.stats.total_prune_survivors() > 0,
            "{}: the pruned walk must actually run on the disk path",
            variant.name()
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}
