//! Spherical Elkan's algorithm (§5.2): per-(point, center) upper bounds
//! `u(i,j)`, a lower bound `l(i)` to the assigned center, plus the
//! center–center half-angle pruning tests:
//!
//! * whole-loop skip: `l(i) ≥ s(a(i))` — no other center can win;
//! * per-center skip: `u(i,j) ≤ l(i)` or `cc(a(i), j) ≤ l(i)`.
//!
//! Both `cc` tests are valid because `cc ≥ 0`, so `cc ≤ l` implies the
//! `l ≥ 0` premise of the paper's derivation. Bounds are maintained across
//! center movement with Eq. 6/7.

use super::{Ctx, IterStats, KMeansConfig};
use crate::bounds::cc::CenterBounds;
use crate::bounds::{update_lower_pre, update_upper_pre};
use crate::util::timer::Stopwatch;

pub(crate) fn run(ctx: &mut Ctx<'_>, cfg: &KMeansConfig) -> bool {
    let n = ctx.data.rows();
    let k = ctx.k;
    let mut l = vec![0.0f64; n];
    let mut u = vec![0.0f64; n * k];

    ctx.initial_assignment(true, |i, _bj, best, _second, sims| {
        l[i] = best;
        u[i * k..(i + 1) * k].copy_from_slice(sims);
    });
    ctx.stats.bound_bytes = (n + n * k) * std::mem::size_of::<f64>();

    let mut cb = CenterBounds::new(k);
    for _ in 0..cfg.max_iter {
        let sw = Stopwatch::start();
        let mut iter = IterStats::default();

        // Maintain bounds across the center movement of the last update.
        let p = ctx.centers.p().to_vec();
        let sin_p: Vec<f64> = p.iter().map(|&v| crate::bounds::sin_from_cos(v)).collect();
        for i in 0..n {
            let a = ctx.assign[i] as usize;
            l[i] = update_lower_pre(l[i], p[a], sin_p[a]);
            let row = &mut u[i * k..(i + 1) * k];
            for (j, uij) in row.iter_mut().enumerate() {
                *uij = update_upper_pre(*uij, p[j], sin_p[j]);
            }
        }

        // Center–center half-angle bounds for the current centers.
        iter.sims_center_center += cb.recompute(ctx.centers.centers());

        let mut moves = 0u64;
        for i in 0..n {
            let mut a = ctx.assign[i] as usize;
            // Whole-loop test: no other center can beat l(i).
            if l[i] >= cb.s(a) {
                iter.loop_skips += 1;
                continue;
            }
            let mut tight = false;
            for j in 0..k {
                if j == a {
                    continue;
                }
                let uij = u[i * k + j];
                if uij <= l[i] || cb.cc(a, j) <= l[i] {
                    iter.bound_skips += 1;
                    continue;
                }
                if !tight {
                    // First failure: make l(i) exact and re-test.
                    l[i] = ctx.similarity(i, a, &mut iter);
                    tight = true;
                    if uij <= l[i] || cb.cc(a, j) <= l[i] {
                        iter.bound_skips += 1;
                        continue;
                    }
                }
                // Compute the exact similarity to the candidate center.
                let s = ctx.similarity(i, j, &mut iter);
                u[i * k + j] = s;
                if s > l[i] {
                    // Reassign: the old exact l(i) becomes a valid upper
                    // bound for the old center.
                    u[i * k + a] = l[i];
                    ctx.centers.apply_move(ctx.data.row(i), a, j);
                    a = j;
                    ctx.assign[i] = j as u32;
                    l[i] = s;
                    moves += 1;
                }
            }
        }

        iter.reassignments = moves;
        if moves == 0 {
            iter.wall_ms = sw.ms();
            ctx.stats.iters.push(iter);
            return true;
        }
        iter.sims_center_center += ctx.centers.update();
        iter.wall_ms = sw.ms();
        ctx.stats.iters.push(iter);
    }
    false
}
