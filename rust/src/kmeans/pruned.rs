//! MaxScore-style bound-pruned assignment traversal for [`Kernel::Pruned`].
//!
//! The dense and inverted kernels score every center for every surviving
//! point. This module instead walks the [`InvertedIndex`] postings in
//! descending `|q_c| · maxw[c]` term order (the classic MaxScore ordering
//! from text retrieval, as applied to k-means assignment by Aoyama & Saito,
//! arXiv 2411.11300): after walking a prefix of the query's terms, every
//! center's partial dot plus the *suffix bound* — the sum of the unwalked
//! terms' per-dimension contribution bounds — is a valid upper bound on its
//! exact similarity. Centers whose upper bound cannot reach the running
//! threshold are pruned; the few survivors are re-scored with the exact
//! ascending-dimension gather dot so the returned similarities are
//! **bit-identical** to what the dense or inverted kernel would have
//! produced.
//!
//! Two traversal modes serve the engines:
//!
//! * **top-2** ([`top2_pruned`]) — the full-assignment path used by the
//!   standard loop, mini-batch, and bound-free initial assignment. The
//!   threshold is the second-largest partial-score lower bound, so the
//!   exact top-2 (including all ties) always survive and the returned
//!   `(argmin-index, best, second)` triple matches the exhaustive scan.
//! * **best-other** ([`best_other_pruned`]) — Hamerly's rescan, which
//!   needs the best center *other than* the current assignment `a`. The
//!   threshold is additionally seeded with the caller's exact `sim(i, a)`
//!   (the paper's cosine lower bound, already tightened before the rescan):
//!   a center that cannot beat the current assignment can never cause a
//!   reassignment, so the walk may stop as soon as the suffix bound drops
//!   below that seed. The returned `m2` may then understate the true
//!   second-best, but only below the seed — exactly the regime where
//!   Hamerly's update `u = l.max(m2)` masks it, so trajectories are
//!   unchanged.
//!
//! The walk stops early at geometric checkpoints (t = 1, 2, 4, 8, …) once
//! the candidate count is at most two or finishing the survivors by exact
//! gather is provably cheaper than draining the remaining postings, which
//! keeps the total multiply-adds at or below the plain inverted kernel's.
//! All floating-point cuts are widened by `2 ·`[`BOUND_MARGIN`] on the
//! pessimistic side, mirroring the serve-side MaxScore discipline, and the
//! final threshold is retained so the audit layer
//! (`audit_set_prune`) can certify every pruned center against an
//! exhaustive throwaway pass.
//!
//! [`Kernel::Pruned`]: super::kernel::Kernel::Pruned
//! [`InvertedIndex`]: crate::sparse::InvertedIndex
//! [`BOUND_MARGIN`]: crate::serve::engine::BOUND_MARGIN

use super::stats::IterStats;
use crate::serve::engine::BOUND_MARGIN;
use crate::sparse::{DenseMatrix, InvertedIndex, RowView};

/// Per-shard scratch for the pruned traversal, reused across every point a
/// Pool worker processes so the hot loop performs no allocations.
#[derive(Default)]
pub(crate) struct PruneScratch {
    /// Query terms as `(dim, value, bound)` where `bound = |value|·maxw[dim]`,
    /// sorted by descending bound (ties: ascending dim). Terms whose bound is
    /// exactly zero are dropped — no center carries them.
    terms: Vec<(u32, f32, f64)>,
    /// `suffix[t]` = sum of `terms[t..]` bounds: the maximum similarity mass
    /// any center can still gain from the unwalked terms.
    suffix: Vec<f64>,
    /// `rem[t]` = total postings length of `terms[t..]`: what a full
    /// inverted-kernel drain of the remaining terms would cost in madds.
    rem: Vec<u64>,
    /// Centers that survived the final cut, ascending.
    survivors: Vec<u32>,
    /// Final similarity-space threshold: every pruned center's exact
    /// similarity is provably `< theta` (up to the widened margin).
    theta: f64,
}

impl PruneScratch {
    /// The threshold the last traversal pruned against, for audit.
    pub(crate) fn theta(&self) -> f64 {
        self.theta
    }

    /// Complement of the survivor set over `0..k`, ascending, for audit.
    pub(crate) fn pruned_members(&self, k: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(k - self.survivors.len());
        let mut s = 0;
        for j in 0..k {
            if s < self.survivors.len() && self.survivors[s] as usize == j {
                s += 1;
            } else {
                out.push(j);
            }
        }
        out
    }
}

/// Similarity-space threshold after walking `t` terms: the best lower bound
/// we hold on the score the traversal must preserve exactly. With no
/// `exclude`, that is the second-largest partial minus the suffix (the top-2
/// must both survive); with `exclude = Some(a)` it is the largest partial
/// among `j != a`, additionally capped by the caller's `seed` bound.
fn theta_at(partial: &[f64], exclude: Option<usize>, seed: f64, suffix: f64) -> f64 {
    let mut mx1 = f64::MIN;
    let mut mx2 = f64::MIN;
    for (j, &p) in partial.iter().enumerate() {
        if Some(j) == exclude {
            continue;
        }
        if p > mx1 {
            mx2 = mx1;
            mx1 = p;
        } else if p > mx2 {
            mx2 = p;
        }
    }
    let reference = if exclude.is_some() { mx1 } else { mx2 };
    seed.min(reference - suffix)
}

/// Walk postings until the stop rule fires, then collect into
/// `ps.survivors` every center whose upper bound clears the threshold.
/// `partial` (len k) holds each center's exact partial dot on return.
fn select_survivors(
    idx: &InvertedIndex,
    row: RowView<'_>,
    partial: &mut [f64],
    ps: &mut PruneScratch,
    iter: &mut IterStats,
    exclude: Option<usize>,
    seed: f64,
) {
    partial.fill(0.0);
    let maxw = idx.max_abs_weights();
    ps.terms.clear();
    for (&c, &v) in row.indices.iter().zip(row.values.iter()) {
        let b = (v.abs() as f64) * (maxw[c as usize] as f64);
        if b > 0.0 {
            ps.terms.push((c, v, b));
        }
    }
    ps.terms
        .sort_unstable_by(|x, y| y.2.partial_cmp(&x.2).expect("finite bounds").then(x.0.cmp(&y.0)));
    let n = ps.terms.len();
    ps.suffix.clear();
    ps.suffix.resize(n + 1, 0.0);
    ps.rem.clear();
    ps.rem.resize(n + 1, 0);
    for t in (0..n).rev() {
        ps.suffix[t] = ps.suffix[t + 1] + ps.terms[t].2;
        ps.rem[t] = ps.rem[t + 1] + idx.dim_len(ps.terms[t].0 as usize) as u64;
    }

    let nnz = row.nnz() as u64;
    let mut t = 0;
    let mut next_check = 1;
    while t < n {
        if t == next_check {
            let cut = theta_at(partial, exclude, seed, ps.suffix[t]) - ps.suffix[t]
                - 2.0 * BOUND_MARGIN;
            let count = partial
                .iter()
                .enumerate()
                .filter(|&(j, &p)| Some(j) != exclude && p >= cut)
                .count();
            // Stop once only the provably-exact survivors remain, or once
            // rescoring every candidate by exact gather is no more expensive
            // than draining the remaining postings lists.
            if count <= 2 || count as u64 * nnz <= ps.rem[t] {
                break;
            }
            next_check *= 2;
        }
        let (c, v, _) = ps.terms[t];
        iter.madds_point_center += idx.accumulate_dim(c as usize, v as f64, partial);
        t += 1;
    }
    iter.prune_terms += t as u64;

    let theta = theta_at(partial, exclude, seed, ps.suffix[t]);
    let cut = theta - ps.suffix[t] - 2.0 * BOUND_MARGIN;
    ps.theta = theta;
    ps.survivors.clear();
    for (j, &p) in partial.iter().enumerate() {
        if Some(j) != exclude && p >= cut {
            ps.survivors.push(j as u32);
        }
    }
    iter.prune_survivors += ps.survivors.len() as u64;
}

/// Exact gather dot between a sparse row and a dense center row, skipping
/// zero center coordinates. Bit-identical to the inverted kernel's
/// accumulation for this center: both add the same `f64` products in the
/// same ascending-dimension order, and the skipped products are exact
/// `+0.0` no-ops (an `f32×f32` product in `f64` cannot round to zero unless
/// an operand is zero).
fn rescore(row: RowView<'_>, center: &[f32]) -> f64 {
    let mut acc = 0.0f64;
    for (&c, &v) in row.indices.iter().zip(row.values.iter()) {
        let cv = center[c as usize];
        if cv != 0.0 {
            acc += v as f64 * cv as f64;
        }
    }
    acc
}

/// Pruned equivalent of scoring all k centers and reducing with `top2`:
/// returns `(best_j, best, second)` bit-identical to the exhaustive scan,
/// with `second` clamped to `-1.0` when fewer than two centers exist.
pub(crate) fn top2_pruned(
    idx: &InvertedIndex,
    centers: &DenseMatrix,
    row: RowView<'_>,
    partial: &mut [f64],
    ps: &mut PruneScratch,
    iter: &mut IterStats,
) -> (usize, f64, f64) {
    select_survivors(idx, row, partial, ps, iter, None, f64::INFINITY);
    iter.madds_point_center += row.nnz() as u64 * ps.survivors.len() as u64;
    let mut best = f64::MIN;
    let mut second = f64::MIN;
    let mut best_j = 0;
    for &j in &ps.survivors {
        let s = rescore(row, centers.row(j as usize));
        if s > best {
            second = best;
            best = s;
            best_j = j as usize;
        } else if s > second {
            second = s;
        }
    }
    (best_j, best, second.max(-1.0))
}

/// Pruned equivalent of Hamerly's rescan reduction: the best and
/// second-best similarity among centers `j != a`, seeded with the exact
/// `l = sim(i, a)` so the walk can stop once nothing can beat the current
/// assignment. `m1` (and its argmax `jm`, first-wins on ties) is always
/// exact; `m2` may understate only below `l`, which the caller's
/// `u = l.max(m2)` masks.
pub(crate) fn best_other_pruned(
    idx: &InvertedIndex,
    centers: &DenseMatrix,
    row: RowView<'_>,
    a: usize,
    l: f64,
    partial: &mut [f64],
    ps: &mut PruneScratch,
    iter: &mut IterStats,
) -> (usize, f64, f64) {
    select_survivors(idx, row, partial, ps, iter, Some(a), l);
    iter.madds_point_center += row.nnz() as u64 * ps.survivors.len() as u64;
    let mut m1 = f64::MIN;
    let mut m2 = f64::MIN;
    let mut jm = a;
    for &j in &ps.survivors {
        let s = rescore(row, centers.row(j as usize));
        if s > m1 {
            m2 = m1;
            m1 = s;
            jm = j as usize;
        } else if s > m2 {
            m2 = s;
        }
    }
    (jm, m1, m2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::CsrMatrix;

    fn gen_problem(seed: u64, rows: usize, d: usize, k: usize, density: f64) -> (CsrMatrix, DenseMatrix) {
        let mut state = seed.wrapping_mul(0x9e3779b97f4a7c15).wrapping_add(1);
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            state
        };
        let mut indptr = vec![0usize];
        let mut indices = Vec::new();
        let mut values = Vec::new();
        for _ in 0..rows {
            let mut nnz = 0;
            for c in 0..d {
                if (next() % 10_000) as f64 / 10_000.0 < density {
                    indices.push(c as u32);
                    values.push(((next() % 2000) as f32 / 1000.0) - 1.0);
                    nnz += 1;
                }
            }
            if nnz == 0 {
                indices.push((next() % d as u64) as u32);
                values.push(1.0);
            }
            indptr.push(indices.len());
        }
        let m = CsrMatrix::from_parts(rows, d, indptr, indices, values);
        let mut cm = DenseMatrix::zeros(k, d);
        for j in 0..k {
            for c in 0..d {
                if (next() % 10_000) as f64 / 10_000.0 < density * 2.0 {
                    cm.row_mut(j)[c] = ((next() % 2000) as f32 / 1000.0) - 1.0;
                }
            }
        }
        (m, cm)
    }

    fn exhaustive(row: RowView<'_>, cm: &DenseMatrix, k: usize) -> Vec<f64> {
        (0..k).map(|j| rescore(row, cm.row(j))).collect()
    }

    #[test]
    fn top2_matches_exhaustive_scan_bit_for_bit() {
        for seed in 0..6u64 {
            for &(d, k, density) in &[(64usize, 3usize, 0.3f64), (256, 16, 0.05), (512, 40, 0.01)] {
                let (m, cm) = gen_problem(seed, 24, d, k, density);
                let idx = InvertedIndex::from_centers(&cm);
                let mut ps = PruneScratch::default();
                let mut partial = vec![0.0f64; k];
                let mut iter = IterStats::default();
                for i in 0..m.rows() {
                    let row = m.row(i);
                    let sims = exhaustive(row, &cm, k);
                    let (ebj, eb, es) = crate::kmeans::top2(&sims);
                    let (bj, b, s) =
                        top2_pruned(&idx, &cm, row, &mut partial, &mut ps, &mut iter);
                    assert_eq!((bj, b.to_bits(), s.to_bits()), (ebj, eb.to_bits(), es.to_bits()));
                    // Every pruned center must be provably below theta.
                    for &pj in &ps.pruned_members(k) {
                        assert!(
                            sims[pj] < ps.theta() + 2.0 * BOUND_MARGIN,
                            "pruned center {pj} beats theta"
                        );
                    }
                }
                assert!(iter.prune_survivors > 0);
            }
        }
    }

    #[test]
    fn best_other_keeps_m1_exact_and_m2_masked() {
        for seed in 0..6u64 {
            let (m, cm) = gen_problem(seed, 24, 256, 12, 0.05);
            let k = 12;
            let idx = InvertedIndex::from_centers(&cm);
            let mut ps = PruneScratch::default();
            let mut partial = vec![0.0f64; k];
            let mut iter = IterStats::default();
            for i in 0..m.rows() {
                let row = m.row(i);
                let sims = exhaustive(row, &cm, k);
                for a in 0..k {
                    let l = sims[a];
                    let (mut em1, mut em2, mut ejm) = (f64::MIN, f64::MIN, a);
                    for (j, &sj) in sims.iter().enumerate() {
                        if j == a {
                            continue;
                        }
                        if sj > em1 {
                            em2 = em1;
                            em1 = sj;
                            ejm = j;
                        } else if sj > em2 {
                            em2 = sj;
                        }
                    }
                    let (jm, m1, m2) =
                        best_other_pruned(&idx, &cm, row, a, l, &mut partial, &mut ps, &mut iter);
                    // m1/jm drive reassignment and must be exact.
                    assert_eq!((jm, m1.to_bits()), (ejm, em1.to_bits()));
                    // m2 only feeds `u = l.max(m2)`: either exact, or hidden
                    // below the seed.
                    assert_eq!(l.max(m2).to_bits(), l.max(em2).to_bits());
                }
            }
        }
    }

    #[test]
    fn tiny_k_and_empty_rows_take_the_generic_path() {
        let (m, cm) = gen_problem(9, 8, 32, 1, 0.2);
        let idx = InvertedIndex::from_centers(&cm);
        let mut ps = PruneScratch::default();
        let mut partial = vec![0.0f64; 1];
        let mut iter = IterStats::default();
        let (bj, _b, s) = top2_pruned(&idx, &cm, m.row(0), &mut partial, &mut ps, &mut iter);
        assert_eq!(bj, 0);
        assert_eq!(s, -1.0);
        let (jm, m1, m2) =
            best_other_pruned(&idx, &cm, m.row(0), 0, 0.5, &mut partial, &mut ps, &mut iter);
        assert_eq!((jm, m1, m2), (0, f64::MIN, f64::MIN));
    }
}
