//! Similarity-kernel crossover benchmark: dense-transpose vs inverted-file
//! vs MaxScore-pruned backends on synthetic text-like corpora of
//! decreasing density.
//!
//! For every corpus all kernels run the Standard variant from identical
//! initial centers; assignments and objectives must be **bit-identical**
//! (the kernel exactness contract), so the comparison isolates cost. The
//! acceptance bars: on sparse (< 5% density) text data at k ≥ 64 the
//! inverted file must perform **strictly fewer multiply-adds** than the
//! dense transpose, and the pruned walk strictly fewer again than the
//! inverted file (both asserted). Wall-clock columns show where each
//! backend actually wins — the dense kernel's contiguous SIMD reads buy
//! it more per madd, so its crossover sits below the madd crossover.
//!
//! Each kernel is fitted `--warmup` untimed + `--runs` timed times;
//! results are written to `BENCH_kernel.json` at the repository root in
//! the shared `sphkm.report.v1` envelope (see `sphkm::util::report`,
//! validated by `sphkm report --check`).
//!
//! ```text
//! cargo bench --bench bench_kernel -- [--rows 8000] [--k 64]
//!     [--max-iter 8] [--threads 0] [--seed 42] [--truncate 64]
//!     [--runs 3] [--warmup 1]
//! ```

// Bench and test targets favour readable literal casts and exact
// (bit-level) float assertions; the workspace clippy warnings on
// those patterns are aimed at library code.
#![allow(clippy::cast_possible_truncation, clippy::float_cmp)]

use sphkm::data::synth::SynthConfig;
use sphkm::init::{seed_centers, InitMethod};
use sphkm::kmeans::{Engine, KernelChoice, MiniBatchParams, SphericalKMeans, Variant};
use sphkm::util::benchkit::BenchOpts;
use sphkm::util::cli::Args;
use sphkm::util::json::Json;
use sphkm::util::report::{timing_fields, RunReport};
use sphkm::util::timer::{Stopwatch, TimingStats};

fn corpus(vocab: usize, rows: usize, k: usize, seed: u64) -> sphkm::data::Dataset {
    SynthConfig {
        name: format!("kern-v{vocab}"),
        n_docs: rows,
        vocab,
        topics: k.max(2),
        doc_len_mean: 60.0,
        doc_len_sigma: 0.4,
        topic_strength: 0.65,
        shared_vocab_frac: 0.2,
        zipf_s: 1.05,
        anomaly_frac: 0.0,
        tfidf: Default::default(),
    }
    .generate(seed)
}

fn main() {
    let args = Args::from_env();
    let rows: usize = args.get_or("rows", 8_000).unwrap_or(8_000);
    let k: usize = args.get_or("k", 64).unwrap_or(64);
    let max_iter: usize = args.get_or("max-iter", 8).unwrap_or(8);
    let threads: usize = args.get_or("threads", 0).unwrap_or(0);
    let seed: u64 = args.get_or("seed", 42).unwrap_or(42);
    let truncate: usize = args.get_or("truncate", 64).unwrap_or(64);
    let mut opts = BenchOpts::from_args(&args);
    if !args.has("runs") {
        opts.runs = 3; // each run is a full capped fit; 3 keeps defaults tractable
    }

    println!(
        "# kernel crossover bench — Standard variant, k={k}, {rows} rows, \
         {max_iter}-iteration cap, threads={threads}, runs={} (+{} warmup)",
        opts.runs, opts.warmup
    );
    println!(
        "{:<14} {:>8} {:>16} {:>16} {:>16} {:>10} {:>10} {:>10}",
        "corpus", "density", "dense madds", "inverted madds", "pruned madds", "dense ms", "inv ms", "pruned ms"
    );

    let mut report = RunReport::new("kernel_crossover");
    report.note("madds are exact and run-invariant; ms columns are mean over --runs");
    for (key, v) in [
        ("rows", rows),
        ("k", k),
        ("max_iter", max_iter),
        ("threads", threads),
        ("truncate", truncate),
        ("runs", opts.runs),
        ("warmup", opts.warmup),
    ] {
        report.config_num(key, v as f64);
    }
    report.config_num("seed", seed as f64);

    let mut sparse_checked = 0usize;
    for &vocab in &[1_500usize, 6_000, 24_000] {
        let ds = corpus(vocab, rows, k, seed);
        let density = ds.matrix.density();
        let init = seed_centers(&ds.matrix, k, &InitMethod::Uniform, seed ^ 1);
        let base = || {
            SphericalKMeans::new(k)
                .variant(Variant::Standard)
                .threads(threads)
                .max_iter(max_iter)
                .warm_start_centers(init.centers.clone())
        };
        // Warmup runs are discarded; every timed run re-fits from the
        // same warm-started centers, so results are run-invariant and
        // only the wall-clock samples vary.
        let time_kernel = |kc: KernelChoice| {
            let mut samples = Vec::new();
            let mut last = None;
            for it in 0..opts.warmup + opts.runs.max(1) {
                let sw = Stopwatch::start();
                let r = base()
                    .kernel(kc)
                    .fit(&ds.matrix)
                    .expect("bench configuration is valid")
                    .into_result();
                let ms = sw.ms();
                if it >= opts.warmup {
                    samples.push(ms);
                }
                last = Some(r);
            }
            (last.expect("at least one run"), TimingStats::from_ms(&samples))
        };

        let (dense, dense_t) = time_kernel(KernelChoice::Dense);
        let (inv, inv_t) = time_kernel(KernelChoice::Inverted);
        let (pruned, pruned_t) = time_kernel(KernelChoice::Pruned);

        // Kernel exactness contract: identical clustering, bit for bit.
        for (other, what) in [(&inv, "inverted"), (&pruned, "pruned")] {
            assert_eq!(
                dense.assignments, other.assignments,
                "{vocab}: {what} assignments"
            );
            assert_eq!(
                dense.objective.to_bits(),
                other.objective.to_bits(),
                "{vocab}: {what} objective"
            );
            assert_eq!(
                dense.stats.total_point_center(),
                other.stats.total_point_center(),
                "{vocab}: {what} similarity counts"
            );
        }

        let dm = dense.stats.total_madds();
        let im = inv.stats.total_madds();
        let pm = pruned.stats.total_madds();
        println!(
            "{:<14} {:>7.3}% {:>16} {:>16} {:>16} {:>10.1} {:>10.1} {:>10.1}",
            ds.name,
            density * 100.0,
            dm,
            im,
            pm,
            dense_t.mean_ms,
            inv_t.mean_ms,
            pruned_t.mean_ms
        );
        let mut row = vec![
            ("corpus".to_string(), Json::Str(ds.name.clone())),
            ("density".to_string(), Json::Num(density)),
            ("dense_madds".to_string(), Json::Num(dm as f64)),
            ("inverted_madds".to_string(), Json::Num(im as f64)),
            ("pruned_madds".to_string(), Json::Num(pm as f64)),
            (
                "prune_terms".to_string(),
                Json::Num(pruned.stats.total_prune_terms() as f64),
            ),
            (
                "prune_survivors".to_string(),
                Json::Num(pruned.stats.total_prune_survivors() as f64),
            ),
        ];
        row.extend(timing_fields("dense", &dense_t));
        row.extend(timing_fields("inverted", &inv_t));
        row.extend(timing_fields("pruned", &pruned_t));
        report.push_result(row);
        if density < 0.05 {
            assert!(
                im < dm,
                "{}: inverted file must do strictly fewer madds ({im} vs {dm})",
                ds.name
            );
            assert!(
                pm < im,
                "{}: pruned walk must do strictly fewer madds than the \
                 inverted file ({pm} vs {im})",
                ds.name
            );
            sparse_checked += 1;
        }
    }
    assert!(
        sparse_checked > 0,
        "no corpus fell under the 5% density bar — acceptance not exercised"
    );

    // Sparse-centroid regime: truncated mini-batch centers cap the postings
    // at truncate·k, where the inverted file is strongest.
    if truncate > 0 {
        let ds = corpus(24_000, rows, k, seed);
        let init = seed_centers(&ds.matrix, k, &InitMethod::Uniform, seed ^ 1);
        let base = || {
            SphericalKMeans::new(k)
                .engine(Engine::MiniBatch(MiniBatchParams {
                    batch_size: 1024,
                    epochs: 4,
                    truncate: Some(truncate),
                    ..Default::default()
                }))
                .seed(seed)
                .threads(threads)
                .warm_start_centers(init.centers.clone())
        };
        let time_kernel = |kc: KernelChoice| {
            let mut samples = Vec::new();
            let mut last = None;
            for it in 0..opts.warmup + opts.runs.max(1) {
                let sw = Stopwatch::start();
                let r = base()
                    .kernel(kc)
                    .fit(&ds.matrix)
                    .expect("bench configuration is valid")
                    .into_result();
                let ms = sw.ms();
                if it >= opts.warmup {
                    samples.push(ms);
                }
                last = Some(r);
            }
            (last.expect("at least one run"), TimingStats::from_ms(&samples))
        };
        let (dense, dense_t) = time_kernel(KernelChoice::Dense);
        let (inv, inv_t) = time_kernel(KernelChoice::Inverted);
        assert_eq!(dense.assignments, inv.assignments, "minibatch assignments");
        assert_eq!(
            dense.objective.to_bits(),
            inv.objective.to_bits(),
            "minibatch objective"
        );
        let (dm, im) = (dense.stats.total_madds(), inv.stats.total_madds());
        assert!(im < dm, "truncated minibatch: {im} vs {dm} madds");
        let label = format!("mb top-{truncate}");
        let mut row = vec![
            ("corpus".to_string(), Json::Str(label.clone())),
            ("density".to_string(), Json::Null),
            ("dense_madds".to_string(), Json::Num(dm as f64)),
            ("inverted_madds".to_string(), Json::Num(im as f64)),
        ];
        row.extend(timing_fields("dense", &dense_t));
        row.extend(timing_fields("inverted", &inv_t));
        report.push_result(row);
        println!(
            "{:<14} {:>8} {:>16} {:>16} {:>6.1}x {:>10.1} {:>10.1}",
            label,
            "-",
            dm,
            im,
            dm as f64 / im.max(1) as f64,
            dense_t.mean_ms,
            inv_t.mean_ms
        );
    }

    let json_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_kernel.json");
    debug_assert!(
        RunReport::check_str(&report.to_json().pretty(2)).is_ok(),
        "emitting an invalid report"
    );
    match report.save(&json_path) {
        Ok(()) => println!("# wrote {}", json_path.display()),
        Err(e) => println!("# could not write {}: {e}", json_path.display()),
    }

    println!(
        "# acceptance: bit-identical clusterings; inverted file strictly fewer \
         madds than dense, pruned walk strictly fewer than inverted, on every \
         <5% density corpus at k={k} — OK"
    );
}
