//! Experiment coordinator: drivers that regenerate every table and figure
//! of the paper, plus report rendering.

pub mod experiments;
pub mod plot;
pub mod report;
