//! Regenerates **Table 2** of the paper: relative change of the converged
//! objective vs uniform random initialization for k-means++ and AFK-MC²
//! with α ∈ {1, 1.5}, across datasets and k.
//!
//! ```text
//! cargo bench --bench bench_table2 -- [--scale S] [--reps 10] [--ks ...]
//!     [--runs N] [--warmup W]
//! ```
//!
//! `--runs` is honored as an alias for `--reps` (the uniform bench-suite
//! spelling) when `--reps` is absent; `--warmup W` runs W untimed tiny
//! passes before the measured experiment.

// Bench and test targets favour readable literal casts and exact
// (bit-level) float assertions; the workspace clippy warnings on
// those patterns are aimed at library code.
#![allow(clippy::cast_possible_truncation, clippy::float_cmp)]

use sphkm::coordinator::experiments::{self, ExperimentOpts};
use sphkm::data::datasets::Scale;
use sphkm::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let mut opts = ExperimentOpts::from_args(&args);
    if args.has("runs") && !args.has("reps") {
        opts.reps = args.get_or("runs", opts.reps).unwrap_or(opts.reps).max(1);
    } else if !args.has("reps") && !args.flag("quick") {
        opts.reps = 3; // paper: 10 seeds; 3 keeps the default run tractable
    }
    let warmup: usize = args.get_or("warmup", 0).unwrap_or(0);
    for _ in 0..warmup {
        println!("# warmup pass (untimed)");
        let mut w = opts.clone();
        w.scale = Scale::Tiny;
        w.reps = 1;
        w.ks = vec![2];
        experiments::table2(&w);
    }
    println!("# Table 2 bench — scale={}, reps={}", opts.scale.name(), opts.reps);
    experiments::table2(&opts);
}
