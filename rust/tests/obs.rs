//! Integration tests for the observability subsystem (`sphkm::obs` +
//! `sphkm::util::{json, report}`): exact histogram quantiles, the
//! merge-equals-serial property, real serve latency percentiles from the
//! timed batch path, run-report round-trips, and — under the `trace`
//! feature — a full fit-to-JSONL trace round-trip whose phase spans
//! account for fit wall-clock.

// Bench and test targets favour readable literal casts and exact
// (bit-level) float assertions; the workspace clippy warnings on
// those patterns are aimed at library code.
#![allow(clippy::cast_possible_truncation, clippy::float_cmp)]

use sphkm::data::synth::SynthConfig;
use sphkm::kmeans::SphericalKMeans;
use sphkm::obs::{LatencyHistogram, Metrics};
use sphkm::serve::{QueryEngine, ServeConfig, ServeMode};
use sphkm::util::json::Json;
use sphkm::util::prop::forall;
use sphkm::util::report::{timing_fields, RunReport};
use sphkm::util::timer::TimingStats;

fn corpus(rows: usize, k: usize, seed: u64) -> sphkm::data::Dataset {
    SynthConfig {
        name: "obs-test".into(),
        n_docs: rows,
        vocab: 2_000,
        topics: k.max(2),
        doc_len_mean: 40.0,
        doc_len_sigma: 0.4,
        topic_strength: 0.65,
        shared_vocab_frac: 0.2,
        zipf_s: 1.05,
        anomaly_frac: 0.0,
        tfidf: Default::default(),
    }
    .generate(seed)
}

#[test]
fn quantiles_are_exact_on_small_samples() {
    // Samples on bucket lower bounds (powers of two) report exactly.
    let mut h = LatencyHistogram::new();
    for ns in [4u64, 8, 16, 32, 64, 128, 256, 512, 1024, 2048] {
        h.record_ns(ns);
    }
    assert_eq!(h.count(), 10);
    assert_eq!(h.quantile_ns(0.50), 32); // nearest rank 5
    assert_eq!(h.quantile_ns(0.95), 2048); // rank 10
    assert_eq!(h.quantile_ns(0.99), 2048);
    assert_eq!(h.quantile_ns(0.0), 4);
    assert_eq!(h.quantile_ns(1.0), 2048);
    // Quantiles are monotone in q and clamped to [min, max].
    let mut prev = 0;
    for q in [0.0, 0.1, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99, 1.0] {
        let v = h.quantile_ns(q);
        assert!(v >= prev, "quantile not monotone at q={q}");
        assert!((h.min_ns()..=h.max_ns()).contains(&v));
        prev = v;
    }
}

#[test]
fn histogram_merge_is_associative_commutative_and_equals_serial() {
    forall(200, 0x0B5_CAFE, |g| {
        // Random sample set split across three "shards" in random order.
        let n = g.usize_in(0, 64);
        let mut serial = LatencyHistogram::new();
        let mut shards = [
            LatencyHistogram::new(),
            LatencyHistogram::new(),
            LatencyHistogram::new(),
        ];
        for _ in 0..n {
            // Log-uniform-ish spread: pick an octave, then an offset.
            let octave = g.usize_in(0, 40) as u32;
            let ns = (1u64 << octave) + g.usize_in(0, 1 << octave.min(20)) as u64;
            serial.record_ns(ns);
            let s = g.usize_in(0, 3);
            shards[s].record_ns(ns);
        }
        // (a ⊕ b) ⊕ c == a ⊕ (b ⊕ c) == serial, in any operand order.
        let mut left = shards[0].clone();
        left.merge(&shards[1]);
        left.merge(&shards[2]);
        let mut right = shards[2].clone();
        right.merge(&shards[1]);
        right.merge(&shards[0]);
        let mut bc = shards[1].clone();
        bc.merge(&shards[2]);
        let mut assoc = shards[0].clone();
        assoc.merge(&bc);
        assert_eq!(left, serial, "case {}", g.case);
        assert_eq!(right, serial, "case {}", g.case);
        assert_eq!(assoc, serial, "case {}", g.case);
    });
}

#[test]
fn timed_serve_batch_reports_real_latency_percentiles() {
    let k = 16;
    let ds = corpus(600, k, 7);
    let fitted = SphericalKMeans::new(k)
        .seed(7)
        .threads(1)
        .max_iter(5)
        .fit(&ds.matrix)
        .expect("valid config");
    let model = fitted.to_model();
    for threads in [1usize, 0] {
        let engine =
            QueryEngine::new(model.clone(), &ServeConfig { mode: ServeMode::Pruned, threads });
        let (plain, plain_stats) = engine.top_p_batch(&ds.matrix, 3);
        let (timed, timed_stats, hist) = engine.top_p_batch_timed(&ds.matrix, 3);
        // The timed path answers bit-identically and counts every query.
        assert_eq!(plain, timed, "threads={threads}");
        assert_eq!(plain_stats, timed_stats, "threads={threads}");
        assert_eq!(hist.count(), ds.matrix.rows() as u64);
        // Real per-query latencies: positive, ordered percentiles.
        let (p50, p95, p99) = (hist.quantile_ns(0.50), hist.quantile_ns(0.95), hist.quantile_ns(0.99));
        assert!(p50 > 0, "p50 must be a real measurement");
        assert!(hist.min_ns() <= p50 && p50 <= p95 && p95 <= p99 && p99 <= hist.max_ns());
        assert!(hist.mean_ns() > 0.0);
    }
}

#[test]
fn metrics_registry_round_trips_through_schema_stamped_json() {
    let mut m = Metrics::new();
    m.incr("serve.queries", 600);
    m.set_gauge("serve.qps", 1234.5);
    for ns in [1_000u64, 2_000, 4_000] {
        m.observe_ns("serve.query", ns);
    }
    let doc = Json::Obj(vec![
        (
            "schema".to_string(),
            Json::Str(sphkm::obs::metrics::METRICS_SCHEMA.to_string()),
        ),
        ("metrics".to_string(), m.to_json()),
    ]);
    let text = doc.pretty(2);
    let back = Json::parse(&text).expect("parses");
    assert_eq!(back.get("schema").and_then(Json::as_str), Some("sphkm.metrics.v1"));
    let hist = back
        .get("metrics")
        .and_then(|m| m.get("histograms"))
        .and_then(|h| h.get("serve.query"))
        .expect("histogram summary");
    assert_eq!(hist.get("count").and_then(Json::as_f64), Some(3.0));
    assert!(hist.get("p99_ns").and_then(Json::as_f64).is_some());
}

#[test]
fn run_report_round_trips_and_validates() {
    let mut r = RunReport::new("obs_selftest");
    r.note("integration round trip");
    r.config_num("rows", 600.0);
    r.config_str("variant", "standard");
    let t = TimingStats::from_ms(&[1.0, 2.0, 3.0]);
    let mut row = vec![("corpus".to_string(), Json::Str("obs-test".to_string()))];
    row.extend(timing_fields("fit", &t));
    r.push_result(row);
    let text = r.to_json().pretty(2);
    RunReport::check_str(&text).expect("valid report");
    let doc = Json::parse(&text).unwrap();
    let rows = doc.get("results").and_then(Json::as_arr).unwrap();
    assert_eq!(rows[0].get("fit_mean_ms").and_then(Json::as_f64), Some(2.0));
    assert_eq!(rows[0].get("fit_runs").and_then(Json::as_f64), Some(3.0));
    // Write-to-disk path, as the benches use it.
    let path = std::env::temp_dir()
        .join(format!("sphkm-obs-report-{}.json", std::process::id()));
    r.save(&path).expect("save");
    let on_disk = std::fs::read_to_string(&path).expect("read back");
    std::fs::remove_file(&path).ok();
    RunReport::check_str(&on_disk).expect("valid on disk");
}

/// With the `trace` feature off every phase table must stay identically
/// zero: the spans compile to nothing and the fit pays no timing cost.
#[cfg(not(feature = "trace"))]
#[test]
fn phase_tables_are_zero_without_the_trace_feature() {
    assert!(!sphkm::obs::TRACE_ENABLED);
    let k = 8;
    let ds = corpus(400, k, 11);
    let fitted = SphericalKMeans::new(k)
        .seed(11)
        .threads(1)
        .max_iter(4)
        .fit(&ds.matrix)
        .expect("valid config");
    assert!(fitted.stats().phase_totals().is_zero());
    for it in &fitted.stats().iters {
        assert!(it.phases.is_zero());
    }
}

/// With the `trace` feature on, a fit's phase spans are live: the
/// disjoint barrier phases must account for fit wall-clock (within 10%
/// plus a small constant for loop overhead), and an emitted JSONL trace
/// must validate against `sphkm.trace.v1`.
#[cfg(feature = "trace")]
#[test]
fn traced_fit_emits_valid_jsonl_and_phases_cover_wall_clock() {
    use std::ops::ControlFlow;

    use sphkm::obs::{TraceWriter, TRACE_ENABLED};
    use sphkm::util::timer::Stopwatch;

    assert!(TRACE_ENABLED);
    let k = 16;
    let ds = corpus(2_000, k, 13);
    let path = std::env::temp_dir()
        .join(format!("sphkm-obs-trace-{}.jsonl", std::process::id()));
    let mut w = TraceWriter::create(&path).expect("create trace");
    w.record(
        "run_start",
        vec![
            ("algo".to_string(), Json::Str("simp-elkan".to_string())),
            ("k".to_string(), Json::Num(k as f64)),
            ("n".to_string(), Json::Num(ds.matrix.rows() as f64)),
            ("d".to_string(), Json::Num(ds.matrix.cols() as f64)),
            ("threads".to_string(), Json::Num(1.0)),
        ],
    )
    .expect("run_start");

    let sw = Stopwatch::start();
    let fitted = SphericalKMeans::new(k)
        .seed(13)
        .threads(1)
        .max_iter(8)
        .fit_observed(&ds.matrix, &mut |s: &sphkm::kmeans::IterSnapshot<'_>| {
            w.record(
                "iter",
                vec![
                    ("iteration".to_string(), Json::Num(s.iteration as f64)),
                    ("wall_ms".to_string(), Json::Num(s.iter_ms)),
                    ("elapsed_ms".to_string(), Json::Num(s.elapsed_ms)),
                    (
                        "sims_point_center".to_string(),
                        Json::Num(s.stats.sims_point_center as f64),
                    ),
                    (
                        "reassignments".to_string(),
                        Json::Num(s.stats.reassignments as f64),
                    ),
                    ("converged".to_string(), Json::Bool(s.converged)),
                    ("phases".to_string(), s.stats.phases.to_json()),
                ],
            )
            .expect("iter record");
            ControlFlow::Continue(())
        })
        .expect("valid config");
    let wall_ms = sw.ms();

    let totals = fitted.stats().phase_totals();
    w.record(
        "run_end",
        vec![
            ("iterations".to_string(), Json::Num(fitted.iterations() as f64)),
            ("objective".to_string(), Json::Num(fitted.objective())),
            ("total_ms".to_string(), Json::Num(wall_ms)),
            ("phases".to_string(), totals.to_json()),
        ],
    )
    .expect("run_end");
    let records = w.records();
    w.finish().expect("flush");
    drop(w);

    // The trace round-trips through the validator.
    let text = std::fs::read_to_string(&path).expect("read trace");
    std::fs::remove_file(&path).ok();
    assert_eq!(sphkm::obs::trace::validate_trace(&text).expect("valid trace"), records);
    assert!(records >= 3, "run_start + at least one iter + run_end");

    // The disjoint barrier phases account for the fit: their sum sits
    // within 10% of wall-clock (plus 5 ms slack for tiny fits where loop
    // overhead dominates), and never exceeds it.
    assert!(!totals.is_zero(), "spans must be live under --features trace");
    let covered = totals.barrier_ms();
    assert!(
        covered <= wall_ms * 1.01 + 1.0,
        "phases ({covered:.2} ms) cannot exceed wall-clock ({wall_ms:.2} ms)"
    );
    assert!(
        covered >= wall_ms * 0.9 - 5.0,
        "phases ({covered:.2} ms) must cover >=90% of wall-clock ({wall_ms:.2} ms)"
    );
}
