//! Spherical k-means and its accelerated variants (§5 of the paper).
//!
//! All variants share the alternating-optimization outline: assign every
//! point to the most-similar center, then recompute each center as the
//! unit-scaled sum of its points. They differ only in how many of the
//! point×center similarity computations they can *prove unnecessary*:
//!
//! | Variant | Bounds kept | Extra per-iteration cost |
//! |---|---|---|
//! | [`Variant::Standard`] | none | — |
//! | [`Variant::Elkan`] | `l(i)`, `u(i,j)` (N·k) | `k²/2` center–center sims |
//! | [`Variant::SimplifiedElkan`] | `l(i)`, `u(i,j)` (N·k) | — |
//! | [`Variant::Hamerly`] | `l(i)`, `u(i)` (2·N) | `k²/2` center–center sims |
//! | [`Variant::SimplifiedHamerly`] | `l(i)`, `u(i)` (2·N) | — |
//! | [`Variant::Yinyang`] | `l(i)`, `u(i,g)` (N·(G+1)) | `k²/2` (group ceilings) |
//! | [`Variant::Exponion`] | `l(i)`, `u(i)` (2·N) | `k²/2` sims + `k² log k` sort |
//!
//! Every accelerated variant is **exact**: given the same initial centers it
//! produces the same assignment sequence as [`Variant::Standard`] (this is
//! asserted by the `exactness` integration tests).
//!
//! Beyond the exact family, the [`minibatch`] submodule hosts the
//! **mini-batch engine** for corpora too large for full-batch passes: it
//! trades a bounded approximation of the objective for an order of
//! magnitude fewer point×center similarity computations (deterministic,
//! sharded, optionally with Knittel-style truncated sparse centroids). It
//! is selected through [`Engine::MiniBatch`] with typed
//! [`MiniBatchParams`] — it is deliberately *not* a
//! [`Variant`], because it does not satisfy the exactness contract above.
//!
//! # Front door
//!
//! Every engine is reached through the [`SphericalKMeans`] estimator
//! ([`estimator`] module): shared knobs on the builder, per-engine knobs
//! in the typed [`Engine`] payloads, a fallible
//! [`SphericalKMeans::fit`] returning a [`FittedModel`] that persists,
//! serves, and resumes, plus [`Observer`] hooks for progress and early
//! stopping. The free functions `run` / `run_seeded` /
//! `run_with_centers` / `run_dataset` / `minibatch::run` /
//! `minibatch::run_with_centers` survive one release as deprecated shims
//! delegating to the same internal path (bit-identical results — see the
//! `shims` integration suite and the README migration table).
//!
//! # Parallel execution
//!
//! The assignment phase of every variant runs on the sharded executor of
//! [`crate::runtime::parallel`]: rows are split into contiguous shards
//! ([`crate::runtime::parallel::Plan`], a pure function of the row count)
//! and each shard owns its rows' mutable state — assignments, per-point
//! bounds, scratch similarity rows, and an [`IterStats`] accumulator. The
//! worker count comes from [`KMeansConfig::threads`] (`0` = all cores;
//! `1`, the default, runs the identical code inline with no thread pool).
//!
//! **Shard-determinism contract.** Results are bit-for-bit identical for
//! every `threads` setting, because nothing an iteration computes depends
//! on shard scheduling:
//!
//! 1. Centers are *frozen* during a pass — similarities are pure functions
//!    of the previous barrier's centers, so each point's decision is
//!    independent of every other point's.
//! 2. Center-sum maintenance is *deferred*: shards record [`Move`]s instead
//!    of calling [`Centers::apply_move`], and the barrier replays them in
//!    ascending point order — the exact floating-point sequence the serial
//!    loop produces.
//! 3. [`IterStats`] counters are per-shard integers summed at the barrier
//!    (exact in any order), and the one floating-point reduction keyed on a
//!    shard grid ([`Centers::rebuild_sharded`]) uses a grid derived from
//!    the problem shape alone, never from the thread count.
//!
//! The `parallel_matches_serial` integration suite asserts the contract
//! (bit-identical assignments and objectives) for all seven variants.
//!
//! # Similarity kernels
//!
//! Every similarity the bounds cannot prune lands in an all-centers pass,
//! which runs on the pluggable kernel layer of [`kernel`]: the
//! **dense-transpose** backend (d×k f32 copy, contiguous SIMD-friendly
//! reads, `O(d·k)` memory), the **gather** backend (k separate sparse×dense
//! dots — the paper's cost model, no derived structure), or the
//! **inverted-file** backend (a CSC postings index over the center
//! non-zeros, [`crate::sparse::InvertedIndex`]) that skips every
//! (point, center) pair sharing no term and avoids the d×k footprint
//! entirely — the right choice for 100k+-term vocabularies and truncated
//! sparse centroids. The **pruned** backend (the `pruned` submodule) keeps
//! the same postings index but walks it MaxScore-style — terms in
//! descending `|q_c|·maxw[c]` order with suffix upper bounds, seeded from
//! the caller's Elkan/Hamerly cosine lower bound where one exists — and
//! re-scores the few surviving centers with the exact gather dot, so the
//! all-centers pass itself is pruned while results stay bit-identical.
//! [`KMeansConfig::kernel`] selects
//! ([`KernelChoice::Auto`] resolves from the problem shape); the Dense,
//! Inverted, and Pruned backends accumulate identically (ascending
//! dimension order) and are **bit-identical**, extending the exactness
//! contract across kernels. Derived structures are refreshed per update
//! barrier for dirty centers only — clean centers provably did not move.
//!
//! # Out-of-core data
//!
//! Every engine consumes point data through
//! [`RowSource`](crate::sparse::RowSource): either the in-memory
//! [`CsrMatrix`] or a chunked on-disk shard store
//! ([`crate::sparse::chunked`]) read chunk-at-a-time through per-shard
//! cursors. The shard grid is a pure function of the row count — never of
//! the backend or chunk size — and the deferred-move replay at the
//! barrier is backend-agnostic, so results are **bit-identical** between
//! backends for every thread count and chunk size (asserted by the
//! `out_of_core` integration suite). Reach the disk backend through
//! [`SphericalKMeans::fit_source`].
//!
//! # Audit mode
//!
//! Under the `audit` cargo feature ([`crate::audit`]) every bound-based
//! skip the variants take is cross-checked against the exactly recomputed
//! cosine, and [`Centers::check_invariants`] re-verifies the center bank
//! at every iteration barrier. Violations surface as
//! [`FitError::AuditViolation`] from [`SphericalKMeans::fit`] and through
//! [`IterSnapshot::audit_violations`]; results stay bit-identical to an
//! unaudited run either way.
//!
//! ```no_run
//! use sphkm::kmeans::{KernelChoice, SphericalKMeans, Variant};
//! # let data = sphkm::data::synth::SynthConfig::small_demo().generate(1).matrix;
//! // Simplified Hamerly on 8 clusters, using every available core and
//! // the inverted-file similarity kernel.
//! let fitted = SphericalKMeans::new(8)
//!     .variant(Variant::SimplifiedHamerly)
//!     .kernel(KernelChoice::Inverted)
//!     .threads(0)
//!     .fit(&data)
//!     .expect("valid configuration");
//! ```

pub mod centers;
pub mod estimator;
pub mod kernel;
pub mod minibatch;
pub mod stats;

mod elkan;
mod exponion;
mod hamerly;
mod pruned;
mod simplified_elkan;
mod simplified_hamerly;
mod standard;
mod yinyang;

use crate::audit::AuditViolation;
use crate::data::Dataset;
use crate::init::InitMethod;
use crate::obs::{span::span_start, Phase};
use crate::runtime::parallel::{split_mut, Plan, Pool};
use crate::sparse::csr::RowView;
use crate::sparse::{CsrMatrix, DenseMatrix, RowCursor, RowSource};
use crate::util::timer::Stopwatch;
use std::ops::Range;
pub use centers::Centers;
pub use estimator::{
    Engine, ExactParams, FitError, FittedModel, IterSnapshot, MiniBatchParams, Observer,
    SphericalKMeans, TrainState,
};
pub use kernel::{DataShape, Kernel, KernelChoice};
pub use stats::{IterStats, RunStats};

/// Which algorithm variant to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Variant {
    /// The baseline spherical k-means (Dhillon & Modha 2001) with the §5
    /// implementation optimizations but no pruning.
    Standard,
    /// Spherical Elkan (§5.2): per-center upper bounds + center–center
    /// pruning (`cc`/`s` tests).
    Elkan,
    /// Spherical Simplified Elkan (§5.1, after Newling & Fleuret): per-center
    /// upper bounds only.
    SimplifiedElkan,
    /// Spherical Hamerly (§5.3): one upper bound per point + `s` test.
    Hamerly,
    /// Spherical Simplified Hamerly (§5.4): one upper bound, no `s` test.
    SimplifiedHamerly,
    /// Spherical Yinyang (§5.5 — listed as future work in the paper;
    /// implemented here): group bounds between Elkan and Hamerly.
    Yinyang,
    /// Spherical Exponion (§5.5 — beyond the paper): Hamerly's bounds plus
    /// sorted center-neighbor annulus search instead of full re-scans.
    Exponion,
}

impl Variant {
    /// All variants evaluated in the paper's experiments (Table 3 order).
    pub const PAPER_SET: [Variant; 5] = [
        Variant::Standard,
        Variant::Elkan,
        Variant::SimplifiedElkan,
        Variant::Hamerly,
        Variant::SimplifiedHamerly,
    ];

    /// All implemented variants, including extensions.
    pub const ALL: [Variant; 7] = [
        Variant::Standard,
        Variant::Elkan,
        Variant::SimplifiedElkan,
        Variant::Hamerly,
        Variant::SimplifiedHamerly,
        Variant::Yinyang,
        Variant::Exponion,
    ];

    /// Display name matching the paper's tables.
    pub fn name(&self) -> &'static str {
        match self {
            Variant::Standard => "Standard",
            Variant::Elkan => "Elkan",
            Variant::SimplifiedElkan => "Simp.Elkan",
            Variant::Hamerly => "Hamerly",
            Variant::SimplifiedHamerly => "Simp.Hamerly",
            Variant::Yinyang => "Yinyang",
            Variant::Exponion => "Exponion",
        }
    }
}

impl std::fmt::Display for Variant {
    /// The paper-table spelling of [`Variant::name`]; round-trips through
    /// [`FromStr`](std::str::FromStr).
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for Variant {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().replace(['_', '.'], "-").as_str() {
            "standard" | "lloyd" => Ok(Variant::Standard),
            "elkan" => Ok(Variant::Elkan),
            "simplified-elkan" | "simp-elkan" | "selkan" => Ok(Variant::SimplifiedElkan),
            "hamerly" => Ok(Variant::Hamerly),
            "simplified-hamerly" | "simp-hamerly" | "shamerly" => Ok(Variant::SimplifiedHamerly),
            "yinyang" | "yin-yang" => Ok(Variant::Yinyang),
            "exponion" => Ok(Variant::Exponion),
            other => Err(format!("unknown variant: {other}")),
        }
    }
}

/// Configuration for one clustering run.
#[derive(Debug, Clone)]
pub struct KMeansConfig {
    /// Number of clusters.
    pub k: usize,
    /// Algorithm variant.
    pub variant: Variant,
    /// Seeding method.
    pub init: InitMethod,
    /// Maximum number of assignment iterations (safety cap; the paper runs
    /// to convergence, which all experiments here reach well before this).
    pub max_iter: usize,
    /// RNG seed for the seeding method.
    pub seed: u64,
    /// Worker threads for the sharded assignment phase: `0` = all
    /// available cores, `1` (default) = the exact serial path with no
    /// thread pool. Results are bit-identical for every setting — see the
    /// shard-determinism contract in the [module docs](crate::kmeans).
    pub threads: usize,
    /// Number of center groups for [`Variant::Yinyang`]; defaults to
    /// `max(1, k/10)` as in Ding et al. (2015) when `None`.
    pub yinyang_groups: Option<usize>,
    /// Which similarity-kernel backend computes the all-centers passes —
    /// see [`kernel`]. [`KernelChoice::Auto`] (the default) resolves per
    /// run from the problem shape: the inverted-file (CSC postings) kernel
    /// when centers are expected to stay sparse; otherwise the dense
    /// transpose, degrading to gather when the d×k footprint is
    /// prohibitive.
    /// [`KernelChoice::Gather`] is the paper-faithful cost model (identical
    /// per-similarity machinery to the pruned variants' selective
    /// computations — c.f. Kriegel et al., "are we comparing algorithms or
    /// implementations?"), which the experiment drivers default to.
    pub kernel: KernelChoice,
    /// Use the guarded min-p single-bound update
    /// ([`crate::bounds::hamerly_bound::update_min_p_guarded`]) instead of
    /// the paper's Eq. 9 in the Hamerly and Yinyang variants. Exact either
    /// way; the guarded rule is provably the tightest single bound (an
    /// improvement over the paper — see `bench_bounds` for the ablation).
    pub tight_hamerly_bound: bool,
    /// Mini-batch engine only ([`minibatch`]): points sampled per batch.
    /// Clamped to the row count at run time. Ignored by the exact
    /// full-batch variants.
    pub batch_size: usize,
    /// Mini-batch engine only: maximum number of epochs; each epoch draws
    /// `ceil(n / batch_size)` deterministic batches (one corpus-worth of
    /// samples).
    pub epochs: usize,
    /// Mini-batch engine only: convergence tolerance on the largest
    /// per-epoch center movement `1 − ⟨c_j, c'_j⟩` (cosine distance);
    /// the run stops early once every center moved less than this over a
    /// whole epoch.
    pub tol: f64,
    /// Mini-batch engine only: optional center truncation — keep only the
    /// `m` largest-magnitude coordinates of each center, renormalized to
    /// unit length (Knittel et al. 2021's sparsified centroids). `None`
    /// keeps exact dense centers.
    pub truncate: Option<usize>,
}

impl KMeansConfig {
    /// Config with defaults: Standard variant, uniform init, 200
    /// iterations, single-threaded.
    pub fn new(k: usize) -> Self {
        Self {
            k,
            variant: Variant::Standard,
            init: InitMethod::Uniform,
            max_iter: 200,
            seed: 0,
            threads: 1,
            yinyang_groups: None,
            kernel: KernelChoice::Auto,
            tight_hamerly_bound: false,
            batch_size: 1024,
            epochs: 10,
            tol: 1e-4,
            truncate: None,
        }
    }

    /// Select the similarity-kernel backend (see [`KMeansConfig::kernel`]).
    #[must_use]
    pub fn kernel(mut self, k: KernelChoice) -> Self {
        self.kernel = k;
        self
    }

    /// Enable the guarded min-p Hamerly bound (beyond-paper improvement).
    #[must_use]
    pub fn tight_bound(mut self, on: bool) -> Self {
        self.tight_hamerly_bound = on;
        self
    }

    /// Set the variant.
    #[must_use]
    pub fn variant(mut self, v: Variant) -> Self {
        self.variant = v;
        self
    }

    /// Set the seeding method.
    #[must_use]
    pub fn init(mut self, i: InitMethod) -> Self {
        self.init = i;
        self
    }

    /// Set the RNG seed.
    #[must_use]
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    /// Set the iteration cap.
    #[must_use]
    pub fn max_iter(mut self, m: usize) -> Self {
        self.max_iter = m;
        self
    }

    /// Set the worker-thread count (see [`KMeansConfig::threads`]).
    #[must_use]
    pub fn threads(mut self, t: usize) -> Self {
        self.threads = t;
        self
    }

    /// Set the mini-batch size (see [`KMeansConfig::batch_size`]).
    #[must_use]
    pub fn batch_size(mut self, b: usize) -> Self {
        self.batch_size = b;
        self
    }

    /// Set the mini-batch epoch cap (see [`KMeansConfig::epochs`]).
    #[must_use]
    pub fn epochs(mut self, e: usize) -> Self {
        self.epochs = e;
        self
    }

    /// Set the mini-batch convergence tolerance (see [`KMeansConfig::tol`]).
    #[must_use]
    pub fn tol(mut self, t: f64) -> Self {
        self.tol = t;
        self
    }

    /// Set the center-truncation knob (see [`KMeansConfig::truncate`]).
    #[must_use]
    pub fn truncate(mut self, m: Option<usize>) -> Self {
        self.truncate = m;
        self
    }
}

/// The outcome of a clustering run.
#[derive(Debug, Clone)]
pub struct KMeansResult {
    /// Cluster assignment per row of the input.
    pub assignments: Vec<u32>,
    /// Final unit-normalized centers (k × d).
    pub centers: DenseMatrix,
    /// The spherical k-means objective `Σᵢ (1 − ⟨xᵢ, c(a(i))⟩)` —
    /// equal to half the within-cluster sum of squared Euclidean deviations
    /// on unit vectors; lower is better (Table 2 reports relative changes
    /// of this quantity).
    pub objective: f64,
    /// Mean cosine similarity of points to their centers (higher is better).
    pub mean_similarity: f64,
    /// Number of assignment iterations performed (excluding the initial
    /// full assignment pass).
    pub iterations: usize,
    /// True if the run converged (no reassignments) before `max_iter`.
    pub converged: bool,
    /// The similarity-kernel backend the run actually resolved and
    /// executed (what [`KMeansConfig::kernel`] became — see [`kernel`]).
    pub kernel: Kernel,
    /// Per-iteration instrumentation.
    pub stats: RunStats,
}

/// How an exact-engine fit starts — the one internal entry every public
/// surface (the [`SphericalKMeans`] estimator and the deprecated `run*`
/// shims) funnels into.
pub(crate) struct ExactStart<'o> {
    /// Initial centers. Normalized on a fresh start; adopted bit-for-bit
    /// when `resume` is set (a resumed run must see exactly the
    /// coordinates the interrupted run saved).
    pub centers: DenseMatrix,
    /// Row-major N×k point-to-seed similarities from the seeding method
    /// (§7 synergy); pre-initializes the bounds and skips the initial
    /// `O(N·k)` assignment pass.
    pub sim_matrix: Option<Vec<f32>>,
    /// Training state of an interrupted run: restores the f64 sum
    /// accumulators, counts, and assignments so the continued trajectory
    /// is bit-identical to an uninterrupted one.
    pub resume: Option<TrainState>,
    /// Steps completed by prior fits of this lineage (provenance).
    pub prior_steps: u64,
    /// Per-iteration hook (progress reporting / early stopping).
    pub obs: Option<&'o mut dyn Observer>,
}

/// Run one exact-engine fit over either data backend. The consolidated
/// internal path behind [`SphericalKMeans::fit`] /
/// [`SphericalKMeans::fit_source`] and the deprecated `run`/`run_seeded`/
/// `run_with_centers`/`run_dataset` shims. The third element carries the
/// bound-certification findings of an audited run ([`crate::audit`]):
/// always empty unless the `audit` cargo feature is on, and empty on a
/// clean audited run.
pub(crate) fn fit_exact(
    src: RowSource<'_>,
    cfg: &KMeansConfig,
    start: ExactStart<'_>,
) -> (KMeansResult, TrainState, Vec<AuditViolation>) {
    let mut ctx = Ctx::new(src, start, cfg);
    let converged = dispatch(&mut ctx, cfg);
    ctx.into_result(converged)
}

/// Cluster `data` (rows must be unit-normalized — see
/// [`CsrMatrix::normalize_rows`]) according to `cfg`.
#[deprecated(
    since = "0.2.0",
    note = "use `SphericalKMeans::fit` (see the README migration table)"
)]
pub fn run(data: &CsrMatrix, cfg: &KMeansConfig) -> KMeansResult {
    let init = crate::init::seed_centers(data, cfg.k, &cfg.init, cfg.seed);
    exact_shim(data, init.centers, None, cfg)
}

/// Cluster `data` from a seeding outcome, consuming the point-to-seed
/// similarity matrix (if the seeding collected one — see
/// [`crate::init::seed_centers_with_bounds`]) to **pre-initialize the
/// bounds** and skip the initial `O(N·k)` assignment pass entirely: the
/// paper's §7 synergy. A conservative margin (±1e-5) is applied to the
/// collected f32 similarities so they remain valid f64 bounds.
#[deprecated(
    since = "0.2.0",
    note = "use `SphericalKMeans::fit` with `ExactParams::preinit` (see the README migration table)"
)]
pub fn run_seeded(
    data: &CsrMatrix,
    init: crate::init::InitOutcome,
    cfg: &KMeansConfig,
) -> KMeansResult {
    if let Some(m) = &init.sim_matrix {
        assert_eq!(m.len(), data.rows() * cfg.k, "sim matrix shape");
    }
    exact_shim(data, init.centers, init.sim_matrix, cfg)
}

/// Cluster `data` starting from explicit initial centers (rows will be
/// normalized).
#[deprecated(
    since = "0.2.0",
    note = "use `SphericalKMeans::fit` with `warm_start_centers` (see the README migration table)"
)]
pub fn run_with_centers(
    data: &CsrMatrix,
    initial_centers: DenseMatrix,
    cfg: &KMeansConfig,
) -> KMeansResult {
    exact_shim(data, initial_centers, None, cfg)
}

/// Shared body of the deprecated exact shims: the old entry points'
/// assertions, then straight into the consolidated [`fit_exact`] path —
/// which is why they stay bit-identical to the estimator (asserted by
/// the `shims` integration suite).
fn exact_shim(
    data: &CsrMatrix,
    centers: DenseMatrix,
    sim_matrix: Option<Vec<f32>>,
    cfg: &KMeansConfig,
) -> KMeansResult {
    assert_eq!(centers.rows(), cfg.k, "initial centers vs k");
    assert_eq!(centers.cols(), data.cols(), "center dimensionality");
    assert!(cfg.k >= 1, "need at least one cluster");
    let (result, _state, violations) = fit_exact(
        RowSource::Mem(data),
        cfg,
        ExactStart { centers, sim_matrix, resume: None, prior_steps: 0, obs: None },
    );
    // The deprecated shims have no error channel; under the `audit`
    // feature a certification failure must not be silently dropped.
    if let Some(v) = violations.first() {
        panic!("{v}");
    }
    result
}

fn dispatch(ctx: &mut Ctx<'_, '_>, cfg: &KMeansConfig) -> bool {
    match cfg.variant {
        Variant::Standard => standard::run(ctx, cfg),
        Variant::Elkan => elkan::run(ctx, cfg),
        Variant::SimplifiedElkan => simplified_elkan::run(ctx, cfg),
        Variant::Hamerly => hamerly::run(ctx, cfg),
        Variant::SimplifiedHamerly => simplified_hamerly::run(ctx, cfg),
        Variant::Yinyang => yinyang::run(ctx, cfg),
        Variant::Exponion => exponion::run(ctx, cfg),
    }
}

/// Safety margin applied to f32 similarities collected during seeding so
/// they remain valid f64 bounds (f32 rounding + center renormalization).
const PREINIT_MARGIN: f64 = 1e-5;

/// `(argmax, max, second_max)` of a similarity row. With a single center
/// (`k = 1`) there is no runner-up: the second-best is clamped to `-1.0`,
/// the cosine floor, so bound initializers can consume it directly as a
/// valid (vacuous) upper bound on "all other centers" instead of guarding
/// against a `f64::MIN` sentinel.
#[inline]
pub(crate) fn top2(sims: &[f64]) -> (usize, f64, f64) {
    let mut best = f64::MIN;
    let mut second = f64::MIN;
    let mut best_j = 0usize;
    for (j, &s) in sims.iter().enumerate() {
        if s > best {
            second = best;
            best = s;
            best_j = j;
        } else if s > second {
            second = s;
        }
    }
    (best_j, best, second.max(-1.0))
}

/// One deferred reassignment recorded by a shard during an assignment
/// pass: point `i` left cluster `from` for cluster `to`. Replayed through
/// [`Centers::apply_move`] at the barrier, in ascending point order, so
/// the incrementally maintained center sums see the exact floating-point
/// sequence the serial loop would have produced. Elkan-family scans can
/// reassign one point several times within a pass; every hop is recorded.
pub(crate) struct Move {
    /// Row index of the point.
    pub i: u32,
    /// Cluster the point left.
    pub from: u32,
    /// Cluster the point joined.
    pub to: u32,
}

/// Everything a shard produces during one assignment pass: its counter
/// accumulator, its deferred reassignments (in processing order), and —
/// under the `audit` feature — the bound-certification violations its
/// rows produced (always empty otherwise; an empty `Vec` never
/// allocates).
#[derive(Default)]
pub(crate) struct ShardOut {
    pub iter: IterStats,
    pub moves: Vec<Move>,
    pub violations: Vec<AuditViolation>,
}

/// Work list for a sharded assignment pass of the bound-keeping variants:
/// each shard's row range paired with its mutable slices of the assignment
/// vector (width 1), a first bound buffer (`wa` entries per row — `l`),
/// and a second one (`wb` entries per row — `u`/`u(i,j)`/`u(i,g)`).
pub(crate) type BoundWorks<'w> = Vec<(Range<usize>, &'w mut [u32], &'w mut [f64], &'w mut [f64])>;

/// Build the per-shard work list every bound-keeping variant feeds to
/// [`Pool::run`]: the shard grid zipped with [`split_mut`] carvings of the
/// assignment vector and the two bound buffers. Centralized so the
/// slice/range alignment — which the determinism contract depends on —
/// lives in exactly one place.
pub(crate) fn bound_works<'w>(
    plan: &Plan,
    assign: &'w mut [u32],
    a: &'w mut [f64],
    wa: usize,
    b: &'w mut [f64],
    wb: usize,
) -> BoundWorks<'w> {
    let assign = split_mut(plan, 1, assign);
    let sa = split_mut(plan, wa, a);
    let sb = split_mut(plan, wb, b);
    let mut works = Vec::with_capacity(plan.len());
    for (((r, x), y), z) in plan.ranges().iter().cloned().zip(assign).zip(sa).zip(sb) {
        works.push((r, x, y, z));
    }
    works
}

/// Per-shard `(bounds_a, bounds_b)` state pairs for
/// [`Ctx::initial_assignment`], carved with the same grid as
/// [`bound_works`].
pub(crate) fn bound_states<'w>(
    plan: &Plan,
    a: &'w mut [f64],
    wa: usize,
    b: &'w mut [f64],
    wb: usize,
) -> Vec<(&'w mut [f64], &'w mut [f64])> {
    split_mut(plan, wa, a)
        .into_iter()
        .zip(split_mut(plan, wb, b))
        .collect()
}

/// Per-shard similarity engine of one assignment pass: a row cursor over
/// the data backend ([`RowSource`]), the centers **frozen at the last
/// barrier**, and `k`. Similarities computed through the view are pure
/// functions of those centers — they cannot observe other shards' work,
/// which is what makes the row shards independent.
///
/// Each shard constructs its own view inside its worker closure
/// ([`SimView::new`] is cheap for the in-memory backend; for the disk
/// backend it opens the shard file and buffers one chunk at a time),
/// which is why the methods take `&mut self`: the disk cursor reloads its
/// chunk on access. Row reads are index-based so the engines never touch
/// the backend directly.
pub(crate) struct SimView<'a> {
    rows: RowCursor<'a>,
    pub centers: &'a Centers,
    pub k: usize,
    /// Scratch for the bound-pruned kernel, allocated lazily on first use
    /// and reused across every point this shard processes — the pruned hot
    /// loop performs no per-point allocations.
    prune: Option<pruned::PruneScratch>,
}

impl<'a> SimView<'a> {
    /// Open a view over `src` against the frozen `centers`.
    pub fn new(src: RowSource<'a>, centers: &'a Centers, k: usize) -> Self {
        Self { rows: src.cursor(), centers, k, prune: None }
    }

    /// Borrow row `i` of the data backend.
    #[inline]
    pub fn row(&mut self, i: usize) -> RowView<'_> {
        self.rows.row(i)
    }

    /// Compute similarities of row `i` to **all** centers into `scratch`
    /// (length k) through the active kernel backend; returns
    /// `(argmax, best, second_best)`. Charges `k` similarity computations
    /// plus the backend's multiply-adds.
    #[inline]
    pub fn similarities_full(
        &mut self,
        i: usize,
        iter: &mut IterStats,
        scratch: &mut [f64],
    ) -> (usize, f64, f64) {
        let row = self.rows.row(i);
        iter.madds_point_center += self.centers.sims_all(row, scratch);
        iter.sims_point_center += self.k as u64;
        top2(scratch)
    }

    /// All-centers similarity row of point `i` through the active kernel,
    /// without the `sims_point_center` charge — Hamerly-family re-scans
    /// ignore the assigned center's entry and bill `k − 1` sims
    /// themselves. The backend's multiply-adds are charged here.
    #[inline]
    pub fn sims_row(&mut self, i: usize, iter: &mut IterStats, scratch: &mut [f64]) {
        let row = self.rows.row(i);
        iter.madds_point_center += self.centers.sims_all(row, scratch);
    }

    /// One point×center similarity (gather dot — the selective-similarity
    /// path every pruned variant uses), charged to `iter`.
    #[inline]
    pub fn similarity(&mut self, i: usize, j: usize, iter: &mut IterStats) -> f64 {
        let centers = self.centers;
        let row = self.rows.row(i);
        iter.sims_point_center += 1;
        iter.madds_point_center += row.nnz() as u64;
        row.dot_dense(centers.center(j))
    }

    /// Kernel-dispatched full assignment of point `i`: `(argmax, best,
    /// second_best)`, bit-identical to [`SimView::similarities_full`] on
    /// every backend and charged identically (`k` sims). Under
    /// [`Kernel::Pruned`] the all-centers scan is replaced by the
    /// MaxScore-style postings walk of [`pruned::top2_pruned`]; `scratch`
    /// then holds *partial* scores, not similarities — callers needing the
    /// full similarity row must use `similarities_full` instead. Each
    /// pruned decision is certified through [`audit_set_prune`] when the
    /// `audit` feature is on.
    #[allow(clippy::too_many_arguments)]
    #[inline]
    pub fn assign_top2(
        &mut self,
        i: usize,
        iteration: usize,
        iter: &mut IterStats,
        violations: &mut Vec<AuditViolation>,
        scratch: &mut [f64],
    ) -> (usize, f64, f64) {
        if self.centers.kernel() != Kernel::Pruned {
            return self.similarities_full(i, iter, scratch);
        }
        let centers = self.centers;
        let idx = centers.inverted().expect("pruned kernel keeps a postings index");
        let ps = self.prune.get_or_insert_with(pruned::PruneScratch::default);
        let row = self.rows.row(i);
        let (bj, best, second) =
            pruned::top2_pruned(idx, centers.centers(), row, scratch, ps, iter);
        iter.sims_point_center += self.k as u64;
        if crate::audit::AUDIT_ENABLED {
            let (members, theta) = {
                let ps = self.prune.as_ref().expect("just populated");
                (ps.pruned_members(self.k), ps.theta())
            };
            audit_set_prune(
                self,
                violations,
                "pruned-kernel",
                iteration,
                i,
                bj,
                members,
                Some(theta),
                Some(best),
            );
        }
        (bj, best, second)
    }

    /// Kernel-dispatched "best center other than `a`" for the Hamerly
    /// rescan: `(argmax_other, m1, m2)` over `j ≠ a`, charged `k − 1` sims
    /// on every backend. `l` must be the caller's exact `sim(i, a)` (the
    /// tightened cosine lower bound); under [`Kernel::Pruned`] it seeds
    /// the traversal threshold so already-tight points stop after a few
    /// terms. `m1`/`jm` are always exact; `m2` may understate only below
    /// `l`, which the caller's `u = l.max(m2)` update masks — trajectories
    /// stay bit-identical.
    #[allow(clippy::too_many_arguments)]
    #[inline]
    pub fn best_other(
        &mut self,
        i: usize,
        a: usize,
        l: f64,
        iteration: usize,
        iter: &mut IterStats,
        violations: &mut Vec<AuditViolation>,
        scratch: &mut [f64],
    ) -> (usize, f64, f64) {
        if self.centers.kernel() != Kernel::Pruned {
            self.sims_row(i, iter, scratch);
            iter.sims_point_center += (self.k - 1) as u64;
            let mut m1 = f64::MIN;
            let mut m2 = f64::MIN;
            let mut jm = a;
            for (j, &sj) in scratch.iter().enumerate() {
                if j == a {
                    continue;
                }
                if sj > m1 {
                    m2 = m1;
                    m1 = sj;
                    jm = j;
                } else if sj > m2 {
                    m2 = sj;
                }
            }
            return (jm, m1, m2);
        }
        let centers = self.centers;
        let idx = centers.inverted().expect("pruned kernel keeps a postings index");
        let ps = self.prune.get_or_insert_with(pruned::PruneScratch::default);
        let row = self.rows.row(i);
        let (jm, m1, m2) =
            pruned::best_other_pruned(idx, centers.centers(), row, a, l, scratch, ps, iter);
        iter.sims_point_center += (self.k - 1) as u64;
        if crate::audit::AUDIT_ENABLED {
            let (members, theta) = {
                let ps = self.prune.as_ref().expect("just populated");
                (ps.pruned_members(self.k), ps.theta())
            };
            audit_set_prune(
                self,
                violations,
                "pruned-kernel",
                iteration,
                i,
                a,
                members,
                Some(theta),
                Some(l),
            );
        }
        (jm, m1, m2)
    }
}

// ---------------------------------------------------------------------------
// Bound-certification helpers (`audit` feature — see `crate::audit`).
//
// Every call site sits behind `if crate::audit::AUDIT_ENABLED`, a
// compile-time constant, so the unaudited build compiles these calls out
// of the hot loops entirely. The reference similarities are recomputed
// with a direct gather dot — never through `SimView::similarity` — so
// the `IterStats` counters (and therefore the run's recorded trajectory)
// stay bit-identical between audited and unaudited runs.
// ---------------------------------------------------------------------------

/// Exactly recompute `sim(i, j)` against the frozen barrier centers,
/// outside the counted similarity paths.
#[inline]
pub(crate) fn audit_sim(view: &mut SimView<'_>, i: usize, j: usize) -> f64 {
    let centers = view.centers;
    view.row(i).dot_dense(centers.center(j))
}

/// Certify a **per-center** skip: the engine declined to compute
/// `sim(i, j)` because a bound proved center `j` cannot beat the assigned
/// center `a`. Checks, against exactly recomputed similarities:
/// `upper`-validity (`sim(i, j) ≤ upper`, when the decision used one),
/// `lower`-validity (`sim(i, a) ≥ lower`), and decision safety (`j` does
/// not actually beat `a` — the check that catches a mutated *comparison*
/// even when both bounds are individually valid).
#[allow(clippy::too_many_arguments)]
pub(crate) fn audit_center_prune(
    view: &mut SimView<'_>,
    out: &mut Vec<AuditViolation>,
    engine: &'static str,
    iteration: usize,
    i: usize,
    a: usize,
    j: usize,
    upper: Option<f64>,
    lower: f64,
) {
    let sj = audit_sim(view, i, j);
    let sa = audit_sim(view, i, a);
    if let Some(u) = upper {
        if crate::audit::exceeds_upper(u, sj) {
            out.push(AuditViolation::bound(
                engine,
                "upper-bound-prune",
                iteration,
                Some(i),
                Some(j),
                u,
                sj,
            ));
        }
    }
    if crate::audit::below_lower(lower, sa) {
        out.push(AuditViolation::bound(
            engine,
            "lower-bound",
            iteration,
            Some(i),
            Some(a),
            lower,
            sa,
        ));
    }
    if sj > sa + 2.0 * crate::audit::AUDIT_MARGIN {
        let mut v =
            AuditViolation::bound(engine, "unsafe-prune", iteration, Some(i), Some(j), sa, sj);
        v.detail = format!("pruned center {j} actually beats assigned center {a}");
        out.push(v);
    }
}

/// Certify a **set** skip: the engine declined to scan every center in
/// `members` (a whole-point skip, a Yinyang group, Exponion's
/// out-of-annulus tail, …). For each member `j ≠ a`: `upper`-validity
/// when the decision used a shared upper bound, and decision safety
/// (`j` does not actually beat `a`). `lower`-validity on the assigned
/// center is checked once when `lower` is given.
#[allow(clippy::too_many_arguments)]
pub(crate) fn audit_set_prune(
    view: &mut SimView<'_>,
    out: &mut Vec<AuditViolation>,
    engine: &'static str,
    iteration: usize,
    i: usize,
    a: usize,
    members: impl IntoIterator<Item = usize>,
    upper: Option<f64>,
    lower: Option<f64>,
) {
    let sa = audit_sim(view, i, a);
    if let Some(l) = lower {
        if crate::audit::below_lower(l, sa) {
            out.push(AuditViolation::bound(
                engine,
                "lower-bound",
                iteration,
                Some(i),
                Some(a),
                l,
                sa,
            ));
        }
    }
    for j in members {
        if j == a {
            continue;
        }
        let sj = audit_sim(view, i, j);
        if let Some(u) = upper {
            if crate::audit::exceeds_upper(u, sj) {
                out.push(AuditViolation::bound(
                    engine,
                    "upper-bound-prune",
                    iteration,
                    Some(i),
                    Some(j),
                    u,
                    sj,
                ));
            }
        }
        if sj > sa + 2.0 * crate::audit::AUDIT_MARGIN {
            let mut v =
                AuditViolation::bound(engine, "unsafe-prune", iteration, Some(i), Some(j), sa, sj);
            v.detail = format!("skipped center {j} actually beats assigned center {a}");
            out.push(v);
        }
    }
}

/// Certify a **whole-loop** skip: the engine kept point `i` on center `a`
/// without scanning any other center (Elkan's `s`-test, the Hamerly
/// `u ≤ l` test). Equivalent to [`audit_set_prune`] over all `k` centers.
pub(crate) fn audit_loop_prune(
    view: &mut SimView<'_>,
    out: &mut Vec<AuditViolation>,
    engine: &'static str,
    iteration: usize,
    i: usize,
    a: usize,
    lower: f64,
) {
    let k = view.k;
    audit_set_prune(view, out, engine, iteration, i, a, 0..k, None, Some(lower));
}

/// Shared mutable state threaded through every algorithm implementation.
pub(crate) struct Ctx<'a, 'o> {
    /// The point data, behind either backend ([`RowSource`] is `Copy`:
    /// shard closures copy it and open their own cursors).
    pub src: RowSource<'a>,
    pub k: usize,
    pub assign: Vec<u32>,
    pub centers: Centers,
    pub stats: RunStats,
    /// Bound-certification findings collected by an audited run
    /// ([`crate::audit`]): shard findings merged at every barrier plus
    /// data-structure invariant failures. Always empty without the
    /// `audit` feature.
    pub violations: Vec<AuditViolation>,
    /// Row-shard grid for the assignment phase (a function of the row
    /// count only — see the module docs).
    pub plan: Plan,
    /// Worker pool executing the shards.
    pub pool: Pool,
    /// Row-major N×k point-to-seed similarities from the seeding method
    /// (§7 synergy); consumed by [`Ctx::initial_assignment`].
    pub preinit: Option<Vec<f32>>,
    /// True when this run continues an interrupted one from restored
    /// accumulator state: [`Ctx::initial_assignment`] then re-derives the
    /// bound structures *without* reassigning or rebuilding sums.
    resume: bool,
    /// Steps completed by prior fits of this lineage.
    prior_steps: u64,
    /// Per-iteration hook, notified by [`Ctx::push_iter`].
    obs: Option<&'o mut dyn Observer>,
    /// Started at context construction (= fit start); drives the
    /// [`IterSnapshot::elapsed_ms`] wall-clock field.
    fit_sw: Stopwatch,
}

impl<'a, 'o> Ctx<'a, 'o> {
    fn new(src: RowSource<'a>, start: ExactStart<'o>, cfg: &KMeansConfig) -> Self {
        let k = start.centers.rows();
        let plan = Plan::for_rows(src.rows());
        // A single-shard plan can never use more than one worker — skip
        // thread-pool construction entirely (runs on tiny inputs would
        // otherwise spawn threads that do no work).
        let threads = if plan.len() <= 1 { 1 } else { cfg.threads };
        // Resolve the similarity kernel once, from the problem shape (the
        // exact variants keep dense centers, so no truncation estimate).
        let kernel = cfg.kernel.resolve(&DataShape::of_source(src, k, None));
        let (assign, centers, resume) = match start.resume {
            Some(state) => (
                state.assignments,
                // Restored bit-for-bit: centers, f64 sums, counts.
                Centers::restore(start.centers, state.sums, state.counts, kernel),
                true,
            ),
            None => (
                vec![0; src.rows()],
                Centers::from_initial_for(start.centers, kernel),
                false,
            ),
        };
        // Audit mode certifies the training input once up front: a CSR
        // matrix that breaks its own invariants invalidates every bound
        // derived from it. The disk backend's structure was validated at
        // `ShardStore::open` (header/length) and is spot-checked per
        // chunk load; there is no resident matrix to deep-verify.
        let mut violations = Vec::new();
        if crate::audit::AUDIT_ENABLED {
            if let RowSource::Mem(m) = src {
                if let Err(v) = m.check_invariants() {
                    violations.push(v);
                }
            }
        }
        Self {
            src,
            k,
            assign,
            centers,
            stats: RunStats::default(),
            violations,
            plan,
            pool: Pool::new(threads),
            preinit: if resume { None } else { start.sim_matrix },
            resume,
            prior_steps: start.prior_steps,
            obs: start.obs,
            fit_sw: Stopwatch::start(),
        }
    }

    /// Whether this run resumes restored state (variants without bound
    /// structures skip their initial pass entirely — see
    /// [`Ctx::resume_marker`]).
    #[inline]
    pub fn resuming(&self) -> bool {
        self.resume
    }

    /// Record a completed iteration and notify the observer. Returns
    /// `true` when the observer requests an early stop — the variant loop
    /// must then return without starting another iteration.
    ///
    /// Under the `audit` feature this is also the **iteration barrier**
    /// at which the deep data-structure invariants re-verify: the center
    /// bank has just completed its update, so the f64 sums, f32 centers,
    /// norms, and derived kernel structures must all cohere
    /// ([`Centers::check_invariants`]).
    pub(crate) fn push_iter(&mut self, iter: IterStats, converged: bool) -> bool {
        self.stats.iters.push(iter);
        if crate::audit::AUDIT_ENABLED {
            let iteration = self.stats.iters.len() - 1;
            if let Err(v) = self.centers.check_invariants(false) {
                self.violations.push(v.at_iteration(iteration));
            }
        }
        self.notify(converged)
    }

    fn notify(&mut self, converged: bool) -> bool {
        let Some(obs) = self.obs.as_deref_mut() else {
            return false;
        };
        let iteration = self.stats.iters.len() - 1;
        let snap = IterSnapshot {
            iteration,
            stats: &self.stats.iters[iteration],
            converged,
            center_shift: None,
            audit_violations: &self.violations,
            elapsed_ms: self.fit_sw.ms(),
            iter_ms: self.stats.iters[iteration].wall_ms,
        };
        obs.on_iteration(&snap).is_break()
    }

    /// Iteration-0 placeholder for resumed runs of variants that keep no
    /// bound state (Standard): records an empty stats entry so resumed
    /// and fresh runs count iterations alike, and notifies the observer.
    /// Returns `true` on an early-stop request.
    pub(crate) fn resume_marker(&mut self) -> bool {
        self.push_iter(IterStats::default(), false)
    }

    /// The initial full assignment pass shared by all variants: assigns
    /// every point to its most similar initial center (one row shard per
    /// worker), records an iteration-0 stats entry, and rebuilds the
    /// center sums via the shard-partial path.
    ///
    /// `states` carries one mutable bound-capture state per shard of
    /// [`Ctx::plan`] (build it with [`split_mut`]); for every point the
    /// shard owns, `on_point(state, local_i, best_j, best, second, sims_row)`
    /// lets the variant record whatever bound state it needs. `local_i`
    /// indexes into the shard's slices; `sims_row` is only filled when
    /// `want_sims_row` is set.
    ///
    /// **Resumed runs** ([`Ctx::resuming`]) re-derive bound state without
    /// touching the restored assignments or sums: `on_point` then receives
    /// the point's *current* cluster `a` with `best = sim(i, a)` and
    /// `second = max_{j≠a} sim(i, j)` — exact values, hence valid (tight)
    /// bounds — and no rebuild/update barrier runs, so the first real
    /// iteration continues the interrupted trajectory bit-for-bit.
    ///
    /// Returns `true` when the observer requested an early stop.
    pub fn initial_assignment<S, F>(
        &mut self,
        want_sims_row: bool,
        states: Vec<S>,
        on_point: F,
    ) -> bool
    where
        S: Send,
        F: Fn(&mut S, usize, usize, f64, f64, &[f64]) + Sync + Send,
    {
        if self.resume {
            return self.resume_bound_init(states, on_point);
        }
        assert_eq!(states.len(), self.plan.len(), "one state per shard");
        let sw = Stopwatch::start();
        let k = self.k;
        let pre = self.preinit.take();
        let mut iter = IterStats::default();
        let sp = span_start();
        {
            let src = self.src;
            let centers = &self.centers;
            let pre = pre.as_deref();
            let mut works: Vec<(Range<usize>, &mut [u32], S)> =
                Vec::with_capacity(self.plan.len());
            {
                let shards = split_mut(&self.plan, 1, &mut self.assign);
                for ((r, a), s) in self.plan.ranges().iter().cloned().zip(shards).zip(states) {
                    works.push((r, a, s));
                }
            }
            let outs = self.pool.run(works, |_, (range, assign, mut state)| {
                let mut it = IterStats::default();
                let mut viol: Vec<AuditViolation> = Vec::new();
                let mut sims_row = vec![0.0f64; k];
                if let Some(pre) = pre {
                    // §7 synergy: bounds come from the seeding pass for
                    // free. Margins keep the f32 values valid as f64
                    // bounds; l gets a downward margin, u values an upward
                    // one.
                    for (li, i) in range.enumerate() {
                        let row = &pre[i * k..(i + 1) * k];
                        let mut best = f64::MIN;
                        let mut second = f64::MIN;
                        let mut bj = 0usize;
                        for (j, &s) in row.iter().enumerate() {
                            let s = s as f64;
                            if s > best {
                                second = best;
                                best = s;
                                bj = j;
                            } else if s > second {
                                second = s;
                            }
                        }
                        let second = second.max(-1.0);
                        if want_sims_row {
                            for (o, &s) in sims_row.iter_mut().zip(row.iter()) {
                                *o = s as f64 + PREINIT_MARGIN;
                            }
                        }
                        assign[li] = bj as u32;
                        on_point(
                            &mut state,
                            li,
                            bj,
                            best - PREINIT_MARGIN,
                            second + PREINIT_MARGIN,
                            &sims_row,
                        );
                    }
                } else {
                    let mut view = SimView::new(src, centers, k);
                    if want_sims_row {
                        // Bound-seeding engines consume the full similarity
                        // row, so the pruned kernel cannot skip any center
                        // here; the exhaustive backends all land in
                        // `similarities_full`.
                        for (li, i) in range.enumerate() {
                            let (bj, b, s) = view.similarities_full(i, &mut it, &mut sims_row);
                            assign[li] = bj as u32;
                            on_point(&mut state, li, bj, b, s, &sims_row);
                        }
                    } else {
                        for (li, i) in range.enumerate() {
                            let (bj, b, s) =
                                view.assign_top2(i, 0, &mut it, &mut viol, &mut sims_row);
                            assign[li] = bj as u32;
                            on_point(&mut state, li, bj, b, s, &sims_row);
                        }
                    }
                }
                (it, viol)
            });
            for (o, v) in outs {
                iter.absorb(&o);
                self.violations.extend(v);
            }
        }
        iter.phases.record(Phase::Assignment, sp);
        iter.reassignments = self.src.rows() as u64;
        // Build sums for the initial assignment and move centers once.
        let sp = span_start();
        self.centers
            .rebuild_sharded_source(self.src, &self.assign, &self.pool);
        iter.sims_center_center += self.centers.update();
        iter.phases.record(Phase::Update, sp);
        iter.phases
            .shift(Phase::Update, Phase::IndexRefresh, self.centers.take_refresh_ms());
        iter.wall_ms = sw.ms();
        self.push_iter(iter, false)
    }

    /// Resume-mode counterpart of [`Ctx::initial_assignment`]: one full
    /// similarity pass that only (re)derives bound state — assignments,
    /// sums, and centers are the restored accumulators and must not move.
    fn resume_bound_init<S, F>(&mut self, states: Vec<S>, on_point: F) -> bool
    where
        S: Send,
        F: Fn(&mut S, usize, usize, f64, f64, &[f64]) + Sync + Send,
    {
        assert_eq!(states.len(), self.plan.len(), "one state per shard");
        let sw = Stopwatch::start();
        let k = self.k;
        let mut iter = IterStats::default();
        let sp = span_start();
        {
            let src = self.src;
            let centers = &self.centers;
            let assign: &[u32] = &self.assign;
            let mut works: Vec<(Range<usize>, S)> = Vec::with_capacity(self.plan.len());
            for (r, s) in self.plan.ranges().iter().cloned().zip(states) {
                works.push((r, s));
            }
            let outs = self.pool.run(works, |_, (range, mut state)| {
                let mut it = IterStats::default();
                let mut sims_row = vec![0.0f64; k];
                let mut view = SimView::new(src, centers, k);
                for (li, i) in range.enumerate() {
                    let (_, _, _) = view.similarities_full(i, &mut it, &mut sims_row);
                    let a = assign[i] as usize;
                    // Exact values are the tightest valid bounds: the
                    // assigned-center similarity and the best among the
                    // *other* centers (cosine floor when k = 1).
                    let mut other = f64::MIN;
                    for (j, &s) in sims_row.iter().enumerate() {
                        if j != a && s > other {
                            other = s;
                        }
                    }
                    on_point(&mut state, li, a, sims_row[a], other.max(-1.0), &sims_row);
                }
                it
            });
            for o in &outs {
                iter.absorb(o);
            }
        }
        // A resume pass only (re)derives bound state — charge it to the
        // bounds-maintenance phase rather than assignment.
        iter.phases.record(Phase::Bounds, sp);
        iter.wall_ms = sw.ms();
        self.push_iter(iter, false)
    }

    /// Barrier after a sharded assignment pass: fold every shard's
    /// counters into `iter` and replay the deferred reassignments in
    /// ascending point order (shards are ascending and contiguous, and
    /// each shard records its moves in processing order, so concatenation
    /// *is* the serial order). After this returns, `iter.reassignments`
    /// holds the pass's total move count.
    pub(crate) fn merge_shards(&mut self, outs: Vec<ShardOut>, iter: &mut IterStats) {
        // One local cursor replays every move; on the disk backend the
        // ascending replay order makes this a sequential chunk walk.
        let src = self.src;
        let mut rows = src.cursor();
        for out in outs {
            iter.absorb(&out.iter);
            self.violations.extend(out.violations);
            for mv in out.moves {
                self.centers
                    .apply_move(rows.row(mv.i as usize), mv.from as usize, mv.to as usize);
            }
        }
    }

    /// Finalize: compute the objective and assemble the result plus the
    /// resumable training state (the accumulators a continued fit
    /// restores — see [`TrainState`]) and any audit violations the run
    /// collected (empty unless the `audit` feature found a problem).
    fn into_result(self, converged: bool) -> (KMeansResult, TrainState, Vec<AuditViolation>) {
        let mut obj = 0.0f64;
        {
            let mut rows = self.src.cursor();
            for i in 0..self.src.rows() {
                let s = rows
                    .row(i)
                    .dot_dense(self.centers.center(self.assign[i] as usize));
                obj += 1.0 - s;
            }
        }
        let n = self.src.rows().max(1) as f64;
        let iterations = self.stats.iters.len().saturating_sub(1);
        let state = TrainState {
            steps_done: self.prior_steps + iterations as u64,
            converged,
            assignments: self.assign.clone(),
            counts: self.centers.counts().to_vec(),
            sums: self.centers.sums().to_vec(),
            minibatch: None,
        };
        let result = KMeansResult {
            mean_similarity: 1.0 - obj / n,
            objective: obj,
            assignments: self.assign,
            kernel: self.centers.kernel(),
            centers: self.centers.centers().clone(),
            iterations,
            converged,
            stats: self.stats,
        };
        (result, state, self.violations)
    }
}

/// Convenience: cluster a [`Dataset`] (which carries its matrix plus
/// metadata) and return the result.
#[deprecated(
    since = "0.2.0",
    note = "use `SphericalKMeans::fit_dataset` (see the README migration table)"
)]
pub fn run_dataset(ds: &Dataset, cfg: &KMeansConfig) -> KMeansResult {
    let init = crate::init::seed_centers(&ds.matrix, cfg.k, &cfg.init, cfg.seed);
    exact_shim(&ds.matrix, init.centers, None, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_parsing_and_names() {
        assert_eq!("elkan".parse::<Variant>().unwrap(), Variant::Elkan);
        assert_eq!(
            "Simp_Elkan".parse::<Variant>().unwrap(),
            Variant::SimplifiedElkan
        );
        assert_eq!(
            "simplified-hamerly".parse::<Variant>().unwrap(),
            Variant::SimplifiedHamerly
        );
        assert_eq!("YinYang".parse::<Variant>().unwrap(), Variant::Yinyang);
        assert!("nope".parse::<Variant>().is_err());
        for v in Variant::ALL {
            assert!(!v.name().is_empty());
            // Display ↔ FromStr round trip, exhaustively over ALL.
            assert_eq!(v.to_string(), v.name());
            assert_eq!(v.to_string().parse::<Variant>().unwrap(), v);
        }
    }

    #[test]
    fn config_builder() {
        let cfg = KMeansConfig::new(7)
            .variant(Variant::Hamerly)
            .seed(9)
            .max_iter(50)
            .threads(4);
        assert_eq!(cfg.k, 7);
        assert_eq!(cfg.variant, Variant::Hamerly);
        assert_eq!(cfg.seed, 9);
        assert_eq!(cfg.max_iter, 50);
        assert_eq!(cfg.threads, 4);
        assert_eq!(KMeansConfig::new(2).threads, 1, "serial by default");
        let mb = KMeansConfig::new(3)
            .batch_size(512)
            .epochs(6)
            .tol(1e-3)
            .truncate(Some(64));
        assert_eq!(mb.batch_size, 512);
        assert_eq!(mb.epochs, 6);
        assert_eq!(mb.tol, 1e-3);
        assert_eq!(mb.truncate, Some(64));
        assert_eq!(KMeansConfig::new(2).truncate, None, "dense by default");
        assert_eq!(
            KMeansConfig::new(2).kernel,
            KernelChoice::Auto,
            "auto kernel by default"
        );
        let kc = KMeansConfig::new(2).kernel(KernelChoice::Inverted);
        assert_eq!(kc.kernel, KernelChoice::Inverted);
    }

    #[test]
    fn top2_clamps_missing_runner_up_to_cosine_floor() {
        // k = 1: no runner-up exists; the second-best must be the cosine
        // floor, not the f64::MIN sentinel.
        let (j, best, second) = top2(&[0.25]);
        assert_eq!(j, 0);
        assert_eq!(best, 0.25);
        assert_eq!(second, -1.0);
        // k ≥ 2: real similarities (≥ −1) are unaffected by the clamp.
        let (j, best, second) = top2(&[0.1, 0.9, -0.5]);
        assert_eq!((j, best, second), (1, 0.9, 0.1));
        let (_, _, second) = top2(&[-1.0, -1.0]);
        assert_eq!(second, -1.0);
    }
}
