//! The `sphkm.rpc.v1` wire protocol: newline-delimited JSON frames over
//! a byte stream (the daemon's TCP sockets, a test's in-memory pipe).
//!
//! Every frame is one JSON object on one line. Requests carry
//! `"rpc": "sphkm.rpc.v1"` (frames without the stamp are rejected — a
//! client speaking a future incompatible revision fails loudly instead
//! of being half-understood) and an `"op"` selector; replies carry the
//! stamp, `"ok"`, and on success echo the `"op"`. Error replies are
//! `{"ok": false, "error": "…"}` and never terminate the connection —
//! the line framing survives any malformed *content*, so one bad request
//! costs one error frame, not the session. Only a frame that breaks the
//! *framing itself* (longer than [`MAX_FRAME_BYTES`] without a newline,
//! or not UTF-8) forces a disconnect, since the byte stream can no
//! longer be resynchronized.
//!
//! Similarities travel as JSON numbers rendered by the shortest
//! round-trip `f64` formatter ([`crate::util::json`]), so a reply
//! carries the server's scores **bit-exactly** — what lets the
//! daemon-smoke CI job and the swap-under-load bench demand bitwise
//! equality between daemon answers and one-shot [`QueryEngine`] runs.
//!
//! [`QueryEngine`]: crate::serve::QueryEngine

use std::io::{self, Read, Write};

use crate::util::json::Json;

/// Protocol identifier stamped on every request and reply frame; bump on
/// any breaking change to the frame shapes.
pub const RPC_SCHEMA: &str = "sphkm.rpc.v1";

/// Hard cap on one frame's bytes (16 MiB), enforced on both the reader
/// ([`FrameReader`]) and the JSON parser ([`Json::parse_bounded`]). A
/// peer streaming an endless line cannot make the daemon buffer more
/// than this.
pub const MAX_FRAME_BYTES: usize = 16 * 1024 * 1024;

/// A client request, decoded from one frame.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Top-p nearest-center queries for a batch of sparse rows. Rows are
    /// `(indices, values)` pairs in the model's vector space and should
    /// be unit-normalized for the similarities to be cosines; the daemon
    /// validates shape (sorted unique indices below the model dimension,
    /// finite values) but never renormalizes.
    Query {
        /// How many centers to return per row (`top` ≥ 1 is useful;
        /// `0` yields empty result lists).
        top: usize,
        /// The query rows as `(indices, values)` pairs.
        rows: Vec<(Vec<u32>, Vec<f32>)>,
    },
    /// Fetch the daemon's metrics registry, slot epoch/swap counters,
    /// and per-epoch query totals.
    Stats,
    /// Hot-swap the served model: load a `.spkm` file and publish it as
    /// the next epoch. `None` reloads the daemon's watched model path.
    Reload {
        /// Path of the model file to load, if not the watched default.
        path: Option<String>,
    },
    /// Run one warm-started mini-batch refit round on the daemon's refit
    /// corpus and publish the result as the next epoch.
    Refit,
    /// Liveness probe; answers [`Reply::Pong`] with the current epoch.
    Ping,
    /// Stop the daemon: it acknowledges with [`Reply::Shutdown`], stops
    /// accepting connections, and drains its threads.
    Shutdown,
}

/// A server reply, decoded from one frame.
#[derive(Debug, Clone, PartialEq)]
pub enum Reply {
    /// Answer to [`Request::Query`].
    Query {
        /// Epoch of the engine that served the batch (pinned for the
        /// whole request — one batch is never split across a swap).
        epoch: u64,
        /// Per-row `(center, similarity)` lists in rank order.
        results: Vec<Vec<(u32, f64)>>,
    },
    /// Answer to [`Request::Stats`].
    Stats {
        /// Current slot epoch.
        epoch: u64,
        /// Hot swaps performed since startup.
        swaps: u64,
        /// `(epoch, queries answered)` per epoch, oldest first.
        epoch_queries: Vec<(u64, u64)>,
        /// The metrics registry rendered by
        /// [`Metrics::to_json`](crate::obs::Metrics::to_json).
        metrics: Json,
    },
    /// Answer to [`Request::Reload`]: the epoch the reloaded model was
    /// published under.
    Reload {
        /// Epoch of the newly published model.
        epoch: u64,
    },
    /// Answer to [`Request::Refit`]: the epoch the refit model was
    /// published under.
    Refit {
        /// Epoch of the newly published model.
        epoch: u64,
    },
    /// Answer to [`Request::Ping`].
    Pong {
        /// Current slot epoch.
        epoch: u64,
    },
    /// Acknowledgement of [`Request::Shutdown`].
    Shutdown,
    /// The request could not be served; the connection remains usable.
    Error {
        /// One-line description of what was wrong.
        message: String,
    },
}

/// `j` as a non-negative integer (JSON numbers are `f64`; counts must
/// be whole and within `f64`'s exact-integer range).
fn as_count(j: &Json, what: &str) -> Result<u64, String> {
    let v = j.as_f64().ok_or_else(|| format!("{what} must be a number"))?;
    if !(0.0..=9.007_199_254_740_992e15).contains(&v) || v.fract() != 0.0 {
        return Err(format!("{what} must be a non-negative integer, got {v}"));
    }
    Ok(v as u64)
}

fn field<'a>(j: &'a Json, key: &str) -> Result<&'a Json, String> {
    j.get(key).ok_or_else(|| format!("missing field {key:?}"))
}

fn num_arr(j: &Json, what: &str) -> Result<&[Json], String> {
    j.as_arr().ok_or_else(|| format!("{what} must be an array"))
}

/// Check the `"rpc"` stamp on a decoded frame.
fn check_schema(j: &Json) -> Result<(), String> {
    match j.get("rpc").and_then(Json::as_str) {
        Some(RPC_SCHEMA) => Ok(()),
        Some(other) => Err(format!("unsupported rpc schema {other:?} (this build speaks {RPC_SCHEMA})")),
        None => Err(format!("missing rpc schema stamp (expected {RPC_SCHEMA:?})")),
    }
}

impl Request {
    /// Encode as one frame's JSON document.
    pub fn to_json(&self) -> Json {
        let mut members = vec![("rpc".to_string(), Json::Str(RPC_SCHEMA.to_string()))];
        match self {
            Request::Query { top, rows } => {
                members.push(("op".to_string(), Json::Str("query".to_string())));
                members.push(("top".to_string(), Json::Num(*top as f64)));
                let rows = rows
                    .iter()
                    .map(|(idx, val)| {
                        Json::Obj(vec![
                            (
                                "i".to_string(),
                                Json::Arr(idx.iter().map(|&i| Json::Num(f64::from(i))).collect()),
                            ),
                            (
                                "v".to_string(),
                                Json::Arr(val.iter().map(|&v| Json::Num(f64::from(v))).collect()),
                            ),
                        ])
                    })
                    .collect();
                members.push(("rows".to_string(), Json::Arr(rows)));
            }
            Request::Stats => members.push(("op".to_string(), Json::Str("stats".to_string()))),
            Request::Reload { path } => {
                members.push(("op".to_string(), Json::Str("reload".to_string())));
                if let Some(p) = path {
                    members.push(("path".to_string(), Json::Str(p.clone())));
                }
            }
            Request::Refit => members.push(("op".to_string(), Json::Str("refit".to_string()))),
            Request::Ping => members.push(("op".to_string(), Json::Str("ping".to_string()))),
            Request::Shutdown => {
                members.push(("op".to_string(), Json::Str("shutdown".to_string())));
            }
        }
        Json::Obj(members)
    }

    /// Decode a frame's JSON document. Errors describe the first problem
    /// found and are safe to echo back to the peer in an error frame.
    pub fn from_json(j: &Json) -> Result<Request, String> {
        check_schema(j)?;
        let op = j
            .get("op")
            .and_then(Json::as_str)
            .ok_or_else(|| "missing string field \"op\"".to_string())?;
        match op {
            "query" => {
                let top = match j.get("top") {
                    Some(t) => as_count(t, "top")? as usize,
                    None => 1,
                };
                let mut rows = Vec::new();
                for (r, row) in num_arr(field(j, "rows")?, "rows")?.iter().enumerate() {
                    let idx = num_arr(field(row, "i").map_err(|e| format!("row {r}: {e}"))?, "i")?;
                    let val = num_arr(field(row, "v").map_err(|e| format!("row {r}: {e}"))?, "v")?;
                    let mut indices = Vec::with_capacity(idx.len());
                    for i in idx {
                        let i = as_count(i, "row index")?;
                        if i > u64::from(u32::MAX) {
                            return Err(format!("row {r}: index {i} exceeds u32"));
                        }
                        indices.push(i as u32);
                    }
                    let mut values = Vec::with_capacity(val.len());
                    for v in val {
                        // Finiteness and f32-range are validated against
                        // the model by the daemon (SparseVec::try_new);
                        // here only the JSON shape matters.
                        values.push(
                            v.as_f64().ok_or_else(|| format!("row {r}: values must be numbers"))?
                                as f32,
                        );
                    }
                    rows.push((indices, values));
                }
                Ok(Request::Query { top, rows })
            }
            "stats" => Ok(Request::Stats),
            "reload" => Ok(Request::Reload {
                path: j.get("path").and_then(Json::as_str).map(str::to_string),
            }),
            "refit" => Ok(Request::Refit),
            "ping" => Ok(Request::Ping),
            "shutdown" => Ok(Request::Shutdown),
            other => Err(format!("unknown op {other:?}")),
        }
    }
}

impl Reply {
    /// Encode as one frame's JSON document.
    pub fn to_json(&self) -> Json {
        let mut members = vec![("rpc".to_string(), Json::Str(RPC_SCHEMA.to_string()))];
        let ok = !matches!(self, Reply::Error { .. });
        members.push(("ok".to_string(), Json::Bool(ok)));
        match self {
            Reply::Query { epoch, results } => {
                members.push(("op".to_string(), Json::Str("query".to_string())));
                members.push(("epoch".to_string(), Json::Num(*epoch as f64)));
                let rows = results
                    .iter()
                    .map(|row| {
                        Json::Arr(
                            row.iter()
                                .map(|&(c, s)| {
                                    Json::Arr(vec![Json::Num(f64::from(c)), Json::Num(s)])
                                })
                                .collect(),
                        )
                    })
                    .collect();
                members.push(("results".to_string(), Json::Arr(rows)));
            }
            Reply::Stats { epoch, swaps, epoch_queries, metrics } => {
                members.push(("op".to_string(), Json::Str("stats".to_string())));
                members.push(("epoch".to_string(), Json::Num(*epoch as f64)));
                members.push(("swaps".to_string(), Json::Num(*swaps as f64)));
                members.push((
                    "epoch_queries".to_string(),
                    Json::Arr(
                        epoch_queries
                            .iter()
                            .map(|&(e, n)| {
                                Json::Arr(vec![Json::Num(e as f64), Json::Num(n as f64)])
                            })
                            .collect(),
                    ),
                ));
                members.push(("metrics".to_string(), metrics.clone()));
            }
            Reply::Reload { epoch } => {
                members.push(("op".to_string(), Json::Str("reload".to_string())));
                members.push(("epoch".to_string(), Json::Num(*epoch as f64)));
            }
            Reply::Refit { epoch } => {
                members.push(("op".to_string(), Json::Str("refit".to_string())));
                members.push(("epoch".to_string(), Json::Num(*epoch as f64)));
            }
            Reply::Pong { epoch } => {
                members.push(("op".to_string(), Json::Str("ping".to_string())));
                members.push(("epoch".to_string(), Json::Num(*epoch as f64)));
            }
            Reply::Shutdown => {
                members.push(("op".to_string(), Json::Str("shutdown".to_string())));
            }
            Reply::Error { message } => {
                members.push(("error".to_string(), Json::Str(message.clone())));
            }
        }
        Json::Obj(members)
    }

    /// Decode a frame's JSON document.
    pub fn from_json(j: &Json) -> Result<Reply, String> {
        check_schema(j)?;
        let ok = j
            .get("ok")
            .and_then(Json::as_bool)
            .ok_or_else(|| "missing boolean field \"ok\"".to_string())?;
        if !ok {
            let message = j
                .get("error")
                .and_then(Json::as_str)
                .unwrap_or("unspecified error")
                .to_string();
            return Ok(Reply::Error { message });
        }
        let op = j
            .get("op")
            .and_then(Json::as_str)
            .ok_or_else(|| "missing string field \"op\"".to_string())?;
        let epoch = |j: &Json| as_count(field(j, "epoch")?, "epoch");
        match op {
            "query" => {
                let mut results = Vec::new();
                for (r, row) in num_arr(field(j, "results")?, "results")?.iter().enumerate() {
                    let mut out = Vec::new();
                    for pair in num_arr(row, "result row")? {
                        let pair = num_arr(pair, "result pair")?;
                        if pair.len() != 2 {
                            return Err(format!("row {r}: result pairs are [center, similarity]"));
                        }
                        let c = as_count(&pair[0], "center")?;
                        if c > u64::from(u32::MAX) {
                            return Err(format!("row {r}: center {c} exceeds u32"));
                        }
                        let s = pair[1]
                            .as_f64()
                            .ok_or_else(|| format!("row {r}: similarity must be a number"))?;
                        out.push((c as u32, s));
                    }
                    results.push(out);
                }
                Ok(Reply::Query { epoch: epoch(j)?, results })
            }
            "stats" => {
                let mut epoch_queries = Vec::new();
                for pair in num_arr(field(j, "epoch_queries")?, "epoch_queries")? {
                    let pair = num_arr(pair, "epoch_queries entry")?;
                    if pair.len() != 2 {
                        return Err("epoch_queries entries are [epoch, queries]".to_string());
                    }
                    epoch_queries
                        .push((as_count(&pair[0], "epoch")?, as_count(&pair[1], "queries")?));
                }
                Ok(Reply::Stats {
                    epoch: epoch(j)?,
                    swaps: as_count(field(j, "swaps")?, "swaps")?,
                    epoch_queries,
                    metrics: field(j, "metrics")?.clone(),
                })
            }
            "reload" => Ok(Reply::Reload { epoch: epoch(j)? }),
            "refit" => Ok(Reply::Refit { epoch: epoch(j)? }),
            "ping" => Ok(Reply::Pong { epoch: epoch(j)? }),
            "shutdown" => Ok(Reply::Shutdown),
            other => Err(format!("unknown op {other:?}")),
        }
    }
}

/// Write one frame: the document rendered compactly (JSON string
/// escaping guarantees a single line) plus the `\n` delimiter, flushed
/// so the peer sees it immediately.
pub fn write_frame<W: Write>(w: &mut W, doc: &Json) -> io::Result<()> {
    let mut line = doc.render();
    line.push('\n');
    w.write_all(line.as_bytes())?;
    w.flush()
}

/// Incremental, size-capped newline framer over any byte stream.
///
/// Unlike `BufRead::read_line`, a read error ([`io::ErrorKind::WouldBlock`],
/// [`io::ErrorKind::TimedOut`]) does **not** lose buffered bytes: the
/// partial frame stays in the accumulator and the next
/// [`FrameReader::read_frame`] call resumes where the stream left off —
/// which is what lets daemon connection threads poll a shutdown flag on
/// a read timeout without corrupting the framing.
#[derive(Debug)]
pub struct FrameReader<R: Read> {
    inner: R,
    buf: Vec<u8>,
    /// Bytes of `buf` already scanned for a delimiter (avoids re-scanning
    /// the prefix on every refill).
    scanned: usize,
    limit: usize,
}

impl<R: Read> FrameReader<R> {
    /// A framer enforcing the protocol's [`MAX_FRAME_BYTES`] cap.
    pub fn new(inner: R) -> Self {
        Self::with_limit(inner, MAX_FRAME_BYTES)
    }

    /// A framer with a custom frame-size cap (tests; tighter policies).
    pub fn with_limit(inner: R, limit: usize) -> Self {
        Self { inner, buf: Vec::new(), scanned: 0, limit }
    }

    /// Next frame as a string with the `\n` (and any `\r`) stripped.
    ///
    /// Returns `Ok(None)` at a clean end of stream. An unterminated
    /// final frame before EOF is returned as a frame. Errors:
    /// [`io::ErrorKind::InvalidData`] for an over-limit or non-UTF-8
    /// frame (the stream cannot be resynchronized afterwards — close
    /// it), and any transport error as-is, with buffered bytes kept for
    /// the next call.
    pub fn read_frame(&mut self) -> io::Result<Option<String>> {
        loop {
            if let Some(pos) = self.buf[self.scanned..].iter().position(|&b| b == b'\n') {
                let end = self.scanned + pos;
                let mut frame: Vec<u8> = self.buf.drain(..=end).collect();
                self.scanned = 0;
                frame.pop();
                if frame.last() == Some(&b'\r') {
                    frame.pop();
                }
                return frame_to_string(frame).map(Some);
            }
            self.scanned = self.buf.len();
            if self.buf.len() > self.limit {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("frame exceeds the {}-byte limit", self.limit),
                ));
            }
            let mut chunk = [0u8; 4096];
            match self.inner.read(&mut chunk) {
                Ok(0) => {
                    if self.buf.is_empty() {
                        return Ok(None);
                    }
                    let frame = std::mem::take(&mut self.buf);
                    self.scanned = 0;
                    return frame_to_string(frame).map(Some);
                }
                Ok(n) => self.buf.extend_from_slice(&chunk[..n]),
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
        }
    }
}

fn frame_to_string(frame: Vec<u8>) -> io::Result<String> {
    String::from_utf8(frame)
        .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, "frame is not UTF-8"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip_request(req: &Request) {
        let doc = req.to_json();
        let parsed = Json::parse(&doc.render()).expect("frame parses");
        assert_eq!(&Request::from_json(&parsed).expect("decodes"), req);
    }

    fn round_trip_reply(rep: &Reply) {
        let doc = rep.to_json();
        let parsed = Json::parse(&doc.render()).expect("frame parses");
        assert_eq!(&Reply::from_json(&parsed).expect("decodes"), rep);
    }

    #[test]
    fn requests_round_trip() {
        round_trip_request(&Request::Query {
            top: 3,
            rows: vec![
                (vec![0, 7, 4_000_000_000], vec![0.25, -0.5, 0.125]),
                (vec![], vec![]),
            ],
        });
        round_trip_request(&Request::Stats);
        round_trip_request(&Request::Reload { path: Some("m.spkm".to_string()) });
        round_trip_request(&Request::Reload { path: None });
        round_trip_request(&Request::Refit);
        round_trip_request(&Request::Ping);
        round_trip_request(&Request::Shutdown);
    }

    #[test]
    fn replies_round_trip_bit_exactly() {
        // Similarities with no short decimal representation must survive
        // the wire bit-for-bit (shortest round-trip f64 rendering).
        let s = 1.0 / 3.0;
        round_trip_reply(&Reply::Query {
            epoch: 4,
            results: vec![vec![(2, s), (0, s * s)], vec![]],
        });
        round_trip_reply(&Reply::Stats {
            epoch: 2,
            swaps: 2,
            epoch_queries: vec![(0, 10), (1, 0), (2, 7)],
            metrics: Json::Obj(vec![("counters".to_string(), Json::Obj(vec![]))]),
        });
        round_trip_reply(&Reply::Reload { epoch: 9 });
        round_trip_reply(&Reply::Refit { epoch: 10 });
        round_trip_reply(&Reply::Pong { epoch: 0 });
        round_trip_reply(&Reply::Shutdown);
        round_trip_reply(&Reply::Error { message: "no such model".to_string() });
    }

    #[test]
    fn query_values_round_trip_exact_f32() {
        let vals = vec![0.1f32, 1.0 / 3.0, f32::MIN_POSITIVE, 3.402_823_5e38];
        let req = Request::Query { top: 1, rows: vec![(vec![0, 1, 2, 3], vals.clone())] };
        let parsed = Request::from_json(&Json::parse(&req.to_json().render()).unwrap()).unwrap();
        let Request::Query { rows, .. } = parsed else { panic!("query") };
        for (a, b) in rows[0].1.iter().zip(&vals) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn rejects_malformed_frames() {
        for (doc, why) in [
            (r#"{"op":"ping"}"#, "missing schema stamp"),
            (r#"{"rpc":"sphkm.rpc.v2","op":"ping"}"#, "wrong schema"),
            (r#"{"rpc":"sphkm.rpc.v1"}"#, "missing op"),
            (r#"{"rpc":"sphkm.rpc.v1","op":"frobnicate"}"#, "unknown op"),
            (r#"{"rpc":"sphkm.rpc.v1","op":"query"}"#, "query without rows"),
            (
                r#"{"rpc":"sphkm.rpc.v1","op":"query","rows":[{"i":[-1],"v":[1.0]}]}"#,
                "negative index",
            ),
            (
                r#"{"rpc":"sphkm.rpc.v1","op":"query","rows":[{"i":[1.5],"v":[1.0]}]}"#,
                "fractional index",
            ),
            (
                r#"{"rpc":"sphkm.rpc.v1","op":"query","rows":[{"i":[5000000000],"v":[1.0]}]}"#,
                "index beyond u32",
            ),
            (
                r#"{"rpc":"sphkm.rpc.v1","op":"query","top":-3,"rows":[]}"#,
                "negative top",
            ),
        ] {
            let parsed = Json::parse(doc).expect("valid json");
            assert!(Request::from_json(&parsed).is_err(), "{why}: {doc}");
        }
        // Reply-side: ok:false always decodes to Error.
        let err = Json::parse(r#"{"rpc":"sphkm.rpc.v1","ok":false,"error":"nope"}"#).unwrap();
        assert_eq!(
            Reply::from_json(&err).unwrap(),
            Reply::Error { message: "nope".to_string() }
        );
        let missing_ok = Json::parse(r#"{"rpc":"sphkm.rpc.v1","op":"ping"}"#).unwrap();
        assert!(Reply::from_json(&missing_ok).is_err());
    }

    #[test]
    fn frame_reader_splits_and_caps() {
        let wire = b"{\"a\":1}\r\n\n{\"b\":2}".to_vec();
        let mut r = FrameReader::new(io::Cursor::new(wire));
        assert_eq!(r.read_frame().unwrap().as_deref(), Some("{\"a\":1}"));
        assert_eq!(r.read_frame().unwrap().as_deref(), Some(""));
        // Unterminated final frame is still delivered.
        assert_eq!(r.read_frame().unwrap().as_deref(), Some("{\"b\":2}"));
        assert_eq!(r.read_frame().unwrap(), None);

        let mut capped = FrameReader::with_limit(io::Cursor::new(vec![b'x'; 64]), 8);
        let err = capped.read_frame().unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);

        let mut bad_utf8 = FrameReader::new(io::Cursor::new(vec![0xff, 0xfe, b'\n']));
        assert_eq!(bad_utf8.read_frame().unwrap_err().kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn write_frame_is_one_line() {
        let doc = Reply::Error { message: "line\nbreak".to_string() }.to_json();
        let mut out = Vec::new();
        write_frame(&mut out, &doc).unwrap();
        assert_eq!(out.iter().filter(|&&b| b == b'\n').count(), 1);
        assert_eq!(out.last(), Some(&b'\n'));
        let text = std::str::from_utf8(&out).unwrap();
        let parsed = Json::parse(text.trim_end()).unwrap();
        assert_eq!(
            Reply::from_json(&parsed).unwrap(),
            Reply::Error { message: "line\nbreak".to_string() }
        );
    }
}
