//! The [`QueryEngine`]: top-p nearest-center queries against a frozen
//! model, exhaustive or MaxScore-pruned — see the [module docs](super)
//! for the traversal design and the bit-identity contract.

use crate::audit::{AuditViolation, AUDIT_ENABLED};
use crate::kmeans::{DataShape, Kernel, KernelChoice};
use crate::model::Model;
use crate::obs::metrics::LatencyHistogram;
use crate::runtime::parallel::{Plan, Pool};
use crate::sparse::csr::RowView;
use crate::sparse::{CsrMatrix, InvertedIndex};

/// Float-safety margin added to every MaxScore bound. The bound pass
/// accumulates partial similarities in descending-contribution order
/// while the exact gather dot sums in its own order; both agree with the
/// real-arithmetic value to far better than this margin (worst case
/// `≈ nnz · ε · Σ|terms| ≲ 1e-12` for realistic rows), so inflating the
/// pruning window by it keeps the candidate set a provable superset of
/// the true top-p — bounds can only ever *widen*, never drop a winner.
pub const BOUND_MARGIN: f64 = 1e-9;

/// Which traversal the engine runs for dispatching queries.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ServeMode {
    /// Resolve per model through the kernel layer's Auto heuristic
    /// ([`KernelChoice::resolve`] on [`DataShape::of_centers`]): the
    /// pruned inverted-file traversal when the trained centers are
    /// sparse, exhaustive gather otherwise.
    #[default]
    Auto,
    /// Always the MaxScore-pruned inverted-file traversal.
    Pruned,
    /// Always the exhaustive gather pass.
    Exhaustive,
}

impl ServeMode {
    /// Display name (CLI/report spelling).
    pub fn name(&self) -> &'static str {
        match self {
            ServeMode::Auto => "auto",
            ServeMode::Pruned => "pruned",
            ServeMode::Exhaustive => "exhaustive",
        }
    }
}

impl std::fmt::Display for ServeMode {
    /// The CLI/report spelling of [`ServeMode::name`]; round-trips
    /// through [`FromStr`](std::str::FromStr).
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for ServeMode {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(ServeMode::Auto),
            "pruned" | "maxscore" | "inverted" => Ok(ServeMode::Pruned),
            "exhaustive" | "gather" | "full" => Ok(ServeMode::Exhaustive),
            other => Err(format!("unknown serve mode: {other}")),
        }
    }
}

/// Engine construction options.
#[derive(Debug, Clone, Default)]
pub struct ServeConfig {
    /// Traversal selection — see [`ServeMode`].
    pub mode: ServeMode,
    /// Worker threads for batch queries (`0` = all cores, `1` = serial;
    /// the [`crate::runtime::parallel`] convention). Results are
    /// bit-identical for every setting: each query is a pure function of
    /// the frozen model, and shard outputs are concatenated in row order.
    pub threads: usize,
}

/// Work counters for a stream of queries. All integer sums, so merging
/// shard-local stats is exact in any order.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServeStats {
    /// Queries answered.
    pub queries: u64,
    /// Total multiply-adds (postings walked + gather re-scoring) — the
    /// same cost model as [`crate::kmeans::stats::IterStats`]'s
    /// `madds_point_center`, so serve and train costs are comparable.
    pub madds: u64,
    /// Centers that received an exact gather score.
    pub candidates_scored: u64,
    /// Centers the bound pass skipped without touching.
    pub centers_pruned: u64,
}

impl ServeStats {
    /// Fold shard-local counters into this accumulator.
    pub fn absorb(&mut self, other: &ServeStats) {
        self.queries += other.queries;
        self.madds += other.madds;
        self.candidates_scored += other.candidates_scored;
        self.centers_pruned += other.centers_pruned;
    }
}

/// Per-worker reusable buffers so the batch hot loop allocates nothing
/// per query.
struct Scratch {
    /// Exact-so-far partial similarity per center (bound pass).
    partial: Vec<f64>,
    /// Selection copy of `partial` for the p-th-largest computation.
    sel: Vec<f64>,
    /// The query's terms as `(dim, value, contribution bound)`.
    dims: Vec<(u32, f32, f64)>,
    /// Suffix sums of the contribution bounds.
    suffix: Vec<f64>,
    /// Candidate center ids surviving the bound pass.
    cands: Vec<u32>,
}

impl Scratch {
    fn new(k: usize) -> Self {
        Self {
            partial: vec![0.0; k],
            sel: vec![0.0; k],
            dims: Vec::new(),
            suffix: Vec::new(),
            cands: Vec::new(),
        }
    }
}

/// Total order on `(center, similarity)` results: descending similarity,
/// ties broken by ascending center id — the same winner rule the training
/// argmax uses (`top2` keeps the lowest index among equal maxima), so a
/// converged model's p = 1 answers reproduce its training assignments.
#[inline]
fn by_rank(a: &(u32, f64), b: &(u32, f64)) -> std::cmp::Ordering {
    b.1.partial_cmp(&a.1).expect("similarities are finite").then(a.0.cmp(&b.0))
}

/// A loaded model plus the derived structures its traversals read: the
/// inverted-file postings index and the per-dimension MaxScore bound
/// table. Immutable after construction — queries take `&self`, so one
/// engine serves any number of worker threads.
#[derive(Debug)]
pub struct QueryEngine {
    model: Model,
    /// Postings index over the center non-zeros; its cached per-dimension
    /// MaxScore bound table (`maxw[c] = max_j |centers[j][c]|`,
    /// [`InvertedIndex::max_abs_weights`]) is maintained by the index
    /// itself. Built only when the resolved mode can prune — an
    /// exhaustive engine never reads it, and for a dense model the
    /// postings would cost roughly twice the dense matrix they mirror.
    index: Option<InvertedIndex>,
    /// What [`ServeMode`] resolved to: `true` = pruned traversal.
    pruned: bool,
    pool: Pool,
}

impl QueryEngine {
    /// Build an engine over `model`, resolving [`ServeMode::Auto`]
    /// through the similarity-kernel heuristic of
    /// [`crate::kmeans::kernel`]. When the resolved traversal prunes,
    /// the inverted-file index and bound table are constructed once
    /// (`O(center nnz)`); an exhaustive engine builds nothing.
    pub fn new(model: Model, cfg: &ServeConfig) -> Self {
        let pruned = match cfg.mode {
            ServeMode::Pruned => true,
            ServeMode::Exhaustive => false,
            ServeMode::Auto => {
                let shape = DataShape::of_centers(model.d(), model.k(), model.center_nnz());
                matches!(
                    KernelChoice::Auto.resolve(&shape),
                    Kernel::Inverted | Kernel::Pruned
                )
            }
        };
        let index = pruned.then(|| InvertedIndex::from_centers(model.centers()));
        Self { model, index, pruned, pool: Pool::new(cfg.threads) }
    }

    /// The model being served.
    #[inline]
    pub fn model(&self) -> &Model {
        &self.model
    }

    /// Resolved traversal name (`"pruned"` or `"exhaustive"`).
    pub fn mode(&self) -> &'static str {
        if self.pruned { "pruned" } else { "exhaustive" }
    }

    /// Density of the serving-side postings index (the model's center
    /// density when the engine resolved exhaustive and built none).
    pub fn index_density(&self) -> f64 {
        match &self.index {
            Some(idx) => idx.density(),
            None => self.model.center_density(),
        }
    }

    /// Top-p centers for one sparse query row (must be unit-normalized
    /// for the similarities to be cosines, and its indices must lie
    /// below [`Model::d`]), via the resolved traversal. Returns
    /// `(center, similarity)` pairs in rank order (see the tie rule on
    /// the [module docs](super)).
    pub fn top_p(&self, row: RowView<'_>, p: usize) -> (Vec<(u32, f64)>, ServeStats) {
        let mut stats = ServeStats::default();
        let mut scratch = Scratch::new(self.model.k());
        let out = if self.pruned {
            self.top_p_pruned_into(row, p, &mut scratch, &mut stats)
        } else {
            self.top_p_exhaustive_into(row, p, &mut stats)
        };
        (out, stats)
    }

    /// Exhaustive gather traversal: `k` sparse×dense dots, then a top-p
    /// selection under the deterministic rank order.
    pub fn top_p_exhaustive(&self, row: RowView<'_>, p: usize) -> (Vec<(u32, f64)>, ServeStats) {
        let mut stats = ServeStats::default();
        let out = self.top_p_exhaustive_into(row, p, &mut stats);
        (out, stats)
    }

    /// MaxScore-pruned traversal — bit-identical to
    /// [`QueryEngine::top_p_exhaustive`] (see the [module docs](super)).
    pub fn top_p_pruned(&self, row: RowView<'_>, p: usize) -> (Vec<(u32, f64)>, ServeStats) {
        let mut stats = ServeStats::default();
        let mut scratch = Scratch::new(self.model.k());
        let out = self.top_p_pruned_into(row, p, &mut scratch, &mut stats);
        (out, stats)
    }

    fn top_p_exhaustive_into(
        &self,
        row: RowView<'_>,
        p: usize,
        stats: &mut ServeStats,
    ) -> Vec<(u32, f64)> {
        let k = self.model.k();
        stats.queries += 1;
        if p == 0 || k == 0 {
            return Vec::new();
        }
        let mut scored: Vec<(u32, f64)> = (0..k)
            .map(|j| (j as u32, row.dot_dense(self.model.centers().row(j))))
            .collect();
        stats.madds += (row.nnz() * k) as u64;
        stats.candidates_scored += k as u64;
        let p = p.min(k);
        if p < k {
            scored.select_nth_unstable_by(p - 1, by_rank);
            scored.truncate(p);
        }
        scored.sort_unstable_by(by_rank);
        scored
    }

    fn top_p_pruned_into(
        &self,
        row: RowView<'_>,
        p: usize,
        scratch: &mut Scratch,
        stats: &mut ServeStats,
    ) -> Vec<(u32, f64)> {
        let k = self.model.k();
        // An engine resolved to exhaustive built no postings index; the
        // pruned entry points degrade to the exhaustive pass, which is
        // bit-identical anyway.
        let Some(index) = self.index.as_ref() else {
            return self.top_p_exhaustive_into(row, p, stats);
        };
        let maxw = index.max_abs_weights();
        stats.queries += 1;
        if p == 0 || k == 0 {
            return Vec::new();
        }
        if p >= k {
            // Nothing to prune: every center must be scored exactly.
            stats.queries -= 1;
            return self.top_p_exhaustive_into(row, p, stats);
        }
        // The query's terms ordered by descending contribution bound
        // |q_c|·maxw[c]; terms no center carries bound (and contribute)
        // exactly zero and are dropped up front.
        scratch.dims.clear();
        for (&c, &v) in row.indices.iter().zip(row.values.iter()) {
            let b = (v.abs() as f64) * (maxw[c as usize] as f64);
            if b > 0.0 {
                scratch.dims.push((c, v, b));
            }
        }
        scratch.dims.sort_unstable_by(|a, b| {
            b.2.partial_cmp(&a.2).expect("finite bounds").then(a.0.cmp(&b.0))
        });
        // suffix[t] = Σ_{i ≥ t} bound_i: the most the unprocessed terms
        // can still add to (or subtract from) any center's similarity.
        let n = scratch.dims.len();
        scratch.suffix.clear();
        scratch.suffix.resize(n + 1, 0.0);
        for t in (0..n).rev() {
            scratch.suffix[t] = scratch.suffix[t + 1] + scratch.dims[t].2;
        }
        scratch.partial[..k].fill(0.0);
        // Bound pass: accumulate exact partial similarities term by term,
        // stopping as soon as the suffix bound can no longer move any
        // center into the top p. The stop test costs O(k) (a quickselect
        // over the partials), so it runs at geometrically spaced terms —
        // O(k log nnz) total — rather than after every one.
        let mut t = 0;
        let mut next_check = 1;
        while t < n {
            if t == next_check {
                if self.candidate_count(p, scratch.suffix[t], scratch) <= p {
                    break;
                }
                next_check *= 2;
            }
            let (c, v, _) = scratch.dims[t];
            stats.madds += index.accumulate_dim(c as usize, v as f64, &mut scratch.partial);
            t += 1;
        }
        let slack = 2.0 * scratch.suffix[t] + 2.0 * BOUND_MARGIN;
        let cut = self.pth_largest(p, scratch) - slack;
        scratch.cands.clear();
        for (j, &s) in scratch.partial[..k].iter().enumerate() {
            if s >= cut {
                scratch.cands.push(j as u32);
            }
        }
        // Exact re-scoring of the survivors with the same gather dot the
        // exhaustive path uses — this is what makes the two traversals
        // bit-identical.
        stats.centers_pruned += (k - scratch.cands.len()) as u64;
        stats.candidates_scored += scratch.cands.len() as u64;
        stats.madds += (row.nnz() * scratch.cands.len()) as u64;
        let mut scored: Vec<(u32, f64)> = scratch
            .cands
            .iter()
            .map(|&j| (j, row.dot_dense(self.model.centers().row(j as usize))))
            .collect();
        if p < scored.len() {
            scored.select_nth_unstable_by(p - 1, by_rank);
            scored.truncate(p);
        }
        scored.sort_unstable_by(by_rank);
        if AUDIT_ENABLED {
            // Bound certification ([`crate::audit`]): re-answer the query
            // exhaustively (into throwaway counters, so the reported stats
            // stay identical to an unaudited run) and demand the pruned
            // answer bit-for-bit. Serving has no error channel to thread a
            // violation through, so a divergence is a hard stop.
            let mut audit_stats = ServeStats::default();
            let exact = self.top_p_exhaustive_into(row, p, &mut audit_stats);
            let diverges = exact.len() != scored.len()
                || exact
                    .iter()
                    .zip(&scored)
                    .any(|(a, b)| a.0 != b.0 || a.1.to_bits() != b.1.to_bits());
            if diverges {
                let rank = exact
                    .iter()
                    .zip(&scored)
                    .position(|(a, b)| a.0 != b.0 || a.1.to_bits() != b.1.to_bits())
                    .unwrap_or_else(|| exact.len().min(scored.len()));
                let v = AuditViolation::invariant(
                    "serve",
                    "pruned-vs-exhaustive",
                    format!(
                        "MaxScore traversal diverges from the exhaustive pass at rank {rank}: \
                         pruned {:?} vs exhaustive {:?} (top-{p} query, k = {k})",
                        scored.get(rank),
                        exact.get(rank)
                    ),
                );
                panic!("{v}");
            }
        }
        scored
    }

    /// p-th largest current partial similarity (the top-p lower-bound
    /// threshold before margins). O(k) via quickselect on a scratch copy.
    fn pth_largest(&self, p: usize, scratch: &mut Scratch) -> f64 {
        let k = self.model.k();
        scratch.sel[..k].copy_from_slice(&scratch.partial[..k]);
        let (_, pth, _) = scratch.sel[..k]
            .select_nth_unstable_by(p - 1, |a, b| b.partial_cmp(a).expect("finite partials"));
        *pth
    }

    /// How many centers could still reach the top p if the walk stopped
    /// now, with `s` of contribution bound left unprocessed: those whose
    /// upper bound `partial + s + margin` meets the p-th best lower bound
    /// `pth_partial − s − margin`.
    fn candidate_count(&self, p: usize, s: f64, scratch: &mut Scratch) -> usize {
        let cut = self.pth_largest(p, scratch) - 2.0 * s - 2.0 * BOUND_MARGIN;
        scratch.partial[..self.model.k()].iter().filter(|&&v| v >= cut).count()
    }

    /// Top-p centers for every row of `data` (rows unit-normalized,
    /// `data.cols() ≤ model.d()`), sharded across the engine's worker
    /// pool on the [`Plan`] row grid. Output order matches row order and
    /// is bit-identical for every thread count.
    pub fn top_p_batch(&self, data: &CsrMatrix, p: usize) -> (Vec<Vec<(u32, f64)>>, ServeStats) {
        self.batch(data, p, self.pruned)
    }

    /// Batch variant of [`QueryEngine::top_p_pruned`].
    pub fn top_p_batch_pruned(
        &self,
        data: &CsrMatrix,
        p: usize,
    ) -> (Vec<Vec<(u32, f64)>>, ServeStats) {
        self.batch(data, p, true)
    }

    /// Batch variant of [`QueryEngine::top_p_exhaustive`].
    pub fn top_p_batch_exhaustive(
        &self,
        data: &CsrMatrix,
        p: usize,
    ) -> (Vec<Vec<(u32, f64)>>, ServeStats) {
        self.batch(data, p, false)
    }

    /// Nearest-center label per row — the p = 1 batch query flattened to
    /// an assignment vector (rows matching no center at all, e.g. empty
    /// rows, get the rank winner center 0 like the training argmax).
    pub fn assign_batch(&self, data: &CsrMatrix) -> (Vec<u32>, ServeStats) {
        let (top, stats) = self.top_p_batch(data, 1);
        let labels = top.iter().map(|r| r.first().map_or(0, |&(j, _)| j)).collect();
        (labels, stats)
    }

    /// [`QueryEngine::top_p_batch`] plus a per-query latency histogram.
    ///
    /// Each worker times every query with one `Instant` pair and records
    /// into a shard-local [`LatencyHistogram`]; the coordinating thread
    /// merges the shards (order is immaterial — merging is associative
    /// and commutative). Timing is available in every build — calling
    /// this entry point is the opt-in, so the untimed batch paths pay
    /// nothing — and the results and [`ServeStats`] are bit-identical to
    /// [`QueryEngine::top_p_batch`] on the same engine.
    pub fn top_p_batch_timed(
        &self,
        data: &CsrMatrix,
        p: usize,
    ) -> (Vec<Vec<(u32, f64)>>, ServeStats, LatencyHistogram) {
        assert!(
            data.cols() <= self.model.d(),
            "query data has {} features but the model serves {}",
            data.cols(),
            self.model.d()
        );
        let plan = Plan::for_rows(data.rows());
        let k = self.model.k();
        let pruned = self.pruned;
        let outs = self.pool.run(plan.ranges().to_vec(), |_, range| {
            let mut scratch = Scratch::new(k);
            let mut stats = ServeStats::default();
            let mut hist = LatencyHistogram::new();
            let results: Vec<Vec<(u32, f64)>> = range
                .map(|i| {
                    let row = data.row(i);
                    let t = std::time::Instant::now();
                    let out = if pruned {
                        self.top_p_pruned_into(row, p, &mut scratch, &mut stats)
                    } else {
                        self.top_p_exhaustive_into(row, p, &mut stats)
                    };
                    hist.record(t.elapsed());
                    out
                })
                .collect();
            (results, stats, hist)
        });
        let mut all = Vec::with_capacity(data.rows());
        let mut stats = ServeStats::default();
        let mut hist = LatencyHistogram::new();
        for (results, s, h) in outs {
            all.extend(results);
            stats.absorb(&s);
            hist.merge(&h);
        }
        (all, stats, hist)
    }

    fn batch(
        &self,
        data: &CsrMatrix,
        p: usize,
        pruned: bool,
    ) -> (Vec<Vec<(u32, f64)>>, ServeStats) {
        assert!(
            data.cols() <= self.model.d(),
            "query data has {} features but the model serves {}",
            data.cols(),
            self.model.d()
        );
        let plan = Plan::for_rows(data.rows());
        let k = self.model.k();
        let outs = self.pool.run(plan.ranges().to_vec(), |_, range| {
            let mut scratch = Scratch::new(k);
            let mut stats = ServeStats::default();
            let results: Vec<Vec<(u32, f64)>> = range
                .map(|i| {
                    let row = data.row(i);
                    if pruned {
                        self.top_p_pruned_into(row, p, &mut scratch, &mut stats)
                    } else {
                        self.top_p_exhaustive_into(row, p, &mut stats)
                    }
                })
                .collect();
            (results, stats)
        });
        let mut all = Vec::with_capacity(data.rows());
        let mut stats = ServeStats::default();
        for (results, s) in outs {
            all.extend(results);
            stats.absorb(&s);
        }
        (all, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Model, TrainingMeta};
    use crate::sparse::{DenseMatrix, SparseVec};

    fn meta() -> TrainingMeta {
        TrainingMeta {
            variant: "Standard".into(),
            kernel: "gather".into(),
            iterations: 1,
            objective: 0.0,
            seed: 0,
        }
    }

    fn toy_engine(mode: ServeMode) -> QueryEngine {
        // 4 sparse centers over 6 dims.
        let centers = DenseMatrix::from_vec(
            4,
            6,
            vec![
                0.6, 0.0, 0.8, 0.0, 0.0, 0.0, //
                0.0, 1.0, 0.0, 0.0, 0.0, 0.0, //
                0.5, 0.0, 0.5, 0.5, 0.5, 0.0, //
                0.0, 0.0, 0.0, 0.0, 0.6, 0.8,
            ],
        );
        QueryEngine::new(Model::new(centers, meta()), &ServeConfig { mode, threads: 1 })
    }

    #[test]
    fn mode_parsing_and_resolution() {
        assert_eq!("auto".parse::<ServeMode>().unwrap(), ServeMode::Auto);
        assert_eq!("MaxScore".parse::<ServeMode>().unwrap(), ServeMode::Pruned);
        assert_eq!("full".parse::<ServeMode>().unwrap(), ServeMode::Exhaustive);
        assert!("nope".parse::<ServeMode>().is_err());
        assert_eq!(ServeMode::default(), ServeMode::Auto);
        for m in [ServeMode::Auto, ServeMode::Pruned, ServeMode::Exhaustive] {
            assert!(!m.name().is_empty());
            // Display ↔ FromStr round trip, exhaustively.
            assert_eq!(m.to_string(), m.name());
            assert_eq!(m.to_string().parse::<ServeMode>().unwrap(), m);
        }
        assert_eq!(toy_engine(ServeMode::Pruned).mode(), "pruned");
        assert_eq!(toy_engine(ServeMode::Exhaustive).mode(), "exhaustive");
    }

    #[test]
    fn pruned_matches_exhaustive_on_toy_queries() {
        let engine = toy_engine(ServeMode::Pruned);
        let q = SparseVec::from_pairs(6, vec![(0, 0.6), (2, 0.64), (4, 0.48)]);
        let row = RowView { indices: q.indices(), values: q.values() };
        for p in [1usize, 2, 3, 4, 9] {
            let (ex, _) = engine.top_p_exhaustive(row, p);
            let (pr, _) = engine.top_p_pruned(row, p);
            assert_eq!(ex.len(), p.min(4));
            assert_eq!(pr.len(), ex.len(), "p={p}");
            for (a, b) in ex.iter().zip(&pr) {
                assert_eq!(a.0, b.0, "p={p}");
                assert_eq!(a.1.to_bits(), b.1.to_bits(), "p={p}");
            }
        }
        // Ranks are descending with the id tiebreak.
        let (ex, _) = engine.top_p_exhaustive(row, 4);
        for w in ex.windows(2) {
            assert!(w[0].1 >= w[1].1);
        }
    }

    #[test]
    fn empty_and_degenerate_queries() {
        let engine = toy_engine(ServeMode::Pruned);
        let empty = SparseVec::zeros(6);
        let row = RowView { indices: empty.indices(), values: empty.values() };
        let (pr, _) = engine.top_p_pruned(row, 2);
        let (ex, _) = engine.top_p_exhaustive(row, 2);
        assert_eq!(pr, ex);
        assert_eq!(pr[0], (0, 0.0), "all-zero query: rank by center id");
        let (none, _) = engine.top_p(row, 0);
        assert!(none.is_empty());
        // A query on a term no center carries prunes everything to a
        // zero-score tie.
        let oov = SparseVec::from_pairs(6, vec![(1, 1.0)]);
        let row = RowView { indices: oov.indices(), values: oov.values() };
        let (pr, _) = engine.top_p_pruned(row, 1);
        let (ex, _) = engine.top_p_exhaustive(row, 1);
        assert_eq!(pr, ex);
        assert_eq!(pr[0].0, 1, "center 1 owns the term");
    }

    #[test]
    fn batch_is_thread_count_invariant_and_matches_single() {
        let data = crate::data::synth::SynthConfig::small_demo().generate(5).matrix;
        let mk = |threads: usize| {
            let ds = crate::data::synth::SynthConfig::small_demo().generate(9);
            let fitted = crate::kmeans::SphericalKMeans::new(6)
                .seed(2)
                .max_iter(10)
                .fit(&ds.matrix)
                .unwrap();
            let model = Model::new(fitted.centers().clone(), fitted.meta().clone());
            QueryEngine::new(model, &ServeConfig { mode: ServeMode::Pruned, threads })
        };
        let serial = mk(1);
        let (base, bstats) = serial.top_p_batch(&data, 3);
        assert_eq!(bstats.queries, data.rows() as u64);
        for threads in [2usize, 0] {
            let engine = mk(threads);
            let (out, stats) = engine.top_p_batch(&data, 3);
            assert_eq!(stats, bstats, "threads={threads}");
            for (i, (a, b)) in base.iter().zip(&out).enumerate() {
                assert_eq!(a.len(), b.len(), "row {i}");
                for (x, y) in a.iter().zip(b) {
                    assert_eq!(x.0, y.0, "row {i}");
                    assert_eq!(x.1.to_bits(), y.1.to_bits(), "row {i}");
                }
            }
        }
        // Pruned and exhaustive batches agree bitwise.
        let (ex, _) = serial.top_p_batch_exhaustive(&data, 3);
        let (pr, _) = serial.top_p_batch_pruned(&data, 3);
        assert_eq!(ex, pr);
        // assign_batch is the p = 1 column.
        let (labels, _) = serial.assign_batch(&data);
        for (i, row) in pr.iter().enumerate() {
            assert_eq!(labels[i], row[0].0);
        }
    }

    #[test]
    fn timed_batch_matches_untimed_and_counts_queries() {
        let data = crate::data::synth::SynthConfig::small_demo().generate(5).matrix;
        let ds = crate::data::synth::SynthConfig::small_demo().generate(9);
        let fitted = crate::kmeans::SphericalKMeans::new(6)
            .seed(2)
            .max_iter(10)
            .fit(&ds.matrix)
            .unwrap();
        let model = Model::new(fitted.centers().clone(), fitted.meta().clone());
        let engine =
            QueryEngine::new(model, &ServeConfig { mode: ServeMode::Pruned, threads: 2 });
        let (base, bstats) = engine.top_p_batch(&data, 3);
        let (out, stats, hist) = engine.top_p_batch_timed(&data, 3);
        assert_eq!(stats, bstats);
        assert_eq!(out, base);
        assert_eq!(hist.count(), data.rows() as u64);
        // Quantiles of real samples are ordered and within [min, max].
        let (p50, p95, p99) = (
            hist.quantile_ns(0.50),
            hist.quantile_ns(0.95),
            hist.quantile_ns(0.99),
        );
        assert!(hist.min_ns() <= p50 && p50 <= p95 && p95 <= p99);
        assert!(p99 <= hist.max_ns());
    }

    #[test]
    #[should_panic(expected = "features")]
    fn batch_rejects_wider_data_than_model() {
        let engine = toy_engine(ServeMode::Pruned);
        let wide = CsrMatrix::from_rows(9, &[SparseVec::from_pairs(9, vec![(8, 1.0)])]);
        let _ = engine.top_p_batch(&wide, 1);
    }
}
