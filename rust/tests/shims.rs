//! Deprecated-shim coverage: the legacy `run*` free functions survive one
//! release as thin shims over the `SphericalKMeans` estimator, and they
//! must produce **bit-identical** results to the estimator they delegate
//! to. This is the only place in the repository allowed to call them.
#![allow(deprecated)]

// Bench and test targets favour readable literal casts and exact
// (bit-level) float assertions; the workspace clippy warnings on
// those patterns are aimed at library code.
#![allow(clippy::cast_possible_truncation, clippy::float_cmp)]

use sphkm::data::synth::SynthConfig;
use sphkm::init::{seed_centers, seed_centers_with_bounds, InitMethod};
use sphkm::kmeans::{
    self, minibatch, Engine, ExactParams, KMeansConfig, KMeansResult, MiniBatchParams, Variant,
};
use sphkm::SphericalKMeans;

fn assert_bit_identical(a: &KMeansResult, b: &KMeansResult, what: &str) {
    assert_eq!(a.assignments, b.assignments, "{what}: assignments");
    assert_eq!(a.objective.to_bits(), b.objective.to_bits(), "{what}: objective");
    assert_eq!(a.iterations, b.iterations, "{what}: iterations");
    assert_eq!(a.converged, b.converged, "{what}: converged");
    assert_eq!(a.kernel, b.kernel, "{what}: resolved kernel");
    assert_eq!(
        a.stats.total_point_center(),
        b.stats.total_point_center(),
        "{what}: pruning decisions"
    );
    for j in 0..a.centers.rows() {
        for (x, y) in a.centers.row(j).iter().zip(b.centers.row(j)) {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: center {j}");
        }
    }
}

#[test]
fn run_matches_estimator_fit() {
    let ds = SynthConfig::small_demo().generate(5);
    for variant in [Variant::Standard, Variant::SimplifiedHamerly, Variant::Elkan] {
        let cfg = KMeansConfig::new(7).variant(variant).seed(3);
        let shim = kmeans::run(&ds.matrix, &cfg);
        let est = SphericalKMeans::new(7)
            .variant(variant)
            .seed(3)
            .fit(&ds.matrix)
            .unwrap()
            .into_result();
        assert_bit_identical(&shim, &est, variant.name());
    }
}

#[test]
fn run_with_centers_matches_warm_start_centers() {
    let ds = SynthConfig::small_demo().generate(7);
    let init = seed_centers(&ds.matrix, 6, &InitMethod::Uniform, 9);
    for threads in [1usize, 0] {
        let cfg = KMeansConfig::new(6)
            .variant(Variant::Exponion)
            .threads(threads);
        let shim = kmeans::run_with_centers(&ds.matrix, init.centers.clone(), &cfg);
        let est = SphericalKMeans::new(6)
            .variant(Variant::Exponion)
            .threads(threads)
            .warm_start_centers(init.centers.clone())
            .fit(&ds.matrix)
            .unwrap()
            .into_result();
        assert_bit_identical(&shim, &est, &format!("threads={threads}"));
    }
}

#[test]
fn run_seeded_matches_preinit_engine() {
    let ds = SynthConfig::small_demo().generate(9);
    let k = 8;
    let method = InitMethod::KMeansPP { alpha: 1.0 };
    let outcome = seed_centers_with_bounds(&ds.matrix, k, &method, 17);
    let cfg = KMeansConfig::new(k)
        .variant(Variant::SimplifiedElkan)
        .init(method)
        .seed(17);
    let shim = kmeans::run_seeded(&ds.matrix, outcome, &cfg);
    let est = SphericalKMeans::new(k)
        .engine(Engine::Exact(ExactParams {
            variant: Variant::SimplifiedElkan,
            preinit: true,
            ..Default::default()
        }))
        .init(method)
        .seed(17)
        .fit(&ds.matrix)
        .unwrap()
        .into_result();
    assert_bit_identical(&shim, &est, "preinit");
}

#[test]
fn run_dataset_matches_fit_dataset() {
    let ds = SynthConfig::small_demo().generate(11);
    let cfg = KMeansConfig::new(5).variant(Variant::Yinyang).seed(1);
    let shim = kmeans::run_dataset(&ds, &cfg);
    let est = SphericalKMeans::new(5)
        .variant(Variant::Yinyang)
        .seed(1)
        .fit_dataset(&ds)
        .unwrap()
        .into_result();
    assert_bit_identical(&shim, &est, "run_dataset");
}

#[test]
fn minibatch_shims_match_minibatch_engine() {
    let ds = SynthConfig::small_demo().generate(13);
    let k = 6;
    let cfg = KMeansConfig::new(k)
        .seed(21)
        .batch_size(64)
        .epochs(3)
        .truncate(Some(16));
    let est = || {
        SphericalKMeans::new(k)
            .engine(Engine::MiniBatch(MiniBatchParams {
                batch_size: 64,
                epochs: 3,
                truncate: Some(16),
                ..Default::default()
            }))
            .seed(21)
    };
    let shim = minibatch::run(&ds.matrix, &cfg);
    let fit = est().fit(&ds.matrix).unwrap().into_result();
    assert_bit_identical(&shim, &fit, "minibatch::run");

    let init = seed_centers(&ds.matrix, k, &InitMethod::Uniform, 21);
    let shim = minibatch::run_with_centers(&ds.matrix, init.centers.clone(), &cfg);
    let fit = est()
        .warm_start_centers(init.centers.clone())
        .fit(&ds.matrix)
        .unwrap()
        .into_result();
    assert_bit_identical(&shim, &fit, "minibatch::run_with_centers");
}
