//! Spherical Simplified Hamerly's algorithm (§5.4): Hamerly's single-bound
//! scheme with the `l(i) ≥ s(a(i))` nearest-other-center test removed —
//! avoiding the `O(k²)` center–center similarity computations per iteration,
//! for the same reasons as Simplified Elkan. The paper finds this "a
//! reasonable default choice" across data set shapes (§6). Runs on the
//! same sharded per-point pass as full Hamerly.

use super::{Ctx, KMeansConfig};

pub(crate) fn run(ctx: &mut Ctx<'_, '_>, cfg: &KMeansConfig) -> bool {
    super::hamerly::run_impl(ctx, cfg, false)
}
