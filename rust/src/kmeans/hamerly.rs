//! Spherical Hamerly's algorithm (§5.3): one lower bound `l(i)` to the
//! assigned center and **one** upper bound `u(i)` on the similarity to all
//! other centers, plus the nearest-other-center test `l(i) ≥ s(a(i))`.
//!
//! The single-bound update is the paper's subtle point: Eq. 7 is not
//! monotone in `p(j)`, so the bound is maintained with Eq. 9 (using
//! `p'(a) = min_{j≠a} p(j)`, precomputing `1 − p'²`) on the fast path,
//! falling back to the provably safe interval bound
//! [`crate::bounds::hamerly_bound::update_safe`] outside Eq. 9's validity
//! regime (`u < 0` or `p' < 0`, possible with non-TF-IDF data).
//!
//! Bound maintenance and the assignment scan are fused into one sharded
//! per-point pass: both depend only on the point's own state and the
//! frozen centers (see [`crate::kmeans`]'s parallel-execution docs).

use super::{
    audit_loop_prune, audit_set_prune, bound_states, bound_works, Ctx, IterStats, KMeansConfig,
    Move, ShardOut, SimView,
};
use crate::audit::AUDIT_ENABLED;
use crate::bounds::cc::nearest_center_bounds;
use crate::bounds::hamerly_bound::{update_eq9_pre, update_min_p_guarded, update_safe};
use crate::bounds::update_lower;
use crate::obs::{span::span_start, Phase};
use crate::util::timer::Stopwatch;

/// Shared implementation: `use_s_test = true` for full Hamerly,
/// `false` for Simplified Hamerly (§5.4).
pub(crate) fn run_impl(ctx: &mut Ctx<'_, '_>, cfg: &KMeansConfig, use_s_test: bool) -> bool {
    let n = ctx.src.rows();
    let k = ctx.k;
    let mut l = vec![0.0f64; n];
    let mut u = vec![0.0f64; n];

    let stop = {
        let states = bound_states(&ctx.plan, &mut l, 1, &mut u, 1);
        ctx.initial_assignment(false, states, |(l, u), li, _bj, best, second, _| {
            l[li] = best;
            u[li] = second;
        })
    };
    if stop {
        return false;
    }
    ctx.stats.bound_bytes = 2 * n * std::mem::size_of::<f64>();

    // Per-cluster movement extremes for the single-bound update.
    let mut p_min_ex = vec![0.0f64; k];
    let mut p_max_ex = vec![0.0f64; k];
    let mut one_minus_pmin_sq = vec![0.0f64; k];
    let mut s = Vec::new();

    let engine = if use_s_test { "hamerly" } else { "simplified-hamerly" };
    for _ in 0..cfg.max_iter {
        let sw = Stopwatch::start();
        let mut iter = IterStats::default();
        let iteration = ctx.stats.iters.len();

        let sp = span_start();
        {
            let ex = ctx.centers.p_extremes();
            for a in 0..k {
                let pm = if k > 1 { ex.min_excluding(a) } else { 1.0 };
                let px = if k > 1 { ex.max_excluding(a) } else { 1.0 };
                p_min_ex[a] = pm;
                p_max_ex[a] = px;
                one_minus_pmin_sq[a] = (1.0 - pm * pm).max(0.0);
            }
        }

        // Nearest-other-center half-angle bounds (full variant only).
        if use_s_test {
            iter.sims_center_center += nearest_center_bounds(ctx.centers.centers(), &mut s);
        }
        iter.phases.record(Phase::Bounds, sp);

        let sp = span_start();
        let outs = {
            let src = ctx.src;
            let centers = &ctx.centers;
            let p = ctx.centers.p();
            let tight = cfg.tight_hamerly_bound;
            let s = &s;
            let p_min_ex = &p_min_ex;
            let p_max_ex = &p_max_ex;
            let one_minus_pmin_sq = &one_minus_pmin_sq;
            let works = bound_works(&ctx.plan, &mut ctx.assign, &mut l, 1, &mut u, 1);
            ctx.pool.run(works, |_, (range, assign, l, u)| {
                let mut out = ShardOut::default();
                let mut scan = vec![0.0f64; k];
                let mut view = SimView::new(src, centers, k);
                for (li, i) in range.enumerate() {
                    let a = assign[li] as usize;
                    // Maintain bounds across the last center movement.
                    l[li] = update_lower(l[li], p[a]);
                    u[li] = if tight {
                        // Beyond-paper: guarded min-p — valid for all
                        // inputs and the tightest possible single bound.
                        update_min_p_guarded(u[li], p_min_ex[a])
                    } else if u[li] >= 0.0 && p_min_ex[a] >= 0.0 {
                        update_eq9_pre(u[li], one_minus_pmin_sq[a])
                    } else {
                        update_safe(u[li], p_min_ex[a], p_max_ex[a])
                    };
                    if use_s_test && l[li] >= s[a] {
                        out.iter.loop_skips += 1;
                        if AUDIT_ENABLED {
                            audit_loop_prune(
                                &mut view,
                                &mut out.violations,
                                engine,
                                iteration,
                                i,
                                a,
                                l[li],
                            );
                        }
                        continue;
                    }
                    if l[li] >= u[li] {
                        out.iter.bound_skips += 1;
                        if AUDIT_ENABLED {
                            // u(i) is one shared upper bound on every
                            // other center.
                            audit_set_prune(
                                &mut view,
                                &mut out.violations,
                                engine,
                                iteration,
                                i,
                                a,
                                0..k,
                                Some(u[li]),
                                Some(l[li]),
                            );
                        }
                        continue;
                    }
                    // Tighten l(i) and re-test before the expensive full
                    // scan. Book each post-tighten success into its own
                    // channel: a u-test success is a bound skip, an s-test
                    // success is a whole-loop skip (the Fig. 1 per-channel
                    // pruning stats must not conflate the two).
                    l[li] = view.similarity(i, a, &mut out.iter);
                    if l[li] >= u[li] {
                        out.iter.bound_skips += 1;
                        if AUDIT_ENABLED {
                            audit_set_prune(
                                &mut view,
                                &mut out.violations,
                                engine,
                                iteration,
                                i,
                                a,
                                0..k,
                                Some(u[li]),
                                Some(l[li]),
                            );
                        }
                        continue;
                    }
                    if use_s_test && l[li] >= s[a] {
                        out.iter.loop_skips += 1;
                        if AUDIT_ENABLED {
                            audit_loop_prune(
                                &mut view,
                                &mut out.violations,
                                engine,
                                iteration,
                                i,
                                a,
                                l[li],
                            );
                        }
                        continue;
                    }
                    // Bounds failed: find the best center other than `a`
                    // through the kernel backend. The exact l(i) = sim(i, a)
                    // just computed seeds the pruned kernel's traversal
                    // threshold — a center that cannot beat the current
                    // assignment cannot cause a reassignment, so the
                    // postings walk may stop as soon as its suffix bound
                    // drops below it (m2 may then understate only below
                    // l(i), which `u = l.max(m2)` masks).
                    let (jm, m1, m2) = view.best_other(
                        i,
                        a,
                        l[li],
                        iteration,
                        &mut out.iter,
                        &mut out.violations,
                        &mut scan,
                    );
                    if m1 > l[li] {
                        // Reassign; the old center becomes the best "other"
                        // unless the runner-up among the others beats it.
                        assign[li] = jm as u32;
                        out.moves.push(Move { i: i as u32, from: a as u32, to: jm as u32 });
                        out.iter.reassignments += 1;
                        u[li] = l[li].max(m2);
                        l[li] = m1;
                    } else {
                        u[li] = m1;
                    }
                }
                out
            })
        };
        iter.phases.record(Phase::Assignment, sp);
        let sp = span_start();
        ctx.merge_shards(outs, &mut iter);

        if iter.reassignments == 0 {
            iter.phases.record(Phase::Update, sp);
            iter.wall_ms = sw.ms();
            ctx.push_iter(iter, true);
            return true;
        }
        iter.sims_center_center += ctx.centers.update();
        iter.phases.record(Phase::Update, sp);
        iter.phases
            .shift(Phase::Update, Phase::IndexRefresh, ctx.centers.take_refresh_ms());
        iter.wall_ms = sw.ms();
        if ctx.push_iter(iter, false) {
            return false;
        }
    }
    false
}

pub(crate) fn run(ctx: &mut Ctx<'_, '_>, cfg: &KMeansConfig) -> bool {
    run_impl(ctx, cfg, true)
}
