//! Regenerates **Fig. 2** of the paper: run time vs k on the DBLP
//! author-conference analogue (high N, low d) and its transpose
//! (low N, high d) — the contrast where the `O(k²·d)` center–center cost
//! makes the full Elkan/Hamerly variants blow up.
//!
//! ```text
//! cargo bench --bench bench_fig2 -- [--scale S] [--reps N] [--ks ...]
//!     [--runs N] [--warmup W]
//!     [--ablation]   # adds the cc-cost-vs-dimensionality ablation
//! ```
//!
//! `--runs` is honored as an alias for `--reps` (the uniform bench-suite
//! spelling) when `--reps` is absent; `--warmup W` runs W untimed tiny
//! passes before the measured experiment.

// Bench and test targets favour readable literal casts and exact
// (bit-level) float assertions; the workspace clippy warnings on
// those patterns are aimed at library code.
#![allow(clippy::cast_possible_truncation, clippy::float_cmp)]

use sphkm::coordinator::experiments::{self, ExperimentOpts};
use sphkm::data::datasets::Scale;
use sphkm::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let mut opts = ExperimentOpts::from_args(&args);
    if args.has("runs") && !args.has("reps") {
        opts.reps = args.get_or("runs", opts.reps).unwrap_or(opts.reps).max(1);
    }
    let warmup: usize = args.get_or("warmup", 0).unwrap_or(0);
    for _ in 0..warmup {
        println!("# warmup pass (untimed)");
        let mut w = opts.clone();
        w.scale = Scale::Tiny;
        w.reps = 1;
        w.ks = vec![2];
        experiments::fig2(&w);
    }
    println!("# Fig. 2 bench — scale={}, reps={}", opts.scale.name(), opts.reps);
    experiments::fig2(&opts);
    if args.flag("ablation") {
        let k = args.get_or("k", 50usize).unwrap_or(50);
        experiments::ablation_cc(&opts, k);
    }
}
