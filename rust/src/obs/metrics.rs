//! Metrics registry: named counters, gauges, and fixed-bucket log-scale
//! latency histograms with exact rank-based quantiles.
//!
//! [`LatencyHistogram`] is the workhorse: a fixed array of 256
//! log-spaced buckets (4 sub-buckets per power-of-two octave of
//! nanoseconds, covering 0 ns to ~2⁶³ ns) plus exact count/sum/min/max.
//! Recording is O(1) with no allocation; two histograms merge by
//! element-wise addition, which is associative and commutative, so
//! per-shard histograms recorded on worker threads combine into exactly
//! the histogram a serial recording would have produced (property-tested
//! in `tests/obs.rs`). Quantiles are nearest-rank over bucket lower
//! bounds, clamped to the exact observed min/max — exact for samples on
//! bucket boundaries and within ≤ 25% relative bucket resolution
//! otherwise.
//!
//! [`Metrics`] groups named counters, gauges, and histograms and renders
//! to JSON for `assign --metrics-out`. A process-global instance
//! collects background metrics that have no natural owner — currently
//! the [`ShardStore`](crate::sparse::ShardStore) chunk loader — behind
//! the same zero-cost `trace` gate as the spans:
//! [`record_shard_io`] is a no-op unless handed a live span from
//! [`span_start`](crate::obs::span::span_start).

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// Number of histogram buckets: 4 sub-buckets for each of 62 octaves
/// plus 4 exact unit buckets, padded to a power of two.
pub const HIST_BUCKETS: usize = 256;

/// Schema identifier stamped on `assign --metrics-out` dumps (an object
/// `{"schema": …, "metrics": {counters, gauges, histograms}}`); bump on
/// any breaking shape change.
pub const METRICS_SCHEMA: &str = "sphkm.metrics.v1";

/// Counter name for shard-store chunk loads in the global registry.
pub const SHARD_IO_LOADS: &str = "shard_io.chunk_loads";
/// Counter name for shard-store bytes read in the global registry.
pub const SHARD_IO_BYTES: &str = "shard_io.bytes_read";
/// Histogram name for shard-store chunk-load latency in the global
/// registry.
pub const SHARD_IO_LATENCY: &str = "shard_io.chunk_load";

/// Fixed-bucket log-scale latency histogram over nanosecond samples.
///
/// Buckets 0–3 hold the exact values 0–3 ns; from there each
/// power-of-two octave `[2^o, 2^(o+1))` splits into 4 equal sub-buckets,
/// so relative bucket resolution is ≤ 25% everywhere. Exact count, sum,
/// min, and max ride alongside, making mean and the extreme quantiles
/// exact regardless of bucketing.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LatencyHistogram {
    counts: [u64; HIST_BUCKETS],
    count: u64,
    sum_ns: u64,
    min_ns: u64,
    max_ns: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self {
            counts: [0; HIST_BUCKETS],
            count: 0,
            sum_ns: 0,
            min_ns: u64::MAX,
            max_ns: 0,
        }
    }
}

impl LatencyHistogram {
    /// An empty histogram.
    pub fn new() -> Self {
        Self::default()
    }

    /// Bucket index for a nanosecond sample. Monotone in `ns`.
    fn bucket(ns: u64) -> usize {
        if ns < 4 {
            return ns as usize;
        }
        // The leading one sits at bit `o` (o >= 2 here); the next two
        // bits select the sub-bucket within the octave.
        let o = 63 - u64::from(ns.leading_zeros());
        let sub = (ns >> (o - 2)) & 3;
        (4 * o - 4 + sub) as usize
    }

    /// Inclusive lower bound (in ns) of bucket `idx` — the value
    /// quantile queries report for ranks landing in that bucket.
    pub fn bucket_lower_ns(idx: usize) -> u64 {
        if idx < 4 {
            return idx as u64;
        }
        let o = (idx as u64) / 4 + 1;
        let sub = (idx as u64) & 3;
        (1u64 << o) + sub * (1u64 << (o - 2))
    }

    /// Record one duration sample.
    #[inline]
    pub fn record(&mut self, d: Duration) {
        self.record_ns(u64::try_from(d.as_nanos()).unwrap_or(u64::MAX));
    }

    /// Record one nanosecond sample.
    #[inline]
    pub fn record_ns(&mut self, ns: u64) {
        self.counts[Self::bucket(ns)] += 1;
        self.count += 1;
        self.sum_ns = self.sum_ns.saturating_add(ns);
        self.min_ns = self.min_ns.min(ns);
        self.max_ns = self.max_ns.max(ns);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact sum of all samples in nanoseconds (saturating).
    pub fn sum_ns(&self) -> u64 {
        self.sum_ns
    }

    /// Exact minimum sample in nanoseconds (`0` when empty).
    pub fn min_ns(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min_ns
        }
    }

    /// Exact maximum sample in nanoseconds (`0` when empty).
    pub fn max_ns(&self) -> u64 {
        self.max_ns
    }

    /// Exact mean in nanoseconds (`0.0` when empty).
    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.count as f64
        }
    }

    /// Element-wise accumulate another histogram into this one.
    /// Associative and commutative: merging per-shard histograms in any
    /// order reproduces the serial recording exactly.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += *b;
        }
        self.count += other.count;
        self.sum_ns = self.sum_ns.saturating_add(other.sum_ns);
        self.min_ns = self.min_ns.min(other.min_ns);
        self.max_ns = self.max_ns.max(other.max_ns);
    }

    /// Nearest-rank quantile in nanoseconds: the lower bound of the
    /// bucket holding the `⌈q·n⌉`-th smallest sample, clamped to the
    /// exact observed `[min, max]`. `q ≤ 0` gives the exact minimum,
    /// `q ≥ 1` the exact maximum; an empty histogram reports `0`.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        if q <= 0.0 {
            return self.min_ns;
        }
        if q >= 1.0 {
            return self.max_ns;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Self::bucket_lower_ns(idx).clamp(self.min_ns, self.max_ns);
            }
        }
        self.max_ns
    }

    /// [`Self::quantile_ns`] converted to milliseconds.
    pub fn quantile_ms(&self, q: f64) -> f64 {
        self.quantile_ns(q) as f64 / 1e6
    }

    /// Render a summary object: count, sum, exact min/mean/max, and the
    /// p50/p90/p95/p99 quantiles, all in nanoseconds.
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("count".to_string(), Json::Num(self.count as f64)),
            ("sum_ns".to_string(), Json::Num(self.sum_ns as f64)),
            ("min_ns".to_string(), Json::Num(self.min_ns() as f64)),
            ("mean_ns".to_string(), Json::Num(self.mean_ns())),
            ("max_ns".to_string(), Json::Num(self.max_ns as f64)),
            ("p50_ns".to_string(), Json::Num(self.quantile_ns(0.50) as f64)),
            ("p90_ns".to_string(), Json::Num(self.quantile_ns(0.90) as f64)),
            ("p95_ns".to_string(), Json::Num(self.quantile_ns(0.95) as f64)),
            ("p99_ns".to_string(), Json::Num(self.quantile_ns(0.99) as f64)),
        ])
    }
}

/// A registry of named counters, gauges, and latency histograms.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Metrics {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, LatencyHistogram>,
}

impl Metrics {
    /// An empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `by` to the named counter (created at zero on first use).
    pub fn incr(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    /// Set the named gauge to `value`.
    pub fn set_gauge(&mut self, name: &str, value: f64) {
        self.gauges.insert(name.to_string(), value);
    }

    /// Record a nanosecond sample into the named histogram (created
    /// empty on first use).
    pub fn observe_ns(&mut self, name: &str, ns: u64) {
        self.histograms.entry(name.to_string()).or_default().record_ns(ns);
    }

    /// Current value of the named counter (`0` if never incremented).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Current value of the named gauge, if set.
    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    /// The named histogram, if any sample was recorded.
    pub fn histogram(&self, name: &str) -> Option<&LatencyHistogram> {
        self.histograms.get(name)
    }

    /// Insert (or merge into) a histogram recorded elsewhere — how
    /// per-shard serve histograms reach a registry.
    pub fn merge_histogram(&mut self, name: &str, h: &LatencyHistogram) {
        self.histograms.entry(name.to_string()).or_default().merge(h);
    }

    /// Accumulate another registry: counters add, gauges take the other
    /// side's value, histograms merge element-wise.
    pub fn merge(&mut self, other: &Metrics) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, v) in &other.gauges {
            self.gauges.insert(k.clone(), *v);
        }
        for (k, h) in &other.histograms {
            self.merge_histogram(k, h);
        }
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.histograms.is_empty()
    }

    /// Render the registry as a JSON object with `counters`, `gauges`,
    /// and `histograms` sections (histograms as summary objects, see
    /// [`LatencyHistogram::to_json`]).
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            (
                "counters".to_string(),
                Json::Obj(
                    self.counters
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::Num(*v as f64)))
                        .collect(),
                ),
            ),
            (
                "gauges".to_string(),
                Json::Obj(self.gauges.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect()),
            ),
            (
                "histograms".to_string(),
                Json::Obj(
                    self.histograms
                        .iter()
                        .map(|(k, h)| (k.clone(), h.to_json()))
                        .collect(),
                ),
            ),
        ])
    }
}

/// Process-global registry for background metrics with no natural owner
/// (shard-store chunk loads). `None` until the first record, so the
/// untraced path never allocates.
static GLOBAL: Mutex<Option<Metrics>> = Mutex::new(None);

/// Charge one shard-store chunk load to the global registry: latency
/// from the span, plus load-count and bytes-read counters. No-op (and
/// compiled out) when `span` is `None`, i.e. whenever the `trace`
/// feature is off.
pub fn record_shard_io(span: Option<Instant>, bytes: u64) {
    if let Some(t) = span {
        let ns = u64::try_from(t.elapsed().as_nanos()).unwrap_or(u64::MAX);
        let mut g = GLOBAL.lock().expect("metrics lock");
        let m = g.get_or_insert_with(Metrics::new);
        m.incr(SHARD_IO_LOADS, 1);
        m.incr(SHARD_IO_BYTES, bytes);
        m.observe_ns(SHARD_IO_LATENCY, ns);
    }
}

/// Total shard-store chunk-load wall-clock accumulated in the global
/// registry, in milliseconds. The estimator differences this around a
/// fit to attribute the run's [`Phase::ShardIo`](crate::obs::Phase)
/// total; always exactly 0.0 without the `trace` feature.
pub fn global_shard_io_ms() -> f64 {
    GLOBAL
        .lock()
        .expect("metrics lock")
        .as_ref()
        .and_then(|m| m.histogram(SHARD_IO_LATENCY))
        .map_or(0.0, |h| h.sum_ns() as f64 / 1e6)
}

/// Snapshot (clone) the global registry; empty if nothing was recorded.
pub fn global_snapshot() -> Metrics {
    GLOBAL.lock().expect("metrics lock").clone().unwrap_or_default()
}

/// Clear the global registry (test isolation and per-run deltas).
pub fn reset_global() {
    *GLOBAL.lock().expect("metrics lock") = None;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_monotone_and_lower_bounds_invert() {
        let mut prev = 0usize;
        for ns in [0u64, 1, 2, 3, 4, 5, 7, 8, 15, 16, 100, 1_000, 1_000_000, u64::MAX / 2] {
            let b = LatencyHistogram::bucket(ns);
            assert!(b >= prev, "bucket({ns}) = {b} < {prev}");
            assert!(b < HIST_BUCKETS);
            let lo = LatencyHistogram::bucket_lower_ns(b);
            assert!(lo <= ns, "lower({b}) = {lo} > {ns}");
            // The lower bound maps back into its own bucket.
            assert_eq!(LatencyHistogram::bucket(lo), b);
            prev = b;
        }
    }

    #[test]
    fn exact_quantiles_on_boundary_samples() {
        let mut h = LatencyHistogram::new();
        // Powers of two are bucket lower bounds, so quantiles are exact.
        for ns in [1u64, 2, 4, 8, 16, 32, 64, 128, 256, 512] {
            h.record_ns(ns);
        }
        assert_eq!(h.count(), 10);
        assert_eq!(h.min_ns(), 1);
        assert_eq!(h.max_ns(), 512);
        assert_eq!(h.quantile_ns(0.0), 1);
        assert_eq!(h.quantile_ns(0.10), 1); // rank 1
        assert_eq!(h.quantile_ns(0.50), 16); // rank 5
        assert_eq!(h.quantile_ns(0.90), 256); // rank 9
        assert_eq!(h.quantile_ns(0.95), 512); // rank 10
        assert_eq!(h.quantile_ns(0.99), 512); // rank 10
        assert_eq!(h.quantile_ns(1.0), 512);
        assert_eq!(h.sum_ns(), 1023);
    }

    #[test]
    fn single_sample_quantiles_are_the_sample() {
        let mut h = LatencyHistogram::new();
        h.record_ns(1000); // not a bucket boundary: clamped to min/max
        for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(h.quantile_ns(q), 1000, "q={q}");
        }
        assert!((h.mean_ns() - 1000.0).abs() < 1e-9);
    }

    #[test]
    fn empty_histogram_reports_zeros() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.min_ns(), 0);
        assert_eq!(h.max_ns(), 0);
        assert_eq!(h.quantile_ns(0.5), 0);
        assert_eq!(h.mean_ns(), 0.0);
    }

    #[test]
    fn merge_equals_serial_recording() {
        let samples = [3u64, 10, 10, 500, 90_000, 7, 2_000_000, 64];
        let mut serial = LatencyHistogram::new();
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        for (i, &s) in samples.iter().enumerate() {
            serial.record_ns(s);
            if i % 2 == 0 {
                a.record_ns(s);
            } else {
                b.record_ns(s);
            }
        }
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab, serial);
        assert_eq!(ba, serial); // commutative
    }

    #[test]
    fn registry_counters_gauges_histograms() {
        let mut m = Metrics::new();
        assert!(m.is_empty());
        m.incr("queries", 2);
        m.incr("queries", 3);
        m.set_gauge("qps", 123.5);
        m.observe_ns("latency", 1_000);
        m.observe_ns("latency", 2_000);
        assert_eq!(m.counter("queries"), 5);
        assert_eq!(m.counter("absent"), 0);
        assert_eq!(m.gauge("qps"), Some(123.5));
        assert_eq!(m.histogram("latency").map(LatencyHistogram::count), Some(2));

        let mut other = Metrics::new();
        other.incr("queries", 1);
        other.observe_ns("latency", 3_000);
        m.merge(&other);
        assert_eq!(m.counter("queries"), 6);
        assert_eq!(m.histogram("latency").map(LatencyHistogram::count), Some(3));

        let j = m.to_json();
        assert!(j.get("counters").and_then(|c| c.get("queries")).is_some());
        assert!(j.get("histograms").and_then(|h| h.get("latency")).is_some());
    }

    #[test]
    fn global_shard_io_gated_on_live_span() {
        // Other test threads may record concurrently (chunk loads under
        // `--features trace`), so assert only this thread's deltas.
        let before = global_snapshot();
        record_shard_io(None, 4096); // always a no-op
        // A live span records regardless of the feature: the gate is
        // span creation (span_start), not this sink.
        record_shard_io(Some(Instant::now()), 4096);
        let after = global_snapshot();
        assert!(after.counter(SHARD_IO_LOADS) >= before.counter(SHARD_IO_LOADS) + 1);
        assert!(after.counter(SHARD_IO_BYTES) >= before.counter(SHARD_IO_BYTES) + 4096);
        let n = after.histogram(SHARD_IO_LATENCY).map_or(0, LatencyHistogram::count);
        assert!(n >= 1);
    }
}
