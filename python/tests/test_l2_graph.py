"""L2 graph-quality tests (§Perf): the lowered HLO of the assignment step
must contain exactly one matmul (the Pallas kernel's dot per grid step —
no redundant recomputation), and the top-2 reduction must lower to classic
reduce ops the old XLA text parser accepts (no `topk`)."""

import re

from compile import aot


def test_assign_hlo_has_single_dot_and_no_topk():
    text = aot.lower_assign(8, 4, 16)
    # Exactly one dot op in the kernel body (one matmul per grid step).
    dots = re.findall(r"= f32\[[0-9,]*\]\{?[0-9,]*\}? dot\(", text)
    assert len(dots) == 1, f"expected 1 dot, found {len(dots)}"
    assert "topk" not in text, "topk op would break the XLA 0.5.1 parser"
    # Argmax/top-2 lower to reduces.
    assert text.count("reduce(") >= 2, "expected argmax + max reduces"


def test_assign_hlo_grid_matches_blockspec():
    # For a shape that tiles (B=256, K=16, D=512 with default blocks
    # (128,128,512) clamped to divisors), the grid is (2, 1, 1): the
    # pallas interpret lowering appears as a while loop over grid steps.
    text = aot.lower_assign(256, 16, 512)
    assert "while(" in text, "expected the pallas grid loop"


def test_bound_update_kernel_is_elementwise():
    """The bound-update kernel must stay free of dots/convolutions —
    a pure VPU elementwise graph."""
    import jax
    import jax.numpy as jnp
    from jax._src.lib import xla_client as xc

    from compile.kernels import bound_update as bu

    n = 2048
    spec = jax.ShapeDtypeStruct((n,), jnp.float32)
    lowered = jax.jit(bu.bound_update).lower(spec, spec, spec, spec)
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(lowered.compiler_ir("stablehlo")), use_tuple_args=False, return_tuple=True
    )
    text = comp.as_hlo_text()
    assert " dot(" not in text
    assert "convolution" not in text


def test_vmem_budget_documented_shapes():
    """The DESIGN.md §Perf VMEM analysis: default blocks fit comfortably,
    and doubling for double-buffering still fits the 16 MiB budget."""
    from compile.kernels import similarity as simk

    vm = simk.vmem_bytes()
    assert 2 * vm < 16 * 2**20
