//! Datasets: synthetic corpus/graph generators shaped like the paper's six
//! benchmark data sets (Table 1), TF-IDF weighting, a text-ingestion
//! pipeline, and sparse-matrix file I/O.
//!
//! The original evaluation data (DBLP snapshots, the Simpsons wiki dump,
//! 20 Newsgroups, RCV-1) is not redistributable/available offline, so the
//! generators in [`synth`] and [`datasets`] produce matrices matched in
//! *shape* — rows/columns ratio, non-zero density, Zipfian token
//! statistics, planted community structure, and (for the 20news analogue)
//! injected anomalous documents — at configurable scale. DESIGN.md §4
//! documents each substitution.

pub mod convert;
pub mod datasets;
pub mod io;
pub mod synth;
pub mod text;
pub mod tfidf;

use crate::sparse::CsrMatrix;

/// A dataset: its (normalized) matrix plus metadata.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Short name (Table 1 style).
    pub name: String,
    /// Row-normalized sparse matrix (rows = samples).
    pub matrix: CsrMatrix,
    /// Planted ground-truth labels, when the generator knows them.
    pub labels: Option<Vec<u32>>,
}

impl Dataset {
    /// Table 1 row: rows, columns, density(%) — for the dataset inventory.
    pub fn table1_row(&self) -> (String, usize, usize, f64) {
        (
            self.name.clone(),
            self.matrix.rows(),
            self.matrix.cols(),
            self.matrix.density() * 100.0,
        )
    }
}
