//! Mini-batch engine acceptance benchmark: on a ≥100k-row synthetic Zipf
//! corpus the mini-batch engine must reach within **2%** of the full-batch
//! Standard objective using **≥5×** fewer point–center similarity
//! computations (both checked with asserts at the end of the run).
//!
//! Both optimizers run `--warmup` untimed + `--runs` timed repetitions
//! (fits are deterministic, so the acceptance asserts see the same result
//! every time and only the wall-clock samples vary).
//!
//! ```text
//! cargo bench --bench bench_minibatch -- [--rows 100000] [--k 50]
//!     [--batch 1024] [--epochs 2] [--tol 1e-4] [--truncate 0]
//!     [--threads 0] [--max-iter 100] [--seed 42] [--runs 1] [--warmup 0]
//! ```

// Bench and test targets favour readable literal casts and exact
// (bit-level) float assertions; the workspace clippy warnings on
// those patterns are aimed at library code.
#![allow(clippy::cast_possible_truncation, clippy::float_cmp)]

use sphkm::data::synth::SynthConfig;
use sphkm::init::{seed_centers, InitMethod};
use sphkm::kmeans::{Engine, MiniBatchParams, SphericalKMeans, Variant};
use sphkm::metrics;
use sphkm::util::benchkit::BenchOpts;
use sphkm::util::cli::Args;
use sphkm::util::timer::{Stopwatch, TimingStats};

fn main() {
    let args = Args::from_env();
    let rows: usize = args.get_or("rows", 100_000).unwrap_or(100_000);
    let k: usize = args.get_or("k", 50).unwrap_or(50);
    let batch: usize = args.get_or("batch", 1024).unwrap_or(1024);
    let epochs: usize = args.get_or("epochs", 2).unwrap_or(2);
    let tol: f64 = args.get_or("tol", 1e-4).unwrap_or(1e-4);
    let truncate: usize = args.get_or("truncate", 0).unwrap_or(0);
    let threads: usize = args.get_or("threads", 0).unwrap_or(0);
    let max_iter: usize = args.get_or("max-iter", 100).unwrap_or(100);
    let seed: u64 = args.get_or("seed", 42).unwrap_or(42);
    // Each run is a full fit over a 100k-row corpus: default to a single
    // timed run with no warmup (the historical behaviour).
    let mut opts = BenchOpts::from_args(&args);
    if !args.has("runs") {
        opts.runs = 1;
    }
    if !args.has("warmup") {
        opts.warmup = 0;
    }

    let ds = SynthConfig {
        name: format!("mb-blobs-{rows}"),
        n_docs: rows,
        vocab: 20_000,
        topics: k.max(2),
        doc_len_mean: 60.0,
        doc_len_sigma: 0.4,
        topic_strength: 0.65,
        shared_vocab_frac: 0.2,
        zipf_s: 1.05,
        anomaly_frac: 0.0,
        tfidf: Default::default(),
    }
    .generate(seed);
    println!(
        "# mini-batch acceptance bench — {} ({}×{}, {:.4}% nnz), k={k}, threads={threads}, \
         runs={} (+{} warmup)",
        ds.name,
        ds.matrix.rows(),
        ds.matrix.cols(),
        ds.matrix.density() * 100.0,
        opts.runs,
        opts.warmup,
    );

    // Shared initial centers so the comparison isolates the optimizer.
    let init = seed_centers(&ds.matrix, k, &InitMethod::Uniform, seed ^ 1);

    // Deterministic fits: repeated runs reproduce the same result, so the
    // last repetition feeds the acceptance asserts while every post-warmup
    // repetition contributes a wall-clock sample.
    let time_fit = |fit: &dyn Fn() -> sphkm::kmeans::KMeansResult| {
        let mut samples = Vec::new();
        let mut last = None;
        for it in 0..opts.warmup + opts.runs.max(1) {
            let sw = Stopwatch::start();
            let r = fit();
            let ms = sw.ms();
            if it >= opts.warmup {
                samples.push(ms);
            }
            last = Some(r);
        }
        (last.expect("at least one run"), TimingStats::from_ms(&samples))
    };

    let (full, full_t) = time_fit(&|| {
        SphericalKMeans::new(k)
            .variant(Variant::Standard)
            .threads(threads)
            .max_iter(max_iter)
            .warm_start_centers(init.centers.clone())
            .fit(&ds.matrix)
            .expect("bench configuration is valid")
            .into_result()
    });
    let full_ms = full_t.mean_ms;
    println!(
        "full-batch Standard : obj={:.2}  pc_sims={}  iters={}  converged={}  {:.0} ms",
        full.objective,
        full.stats.total_point_center(),
        full.iterations,
        full.converged,
        full_ms,
    );

    let (mb, mb_t) = time_fit(&|| {
        SphericalKMeans::new(k)
            .engine(Engine::MiniBatch(MiniBatchParams {
                batch_size: batch,
                epochs,
                tol,
                truncate: if truncate == 0 { None } else { Some(truncate) },
            }))
            .seed(seed)
            .threads(threads)
            .warm_start_centers(init.centers.clone())
            .fit(&ds.matrix)
            .expect("bench configuration is valid")
            .into_result()
    });
    let mb_ms = mb_t.mean_ms;
    let gap = metrics::objective_gap(mb.objective, full.objective);
    let ratio =
        full.stats.total_point_center() as f64 / mb.stats.total_point_center().max(1) as f64;
    println!(
        "mini-batch b={batch:<5}: obj={:.2}  pc_sims={}  epochs={}  {:.0} ms",
        mb.objective,
        mb.stats.total_point_center(),
        mb.iterations,
        mb_ms,
    );
    println!(
        "trade-off           : gap={:+.3}%  sims ratio={ratio:.1}x  speedup={:.1}x",
        gap * 100.0,
        full_ms / mb_ms.max(1e-3),
    );

    assert!(
        rows < 100_000 || gap <= 0.02,
        "objective gap {:.3}% exceeds the 2% acceptance bar",
        gap * 100.0
    );
    assert!(
        rows < 100_000 || ratio >= 5.0,
        "similarity ratio {ratio:.2}x is below the 5x acceptance bar"
    );
    println!("# acceptance: objective gap <= 2% and >= 5x fewer point-center sims — OK");
}
