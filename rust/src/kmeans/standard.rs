//! The baseline spherical k-means algorithm (Dhillon & Modha 2001) with the
//! paper's §5 implementation optimizations: unit-normalized data (dot
//! product = cosine), cached unnormalized sums updated incrementally, and
//! sums scaled (not averaged) to unit length. No pruning — every iteration
//! computes all `N·k` similarities through the configured kernel backend
//! ([`crate::kmeans::kernel`]: dense transpose, gather dots, or the
//! inverted file), sharded across the worker pool (see the module docs of
//! [`crate::kmeans`] for the determinism contract).

use super::{audit_sim, Ctx, IterStats, KMeansConfig, Kernel, Move, ShardOut, SimView};
use crate::audit::{AuditViolation, AUDIT_ENABLED, AUDIT_MARGIN};
use crate::obs::{span::span_start, Phase};
use crate::runtime::parallel::split_mut;
use crate::util::timer::Stopwatch;
use std::ops::Range;

pub(crate) fn run(ctx: &mut Ctx<'_, '_>, cfg: &KMeansConfig) -> bool {
    // Iteration 0: full assignment to the initial centers. Standard keeps
    // no bound state, so a resumed run records only the placeholder entry.
    let shards = ctx.plan.len();
    let stop = if ctx.resuming() {
        ctx.resume_marker()
    } else {
        ctx.initial_assignment(false, vec![(); shards], |_, _, _, _, _, _| {})
    };
    if stop {
        return false;
    }

    let k = ctx.k;
    for _ in 0..cfg.max_iter {
        let sw = Stopwatch::start();
        let mut iter = IterStats::default();
        let iteration = ctx.stats.iters.len();

        let sp = span_start();
        let outs = {
            let src = ctx.src;
            let centers = &ctx.centers;
            let mut works: Vec<(Range<usize>, &mut [u32])> = Vec::with_capacity(shards);
            {
                let assign = split_mut(&ctx.plan, 1, &mut ctx.assign);
                for (r, a) in ctx.plan.ranges().iter().cloned().zip(assign) {
                    works.push((r, a));
                }
            }
            ctx.pool.run(works, |_, (range, assign)| {
                let mut out = ShardOut::default();
                let mut scratch = vec![0.0f64; k];
                let mut view = SimView::new(src, centers, k);
                for (li, i) in range.enumerate() {
                    let (best_j, _, _) = view.assign_top2(
                        i,
                        iteration,
                        &mut out.iter,
                        &mut out.violations,
                        &mut scratch,
                    );
                    if AUDIT_ENABLED && centers.kernel() != Kernel::Pruned {
                        // Standard takes no pruning decisions; what audit
                        // certifies here is the kernel layer itself — the
                        // configured backend's similarity row must agree
                        // with directly recomputed gather dots, or every
                        // bound the accelerated variants derive from the
                        // same backend is suspect. (Under the pruned kernel
                        // `scratch` holds partial scores, not similarities;
                        // `assign_top2` certifies its own decisions through
                        // `audit_set_prune` instead.)
                        for (j, &sj) in scratch.iter().enumerate() {
                            let exact = audit_sim(&mut view, i, j);
                            if (sj - exact).abs() > AUDIT_MARGIN {
                                out.violations.push(AuditViolation::bound(
                                    "standard",
                                    "kernel-sim-coherence",
                                    iteration,
                                    Some(i),
                                    Some(j),
                                    sj,
                                    exact,
                                ));
                            }
                        }
                    }
                    let old = assign[li] as usize;
                    if best_j != old {
                        assign[li] = best_j as u32;
                        out.moves.push(Move {
                            i: i as u32,
                            from: old as u32,
                            to: best_j as u32,
                        });
                        out.iter.reassignments += 1;
                    }
                }
                out
            })
        };
        iter.phases.record(Phase::Assignment, sp);
        let sp = span_start();
        ctx.merge_shards(outs, &mut iter);

        if iter.reassignments == 0 {
            iter.phases.record(Phase::Update, sp);
            iter.wall_ms = sw.ms();
            ctx.push_iter(iter, true);
            return true;
        }
        iter.sims_center_center += ctx.centers.update();
        iter.phases.record(Phase::Update, sp);
        iter.phases
            .shift(Phase::Update, Phase::IndexRefresh, ctx.centers.take_refresh_ms());
        iter.wall_ms = sw.ms();
        if ctx.push_iter(iter, false) {
            return false;
        }
    }
    false
}
