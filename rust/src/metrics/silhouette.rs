//! Cosine silhouette coefficient (sampled).
//!
//! The silhouette of a point compares its mean dissimilarity to its own
//! cluster (`a`) with the smallest mean dissimilarity to another cluster
//! (`b`): `s = (b − a) / max(a, b)`, in `[−1, 1]`. Dissimilarity here is
//! the cosine dissimilarity `1 − ⟨x, y⟩` (valid since rows are unit
//! length). Exact silhouette is `O(N²)`; we evaluate a deterministic
//! sample of points against all others — enough for model selection.

use crate::sparse::CsrMatrix;
use crate::util::rng::Xoshiro256;

/// Mean sampled silhouette for an assignment. `sample` points are drawn
/// deterministically from `seed`; pass `sample >= N` for the exact value.
/// Returns `None` when fewer than 2 clusters are non-empty.
pub fn silhouette_sampled(
    data: &CsrMatrix,
    assign: &[u32],
    sample: usize,
    seed: u64,
) -> Option<f64> {
    assert_eq!(assign.len(), data.rows());
    let n = data.rows();
    if n == 0 {
        return None;
    }
    let k = assign.iter().copied().max()? as usize + 1;
    let mut counts = vec![0u64; k];
    for &a in assign {
        counts[a as usize] += 1;
    }
    if counts.iter().filter(|&&c| c > 0).count() < 2 {
        return None;
    }
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let ids: Vec<usize> = if sample >= n {
        (0..n).collect()
    } else {
        rng.sample_distinct(n, sample)
    };
    let mut total = 0.0;
    let mut used = 0usize;
    let mut dis_sum = vec![0.0f64; k];
    for &i in &ids {
        let own = assign[i] as usize;
        if counts[own] <= 1 {
            // Singleton clusters have silhouette 0 by convention.
            used += 1;
            continue;
        }
        dis_sum.iter_mut().for_each(|v| *v = 0.0);
        let row = data.row(i);
        for j in 0..n {
            if j == i {
                continue;
            }
            let d = 1.0 - row.dot(&data.row(j));
            dis_sum[assign[j] as usize] += d;
        }
        let a = dis_sum[own] / (counts[own] - 1) as f64;
        let b = (0..k)
            .filter(|&c| c != own && counts[c] > 0)
            .map(|c| dis_sum[c] / counts[c] as f64)
            .fold(f64::MAX, f64::min);
        let denom = a.max(b);
        if denom > 0.0 {
            total += (b - a) / denom;
        }
        used += 1;
    }
    if used == 0 {
        None
    } else {
        Some(total / used as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::SparseVec;

    /// Two tight orthogonal clusters → silhouette near 1.
    fn two_blobs() -> (CsrMatrix, Vec<u32>) {
        let mut rows = Vec::new();
        let mut labels = Vec::new();
        for g in 0..2u32 {
            for t in 0..10u32 {
                // Main direction e_g plus a small private component.
                rows.push(SparseVec::from_pairs(
                    64,
                    vec![(g, 1.0), (10 + g * 10 + t, 0.1)],
                ));
                labels.push(g);
            }
        }
        let mut m = CsrMatrix::from_rows(64, &rows);
        m.normalize_rows();
        (m, labels)
    }

    #[test]
    fn separated_clusters_score_high() {
        let (m, labels) = two_blobs();
        let s = silhouette_sampled(&m, &labels, usize::MAX, 1).unwrap();
        assert!(s > 0.9, "silhouette {s} too low for separated blobs");
    }

    #[test]
    fn random_labels_score_low() {
        let (m, _) = two_blobs();
        let random: Vec<u32> = (0..20).map(|i| (i % 2) as u32).collect();
        // Alternating labels mix the blobs.
        let s = silhouette_sampled(&m, &random, usize::MAX, 1).unwrap();
        assert!(s < 0.1, "silhouette {s} should be poor for mixed labels");
    }

    #[test]
    fn single_cluster_is_none() {
        let (m, _) = two_blobs();
        let one = vec![0u32; 20];
        assert!(silhouette_sampled(&m, &one, usize::MAX, 1).is_none());
    }

    #[test]
    fn sampling_approximates_exact() {
        let (m, labels) = two_blobs();
        let exact = silhouette_sampled(&m, &labels, usize::MAX, 1).unwrap();
        let sampled = silhouette_sampled(&m, &labels, 10, 2).unwrap();
        assert!((exact - sampled).abs() < 0.15);
    }
}
