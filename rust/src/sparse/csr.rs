//! Compressed Sparse Row matrix — the container for a whole dataset.

use super::ops::{normalize_row_values, sparse_dense_dot, sparse_sparse_dot};
use super::vec::SparseVec;
use crate::audit::AuditViolation;

/// A read-optimized CSR matrix of `f32` values with `u32` column indices.
///
/// Rows are the data samples; the clustering algorithms only ever iterate
/// rows and take row·center dot products, so CSR is the natural layout.
#[derive(Debug, Clone, PartialEq)]
pub struct CsrMatrix {
    rows: usize,
    cols: usize,
    indptr: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<f32>,
}

/// A borrowed view of one CSR row (sorted indices + parallel values).
#[derive(Debug, Clone, Copy)]
pub struct RowView<'a> {
    /// Sorted column indices of the non-zeros.
    pub indices: &'a [u32],
    /// Values parallel to `indices`.
    pub values: &'a [f32],
}

impl<'a> RowView<'a> {
    /// Number of non-zeros in the row.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Dot product with a dense vector.
    #[inline]
    pub fn dot_dense(&self, dense: &[f32]) -> f64 {
        sparse_dense_dot(self.indices, self.values, dense)
    }

    /// Dot product with another row view (sorted merge).
    #[inline]
    pub fn dot(&self, other: &RowView<'_>) -> f64 {
        sparse_sparse_dot(self.indices, self.values, other.indices, other.values)
    }

    /// Squared Euclidean norm of the row.
    pub fn norm_sq(&self) -> f64 {
        self.values.iter().map(|&v| v as f64 * v as f64).sum()
    }
}

impl CsrMatrix {
    /// Assemble from raw CSR parts. Shape invariants (lengths, indptr end)
    /// are always asserted; the per-row invariants (monotone indptr,
    /// strictly increasing sorted indices, column bounds) are
    /// `debug_assert`-only — this is the trusted constructor for parts
    /// built by this crate. Untrusted parts (external files) must go
    /// through [`CsrMatrix::try_from_parts`], which validates everything
    /// with real errors in every build profile.
    pub fn from_parts(
        rows: usize,
        cols: usize,
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<f32>,
    ) -> Self {
        assert_eq!(indptr.len(), rows + 1, "indptr length");
        assert_eq!(*indptr.last().unwrap_or(&0), indices.len(), "indptr end");
        assert_eq!(indices.len(), values.len(), "indices/values length");
        debug_assert!(indptr.windows(2).all(|w| w[0] <= w[1]));
        for r in 0..rows {
            let s = &indices[indptr[r]..indptr[r + 1]];
            debug_assert!(s.windows(2).all(|w| w[0] < w[1]), "row {r} not sorted");
            debug_assert!(s.last().map(|&c| (c as usize) < cols).unwrap_or(true));
        }
        Self { rows, cols, indptr, indices, values }
    }

    /// Validating constructor for **untrusted** CSR parts: performs every
    /// check [`CsrMatrix::from_parts`] only `debug_assert`s — indptr
    /// length/monotonicity/end, parallel index/value lengths, strictly
    /// increasing per-row indices (no duplicates), and column bounds —
    /// returning a descriptive error instead of silently corrupting the
    /// merge dot products in release builds.
    pub fn try_from_parts(
        rows: usize,
        cols: usize,
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<f32>,
    ) -> Result<Self, String> {
        if indptr.len() != rows + 1 {
            return Err(format!(
                "indptr length {} does not match rows {rows} + 1",
                indptr.len()
            ));
        }
        if *indptr.last().unwrap_or(&0) != indices.len() {
            return Err(format!(
                "indptr end {} does not match nnz {}",
                indptr.last().unwrap_or(&0),
                indices.len()
            ));
        }
        if indices.len() != values.len() {
            return Err(format!(
                "index/value length mismatch: {} vs {}",
                indices.len(),
                values.len()
            ));
        }
        if let Some(w) = indptr.windows(2).find(|w| w[0] > w[1]) {
            return Err(format!("indptr not monotone: {} before {}", w[0], w[1]));
        }
        for r in 0..rows {
            let s = &indices[indptr[r]..indptr[r + 1]];
            for w in s.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!(
                        "row {r}: indices not strictly increasing ({} then {})",
                        w[0], w[1]
                    ));
                }
            }
            if let Some(&last) = s.last() {
                if last as usize >= cols {
                    return Err(format!(
                        "row {r}: index {last} out of bounds for {cols} columns"
                    ));
                }
            }
        }
        Ok(Self { rows, cols, indptr, indices, values })
    }

    /// Build from a list of sparse rows (all must share `cols`).
    pub fn from_rows(cols: usize, rows: &[SparseVec]) -> Self {
        let mut indptr = Vec::with_capacity(rows.len() + 1);
        let nnz: usize = rows.iter().map(|r| r.nnz()).sum();
        let mut indices = Vec::with_capacity(nnz);
        let mut values = Vec::with_capacity(nnz);
        indptr.push(0);
        for r in rows {
            assert_eq!(r.dim, cols, "row dimension mismatch");
            indices.extend_from_slice(r.indices());
            values.extend_from_slice(r.values());
            indptr.push(indices.len());
        }
        Self { rows: rows.len(), cols, indptr, indices, values }
    }

    /// Number of rows (samples).
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns (features).
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Total non-zeros.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Fraction of stored entries: `nnz / (rows·cols)`.
    pub fn density(&self) -> f64 {
        if self.rows == 0 || self.cols == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.rows as f64 * self.cols as f64)
    }

    /// Borrow row `r`.
    #[inline]
    pub fn row(&self, r: usize) -> RowView<'_> {
        let (s, e) = (self.indptr[r], self.indptr[r + 1]);
        RowView {
            indices: &self.indices[s..e],
            values: &self.values[s..e],
        }
    }

    /// Iterate all rows in order.
    pub fn iter_rows(&self) -> impl Iterator<Item = RowView<'_>> + '_ {
        (0..self.rows).map(move |r| self.row(r))
    }

    /// Copy row `r` into an owned [`SparseVec`].
    pub fn row_vec(&self, r: usize) -> SparseVec {
        let v = self.row(r);
        SparseVec::new(self.cols, v.indices.to_vec(), v.values.to_vec())
    }

    /// L2-normalize every row in place; all-zero rows are left untouched.
    /// Returns the number of rows that could not be normalized. Shares its
    /// arithmetic with the streaming shard converter via
    /// [`normalize_row_values`] so both pipelines produce bit-identical
    /// unit rows.
    pub fn normalize_rows(&mut self) -> usize {
        let mut failures = 0;
        for r in 0..self.rows {
            let (s, e) = (self.indptr[r], self.indptr[r + 1]);
            if !normalize_row_values(&mut self.values[s..e]) {
                failures += 1;
            }
        }
        failures
    }

    /// Transpose (CSR→CSR of the transpose). Used for the DBLP
    /// conference–author experiments (Fig. 2), where the paper transposes
    /// the bipartite matrix before TF-IDF.
    pub fn transpose(&self) -> CsrMatrix {
        // Row ids become column indices of the transpose, which the CSR
        // layout stores as u32 — a lossy cast would silently alias rows.
        assert!(
            self.rows == 0 || u32::try_from(self.rows - 1).is_ok(),
            "transpose: {} rows exceed the u32 index space",
            self.rows
        );
        let mut counts = vec![0usize; self.cols + 1];
        for &c in &self.indices {
            counts[c as usize + 1] += 1;
        }
        for i in 1..counts.len() {
            counts[i] += counts[i - 1];
        }
        let indptr = counts.clone();
        let mut pos = counts;
        let mut indices = vec![0u32; self.nnz()];
        let mut values = vec![0.0f32; self.nnz()];
        for r in 0..self.rows {
            let (s, e) = (self.indptr[r], self.indptr[r + 1]);
            for k in s..e {
                let c = self.indices[k] as usize;
                let p = pos[c];
                indices[p] = r as u32;
                values[p] = self.values[k];
                pos[c] = p + 1;
            }
        }
        // Row order within each transposed row follows original row order,
        // which is increasing — so indices are already sorted.
        CsrMatrix::from_parts(self.cols, self.rows, indptr, indices, values)
    }

    /// Sum of selected rows accumulated into a dense buffer (used by center
    /// computation). `out.len()` must equal `cols`.
    pub fn sum_rows_into(&self, row_ids: impl Iterator<Item = usize>, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.cols);
        for r in row_ids {
            let v = self.row(r);
            for (i, &c) in v.indices.iter().enumerate() {
                out[c as usize] += v.values[i];
            }
        }
    }

    /// Remove all-zero rows, returning the filtered matrix and the indices
    /// of the kept rows (used to filter label vectors in parallel). The
    /// transpose of a bipartite graph can contain empty rows (venues with
    /// no papers at small scale) which cannot be unit-normalized.
    pub fn drop_empty_rows(&self) -> (CsrMatrix, Vec<usize>) {
        let mut kept = Vec::new();
        let mut rows = Vec::new();
        for r in 0..self.rows {
            if self.indptr[r + 1] > self.indptr[r] {
                kept.push(r);
                rows.push(self.row_vec(r));
            }
        }
        (CsrMatrix::from_rows(self.cols, &rows), kept)
    }

    /// Dense materialization (tests / PJRT batches only — O(rows·cols)).
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.cols];
        for r in 0..self.rows {
            let v = self.row(r);
            for (i, &c) in v.indices.iter().enumerate() {
                out[r * self.cols + c as usize] = v.values[i];
            }
        }
        out
    }

    /// Deep invariant check for the audit layer ([`crate::audit`]): every
    /// structural property the merge dot products and the incremental
    /// center maintenance silently rely on — indptr shape/monotonicity,
    /// parallel index/value arrays, strictly increasing in-bounds row
    /// indices, and finite values (a NaN row poisons every bound derived
    /// from it). Run once per audited fit and callable from tests; returns
    /// the first broken invariant with full context.
    pub fn check_invariants(&self) -> Result<(), AuditViolation> {
        let fail = |check: &'static str, detail: String| {
            Err(AuditViolation::invariant("csr", check, detail))
        };
        if self.indptr.len() != self.rows + 1 {
            return fail(
                "indptr-shape",
                format!("indptr length {} != rows {} + 1", self.indptr.len(), self.rows),
            );
        }
        if self.indptr.first() != Some(&0) {
            return fail("indptr-shape", format!("indptr[0] = {:?} != 0", self.indptr.first()));
        }
        if *self.indptr.last().unwrap_or(&0) != self.indices.len() {
            return fail(
                "indptr-end",
                format!(
                    "indptr end {} != nnz {}",
                    self.indptr.last().unwrap_or(&0),
                    self.indices.len()
                ),
            );
        }
        if self.indices.len() != self.values.len() {
            return fail(
                "parallel-arrays",
                format!("{} indices vs {} values", self.indices.len(), self.values.len()),
            );
        }
        if let Some(r) = (0..self.rows).find(|&r| self.indptr[r] > self.indptr[r + 1]) {
            return fail(
                "indptr-monotone",
                format!("indptr[{r}]={} > indptr[{}]={}", self.indptr[r], r + 1, self.indptr[r + 1]),
            );
        }
        for r in 0..self.rows {
            let s = &self.indices[self.indptr[r]..self.indptr[r + 1]];
            if let Some(w) = s.windows(2).find(|w| w[0] >= w[1]) {
                return fail(
                    "row-indices-sorted",
                    format!("row {r}: index {} then {}", w[0], w[1]),
                );
            }
            if let Some(&last) = s.last() {
                if last as usize >= self.cols {
                    return fail(
                        "column-bounds",
                        format!("row {r}: index {last} out of bounds for {} columns", self.cols),
                    );
                }
            }
        }
        if let Some((t, &v)) = self.values.iter().enumerate().find(|(_, v)| !v.is_finite()) {
            return fail("finite-values", format!("values[{t}] = {v}"));
        }
        Ok(())
    }

    /// Dense materialization of a contiguous row range `[start, end)` into
    /// a row-major `(end-start) × cols` buffer (PJRT batch staging).
    pub fn rows_to_dense(&self, start: usize, end: usize, out: &mut [f32]) {
        let n = end - start;
        debug_assert_eq!(out.len(), n * self.cols);
        out.fill(0.0);
        for (local, r) in (start..end).enumerate() {
            let v = self.row(r);
            for (i, &c) in v.indices.iter().enumerate() {
                out[local * self.cols + c as usize] = v.values[i];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    fn small() -> CsrMatrix {
        // [[1,0,2],[0,0,0],[0,3,4]]
        CsrMatrix::from_parts(
            3,
            3,
            vec![0, 2, 2, 4],
            vec![0, 2, 1, 2],
            vec![1.0, 2.0, 3.0, 4.0],
        )
    }

    #[test]
    fn basic_accessors() {
        let m = small();
        assert_eq!(m.rows(), 3);
        assert_eq!(m.cols(), 3);
        assert_eq!(m.nnz(), 4);
        assert_eq!(m.row(0).nnz(), 2);
        assert_eq!(m.row(1).nnz(), 0);
        assert!((m.density() - 4.0 / 9.0).abs() < 1e-12);
    }

    #[test]
    fn try_from_parts_validates_untrusted_input() {
        // The same parts `small()` trusts pass the validating path.
        let ok = CsrMatrix::try_from_parts(
            3,
            3,
            vec![0, 2, 2, 4],
            vec![0, 2, 1, 2],
            vec![1.0, 2.0, 3.0, 4.0],
        );
        assert_eq!(ok.unwrap(), small());
        // Unsorted row.
        assert!(CsrMatrix::try_from_parts(
            1,
            3,
            vec![0, 2],
            vec![2, 0],
            vec![1.0, 2.0]
        )
        .unwrap_err()
        .contains("strictly increasing"));
        // Column out of bounds.
        assert!(CsrMatrix::try_from_parts(
            1,
            3,
            vec![0, 1],
            vec![3],
            vec![1.0]
        )
        .unwrap_err()
        .contains("out of bounds"));
        // Non-monotone indptr.
        assert!(CsrMatrix::try_from_parts(
            2,
            3,
            vec![0, 2, 1],
            vec![0],
            vec![1.0]
        )
        .is_err());
        // indptr end disagrees with nnz.
        assert!(CsrMatrix::try_from_parts(1, 3, vec![0, 2], vec![0], vec![1.0]).is_err());
    }

    #[test]
    fn row_dot_products() {
        let m = small();
        let dense = vec![1.0f32, 1.0, 1.0];
        assert!((m.row(0).dot_dense(&dense) - 3.0).abs() < 1e-12);
        assert!((m.row(2).dot_dense(&dense) - 7.0).abs() < 1e-12);
        let r0 = m.row(0);
        let r2 = m.row(2);
        assert!((r0.dot(&r2) - 8.0).abs() < 1e-12);
    }

    #[test]
    fn normalize_rows_handles_zero_rows() {
        let mut m = small();
        let failures = m.normalize_rows();
        assert_eq!(failures, 1); // row 1 is all-zero
        assert!((m.row(0).norm_sq() - 1.0).abs() < 1e-6);
        assert!((m.row(2).norm_sq() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn transpose_round_trip() {
        let m = small();
        let t = m.transpose();
        assert_eq!(t.rows(), 3);
        assert_eq!(t.cols(), 3);
        assert_eq!(t.row(2).indices, &[0, 2]);
        let tt = t.transpose();
        assert_eq!(tt, m);
    }

    #[test]
    fn sum_rows_into_accumulates() {
        let m = small();
        let mut acc = vec![0.0f32; 3];
        m.sum_rows_into([0usize, 2].into_iter(), &mut acc);
        assert_eq!(acc, vec![1.0, 3.0, 6.0]);
    }

    #[test]
    fn rows_to_dense_block() {
        let m = small();
        let mut buf = vec![9.0f32; 2 * 3];
        m.rows_to_dense(1, 3, &mut buf);
        assert_eq!(buf, vec![0.0, 0.0, 0.0, 0.0, 3.0, 4.0]);
    }

    #[test]
    fn prop_transpose_involution_and_dot_preservation() {
        forall(60, 0xC5A, |g| {
            let rows = g.usize_in(1, 30);
            let cols = g.usize_in(1, 30);
            let mut svs = Vec::new();
            for _ in 0..rows {
                let nnz = g.usize_in(0, cols + 1);
                let p = g.sparse_pattern(cols, nnz);
                svs.push(SparseVec::new(
                    cols,
                    p.iter().map(|&i| i as u32).collect(),
                    p.iter().map(|_| g.f64_in(0.1, 2.0) as f32).collect(),
                ));
            }
            let m = CsrMatrix::from_rows(cols, &svs);
            let tt = m.transpose().transpose();
            assert_eq!(tt, m);
        });
    }

    #[test]
    fn check_invariants_accepts_valid_and_names_broken_structure() {
        assert!(small().check_invariants().is_ok());

        // Unsorted indices within a row.
        let mut m = small();
        m.indices.swap(0, 1);
        assert_eq!(m.check_invariants().unwrap_err().check, "row-indices-sorted");

        // Non-finite stored value.
        let mut m = small();
        m.values[0] = f32::NAN;
        assert_eq!(m.check_invariants().unwrap_err().check, "finite-values");

        // Decreasing row pointer.
        let mut m = small();
        m.indptr[1] = 3;
        m.indptr[2] = 1;
        assert_eq!(m.check_invariants().unwrap_err().check, "indptr-monotone");

        // Column index out of bounds.
        let mut m = small();
        m.indices[3] = 7;
        assert_eq!(m.check_invariants().unwrap_err().check, "column-bounds");
    }
}
