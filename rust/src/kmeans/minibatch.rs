//! Mini-batch spherical k-means — the large-corpus workload engine.
//!
//! The exact variants (see [`crate::kmeans`]) pay at least one full
//! `O(N·k)` assignment pass per iteration. For corpora far beyond what one
//! pass can afford, mini-batch optimization (Sculley 2010; Knittel et al.
//! 2021 for the sparse spherical regime) converges on a small **sampled
//! batch** per step instead: assign only the batch against frozen centers,
//! then fold each batch point into its center's cached sum — the running
//! mean updated at the decayed per-center learning rate `η_j = 1/n_j` —
//! and re-scale the touched centers to unit length. Quality is a bounded
//! approximation of the full-batch objective (measure it with
//! [`crate::metrics::objective_gap`]); the payoff is an order of magnitude
//! fewer point×center similarities (`bench_minibatch` demonstrates the
//! trade on a 100k-row corpus).
//!
//! The engine is selected through the estimator front door:
//! [`Engine::MiniBatch`](super::Engine) with typed
//! [`MiniBatchParams`](super::MiniBatchParams) (`batch_size`, `epochs`,
//! `tol`, `truncate`) — it is deliberately *not* a
//! [`Variant`](super::Variant), because it does not satisfy the exactness
//! contract of the full-batch family.
//!
//! **Determinism.** Results are bit-identical for every
//! `threads` setting, by the same reasoning as the exact
//! variants' shard contract:
//!
//! 1. Batches are sampled on the coordinating thread from a dedicated
//!    [`Xoshiro256`] substream of the seed — the sequence
//!    never observes worker scheduling.
//! 2. Batch assignment runs sharded over the batch with **frozen**
//!    centers: each sampled point's nearest center is a pure function of
//!    the last barrier's state.
//! 3. The fold ([`Centers::fold_point`]) replays sequentially in batch
//!    order at the barrier, and the partial center update
//!    ([`Centers::update_partial`]) walks centers in ascending index
//!    order.
//!
//! A **resumed** run ([`super::SphericalKMeans::warm_start`]) restores the
//! fold accumulators (sums, counts) bit-for-bit and fast-forwards the
//! batch-sampling substream past the epochs already taken, so
//! interrupted + resumed training draws exactly the batches — and folds
//! exactly the floating-point sequence — an uninterrupted run would have.
//!
//! **Truncation.** With `truncate = Some(m)` every
//! recomputed center keeps only its `m` largest-magnitude coordinates
//! (renormalized to the sphere), bounding each center's support as in
//! Knittel et al.'s sparsified centroids. Combined with the inverted-file
//! similarity kernel ([`crate::kmeans::kernel`] — which
//! [`KernelChoice::Auto`](super::KernelChoice) picks automatically once
//! `m/d` is small), the `m`-sparse invariant makes every batch similarity
//! cheaper: the postings index holds at most `m·k` entries, so an
//! all-centers pass costs `Σ_c∈row postings(c)` multiply-adds instead of
//! `nnz(row)·k`.
//!
//! One epoch draws `ceil(n / batch_size)` distinct-sample batches (one
//! corpus-worth); the run stops after the configured epochs, as soon as no
//! center moved more than `tol` (cosine distance) across a whole epoch, or
//! when an [`Observer`] breaks. A final sharded full assignment pass
//! produces the reported assignments and objective.
//!
//! ```no_run
//! use sphkm::data::synth::SynthConfig;
//! use sphkm::kmeans::{Engine, MiniBatchParams, SphericalKMeans};
//! let ds = SynthConfig::small_demo().generate(1);
//! let fitted = SphericalKMeans::new(8)
//!     .engine(Engine::MiniBatch(MiniBatchParams {
//!         batch_size: 256,
//!         epochs: 8,
//!         ..Default::default()
//!     }))
//!     .threads(0)
//!     .fit(&ds.matrix)
//!     .expect("valid configuration");
//! println!("approx objective = {}", fitted.objective());
//! ```

use super::kernel::DataShape;
use super::{
    Centers, IterSnapshot, IterStats, KMeansConfig, KMeansResult, Observer, RunStats, SimView,
    TrainState,
};
use crate::audit::{AuditViolation, AUDIT_ENABLED};
use crate::obs::{span::span_start, Phase};
use crate::runtime::parallel::{split_mut, Plan, Pool};
use crate::sparse::{CsrMatrix, DenseMatrix, RowSource};
use crate::util::rng::Xoshiro256;
use crate::util::timer::Stopwatch;
use std::ops::Range;

/// Substream index separating the batch-sampling RNG from every other
/// consumer of the master seed.
const BATCH_STREAM: u64 = 0x4D42_5348; // "MBSH"

/// Cluster `data` (rows must be unit-normalized) with the mini-batch
/// engine, seeding initial centers with [`KMeansConfig::init`].
#[deprecated(
    since = "0.2.0",
    note = "use `SphericalKMeans::fit` with `Engine::MiniBatch` (see the README migration table)"
)]
pub fn run(data: &CsrMatrix, cfg: &KMeansConfig) -> KMeansResult {
    let init = crate::init::seed_centers(data, cfg.k, &cfg.init, cfg.seed);
    minibatch_shim(data, init.centers, cfg)
}

/// Mini-batch clustering from explicit initial centers (rows will be
/// normalized).
#[deprecated(
    since = "0.2.0",
    note = "use `SphericalKMeans::fit` with `Engine::MiniBatch` and `warm_start_centers` \
            (see the README migration table)"
)]
pub fn run_with_centers(
    data: &CsrMatrix,
    initial_centers: DenseMatrix,
    cfg: &KMeansConfig,
) -> KMeansResult {
    minibatch_shim(data, initial_centers, cfg)
}

/// Shared body of the deprecated mini-batch shims: the old entry points'
/// assertions, then the consolidated [`fit_minibatch`] path (bit-identical
/// to the estimator — asserted by the `shims` integration suite).
fn minibatch_shim(data: &CsrMatrix, centers: DenseMatrix, cfg: &KMeansConfig) -> KMeansResult {
    assert_eq!(centers.rows(), cfg.k, "initial centers vs k");
    assert_eq!(centers.cols(), data.cols(), "center dimensionality");
    assert!(cfg.k >= 1, "need at least one cluster");
    assert!(cfg.batch_size >= 1, "batch size must be positive");
    let (result, _, violations) =
        fit_minibatch(RowSource::Mem(data), cfg, centers, None, 0, None);
    // The deprecated infallible entry points have no error channel; a
    // certification failure under the `audit` feature is a hard stop.
    if let Some(v) = violations.first() {
        panic!("{v}");
    }
    result
}

/// Run one mini-batch fit. The consolidated internal path behind
/// [`super::SphericalKMeans::fit`] and the deprecated shims above.
/// `resume` restores an interrupted run's accumulators (see the
/// [module docs](self)); `prior_steps` is the epoch count the restored
/// batch sampler fast-forwards past. The third return is the audit
/// violations collected at the epoch barriers (always empty without the
/// `audit` feature).
pub(crate) fn fit_minibatch(
    src: RowSource<'_>,
    cfg: &KMeansConfig,
    initial_centers: DenseMatrix,
    resume: Option<TrainState>,
    prior_steps: u64,
    mut obs: Option<&mut dyn Observer>,
) -> (KMeansResult, TrainState, Vec<AuditViolation>) {
    let fit_sw = Stopwatch::start();
    let n = src.rows();
    let k = cfg.k;
    let b = cfg.batch_size.min(n.max(1));
    let batches_per_epoch = n.div_ceil(b.max(1));
    // Resolve the similarity kernel from the problem shape; truncated
    // sparse centroids cap the center density, which is exactly the regime
    // the inverted-file backend exists for.
    let kernel = cfg.kernel.resolve(&DataShape::of_source(src, k, cfg.truncate));
    let resuming = resume.is_some();
    let (mut centers, mut assign) = match resume {
        Some(state) => (
            // Bit-for-bit restore of the fold accumulators; the centers
            // already satisfy any truncation invariant they were trained
            // under, so nothing is renormalized or re-truncated here.
            Centers::restore(initial_centers, state.sums, state.counts, kernel),
            state.assignments,
        ),
        None => {
            let mut centers = Centers::from_initial_for(initial_centers, kernel);
            if let Some(m) = cfg.truncate {
                // Establish the m-sparse invariant on the initial centers.
                centers.truncate_centers(m);
            }
            (centers, vec![0u32; n])
        }
    };
    // A corpus whose *largest* plan (the final full pass) is a single
    // shard can never use more than one worker — skip thread-pool
    // construction, as the exact engines do.
    let pool = Pool::new(if Plan::for_rows(n).len() <= 1 { 1 } else { cfg.threads });
    let mut rng = Xoshiro256::substream(cfg.seed, BATCH_STREAM);
    if resuming {
        // Fast-forward the sampling substream past the epochs already
        // taken, so the resumed run draws exactly the batches an
        // uninterrupted run would draw next. Each prior epoch consumed
        // `batches_per_epoch` deterministic draws. This replays the draws
        // (O(prior_epochs · n) RNG work, one corpus-worth of sampling per
        // prior epoch) — a deliberate trade: the `.spkm` format stays free
        // of RNG internals, and the cost is paid once per resume, before
        // any training.
        for _ in 0..prior_steps.saturating_mul(batches_per_epoch as u64) {
            let _ = rng.sample_distinct(n, b);
        }
    }
    let mut stats = RunStats::default();
    let mut basg = vec![0u32; b];
    let mut converged = false;
    let mut epochs_run = 0usize;
    // Audit trail (empty Vec never allocates; stays empty when off). The
    // input matrix is certified once up front — a CSR that breaks its own
    // invariants invalidates every similarity computed from it.
    let mut violations: Vec<AuditViolation> = Vec::new();
    if AUDIT_ENABLED {
        // Disk shards were length- and monotonicity-checked at open time;
        // the deep CSR invariant check applies to the in-memory backend.
        if let RowSource::Mem(data) = src {
            if let Err(v) = data.check_invariants() {
                violations.push(v);
            }
        }
    }

    for _epoch in 0..cfg.epochs {
        let sw = Stopwatch::start();
        let mut iter = IterStats::default();
        // Epoch-start snapshot for the movement-based convergence test.
        let snapshot = centers.centers().clone();
        for _batch in 0..batches_per_epoch {
            let batch = rng.sample_distinct(n, b);
            // Sharded batch assignment against frozen centers.
            let plan = Plan::for_rows(b);
            let sp = span_start();
            let outs = {
                let centers = &centers;
                let batch_ref: &[usize] = &batch;
                let mut works: Vec<(Range<usize>, &mut [u32])> =
                    Vec::with_capacity(plan.len());
                {
                    let shards = split_mut(&plan, 1, &mut basg);
                    for (r, a) in plan.ranges().iter().cloned().zip(shards) {
                        works.push((r, a));
                    }
                }
                let iteration = stats.iters.len();
                pool.run(works, |_, (range, asg)| {
                    let mut it = IterStats::default();
                    let mut viol: Vec<AuditViolation> = Vec::new();
                    let mut scratch = vec![0.0f64; k];
                    let mut view = SimView::new(src, centers, k);
                    for (li, pos) in range.enumerate() {
                        let (bj, _, _) = view.assign_top2(
                            batch_ref[pos],
                            iteration,
                            &mut it,
                            &mut viol,
                            &mut scratch,
                        );
                        asg[li] = bj as u32;
                    }
                    (it, viol)
                })
            };
            for (o, v) in outs {
                iter.absorb(&o);
                violations.extend(v);
            }
            iter.phases.record(Phase::Assignment, sp);
            // Sequential decayed-rate fold, in batch order, then a partial
            // center update touching only the folded centers.
            let sp = span_start();
            let mut rows = src.cursor();
            for (pos, &i) in batch.iter().enumerate() {
                let j = basg[pos];
                if assign[i] != j {
                    assign[i] = j;
                    iter.reassignments += 1;
                }
                centers.fold_point(rows.row(i), j as usize);
            }
            drop(rows);
            iter.sims_center_center += centers.update_partial(cfg.truncate);
            iter.phases.record(Phase::Update, sp);
            iter.phases
                .shift(Phase::Update, Phase::IndexRefresh, centers.take_refresh_ms());
        }
        // Largest per-center movement over the whole epoch, in cosine
        // distance (k center·center dots, charged).
        let mut shift = 0.0f64;
        for j in 0..k {
            let s = centers.centers().row_dot(j, &snapshot, j);
            shift = shift.max(1.0 - s);
        }
        iter.sims_center_center += k as u64;
        iter.wall_ms = sw.ms();
        stats.iters.push(iter);
        epochs_run += 1;
        if AUDIT_ENABLED {
            // Epoch barrier: re-verify the center bank. Truncated runs
            // deliberately break the sums↔centers coherence (the stored
            // center keeps only the m largest coordinates), so that one
            // check is relaxed for them.
            if let Err(v) = centers.check_invariants(cfg.truncate.is_some()) {
                violations.push(v.at_iteration(stats.iters.len() - 1));
            }
        }
        if shift <= cfg.tol {
            converged = true;
            notify(&mut obs, &stats, true, Some(shift), &violations, fit_sw.ms());
            break;
        }
        if notify(&mut obs, &stats, false, Some(shift), &violations, fit_sw.ms()) {
            break;
        }
    }

    // Final sharded full assignment pass: the reported clustering. The
    // objective accumulates per shard from the best similarity the pass
    // already computes (the shard grid is a pure function of `n`, so the
    // reduction tree — and the resulting bits — never depend on the
    // thread count).
    let mut obj = 0.0f64;
    {
        let sw = Stopwatch::start();
        let mut iter = IterStats::default();
        let plan = Plan::for_rows(n);
        let sp = span_start();
        let outs = {
            let centers = &centers;
            let mut works: Vec<(Range<usize>, &mut [u32])> = Vec::with_capacity(plan.len());
            {
                let shards = split_mut(&plan, 1, &mut assign);
                for (r, a) in plan.ranges().iter().cloned().zip(shards) {
                    works.push((r, a));
                }
            }
            let iteration = stats.iters.len();
            pool.run(works, |_, (range, asg)| {
                let mut it = IterStats::default();
                let mut viol: Vec<AuditViolation> = Vec::new();
                let mut scratch = vec![0.0f64; k];
                let mut shard_obj = 0.0f64;
                let mut view = SimView::new(src, centers, k);
                for (li, i) in range.enumerate() {
                    let (bj, best, _) =
                        view.assign_top2(i, iteration, &mut it, &mut viol, &mut scratch);
                    if asg[li] != bj as u32 {
                        asg[li] = bj as u32;
                        it.reassignments += 1;
                    }
                    shard_obj += 1.0 - best;
                }
                (it, shard_obj, viol)
            })
        };
        for (it, shard_obj, v) in outs {
            iter.absorb(&it);
            obj += shard_obj;
            violations.extend(v);
        }
        iter.phases.record(Phase::Assignment, sp);
        iter.wall_ms = sw.ms();
        stats.iters.push(iter);
        // The final pass is reported to the observer for completeness; the
        // run is over either way, so its stop request is moot.
        let _ = notify(&mut obs, &stats, converged, None, &violations, fit_sw.ms());
    }

    let state = TrainState {
        steps_done: prior_steps + epochs_run as u64,
        converged,
        assignments: assign.clone(),
        counts: centers.counts().to_vec(),
        sums: centers.sums().to_vec(),
        // Record the schedule this state was trained under, so a resume
        // can reproduce it (the sampler fast-forward depends on it).
        minibatch: Some(super::MiniBatchParams {
            batch_size: cfg.batch_size,
            epochs: cfg.epochs,
            tol: cfg.tol,
            truncate: cfg.truncate,
        }),
    };
    let result = KMeansResult {
        mean_similarity: 1.0 - obj / n.max(1) as f64,
        objective: obj,
        assignments: assign,
        kernel: centers.kernel(),
        centers: centers.centers().clone(),
        iterations: epochs_run,
        converged,
        stats,
    };
    (result, state, violations)
}

/// Deliver the newest stats entry to the observer (when one is attached);
/// returns `true` on an early-stop request.
fn notify(
    obs: &mut Option<&mut dyn Observer>,
    stats: &RunStats,
    converged: bool,
    center_shift: Option<f64>,
    audit_violations: &[AuditViolation],
    elapsed_ms: f64,
) -> bool {
    let Some(obs) = obs.as_deref_mut() else {
        return false;
    };
    let iteration = stats.iters.len() - 1;
    let snap = IterSnapshot {
        iteration,
        stats: &stats.iters[iteration],
        converged,
        center_shift,
        audit_violations,
        elapsed_ms,
        iter_ms: stats.iters[iteration].wall_ms,
    };
    obs.on_iteration(&snap).is_break()
}

#[cfg(test)]
mod tests {
    use super::super::{Engine, MiniBatchParams, SphericalKMeans};
    use crate::data::synth::SynthConfig;
    use crate::init::{seed_centers, InitMethod};

    fn minibatch(params: MiniBatchParams) -> SphericalKMeans {
        SphericalKMeans::new(6).engine(Engine::MiniBatch(params))
    }

    #[test]
    fn runs_and_reports_consistent_result() {
        let ds = SynthConfig::small_demo().generate(41);
        let r = minibatch(MiniBatchParams { batch_size: 64, epochs: 4, ..Default::default() })
            .seed(2)
            .fit(&ds.matrix)
            .unwrap();
        assert_eq!(r.assignments().len(), ds.matrix.rows());
        assert!(r.assignments().iter().all(|&a| (a as usize) < 6));
        assert!(r.iterations() <= 4);
        // One stats entry per epoch plus the final full pass.
        assert_eq!(r.stats().iters.len(), r.iterations() + 1);
        // The reported objective matches a recomputation from the result.
        let recomputed = crate::metrics::objective(&ds.matrix, r.assignments(), r.centers());
        assert!((recomputed - r.objective()).abs() < 1e-9 * (1.0 + r.objective()));
    }

    #[test]
    fn zero_epochs_degenerates_to_nearest_initial_center() {
        let ds = SynthConfig::small_demo().generate(43);
        let init = seed_centers(&ds.matrix, 5, &InitMethod::Uniform, 7);
        let r = SphericalKMeans::new(5)
            .engine(Engine::MiniBatch(MiniBatchParams { epochs: 0, ..Default::default() }))
            .warm_start_centers(init.centers.clone())
            .fit(&ds.matrix)
            .unwrap();
        assert_eq!(r.iterations(), 0);
        assert!(!r.converged());
        // Exactly the final full assignment: n·k similarities.
        assert_eq!(
            r.stats().iters.iter().map(|i| i.sims_point_center).sum::<u64>(),
            (ds.matrix.rows() * 5) as u64
        );
    }

    #[test]
    fn batch_size_larger_than_corpus_is_clamped() {
        let ds = SynthConfig::small_demo().generate(47);
        let r = SphericalKMeans::new(4)
            .engine(Engine::MiniBatch(MiniBatchParams {
                batch_size: 1 << 20,
                epochs: 2,
                ..Default::default()
            }))
            .seed(5)
            .fit(&ds.matrix)
            .unwrap();
        assert_eq!(r.assignments().len(), ds.matrix.rows());
    }
}
