//! Lightweight wall-clock timing helpers used by the experiment drivers.

use std::time::{Duration, Instant};

/// A simple stopwatch.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    /// Elapsed time since start.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Elapsed milliseconds as f64.
    pub fn ms(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }

    /// Restart and return the elapsed duration of the previous lap.
    /// Drift-free: the next lap starts from the same captured instant
    /// this lap ends at, so consecutive laps tile the timeline with no
    /// gap (a second `Instant::now()` read would leak the time between
    /// the two reads out of every lap).
    pub fn lap(&mut self) -> Duration {
        let now = Instant::now();
        let e = now.duration_since(self.start);
        self.start = now;
        e
    }
}

/// Summary statistics over repeated timing measurements.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimingStats {
    /// Number of samples.
    pub n: usize,
    /// Mean in milliseconds.
    pub mean_ms: f64,
    /// Minimum in milliseconds.
    pub min_ms: f64,
    /// Maximum in milliseconds.
    pub max_ms: f64,
    /// Sample standard deviation in milliseconds.
    pub std_ms: f64,
    /// Median in milliseconds.
    pub median_ms: f64,
}

impl TimingStats {
    /// Compute stats from raw millisecond samples. Degenerate inputs
    /// are well-defined instead of panicking or producing NaN: zero
    /// samples yield all-zero stats, a single sample has zero standard
    /// deviation.
    pub fn from_ms(samples: &[f64]) -> Self {
        if samples.is_empty() {
            return Self { n: 0, mean_ms: 0.0, min_ms: 0.0, max_ms: 0.0, std_ms: 0.0, median_ms: 0.0 };
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = if n % 2 == 1 {
            sorted[n / 2]
        } else {
            0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
        };
        Self {
            n,
            mean_ms: mean,
            min_ms: sorted[0],
            max_ms: sorted[n - 1],
            std_ms: var.sqrt(),
            median_ms: median,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let s = TimingStats::from_ms(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean_ms - 2.5).abs() < 1e-12);
        assert_eq!(s.min_ms, 1.0);
        assert_eq!(s.max_ms, 4.0);
        assert!((s.median_ms - 2.5).abs() < 1e-12);
        let expect_std = (((1.5f64).powi(2) * 2.0 + (0.5f64).powi(2) * 2.0) / 3.0).sqrt();
        assert!((s.std_ms - expect_std).abs() < 1e-12);
    }

    #[test]
    fn stats_single_sample() {
        let s = TimingStats::from_ms(&[5.0]);
        assert_eq!(s.std_ms, 0.0);
        assert_eq!(s.median_ms, 5.0);
        assert!(s.std_ms.is_finite() && s.mean_ms.is_finite());
    }

    #[test]
    fn stats_empty_is_all_zero() {
        let s = TimingStats::from_ms(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean_ms, 0.0);
        assert_eq!(s.min_ms, 0.0);
        assert_eq!(s.max_ms, 0.0);
        assert_eq!(s.std_ms, 0.0);
        assert_eq!(s.median_ms, 0.0);
    }

    #[test]
    fn laps_tile_the_timeline() {
        let mut sw = Stopwatch::start();
        let outer = Stopwatch::start();
        let a = sw.lap();
        let b = sw.lap();
        // Drift-free contract: consecutive laps cover the full elapsed
        // span with no gap, so their sum cannot exceed an enclosing
        // measurement taken after them.
        assert!(a + b <= outer.elapsed() + sw.elapsed());
    }

    #[test]
    fn stopwatch_monotone() {
        let mut sw = Stopwatch::start();
        let a = sw.lap();
        let b = sw.elapsed();
        assert!(b >= a || b.as_nanos() == 0 || a.as_nanos() > 0);
    }
}
