//! Spherical Simplified Elkan's algorithm (§5.1, after Newling & Fleuret
//! 2016): keeps the full `u(i,j)` bound matrix and `l(i)`, but drops the
//! center–center (`cc`/`s`) pruning tests — saving the `O(k²)`
//! center–center similarities per iteration at the cost of having to scan
//! all k bounds for every point. The paper finds this trade favorable on
//! high-dimensional data (Fig. 2b) and unfavorable for large k on
//! low-dimensional data (Fig. 1c/d).

use super::{Ctx, IterStats, KMeansConfig};
use crate::bounds::{update_lower_pre, update_upper_pre};
use crate::util::timer::Stopwatch;

pub(crate) fn run(ctx: &mut Ctx<'_>, cfg: &KMeansConfig) -> bool {
    let n = ctx.data.rows();
    let k = ctx.k;
    let mut l = vec![0.0f64; n];
    let mut u = vec![0.0f64; n * k];

    ctx.initial_assignment(true, |i, _bj, best, _second, sims| {
        l[i] = best;
        u[i * k..(i + 1) * k].copy_from_slice(sims);
    });
    ctx.stats.bound_bytes = (n + n * k) * std::mem::size_of::<f64>();

    for _ in 0..cfg.max_iter {
        let sw = Stopwatch::start();
        let mut iter = IterStats::default();

        let p = ctx.centers.p().to_vec();
        let sin_p: Vec<f64> = p.iter().map(|&v| crate::bounds::sin_from_cos(v)).collect();
        for i in 0..n {
            let a = ctx.assign[i] as usize;
            l[i] = update_lower_pre(l[i], p[a], sin_p[a]);
            let row = &mut u[i * k..(i + 1) * k];
            for (j, uij) in row.iter_mut().enumerate() {
                *uij = update_upper_pre(*uij, p[j], sin_p[j]);
            }
        }

        let mut moves = 0u64;
        for i in 0..n {
            let mut a = ctx.assign[i] as usize;
            let mut tight = false;
            for j in 0..k {
                if j == a {
                    continue;
                }
                if u[i * k + j] <= l[i] {
                    iter.bound_skips += 1;
                    continue;
                }
                if !tight {
                    l[i] = ctx.similarity(i, a, &mut iter);
                    tight = true;
                    if u[i * k + j] <= l[i] {
                        iter.bound_skips += 1;
                        continue;
                    }
                }
                let s = ctx.similarity(i, j, &mut iter);
                u[i * k + j] = s;
                if s > l[i] {
                    u[i * k + a] = l[i];
                    ctx.centers.apply_move(ctx.data.row(i), a, j);
                    a = j;
                    ctx.assign[i] = j as u32;
                    l[i] = s;
                    moves += 1;
                }
            }
        }

        iter.reassignments = moves;
        if moves == 0 {
            iter.wall_ms = sw.ms();
            ctx.stats.iters.push(iter);
            return true;
        }
        iter.sims_center_center += ctx.centers.update();
        iter.wall_ms = sw.ms();
        ctx.stats.iters.push(iter);
    }
    false
}
