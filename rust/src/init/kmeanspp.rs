//! Spherical k-means++ (§5.6): the first seed is uniform; each further
//! seed is sampled proportional to the dissimilarity `α − max_c ⟨x, c⟩`
//! of the point to its closest already-chosen center. For α = 1 this is
//! exactly proportional to the squared Euclidean distance on unit vectors
//! (the canonical k-means++ weighting); α = 1.5 is the offset for which
//! Endo & Miyamoto prove metric guarantees.
//!
//! `O(N·k)` total: the running `max_c ⟨x, c⟩` is cached per point and
//! refreshed with one sparse dot per point per new center (the "caching
//! the previous maximum" optimization the paper describes).

use crate::sparse::csr::RowView;
use crate::sparse::RowSource;
use crate::util::rng::Xoshiro256;

/// k-means++ seeding, optionally recording every point-to-seed similarity in
/// a row-major `N × k` matrix (`collect`) — the similarities are computed
/// anyway, which is exactly the §7 bound-pre-initialization synergy.
///
/// Generic over the row backend: the most recent seed is copied out as an
/// owned sparse vector and every refresh dot runs through the same
/// sorted-merge kernel, so the chosen rows — and the collected similarity
/// matrix — are bit-identical between memory and disk shards.
pub(crate) fn choose_collecting(
    src: RowSource<'_>,
    k: usize,
    alpha: f64,
    rng: &mut Xoshiro256,
    mut collect: Option<&mut [f32]>,
) -> (Vec<usize>, u64) {
    let n = src.rows();
    let mut rows = src.cursor();
    let mut chosen = Vec::with_capacity(k);
    let mut sims = 0u64;

    let first = rng.index(n);
    chosen.push(first);

    // Cached max similarity to any chosen center, per point.
    let mut max_sim = vec![f64::MIN; n];
    let mut weights = vec![0.0f64; n];
    let mut is_chosen = vec![false; n];
    is_chosen[first] = true;

    for _ in 1..k {
        // Refresh the cache with the most recently chosen center (owned
        // copy: the cursor's chunk buffer is about to be re-used by the
        // refresh scan).
        let c = rows.row_vec(*chosen.last().unwrap());
        let cv = RowView { indices: c.indices(), values: c.values() };
        let col = chosen.len() - 1;
        for i in 0..n {
            let s = rows.row(i).dot(&cv);
            if let Some(m) = collect.as_deref_mut() {
                m[i * k + col] = s as f32;
            }
            if s > max_sim[i] {
                max_sim[i] = s;
            }
        }
        sims += n as u64;
        for i in 0..n {
            // α − max sim, floored at 0; already-chosen points get weight 0
            // so α = 1.5 cannot re-pick them (α − 1 > 0 for the seed itself).
            weights[i] = if is_chosen[i] {
                0.0
            } else {
                (alpha - max_sim[i]).max(0.0)
            };
        }
        let next = match rng.weighted_index(&weights) {
            Some(i) => i,
            None => {
                // All weights zero (e.g. duplicate-heavy data): fall back to
                // a uniform unchosen row.
                let unchosen: Vec<usize> = (0..n).filter(|&i| !is_chosen[i]).collect();
                unchosen[rng.index(unchosen.len())]
            }
        };
        is_chosen[next] = true;
        chosen.push(next);
    }
    (chosen, sims)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{CsrMatrix, SparseVec};

    /// Three well-separated orthogonal groups: k-means++ should pick one
    /// seed from each group far more often than uniform would.
    fn orthogonal_groups() -> CsrMatrix {
        let mut rows = Vec::new();
        // 30 copies of e0, 30 of e1, 30 of e2 (with tiny per-row jitter on a
        // private dimension so rows are distinct).
        for g in 0..3u32 {
            for t in 0..30u32 {
                rows.push(SparseVec::from_pairs(
                    100,
                    vec![(g, 1.0), (10 + g * 30 + t, 0.05)],
                ));
            }
        }
        let mut m = CsrMatrix::from_rows(100, &rows);
        m.normalize_rows();
        m
    }

    #[test]
    fn plusplus_spreads_across_groups() {
        let data = orthogonal_groups();
        let mut hits = 0;
        let trials = 40;
        for seed in 0..trials {
            let mut rng = Xoshiro256::seed_from_u64(seed);
            let (chosen, _) = choose_collecting(RowSource::Mem(&data), 3, 1.0, &mut rng, None);
            let groups: std::collections::HashSet<usize> =
                chosen.iter().map(|&i| i / 30).collect();
            if groups.len() == 3 {
                hits += 1;
            }
        }
        // Uniform seeding would hit all three groups ~22% of the time;
        // k-means++ should nearly always.
        assert!(hits >= trials * 8 / 10, "only {hits}/{trials} spread runs");
    }

    #[test]
    fn weights_zero_for_chosen_points() {
        let data = orthogonal_groups();
        let mut rng = Xoshiro256::seed_from_u64(1);
        let (chosen, _) = choose_collecting(RowSource::Mem(&data), 10, 1.5, &mut rng, None);
        let set: std::collections::HashSet<_> = chosen.iter().collect();
        assert_eq!(set.len(), 10, "α=1.5 must not re-pick chosen seeds");
    }

    #[test]
    fn sims_accounting() {
        let data = orthogonal_groups();
        let mut rng = Xoshiro256::seed_from_u64(2);
        let (_, sims) = choose_collecting(RowSource::Mem(&data), 4, 1.0, &mut rng, None);
        assert_eq!(sims, (3 * data.rows()) as u64);
    }
}
