//! End-to-end text clustering: raw documents → tokenize/stem/filter →
//! TF-IDF → spherical k-means → top terms per cluster.
//!
//! By default runs on a small built-in three-theme corpus; point it at a
//! directory of `.txt` files to cluster your own documents:
//!
//! ```text
//! cargo run --release --example text_clustering -- [--dir path/] [--k 3]
//! ```

// Example code favours readable literal casts; the workspace clippy
// warnings on those patterns are aimed at library code.
#![allow(clippy::cast_possible_truncation, clippy::float_cmp)]

use sphkm::data::text::{demo_corpus, TextPipeline};
use sphkm::init::InitMethod;
use sphkm::kmeans::{SphericalKMeans, Variant};
use sphkm::util::cli::Args;

fn load_docs(args: &Args) -> Vec<String> {
    if let Some(dir) = args.get("dir") {
        let mut docs = Vec::new();
        let mut names = Vec::new();
        for entry in std::fs::read_dir(dir).expect("readable --dir") {
            let path = entry.expect("dir entry").path();
            if path.extension().map(|e| e == "txt").unwrap_or(false) {
                names.push(path.display().to_string());
                docs.push(std::fs::read_to_string(&path).unwrap_or_default());
            }
        }
        println!("loaded {} documents from {dir}", docs.len());
        docs
    } else {
        let docs = demo_corpus();
        println!("using the built-in demo corpus ({} docs, 3 themes)", docs.len());
        docs
    }
}

fn main() {
    let args = Args::from_env();
    let docs = load_docs(&args);
    let k: usize = args.get_or("k", 3).unwrap_or(3);

    let pipeline = TextPipeline {
        min_df: 1,
        max_df_frac: 0.7,
        ..Default::default()
    };
    let (ds, vocab) = pipeline.fit(&docs, "text");
    println!(
        "matrix: {} docs × {} terms after filtering",
        ds.matrix.rows(),
        ds.matrix.cols()
    );

    let r = SphericalKMeans::new(k)
        .variant(Variant::SimplifiedElkan)
        .init(InitMethod::KMeansPP { alpha: 1.0 })
        .seed(11)
        .fit(&ds.matrix)
        .expect("valid configuration");
    println!(
        "converged={} in {} iterations, mean cosine {:.3}\n",
        r.converged(),
        r.iterations(),
        r.mean_similarity()
    );

    // Top terms per cluster = largest center weights.
    for j in 0..k {
        let center = r.centers().row(j);
        let mut weighted: Vec<(usize, f32)> = center
            .iter()
            .enumerate()
            .filter(|(_, &w)| w > 0.0)
            .map(|(t, &w)| (t, w))
            .collect();
        weighted.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let members = r.assignments().iter().filter(|&&a| a as usize == j).count();
        let top: Vec<&str> = weighted
            .iter()
            .take(6)
            .map(|&(t, _)| vocab[t].as_str())
            .collect();
        println!("cluster {j} ({members} docs): {}", top.join(", "));
    }
}
