//! The versioned model slot: an epoch-counted [`QueryEngine`] shared by
//! every daemon connection, swapped atomically under concurrent queries.
//!
//! A [`ModelSlot`] holds the *current* engine behind an
//! `RwLock<Arc<EpochEngine>>`. Readers [`pin`](ModelSlot::pin) the slot —
//! a cheap `Arc` clone under the read lock — and hold the resulting
//! [`EpochEngine`] for the duration of one request, so a concurrent
//! [`publish`](ModelSlot::publish) can never invalidate in-flight work:
//! the swapped-out engine stays alive until its last pinned reader drops
//! it. Every published engine gets the next **epoch** number, and the
//! slot keeps a per-epoch query counter plus a swap counter, which is
//! what lets the daemon's `stats` RPC (and the swap-under-load bench)
//! attribute each answer to the exact model generation that produced it.
//!
//! The per-epoch counters live in the slot, not in the [`EpochEngine`]:
//! retired engines are dropped as soon as their last reader unpins, but
//! their query totals remain reportable forever.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use super::engine::QueryEngine;

/// A [`QueryEngine`] stamped with the slot epoch it was published under.
/// Handed out by [`ModelSlot::pin`]; immutable, so any number of threads
/// can query it concurrently.
#[derive(Debug)]
pub struct EpochEngine {
    epoch: u64,
    engine: QueryEngine,
}

impl EpochEngine {
    /// The slot epoch this engine was published under (0 = the engine
    /// the slot was created with).
    #[inline]
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// The engine itself.
    #[inline]
    pub fn engine(&self) -> &QueryEngine {
        &self.engine
    }
}

/// Atomically swappable, epoch-counted engine slot — see the
/// [module docs](self).
#[derive(Debug)]
pub struct ModelSlot {
    current: RwLock<Arc<EpochEngine>>,
    /// Queries answered per epoch, indexed by epoch number.
    queries: Mutex<Vec<u64>>,
    /// Number of [`ModelSlot::publish`] calls (hot swaps) so far.
    swaps: AtomicU64,
}

/// Compile-time proof that the serving types are safely shareable across
/// threads: the daemon hands one [`ModelSlot`] (and through it, pinned
/// [`QueryEngine`]s) to every connection thread. If a future change made
/// any of them `!Send`/`!Sync` — say an `Rc` or a raw pointer slipped
/// into the engine — this stops compiling instead of racing at runtime.
const fn assert_send_sync<T: Send + Sync>() {}
const _: () = {
    assert_send_sync::<QueryEngine>();
    assert_send_sync::<EpochEngine>();
    assert_send_sync::<ModelSlot>();
    assert_send_sync::<Arc<EpochEngine>>();
};

impl ModelSlot {
    /// A slot serving `engine` as epoch 0.
    pub fn new(engine: QueryEngine) -> Self {
        Self {
            current: RwLock::new(Arc::new(EpochEngine { epoch: 0, engine })),
            queries: Mutex::new(vec![0]),
            swaps: AtomicU64::new(0),
        }
    }

    /// Pin the current engine for one request: a cheap `Arc` clone under
    /// the read lock. The returned [`EpochEngine`] remains valid — and
    /// its answers remain attributable to its epoch — no matter how many
    /// swaps happen while the request is in flight.
    pub fn pin(&self) -> Arc<EpochEngine> {
        self.current.read().expect("slot lock").clone()
    }

    /// Atomically publish `engine` as the next epoch and return that
    /// epoch number. In-flight readers keep the engine they pinned;
    /// every subsequent [`ModelSlot::pin`] sees the new one.
    pub fn publish(&self, engine: QueryEngine) -> u64 {
        let mut cur = self.current.write().expect("slot lock");
        let epoch = cur.epoch + 1;
        *cur = Arc::new(EpochEngine { epoch, engine });
        // Counter slots exist for every epoch ever published, even ones
        // that never answer a query.
        let mut q = self.queries.lock().expect("slot counters");
        q.resize((epoch + 1) as usize, 0);
        drop(q);
        self.swaps.fetch_add(1, Ordering::Relaxed);
        epoch
    }

    /// Charge `n` answered queries to `epoch` (the epoch of the pinned
    /// engine that served them, which may already be swapped out).
    pub fn record_queries(&self, epoch: u64, n: u64) {
        let mut q = self.queries.lock().expect("slot counters");
        let idx = epoch as usize;
        if idx >= q.len() {
            q.resize(idx + 1, 0);
        }
        q[idx] += n;
    }

    /// The current epoch number.
    pub fn epoch(&self) -> u64 {
        self.current.read().expect("slot lock").epoch
    }

    /// Number of hot swaps ([`ModelSlot::publish`] calls) so far.
    pub fn swaps(&self) -> u64 {
        self.swaps.load(Ordering::Relaxed)
    }

    /// Per-epoch query totals as `(epoch, queries)` pairs, oldest first.
    pub fn epoch_queries(&self) -> Vec<(u64, u64)> {
        self.queries
            .lock()
            .expect("slot counters")
            .iter()
            .enumerate()
            .map(|(e, &n)| (e as u64, n))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Model, TrainingMeta};
    use crate::serve::{ServeConfig, ServeMode};
    use crate::sparse::{CsrMatrix, DenseMatrix, SparseVec};

    fn meta(seed: u64) -> TrainingMeta {
        TrainingMeta {
            variant: "Standard".into(),
            kernel: "gather".into(),
            iterations: 1,
            objective: 0.0,
            seed,
        }
    }

    /// A 2-center engine whose centers are the axis pair rotated by
    /// `which`, so different "generations" give different answers.
    fn engine(which: u64) -> QueryEngine {
        let centers = if which % 2 == 0 {
            DenseMatrix::from_vec(2, 3, vec![1.0, 0.0, 0.0, 0.0, 1.0, 0.0])
        } else {
            DenseMatrix::from_vec(2, 3, vec![0.0, 1.0, 0.0, 0.0, 0.0, 1.0])
        };
        QueryEngine::new(
            Model::new(centers, meta(which)),
            &ServeConfig { mode: ServeMode::Exhaustive, threads: 1 },
        )
    }

    fn probe() -> CsrMatrix {
        CsrMatrix::from_rows(3, &[SparseVec::from_pairs(3, vec![(1, 1.0)])])
    }

    #[test]
    fn publish_advances_epoch_and_counters() {
        let slot = ModelSlot::new(engine(0));
        assert_eq!(slot.epoch(), 0);
        assert_eq!(slot.swaps(), 0);
        let pinned = slot.pin();
        assert_eq!(pinned.epoch(), 0);
        assert_eq!(slot.publish(engine(1)), 1);
        assert_eq!(slot.publish(engine(2)), 2);
        assert_eq!(slot.epoch(), 2);
        assert_eq!(slot.swaps(), 2);
        // The pre-swap pin still answers with its own generation.
        let (top, stats) = pinned.engine().top_p_batch(&probe(), 1);
        assert_eq!(top[0][0].0, 1, "epoch-0 centers: e1 query hits center 1");
        slot.record_queries(pinned.epoch(), stats.queries);
        slot.record_queries(slot.epoch(), 5);
        assert_eq!(slot.epoch_queries(), vec![(0, 1), (1, 0), (2, 5)]);
    }

    /// The TSan target: readers pin and query while a writer publishes.
    /// Every answer must be internally consistent with the epoch that
    /// served it — a torn swap would pair an old epoch with new centers
    /// (or race outright under ThreadSanitizer).
    #[test]
    fn concurrent_pins_survive_swaps() {
        let slot = Arc::new(ModelSlot::new(engine(0)));
        let data = probe();
        let readers: u64 = 3;
        let queries_each: u64 = 60;
        std::thread::scope(|s| {
            for _ in 0..readers {
                let slot = Arc::clone(&slot);
                let data = data.clone();
                s.spawn(move || {
                    for _ in 0..queries_each {
                        let pinned = slot.pin();
                        let (top, stats) = pinned.engine().top_p_batch(&data, 1);
                        // Even epochs serve centers {e0,e1}: the e1 probe
                        // hits center 1. Odd epochs serve {e1,e2}: it
                        // hits center 0. Any other pairing is a tear.
                        let expect = if pinned.epoch() % 2 == 0 { 1 } else { 0 };
                        assert_eq!(top[0][0].0, expect, "epoch {}", pinned.epoch());
                        slot.record_queries(pinned.epoch(), stats.queries);
                    }
                });
            }
            let slot = Arc::clone(&slot);
            s.spawn(move || {
                for gen in 1..=6u64 {
                    slot.publish(engine(gen));
                    std::thread::yield_now();
                }
            });
        });
        assert_eq!(slot.swaps(), 6);
        assert_eq!(slot.epoch(), 6);
        let counted: u64 = slot.epoch_queries().iter().map(|&(_, n)| n).sum();
        assert_eq!(counted, readers * queries_each, "every query attributed");
    }
}
