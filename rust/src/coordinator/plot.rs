//! Terminal ASCII plots for the figure drivers (no plotting libraries
//! offline). Renders multiple named series as a braille-free, monospace
//! line chart with a log-scale option — enough to eyeball the *shape* of
//! Fig. 1/Fig. 2 (who wins, where the crossovers are).

/// One named series of (x, y) points.
#[derive(Debug, Clone)]
pub struct Series {
    /// Legend label.
    pub name: String,
    /// Data points (x ascending).
    pub points: Vec<(f64, f64)>,
}

/// Plot configuration.
#[derive(Debug, Clone)]
pub struct PlotCfg {
    /// Total chart width in characters (plot area excludes the y-axis gutter).
    pub width: usize,
    /// Plot height in rows.
    pub height: usize,
    /// Log₁₀-scale the y axis (run times spanning decades).
    pub log_y: bool,
    /// Chart title.
    pub title: String,
}

impl Default for PlotCfg {
    fn default() -> Self {
        Self { width: 72, height: 18, log_y: false, title: String::new() }
    }
}

const MARKS: &[char] = &['*', 'o', '+', 'x', '#', '@', '%', '&'];

/// Render the series into a string.
pub fn render(series: &[Series], cfg: &PlotCfg) -> String {
    let mut out = String::new();
    if series.iter().all(|s| s.points.is_empty()) {
        return "(no data)\n".to_string();
    }
    let ys = |y: f64| -> f64 {
        if cfg.log_y {
            y.max(1e-12).log10()
        } else {
            y
        }
    };
    let (mut xmin, mut xmax) = (f64::MAX, f64::MIN);
    let (mut ymin, mut ymax) = (f64::MAX, f64::MIN);
    for s in series {
        for &(x, y) in &s.points {
            xmin = xmin.min(x);
            xmax = xmax.max(x);
            ymin = ymin.min(ys(y));
            ymax = ymax.max(ys(y));
        }
    }
    if (xmax - xmin).abs() < 1e-12 {
        xmax = xmin + 1.0;
    }
    if (ymax - ymin).abs() < 1e-12 {
        ymax = ymin + 1.0;
    }
    let w = cfg.width.max(16);
    let h = cfg.height.max(4);
    let mut grid = vec![vec![' '; w]; h];
    for (si, s) in series.iter().enumerate() {
        let mark = MARKS[si % MARKS.len()];
        // Interpolate between consecutive points so lines are visible.
        for win in s.points.windows(2) {
            let (x0, y0) = win[0];
            let (x1, y1) = win[1];
            let steps = w * 2;
            for t in 0..=steps {
                let f = t as f64 / steps as f64;
                let x = x0 + (x1 - x0) * f;
                let y = ys(y0) + (ys(y1) - ys(y0)) * f;
                let cx = ((x - xmin) / (xmax - xmin) * (w - 1) as f64).round() as usize;
                let cy = ((y - ymin) / (ymax - ymin) * (h - 1) as f64).round() as usize;
                let row = h - 1 - cy.min(h - 1);
                let col = cx.min(w - 1);
                if grid[row][col] == ' ' || t == 0 || t == steps {
                    grid[row][col] = mark;
                }
            }
        }
        if s.points.len() == 1 {
            let (x, y) = s.points[0];
            let cx = ((x - xmin) / (xmax - xmin) * (w - 1) as f64).round() as usize;
            let cy = ((ys(y) - ymin) / (ymax - ymin) * (h - 1) as f64).round() as usize;
            grid[h - 1 - cy.min(h - 1)][cx.min(w - 1)] = mark;
        }
    }
    if !cfg.title.is_empty() {
        out.push_str(&format!("  {}\n", cfg.title));
    }
    let fmt_y = |v: f64| -> String {
        let v = if cfg.log_y { 10f64.powf(v) } else { v };
        if v.abs() >= 1000.0 {
            format!("{:>9.0}", v)
        } else {
            format!("{:>9.2}", v)
        }
    };
    for (r, row) in grid.iter().enumerate() {
        let yv = ymax - (ymax - ymin) * r as f64 / (h - 1) as f64;
        let label = if r == 0 || r == h - 1 || r == h / 2 {
            fmt_y(yv)
        } else {
            " ".repeat(9)
        };
        out.push_str(&format!("{label} |{}\n", row.iter().collect::<String>()));
    }
    out.push_str(&format!("{} +{}\n", " ".repeat(9), "-".repeat(w)));
    out.push_str(&format!(
        "{}  {:<12}{}{:>12}\n",
        " ".repeat(9),
        format!("{xmin:.0}"),
        " ".repeat(w.saturating_sub(24)),
        format!("{xmax:.0}")
    ));
    // Legend.
    out.push_str("  legend: ");
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!("{}={}  ", MARKS[si % MARKS.len()], s.name));
    }
    out.push('\n');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn series() -> Vec<Series> {
        vec![
            Series {
                name: "a".into(),
                points: vec![(2.0, 10.0), (10.0, 100.0), (100.0, 1000.0)],
            },
            Series {
                name: "b".into(),
                points: vec![(2.0, 20.0), (10.0, 50.0), (100.0, 200.0)],
            },
        ]
    }

    #[test]
    fn renders_marks_and_legend() {
        let cfg = PlotCfg { title: "test".into(), ..Default::default() };
        let r = render(&series(), &cfg);
        assert!(r.contains('*'));
        assert!(r.contains('o'));
        assert!(r.contains("legend: *=a  o=b"));
        assert!(r.contains("test"));
        assert!(r.lines().count() > 18);
    }

    #[test]
    fn log_scale_compresses() {
        let cfg = PlotCfg { log_y: true, ..Default::default() };
        let r = render(&series(), &cfg);
        assert!(r.contains('*'));
    }

    #[test]
    fn empty_and_single_point_are_safe() {
        let cfg = PlotCfg::default();
        assert_eq!(render(&[], &cfg), "(no data)\n");
        let s = vec![Series { name: "p".into(), points: vec![(1.0, 1.0)] }];
        let r = render(&s, &cfg);
        assert!(r.contains('*'));
    }

    #[test]
    fn flat_series_do_not_divide_by_zero() {
        let s = vec![Series { name: "f".into(), points: vec![(1.0, 5.0), (2.0, 5.0)] }];
        let r = render(&s, &PlotCfg::default());
        assert!(r.contains('*'));
    }
}
