//! Minimal command-line argument parsing (the offline registry has no
//! `clap`). Supports `--key value`, `--key=value`, boolean `--flag`, and
//! positional arguments, with typed getters and a usage renderer.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Positional arguments in order.
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    present: Vec<String>,
}

/// Error type for argument access.
#[derive(Debug, thiserror::Error)]
pub enum ArgError {
    /// A value failed to parse as the requested type.
    #[error("invalid value for --{0}: {1:?}")]
    Invalid(String, String),
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Self {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                    out.present.push(k.to_string());
                } else {
                    // `--key value` unless the next token is another flag.
                    let key = stripped.to_string();
                    let take_value = it
                        .peek()
                        .map(|n| !n.starts_with("--"))
                        .unwrap_or(false);
                    if take_value {
                        out.flags.insert(key.clone(), it.next().unwrap());
                    } else {
                        out.flags.insert(key.clone(), "true".to_string());
                    }
                    out.present.push(key);
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Whether `--key` appeared at all.
    pub fn has(&self, key: &str) -> bool {
        self.present.iter().any(|k| k == key)
    }

    /// Raw string value of `--key`.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// Typed value of `--key`, falling back to `default`.
    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, ArgError> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| ArgError::Invalid(key.to_string(), v.clone())),
        }
    }

    /// Boolean flag: present without value, or with true/false value.
    pub fn flag(&self, key: &str) -> bool {
        match self.flags.get(key).map(|s| s.as_str()) {
            Some("false") | Some("0") | Some("no") => false,
            Some(_) => true,
            None => false,
        }
    }

    /// Comma-separated list of typed values for `--key`.
    pub fn list<T: std::str::FromStr>(&self, key: &str) -> Result<Option<Vec<T>>, ArgError> {
        match self.flags.get(key) {
            None => Ok(None),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim()
                        .parse()
                        .map_err(|_| ArgError::Invalid(key.to_string(), v.clone()))
                })
                .collect::<Result<Vec<_>, _>>()
                .map(Some),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn positional_and_flags() {
        let a = parse("cluster --k 10 --algo elkan data.svm");
        assert_eq!(a.positional, vec!["cluster", "data.svm"]);
        assert_eq!(a.get("k"), Some("10"));
        assert_eq!(a.get("algo"), Some("elkan"));
    }

    #[test]
    fn equals_syntax_and_defaults() {
        let a = parse("--k=25 --scale=0.5");
        assert_eq!(a.get_or("k", 0usize).unwrap(), 25);
        assert!((a.get_or("scale", 1.0f64).unwrap() - 0.5).abs() < 1e-12);
        assert_eq!(a.get_or("seed", 42u64).unwrap(), 42);
    }

    #[test]
    fn boolean_flags() {
        let a = parse("--verbose --quiet=false --k 3");
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert!(!a.flag("absent"));
        assert!(a.has("quiet"));
        assert!(!a.has("absent"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("--verbose --k 7");
        assert!(a.flag("verbose"));
        assert_eq!(a.get_or("k", 0usize).unwrap(), 7);
    }

    #[test]
    fn lists() {
        let a = parse("--ks 2,10,20,50");
        assert_eq!(a.list::<usize>("ks").unwrap().unwrap(), vec![2, 10, 20, 50]);
        assert!(a.list::<usize>("absent").unwrap().is_none());
        let bad = parse("--ks 2,x");
        assert!(bad.list::<usize>("ks").is_err());
    }

    #[test]
    fn invalid_value_errors() {
        let a = parse("--k notanumber");
        assert!(a.get_or("k", 0usize).is_err());
    }
}
