//! The pluggable point×center similarity-kernel layer.
//!
//! Every similarity the bounds cannot prune lands in an all-centers pass;
//! this module owns the backends that compute it and the heuristic that
//! picks one:
//!
//! | Backend | Memory | Multiply-adds per all-k pass | Sweet spot |
//! |---|---|---|---|
//! | [`Kernel::Dense`] | d×k f32 transpose | `nnz(row)·k` (contiguous, vectorizes) | dense-ish centers, modest d·k |
//! | [`Kernel::Gather`] | none | `nnz(row)·k` (k gather dots) | paper-faithful cost model |
//! | [`Kernel::Inverted`] | postings = nnz(centers) | `Σ_c∈row postings(c)` | sparse centers, huge d·k |
//! | [`Kernel::Pruned`] | postings + maxw table | `≤ Σ_c∈row postings(c)` walked + survivors rescored | sparse centers **and** many clusters |
//!
//! The inverted-file backend ([`crate::sparse::InvertedIndex`]) skips every
//! (point, center) pair that shares no term — the SIVF idea (Aoyama &
//! Saito, arXiv:2103.16141) — and avoids materializing the d×k transpose
//! altogether, which for a 100k-term vocabulary at k = 1000 is a 400 MB
//! allocation the Dense backend cannot do without.
//!
//! The **pruned backend** (the `pruned` submodule of [`super`]) walks the
//! same postings in
//! MaxScore order (descending `|q_c|·maxw[c]`) with suffix upper bounds,
//! stops once the candidate set is small, and re-scores only the
//! survivors exactly — composing the inverted file with per-point
//! similarity bounds the way Aoyama & Saito (arXiv:2411.11300) accelerate
//! the training assignment itself. The bounds only ever decide *which*
//! centers get an exact score, never what the score is, so results stay
//! bit-identical to Dense/Inverted while the madds drop further.
//!
//! **Exactness.** The Dense and Inverted backends accumulate each center's
//! `f64` sum in ascending dimension order of the row's non-zeros, so their
//! results are **bit-identical** to each other (terms the inverted file
//! skips are exact ±0.0 products, which cannot change a
//! `+0.0`-initialized accumulator) — and therefore so are assignments,
//! objectives, and pruning statistics, for every thread count. The
//! `kernel_equivalence` test suite asserts this across densities and
//! truncation settings. The Gather backend reuses the unrolled gather dot
//! the pruned variants charge for selective similarities; its four-lane
//! summation tree differs, so it agrees to within summation-order
//! rounding rather than bitwise.
//!
//! Selection is configured through [`crate::kmeans::KMeansConfig::kernel`]
//! (CLI `--kernel`): [`KernelChoice::Auto`] resolves per run from the
//! problem shape via [`KernelChoice::resolve`]; `dense`, `gather`, and
//! `inverted` force a backend. The `bench_kernel` benchmark measures the
//! Dense/Inverted crossover on synthetic text-like data.

use crate::sparse::csr::RowView;
use crate::sparse::{CsrMatrix, DenseMatrix, RowSource};

/// Which similarity kernel to use, as configured (CLI `--kernel`, sweep
/// `kernel =`, [`crate::kmeans::KMeansConfig::kernel`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum KernelChoice {
    /// Pick per run from the problem shape ([`KernelChoice::resolve`]):
    /// the inverted file when the centers are expected to stay sparse;
    /// otherwise the dense transpose, degrading to gather when the d×k
    /// footprint is prohibitive.
    #[default]
    Auto,
    /// The d×k transposed-centers kernel (contiguous reads, vectorizes).
    Dense,
    /// Per-center gather dots — no derived structure at all. This is the
    /// paper's cost model: identical per-similarity work to the pruned
    /// variants' selective computations (c.f. Kriegel et al., "are we
    /// comparing algorithms or implementations?"), which is why the
    /// experiment drivers default to it.
    Gather,
    /// The inverted-file (CSC postings) kernel over sparse centers.
    Inverted,
    /// The bound-pruned inverted-file kernel: a MaxScore-ordered postings
    /// walk with suffix upper bounds that exactly re-scores only the
    /// surviving candidates. Bit-identical to Dense/Inverted.
    Pruned,
}

/// A resolved similarity backend — what [`KernelChoice`] becomes once the
/// problem shape is known. Stored by [`super::Centers`], which maintains
/// exactly the derived structure its backend needs (the d×k transpose,
/// the postings index, or nothing).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Kernel {
    /// Transposed-centers fast path.
    Dense,
    /// Per-center gather dots.
    Gather,
    /// Inverted-file postings walk.
    Inverted,
    /// Bound-pruned inverted-file walk (MaxScore order + suffix bounds,
    /// exact rescore of survivors).
    Pruned,
}

/// Auto picks the inverted file below this estimated center density: the
/// postings walk trades the dense kernel's contiguous SIMD reads for
/// skipped work, which by measurement (`bench_kernel`) pays off once most
/// center coordinates are zero. Deliberately conservative.
const AUTO_DENSITY_CUTOFF: f64 = 0.15;

/// Auto refuses to materialize a d×k f32 transpose larger than this.
/// Above the density cutoff the fallback is the zero-memory gather path,
/// not the inverted file — a postings index over *dense* centers stores
/// the same d·k entries at triple the bytes plus per-refresh list shifts.
const AUTO_FOOTPRINT_BYTES: usize = 256 << 20;

/// Below the density cutoff, Auto upgrades the inverted file to the
/// bound-pruned walk once there are at least this many clusters: the
/// MaxScore suffix bounds prune *centers*, so their bookkeeping (term
/// sort, checkpoint counts, survivor rescore) only amortizes when there
/// are enough centers to prune. At tiny k the plain postings walk is
/// already near-optimal.
const AUTO_PRUNED_MIN_K: usize = 8;

/// The problem-shape statistics the Auto heuristic reads. A pure function
/// of the inputs — never of runtime state — so the resolved kernel is
/// deterministic for a given (data, config) pair.
#[derive(Debug, Clone, Copy)]
pub struct DataShape {
    /// Dimensionality (columns).
    pub dims: usize,
    /// Total data non-zeros.
    pub nnz: usize,
    /// Number of clusters.
    pub k: usize,
    /// Center truncation (top-m coordinates), if configured.
    pub truncate: Option<usize>,
}

impl DataShape {
    /// Collect the shape of one clustering problem.
    pub fn of(data: &CsrMatrix, k: usize, truncate: Option<usize>) -> Self {
        Self::of_source(RowSource::Mem(data), k, truncate)
    }

    /// Collect the shape of one clustering problem from either data
    /// backend ([`RowSource`]) — the shape statistics (dims, nnz) are
    /// header fields of the shard store, so no row data is read.
    pub fn of_source(src: RowSource<'_>, k: usize, truncate: Option<usize>) -> Self {
        Self {
            dims: src.cols(),
            nnz: src.nnz(),
            k,
            truncate,
        }
    }

    /// Collect the shape of a *trained* model whose center non-zeros are
    /// known exactly (`center_nnz` = total stored coordinates across the
    /// k centers) — what [`crate::serve`] feeds the Auto heuristic when
    /// it decides between the pruned inverted-file traversal and the
    /// exhaustive gather pass. Setting `nnz = center_nnz` makes
    /// [`DataShape::est_center_density`]'s `nnz/k` union bound collapse
    /// to the *actual* per-center support.
    pub fn of_centers(dims: usize, k: usize, center_nnz: usize) -> Self {
        Self { dims, nnz: center_nnz, k, truncate: None }
    }

    /// Upper estimate of the converged centers' density: a center's
    /// support is at most the summed nnz of its points (`≈ nnz/k` under
    /// balanced clusters, the union bound), at most `d`, and at most the
    /// truncation budget `m` when sparse centroids are configured.
    pub fn est_center_density(&self) -> f64 {
        if self.dims == 0 {
            return 1.0;
        }
        let mut support = self.dims.min(self.nnz / self.k.max(1) + 1);
        if let Some(m) = self.truncate {
            if m > 0 {
                support = support.min(m);
            }
        }
        support as f64 / self.dims as f64
    }

    /// Bytes of the d×k f32 transpose the Dense backend would allocate.
    pub fn transpose_bytes(&self) -> usize {
        self.dims
            .saturating_mul(self.k)
            .saturating_mul(std::mem::size_of::<f32>())
    }
}

impl KernelChoice {
    /// Resolve the configured choice against a problem shape. Explicit
    /// choices pass through. `Auto` takes the inverted file when the
    /// estimated center density falls under [`AUTO_DENSITY_CUTOFF`] —
    /// upgraded to the bound-pruned walk at [`AUTO_PRUNED_MIN_K`] or more
    /// clusters, where per-center pruning has something to prune; at
    /// higher density it takes the dense transpose, unless that footprint
    /// exceeds [`AUTO_FOOTPRINT_BYTES`] — for *dense* centers the postings
    /// index would be even larger than the transpose it refused, so the
    /// oversized case falls back to the zero-memory gather path.
    pub fn resolve(self, shape: &DataShape) -> Kernel {
        match self {
            KernelChoice::Dense => Kernel::Dense,
            KernelChoice::Gather => Kernel::Gather,
            KernelChoice::Inverted => Kernel::Inverted,
            KernelChoice::Pruned => Kernel::Pruned,
            KernelChoice::Auto => {
                if shape.est_center_density() <= AUTO_DENSITY_CUTOFF {
                    if shape.k >= AUTO_PRUNED_MIN_K {
                        Kernel::Pruned
                    } else {
                        Kernel::Inverted
                    }
                } else if shape.transpose_bytes() > AUTO_FOOTPRINT_BYTES {
                    Kernel::Gather
                } else {
                    Kernel::Dense
                }
            }
        }
    }

    /// Display name (CLI/report spelling).
    pub fn name(&self) -> &'static str {
        match self {
            KernelChoice::Auto => "auto",
            KernelChoice::Dense => "dense",
            KernelChoice::Gather => "gather",
            KernelChoice::Inverted => "inverted",
            KernelChoice::Pruned => "pruned",
        }
    }
}

impl Kernel {
    /// Display name (report spelling).
    pub fn name(&self) -> &'static str {
        match self {
            Kernel::Dense => "dense",
            Kernel::Gather => "gather",
            Kernel::Inverted => "inverted",
            Kernel::Pruned => "pruned",
        }
    }
}

impl std::fmt::Display for KernelChoice {
    /// The CLI/report spelling of [`KernelChoice::name`]; round-trips
    /// through [`FromStr`](std::str::FromStr).
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::fmt::Display for Kernel {
    /// The report spelling of [`Kernel::name`].
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for KernelChoice {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(KernelChoice::Auto),
            "dense" | "transpose" => Ok(KernelChoice::Dense),
            "gather" | "dots" => Ok(KernelChoice::Gather),
            "inverted" | "ivf" | "csc" => Ok(KernelChoice::Inverted),
            "pruned" | "maxscore" => Ok(KernelChoice::Pruned),
            other => Err(format!("unknown kernel: {other}")),
        }
    }
}

/// Dense-transpose backend: per non-zero of the row, the k center
/// coordinates are contiguous in the d×k transpose `t`, so the inner loop
/// vectorizes. `f64` accumulators (exactness), contiguous f32 reads
/// (speed). Returns the multiply-adds performed (`nnz(row)·k`).
#[inline]
pub(crate) fn sims_transposed(t: &DenseMatrix, k: usize, row: RowView<'_>, out: &mut [f64]) -> u64 {
    debug_assert_eq!(out.len(), k);
    for o in out.iter_mut() {
        *o = 0.0;
    }
    let t = t.data();
    for (t_i, &v) in row.indices.iter().zip(row.values.iter()) {
        let base = *t_i as usize * k;
        let col = &t[base..base + k];
        let v = v as f64;
        for (o, &cv) in out.iter_mut().zip(col.iter()) {
            *o += v * cv as f64;
        }
    }
    (row.nnz() * k) as u64
}

/// Gather backend: k separate sparse×dense dots against the center rows —
/// the same per-similarity machinery the pruned variants use selectively.
/// Returns the multiply-adds performed (`nnz(row)·k`).
#[inline]
pub(crate) fn sims_gather(centers: &DenseMatrix, row: RowView<'_>, out: &mut [f64]) -> u64 {
    debug_assert_eq!(out.len(), centers.rows());
    for (j, o) in out.iter_mut().enumerate() {
        *o = row.dot_dense(centers.row(j));
    }
    (row.nnz() * centers.rows()) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parsing_and_names() {
        assert_eq!("auto".parse::<KernelChoice>().unwrap(), KernelChoice::Auto);
        assert_eq!("Dense".parse::<KernelChoice>().unwrap(), KernelChoice::Dense);
        assert_eq!(
            "transpose".parse::<KernelChoice>().unwrap(),
            KernelChoice::Dense
        );
        assert_eq!(
            "gather".parse::<KernelChoice>().unwrap(),
            KernelChoice::Gather
        );
        assert_eq!(
            "inverted".parse::<KernelChoice>().unwrap(),
            KernelChoice::Inverted
        );
        assert_eq!("IVF".parse::<KernelChoice>().unwrap(), KernelChoice::Inverted);
        assert_eq!("pruned".parse::<KernelChoice>().unwrap(), KernelChoice::Pruned);
        assert_eq!(
            "maxscore".parse::<KernelChoice>().unwrap(),
            KernelChoice::Pruned
        );
        assert!("nope".parse::<KernelChoice>().is_err());
        for c in [
            KernelChoice::Auto,
            KernelChoice::Dense,
            KernelChoice::Gather,
            KernelChoice::Inverted,
            KernelChoice::Pruned,
        ] {
            assert!(!c.name().is_empty());
            // Display ↔ FromStr round trip, exhaustively.
            assert_eq!(c.to_string(), c.name());
            assert_eq!(c.to_string().parse::<KernelChoice>().unwrap(), c);
        }
        for k in [Kernel::Dense, Kernel::Gather, Kernel::Inverted, Kernel::Pruned] {
            assert_eq!(k.to_string(), k.name());
        }
        assert_eq!(KernelChoice::default(), KernelChoice::Auto);
    }

    #[test]
    fn explicit_choices_pass_through() {
        let shape = DataShape { dims: 10, nnz: 100, k: 2, truncate: None };
        assert_eq!(KernelChoice::Dense.resolve(&shape), Kernel::Dense);
        assert_eq!(KernelChoice::Gather.resolve(&shape), Kernel::Gather);
        assert_eq!(KernelChoice::Inverted.resolve(&shape), Kernel::Inverted);
        assert_eq!(KernelChoice::Pruned.resolve(&shape), Kernel::Pruned);
    }

    #[test]
    fn auto_prefers_dense_on_densifying_centers() {
        // Small vocabulary, many points per cluster: centers densify
        // (§5.2 of the paper), the transpose wins.
        let shape = DataShape { dims: 800, nnz: 400_000, k: 8, truncate: None };
        assert!(shape.est_center_density() > 0.5);
        assert_eq!(KernelChoice::Auto.resolve(&shape), Kernel::Dense);
    }

    #[test]
    fn auto_prefers_pruned_on_sparse_and_gather_on_oversized_problems() {
        // 100k-term vocabulary: per-cluster mass covers a sliver of it,
        // and 256 clusters give the MaxScore bounds plenty to prune.
        let sparse = DataShape {
            dims: 100_000,
            nnz: 3_000_000,
            k: 256,
            truncate: None,
        };
        assert!(sparse.est_center_density() < AUTO_DENSITY_CUTOFF);
        assert_eq!(KernelChoice::Auto.resolve(&sparse), Kernel::Pruned);
        // At tiny k the plain postings walk is already near-optimal: the
        // same sparse shape with few clusters keeps the inverted file.
        let sparse_small_k = DataShape { k: AUTO_PRUNED_MIN_K - 1, ..sparse };
        assert!(sparse_small_k.est_center_density() < AUTO_DENSITY_CUTOFF);
        assert_eq!(
            KernelChoice::Auto.resolve(&sparse_small_k),
            Kernel::Inverted
        );
        // Truncated centers cap the density regardless of the data.
        let truncated = DataShape {
            dims: 20_000,
            nnz: 100_000_000,
            k: 64,
            truncate: Some(128),
        };
        assert!(truncated.est_center_density() <= 128.0 / 20_000.0 + 1e-12);
        assert_eq!(KernelChoice::Auto.resolve(&truncated), Kernel::Pruned);
        // Footprint guard at *high* density: the transpose is too large to
        // materialize, and a postings index over dense centers would be
        // larger still — Auto falls back to the zero-memory gather path.
        let huge = DataShape {
            dims: 500_000,
            nnz: usize::MAX / 2,
            k: 1_000,
            truncate: None,
        };
        assert!(huge.est_center_density() > AUTO_DENSITY_CUTOFF);
        assert!(huge.transpose_bytes() > AUTO_FOOTPRINT_BYTES);
        assert_eq!(KernelChoice::Auto.resolve(&huge), Kernel::Gather);
        // A huge-but-sparse problem still gets the postings index: the
        // density rule fires before the footprint fallback.
        let huge_sparse = DataShape { nnz: 5_000_000, ..huge };
        assert!(huge_sparse.est_center_density() <= AUTO_DENSITY_CUTOFF);
        assert_eq!(KernelChoice::Auto.resolve(&huge_sparse), Kernel::Pruned);
    }

    #[test]
    fn backends_agree_on_random_sparse_problems() {
        use crate::sparse::{InvertedIndex, SparseVec};
        use crate::util::prop::forall;
        forall(60, 0x5EED, |g| {
            let d = g.usize_in(1, 64);
            let k = g.usize_in(1, 12);
            let mut centers = DenseMatrix::zeros(k, d);
            for j in 0..k {
                let nnz = g.usize_in(0, d + 1);
                for c in g.sparse_pattern(d, nnz) {
                    centers.row_mut(j)[c] = g.f64_in(-1.0, 1.0) as f32;
                }
            }
            let mut t = DenseMatrix::zeros(d, k);
            for j in 0..k {
                for (c, &v) in centers.row(j).iter().enumerate() {
                    t.data_mut()[c * k + j] = v;
                }
            }
            let idx = InvertedIndex::from_centers(&centers);
            let nnz = g.usize_in(0, d + 1);
            let pat = g.sparse_pattern(d, nnz);
            let row = SparseVec::new(
                d,
                pat.iter().map(|&c| c as u32).collect(),
                pat.iter().map(|_| g.f64_in(-1.0, 1.0) as f32).collect(),
            );
            let rv = RowView { indices: row.indices(), values: row.values() };
            let mut dense = vec![0.0f64; k];
            let mut inv = vec![0.0f64; k];
            let mut gather = vec![0.0f64; k];
            let md = sims_transposed(&t, k, rv, &mut dense);
            let mi = idx.sims_into(rv, &mut inv);
            let mg = sims_gather(&centers, rv, &mut gather);
            // Dense ↔ Inverted: bit-identical, and the inverted file never
            // does more multiply-adds.
            assert!(mi <= md);
            assert_eq!(md, mg);
            for (x, y) in dense.iter().zip(&inv) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
            // Gather: same values up to summation-order rounding.
            for (x, y) in dense.iter().zip(&gather) {
                assert!((x - y).abs() < 1e-12, "{x} vs {y}");
            }
        });
    }
}
