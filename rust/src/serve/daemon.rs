//! The serving daemon: a persistent TCP process answering
//! `sphkm.rpc.v1` frames (see [`crate::serve::rpc`]) over a
//! [`ModelSlot`], with hot model swap and an optional background
//! mini-batch refit loop.
//!
//! # Architecture
//!
//! [`Daemon::start`] binds a [`std::net::TcpListener`] and spawns one
//! accept thread; each accepted connection gets its own handler thread.
//! All of them share one state block: the [`ModelSlot`] (the
//! versioned engine), a [`Metrics`] registry, and the shutdown flag.
//! A query request pins the slot once, validates and assembles the rows
//! into a [`CsrMatrix`], and runs
//! [`QueryEngine::top_p_batch_timed`](crate::serve::QueryEngine::top_p_batch_timed)
//! — which shards the batch across the engine's [`runtime`](crate::runtime)
//! Plan/Pool executor — so one client's large batch uses every core while
//! other connections interleave between batches.
//!
//! # Hot swap
//!
//! Three paths publish a new epoch into the slot, all equivalent from a
//! reader's point of view (in-flight queries keep their pinned engine;
//! see [`ModelSlot`]):
//!
//! 1. the `reload` RPC (explicit path, or the watched path),
//! 2. the **watcher thread**: polls the watched `.spkm` file's
//!    `(mtime, len)` signature and publishes on change — loading via
//!    [`Model::load_low_mem`], and treating a load failure as "the file
//!    is mid-write, retry next tick" (the served model is never touched
//!    by a failed load),
//! 3. the **refit loop**: periodically (or on the `refit` RPC) reruns the
//!    mini-batch estimator warm-started from the live lineage and
//!    publishes the result.
//!
//! # Shutdown
//!
//! The `shutdown` RPC (or [`DaemonHandle::shutdown`]) raises one atomic
//! flag. Connection threads poll it on their read timeout, the watcher
//! and refit threads between sleep slices, and the accept thread on its
//! own accept timeout loop; [`DaemonHandle::join`] then drains them all.

use std::io;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::ops::ControlFlow;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::kmeans::{Engine, FittedModel, IterSnapshot, MiniBatchParams, SphericalKMeans};
use crate::model::Model;
use crate::obs::Metrics;
use crate::serve::rpc::{self, FrameReader, Reply, Request};
use crate::serve::slot::ModelSlot;
use crate::serve::ServeMode;
use crate::sparse::{CsrMatrix, SparseVec};
use crate::util::json::Json;

/// How a [`Daemon`] binds and serves — everything but the model itself.
#[derive(Debug, Clone)]
pub struct DaemonConfig {
    /// Bind address; use port 0 for an ephemeral port (the bound address
    /// is reported by [`DaemonHandle::local_addr`]).
    pub addr: String,
    /// Traversal mode every published engine is opened with.
    pub mode: ServeMode,
    /// Worker threads per query batch (0 = all cores, 1 = serial).
    pub threads: usize,
    /// Watch this `.spkm` path and hot-swap when its `(mtime, len)`
    /// signature changes, polling at the given interval. Also the
    /// default path for a `reload` RPC that names none.
    pub watch: Option<(PathBuf, Duration)>,
    /// Background mini-batch refit configuration; `None` disables the
    /// loop and makes the `refit` RPC an error.
    pub refit: Option<RefitConfig>,
}

impl Default for DaemonConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:0".to_string(),
            mode: ServeMode::Auto,
            threads: 0,
            watch: None,
            refit: None,
        }
    }
}

/// The background refit loop's corpus and optimizer settings.
#[derive(Debug, Clone)]
pub struct RefitConfig {
    /// Unit-normalized training rows the refit resamples each round.
    pub data: CsrMatrix,
    /// Mini-batch optimizer parameters for each round.
    pub params: MiniBatchParams,
    /// Training threads per round (0 = all cores).
    pub threads: usize,
    /// Run a round automatically at this interval; `None` refits only on
    /// the `refit` RPC.
    pub interval: Option<Duration>,
}

/// Refit state guarded by one mutex: rounds are serialized (a concurrent
/// `refit` RPC and timer tick warm-start from the same lineage one after
/// the other instead of racing to publish stale centers).
struct RefitState {
    data: CsrMatrix,
    params: MiniBatchParams,
    threads: usize,
    /// The lineage the next round warm-starts from — updated by every
    /// publish (reload, watcher, refit) so rounds always continue the
    /// model that is actually serving.
    lineage: FittedModel,
}

/// State shared by the accept, connection, watcher, and refit threads.
struct Shared {
    slot: ModelSlot,
    mode: ServeMode,
    threads: usize,
    watch_path: Option<PathBuf>,
    metrics: Mutex<Metrics>,
    shutdown: AtomicBool,
    refit: Mutex<Option<RefitState>>,
}

/// Poll interval connection/accept threads use to notice the shutdown
/// flag without burning a core.
const POLL: Duration = Duration::from_millis(100);

/// The daemon entry point; see the [module docs](self).
pub struct Daemon;

/// A running daemon: its bound address plus the handles needed to stop
/// it and collect its metrics.
pub struct DaemonHandle {
    addr: SocketAddr,
    shared: Arc<Shared>,
    threads: Vec<JoinHandle<()>>,
}

impl Daemon {
    /// Start serving `model` per `cfg`: bind the listener, publish the
    /// model as epoch 0, and spawn the accept (and optional watcher /
    /// refit) threads. Returns once the socket is bound — queries can be
    /// sent as soon as this returns.
    pub fn start(model: Model, cfg: &DaemonConfig) -> io::Result<DaemonHandle> {
        let lineage = FittedModel::from_model(model);
        let engine = lineage.query_engine_with(cfg.mode, cfg.threads);
        let listener = TcpListener::bind(&cfg.addr)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;

        let shared = Arc::new(Shared {
            slot: ModelSlot::new(engine),
            mode: cfg.mode,
            threads: cfg.threads,
            watch_path: cfg.watch.as_ref().map(|(p, _)| p.clone()),
            metrics: Mutex::new(Metrics::new()),
            shutdown: AtomicBool::new(false),
            refit: Mutex::new(cfg.refit.as_ref().map(|r| RefitState {
                data: r.data.clone(),
                params: r.params,
                threads: r.threads,
                lineage: lineage.clone(),
            })),
        });

        let mut threads = Vec::new();
        {
            let shared = Arc::clone(&shared);
            threads.push(
                std::thread::Builder::new()
                    .name("sphkm-accept".to_string())
                    .spawn(move || accept_loop(&listener, &shared))?,
            );
        }
        if let Some((path, interval)) = cfg.watch.clone() {
            let shared = Arc::clone(&shared);
            threads.push(
                std::thread::Builder::new()
                    .name("sphkm-watch".to_string())
                    .spawn(move || watch_loop(&path, interval, &shared))?,
            );
        }
        if let Some(interval) = cfg.refit.as_ref().and_then(|r| r.interval) {
            let shared = Arc::clone(&shared);
            threads.push(
                std::thread::Builder::new()
                    .name("sphkm-refit".to_string())
                    .spawn(move || refit_timer_loop(interval, &shared))?,
            );
        }
        Ok(DaemonHandle { addr, shared, threads })
    }
}

impl DaemonHandle {
    /// The address the daemon actually bound (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Current slot epoch (0 until the first swap).
    pub fn epoch(&self) -> u64 {
        self.shared.slot.epoch()
    }

    /// Hot swaps performed so far.
    pub fn swaps(&self) -> u64 {
        self.shared.slot.swaps()
    }

    /// Raise the shutdown flag and nudge the accept thread. Idempotent;
    /// returns immediately — call [`DaemonHandle::join`] to wait.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        // The accept loop polls on a nonblocking listener, so the flag
        // alone is enough; a best-effort self-connect shortens the wait.
        let _ = TcpStream::connect_timeout(&self.addr, POLL);
    }

    /// Wait for every daemon thread to exit (call after
    /// [`DaemonHandle::shutdown`], or after a client sent the `shutdown`
    /// RPC).
    pub fn join(mut self) -> Metrics {
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        self.shared.metrics.lock().expect("daemon metrics").clone()
    }

    /// Snapshot of the daemon's metrics registry.
    pub fn metrics(&self) -> Metrics {
        self.shared.metrics.lock().expect("daemon metrics").clone()
    }
}

fn accept_loop(listener: &TcpListener, shared: &Arc<Shared>) {
    let mut conns: Vec<JoinHandle<()>> = Vec::new();
    while !shared.shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                let shared = Arc::clone(shared);
                if let Ok(t) = std::thread::Builder::new()
                    .name("sphkm-conn".to_string())
                    .spawn(move || connection_loop(stream, &shared))
                {
                    conns.push(t);
                }
                // Reap finished handlers so a long-lived daemon does not
                // accumulate one JoinHandle per past connection.
                conns.retain(|t| !t.is_finished());
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => std::thread::sleep(POLL),
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(_) => break,
        }
    }
    for t in conns {
        let _ = t.join();
    }
}

fn connection_loop(stream: TcpStream, shared: &Arc<Shared>) {
    // The listener is nonblocking; the accepted stream must not be (on
    // platforms where it inherits the flag). A read timeout then turns
    // the blocking read into a shutdown-flag poll; FrameReader keeps
    // partial frames across timeouts.
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(POLL));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = FrameReader::new(stream);
    loop {
        match reader.read_frame() {
            Ok(Some(line)) => {
                let (reply, stop) = handle_frame(&line, shared);
                if rpc::write_frame(&mut writer, &reply.to_json()).is_err() {
                    return;
                }
                if stop {
                    return;
                }
            }
            Ok(None) => return,
            Err(e) if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) => {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
            }
            Err(e) if e.kind() == io::ErrorKind::InvalidData => {
                // Framing is lost (oversize or non-UTF-8 frame): report
                // once, then close — the stream cannot be resynced.
                let reply = Reply::Error { message: e.to_string() };
                let _ = rpc::write_frame(&mut writer, &reply.to_json());
                return;
            }
            Err(_) => return,
        }
    }
}

/// Decode and execute one frame. Returns the reply and whether the
/// connection (and for `shutdown`, the daemon) should stop afterwards.
fn handle_frame(line: &str, shared: &Arc<Shared>) -> (Reply, bool) {
    shared.metrics.lock().expect("daemon metrics").incr("daemon.requests", 1);
    let req = Json::parse_bounded(line, rpc::MAX_FRAME_BYTES)
        .map_err(|e| format!("bad frame: {e}"))
        .and_then(|doc| Request::from_json(&doc));
    let req = match req {
        Ok(r) => r,
        Err(message) => {
            shared.metrics.lock().expect("daemon metrics").incr("daemon.errors", 1);
            return (Reply::Error { message }, false);
        }
    };
    match req {
        Request::Query { top, rows } => {
            let reply = handle_query(top, &rows, shared);
            if matches!(reply, Reply::Error { .. }) {
                shared.metrics.lock().expect("daemon metrics").incr("daemon.errors", 1);
            }
            (reply, false)
        }
        Request::Stats => (handle_stats(shared), false),
        Request::Reload { path } => {
            let reply = match handle_reload(path.as_deref(), shared) {
                Ok(epoch) => Reply::Reload { epoch },
                Err(message) => {
                    shared.metrics.lock().expect("daemon metrics").incr("daemon.errors", 1);
                    Reply::Error { message }
                }
            };
            (reply, false)
        }
        Request::Refit => {
            let reply = match refit_round(shared) {
                Ok(epoch) => Reply::Refit { epoch },
                Err(message) => {
                    shared.metrics.lock().expect("daemon metrics").incr("daemon.errors", 1);
                    Reply::Error { message }
                }
            };
            (reply, false)
        }
        Request::Ping => (Reply::Pong { epoch: shared.slot.epoch() }, false),
        Request::Shutdown => {
            shared.shutdown.store(true, Ordering::SeqCst);
            (Reply::Shutdown, true)
        }
    }
}

fn handle_query(top: usize, rows: &[(Vec<u32>, Vec<f32>)], shared: &Arc<Shared>) -> Reply {
    // Pin once: the whole batch is answered by one epoch, never split
    // across a concurrent swap.
    let pinned = shared.slot.pin();
    let d = pinned.engine().model().d();
    let mut vecs = Vec::with_capacity(rows.len());
    for (r, (idx, val)) in rows.iter().enumerate() {
        // try_new validates sorted unique in-range indices and finite
        // values — the batch kernel's dimension assert can never fire on
        // wire input.
        match SparseVec::try_new(d, idx.clone(), val.clone()) {
            Ok(v) => vecs.push(v),
            Err(e) => return Reply::Error { message: format!("row {r}: {e}") },
        }
    }
    let data = CsrMatrix::from_rows(d, &vecs);
    let (results, stats, hist) = pinned.engine().top_p_batch_timed(&data, top);
    shared.slot.record_queries(pinned.epoch(), stats.queries);
    let mut m = shared.metrics.lock().expect("daemon metrics");
    m.incr("serve.queries", stats.queries);
    m.incr("serve.madds", stats.madds);
    m.incr("serve.candidates_scored", stats.candidates_scored);
    m.incr("serve.centers_pruned", stats.centers_pruned);
    m.merge_histogram("daemon.query", &hist);
    m.set_gauge("daemon.epoch", pinned.epoch() as f64);
    drop(m);
    Reply::Query { epoch: pinned.epoch(), results }
}

fn handle_stats(shared: &Arc<Shared>) -> Reply {
    Reply::Stats {
        epoch: shared.slot.epoch(),
        swaps: shared.slot.swaps(),
        epoch_queries: shared.slot.epoch_queries(),
        metrics: shared.metrics.lock().expect("daemon metrics").to_json(),
    }
}

/// Load a model file and publish it as the next epoch. `path = None`
/// falls back to the watched path. The served model is untouched on any
/// failure.
fn handle_reload(path: Option<&str>, shared: &Arc<Shared>) -> Result<u64, String> {
    let owned;
    let path: &Path = match path {
        Some(p) => {
            owned = PathBuf::from(p);
            &owned
        }
        None => shared
            .watch_path
            .as_deref()
            .ok_or("reload without a path and no watched model path configured")?,
    };
    let model = Model::load_low_mem(path)
        .map_err(|e| format!("cannot load {}: {e}", path.display()))?;
    Ok(publish_model(model, shared))
}

/// Publish `model` as the next epoch and realign the refit lineage so
/// future rounds warm-start from what is now serving.
fn publish_model(model: Model, shared: &Arc<Shared>) -> u64 {
    let lineage = FittedModel::from_model(model);
    let engine = lineage.query_engine_with(shared.mode, shared.threads);
    if let Some(state) = shared.refit.lock().expect("refit state").as_mut() {
        state.lineage = lineage;
    }
    let epoch = shared.slot.publish(engine);
    let mut m = shared.metrics.lock().expect("daemon metrics");
    m.incr("daemon.reloads", 1);
    m.set_gauge("daemon.epoch", epoch as f64);
    epoch
}

/// Poll `path`'s `(mtime, len)` signature and hot-swap on change.
fn watch_loop(path: &Path, interval: Duration, shared: &Arc<Shared>) {
    let signature = |p: &Path| {
        std::fs::metadata(p)
            .ok()
            .map(|md| (md.modified().ok(), md.len()))
    };
    let mut last = signature(path);
    while !sleep_poll(interval, shared) {
        let now = signature(path);
        if now != last && now.is_some() {
            // Advance the seen-signature only after a *successful* load:
            // a publisher caught mid-write fails Model::load_low_mem's
            // checksum and is retried on the next tick instead of being
            // skipped forever.
            if let Ok(model) = Model::load_low_mem(path) {
                publish_model(model, shared);
                last = now;
            }
        }
    }
}

/// Run refit rounds on a timer until shutdown.
fn refit_timer_loop(interval: Duration, shared: &Arc<Shared>) {
    while !sleep_poll(interval, shared) {
        let _ = refit_round(shared);
    }
}

/// Sleep `total` in shutdown-polling slices; true once shutdown is up.
fn sleep_poll(total: Duration, shared: &Arc<Shared>) -> bool {
    let mut left = total;
    while left > Duration::ZERO {
        if shared.shutdown.load(Ordering::SeqCst) {
            return true;
        }
        let step = left.min(POLL);
        std::thread::sleep(step);
        left -= step;
    }
    shared.shutdown.load(Ordering::SeqCst)
}

/// One warm-started mini-batch round over the refit corpus, published as
/// the next epoch. Rounds are serialized by the refit-state mutex; the
/// warm start resumes the live lineage's persisted schedule, so the
/// produced centers are a deterministic function of (lineage, corpus,
/// params) — refit epochs are reproducible offline.
fn refit_round(shared: &Arc<Shared>) -> Result<u64, String> {
    let mut guard = shared.refit.lock().expect("refit state");
    let state = guard.as_mut().ok_or("refit is not configured on this daemon")?;
    let est = SphericalKMeans::new(state.lineage.k())
        .engine(Engine::MiniBatch(state.params))
        .seed(state.lineage.meta().seed)
        .threads(state.threads)
        .warm_start(&state.lineage);
    let shutdown = &shared.shutdown;
    let mut observer = |_snap: &IterSnapshot<'_>| {
        if shutdown.load(Ordering::SeqCst) {
            ControlFlow::Break(())
        } else {
            ControlFlow::Continue(())
        }
    };
    let fitted = est
        .fit_observed(&state.data, &mut observer)
        .map_err(|e| format!("refit failed: {e}"))?;
    state.lineage = fitted.clone();
    let engine = fitted.query_engine_with(shared.mode, shared.threads);
    drop(guard);
    let epoch = shared.slot.publish(engine);
    let mut m = shared.metrics.lock().expect("daemon metrics");
    m.incr("daemon.refits", 1);
    m.set_gauge("daemon.epoch", epoch as f64);
    drop(m);
    Ok(epoch)
}

/// Render the daemon's metrics registry as a `sphkm.metrics.v1` document
/// — the same envelope `assign --metrics-out` writes, so downstream
/// tooling reads both.
pub fn metrics_dump(metrics: &Metrics) -> String {
    let doc = Json::Obj(vec![
        (
            "schema".to_string(),
            Json::Str(crate::obs::metrics::METRICS_SCHEMA.to_string()),
        ),
        ("metrics".to_string(), metrics.to_json()),
    ]);
    let mut text = doc.pretty(2);
    text.push('\n');
    text
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TrainingMeta;
    use crate::serve::client::Client;
    use crate::sparse::DenseMatrix;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("sphkm-daemon-tests-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn meta(seed: u64) -> TrainingMeta {
        TrainingMeta {
            variant: "Standard".into(),
            kernel: "gather".into(),
            iterations: 1,
            objective: 0.0,
            seed,
        }
    }

    fn axis_model(which: u64) -> Model {
        let centers = if which % 2 == 0 {
            DenseMatrix::from_vec(2, 3, vec![1.0, 0.0, 0.0, 0.0, 1.0, 0.0])
        } else {
            DenseMatrix::from_vec(2, 3, vec![0.0, 1.0, 0.0, 0.0, 0.0, 1.0])
        };
        Model::new(centers, meta(which))
    }

    fn serial_cfg() -> DaemonConfig {
        DaemonConfig {
            mode: ServeMode::Exhaustive,
            threads: 1,
            ..DaemonConfig::default()
        }
    }

    /// The TSan-matrix loopback hammer: several client threads query over
    /// real sockets while the main thread hot-swaps via the `reload` RPC.
    /// Every answer must match the generation its epoch advertises.
    #[test]
    fn loopback_hammer_with_swaps() {
        let b_path = tmp("hammer-b.spkm");
        axis_model(1).save(&b_path).unwrap();
        let a_path = tmp("hammer-a.spkm");
        axis_model(0).save(&a_path).unwrap();

        let handle = Daemon::start(axis_model(0), &serial_cfg()).unwrap();
        let addr = handle.local_addr().to_string();
        let probe = (vec![1u32], vec![1.0f32]);

        std::thread::scope(|s| {
            for _ in 0..3 {
                let addr = addr.clone();
                let probe = probe.clone();
                s.spawn(move || {
                    let mut client = Client::connect(&addr).unwrap();
                    for _ in 0..40 {
                        let (epoch, results) = client.query(1, &[probe.clone()]).unwrap();
                        let expect = if epoch % 2 == 0 { 1 } else { 0 };
                        assert_eq!(results[0][0].0, expect, "epoch {epoch}");
                    }
                });
            }
            let addr = addr.clone();
            let a = a_path.clone();
            let b = b_path.clone();
            s.spawn(move || {
                let mut client = Client::connect(&addr).unwrap();
                for swap in 1..=4u64 {
                    let path = if swap % 2 == 0 { &a } else { &b };
                    let epoch = client.reload(Some(path.to_str().unwrap())).unwrap();
                    assert_eq!(epoch, swap);
                    std::thread::yield_now();
                }
            });
        });

        assert_eq!(handle.swaps(), 4);
        let mut client = Client::connect(&addr).unwrap();
        let (epoch, swaps, per_epoch, _metrics) = client.stats().unwrap();
        assert_eq!(epoch, 4);
        assert_eq!(swaps, 4);
        let counted: u64 = per_epoch.iter().map(|&(_, n)| n).sum();
        assert_eq!(counted, 3 * 40, "every query attributed to an epoch");
        client.shutdown().unwrap();
        let metrics = handle.join();
        assert_eq!(metrics.counter("serve.queries"), 3 * 40);
        assert_eq!(metrics.counter("daemon.reloads"), 4);
        assert_eq!(metrics.counter("daemon.errors"), 0);
    }

    /// Malformed content costs one error frame, never the connection; a
    /// failed reload never touches the served model.
    #[test]
    fn errors_are_frames_not_disconnects() {
        let handle = Daemon::start(axis_model(0), &serial_cfg()).unwrap();
        let addr = handle.local_addr().to_string();
        let mut client = Client::connect(&addr).unwrap();

        for bad in [
            "this is not json",
            "{\"rpc\":\"sphkm.rpc.v1\",\"op\":\"frobnicate\"}",
            // Out-of-range index: must be an error reply, not a panic.
            "{\"rpc\":\"sphkm.rpc.v1\",\"op\":\"query\",\"top\":1,\"rows\":[{\"i\":[9],\"v\":[1.0]}]}",
        ] {
            let reply = client.call_raw(bad).unwrap();
            assert!(matches!(reply, Reply::Error { .. }), "{bad}");
        }
        // Reload of a nonexistent file: error reply, epoch unchanged.
        let missing = tmp("never-written.spkm");
        assert!(client.reload(Some(missing.to_str().unwrap())).is_err());
        let (epoch, _) = client.query(1, &[(vec![1], vec![1.0])]).unwrap();
        assert_eq!(epoch, 0, "failed reload left epoch 0 serving");
        // The same connection still works after every error above.
        assert_eq!(client.ping().unwrap(), 0);
        // Refit is not configured: typed error, connection survives.
        assert!(client.refit().is_err());

        client.shutdown().unwrap();
        let metrics = handle.join();
        assert!(metrics.counter("daemon.errors") >= 5);
    }

    /// The watcher publishes a new epoch when the watched file changes.
    #[test]
    fn watcher_hot_swaps_on_file_change() {
        let path = tmp("watched.spkm");
        axis_model(0).save(&path).unwrap();
        let cfg = DaemonConfig {
            watch: Some((path.clone(), Duration::from_millis(20))),
            ..serial_cfg()
        };
        let handle = Daemon::start(axis_model(0), &cfg).unwrap();
        let addr = handle.local_addr().to_string();
        // Overwrite the watched file with generation 1 (different length
        // is not guaranteed, but mtime advances).
        std::thread::sleep(Duration::from_millis(30));
        axis_model(1).save(&path).unwrap();
        let mut client = Client::connect(&addr).unwrap();
        let mut swapped = false;
        for _ in 0..100 {
            let (epoch, results) = client.query(1, &[(vec![1], vec![1.0])]).unwrap();
            if epoch >= 1 {
                assert_eq!(results[0][0].0, 0, "generation 1 centers serve e1 -> center 0");
                swapped = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(swapped, "watcher never published the rewritten model");
        client.shutdown().unwrap();
        handle.join();
    }

    #[test]
    fn metrics_dump_is_schema_stamped() {
        let mut m = Metrics::new();
        m.incr("daemon.requests", 2);
        let text = metrics_dump(&m);
        let doc = Json::parse(text.trim_end()).unwrap();
        assert_eq!(
            doc.get("schema").and_then(Json::as_str),
            Some(crate::obs::metrics::METRICS_SCHEMA)
        );
        assert!(doc.get("metrics").is_some());
    }
}
