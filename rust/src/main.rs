//! `sphkm` — the spherical k-means CLI.
//!
//! ```text
//! sphkm datasets  [--scale small] [--seed 42]
//! sphkm cluster   --data <name|path.svm|path.mtx|path.sks> --k 20
//!                 [--algo simp-elkan] [--init kmeans++] [--seed 0]
//!                 [--scale small] [--stats] [--mmap] [--chunk-rows N]
//!                 [--save-model model.spkm] [--resume model.spkm]
//!                 [--save-assign assign.csv] [--trace-out trace.jsonl]
//! sphkm assign    --model model.spkm --data <name|path.svm|path.mtx>
//!                 [--top 1] [--mode auto|pruned|exhaustive] [--out top.csv]
//!                 [--mmap] [--metrics-out metrics.json]
//! sphkm serve     --model model.spkm [--addr 127.0.0.1:0] [--mode auto]
//!                 [--watch model.spkm] [--refit-data <name>]
//! sphkm query     [--addr HOST:PORT | --addr-file FILE] [--data <name>]
//!                 [--op query|stats|ping|reload|refit|shutdown]
//! sphkm report    --check FILE.json FILE.jsonl ...
//! sphkm convert   --data file.svm --out file.sks [--normalize]
//! sphkm gen       --data <name> --out file.svm [--scale small] [--seed 42]
//! sphkm bench     --exp table1|table2|table3|fig1|fig2|ablation-cc|serve [opts]
//! sphkm info
//! ```

// CLI reporting casts counters to floats for display; the workspace
// clippy warnings on truncating casts target library code.
#![allow(clippy::cast_possible_truncation, clippy::float_cmp)]

use std::ops::ControlFlow;

use sphkm::coordinator::experiments::{self, ExperimentOpts};
use sphkm::data::datasets::{self, Scale, DATASET_NAMES};
use sphkm::data::Dataset;
use sphkm::init::InitMethod;
use sphkm::kmeans::{IterSnapshot, KernelChoice, Variant};
use sphkm::metrics;
use sphkm::model::Model;
use sphkm::serve::{Client, Daemon, DaemonConfig, QueryEngine, RefitConfig, ServeConfig, ServeMode};
use sphkm::sparse::{RowSource, ShardStore};
use sphkm::util::cli::Args;
use sphkm::util::json::Json;
use sphkm::{Engine, ExactParams, FittedModel, MiniBatchParams, SphericalKMeans};

fn usage() -> ! {
    eprintln!(
        "sphkm — Accelerating Spherical k-Means (Schubert, Lang, Feher 2021)

USAGE:
  sphkm datasets [--scale tiny|small|medium] [--seed N]
  sphkm cluster --data <dataset> --k K [--algo VARIANT] [--init METHOD]
                [--seed N] [--scale S] [--max-iter M] [--stats]
                [--threads T] # sharded assignment: 0 = all cores, 1 = serial
                [--kernel X]  # similarity backend: auto|dense|gather|inverted|pruned
                [--preinit]   # §7: pre-initialize bounds from k-means++
                [--minibatch] # approximate mini-batch engine (large corpora)
                [--batch-size B] [--epochs E] [--tol T]
                [--truncate M] # keep top-M coords per center (0 = dense)
                [--mmap]      # out-of-core: train from chunked disk shards
                              # (a .svm input is converted to a sibling
                              # .sks store first; a .sks input implies it)
                [--chunk-rows N] # rows buffered per chunk in --mmap mode
                [--save-assign FILE.csv] # write row,cluster assignments
                [--audit]     # certify every bound-based skip against the
                              # exact cosine (needs --features audit)
                [--trace-out FILE.jsonl] # per-iteration phase timings as
                              # schema-stamped JSONL (needs --features trace)
                [--save-model FILE.spkm] # persist the trained model + state
                [--resume FILE.spkm]     # continue training a saved model
                                         # (k, engine, schedule and seed
                                         # default from the file)
  sphkm assign --model FILE.spkm --data <dataset> [--top P] [--threads T]
               [--mode auto|pruned|exhaustive] [--out FILE.csv]
               [--mmap]                 # low-memory streaming model load
               [--metrics-out FILE.json] # query counters + per-query latency
                                         # histogram (exact p50/p95/p99)
               [--scale S] [--seed N]   # answer nearest-center queries
  sphkm serve --model FILE.spkm   # persistent serving daemon: newline-
               [--addr 127.0.0.1:0]     # delimited sphkm.rpc.v1 JSON over
               [--addr-file FILE]       # TCP, hot model swap, runs until a
               [--mode auto|pruned|exhaustive] [--threads T]  # shutdown RPC
               [--mmap]                 # low-memory model load (no refit state)
               [--watch FILE.spkm] [--watch-interval-ms N] # swap on change
               [--refit-data <dataset>] # background mini-batch refit corpus
               [--refit-interval-ms N]  # periodic rounds (omit: RPC-only)
               [--refit-batch-size B] [--refit-epochs E] [--refit-tol T]
               [--refit-truncate M] [--refit-threads T]
               [--metrics-out FILE.json] # final registry dump on shutdown
  sphkm query [--addr HOST:PORT | --addr-file FILE] # daemon client
              [--op query|stats|ping|reload|refit|shutdown]
              [--data <dataset>] [--top P] [--batch N] [--out FILE.csv]
              [--path FILE.spkm]  # reload target (default: watched path)
              # default op: query with --data, stats without; query CSVs
              # are byte-identical to `assign --out` for the same model
  sphkm report --check FILE...    # validate machine-readable outputs:
                                  # .jsonl traces, report/metrics .json
  sphkm convert --data FILE.svm --out FILE.sks [--normalize]
               # stream a libsvm file into the chunked shard store the
               # --mmap trainer reads (bounded memory at any corpus size);
               # fully labeled inputs also get a FILE.sks.labels sidecar
  sphkm sweep --config FILE.cfg   # cross-product runs from a config file
  sphkm gen --data <dataset> --out FILE.svm [--scale S] [--seed N]
  sphkm bench --exp table1|table2|table3|fig1|fig2|ablation-cc|ablation-preinit
              |minibatch|serve
              [--scale S] [--reps R] [--ks 2,10,20] [--quick] [--k K]
              [--threads T] [--kernel X]
  sphkm info

  <dataset>: one of {names}, or a .svm/.libsvm/.mtx file path
  VARIANT:   standard | elkan | simp-elkan | hamerly | simp-hamerly | yinyang
  METHOD:    uniform | kmeans++ | kmeans++1.5 | afkmc2 | afkmc2-1.5
  KERNEL:    auto (problem-shape heuristic) | dense (d×k transpose)
             | gather (per-center dots) | inverted (CSC postings index)
             | pruned (MaxScore bound-pruned postings walk; bit-identical,
               --stats adds walked-term / survivor prune counters)",
        names = DATASET_NAMES.join("|")
    );
    std::process::exit(2)
}

fn load_dataset(args: &Args, scale: Scale, seed: u64) -> Dataset {
    load_dataset_spec(args.get("data").unwrap_or("demo"), scale, seed)
}

/// Resolve a dataset spec (named synthetic corpus or `.svm`/`.mtx` path)
/// into unit-normalized rows — shared by `--data`, `--refit-data`, and
/// the query client.
fn load_dataset_spec(spec: &str, scale: Scale, seed: u64) -> Dataset {
    if spec.ends_with(".svm") || spec.ends_with(".libsvm") {
        let (mut m, labels) =
            sphkm::data::io::read_libsvm(std::path::Path::new(spec)).unwrap_or_else(|e| {
                eprintln!("error reading {spec}: {e}");
                std::process::exit(1)
            });
        m.normalize_rows();
        Dataset { name: spec.into(), matrix: m, labels }
    } else if spec.ends_with(".mtx") {
        let mut m = sphkm::data::io::read_matrix_market(std::path::Path::new(spec))
            .unwrap_or_else(|e| {
                eprintln!("error reading {spec}: {e}");
                std::process::exit(1)
            });
        m.normalize_rows();
        Dataset { name: spec.into(), matrix: m, labels: None }
    } else {
        datasets::by_name(spec, scale, seed).unwrap_or_else(|| {
            eprintln!("unknown dataset: {spec}");
            usage()
        })
    }
}

/// The `cluster` command's training data, behind either backend: a fully
/// loaded in-memory dataset, or an on-disk chunked shard store streamed
/// through training (`--mmap` / a `.sks` path). Results are bit-identical
/// between the two (the `out_of_core` integration suite asserts it).
enum TrainData {
    Mem(Dataset),
    Disk {
        store: ShardStore,
        name: String,
        labels: Option<Vec<u32>>,
    },
}

impl TrainData {
    fn rows(&self) -> usize {
        match self {
            TrainData::Mem(d) => d.matrix.rows(),
            TrainData::Disk { store, .. } => store.rows(),
        }
    }

    fn cols(&self) -> usize {
        match self {
            TrainData::Mem(d) => d.matrix.cols(),
            TrainData::Disk { store, .. } => store.cols(),
        }
    }

    fn density(&self) -> f64 {
        match self {
            TrainData::Mem(d) => d.matrix.density(),
            TrainData::Disk { store, .. } => {
                let cells = store.rows() as f64 * store.cols() as f64;
                if cells > 0.0 { store.nnz() as f64 / cells } else { 0.0 }
            }
        }
    }

    fn name(&self) -> &str {
        match self {
            TrainData::Mem(d) => &d.name,
            TrainData::Disk { name, .. } => name,
        }
    }

    fn labels(&self) -> Option<&[u32]> {
        match self {
            TrainData::Mem(d) => d.labels.as_deref(),
            TrainData::Disk { labels, .. } => labels.as_deref(),
        }
    }

    fn source(&self) -> RowSource<'_> {
        match self {
            TrainData::Mem(d) => RowSource::Mem(&d.matrix),
            TrainData::Disk { store, .. } => RowSource::Disk(store),
        }
    }
}

/// Resolve the `cluster` data spec into a backend: a `.sks` path opens
/// the shard store directly; `--mmap` on a `.svm`/`.libsvm` path first
/// converts it to a sibling `.sks` store (reusing one that already
/// exists); everything else loads in memory via [`load_dataset`].
fn load_train_data(args: &Args, scale: Scale, seed: u64) -> TrainData {
    let spec = args.get("data").unwrap_or("demo").to_string();
    let mmap = args.flag("mmap");
    let shard_path: Option<std::path::PathBuf> = if spec.ends_with(".sks") {
        Some(spec.clone().into())
    } else if mmap && (spec.ends_with(".svm") || spec.ends_with(".libsvm")) {
        let sks = std::path::Path::new(&spec).with_extension("sks");
        if sks.exists() {
            println!("[convert] reusing existing shard store {}", sks.display());
        } else {
            // Normalization during conversion is bit-identical to the
            // normalize_rows() call the in-memory .svm path performs.
            let rep =
                sphkm::data::convert::convert_libsvm_to_shards(std::path::Path::new(&spec), &sks, true)
                    .unwrap_or_else(|e| {
                        eprintln!("error converting {spec}: {e}");
                        std::process::exit(1)
                    });
            println!(
                "[convert] {spec} -> {} ({}x{}, nnz={}{})",
                sks.display(),
                rep.rows,
                rep.cols,
                rep.nnz,
                if rep.labeled { ", labels sidecar" } else { "" }
            );
        }
        Some(sks)
    } else if mmap {
        eprintln!("error: --mmap needs a .svm/.libsvm or .sks data path (named synthetic datasets are generated in memory; `gen` one to a file first)");
        std::process::exit(2);
    } else {
        None
    };
    match shard_path {
        Some(p) => {
            let mut store = ShardStore::open(&p).unwrap_or_else(|e| {
                eprintln!("error opening shard store {}: {e}", p.display());
                std::process::exit(1)
            });
            if let Some(c) = args.get("chunk-rows") {
                let c: usize = c.parse().unwrap_or_else(|_| {
                    eprintln!("error: --chunk-rows must be a positive integer");
                    std::process::exit(2)
                });
                store = store.with_chunk_rows(c);
            }
            let labels = sphkm::data::convert::read_labels_sidecar(
                &sphkm::data::convert::labels_sidecar_path(&p),
            )
            .ok()
            .filter(|l| l.len() == store.rows());
            TrainData::Disk { name: p.display().to_string(), store, labels }
        }
        None => TrainData::Mem(load_dataset(args, scale, seed)),
    }
}

/// `sphkm sweep --config file.cfg`: run the cross product of
/// datasets × variants × inits × ks from a config file and print/save a
/// result table (objective, time, sims, quality vs labels).
fn run_sweep(cfg: &sphkm::util::config::Config) {
    use sphkm::coordinator::report::{fmt_ms, Table};
    let scale: Scale = cfg.get_or("scale", Scale::Small).unwrap_or(Scale::Small);
    let seed: u64 = cfg.get_or("seed", 42).unwrap_or(42);
    let reps: usize = cfg.get_or("reps", 1).unwrap_or(1).max(1);
    let max_iter: usize = cfg.get_or("max_iter", 200).unwrap_or(200);
    let threads: usize = cfg.get_or("threads", 1).unwrap_or(1);
    let kernel: KernelChoice = cfg
        .get_or("kernel", KernelChoice::Auto)
        .unwrap_or_else(|e| { eprintln!("{e}"); usage() });
    let datasets_list: Vec<String> = {
        let l = cfg.list::<String>("datasets").unwrap_or_default();
        if l.is_empty() {
            vec![cfg.get("dataset").unwrap_or("demo").to_string()]
        } else {
            l
        }
    };
    let ks: Vec<usize> = {
        let l = cfg.list::<usize>("ks").unwrap_or_default();
        if l.is_empty() { vec![10] } else { l }
    };
    let variants: Vec<Variant> = {
        let raw = cfg.list::<String>("variants").unwrap_or_default();
        if raw.is_empty() {
            vec![Variant::SimplifiedElkan]
        } else {
            raw.iter()
                .map(|s| s.parse().unwrap_or_else(|e| { eprintln!("{e}"); usage() }))
                .collect()
        }
    };
    let inits: Vec<InitMethod> = {
        let raw = cfg.list::<String>("inits").unwrap_or_default();
        if raw.is_empty() {
            vec![InitMethod::Uniform]
        } else {
            raw.iter()
                .map(|s| s.parse().unwrap_or_else(|e| { eprintln!("{e}"); usage() }))
                .collect()
        }
    };
    let mut t = Table::new(&[
        "dataset", "variant", "init", "k", "ms", "iters", "objective", "NMI",
    ]);
    for name in &datasets_list {
        let ds = datasets::by_name(name, scale, seed).unwrap_or_else(|| {
            eprintln!("unknown dataset {name}");
            usage()
        });
        for &k in &ks {
            let k = k.min(ds.matrix.rows() / 2).max(1);
            for variant in &variants {
                for init in &inits {
                    let mut ms = 0.0;
                    let mut last: Option<FittedModel> = None;
                    for rep in 0..reps {
                        let estimator = SphericalKMeans::new(k)
                            .variant(*variant)
                            .init(*init)
                            .seed(seed ^ rep as u64)
                            .threads(threads)
                            .kernel(kernel)
                            .max_iter(max_iter);
                        let sw = sphkm::util::timer::Stopwatch::start();
                        last = Some(estimator.fit(&ds.matrix).unwrap_or_else(|e| {
                            eprintln!("sweep cell failed: {e}");
                            std::process::exit(1)
                        }));
                        ms += sw.ms();
                    }
                    let r = last.unwrap();
                    let nmi = ds
                        .labels
                        .as_ref()
                        .map(|l| format!("{:.3}", metrics::nmi(r.assignments(), l)))
                        .unwrap_or_else(|| "-".into());
                    t.row(vec![
                        ds.name.clone(),
                        variant.name().into(),
                        init.name(),
                        k.to_string(),
                        fmt_ms(ms / reps as f64),
                        r.iterations().to_string(),
                        format!("{:.2}", r.objective()),
                        nmi,
                    ]);
                }
            }
        }
        println!("  {} done", ds.name);
    }
    println!("{}", t.render());
    if let Some(out) = cfg.get("out") {
        // A sweep whose results cannot be saved has failed: propagate the
        // error as a nonzero exit instead of burying it in stderr.
        if let Err(e) = t.save_csv(std::path::Path::new(out)) {
            eprintln!("could not save {out}: {e}");
            std::process::exit(1);
        }
        println!("[csv] {out}");
    }
}

/// `sphkm assign`: load a persisted model and answer top-p nearest-center
/// queries for every row of a dataset — the serving half of the
/// train → persist → serve pipeline (see [`sphkm::serve`]).
fn run_assign(args: &Args, scale: Scale, seed: u64) {
    let model_path = args.get("model").unwrap_or_else(|| usage());
    // --mmap: low-memory streaming load — the training-state section of a
    // version-2 file is checksummed but never materialized (serve-only).
    let low_mem = args.flag("mmap");
    let model = load_model_or_exit(model_path, low_mem);
    if low_mem {
        println!("[mmap] low-memory model load: training state skipped, O(k·d) peak");
    }
    println!(
        "model {model_path}: k={}, d={}, {} center nnz ({:.3}% dense), trained by {} \
         (kernel={}, {} iters, objective={:.4}, seed={})",
        model.k(),
        model.d(),
        model.center_nnz(),
        model.center_density() * 100.0,
        model.meta().variant,
        model.meta().kernel,
        model.meta().iterations,
        model.meta().objective,
        model.meta().seed,
    );
    let ds = load_dataset(args, scale, seed);
    if ds.matrix.cols() > model.d() {
        eprintln!(
            "error: {} has {} features but the model was trained on {}",
            ds.name,
            ds.matrix.cols(),
            model.d()
        );
        std::process::exit(1);
    }
    let p: usize = args.get_or("top", 1).unwrap_or(1).max(1);
    let threads: usize = args.get_or("threads", 0).unwrap_or(0);
    let mode: ServeMode = args
        .get("mode")
        .unwrap_or("auto")
        .parse()
        .unwrap_or_else(|e| { eprintln!("{e}"); usage() });
    let engine = QueryEngine::new(model, &ServeConfig { mode, threads });
    // --metrics-out opts into the timed batch path: same results and
    // ServeStats, plus a per-query latency histogram merged across the
    // worker shards (available in every build — no feature needed).
    let metrics_out = args.get("metrics-out").map(str::to_string);
    let sw = sphkm::util::timer::Stopwatch::start();
    let (top, stats, hist) = if metrics_out.is_some() {
        let (top, stats, hist) = engine.top_p_batch_timed(&ds.matrix, p);
        (top, stats, Some(hist))
    } else {
        let (top, stats) = engine.top_p_batch(&ds.matrix, p);
        (top, stats, None)
    };
    let ms = sw.ms();
    let qps = stats.queries as f64 / (ms / 1000.0).max(1e-9);
    println!(
        "assigned {} rows (top-{p}, {} traversal, threads={threads}) in {ms:.1} ms: \
         {qps:.0} queries/s, {} madds ({:.1} per query), {} centers pruned",
        stats.queries,
        engine.mode(),
        stats.madds,
        stats.madds as f64 / stats.queries.max(1) as f64,
        stats.centers_pruned,
    );
    if let Some(h) = &hist {
        println!(
            "query latency: p50={:.4} ms, p95={:.4} ms, p99={:.4} ms \
             (min {:.4}, mean {:.4}, max {:.4}; {} samples)",
            h.quantile_ms(0.50),
            h.quantile_ms(0.95),
            h.quantile_ms(0.99),
            h.min_ns() as f64 / 1e6,
            h.mean_ns() / 1e6,
            h.max_ns() as f64 / 1e6,
            h.count(),
        );
    }
    if let (Some(out), Some(h)) = (&metrics_out, &hist) {
        let mut m = sphkm::obs::Metrics::new();
        m.incr("serve.queries", stats.queries);
        m.incr("serve.madds", stats.madds);
        m.incr("serve.candidates_scored", stats.candidates_scored);
        m.incr("serve.centers_pruned", stats.centers_pruned);
        m.set_gauge("serve.qps", qps);
        m.set_gauge("serve.wall_ms", ms);
        m.merge_histogram("serve.query", h);
        let doc = Json::Obj(vec![
            (
                "schema".to_string(),
                Json::Str(sphkm::obs::metrics::METRICS_SCHEMA.to_string()),
            ),
            ("metrics".to_string(), m.to_json()),
        ]);
        let mut text = doc.pretty(2);
        text.push('\n');
        if let Err(e) = std::fs::write(out, text) {
            eprintln!("could not save {out}: {e}");
            std::process::exit(1);
        }
        println!("[metrics] {out}");
    }
    if let Some(rss) = sphkm::util::mem::peak_rss_bytes() {
        println!("peak RSS: {:.2} MiB", rss as f64 / (1024.0 * 1024.0));
    }
    if let Some(truth) = &ds.labels {
        let labels: Vec<u32> = top.iter().map(|r| r.first().map_or(0, |&(j, _)| j)).collect();
        println!(
            "vs ground-truth labels: NMI={:.4} ARI={:.4} purity={:.4}",
            metrics::nmi(&labels, truth),
            metrics::ari(&labels, truth),
            metrics::purity(&labels, truth)
        );
    }
    if let Some(out) = args.get("out") {
        let mut csv = String::from("row,rank,center,similarity\n");
        for (i, ranks) in top.iter().enumerate() {
            for (rank, &(j, s)) in ranks.iter().enumerate() {
                csv.push_str(&format!("{i},{rank},{j},{s}\n"));
            }
        }
        if let Err(e) = std::fs::write(out, csv) {
            eprintln!("could not save {out}: {e}");
            std::process::exit(1);
        }
        println!("[csv] {out}");
    }
}

/// Load a `.spkm` file or exit 2 with the typed [`sphkm::model::ModelError`]
/// as a one-line diagnostic (bad magic, version, truncation, checksum —
/// all usage-class failures on the CLI surface, never a raw panic).
fn load_model_or_exit(path: &str, low_mem: bool) -> Model {
    let res = if low_mem {
        Model::load_low_mem(std::path::Path::new(path))
    } else {
        Model::load(std::path::Path::new(path))
    };
    res.unwrap_or_else(|e| {
        eprintln!("error loading model {path}: {e}");
        std::process::exit(2)
    })
}

/// `sphkm serve`: run the persistent serving daemon (see
/// [`sphkm::serve::daemon`]) until a client sends the `shutdown` RPC.
fn run_serve(args: &Args, scale: Scale, seed: u64) {
    let model_path = args.get("model").unwrap_or_else(|| usage());
    // Default to the full load: a background refit warm-starts from the
    // persisted training state, which --mmap deliberately skips.
    let low_mem = args.flag("mmap");
    let model = load_model_or_exit(model_path, low_mem);
    let mode: ServeMode = args
        .get("mode")
        .unwrap_or("auto")
        .parse()
        .unwrap_or_else(|e| { eprintln!("{e}"); usage() });
    let threads: usize = args.get_or("threads", 0).unwrap_or(0);
    let watch = args.get("watch").map(|p| {
        let ms: u64 = args.get_or("watch-interval-ms", 500).unwrap_or(500).max(1);
        (std::path::PathBuf::from(p), std::time::Duration::from_millis(ms))
    });
    let refit = args.get("refit-data").map(|spec| {
        if low_mem {
            eprintln!(
                "warning: --mmap skips training state; the first refit round \
                 transfers centers instead of resuming the schedule"
            );
        }
        let ds = load_dataset_spec(spec, scale, seed);
        let params = MiniBatchParams {
            batch_size: args.get_or("refit-batch-size", 1024).unwrap_or(1024),
            epochs: args.get_or("refit-epochs", 1).unwrap_or(1),
            tol: args.get_or("refit-tol", 1e-4).unwrap_or(1e-4),
            truncate: match args.get_or("refit-truncate", 0).unwrap_or(0) {
                0 => None,
                m => Some(m),
            },
        };
        let interval = args
            .get("refit-interval-ms")
            .and_then(|v| v.parse::<u64>().ok())
            .map(std::time::Duration::from_millis);
        println!(
            "[refit] corpus {} ({} rows), batch={}, epochs={}, {}",
            ds.name,
            ds.matrix.rows(),
            params.batch_size,
            params.epochs,
            match interval {
                Some(d) => format!("every {} ms", d.as_millis()),
                None => "on `refit` RPC only".to_string(),
            }
        );
        RefitConfig {
            data: ds.matrix,
            params,
            threads: args.get_or("refit-threads", threads).unwrap_or(threads),
            interval,
        }
    });
    let cfg = DaemonConfig {
        addr: args.get_or("addr", "127.0.0.1:0".to_string()).unwrap_or_else(|_| usage()),
        mode,
        threads,
        watch,
        refit,
    };
    let (k, d) = (model.k(), model.d());
    let handle = Daemon::start(model, &cfg).unwrap_or_else(|e| {
        eprintln!("error starting daemon on {}: {e}", cfg.addr);
        std::process::exit(1)
    });
    let addr = handle.local_addr();
    println!(
        "[serve] {model_path} (k={k}, d={d}) listening on {addr} — mode={mode}, \
         threads={threads}; stop with `sphkm query --addr {addr} --op shutdown`"
    );
    if let Some(path) = args.get("addr-file") {
        // Written after the bind so a launcher can poll for the
        // ephemeral port instead of parsing stdout.
        if let Err(e) = std::fs::write(path, format!("{addr}\n")) {
            eprintln!("could not write {path}: {e}");
            handle.shutdown();
            handle.join();
            std::process::exit(1);
        }
        println!("[serve] bound address written to {path}");
    }
    let metrics = handle.join();
    println!("[serve] shutdown: {} requests served", metrics.counter("daemon.requests"));
    if let Some(out) = args.get("metrics-out") {
        if let Err(e) = std::fs::write(out, sphkm::serve::daemon::metrics_dump(&metrics)) {
            eprintln!("could not save {out}: {e}");
            std::process::exit(1);
        }
        println!("[metrics] {out}");
    }
}

/// `sphkm query`: drive a running daemon over `sphkm.rpc.v1` — the CLI,
/// smoke tests, and walkthroughs all use this instead of hand-rolled
/// netcat framing.
fn run_query(args: &Args, scale: Scale, seed: u64) {
    let addr_owned;
    let addr: &str = if let Some(a) = args.get("addr") {
        a
    } else if let Some(f) = args.get("addr-file") {
        addr_owned = std::fs::read_to_string(f)
            .unwrap_or_else(|e| {
                eprintln!("error reading {f}: {e}");
                std::process::exit(1)
            })
            .trim()
            .to_string();
        &addr_owned
    } else {
        eprintln!("error: query needs --addr HOST:PORT or --addr-file FILE");
        usage()
    };
    let mut client = Client::connect(addr).unwrap_or_else(|e| {
        eprintln!("error connecting to {addr}: {e}");
        std::process::exit(1)
    });
    /// Unwrap an RPC result or exit 1 with the one-line client error.
    fn check<T>(r: Result<T, sphkm::serve::ClientError>) -> T {
        r.unwrap_or_else(|e| {
            eprintln!("error: {e}");
            std::process::exit(1)
        })
    }
    let op = args.get("op").unwrap_or(if args.get("data").is_some() { "query" } else { "stats" });
    match op {
        "ping" => {
            let epoch = check(client.ping());
            println!("pong (epoch {epoch})");
        }
        "stats" => {
            let (epoch, swaps, per_epoch, metrics) = check(client.stats());
            println!("epoch {epoch}, {swaps} hot swaps");
            for (e, n) in per_epoch {
                println!("  epoch {e}: {n} queries");
            }
            println!("{}", metrics.pretty(2));
        }
        "reload" => {
            let epoch = check(client.reload(args.get("path")));
            println!("reloaded: now serving epoch {epoch}");
        }
        "refit" => {
            let epoch = check(client.refit());
            println!("refit round published epoch {epoch}");
        }
        "shutdown" => {
            check(client.shutdown());
            println!("daemon acknowledged shutdown");
        }
        "query" => {
            let ds = load_dataset_spec(args.get("data").unwrap_or("demo"), scale, seed);
            let p: usize = args.get_or("top", 1).unwrap_or(1).max(1);
            // Rows per frame: one frame per batch keeps any corpus under
            // the 16 MiB frame cap; a swap can only land *between*
            // batches, never inside one.
            let batch: usize = args.get_or("batch", 1024).unwrap_or(1024).max(1);
            let n = ds.matrix.rows();
            let sw = sphkm::util::timer::Stopwatch::start();
            let mut top: Vec<Vec<(u32, f64)>> = Vec::with_capacity(n);
            let mut epochs: Vec<u64> = Vec::new();
            let mut start = 0usize;
            while start < n {
                let end = (start + batch).min(n);
                let rows: Vec<(Vec<u32>, Vec<f32>)> = (start..end)
                    .map(|i| {
                        let r = ds.matrix.row(i);
                        (r.indices.to_vec(), r.values.to_vec())
                    })
                    .collect();
                let (epoch, results) = check(client.query(p, &rows));
                if epochs.last() != Some(&epoch) {
                    epochs.push(epoch);
                }
                top.extend(results);
                start = end;
            }
            let ms = sw.ms();
            let epochs_str = epochs.iter().map(u64::to_string).collect::<Vec<_>>().join(",");
            println!(
                "queried {n} rows (top-{p}) against {addr} in {ms:.1} ms \
                 ({:.0} queries/s), served by epoch(s) {epochs_str}",
                n as f64 / (ms / 1000.0).max(1e-9),
            );
            if let Some(out) = args.get("out") {
                // Byte-identical to `assign --out` for the same model —
                // the daemon-smoke CI job diffs the two.
                let mut csv = String::from("row,rank,center,similarity\n");
                for (i, ranks) in top.iter().enumerate() {
                    for (rank, &(j, s)) in ranks.iter().enumerate() {
                        csv.push_str(&format!("{i},{rank},{j},{s}\n"));
                    }
                }
                if let Err(e) = std::fs::write(out, csv) {
                    eprintln!("could not save {out}: {e}");
                    std::process::exit(1);
                }
                println!("[csv] {out}");
            }
        }
        other => {
            eprintln!("unknown query op: {other}");
            usage()
        }
    }
}

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("");
    let scale: Scale = args
        .get_or("scale", Scale::Small)
        .unwrap_or_else(|e| { eprintln!("{e}"); usage() });
    let seed: u64 = args.get_or("seed", 42).unwrap_or(42);

    match cmd {
        "datasets" => {
            let opts = ExperimentOpts { scale, seed, ..Default::default() };
            experiments::table1(&opts);
        }
        "cluster" => {
            // --resume: continue training a persisted model. k and the
            // engine come from the model (CLI knobs still budget the run).
            // Loaded *before* the dataset: a bit-identical continuation
            // must reuse the original run's seed — both for the sampler
            // substream and for regenerating the very same named
            // synthetic corpus. An explicit --seed still overrides.
            let resume_model = args.get("resume").map(|path| {
                // A typed ModelError (bad magic, truncation, checksum)
                // is a usage-class failure: one-line diagnostic, exit 2.
                FittedModel::load(std::path::Path::new(path)).unwrap_or_else(|e| {
                    eprintln!("error loading model {path}: {e}");
                    std::process::exit(2)
                })
            });
            let seed: u64 = match (&resume_model, args.get("seed")) {
                (Some(m), None) => m.meta().seed,
                _ => seed,
            };
            let td = load_train_data(&args, scale, seed);
            let init: InitMethod = args
                .get("init")
                .unwrap_or("uniform")
                .parse()
                .unwrap_or_else(|e| { eprintln!("{e}"); usage() });
            let threads: usize = args.get_or("threads", 1).unwrap_or(1);
            let kernel: KernelChoice = args
                .get("kernel")
                .unwrap_or("auto")
                .parse()
                .unwrap_or_else(|e| { eprintln!("{e}"); usage() });
            let trunc_cli: usize = args.get_or("truncate", 0).unwrap_or(0);
            let k: usize = match &resume_model {
                Some(m) => m.k(),
                None => args.get_or("k", 10).unwrap_or(10),
            };
            let minibatch = match &resume_model {
                Some(m) => m.meta().variant == "minibatch",
                None => args.flag("minibatch"),
            };
            let variant: Variant = match &resume_model {
                // The model's variant, unless --algo explicitly overrides
                // (any exact variant continues any exact run — exactness).
                Some(m) if args.get("algo").is_none() => {
                    m.meta().variant.parse().unwrap_or(Variant::SimplifiedElkan)
                }
                _ => args
                    .get("algo")
                    .unwrap_or("simp-elkan")
                    .parse()
                    .unwrap_or_else(|e| { eprintln!("{e}"); usage() }),
            };
            let engine = if minibatch {
                // Approximate mini-batch engine (ignores --algo). When
                // resuming, defaults come from the schedule persisted in
                // the model's training state — an exact continuation must
                // reuse the original batch size and truncation — and CLI
                // flags override only when passed explicitly.
                let base = resume_model
                    .as_ref()
                    .and_then(|m| m.state())
                    .and_then(|s| s.minibatch)
                    .unwrap_or_default();
                Engine::MiniBatch(MiniBatchParams {
                    batch_size: args.get_or("batch-size", base.batch_size).unwrap_or(base.batch_size),
                    epochs: args.get_or("epochs", base.epochs).unwrap_or(base.epochs),
                    tol: args.get_or("tol", base.tol).unwrap_or(base.tol),
                    truncate: if args.get("truncate").is_none() {
                        base.truncate
                    } else if trunc_cli == 0 {
                        None
                    } else {
                        Some(trunc_cli)
                    },
                })
            } else {
                Engine::Exact(ExactParams {
                    variant,
                    // §7 synergy: pre-initialize bounds from the seeding.
                    preinit: args.flag("preinit"),
                    ..Default::default()
                })
            };
            let mut estimator = SphericalKMeans::new(k)
                .engine(engine)
                .init(init)
                .seed(seed)
                .threads(threads)
                .kernel(kernel)
                .max_iter(args.get_or("max-iter", 200).unwrap_or(200));
            if let Some(m) = &resume_model {
                estimator = estimator.warm_start(m);
                // Honest reporting: state for a different corpus — or a
                // mini-batch schedule overridden away from the persisted
                // one — cannot be continued; the estimator falls back to
                // transferring the centers into a fresh run, and the user
                // should know which of the two is happening. Mirrors the
                // estimator's own resume conditions.
                let resumable = m.state().is_some_and(|s| {
                    s.assignments.len() == td.rows()
                        && match (&engine, s.minibatch) {
                            (Engine::MiniBatch(cur), Some(orig)) => {
                                cur.batch_size == orig.batch_size
                                    && cur.truncate == orig.truncate
                            }
                            (Engine::MiniBatch(_), None) => false,
                            (Engine::Exact(_), _) => true,
                        }
                });
                if resumable {
                    println!(
                        "resuming {} model (k={k}, {} prior steps, objective={:.4})",
                        m.meta().variant,
                        m.meta().iterations,
                        m.meta().objective
                    );
                } else {
                    println!(
                        "warning: model carries no resumable state for this corpus \
                         ({} rows); transferring its centers into a fresh run",
                        td.rows()
                    );
                }
            }
            println!(
                "dataset {} ({}×{}, {:.3}% nnz{}), k={k}, algo={}, seed={seed}, \
                 threads={threads}, kernel={kernel}",
                td.name(),
                td.rows(),
                td.cols(),
                td.density() * 100.0,
                if td.source().is_disk() { ", out-of-core" } else { "" },
                if minibatch { "minibatch" } else { variant.name() },
            );
            // --audit: bound certification (see the `sphkm::audit` module).
            // The checks only exist in binaries compiled with the `audit`
            // cargo feature; in a plain build the flag is an error rather
            // than a silent no-op that would report an uncertified run as
            // certified.
            if args.flag("audit") {
                if !sphkm::audit::AUDIT_ENABLED {
                    eprintln!(
                        "error: --audit requires a binary built with the `audit` feature\n\
                         (cargo run --features audit -- cluster ...)"
                    );
                    std::process::exit(2);
                }
                println!(
                    "[audit] bound certification active: every bound-based skip is \
                     cross-checked against the exact cosine"
                );
            }
            // --trace-out: the fit as schema-stamped JSONL (run_start /
            // iter / run_end — see sphkm::obs::trace). Mirrors --audit:
            // without the `trace` feature the spans a trace would report
            // are compile-time no-ops, so the flag is an error rather
            // than a file of all-zero phase timings posing as measured.
            let mut tracer = args.get("trace-out").map(|path| {
                if !sphkm::obs::TRACE_ENABLED {
                    eprintln!(
                        "error: --trace-out requires a binary built with the `trace` feature\n\
                         (cargo run --features trace -- cluster ...)"
                    );
                    std::process::exit(2);
                }
                let fail = |e: std::io::Error| -> ! {
                    eprintln!("could not write trace {path}: {e}");
                    std::process::exit(1)
                };
                let mut w = sphkm::obs::trace::TraceWriter::create(std::path::Path::new(path))
                    .unwrap_or_else(|e| fail(e));
                w.record(
                    "run_start",
                    vec![
                        (
                            "algo".to_string(),
                            Json::Str(
                                if minibatch { "minibatch" } else { variant.name() }.to_string(),
                            ),
                        ),
                        ("k".to_string(), Json::Num(k as f64)),
                        ("n".to_string(), Json::Num(td.rows() as f64)),
                        ("d".to_string(), Json::Num(td.cols() as f64)),
                        ("threads".to_string(), Json::Num(threads as f64)),
                        ("dataset".to_string(), Json::Str(td.name().to_string())),
                        ("seed".to_string(), Json::Num(seed as f64)),
                        ("kernel".to_string(), Json::Str(kernel.to_string())),
                    ],
                )
                .unwrap_or_else(|e| fail(e));
                (w, path.to_string())
            });
            sphkm::sparse::chunked::reset_resident_peak();
            let sw = sphkm::util::timer::Stopwatch::start();
            let stats_live = args.flag("stats");
            let fitted = if stats_live || tracer.is_some() {
                // Live per-iteration progress through the observer hook.
                // The prune(terms/surv) columns are live only under
                // --kernel pruned: query terms the MaxScore walk touched
                // and centers that survived to an exact re-score.
                if stats_live {
                    println!(
                        "\niter  sims_pc  sims_cc  reassign  skips(loop/bound)  \
                         prune(terms/surv)  ms   elapsed"
                    );
                }
                let mut reported = 0usize;
                let mut observer = |s: &IterSnapshot<'_>| {
                    if stats_live {
                        println!(
                            "{:>4}  {:>8} {:>8} {:>9}  {:>7}/{:<9} {:>8}/{:<8} {:>8.2} {:>9.2}",
                            s.iteration,
                            s.stats.sims_point_center,
                            s.stats.sims_center_center,
                            s.stats.reassignments,
                            s.stats.loop_skips,
                            s.stats.bound_skips,
                            s.stats.prune_terms,
                            s.stats.prune_survivors,
                            s.stats.wall_ms,
                            s.elapsed_ms
                        );
                        // Surface audit violations as they are recorded
                        // (the fit also fails at the end with the first).
                        for v in &s.audit_violations[reported..] {
                            eprintln!("[audit] {v}");
                        }
                        reported = s.audit_violations.len();
                    }
                    if let Some((w, path)) = tracer.as_mut() {
                        let res = w.record(
                            "iter",
                            vec![
                                ("iteration".to_string(), Json::Num(s.iteration as f64)),
                                ("wall_ms".to_string(), Json::Num(s.stats.wall_ms)),
                                ("elapsed_ms".to_string(), Json::Num(s.elapsed_ms)),
                                (
                                    "sims_point_center".to_string(),
                                    Json::Num(s.stats.sims_point_center as f64),
                                ),
                                (
                                    "sims_center_center".to_string(),
                                    Json::Num(s.stats.sims_center_center as f64),
                                ),
                                (
                                    "reassignments".to_string(),
                                    Json::Num(s.stats.reassignments as f64),
                                ),
                                ("loop_skips".to_string(), Json::Num(s.stats.loop_skips as f64)),
                                (
                                    "bound_skips".to_string(),
                                    Json::Num(s.stats.bound_skips as f64),
                                ),
                                ("converged".to_string(), Json::Bool(s.converged)),
                                ("phases".to_string(), s.stats.phases.to_json()),
                            ],
                        );
                        if let Err(e) = res {
                            eprintln!("could not write trace {path}: {e}");
                            std::process::exit(1);
                        }
                    }
                    ControlFlow::Continue(())
                };
                estimator.fit_source_observed(td.source(), &mut observer)
            } else {
                estimator.fit_source(td.source())
            };
            let r = fitted.unwrap_or_else(|e| {
                eprintln!("error: {e}");
                std::process::exit(1)
            });
            let total_ms = sw.ms();
            if let Some((mut w, path)) = tracer.take() {
                let res = w
                    .record(
                        "run_end",
                        vec![
                            ("iterations".to_string(), Json::Num(r.iterations() as f64)),
                            ("objective".to_string(), Json::Num(r.objective())),
                            ("total_ms".to_string(), Json::Num(total_ms)),
                            ("converged".to_string(), Json::Bool(r.converged())),
                            ("phases".to_string(), r.stats().phase_totals().to_json()),
                        ],
                    )
                    .and_then(|()| w.finish());
                if let Err(e) = res {
                    eprintln!("could not write trace {path}: {e}");
                    std::process::exit(1);
                }
                println!("[trace] {path} ({} records)", w.records());
            }
            println!(
                "done in {:.1} ms: {} iterations, converged={}, objective={:.4}, mean similarity={:.4}",
                total_ms,
                r.iterations(),
                r.converged(),
                r.objective(),
                r.mean_similarity()
            );
            // Per-phase wall-clock breakdown (all-zero, and therefore
            // omitted, unless built with the `trace` feature).
            if stats_live {
                let totals = r.stats().phase_totals();
                if !totals.is_zero() {
                    let parts: Vec<String> = sphkm::obs::Phase::ALL
                        .iter()
                        .filter(|&&p| totals.get(p) > 0.0)
                        .map(|&p| format!("{} {:.1} ms", p.name(), totals.get(p)))
                        .collect();
                    println!(
                        "phases: {} — barrier phases cover {:.1} of {:.1} ms wall",
                        parts.join(", "),
                        totals.barrier_ms(),
                        total_ms
                    );
                }
            }
            println!(
                "similarity computations: {} point-center ({} kernel madds via {}) + \
                 {} center-center",
                r.stats().total_point_center(),
                r.stats().total_madds(),
                r.kernel(),
                r.stats().total_sims() - r.stats().total_point_center()
            );
            if r.stats().total_prune_survivors() > 0 {
                println!(
                    "pruned kernel: {} query terms walked, {} centers survived \
                     to exact re-score ({:.1} per assignment)",
                    r.stats().total_prune_terms(),
                    r.stats().total_prune_survivors(),
                    r.stats().total_prune_survivors() as f64
                        / (r.stats().total_point_center() as f64 / k as f64).max(1.0)
                );
            }
            // Memory accounting: chunk-buffer high-water mark of the
            // shard cursors (out-of-core runs only) next to what the full
            // matrix would have cost resident, plus the process-level
            // peak RSS — the headline numbers of the out-of-core path.
            if let TrainData::Disk { store, .. } = &td {
                let peak = sphkm::sparse::chunked::resident_peak_bytes();
                println!(
                    "out-of-core: {:.2} MiB peak resident point data \
                     (chunks of {} rows) vs {:.2} MiB as an in-memory matrix; \
                     {:.2} MiB shard file",
                    peak as f64 / (1024.0 * 1024.0),
                    store.chunk_rows(),
                    store.in_memory_bytes() as f64 / (1024.0 * 1024.0),
                    store.file_len() as f64 / (1024.0 * 1024.0),
                );
            }
            if let Some(rss) = sphkm::util::mem::peak_rss_bytes() {
                println!("peak RSS: {:.2} MiB", rss as f64 / (1024.0 * 1024.0));
            }
            // External quality is free whenever the input carries
            // ground-truth labels — always report it.
            if let Some(truth) = td.labels() {
                println!(
                    "vs ground-truth labels: NMI={:.4} ARI={:.4} purity={:.4}",
                    metrics::nmi(r.assignments(), truth),
                    metrics::ari(r.assignments(), truth),
                    metrics::purity(r.assignments(), truth)
                );
            }
            // --save-assign: the final row -> cluster mapping as CSV (what
            // the CI out-of-core smoke diffs between backends).
            if let Some(path) = args.get("save-assign") {
                let mut csv = String::with_capacity(12 * r.assignments().len() + 16);
                csv.push_str("row,cluster\n");
                for (i, &a) in r.assignments().iter().enumerate() {
                    csv.push_str(&format!("{i},{a}\n"));
                }
                if let Err(e) = std::fs::write(path, csv) {
                    eprintln!("could not save {path}: {e}");
                    std::process::exit(1);
                }
                println!("[csv] {path}");
            }
            if let Some(path) = args.get("save-model") {
                // FittedModel::save persists the training state too, so
                // the file can be resumed with `cluster --resume`.
                if let Err(e) = r.save(std::path::Path::new(path)) {
                    eprintln!("error saving model {path}: {e}");
                    std::process::exit(1);
                }
                println!(
                    "[model] {path} (k={}, d={}, trained by {}, {} steps)",
                    r.k(),
                    r.d(),
                    r.meta().variant,
                    r.meta().iterations
                );
            }
        }
        "convert" => {
            // Stream a libsvm text file into the chunked binary shard
            // store (`.sks`) that `cluster --mmap` trains from — bounded
            // memory at any corpus size (see sphkm::data::convert).
            let input = args.get("data").unwrap_or_else(|| usage());
            if !(input.ends_with(".svm") || input.ends_with(".libsvm")) {
                eprintln!("error: convert reads .svm/.libsvm files, got {input}");
                std::process::exit(2);
            }
            let derived;
            let out = match args.get("out") {
                Some(o) => o,
                None => {
                    derived = std::path::Path::new(input)
                        .with_extension("sks")
                        .display()
                        .to_string();
                    &derived
                }
            };
            let normalize = args.flag("normalize");
            let rep = sphkm::data::convert::convert_libsvm_to_shards(
                std::path::Path::new(input),
                std::path::Path::new(out),
                normalize,
            )
            .unwrap_or_else(|e| {
                eprintln!("error converting {input}: {e}");
                std::process::exit(1)
            });
            println!(
                "wrote {out} ({}×{}, nnz={}{}{})",
                rep.rows,
                rep.cols,
                rep.nnz,
                if rep.labeled { ", labels sidecar" } else { "" },
                if normalize { ", rows unit-normalized" } else { "" },
            );
            if rep.normalize_failures > 0 {
                eprintln!(
                    "warning: {} all-zero rows could not be normalized",
                    rep.normalize_failures
                );
            }
        }
        "gen" => {
            let ds = load_dataset(&args, scale, seed);
            let out = args.get("out").unwrap_or_else(|| usage());
            sphkm::data::io::write_libsvm(
                std::path::Path::new(out),
                &ds.matrix,
                ds.labels.as_deref(),
            )
            .unwrap_or_else(|e| {
                eprintln!("error writing {out}: {e}");
                std::process::exit(1)
            });
            println!(
                "wrote {} ({}×{}, nnz={})",
                out,
                ds.matrix.rows(),
                ds.matrix.cols(),
                ds.matrix.nnz()
            );
        }
        "bench" => {
            // Validate --kernel here so a typo gets the usage screen, as
            // on `cluster` (from_args would exit 2 without it).
            if let Some(raw) = args.get("kernel") {
                if let Err(e) = raw.parse::<KernelChoice>() {
                    eprintln!("{e}");
                    usage();
                }
            }
            let opts = ExperimentOpts::from_args(&args);
            let k: usize = args.get_or("k", 100).unwrap_or(100);
            match args.get("exp").unwrap_or("table3") {
                "table1" => { experiments::table1(&opts); }
                "table2" => { experiments::table2(&opts); }
                "table3" => { experiments::table3(&opts, args.flag("extended")); }
                "fig1" => { experiments::fig1(&opts, k); }
                "fig2" => { experiments::fig2(&opts); }
                "ablation-cc" => { experiments::ablation_cc(&opts, k.min(50)); }
                "ablation-preinit" => { experiments::ablation_preinit(&opts, k.min(50)); }
                "minibatch" => { experiments::minibatch(&opts, k.min(50)); }
                "serve" => { experiments::serve(&opts, k.min(64)); }
                other => {
                    eprintln!("unknown experiment: {other}");
                    usage()
                }
            }
        }
        "assign" => {
            run_assign(&args, scale, seed);
        }
        "serve" => {
            run_serve(&args, scale, seed);
        }
        "query" => {
            run_query(&args, scale, seed);
        }
        "report" => {
            // `report --check FILE...`: validate machine-readable outputs
            // against their committed schemas — `.jsonl` files as traces
            // (sphkm.trace.v1), `.json` files by their schema stamp
            // (sphkm.report.v1 bench reports, sphkm.metrics.v1 dumps).
            if !args.has("check") {
                usage();
            }
            let mut files: Vec<String> = Vec::new();
            if let Some(v) = args.get("check") {
                // `--check FILE` puts the first file in the flag value.
                if v != "true" {
                    files.push(v.to_string());
                }
            }
            files.extend(args.positional.iter().skip(1).cloned());
            if files.is_empty() {
                eprintln!("error: report --check needs at least one file");
                std::process::exit(2);
            }
            let mut failed = false;
            for f in &files {
                let verdict: Result<String, String> = std::fs::read_to_string(f)
                    .map_err(|e| e.to_string())
                    .and_then(|text| {
                        if f.ends_with(".jsonl") {
                            return sphkm::obs::trace::validate_trace(&text)
                                .map(|n| format!("valid {} ({n} records)", sphkm::obs::TRACE_SCHEMA));
                        }
                        let doc = Json::parse(&text).map_err(|e| e.to_string())?;
                        let schema = doc
                            .get("schema")
                            .and_then(Json::as_str)
                            .unwrap_or("")
                            .to_string();
                        if schema == sphkm::util::report::REPORT_SCHEMA {
                            sphkm::util::report::RunReport::validate(&doc)
                                .map(|()| format!("valid {schema}"))
                        } else if schema == sphkm::obs::metrics::METRICS_SCHEMA {
                            doc.get("metrics")
                                .and_then(Json::as_obj)
                                .map(|_| format!("valid {schema}"))
                                .ok_or_else(|| "missing object field \"metrics\"".to_string())
                        } else {
                            Err(format!("unknown or missing schema {schema:?}"))
                        }
                    });
                match verdict {
                    Ok(msg) => println!("{f}: {msg}"),
                    Err(e) => {
                        eprintln!("{f}: INVALID: {e}");
                        failed = true;
                    }
                }
            }
            if failed {
                std::process::exit(1);
            }
        }
        "sweep" => {
            let path = args.get("config").unwrap_or_else(|| usage());
            let cfg = sphkm::util::config::Config::load(std::path::Path::new(path))
                .unwrap_or_else(|e| {
                    eprintln!("config error: {e}");
                    std::process::exit(1)
                });
            run_sweep(&cfg);
        }
        "info" => {
            println!("spherical-kmeans v{}", env!("CARGO_PKG_VERSION"));
            println!("paper: Accelerating Spherical k-Means (Schubert, Lang, Feher; SISAP 2021)");
            println!("variants: {}", Variant::ALL.map(|v| v.name()).join(", "));
            let art = std::path::Path::new("artifacts");
            println!(
                "PJRT artifacts: {}",
                if sphkm::runtime::artifacts_available(art) {
                    "available (artifacts/)"
                } else {
                    "not built (run `make artifacts`)"
                }
            );
        }
        _ => usage(),
    }
}
