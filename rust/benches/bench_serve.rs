//! Serving-path acceptance benchmark: the full train → persist → serve
//! pipeline on sparse synthetic text, measuring queries/sec and
//! multiply-adds for the MaxScore-pruned traversal against the exhaustive
//! gather baseline.
//!
//! Acceptance bars (asserted):
//! * `Model::save` → `Model::load` round-trips the centers **bit-exactly**.
//! * The pruned top-p answers are **bit-identical** to exhaustive gather
//!   for every thread count.
//! * On <5%-density text at k = 64 the pruned traversal performs
//!   **strictly fewer multiply-adds** than exhaustive gather.
//!
//! Each batch-query measurement repeats `--warmup` untimed + `--runs`
//! timed times (answers are deterministic; only wall-clock varies), and a
//! final timed pass reports exact per-query latency percentiles from the
//! engine's log-scale histogram. Results are written to
//! `BENCH_serve.json` at the repository root in the shared
//! `sphkm.report.v1` envelope (see `sphkm::util::report`, validated by
//! `sphkm report --check`).
//!
//! ```text
//! cargo bench --bench bench_serve -- [--rows 8000] [--k 64] [--top 5]
//!     [--seed 42] [--truncate 64] [--runs 1] [--warmup 0]
//! ```

// Bench and test targets favour readable literal casts and exact
// (bit-level) float assertions; the workspace clippy warnings on
// those patterns are aimed at library code.
#![allow(clippy::cast_possible_truncation, clippy::float_cmp)]

use sphkm::data::synth::SynthConfig;
use sphkm::kmeans::{Engine, KernelChoice, MiniBatchParams, SphericalKMeans};
use sphkm::model::Model;
use sphkm::serve::{QueryEngine, ServeConfig, ServeMode};
use sphkm::util::benchkit::BenchOpts;
use sphkm::util::cli::Args;
use sphkm::util::json::Json;
use sphkm::util::report::{timing_fields, RunReport};
use sphkm::util::timer::{Stopwatch, TimingStats};

fn main() {
    let args = Args::from_env();
    let rows: usize = args.get_or("rows", 8_000).unwrap_or(8_000);
    let k: usize = args.get_or("k", 64).unwrap_or(64);
    let p: usize = args.get_or("top", 5).unwrap_or(5);
    let seed: u64 = args.get_or("seed", 42).unwrap_or(42);
    let truncate: usize = args.get_or("truncate", 64).unwrap_or(64);
    // Each measurement is a full batch over the corpus: default to a
    // single timed run with no warmup (the historical behaviour).
    let mut opts = BenchOpts::from_args(&args);
    if !args.has("runs") {
        opts.runs = 1;
    }
    if !args.has("warmup") {
        opts.warmup = 0;
    }

    let ds = SynthConfig {
        name: "serve-bench".into(),
        n_docs: rows,
        vocab: 24_000,
        topics: k.max(2),
        doc_len_mean: 60.0,
        doc_len_sigma: 0.4,
        topic_strength: 0.65,
        shared_vocab_frac: 0.2,
        zipf_s: 1.05,
        anomaly_frac: 0.0,
        tfidf: Default::default(),
    }
    .generate(seed);
    let density = ds.matrix.density();
    assert!(
        density < 0.05,
        "acceptance corpus must be <5% dense (got {:.3}%)",
        density * 100.0
    );
    println!(
        "# serve bench — {} rows × {} dims ({:.3}% nnz), k={k}, top-{p}, runs={} (+{} warmup)",
        ds.matrix.rows(),
        ds.matrix.cols(),
        density * 100.0,
        opts.runs,
        opts.warmup
    );

    let mut report = RunReport::new("serve");
    report.note("madds are exact and run-invariant; ms columns are mean over --runs");
    for (key, v) in [
        ("rows", rows),
        ("k", k),
        ("top", p),
        ("truncate", truncate),
        ("runs", opts.runs),
        ("warmup", opts.warmup),
    ] {
        report.config_num(key, v as f64);
    }
    report.config_num("seed", seed as f64);
    report.config_num("density", density);

    // Train a sparse-centroid model and round-trip it through persistence.
    let sw = Stopwatch::start();
    let fitted = SphericalKMeans::new(k)
        .engine(Engine::MiniBatch(MiniBatchParams {
            batch_size: 1024,
            epochs: 4,
            truncate: Some(truncate),
            ..Default::default()
        }))
        .seed(seed)
        .threads(0)
        .kernel(KernelChoice::Inverted)
        .fit(&ds.matrix)
        .expect("bench configuration is valid");
    println!("# trained in {:.0} ms (objective {:.2})", sw.ms(), fitted.objective());
    let saved = fitted.to_model();
    let path =
        std::env::temp_dir().join(format!("sphkm-bench-serve-{}-{seed}.spkm", std::process::id()));
    saved.save(&path).expect("save model");
    let model = Model::load(&path).expect("load model");
    std::fs::remove_file(&path).ok();
    for j in 0..k {
        for (a, b) in saved.centers().row(j).iter().zip(model.centers().row(j)) {
            assert_eq!(a.to_bits(), b.to_bits(), "center {j}: persistence round trip");
        }
    }
    println!(
        "# model: {} center nnz ({:.3}% dense), round trip bit-exact — OK",
        model.center_nnz(),
        model.center_density() * 100.0
    );

    println!(
        "{:<10} {:>8} {:>10} {:>10} {:>16} {:>14}",
        "mode", "threads", "ms", "qps", "madds", "pruned/query"
    );
    let mut baseline: Option<Vec<Vec<(u32, f64)>>> = None;
    let mut madds = (0u64, 0u64); // (exhaustive, pruned) at threads = 1
    for threads in [1usize, 0] {
        let engine = QueryEngine::new(
            model.clone(),
            &ServeConfig { mode: ServeMode::Pruned, threads },
        );
        // Batch answers are deterministic, so repetitions reproduce the
        // same results/stats and only add wall-clock samples; the last
        // repetition feeds the bit-identity asserts below.
        let mut ex_samples = Vec::new();
        let mut ex_out = None;
        for it in 0..opts.warmup + opts.runs.max(1) {
            let sw = Stopwatch::start();
            let out = engine.top_p_batch_exhaustive(&ds.matrix, p);
            let ms = sw.ms();
            if it >= opts.warmup {
                ex_samples.push(ms);
            }
            ex_out = Some(out);
        }
        let (ex, ex_stats) = ex_out.expect("at least one run");
        let ex_t = TimingStats::from_ms(&ex_samples);
        let ex_ms = ex_t.mean_ms;
        let mut pr_samples = Vec::new();
        let mut pr_out = None;
        for it in 0..opts.warmup + opts.runs.max(1) {
            let sw = Stopwatch::start();
            let out = engine.top_p_batch_pruned(&ds.matrix, p);
            let ms = sw.ms();
            if it >= opts.warmup {
                pr_samples.push(ms);
            }
            pr_out = Some(out);
        }
        let (pr, pr_stats) = pr_out.expect("at least one run");
        let pr_t = TimingStats::from_ms(&pr_samples);
        let pr_ms = pr_t.mean_ms;

        // Bit-identity of the pruned traversal, per thread count, and of
        // every thread count against the serial baseline.
        assert_eq!(ex.len(), pr.len());
        for (i, (a, b)) in ex.iter().zip(&pr).enumerate() {
            assert_eq!(a.len(), b.len(), "row {i}");
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.0, y.0, "row {i}: center ids");
                assert_eq!(x.1.to_bits(), y.1.to_bits(), "row {i}: similarities");
            }
        }
        if let Some(base) = baseline.as_ref() {
            assert_eq!(base, &pr, "threads={threads} must match serial bitwise");
            assert_eq!(madds, (ex_stats.madds, pr_stats.madds), "thread-invariant madds");
        } else {
            baseline = Some(pr.clone());
            madds = (ex_stats.madds, pr_stats.madds);
        }
        let n = ex_stats.queries.max(1) as f64;
        let mut row = vec![
            ("threads".to_string(), Json::Num(threads as f64)),
            ("queries".to_string(), Json::Num(ex_stats.queries as f64)),
            ("exhaustive_madds".to_string(), Json::Num(ex_stats.madds as f64)),
            ("pruned_madds".to_string(), Json::Num(pr_stats.madds as f64)),
            (
                "exhaustive_qps".to_string(),
                Json::Num(ex_stats.queries as f64 / (ex_ms / 1000.0).max(1e-9)),
            ),
            (
                "pruned_qps".to_string(),
                Json::Num(pr_stats.queries as f64 / (pr_ms / 1000.0).max(1e-9)),
            ),
            (
                "centers_pruned_per_query".to_string(),
                Json::Num(pr_stats.centers_pruned as f64 / n),
            ),
        ];
        row.extend(timing_fields("exhaustive", &ex_t));
        row.extend(timing_fields("pruned", &pr_t));
        report.push_result(row);
        for (mode, ms, stats) in [("exhaustive", ex_ms, ex_stats), ("pruned", pr_ms, pr_stats)] {
            println!(
                "{:<10} {:>8} {:>10.1} {:>10.0} {:>16} {:>14.1}",
                mode,
                threads,
                ms,
                stats.queries as f64 / (ms / 1000.0).max(1e-9),
                stats.madds,
                stats.centers_pruned as f64 / n
            );
        }
    }
    // One timed pass through the histogram-instrumented batch path: exact
    // per-query latency percentiles, and answers bit-identical to the
    // serial baseline.
    let engine = QueryEngine::new(
        model.clone(),
        &ServeConfig { mode: ServeMode::Pruned, threads: 0 },
    );
    let (timed, _, hist) = engine.top_p_batch_timed(&ds.matrix, p);
    assert_eq!(
        baseline.as_ref(),
        Some(&timed),
        "timed batch must match serial baseline bitwise"
    );
    println!(
        "# pruned query latency: p50={:.4} ms, p95={:.4} ms, p99={:.4} ms \
         (min {:.4}, mean {:.4}, max {:.4}; {} samples)",
        hist.quantile_ms(0.50),
        hist.quantile_ms(0.95),
        hist.quantile_ms(0.99),
        hist.min_ns() as f64 / 1e6,
        hist.mean_ns() / 1e6,
        hist.max_ns() as f64 / 1e6,
        hist.count()
    );
    report.push_result(vec![
        ("latency_samples".to_string(), Json::Num(hist.count() as f64)),
        ("latency_p50_ms".to_string(), Json::Num(hist.quantile_ms(0.50))),
        ("latency_p95_ms".to_string(), Json::Num(hist.quantile_ms(0.95))),
        ("latency_p99_ms".to_string(), Json::Num(hist.quantile_ms(0.99))),
        ("latency_mean_ms".to_string(), Json::Num(hist.mean_ns() / 1e6)),
        ("latency_max_ms".to_string(), Json::Num(hist.max_ns() as f64 / 1e6)),
    ]);

    let (ex_madds, pr_madds) = madds;
    assert!(
        pr_madds < ex_madds,
        "pruned traversal must do strictly fewer madds ({pr_madds} vs {ex_madds})"
    );
    println!(
        "# acceptance: bit-exact persistence; pruned top-{p} bit-identical to exhaustive \
         at every thread count; {:.1}x fewer madds ({pr_madds} vs {ex_madds}) — OK",
        ex_madds as f64 / pr_madds.max(1) as f64
    );

    let json_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_serve.json");
    debug_assert!(
        RunReport::check_str(&report.to_json().pretty(2)).is_ok(),
        "emitting an invalid report"
    );
    match report.save(&json_path) {
        Ok(()) => println!("# wrote {}", json_path.display()),
        Err(e) => println!("# could not write {}: {e}", json_path.display()),
    }
}
