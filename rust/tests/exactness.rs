//! The paper's correctness claim: every accelerated variant (Elkan,
//! Simplified Elkan, Hamerly, Simplified Hamerly — and our Yinyang
//! extension) is **exact**: started from the same initial centers it must
//! converge to the same assignment and objective as the standard algorithm.
//!
//! These tests run the full matrix of (dataset kind × k × seed × variant)
//! at tiny scale and compare against Standard, all through the
//! `SphericalKMeans` estimator front door.

// Bench and test targets favour readable literal casts and exact
// (bit-level) float assertions; the workspace clippy warnings on
// those patterns are aimed at library code.
#![allow(clippy::cast_possible_truncation, clippy::float_cmp)]

use sphkm::data::datasets::{self, Scale};
use sphkm::data::synth::SynthConfig;
use sphkm::data::Dataset;
use sphkm::init::{seed_centers, InitMethod};
use sphkm::kmeans::{Engine, ExactParams, KMeansResult, Variant};
use sphkm::sparse::{CsrMatrix, DenseMatrix};
use sphkm::SphericalKMeans;

/// One estimator fit from shared explicit centers — the migration of the
/// old `run_with_centers` test idiom.
fn fit_from(data: &CsrMatrix, centers: DenseMatrix, est: SphericalKMeans) -> KMeansResult {
    est.warm_start_centers(centers)
        .fit(data)
        .expect("test configuration is valid")
        .into_result()
}

fn exactness_on(ds: &Dataset, ks: &[usize], seeds: &[u64]) {
    for &k in ks {
        let k = k.min(ds.matrix.rows() / 2).max(2);
        for &seed in seeds {
            let init = seed_centers(&ds.matrix, k, &InitMethod::Uniform, seed);
            let baseline = fit_from(
                &ds.matrix,
                init.centers.clone(),
                SphericalKMeans::new(k).variant(Variant::Standard),
            );
            assert!(
                baseline.converged,
                "{}: standard did not converge (k={k}, seed={seed})",
                ds.name
            );
            for variant in [
                Variant::Elkan,
                Variant::SimplifiedElkan,
                Variant::Hamerly,
                Variant::SimplifiedHamerly,
                Variant::Yinyang,
                Variant::Exponion,
            ] {
                let r = fit_from(
                    &ds.matrix,
                    init.centers.clone(),
                    SphericalKMeans::new(k).variant(variant),
                );
                assert!(
                    r.converged,
                    "{}: {} did not converge (k={k}, seed={seed})",
                    ds.name,
                    variant.name()
                );
                assert_eq!(
                    r.assignments,
                    baseline.assignments,
                    "{}: {} assignments differ from Standard (k={k}, seed={seed})",
                    ds.name,
                    variant.name()
                );
                assert!(
                    (r.objective - baseline.objective).abs() < 1e-6 * (1.0 + baseline.objective),
                    "{}: {} objective {} vs standard {} (k={k}, seed={seed})",
                    ds.name,
                    variant.name(),
                    r.objective,
                    baseline.objective
                );
                // Pruned variants must never compute MORE point-center sims
                // than the standard algorithm needed.
                assert!(
                    r.stats.total_point_center() <= baseline.stats.total_point_center(),
                    "{}: {} computed more sims than Standard",
                    ds.name,
                    variant.name()
                );
            }
        }
    }
}

#[test]
fn exact_on_synthetic_corpus() {
    let ds = SynthConfig::small_demo().generate(11);
    exactness_on(&ds, &[2, 5, 16], &[1, 2, 3]);
}

#[test]
fn exact_on_dblp_author_conf() {
    let ds = datasets::dblp_author_conf(Scale::Tiny, 5);
    exactness_on(&ds, &[2, 10, 30], &[4, 5]);
}

#[test]
fn exact_on_dblp_conf_author_high_dim() {
    let ds = datasets::dblp_conf_author(Scale::Tiny, 5);
    exactness_on(&ds, &[2, 10], &[6, 7]);
}

#[test]
fn exact_on_newsgroups_with_anomalies() {
    let ds = datasets::newsgroups(Scale::Tiny, 5);
    exactness_on(&ds, &[5, 20], &[8]);
}

#[test]
fn exact_with_kmeanspp_seeding() {
    let ds = SynthConfig::small_demo().generate(13);
    for method in [
        InitMethod::KMeansPP { alpha: 1.0 },
        InitMethod::AfkMc2 { alpha: 1.0, chain: 30 },
    ] {
        let init = seed_centers(&ds.matrix, 8, &method, 21);
        let baseline = fit_from(
            &ds.matrix,
            init.centers.clone(),
            SphericalKMeans::new(8).variant(Variant::Standard),
        );
        for variant in [Variant::Elkan, Variant::SimplifiedHamerly, Variant::Yinyang, Variant::Exponion] {
            let r = fit_from(
                &ds.matrix,
                init.centers.clone(),
                SphericalKMeans::new(8).variant(variant),
            );
            assert_eq!(r.assignments, baseline.assignments, "{:?}", variant);
        }
    }
}

#[test]
fn exact_with_tight_hamerly_bound() {
    // The beyond-paper guarded min-p rule must also be exact.
    let ds = datasets::dblp_author_conf(Scale::Tiny, 9);
    for &k in &[2usize, 10, 30] {
        let init = seed_centers(&ds.matrix, k, &InitMethod::Uniform, 31);
        let baseline = fit_from(
            &ds.matrix,
            init.centers.clone(),
            SphericalKMeans::new(k).variant(Variant::Standard),
        );
        for variant in [Variant::Hamerly, Variant::SimplifiedHamerly, Variant::Yinyang, Variant::Exponion] {
            let tight = fit_from(
                &ds.matrix,
                init.centers.clone(),
                SphericalKMeans::new(k).engine(Engine::Exact(ExactParams {
                    variant,
                    tight_bound: true,
                    ..Default::default()
                })),
            );
            assert_eq!(tight.assignments, baseline.assignments);
            // The tight rule must prune at least as well as Eq. 9.
            let loose = fit_from(
                &ds.matrix,
                init.centers.clone(),
                SphericalKMeans::new(k).variant(variant),
            );
            assert!(
                tight.stats.total_point_center() <= loose.stats.total_point_center(),
                "{}: tight bound pruned less than Eq.9 (k={k})",
                variant.name()
            );
        }
    }
}

/// A synthetic TF-IDF corpus big enough for several row shards
/// (`SHARD_ROWS = 256`), so `threads = 4` genuinely crosses shard
/// boundaries and exercises the deferred-move merge.
fn parallel_test_corpus(seed: u64) -> Dataset {
    let mut cfg = SynthConfig::small_demo();
    cfg.name = "par-synth".into();
    cfg.n_docs = 1200;
    cfg.generate(seed)
}

#[test]
fn parallel_matches_serial() {
    // The shard-determinism contract (kmeans module docs): for every
    // variant, the sharded parallel path must produce **bit-identical**
    // assignments and objectives to the serial path, for any thread count.
    let ds = parallel_test_corpus(29);
    for &k in &[2usize, 8] {
        let init = seed_centers(&ds.matrix, k, &InitMethod::Uniform, 3);
        for variant in Variant::ALL {
            let serial = fit_from(
                &ds.matrix,
                init.centers.clone(),
                SphericalKMeans::new(k).variant(variant).threads(1),
            );
            assert!(serial.converged, "{} did not converge", variant.name());
            for &threads in &[4usize, 0] {
                let par = fit_from(
                    &ds.matrix,
                    init.centers.clone(),
                    SphericalKMeans::new(k).variant(variant).threads(threads),
                );
                assert_eq!(
                    par.assignments,
                    serial.assignments,
                    "{}: assignments diverge at threads={threads}, k={k}",
                    variant.name()
                );
                assert_eq!(
                    par.objective.to_bits(),
                    serial.objective.to_bits(),
                    "{}: objective not bit-identical at threads={threads}, k={k}",
                    variant.name()
                );
                assert_eq!(par.iterations, serial.iterations, "{}", variant.name());
                assert_eq!(par.converged, serial.converged, "{}", variant.name());
            }
        }
    }
}

#[test]
fn parallel_shard_merged_stats_equal_serial_counts() {
    // Shard-merged IterStats must equal the serial counters exactly,
    // iteration by iteration — pruning decisions and similarity charges
    // may not depend on the thread count.
    let ds = parallel_test_corpus(31);
    let k = 10;
    let init = seed_centers(&ds.matrix, k, &InitMethod::Uniform, 5);
    for variant in Variant::ALL {
        let serial = fit_from(
            &ds.matrix,
            init.centers.clone(),
            SphericalKMeans::new(k).variant(variant).threads(1),
        );
        let par = fit_from(
            &ds.matrix,
            init.centers.clone(),
            SphericalKMeans::new(k).variant(variant).threads(4),
        );
        assert_eq!(
            par.stats.iters.len(),
            serial.stats.iters.len(),
            "{}: iteration counts differ",
            variant.name()
        );
        for (it, (p, s)) in par.stats.iters.iter().zip(&serial.stats.iters).enumerate() {
            assert_eq!(p.sims_point_center, s.sims_point_center, "{} iter {it}", variant.name());
            assert_eq!(p.sims_center_center, s.sims_center_center, "{} iter {it}", variant.name());
            assert_eq!(p.reassignments, s.reassignments, "{} iter {it}", variant.name());
            assert_eq!(p.loop_skips, s.loop_skips, "{} iter {it}", variant.name());
            assert_eq!(p.bound_skips, s.bound_skips, "{} iter {it}", variant.name());
        }
        assert_eq!(par.stats.total_sims(), serial.stats.total_sims(), "{}", variant.name());
        assert_eq!(par.stats.bound_bytes, serial.stats.bound_bytes, "{}", variant.name());
    }
}

#[test]
fn parallel_matches_serial_with_preinit_bounds() {
    // The §7 preinit path (seeded bounds, skipped initial pass) must obey
    // the same thread-count invariance.
    let ds = parallel_test_corpus(37);
    let k = 9;
    let preinit_est = |variant, threads| {
        SphericalKMeans::new(k)
            .engine(Engine::Exact(ExactParams {
                variant,
                preinit: true,
                ..Default::default()
            }))
            .init(InitMethod::KMeansPP { alpha: 1.0 })
            .seed(11)
            .threads(threads)
    };
    for variant in [Variant::SimplifiedElkan, Variant::SimplifiedHamerly, Variant::Yinyang] {
        let serial = preinit_est(variant, 1).fit(&ds.matrix).unwrap().into_result();
        let par = preinit_est(variant, 4).fit(&ds.matrix).unwrap().into_result();
        assert_eq!(par.assignments, serial.assignments, "{}", variant.name());
        assert_eq!(
            par.objective.to_bits(),
            serial.objective.to_bits(),
            "{}",
            variant.name()
        );
        assert_eq!(par.stats.iters[0].sims_point_center, 0, "{}", variant.name());
    }
}

#[test]
fn degenerate_k_equals_one_and_k_equals_n() {
    let ds = SynthConfig::small_demo().generate(17);
    let n = ds.matrix.rows();
    for variant in Variant::ALL {
        // k = 1: everything in one cluster, converges immediately. The
        // top2 runner-up clamp (cosine floor, no sentinel) must hold on
        // both the serial and the sharded parallel path.
        for threads in [1usize, 4] {
            let r = SphericalKMeans::new(1)
                .variant(variant)
                .seed(3)
                .threads(threads)
                .fit(&ds.matrix)
                .unwrap();
            assert!(r.converged(), "{} threads={threads}", variant.name());
            assert!(r.assignments().iter().all(|&a| a == 0));
        }
        // k = n/3 (large k relative to n).
        let k = n / 3;
        let r = SphericalKMeans::new(k)
            .variant(variant)
            .seed(3)
            .fit(&ds.matrix)
            .unwrap();
        assert!(r.converged(), "{} large-k", variant.name());
        assert!(r.assignments().iter().all(|&a| (a as usize) < k));
    }
}

#[test]
fn bounds_hold_during_entire_run() {
    // White-box invariant via public API: after convergence the lower
    // bound equality l(i) = ⟨x, c⟩ must reproduce the reported objective.
    let ds = SynthConfig::small_demo().generate(19);
    let r = SphericalKMeans::new(6)
        .variant(Variant::Elkan)
        .seed(5)
        .fit(&ds.matrix)
        .unwrap();
    let recomputed = sphkm::metrics::objective(&ds.matrix, r.assignments(), r.centers());
    assert!((recomputed - r.objective()).abs() < 1e-9 * (1.0 + r.objective()));
}

#[test]
fn preinit_bounds_from_kmeanspp_are_exact_and_cheaper() {
    // §7 synergy: k-means++ collects the N×k similarity matrix during
    // seeding; the preinit engine knob consumes it, skips the initial
    // O(N·k) pass, and must still produce exactly the same clustering as
    // the plain path.
    use sphkm::init::seed_centers_with_bounds;
    let ds = datasets::simpsons_wiki(Scale::Tiny, 7);
    let k = 12;
    let method = InitMethod::KMeansPP { alpha: 1.0 };
    let init = seed_centers_with_bounds(&ds.matrix, k, &method, 17);
    assert!(init.sim_matrix.is_some(), "k-means++ should collect bounds");

    let seeded_est = |variant, preinit| {
        SphericalKMeans::new(k)
            .engine(Engine::Exact(ExactParams { variant, preinit, ..Default::default() }))
            .init(method)
            .seed(17)
    };
    // Baseline: same seeded assignment, standard algorithm.
    let baseline = seeded_est(Variant::Standard, true)
        .fit(&ds.matrix)
        .unwrap()
        .into_result();
    for variant in [
        Variant::Elkan,
        Variant::SimplifiedElkan,
        Variant::Hamerly,
        Variant::SimplifiedHamerly,
        Variant::Yinyang,
        Variant::Exponion,
    ] {
        let seeded = seeded_est(variant, true).fit(&ds.matrix).unwrap().into_result();
        assert_eq!(
            seeded.assignments,
            baseline.assignments,
            "{} with preinit bounds diverged",
            variant.name()
        );
        // Iteration 0 must be free of point-center similarities.
        assert_eq!(
            seeded.stats.iters[0].sims_point_center, 0,
            "{}: initial pass was not skipped",
            variant.name()
        );
        // And the whole run must be cheaper than the non-seeded variant
        // (same seeding, plain bound initialization).
        let plain = fit_from(
            &ds.matrix,
            init.centers.clone(),
            SphericalKMeans::new(k).variant(variant),
        );
        assert!(
            seeded.stats.total_point_center() < plain.stats.total_point_center(),
            "{}: preinit did not save similarities",
            variant.name()
        );
    }
}

#[test]
fn preinit_absent_for_uniform_seeding() {
    use sphkm::init::seed_centers_with_bounds;
    let ds = SynthConfig::small_demo().generate(23);
    let init = seed_centers_with_bounds(&ds.matrix, 5, &InitMethod::Uniform, 3);
    assert!(init.sim_matrix.is_none());
    // The preinit knob is a no-op for seedings that collect no bounds —
    // the fit still works, just without the skip.
    let r = SphericalKMeans::new(5)
        .engine(Engine::Exact(ExactParams {
            variant: Variant::SimplifiedElkan,
            preinit: true,
            ..Default::default()
        }))
        .seed(3)
        .fit(&ds.matrix)
        .unwrap();
    assert!(r.converged());
}
