//! Sparse-matrix file I/O: SVMlight/libsvm and MatrixMarket coordinate
//! formats, plus a labels sidecar. Lets users run the CLI on their own
//! corpora and lets the experiment drivers cache generated datasets.

use crate::sparse::{CsrMatrix, SparseVec};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

/// I/O errors.
#[derive(Debug, thiserror::Error)]
pub enum IoError {
    /// Underlying filesystem error.
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    /// Malformed file contents.
    #[error("parse error at line {line}: {msg}")]
    Parse {
        /// 1-based line number.
        line: usize,
        /// Description.
        msg: String,
    },
    /// A feature index too large for the `u32` column space the CSR
    /// containers (and the shard store) use. Rejected with the exact value
    /// instead of a lossy cast silently aliasing columns.
    #[error("parse error at line {line}: feature index {index} exceeds the u32 index space")]
    IndexOverflow {
        /// 1-based line number.
        line: usize,
        /// The offending index as written in the file.
        index: u64,
    },
}

fn perr<T>(line: usize, msg: impl Into<String>) -> Result<T, IoError> {
    Err(IoError::Parse { line, msg: msg.into() })
}

/// Outcome of parsing one libsvm line (see [`parse_libsvm_line`]).
pub(crate) enum ParsedLine {
    /// Blank or comment-only line — contributes no row.
    Skip,
    /// A data row; its `(index, value)` pairs were appended to the
    /// caller's buffer.
    Row {
        /// The leading label token, if the line carried one.
        label: Option<f64>,
    },
}

/// Parse one libsvm line (`[label] idx:val idx:val … [# comment]`) into
/// `pairs`, which the caller clears and reuses across lines — the single
/// bounded-memory parse path shared by [`read_libsvm_from`] and the shard
/// converter ([`crate::data::convert`]). Indices are parsed in `u64` and
/// values above `u32::MAX` rejected as [`IoError::IndexOverflow`]; `lno`
/// is 1-based.
pub(crate) fn parse_libsvm_line(
    line: &str,
    lno: usize,
    pairs: &mut Vec<(u32, f32)>,
) -> Result<ParsedLine, IoError> {
    let line = line.split('#').next().unwrap_or("").trim();
    if line.is_empty() {
        return Ok(ParsedLine::Skip);
    }
    let mut label: Option<f64> = None;
    for (t, tok) in line.split_whitespace().enumerate() {
        if let Some((i, v)) = tok.split_once(':') {
            let idx: u64 = match i.parse() {
                Ok(x) => x,
                Err(_) => return perr(lno, format!("bad index {i:?}")),
            };
            if idx > u32::MAX as u64 {
                return Err(IoError::IndexOverflow { line: lno, index: idx });
            }
            let val: f32 = match v.parse() {
                Ok(x) => x,
                Err(_) => return perr(lno, format!("bad value {v:?}")),
            };
            pairs.push((idx as u32, val));
        } else if t == 0 {
            match tok.parse::<f64>() {
                // Normalize -0.0 so it cannot split into its own class.
                Ok(x) if x.is_finite() => label = Some(if x == 0.0 { 0.0 } else { x }),
                _ => return perr(lno, format!("bad label {tok:?}")),
            }
        } else {
            return perr(lno, format!("unexpected token {tok:?}"));
        }
    }
    Ok(ParsedLine::Row { label })
}

/// Validate one parsed row in place: sort by index, reject duplicates and
/// non-finite values (same contract as [`SparseVec::try_from_pairs`], same
/// error substrings), then drop explicit zeros. Shared by the reader and
/// the shard converter so both ingest paths accept exactly the same files.
pub(crate) fn validate_row_pairs(
    pairs: &mut Vec<(u32, f32)>,
    lno: usize,
) -> Result<(), IoError> {
    pairs.sort_unstable_by_key(|p| p.0);
    for w in pairs.windows(2) {
        if w[0].0 == w[1].0 {
            return perr(lno, format!("duplicate index {}", w[0].0));
        }
    }
    if let Some(&(_, v)) = pairs.iter().find(|&&(_, v)| !v.is_finite()) {
        return perr(lno, format!("non-finite value {v}"));
    }
    pairs.retain(|&(_, v)| v != 0.0);
    Ok(())
}

/// Remap arbitrary numeric labels to dense `0..k` class ids in ascending
/// numeric order; `None` unless every row carried a label.
pub(crate) fn remap_labels(labels: &[f64], all_labeled: bool) -> Option<Vec<u32>> {
    if !all_labeled || labels.is_empty() {
        return None;
    }
    let mut uniq: Vec<f64> = labels.to_vec();
    uniq.sort_unstable_by(f64::total_cmp);
    uniq.dedup();
    Some(
        labels
            .iter()
            .map(|l| uniq.binary_search_by(|x| x.total_cmp(l)).unwrap() as u32)
            .collect(),
    )
}

/// Read an SVMlight/libsvm file: `[label] idx:val idx:val …` per line.
/// Returns the matrix and the labels (if every line carries one).
/// One-based and zero-based indices are both accepted (auto-detected:
/// if any index is 0, indices are treated as zero-based).
///
/// Labels are parsed as **floats** — standard libsvm files carry class
/// labels like `1.0` / `-1.0` (and regression targets) — and remapped to
/// dense `0..k` class ids in ascending numeric order. Duplicate feature
/// indices within a line, non-finite values, and feature indices beyond
/// `u32::MAX` are rejected with typed errors: silently accepting them
/// would hide corrupt files, and the resulting rows feed the sorted-merge
/// dot products.
///
/// The parse is fully streaming — see [`read_libsvm_from`].
pub fn read_libsvm(path: &Path) -> Result<(CsrMatrix, Option<Vec<u32>>), IoError> {
    read_libsvm_from(BufReader::new(std::fs::File::open(path)?))
}

/// Streaming core of [`read_libsvm`]: one pass over any [`BufRead`],
/// building the CSR arrays directly. Transient memory is one line and one
/// row of pairs — no whole-file slurp and no per-row `Vec` graph — so
/// peak memory is the output matrix plus O(longest line). The shard
/// converter ([`crate::data::convert`]) shares the same per-line parse
/// and validation helpers but streams the arrays to disk instead of
/// collecting them, in truly bounded memory.
pub fn read_libsvm_from<R: BufRead>(
    mut reader: R,
) -> Result<(CsrMatrix, Option<Vec<u32>>), IoError> {
    let mut indptr: Vec<usize> = vec![0];
    let mut indices: Vec<u32> = Vec::new();
    let mut values: Vec<f32> = Vec::new();
    let mut labels: Vec<f64> = Vec::new();
    let mut all_labeled = true;
    let mut saw_zero = false;
    let mut max_idx = 0u32;
    let mut line = String::new();
    let mut pairs: Vec<(u32, f32)> = Vec::new();
    let mut lno = 0usize;
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            break;
        }
        lno += 1;
        pairs.clear();
        let label = match parse_libsvm_line(&line, lno, &mut pairs)? {
            ParsedLine::Skip => continue,
            ParsedLine::Row { label } => label,
        };
        // Column-space detection looks at the raw pairs *before* explicit
        // zeros are dropped: a `7:0` entry still widens the matrix, as it
        // always has.
        for &(i, _) in &pairs {
            saw_zero |= i == 0;
            max_idx = max_idx.max(i);
        }
        validate_row_pairs(&mut pairs, lno)?;
        all_labeled &= label.is_some();
        labels.push(label.unwrap_or(0.0));
        for &(i, v) in &pairs {
            indices.push(i);
            values.push(v);
        }
        indptr.push(indices.len());
    }
    // Auto-detect 1-based indexing; the subtraction below is safe because
    // `offset == 1` implies no index was 0. Computed in u64 so a file
    // using index u32::MAX cannot overflow the width calculation.
    let offset: u32 = if saw_zero { 0 } else { 1 };
    let cols = usize::try_from((max_idx as u64 + 1).saturating_sub(offset as u64))
        .expect("column count fits usize")
        .max(1);
    if offset == 1 {
        for i in &mut indices {
            *i -= 1;
        }
    }
    let rows = indptr.len() - 1;
    let matrix = CsrMatrix::from_parts(rows, cols, indptr, indices, values);
    let labels = remap_labels(&labels, all_labeled);
    Ok((matrix, labels))
}

/// Write a matrix (and optional labels) in SVMlight format (1-based).
///
/// Without labels the label column is **omitted** entirely (the reader
/// accepts label-less lines), so an unlabeled matrix round-trips to
/// `labels = None` instead of a spurious all-zero labeling. An unlabeled
/// **all-zero row** would serialize to an empty line that every reader
/// skips, silently shrinking the matrix on round-trip — that case is
/// rejected with an error (all-zero rows cannot be unit-normalized anyway;
/// see [`CsrMatrix::drop_empty_rows`]). With labels, an empty row keeps
/// its line via the label token.
pub fn write_libsvm(path: &Path, m: &CsrMatrix, labels: Option<&[u32]>) -> Result<(), IoError> {
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    for r in 0..m.rows() {
        let row = m.row(r);
        if labels.is_none() && row.nnz() == 0 {
            return Err(IoError::Parse {
                line: r + 1,
                msg: format!(
                    "row {r} is all-zero and unlabeled: it would serialize to an \
                     empty line and be dropped on read (drop empty rows first)"
                ),
            });
        }
        let mut sep = "";
        if let Some(ls) = labels {
            write!(w, "{}", ls[r])?;
            sep = " ";
        }
        for (t, &c) in row.indices.iter().enumerate() {
            write!(w, "{sep}{}:{}", c + 1, row.values[t])?;
            sep = " ";
        }
        writeln!(w)?;
    }
    Ok(())
}

/// Read a MatrixMarket coordinate file (`%%MatrixMarket matrix coordinate
/// real general`).
pub fn read_matrix_market(path: &Path) -> Result<CsrMatrix, IoError> {
    let file = std::fs::File::open(path)?;
    let reader = BufReader::new(file);
    let mut lines = reader.lines().enumerate();
    // Header.
    let (_, header) = lines
        .next()
        .ok_or_else(|| IoError::Parse { line: 1, msg: "empty file".into() })?;
    let header = header?;
    if !header.starts_with("%%MatrixMarket matrix coordinate") {
        return perr(1, "not a MatrixMarket coordinate file");
    }
    // Size line (skipping comments).
    let mut size: Option<(usize, usize, usize)> = None;
    let mut triples: Vec<(u32, u32, f32)> = Vec::new();
    for (lno, line) in lines {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('%') {
            continue;
        }
        let parts: Vec<&str> = line.split_whitespace().collect();
        if size.is_none() {
            if parts.len() != 3 {
                return perr(lno + 1, "bad size line");
            }
            let r = parts[0].parse().map_err(|_| IoError::Parse { line: lno + 1, msg: "rows".into() })?;
            let c = parts[1].parse().map_err(|_| IoError::Parse { line: lno + 1, msg: "cols".into() })?;
            let n = parts[2].parse().map_err(|_| IoError::Parse { line: lno + 1, msg: "nnz".into() })?;
            size = Some((r, c, n));
            triples.reserve(n);
            continue;
        }
        if parts.len() < 2 {
            return perr(lno + 1, "bad entry line");
        }
        let i: u32 = parts[0].parse().map_err(|_| IoError::Parse { line: lno + 1, msg: "row".into() })?;
        let j: u32 = parts[1].parse().map_err(|_| IoError::Parse { line: lno + 1, msg: "col".into() })?;
        let v: f32 = if parts.len() > 2 {
            parts[2].parse().map_err(|_| IoError::Parse { line: lno + 1, msg: "val".into() })?
        } else {
            1.0 // pattern matrices
        };
        if i == 0 || j == 0 {
            return perr(lno + 1, "MatrixMarket is 1-based");
        }
        triples.push((i - 1, j - 1, v));
    }
    let (r, c, n) = size.ok_or(IoError::Parse { line: 2, msg: "missing size line".into() })?;
    if triples.len() != n {
        return perr(0, format!("expected {n} entries, found {}", triples.len()));
    }
    // Group by row; every row goes through the validating constructor so
    // duplicate entries (forbidden in `general` coordinate files) and
    // out-of-bounds columns surface as parse errors instead of silently
    // corrupting downstream dot products.
    let mut per_row: Vec<Vec<(u32, f32)>> = vec![Vec::new(); r];
    for (i, j, v) in triples {
        if i as usize >= r || j as usize >= c {
            return perr(0, "entry out of bounds");
        }
        per_row[i as usize].push((j, v));
    }
    let mut rows: Vec<SparseVec> = Vec::with_capacity(r);
    for (i, pairs) in per_row.into_iter().enumerate() {
        let row = SparseVec::try_from_pairs(c, pairs)
            .map_err(|msg| IoError::Parse { line: 0, msg: format!("row {}: {msg}", i + 1) })?;
        rows.push(row);
    }
    Ok(CsrMatrix::from_rows(c, &rows))
}

/// Write a matrix in MatrixMarket coordinate format.
pub fn write_matrix_market(path: &Path, m: &CsrMatrix) -> Result<(), IoError> {
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    writeln!(w, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(w, "% written by spherical-kmeans")?;
    writeln!(w, "{} {} {}", m.rows(), m.cols(), m.nnz())?;
    for r in 0..m.rows() {
        let row = m.row(r);
        for (t, &c) in row.indices.iter().enumerate() {
            writeln!(w, "{} {} {}", r + 1, c + 1, row.values[t])?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthConfig;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("sphkm-io-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn libsvm_round_trip_with_labels() {
        let ds = SynthConfig::small_demo().generate(1);
        let path = tmp("rt.svm");
        write_libsvm(&path, &ds.matrix, ds.labels.as_deref()).unwrap();
        let (m, labels) = read_libsvm(&path).unwrap();
        assert_eq!(m.rows(), ds.matrix.rows());
        // Column count may shrink if trailing columns are empty.
        assert!(m.cols() <= ds.matrix.cols());
        assert_eq!(m.nnz(), ds.matrix.nnz());
        assert_eq!(labels.unwrap(), ds.labels.unwrap());
        // Values survive (compare first row).
        assert_eq!(m.row(0).values, ds.matrix.row(0).values);
    }

    #[test]
    fn libsvm_parses_unlabeled_and_comments() {
        let path = tmp("plain.svm");
        std::fs::write(&path, "1:0.5 3:1.5 # comment\n\n2:2.0\n").unwrap();
        let (m, labels) = read_libsvm(&path).unwrap();
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 3);
        assert!(labels.is_none() || labels == Some(vec![0, 0]));
        assert_eq!(m.row(0).values, &[0.5, 1.5]);
    }

    #[test]
    fn libsvm_rejects_garbage() {
        let path = tmp("bad.svm");
        std::fs::write(&path, "1 1:x\n").unwrap();
        assert!(read_libsvm(&path).is_err());
    }

    #[test]
    fn libsvm_parses_float_labels() {
        // Standard libsvm class labels are floats (`1.0`, `-1.0`); they
        // must parse and remap to dense ids in ascending numeric order,
        // merging with integer spellings of the same value.
        let path = tmp("float-labels.svm");
        std::fs::write(&path, "1.0 1:0.5\n-1.0 2:1.0\n2.5 1:0.1\n1 3:2.0\n").unwrap();
        let (m, labels) = read_libsvm(&path).unwrap();
        assert_eq!(m.rows(), 4);
        // Ascending: -1.0 → 0, 1.0 → 1, 2.5 → 2.
        assert_eq!(labels.unwrap(), vec![1, 0, 2, 1]);
        // Non-numeric and non-finite labels still error.
        std::fs::write(&path, "abc 1:0.5\n").unwrap();
        assert!(read_libsvm(&path).is_err());
        std::fs::write(&path, "nan 1:0.5\n").unwrap();
        assert!(read_libsvm(&path).is_err());
    }

    #[test]
    fn libsvm_unlabeled_round_trip_is_lossless() {
        // Writer must omit the label column when there are no labels, so
        // the reader reports None instead of a spurious all-zero labeling.
        let ds = SynthConfig::small_demo().generate(3);
        let path = tmp("rt-unlabeled.svm");
        write_libsvm(&path, &ds.matrix, None).unwrap();
        let first = std::fs::read_to_string(&path).unwrap();
        assert!(
            first.lines().next().unwrap().starts_with(char::is_numeric)
                && first.lines().next().unwrap().contains(':'),
            "line must start with a feature, not a placeholder label"
        );
        let (m, labels) = read_libsvm(&path).unwrap();
        assert!(labels.is_none(), "no labels in, no labels out");
        assert_eq!(m.rows(), ds.matrix.rows());
        assert_eq!(m.nnz(), ds.matrix.nnz());
        assert_eq!(m.row(0).values, ds.matrix.row(0).values);
    }

    #[test]
    fn libsvm_rejects_duplicate_feature_index() {
        let path = tmp("dup.svm");
        std::fs::write(&path, "1 3:1.0 3:2.0\n").unwrap();
        let err = read_libsvm(&path).unwrap_err();
        assert!(format!("{err}").contains("duplicate"), "{err}");
    }

    #[test]
    fn libsvm_write_rejects_unlabeled_empty_row() {
        // Row 1 is all-zero: without a label it would vanish on read.
        let rows = vec![
            SparseVec::from_pairs(2, vec![(0, 1.0)]),
            SparseVec::zeros(2),
            SparseVec::from_pairs(2, vec![(1, 2.0)]),
        ];
        let m = CsrMatrix::from_rows(2, &rows);
        let path = tmp("empty-row.svm");
        let err = write_libsvm(&path, &m, None).unwrap_err();
        assert!(format!("{err}").contains("all-zero"), "{err}");
        // With labels the row keeps its line and the count survives.
        write_libsvm(&path, &m, Some(&[0, 1, 0])).unwrap();
        let (back, labels) = read_libsvm(&path).unwrap();
        assert_eq!(back.rows(), 3);
        assert_eq!(labels.unwrap().len(), 3);
    }

    #[test]
    fn libsvm_rejects_non_finite_values() {
        // `nan`/`inf` parse as valid f32s but would poison every dot
        // product (and panic the truncation selector) downstream.
        let path = tmp("nonfinite.svm");
        for bad in ["1 1:nan\n", "1 1:inf\n", "1 2:-inf\n"] {
            std::fs::write(&path, bad).unwrap();
            let err = read_libsvm(&path).unwrap_err();
            assert!(format!("{err}").contains("non-finite"), "{bad}: {err}");
        }
    }

    #[test]
    fn libsvm_rejects_index_beyond_u32() {
        // 4294967296 == u32::MAX + 1: must surface as the typed overflow
        // error, not a lossy cast aliasing column 0.
        let path = tmp("overflow.svm");
        std::fs::write(&path, "1 4294967296:1.0\n").unwrap();
        match read_libsvm(&path) {
            Err(IoError::IndexOverflow { line: 1, index }) => {
                assert_eq!(index, u32::MAX as u64 + 1);
            }
            other => panic!("expected IndexOverflow, got {other:?}"),
        }
        // u32::MAX itself is the last representable index and must parse.
        std::fs::write(&path, &format!("1 {}:1.0\n", u32::MAX)).unwrap();
        let (m, _) = read_libsvm(&path).unwrap();
        assert_eq!(m.cols(), u32::MAX as usize);
        assert_eq!(m.row(0).indices, &[u32::MAX - 1]);
    }

    #[test]
    fn libsvm_streams_from_any_bufread() {
        // The streaming core accepts any BufRead — no file required — and
        // matches the path-based reader exactly.
        let text = "2.0 1:0.5 3:1.5\n# full-line comment\n-1 2:2.0\n";
        let (m, labels) = read_libsvm_from(std::io::Cursor::new(text)).unwrap();
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 3);
        assert_eq!(labels.unwrap(), vec![1, 0]);
        let path = tmp("stream-eq.svm");
        std::fs::write(&path, text).unwrap();
        let (m2, _) = read_libsvm(&path).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn libsvm_zero_valued_entry_still_widens_matrix() {
        // `5:0` stores nothing but has always determined the column count;
        // the streaming rewrite must preserve that.
        let (m, _) = read_libsvm_from(std::io::Cursor::new("1:1.0 5:0\n")).unwrap();
        assert_eq!(m.cols(), 5);
        assert_eq!(m.nnz(), 1);
    }

    #[test]
    fn matrix_market_round_trip() {
        let ds = SynthConfig::small_demo().generate(2);
        let path = tmp("rt.mtx");
        write_matrix_market(&path, &ds.matrix).unwrap();
        let m = read_matrix_market(&path).unwrap();
        assert_eq!(m.rows(), ds.matrix.rows());
        assert_eq!(m.cols(), ds.matrix.cols());
        assert_eq!(m.nnz(), ds.matrix.nnz());
        assert_eq!(m.row(5).indices, ds.matrix.row(5).indices);
    }

    #[test]
    fn matrix_market_rejects_duplicate_entry() {
        let path = tmp("dup.mtx");
        std::fs::write(
            &path,
            "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n1 1 2.0\n",
        )
        .unwrap();
        let err = read_matrix_market(&path).unwrap_err();
        assert!(format!("{err}").contains("duplicate"), "{err}");
    }

    #[test]
    fn matrix_market_rejects_non_mm() {
        let path = tmp("nomm.mtx");
        std::fs::write(&path, "hello\n1 1 1\n").unwrap();
        assert!(read_matrix_market(&path).is_err());
    }
}
