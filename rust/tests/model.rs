//! Model persistence integration tests: randomized save/load bit-exactness
//! and the corrupt-file rejection taxonomy.

// Bench and test targets favour readable literal casts and exact
// (bit-level) float assertions; the workspace clippy warnings on
// those patterns are aimed at library code.
#![allow(clippy::cast_possible_truncation, clippy::float_cmp)]

use sphkm::kmeans::Variant;
use sphkm::model::{Model, ModelError, TrainingMeta};
use sphkm::SphericalKMeans;
use sphkm::sparse::DenseMatrix;
use sphkm::util::prop::forall;

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("sphkm-model-tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

#[test]
fn prop_save_load_round_trips_bit_exactly() {
    forall(40, 0x40DE1, |g| {
        let k = g.usize_in(1, 12);
        let d = g.usize_in(1, 80);
        let mut centers = DenseMatrix::zeros(k, d);
        for j in 0..k {
            let nnz = g.usize_in(0, d + 1);
            for c in g.sparse_pattern(d, nnz) {
                // Raw values (not unit rows) on purpose: persistence must
                // not assume normalization. Include exact zeros from the
                // generator range edge and negative values.
                centers.row_mut(j)[c] = g.f64_in(-2.0, 2.0) as f32;
            }
        }
        // Occasionally plant a negative zero — its bit pattern must survive.
        if k * d > 2 {
            centers.row_mut(0)[0] = -0.0;
        }
        let meta = TrainingMeta {
            variant: ["Standard", "minibatch", "Simp.Elkan"][g.usize_in(0, 3)].to_string(),
            kernel: ["dense", "gather", "inverted"][g.usize_in(0, 3)].to_string(),
            iterations: g.usize_in(0, 1000) as u64,
            objective: g.f64_in(0.0, 1e6),
            seed: g.usize_in(0, 1 << 30) as u64,
        };
        let model = Model::new(centers, meta);
        let path = tmp(&format!("prop-{}.spkm", g.case));
        model.save(&path).unwrap();
        let back = Model::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back.k(), model.k());
        assert_eq!(back.d(), model.d());
        assert_eq!(back.meta(), model.meta());
        assert_eq!(
            back.meta().objective.to_bits(),
            model.meta().objective.to_bits()
        );
        for (a, b) in back.norms().iter().zip(model.norms()) {
            assert_eq!(a.to_bits(), b.to_bits(), "norms must round-trip bitwise");
        }
        for j in 0..model.k() {
            for (c, (a, b)) in back
                .centers()
                .row(j)
                .iter()
                .zip(model.centers().row(j))
                .enumerate()
            {
                assert_eq!(a.to_bits(), b.to_bits(), "center {j} dim {c}");
            }
        }
    });
}

#[test]
fn trained_model_round_trips_through_disk() {
    let ds = sphkm::data::synth::SynthConfig::small_demo().generate(3);
    let fitted = SphericalKMeans::new(6)
        .variant(Variant::Hamerly)
        .seed(5)
        .max_iter(30)
        .fit(&ds.matrix)
        .unwrap();
    let path = tmp("trained.spkm");
    fitted.save(&path).unwrap();
    let back = Model::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(&back, &fitted.to_model());
    // The state-bearing round trip restores assignments and accumulators
    // bit-for-bit.
    let state = back.state().expect("fitted saves carry training state");
    assert_eq!(state.assignments, fitted.assignments());
    assert_eq!(state.converged, fitted.converged());
    for j in 0..back.k() {
        for (a, b) in back.centers().row(j).iter().zip(fitted.centers().row(j)) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}

#[test]
fn load_rejects_missing_bad_magic_version_truncated_and_corrupt() {
    let centers = DenseMatrix::from_vec(2, 3, vec![0.6, 0.0, 0.8, 0.0, 1.0, 0.0]);
    let model = Model::new(
        centers,
        TrainingMeta {
            variant: "Standard".into(),
            kernel: "gather".into(),
            iterations: 3,
            objective: 0.5,
            seed: 7,
        },
    );
    let path = tmp("victim.spkm");
    model.save(&path).unwrap();
    let good = std::fs::read(&path).unwrap();

    // Missing file → Io.
    let missing = Model::load(&tmp("does-not-exist.spkm")).unwrap_err();
    assert!(matches!(missing, ModelError::Io(_)), "{missing}");

    // Bad magic → BadMagic.
    let mut bytes = good.clone();
    bytes[0] = b'X';
    std::fs::write(&path, &bytes).unwrap();
    let err = Model::load(&path).unwrap_err();
    assert!(matches!(err, ModelError::BadMagic), "{err}");

    // Future version → UnsupportedVersion, reported before any checksum
    // complaint so the message tells the user what is actually wrong.
    let mut bytes = good.clone();
    bytes[8..12].copy_from_slice(&7u32.to_le_bytes());
    std::fs::write(&path, &bytes).unwrap();
    let err = Model::load(&path).unwrap_err();
    assert!(
        matches!(err, ModelError::UnsupportedVersion { found: 7 }),
        "{err}"
    );

    // Truncated body → Truncated, at any cut point past the magic.
    for frac in [0.3, 0.6, 0.95] {
        let cut = (good.len() as f64 * frac) as usize;
        std::fs::write(&path, &good[..cut]).unwrap();
        let err = Model::load(&path).unwrap_err();
        assert!(matches!(err, ModelError::Truncated { .. }), "cut {cut}: {err}");
    }

    // A flipped payload byte → Corrupt (checksum mismatch).
    let mut bytes = good.clone();
    let mid = good.len() - 12;
    bytes[mid] ^= 0x40;
    std::fs::write(&path, &bytes).unwrap();
    let err = Model::load(&path).unwrap_err();
    assert!(matches!(err, ModelError::Corrupt(_)), "{err}");

    // The pristine bytes still load after all that.
    std::fs::write(&path, &good).unwrap();
    assert_eq!(Model::load(&path).unwrap(), model);
    std::fs::remove_file(&path).ok();
}
