//! Minimal JSON value model, parser, and writer (the offline registry
//! has no `serde`). It covers what this crate needs: parse and validate
//! the trace/metrics/report documents the observability layer emits, and
//! render them back deterministically.
//!
//! Objects preserve insertion order (a `Vec` of pairs, not a map) so
//! emitted documents read in the order they were built; duplicate keys
//! are accepted on parse with [`Json::get`] returning the first match.
//! Numbers are `f64` (like JavaScript); non-finite values render as
//! `null` since JSON has no representation for them.
//!
//! The parser is hardened for untrusted wire input (the serving daemon
//! feeds it raw network frames): trailing garbage is rejected, nesting
//! is capped at [`MAX_DEPTH`] so a `[[[[…` bomb cannot blow the stack,
//! [`Json::parse_bounded`] enforces a byte budget before scanning, and
//! strings must escape control characters (raw bytes below `0x20` are
//! a parse error, per RFC 8259).

use std::fmt::Write as _;

/// Maximum container nesting depth [`Json::parse`] accepts. Deep enough
/// for any document this crate emits (reports nest 4–5 levels); shallow
/// enough that a hostile `[[[[…` frame errors out long before the
/// recursive-descent parser can exhaust the stack.
pub const MAX_DEPTH: usize = 64;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always `f64`, like JavaScript).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object, in insertion order.
    Obj(Vec<(String, Json)>),
}

/// Error raised by [`Json::parse`], carrying the byte offset at which
/// parsing failed.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {at}: {what}")]
pub struct JsonError {
    /// Byte offset of the failure in the input.
    pub at: usize,
    /// What was wrong there.
    pub what: String,
}

impl Json {
    /// Parse a complete JSON document (rejects trailing garbage,
    /// nesting beyond [`MAX_DEPTH`], and raw control characters in
    /// strings).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0, depth: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    /// [`Json::parse`] with an input byte budget, for untrusted wire
    /// frames: inputs longer than `max_bytes` are rejected before any
    /// scanning, so a hostile peer cannot make the parser allocate in
    /// proportion to an unbounded payload.
    pub fn parse_bounded(text: &str, max_bytes: usize) -> Result<Json, JsonError> {
        if text.len() > max_bytes {
            return Err(JsonError {
                at: max_bytes,
                what: format!("document of {} bytes exceeds limit of {max_bytes}", text.len()),
            });
        }
        Json::parse(text)
    }

    /// Member of an object by key (first match); `None` for non-objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(members) => members.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The number, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// The string slice, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The boolean, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// The members, if this is an object.
    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(members) => Some(members),
            _ => None,
        }
    }

    /// Is this `null`?
    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Is this a scalar (null / bool / number / string)?
    pub fn is_scalar(&self) -> bool {
        !matches!(self, Json::Arr(_) | Json::Obj(_))
    }

    /// Render compactly (no whitespace), deterministically.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Render with newlines and `indent`-space indentation per level —
    /// the format the committed `BENCH_*.json` files use.
    pub fn pretty(&self, indent: usize) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(indent), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.is_finite() {
                    let _ = write!(out, "{v}");
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(members) => {
                out.push('{');
                for (i, (k, v)) in members.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !members.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl Parser<'_> {
    fn err(&self, what: &str) -> JsonError {
        JsonError { at: self.i, what: what.to_string() }
    }

    /// Enter one container level; errors past [`MAX_DEPTH`]. The
    /// matching `depth -= 1` sits at each container's exit.
    fn descend(&mut self) -> Result<(), JsonError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err(&format!("nesting deeper than {MAX_DEPTH} levels")));
        }
        Ok(())
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).expect("ascii number bytes");
        let v: f64 = text.parse().map_err(|_| JsonError {
            at: start,
            what: format!("invalid number {text:?}"),
        })?;
        if !v.is_finite() {
            return Err(JsonError { at: start, what: format!("non-finite number {text:?}") });
        }
        Ok(Json::Num(v))
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let text = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("non-ascii \\u escape"))?;
        let v = u32::from_str_radix(text, 16).map_err(|_| self.err("invalid \\u escape"))?;
        self.i += 4;
        Ok(v)
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => {
                            out.push('"');
                            self.i += 1;
                        }
                        Some(b'\\') => {
                            out.push('\\');
                            self.i += 1;
                        }
                        Some(b'/') => {
                            out.push('/');
                            self.i += 1;
                        }
                        Some(b'b') => {
                            out.push('\u{8}');
                            self.i += 1;
                        }
                        Some(b'f') => {
                            out.push('\u{c}');
                            self.i += 1;
                        }
                        Some(b'n') => {
                            out.push('\n');
                            self.i += 1;
                        }
                        Some(b'r') => {
                            out.push('\r');
                            self.i += 1;
                        }
                        Some(b't') => {
                            out.push('\t');
                            self.i += 1;
                        }
                        Some(b'u') => {
                            self.i += 1;
                            let hi = self.hex4()?;
                            let code = if (0xD800..0xDC00).contains(&hi) {
                                // Surrogate pair: expect \uDC00..\uDFFF.
                                if self.peek() == Some(b'\\') {
                                    self.i += 1;
                                    self.eat(b'u')?;
                                    let lo = self.hex4()?;
                                    if !(0xDC00..0xE000).contains(&lo) {
                                        return Err(self.err("invalid low surrogate"));
                                    }
                                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else {
                                hi
                            };
                            match char::from_u32(code) {
                                Some(c) => out.push(c),
                                None => return Err(self.err("invalid unicode escape")),
                            }
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                }
                Some(c) if c < 0x20 => {
                    // RFC 8259 §7: control characters must be escaped.
                    return Err(self.err(&format!(
                        "unescaped control character 0x{c:02x} in string"
                    )));
                }
                Some(_) => {
                    // Consume one UTF-8 scalar from the source text.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8 in string"))?;
                    let c = rest.chars().next().expect("non-empty remainder");
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        self.descend()?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        self.descend()?;
        let mut members = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Obj(members));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let value = self.value()?;
            members.push((key, value));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(members));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".to_string()));
    }

    #[test]
    fn parses_nested_documents() {
        let doc = r#"{"a": [1, 2, {"b": null}], "c": {"d": true}, "e": "x\ny"}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("a").and_then(Json::as_arr).map(<[Json]>::len), Some(3));
        assert!(v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().is_null());
        assert_eq!(v.get("c").and_then(|c| c.get("d")).and_then(Json::as_bool), Some(true));
        assert_eq!(v.get("e").and_then(Json::as_str), Some("x\ny"));
        assert_eq!(v.get("absent"), None);
    }

    #[test]
    fn string_escapes_round_trip() {
        let original = Json::Str("quote \" slash \\ tab \t newline \n unicode ünîcødé \u{1}".to_string());
        let rendered = original.render();
        assert_eq!(Json::parse(&rendered).unwrap(), original);
    }

    #[test]
    fn unicode_escapes_and_surrogate_pairs() {
        assert_eq!(
            Json::parse(r#""Aé""#).unwrap(),
            Json::Str("Aé".to_string())
        );
        // U+1F600 as a surrogate pair.
        assert_eq!(
            Json::parse(r#""😀""#).unwrap(),
            Json::Str("\u{1F600}".to_string())
        );
        assert!(Json::parse(r#""\ud83d""#).is_err());
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in [
            "", "{", "[1,", "{\"a\"}", "{\"a\":}", "tru", "1.2.3", "[1] extra", "\"open",
            "{'a': 1}", "nullnull",
        ] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn rejects_hostile_wire_input() {
        // Truncated and malformed \u escapes.
        for bad in [r#""\u12""#, r#""\u""#, r#""\uzzzz""#, r#""\udc00""#, r#""\ud83dA""#] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should fail");
        }
        // Raw (unescaped) control characters inside strings.
        assert!(Json::parse("\"a\u{1}b\"").is_err());
        assert!(Json::parse("\"a\nb\"").is_err());
        // The escaped forms of the same characters are fine.
        assert_eq!(Json::parse(r#""a\u0001b""#).unwrap(), Json::Str("a\u{1}b".to_string()));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".to_string()));
    }

    #[test]
    fn depth_limit_stops_nesting_bombs() {
        let deep_ok = format!("{}0{}", "[".repeat(MAX_DEPTH), "]".repeat(MAX_DEPTH));
        assert!(Json::parse(&deep_ok).is_ok());
        let too_deep = format!("{}0{}", "[".repeat(MAX_DEPTH + 1), "]".repeat(MAX_DEPTH + 1));
        let err = Json::parse(&too_deep).unwrap_err();
        assert!(err.what.contains("nesting"), "{err}");
        // An unclosed bomb (the hostile shape — no closers needed to
        // trigger recursion) must also fail without overflowing.
        assert!(Json::parse(&"[".repeat(100_000)).is_err());
        let obj_bomb = "{\"a\":".repeat(100_000);
        assert!(Json::parse(&obj_bomb).is_err());
    }

    #[test]
    fn parse_bounded_enforces_byte_budget() {
        assert_eq!(Json::parse_bounded("[1,2]", 16).unwrap(), Json::parse("[1,2]").unwrap());
        let err = Json::parse_bounded("[1,2,3,4,5,6,7,8]", 8).unwrap_err();
        assert!(err.what.contains("exceeds limit"), "{err}");
    }

    #[test]
    fn render_round_trips_and_preserves_order() {
        let v = Json::Obj(vec![
            ("z".to_string(), Json::Num(1.0)),
            ("a".to_string(), Json::Arr(vec![Json::Null, Json::Bool(false)])),
        ]);
        let compact = v.render();
        assert_eq!(compact, r#"{"z":1,"a":[null,false]}"#);
        assert_eq!(Json::parse(&compact).unwrap(), v);
        let pretty = v.pretty(2);
        assert!(pretty.contains("\n  \"z\": 1"));
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(5.0).render(), "5");
        assert_eq!(Json::Num(0.25).render(), "0.25");
        assert_eq!(Json::Num(f64::NAN).render(), "null");
    }

    #[test]
    fn empty_containers_render_tight() {
        assert_eq!(Json::Arr(vec![]).pretty(2), "[]");
        assert_eq!(Json::Obj(vec![]).render(), "{}");
    }
}
