//! The baseline spherical k-means algorithm (Dhillon & Modha 2001) with the
//! paper's §5 implementation optimizations: unit-normalized data (dot
//! product = cosine), sparse×dense row–center dots, cached unnormalized
//! sums updated incrementally, and sums scaled (not averaged) to unit
//! length. No pruning — every iteration computes all `N·k` similarities.

use super::{Ctx, IterStats, KMeansConfig};
use crate::util::timer::Stopwatch;

pub(crate) fn run(ctx: &mut Ctx<'_>, cfg: &KMeansConfig) -> bool {
    // Iteration 0: full assignment to the initial centers.
    ctx.initial_assignment(false, |_, _, _, _, _| {});

    let mut scratch = vec![0.0f64; ctx.k];
    for _ in 0..cfg.max_iter {
        let sw = Stopwatch::start();
        let mut iter = IterStats::default();
        let mut moves = 0u64;
        for i in 0..ctx.data.rows() {
            let (best_j, _, _) = if cfg.fast_standard {
                ctx.similarities_full(i, &mut iter, &mut scratch)
            } else {
                ctx.similarities_full_gather(i, &mut iter, &mut scratch)
            };
            let old = ctx.assign[i] as usize;
            if best_j != old {
                ctx.assign[i] = best_j as u32;
                ctx.centers.apply_move(ctx.data.row(i), old, best_j);
                moves += 1;
            }
        }
        iter.reassignments = moves;
        if moves == 0 {
            iter.wall_ms = sw.ms();
            ctx.stats.iters.push(iter);
            return true;
        }
        iter.sims_center_center += ctx.centers.update();
        iter.wall_ms = sw.ms();
        ctx.stats.iters.push(iter);
    }
    false
}
