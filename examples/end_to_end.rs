//! End-to-end driver — exercises the **full system** on a real (synthetic
//! but non-trivial) workload and reports the paper's headline result:
//! accelerated spherical k-means produces *identical* clusterings to the
//! standard algorithm at a fraction of the similarity computations and
//! wall time, with the winner depending on data shape (N vs d) and k.
//!
//! Layers composed here:
//!   L1/L2 (if `make artifacts` has run): the PJRT engine executes the
//!          AOT-compiled JAX/Pallas assignment kernel to cross-check the
//!          Rust assignment on a dense-shaped dataset;
//!   L3:    datasets → seeding → all six algorithm variants → metrics →
//!          report, entirely in Rust.
//!
//! ```text
//! cargo run --release --example end_to_end -- [--scale small] [--quick]
//! ```
//!
//! The output of one run is recorded in EXPERIMENTS.md §End-to-end.

// Example code favours readable literal casts; the workspace clippy
// warnings on those patterns are aimed at library code.
#![allow(clippy::cast_possible_truncation, clippy::float_cmp)]

use sphkm::coordinator::report::{fmt_ms, Table};
use sphkm::data::datasets::{self, Scale};
use sphkm::init::{seed_centers, InitMethod};
use sphkm::kmeans::{SphericalKMeans, Variant};
use sphkm::metrics;
use sphkm::util::cli::Args;
use sphkm::util::timer::Stopwatch;

fn main() {
    let args = Args::from_env();
    let scale: Scale = if args.flag("quick") {
        Scale::Tiny
    } else {
        args.get_or("scale", Scale::Small).unwrap_or(Scale::Small)
    };
    let seed = 42u64;

    println!("=== end-to-end driver (scale={}) ===\n", scale.name());

    // ---- stage 1: the full workload matrix --------------------------
    let workloads = [
        (datasets::dblp_author_conf(scale, seed), 50usize),
        (datasets::dblp_conf_author(scale, seed), 20),
        (datasets::rcv1(scale, seed ^ 4), 50),
    ];
    let mut table = Table::new(&[
        "Data set", "Variant", "ms", "iters", "pc sims", "cc sims", "speedup", "exact",
    ]);
    let mut headline: Vec<(String, f64)> = Vec::new();
    for (ds, k) in &workloads {
        let k = (*k).min(ds.matrix.rows() / 2);
        let init = seed_centers(&ds.matrix, k, &InitMethod::KMeansPP { alpha: 1.0 }, 7);
        let mut baseline_ms = 0.0;
        let mut baseline_assign: Vec<u32> = Vec::new();
        let mut best_speedup: f64 = 1.0;
        for variant in Variant::ALL {
            let sw = Stopwatch::start();
            let r = SphericalKMeans::new(k)
                .variant(variant)
                .warm_start_centers(init.centers.clone())
                .fit(&ds.matrix)
                .expect("valid configuration")
                .into_result();
            let ms = sw.ms();
            let exact = if variant == Variant::Standard {
                baseline_ms = ms;
                baseline_assign = r.assignments.clone();
                true
            } else {
                r.assignments == baseline_assign
            };
            assert!(exact, "{}: {} diverged from Standard!", ds.name, variant.name());
            let speedup = baseline_ms / ms;
            best_speedup = best_speedup.max(speedup);
            let cc = r.stats.total_sims() - r.stats.total_point_center();
            table.row(vec![
                ds.name.clone(),
                variant.name().into(),
                fmt_ms(ms),
                r.iterations.to_string(),
                r.stats.total_point_center().to_string(),
                cc.to_string(),
                format!("{speedup:.2}x"),
                if exact { "yes".into() } else { "NO".into() },
            ]);
        }
        headline.push((ds.name.clone(), best_speedup));
        // Quality sanity on the planted structure.
        if let Some(truth) = &ds.labels {
            println!(
                "{}: NMI vs planted communities = {:.3}",
                ds.name,
                metrics::nmi(&baseline_assign, truth)
            );
        }
    }
    println!("\n{}", table.render());

    // ---- stage 2: the PJRT (L1/L2) path ------------------------------
    pjrt_stage();

    // ---- headline ----------------------------------------------------
    println!("\n=== headline ===");
    for (name, s) in &headline {
        println!("{name}: best accelerated variant is {s:.1}x faster than Standard (identical result)");
    }
}

/// Cross-check the Rust assignment against the AOT-compiled JAX/Pallas
/// kernel executed over PJRT (only built with `--features pjrt`).
#[cfg(feature = "pjrt")]
fn pjrt_stage() {
    use sphkm::runtime::{artifacts_available, AssignEngine};
    let art = std::path::Path::new("artifacts");
    if !artifacts_available(art) {
        println!("PJRT stage skipped (run `make artifacts` to enable)");
        return;
    }
    // Dense-shaped dataset matching the compiled (256, 16, 512) artifact.
    let ds = sphkm::data::synth::SynthConfig {
        name: "pjrt-x-check".into(),
        n_docs: 2048,
        vocab: 512,
        topics: 16,
        doc_len_mean: 40.0,
        doc_len_sigma: 0.4,
        topic_strength: 0.7,
        shared_vocab_frac: 0.25,
        zipf_s: 1.1,
        anomaly_frac: 0.0,
        tfidf: Default::default(),
    }
    .generate(9);
    let k = 16;
    let init = seed_centers(&ds.matrix, k, &InitMethod::Uniform, 5);
    let r = SphericalKMeans::new(k)
        .variant(Variant::SimplifiedElkan)
        .warm_start_centers(init.centers.clone())
        .fit(&ds.matrix)
        .expect("valid configuration")
        .into_result();
    let mut engine = AssignEngine::load_matching(art, k, 512).expect("artifact");
    let tile = engine
        .assign_all(&ds.matrix, r.centers.data())
        .expect("PJRT execute");
    let agree = tile
        .best
        .iter()
        .zip(&r.assignments)
        .filter(|(a, b)| a == b)
        .count();
    println!(
        "PJRT cross-check: JAX/Pallas kernel agrees with Rust assignment on {}/{} rows ({})",
        agree,
        ds.matrix.rows(),
        engine.manifest().filename()
    );
    assert!(agree * 1000 >= ds.matrix.rows() * 999, "PJRT/native disagreement");
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_stage() {
    println!("PJRT stage skipped (build with --features pjrt and run `make artifacts`)");
}
