//! Bound-machinery benchmarks and ablations:
//!
//! 1. **Cost**: closed-form Eq. 4/5 vs the trigonometric Eq. 3 (the paper's
//!    10–50 vs 60–100 CPU-cycle argument) vs the Euclidean detour
//!    `√(2−2s)` + triangle + conversion back.
//! 2. **Tightness**: how often each single-bound rule (Eq. 9, Eq. 8,
//!    guarded min-p, safe-interval) retains enough information to prune,
//!    over simulated center-movement traces.
//! 3. **End-to-end**: Hamerly with Eq. 9 vs the beyond-paper guarded
//!    min-p rule on a real workload (prune counts + time).
//!
//! ```text
//! cargo bench --bench bench_bounds -- [--runs 10]
//! ```

// Bench and test targets favour readable literal casts and exact
// (bit-level) float assertions; the workspace clippy warnings on
// those patterns are aimed at library code.
#![allow(clippy::cast_possible_truncation, clippy::float_cmp)]

use sphkm::bounds::hamerly_bound::{update_eq8, update_eq9, update_min_p_guarded, update_safe};
use sphkm::bounds::{sim_lower, sim_lower_arc, sim_upper, update_upper};
use sphkm::data::datasets::{self, Scale};
use sphkm::init::{seed_centers, InitMethod};
use sphkm::kmeans::{Engine, ExactParams, SphericalKMeans, Variant};
use sphkm::util::benchkit::{bench, black_box, BenchOpts};
use sphkm::util::cli::Args;
use sphkm::util::rng::Xoshiro256;

fn main() {
    let args = Args::from_env();
    let mut opts = BenchOpts::from_args(&args);
    if !args.has("runs") {
        opts.runs = 10;
    }
    let mut rng = Xoshiro256::seed_from_u64(3);
    let pairs: Vec<(f64, f64)> = (0..1_000_000)
        .map(|_| (rng.next_f64() * 2.0 - 1.0, rng.next_f64() * 2.0 - 1.0))
        .collect();

    // --- 1. cost of the bound formulas -------------------------------
    bench("bound_cost/closed-form Eq.4 (1M)", opts, || {
        let mut acc = 0.0;
        for &(a, b) in &pairs {
            acc += sim_lower(a, b);
        }
        black_box(acc);
    });
    bench("bound_cost/trigonometric Eq.3 (1M)", opts, || {
        let mut acc = 0.0;
        for &(a, b) in &pairs {
            acc += sim_lower_arc(a, b);
        }
        black_box(acc);
    });
    bench("bound_cost/euclidean detour (1M)", opts, || {
        // d = √(2−2s); triangle on distances; convert back s = 1−d²/2.
        let mut acc = 0.0;
        for &(a, b) in &pairs {
            let da = (2.0 - 2.0 * a).max(0.0).sqrt();
            let db = (2.0 - 2.0 * b).max(0.0).sqrt();
            let d = da + db;
            acc += 1.0 - 0.5 * d * d;
        }
        black_box(acc);
    });
    bench("bound_cost/upper Eq.5 (1M)", opts, || {
        let mut acc = 0.0;
        for &(a, b) in &pairs {
            acc += sim_upper(a, b);
        }
        black_box(acc);
    });

    // --- 2. tightness of the single-bound rules ----------------------
    // Simulated trace: u ~ second-best sims, p(j) drifting to 1.
    let mut survive = [0u64; 4]; // eq9, eq8, guarded, safe
    let mut total = 0u64;
    for trial in 0..20_000u64 {
        let mut r = Xoshiro256::substream(11, trial);
        let l = 0.5 + 0.5 * r.next_f64(); // tight lower bound
        let mut u = l - 0.3 * r.next_f64(); // below: prunable
        let mut u8v = u;
        let mut ug = u;
        let mut us = u;
        for it in 0..10i32 {
            // Center movements shrink geometrically as the run converges.
            let movement = 0.08 * 0.6f64.powi(it);
            let ps: Vec<f64> = (0..8)
                .map(|_| (1.0 - movement * r.next_f64()).min(1.0))
                .collect();
            let pmin = ps.iter().cloned().fold(f64::MAX, f64::min);
            let pmax = ps.iter().cloned().fold(f64::MIN, f64::max);
            u = update_eq9(u, pmin);
            u8v = update_eq8(u8v, pmin, pmax);
            ug = update_min_p_guarded(ug, pmin);
            us = update_safe(us, pmin, pmax);
            total += 1;
            // Would the bound still prune against the (unchanged) l?
            if l >= u {
                survive[0] += 1;
            }
            if l >= u8v {
                survive[1] += 1;
            }
            if l >= ug {
                survive[2] += 1;
            }
            if l >= us {
                survive[3] += 1;
            }
        }
    }
    println!("\n# single-bound pruning survival over drift traces (higher = tighter)");
    for (name, s) in ["Eq.9", "Eq.8", "guarded min-p", "safe-interval"]
        .iter()
        .zip(survive)
    {
        println!(
            "tightness {:<14} {:>7.3}% of bound checks still prune",
            name,
            100.0 * s as f64 / total as f64
        );
    }
    // Validity sanity: guarded min-p must dominate Eq.8/Eq.9 tightness.
    assert!(survive[2] >= survive[0]);
    assert!(survive[2] >= survive[1]);

    // --- 3. end-to-end: Eq.9 vs guarded min-p in Hamerly --------------
    let ds = datasets::dblp_author_conf(Scale::Tiny, 5);
    let k = 50.min(ds.matrix.rows() / 2);
    let init = seed_centers(&ds.matrix, k, &InitMethod::Uniform, 9);
    for (name, tight) in [("hamerly/eq9", false), ("hamerly/guarded-min-p", true)] {
        let est = SphericalKMeans::new(k).engine(Engine::Exact(ExactParams {
            variant: Variant::SimplifiedHamerly,
            tight_bound: tight,
            ..Default::default()
        }));
        let mut sims = 0u64;
        let r = bench(name, opts, || {
            let res = est
                .clone()
                .warm_start_centers(init.centers.clone())
                .fit(&ds.matrix)
                .expect("bench configuration is valid");
            sims = res.stats().total_point_center();
            black_box(res.objective());
        });
        println!("    -> {} point-center sims ({})", sims, r.name);
    }

    // update_upper itself (the O(N·k) Elkan maintenance cost).
    bench("bound_cost/guarded update_upper (1M)", opts, || {
        let mut acc = 0.0;
        for &(a, b) in &pairs {
            acc += update_upper(a, b);
        }
        black_box(acc);
    });
}
