//! The Hamerly upper-bound update and its "easily overlooked pitfall"
//! (§5.3 of the paper).
//!
//! Hamerly keeps **one** upper bound `u(i)` for "all other centers". With
//! distances one updates it with the largest center movement; with cosines
//! the update `u·p + √((1−u²)(1−p²))` (Eq. 7) is **not monotone in `p`**:
//! for large `u` a *smaller* `p` loosens the bound most, but for small `u`
//! a *larger* `p` does. So no single `p(j)` is safe for all points.
//!
//! The paper's resolution, which we implement:
//!
//! * Eq. 8 — use both `p' = min_{j≠a} p(j)` and `p'' = max_{j≠a} p(j)`:
//!   `u ← u·p'' + √((1−u²)(1−p'²))`.
//! * Eq. 9 — since `p'' → 1` at convergence, drop the first factor:
//!   `u ← u + √((1−u²)(1−p'²))`, precomputing `(1−p'²)` per center.
//!
//! **Validity regime.** Eq. 8/9 as printed assume the practical regime
//! `u ≥ 0` and `p(j) ≥ 0` (TF-IDF document data is non-negative, so all
//! similarities are; and centers barely move after the first iterations,
//! so `p ≈ 1`). For *general* unit vectors (negative similarities
//! possible) we also provide [`update_safe`], the exact interval
//! maximization of Eq. 7 over `p ∈ [p_min, p_max]`, which is valid for all
//! inputs and reduces to Eq. 8 in the practical regime. The spherical
//! Hamerly implementation uses Eq. 9 on the fast path and falls back to
//! [`update_safe`] when `u < 0` or `p_min < 0` — see the
//! `counterexample_*` tests for why the naive updates would be wrong.

use super::{clamp_sim, sin_from_cos};

/// Unsafe-naive update: plug the minimum `p` into the **unguarded** Eq. 7.
/// This is the pitfall — it is **not** a valid single bound (see
/// `counterexample_*` tests); kept only for the ablation bench and
/// regression tests.
#[inline(always)]
pub fn update_naive_min_p(u: f64, p_min: f64) -> f64 {
    super::sim_upper(u, p_min)
}

/// **Beyond the paper:** the *guarded* min-p update. Once Eq. 7 carries
/// the saturation guard of [`crate::bounds::update_upper`] (saturate to 1
/// when `p ≤ u`), the per-center update becomes monotone non-increasing in
/// `p` — so plugging in `p' = min_{j≠a} p(j)` is simultaneously **valid**
/// (it dominates every per-center requirement) and **tight** (it equals
/// the exact requirement `max_j guarded-Eq.7(u, p_j)`). The paper's §5.3
/// "we probably cannot use just one p(j) for all points" refers to the
/// unguarded formula; with the guard we can, and the bound dominates both
/// Eq. 8 and Eq. 9. Proven by `guarded_min_p_is_valid_and_tightest` and
/// benched in `bench_bounds`; selectable in the Hamerly/Yinyang variants
/// via `KMeansConfig::tight_hamerly_bound`.
#[inline(always)]
pub fn update_min_p_guarded(u: f64, p_min: f64) -> f64 {
    super::update_upper(u, p_min)
}

/// Eq. 8 as printed: `u·p'' + √((1−u²)(1−p'²))`, with the saturation guard
/// of [`crate::bounds::update_upper`] (saturate to 1 when any center may
/// have moved past the bound angle, i.e. `p' ≤ u`).
/// Valid for `u ≥ 0` and `0 ≤ p' ≤ p''`.
#[inline(always)]
pub fn update_eq8(u: f64, p_min: f64, p_max: f64) -> f64 {
    let u = clamp_sim(u);
    if p_min <= u {
        return 1.0;
    }
    clamp_sim(u * clamp_sim(p_max) + sin_from_cos(u) * sin_from_cos(p_min))
}

/// Eq. 9: the efficient upper bound `u + √((1−u²)·(1−p'²))`, using the
/// precomputed `one_minus_p_min_sq = 1 − p'²` term.
/// Valid for `u ≥ 0` and `p' ≥ 0` (dominates Eq. 8 there).
#[inline(always)]
pub fn update_eq9_pre(u: f64, one_minus_p_min_sq: f64) -> f64 {
    let u = clamp_sim(u);
    clamp_sim(u + ((1.0 - u * u).max(0.0) * one_minus_p_min_sq.max(0.0)).sqrt())
}

/// Eq. 9 from the raw `p'` value.
#[inline(always)]
pub fn update_eq9(u: f64, p_min: f64) -> f64 {
    let p = clamp_sim(p_min);
    update_eq9_pre(u, 1.0 - p * p)
}

/// Exact interval maximization of Eq. 7 over `p ∈ [p_min, p_max]` —
/// valid for **all** `u, p ∈ [−1, 1]`:
///
/// * the linear term `u·p` is maximized at an endpoint depending on the
///   sign of `u`;
/// * the `√(1−p²)` term is maximized at the `p` of smallest magnitude in
///   the interval (1 if the interval straddles 0).
///
/// Maximizing the two terms separately dominates the joint maximum, so the
/// result is a correct (if slightly loose) single bound.
#[inline(always)]
pub fn update_safe(u: f64, p_min: f64, p_max: f64) -> f64 {
    let u = clamp_sim(u);
    let (p_min, p_max) = (clamp_sim(p_min), clamp_sim(p_max));
    crate::audit::debug_invariant(p_min <= p_max, "bounds::hamerly", "p-interval-order", || {
        format!("p_min {p_min} exceeds p_max {p_max}")
    });
    if p_min <= u {
        // Some center may have moved past the bound angle: saturate
        // (see `crate::bounds::update_upper`).
        return 1.0;
    }
    let linear = if u >= 0.0 { u * p_max } else { u * p_min };
    let max_sin = if p_min <= 0.0 && 0.0 <= p_max {
        1.0
    } else {
        sin_from_cos(if p_min.abs() < p_max.abs() { p_min } else { p_max })
    };
    clamp_sim(linear + sin_from_cos(u) * max_sin)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bounds::update_upper;
    use crate::util::prop::forall;

    /// Eq. 7 evaluated at every actual center movement; the true requirement
    /// for a single bound is `max_j update_upper(u, p_j)`.
    fn exact_requirement(u: f64, ps: &[f64]) -> f64 {
        ps.iter().fold(f64::MIN, |m, &p| m.max(update_upper(u, p)))
    }

    #[test]
    fn eq7_is_not_monotone_in_p() {
        // Raw Eq. 7 equals cos(θ_u − θ_p): it is maximized at p = u, not at
        // an endpoint — so no single p(j) extreme is safe for the unguarded
        // formula (the paper's §5.3 observation).
        use crate::bounds::sim_upper;
        // High u (small θ_u): the *larger* p loosens more…
        assert!(sim_upper(0.95, 0.9) > sim_upper(0.95, 0.6));
        // …while for lower u the *smaller* p loosens more.
        assert!(sim_upper(0.3, 0.6) > sim_upper(0.3, 0.9));
    }

    #[test]
    fn counterexample_naive_min_p_is_invalid() {
        // With high u and centers moving different amounts, plugging the
        // minimum p into unguarded Eq. 7 UNDERestimates the requirement:
        // the p = 0.9 center (which moved past the bound angle, p < u)
        // forces saturation to 1, which p_min = 0.6 does not reflect.
        let u = 0.95;
        let ps = [0.6, 0.9];
        let naive = update_naive_min_p(u, 0.6);
        let required = exact_requirement(u, &ps);
        assert!(
            naive < required - 1e-9,
            "expected the naive bound {naive} to be below the requirement {required}"
        );
    }

    #[test]
    fn guarded_min_p_is_valid_and_tightest() {
        forall(2000, 0x4a8, |g| {
            let u = g.sim();
            let n = g.usize_in(1, 8);
            let ps: Vec<f64> = (0..n).map(|_| g.sim()).collect();
            let p_min = ps.iter().cloned().fold(f64::MAX, f64::min);
            let p_max = ps.iter().cloned().fold(f64::MIN, f64::max);
            let req = exact_requirement(u, &ps);
            let tight = update_min_p_guarded(u, p_min);
            // Valid: dominates the exact requirement…
            assert!(tight >= req - 1e-12, "guarded min-p {tight} < req {req}");
            // …and exactly equals it (tightest possible single bound).
            assert!(
                (tight - req).abs() < 1e-12,
                "guarded min-p {tight} != req {req} (u={u}, ps={ps:?})"
            );
            // Dominated by the looser alternatives wherever they are valid.
            let safe = update_safe(u, p_min, p_max);
            assert!(safe >= tight - 1e-12);
            if u >= 0.0 && p_min >= 0.0 {
                assert!(update_eq9(u, p_min) >= tight - 1e-12);
            }
        });
    }

    #[test]
    fn counterexample_eq9_needs_nonnegative_u() {
        // Outside its validity regime (u < 0), Eq. 9 can under-bound —
        // which is exactly why the algorithm falls back to update_safe.
        let u = -0.9;
        let ps = [0.1, 0.99];
        let req = exact_requirement(u, &ps);
        let e9 = update_eq9(u, 0.1);
        assert!(e9 < req, "expected Eq.9 {e9} below requirement {req} for u<0");
        let safe = update_safe(u, 0.1, 0.99);
        assert!(safe >= req - 1e-9);
    }

    #[test]
    fn eq8_and_eq9_dominate_in_practical_regime() {
        // u ≥ 0 and all p(j) ∈ [0, 1]: the paper's setting.
        forall(1000, 0x4a3, |g| {
            let u = g.f64_in(0.0, 1.0);
            let n = g.usize_in(1, 8);
            let ps: Vec<f64> = (0..n).map(|_| g.f64_in(0.0, 1.0)).collect();
            let p_min = ps.iter().cloned().fold(f64::MAX, f64::min);
            let p_max = ps.iter().cloned().fold(f64::MIN, f64::max);
            let req = exact_requirement(u, &ps);
            let e8 = update_eq8(u, p_min, p_max);
            let e9 = update_eq9(u, p_min);
            assert!(e8 >= req - 1e-9, "Eq.8 {e8} below requirement {req} (u={u})");
            assert!(e9 >= req - 1e-9, "Eq.9 {e9} below requirement {req} (u={u})");
            assert!(e9 >= e8 - 1e-12, "Eq.9 {e9} should dominate Eq.8 {e8}");
        });
    }

    #[test]
    fn safe_dominates_for_all_inputs() {
        forall(2000, 0x4a6, |g| {
            let u = g.sim();
            let n = g.usize_in(1, 8);
            let ps: Vec<f64> = (0..n).map(|_| g.sim()).collect();
            let p_min = ps.iter().cloned().fold(f64::MAX, f64::min);
            let p_max = ps.iter().cloned().fold(f64::MIN, f64::max);
            let req = exact_requirement(u, &ps);
            let safe = update_safe(u, p_min, p_max);
            assert!(
                safe >= req - 1e-9,
                "update_safe {safe} below requirement {req} (u={u}, ps={ps:?})"
            );
        });
    }

    #[test]
    fn safe_reduces_to_eq8_in_practical_regime() {
        forall(500, 0x4a7, |g| {
            let u = g.f64_in(0.0, 1.0);
            let p_min = g.f64_in(0.0, 1.0);
            let p_max = g.f64_in(p_min, 1.0);
            let safe = update_safe(u, p_min, p_max);
            let e8 = update_eq8(u, p_min, p_max);
            assert!(
                (safe - e8).abs() < 1e-12,
                "safe {safe} != Eq.8 {e8} for u={u} p=[{p_min},{p_max}]"
            );
        });
    }

    #[test]
    fn eq9_tightness_at_convergence() {
        // As p' → 1 the update must converge to a no-op.
        for &u in &[0.0, 0.3, 0.8, 0.999] {
            let updated = update_eq9(u, 1.0 - 1e-15);
            assert!((updated - u).abs() < 1e-6, "u={u}, updated={updated}");
        }
    }

    #[test]
    fn eq9_pre_matches_eq9() {
        forall(200, 0x4a5, |g| {
            let u = g.sim();
            let p = g.sim();
            assert!((update_eq9(u, p) - update_eq9_pre(u, 1.0 - p * p)).abs() < 1e-12);
        });
    }
}
