//! Integration of the PJRT runtime with the AOT artifacts: load the HLO
//! text produced by `python/compile/aot.py`, execute it on the CPU PJRT
//! client, and compare against the Rust-native computation on the same
//! inputs. Skipped (with a notice) when `make artifacts` has not run, and
//! compiled only with the `pjrt` feature (the `xla` crate and its PJRT C
//! library are unavailable on clean machines).
#![cfg(feature = "pjrt")]

// Bench and test targets favour readable literal casts and exact
// (bit-level) float assertions; the workspace clippy warnings on
// those patterns are aimed at library code.
#![allow(clippy::cast_possible_truncation, clippy::float_cmp)]

use sphkm::data::synth::SynthConfig;
use sphkm::data::tfidf::TfIdf;
use sphkm::runtime::{artifacts_available, AssignEngine, Manifest};
use sphkm::sparse::CsrMatrix;
use std::path::Path;

fn artifacts_dir() -> std::path::PathBuf {
    // Tests run from the package root.
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn skip_if_missing() -> bool {
    if !artifacts_available(&artifacts_dir()) {
        eprintln!("SKIP: no artifacts (run `make artifacts` first)");
        return true;
    }
    false
}

/// A unit-row dataset matching the (k=16, d=512) artifact.
fn dataset_512() -> CsrMatrix {
    let ds = SynthConfig {
        name: "rt".into(),
        n_docs: 600, // exercises full tiles (256) plus a partial tail (88)
        vocab: 512,
        topics: 16,
        doc_len_mean: 30.0,
        doc_len_sigma: 0.4,
        topic_strength: 0.7,
        shared_vocab_frac: 0.25,
        zipf_s: 1.1,
        anomaly_frac: 0.0,
        tfidf: TfIdf::default(),
    }
    .generate(99);
    ds.matrix
}

fn centers_from_rows(data: &CsrMatrix, k: usize) -> Vec<f32> {
    let mut centers = vec![0.0f32; k * data.cols()];
    for j in 0..k {
        let row = data.row(j * 7);
        for (t, &c) in row.indices.iter().enumerate() {
            centers[j * data.cols() + c as usize] = row.values[t];
        }
    }
    centers
}

#[test]
fn engine_matches_native_assignment() {
    if skip_if_missing() {
        return;
    }
    let data = dataset_512();
    let k = 16;
    let centers = centers_from_rows(&data, k);
    let mut engine = AssignEngine::load(
        &artifacts_dir(),
        Manifest { batch: 256, k, dim: 512 },
    )
    .expect("engine load");
    assert!(engine.platform().to_lowercase().contains("cpu"));

    let tile = engine.assign_all(&data, &centers).expect("execute");
    assert_eq!(tile.best.len(), data.rows());

    // Native reference: argmax / top-2 per row.
    for i in 0..data.rows() {
        let row = data.row(i);
        let mut best = f64::MIN;
        let mut second = f64::MIN;
        let mut best_j = 0usize;
        for j in 0..k {
            let s = row.dot_dense(&centers[j * 512..(j + 1) * 512]);
            if s > best {
                second = best;
                best = s;
                best_j = j;
            } else if s > second {
                second = s;
            }
        }
        let got_best = tile.best_sim[i] as f64;
        let got_second = tile.second_sim[i] as f64;
        assert!(
            (got_best - best).abs() < 1e-4,
            "row {i}: best {got_best} vs native {best}"
        );
        assert!(
            (got_second - second).abs() < 1e-4,
            "row {i}: second {got_second} vs native {second}"
        );
        // Index can differ only under near-ties.
        if tile.best[i] as usize != best_j {
            assert!((best - second).abs() < 1e-4, "row {i}: index mismatch");
        }
    }
}

#[test]
fn engine_rejects_wrong_shapes() {
    if skip_if_missing() {
        return;
    }
    let engine = AssignEngine::load(
        &artifacts_dir(),
        Manifest { batch: 256, k: 16, dim: 512 },
    )
    .expect("engine load");
    let bad_x = vec![0.0f32; 10];
    let centers = vec![0.0f32; 16 * 512];
    assert!(engine.assign_dense(&bad_x, &centers).is_err());
    let x = vec![0.0f32; 256 * 512];
    let bad_c = vec![0.0f32; 7];
    assert!(engine.assign_dense(&x, &bad_c).is_err());
}

#[test]
fn load_matching_finds_artifact() {
    if skip_if_missing() {
        return;
    }
    let e = AssignEngine::load_matching(&artifacts_dir(), 8, 1024).expect("match 8/1024");
    assert_eq!(e.manifest().k, 8);
    assert_eq!(e.manifest().dim, 1024);
    assert!(AssignEngine::load_matching(&artifacts_dir(), 999, 999).is_err());
}

#[test]
fn missing_artifact_is_a_clean_error() {
    let err = AssignEngine::load(
        Path::new("/nonexistent"),
        Manifest { batch: 1, k: 1, dim: 1 },
    )
    .unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("make artifacts"), "unhelpful error: {msg}");
}
