"""L1 Pallas kernel: tiled dense similarity matrix ``X @ C^T``.

This is the one dense compute hot-spot of the (otherwise control-flow
dominated) algorithm family: a block of points against all centers, used by
the runtime to (re)initialize `l`/`u` bounds and by the dense baseline.

TPU-shaped design (DESIGN.md §Hardware-Adaptation):

* grid = (B/bB, K/bK, D/bD); the D axis is the innermost (reduction) axis,
  so each (i, j) output tile stays resident in VMEM while partial products
  accumulate over D-tiles — the HBM↔VMEM schedule a CUDA kernel would
  express with threadblocks + shared memory is expressed with BlockSpecs.
* block shapes default to (128, 128, 512): MXU-friendly multiples of 128,
  f32 accumulation, VMEM footprint = (bB·bD + bK·bD + bB·bK)·4 B ≈ 576 KiB
  per step — far under the ~16 MiB budget, leaving room for
  double-buffering.
* `interpret=True` everywhere in this environment: the CPU PJRT plugin
  cannot execute Mosaic custom-calls; real-TPU lowering would only change
  `interpret` and the artifacts would be compile-only targets.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK = (128, 128, 512)


def _matmul_kernel(x_ref, c_ref, o_ref):
    """One grid step: accumulate x_tile @ c_tile^T into the output tile."""
    d_step = pl.program_id(2)

    @pl.when(d_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], c_ref[...].T, preferred_element_type=jnp.float32
    )


def _pick_block(n, want):
    """Largest divisor of n that is <= want (keeps the grid exact without
    padding; shapes in this project are chosen to divide evenly)."""
    b = min(n, want)
    while n % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block",))
def similarity(x, c, *, block=None):
    """Tiled ``x[B,D] @ c[K,D]^T -> [B,K]`` as a Pallas kernel.

    ``block`` is ``(bB, bK, bD)``; each entry is clamped to a divisor of the
    corresponding dimension.
    """
    b, d = x.shape
    k, d2 = c.shape
    assert d == d2, f"dim mismatch {d} vs {d2}"
    want = block or DEFAULT_BLOCK
    bb = _pick_block(b, want[0])
    bk = _pick_block(k, want[1])
    bd = _pick_block(d, want[2])
    grid = (b // bb, k // bk, d // bd)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bd), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bd), lambda i, j, s: (j, s)),
        ],
        out_specs=pl.BlockSpec((bb, bk), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=True,
    )(x, c)


def vmem_bytes(block=DEFAULT_BLOCK):
    """VMEM footprint estimate of one grid step (f32), for DESIGN.md §Perf."""
    bb, bk, bd = block
    return 4 * (bb * bd + bk * bd + bb * bk)
