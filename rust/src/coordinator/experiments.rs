//! Drivers that regenerate every table and figure of the paper's
//! evaluation (§6). Each driver prints a paper-style table and writes a
//! CSV under `results/` for plotting. See DESIGN.md §5 for the experiment
//! index and EXPERIMENTS.md for recorded outcomes.

use super::plot::{render, PlotCfg, Series};
use super::report::{fmt_ms, fmt_pct, Table};
use crate::data::datasets::{self, Scale};
use crate::data::Dataset;
use crate::init::{seed_centers, InitMethod};
use crate::kmeans::{
    Engine, ExactParams, KMeansResult, KernelChoice, MiniBatchParams, SphericalKMeans, Variant,
};
use crate::sparse::DenseMatrix;
use crate::util::rng::SplitMix64;

/// Options shared by all experiment drivers.
#[derive(Debug, Clone)]
pub struct ExperimentOpts {
    /// Dataset scale preset.
    pub scale: Scale,
    /// Master seed; per-cell RNGs are derived deterministically.
    pub seed: u64,
    /// Repetitions (different seeds) per cell; the paper uses 10.
    pub reps: usize,
    /// The k grid; the paper uses {2, 10, 20, 50, 100, 200}.
    pub ks: Vec<usize>,
    /// Iteration cap per run.
    pub max_iter: usize,
    /// Worker threads for the sharded assignment phase (`0` = all cores,
    /// `1` = serial). Results are thread-count invariant, so this only
    /// changes wall times — the paper's tables default to serial.
    pub threads: usize,
    /// Similarity-kernel override (`--kernel`). `None` keeps each driver's
    /// default: the gather backend, the paper's cost model (identical
    /// per-similarity work to the pruned variants' selective
    /// computations). Results are kernel-invariant up to summation-order
    /// rounding — Dense and Inverted are bit-identical — so this, too,
    /// mainly changes wall times.
    pub kernel: Option<KernelChoice>,
    /// Directory for CSV output.
    pub out_dir: std::path::PathBuf,
}

impl Default for ExperimentOpts {
    fn default() -> Self {
        Self {
            scale: Scale::Small,
            seed: 42,
            reps: 3,
            ks: vec![2, 10, 20, 50, 100, 200],
            max_iter: 200,
            threads: 1,
            kernel: None,
            out_dir: "results".into(),
        }
    }
}

impl ExperimentOpts {
    /// Parse overrides from CLI args (`--scale`, `--seed`, `--reps`,
    /// `--ks`, `--max-iter`, `--threads`, `--kernel`, `--quick`).
    pub fn from_args(args: &crate::util::cli::Args) -> Self {
        let mut o = Self::default();
        if args.flag("quick") {
            o.scale = Scale::Tiny;
            o.reps = 1;
            o.ks = vec![2, 10, 20, 50];
        }
        o.scale = args.get_or("scale", o.scale.name().parse().unwrap()).unwrap_or(o.scale);
        o.seed = args.get_or("seed", o.seed).unwrap_or(o.seed);
        o.reps = args.get_or("reps", o.reps).unwrap_or(o.reps).max(1);
        o.max_iter = args.get_or("max-iter", o.max_iter).unwrap_or(o.max_iter);
        o.threads = args.get_or("threads", o.threads).unwrap_or(o.threads);
        if let Some(raw) = args.get("kernel") {
            // Reject hard, like the cluster/sweep parses: a typo silently
            // falling back to the default would mislabel a whole sweep.
            match raw.parse::<KernelChoice>() {
                Ok(kc) => o.kernel = Some(kc),
                Err(e) => {
                    eprintln!("{e}");
                    std::process::exit(2);
                }
            }
        }
        if let Ok(Some(ks)) = args.list::<usize>("ks") {
            o.ks = ks;
        }
        if let Some(dir) = args.get("out") {
            o.out_dir = dir.into();
        }
        o
    }

    /// Deterministic per-cell seed.
    fn cell_seed(&self, tag: &str, rep: usize) -> u64 {
        let mut h = SplitMix64::new(self.seed ^ rep as u64);
        let mut acc = h.next_u64();
        for b in tag.bytes() {
            acc = acc.wrapping_mul(0x100000001B3) ^ b as u64;
        }
        SplitMix64::new(acc).next_u64()
    }

    fn save(&self, t: &Table, name: &str) {
        let path = self.out_dir.join(name);
        if let Err(e) = t.save_csv(&path) {
            eprintln!("warning: could not write {}: {e}", path.display());
        } else {
            println!("[csv] {}", path.display());
        }
    }
}

/// Run one (dataset, variant, k, rep) cell from shared initial centers.
/// Unless `--kernel` overrides it, cells run the **gather** similarity
/// kernel so per-similarity cost matches the pruned variants (the paper's
/// cost model); the transposed SIMD path is benchmarked separately as
/// "Standard+SIMD".
fn run_cell(
    ds: &Dataset,
    variant: Variant,
    k: usize,
    initial: DenseMatrix,
    opts: &ExperimentOpts,
) -> KMeansResult {
    SphericalKMeans::new(k)
        .variant(variant)
        .max_iter(opts.max_iter)
        .threads(opts.threads)
        .kernel(opts.kernel.unwrap_or(KernelChoice::Gather))
        .warm_start_centers(initial)
        .fit(&ds.matrix)
        .expect("experiment cell configuration is valid")
        .into_result()
}

/// The extra beyond-paper baseline: Standard with the dense
/// transposed-centers SIMD kernel (see EXPERIMENTS.md §Perf).
fn run_cell_simd_standard(
    ds: &Dataset,
    k: usize,
    initial: DenseMatrix,
    opts: &ExperimentOpts,
) -> KMeansResult {
    SphericalKMeans::new(k)
        .variant(Variant::Standard)
        .max_iter(opts.max_iter)
        .threads(opts.threads)
        .kernel(KernelChoice::Dense)
        .warm_start_centers(initial)
        .fit(&ds.matrix)
        .expect("experiment cell configuration is valid")
        .into_result()
}

/// Uniform initial centers for a cell (shared across variants so the
/// exactness property makes timings comparable).
fn uniform_centers(ds: &Dataset, k: usize, seed: u64) -> DenseMatrix {
    seed_centers(&ds.matrix, k, &InitMethod::Uniform, seed).centers
}

// ---------------------------------------------------------------- Table 1

/// Table 1: the dataset inventory (rows, columns, density).
pub fn table1(opts: &ExperimentOpts) -> Table {
    println!("\n== Table 1: data sets (scale={}) ==", opts.scale.name());
    let mut t = Table::new(&["Data set", "Rows", "Columns", "Non-zero"]);
    for ds in datasets::paper_datasets(opts.scale, opts.seed) {
        let (name, rows, cols, dens) = ds.table1_row();
        t.row(vec![
            name,
            rows.to_string(),
            cols.to_string(),
            format!("{dens:.3}%"),
        ]);
    }
    println!("{}", t.render());
    opts.save(&t, "table1.csv");
    t
}

// ---------------------------------------------------------------- Fig. 1

/// Fig. 1: per-iteration similarity computations (a cumulative: b) and
/// per-iteration run time (c, cumulative: d) for one initialization on the
/// DBLP author-conference analogue with large k.
///
/// Returns the long-format table: one row per (algorithm, iteration).
pub fn fig1(opts: &ExperimentOpts, k: usize) -> Table {
    println!(
        "\n== Fig. 1: per-iteration behaviour, DBLP Author-Conf., k={k}, scale={} ==",
        opts.scale.name()
    );
    let ds = datasets::dblp_author_conf(opts.scale, opts.seed);
    let k = k.min(ds.matrix.rows());
    let initial = uniform_centers(&ds, k, opts.cell_seed("fig1", 0));
    let mut t = Table::new(&[
        "Algorithm", "iter", "sims", "cum_sims", "ms", "cum_ms", "reassign",
    ]);
    let mut sims_series: Vec<Series> = Vec::new();
    let mut time_series: Vec<Series> = Vec::new();
    for variant in Variant::PAPER_SET {
        // Average wall times over reps (sims are deterministic).
        let mut runs = Vec::new();
        for _ in 0..opts.reps {
            runs.push(run_cell(&ds, variant, k, initial.clone(), opts));
        }
        let r0 = &runs[0];
        for it in 0..r0.stats.iters.len() {
            let s = &r0.stats.iters[it];
            let ms = runs
                .iter()
                .filter_map(|r| r.stats.iters.get(it).map(|i| i.wall_ms))
                .sum::<f64>()
                / runs.len() as f64;
            let cum_ms: f64 = (0..=it)
                .map(|j| {
                    runs.iter()
                        .filter_map(|r| r.stats.iters.get(j).map(|i| i.wall_ms))
                        .sum::<f64>()
                        / runs.len() as f64
                })
                .sum();
            t.row(vec![
                variant.name().into(),
                it.to_string(),
                s.sims_total().to_string(),
                r0.stats.cumulative_sims()[it].to_string(),
                format!("{ms:.2}"),
                format!("{cum_ms:.2}"),
                s.reassignments.to_string(),
            ]);
        }
        println!(
            "  {:<14} iters={:<3} total sims={:<12} total ms={:>10.1} obj={:.2}",
            variant.name(),
            r0.iterations,
            r0.stats.total_sims(),
            runs.iter().map(|r| r.stats.total_ms()).sum::<f64>() / runs.len() as f64,
            r0.objective,
        );
        sims_series.push(Series {
            name: variant.name().into(),
            points: r0
                .stats
                .iters
                .iter()
                .enumerate()
                .map(|(it, s)| (it as f64, (s.sims_total() as f64).max(1.0)))
                .collect(),
        });
        time_series.push(Series {
            name: variant.name().into(),
            points: r0
                .stats
                .cumulative_ms()
                .iter()
                .enumerate()
                .map(|(it, &ms)| (it as f64, ms.max(1e-3)))
                .collect(),
        });
    }
    println!(
        "\n{}",
        render(
            &sims_series,
            &PlotCfg {
                title: format!("Fig. 1a: similarity computations per iteration (k={k}, log y)"),
                log_y: true,
                ..Default::default()
            }
        )
    );
    println!(
        "{}",
        render(
            &time_series,
            &PlotCfg {
                title: format!("Fig. 1d: cumulative run time (ms) per iteration (k={k})"),
                ..Default::default()
            }
        )
    );
    opts.save(&t, "fig1.csv");
    t
}

// ---------------------------------------------------------------- Table 2

/// Table 2: relative change of the converged objective vs uniform random
/// initialization (lower = better), for k-means++ and AFK-MC² with
/// α ∈ {1, 1.5}, averaged over `reps` seeds.
pub fn table2(opts: &ExperimentOpts) -> Table {
    println!(
        "\n== Table 2: initialization quality (relative objective vs uniform), scale={} ==",
        opts.scale.name()
    );
    let methods = InitMethod::paper_set();
    let mut t = {
        let mut header: Vec<String> = vec!["Data set".into(), "Initialization".into()];
        header.extend(opts.ks.iter().map(|k| format!("k={k}")));
        let hrefs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        Table::new(&hrefs)
    };
    for ds in datasets::paper_datasets(opts.scale, opts.seed) {
        // Baseline objectives per (k, rep) with uniform init.
        let mut base = vec![vec![0.0f64; opts.reps]; opts.ks.len()];
        for (ki, &k) in opts.ks.iter().enumerate() {
            let k = k.min(ds.matrix.rows());
            for rep in 0..opts.reps {
                let seed = opts.cell_seed(&format!("t2-{}-{k}", ds.name), rep);
                let initial = uniform_centers(&ds, k, seed);
                // Simplified Hamerly: fastest reasonable default; the
                // converged objective is variant-independent (exactness).
                let r = run_cell(&ds, Variant::SimplifiedHamerly, k, initial, opts);
                base[ki][rep] = r.objective;
            }
        }
        for method in &methods {
            let mut cells: Vec<String> = Vec::with_capacity(opts.ks.len());
            for (ki, &k) in opts.ks.iter().enumerate() {
                let k = k.min(ds.matrix.rows());
                if matches!(method, InitMethod::Uniform) {
                    cells.push(fmt_pct(0.0));
                    continue;
                }
                let mut rel_sum = 0.0;
                for rep in 0..opts.reps {
                    let seed = opts.cell_seed(&format!("t2-{}-{k}", ds.name), rep);
                    let init = seed_centers(&ds.matrix, k, method, seed);
                    let r = run_cell(&ds, Variant::SimplifiedHamerly, k, init.centers, opts);
                    rel_sum += r.objective / base[ki][rep] - 1.0;
                }
                cells.push(fmt_pct(rel_sum / opts.reps as f64));
            }
            let mut row = vec![ds.name.clone(), method.name()];
            row.extend(cells);
            t.row(row);
        }
        println!("  {} done", ds.name);
    }
    println!("{}", t.render());
    opts.save(&t, "table2.csv");
    t
}

// ---------------------------------------------------------------- Table 3

/// Table 3: run times (ms) of all five paper variants across the dataset ×
/// k grid, averaged over `reps` seeds (same seeds across variants).
/// `extended` additionally includes the Yinyang variant.
pub fn table3(opts: &ExperimentOpts, extended: bool) -> Table {
    println!(
        "\n== Table 3: run times in ms (reps={}, scale={}) ==",
        opts.reps,
        opts.scale.name()
    );
    let variants: Vec<Variant> = if extended {
        Variant::ALL.to_vec()
    } else {
        Variant::PAPER_SET.to_vec()
    };
    // Extended mode adds the SIMD standard baseline as a final pseudo-row.
    let n_rows = variants.len() + usize::from(extended);
    let mut t = {
        let mut header: Vec<String> = vec!["Data set".into(), "Algorithm".into()];
        header.extend(opts.ks.iter().map(|k| format!("k={k}")));
        let hrefs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        Table::new(&hrefs)
    };
    for ds in datasets::paper_datasets(opts.scale, opts.seed) {
        let mut cells = vec![vec![String::new(); opts.ks.len()]; n_rows];
        for (ki, &k) in opts.ks.iter().enumerate() {
            let k = k.min(ds.matrix.rows());
            // Shared initial centers per rep.
            let initials: Vec<DenseMatrix> = (0..opts.reps)
                .map(|rep| {
                    uniform_centers(&ds, k, opts.cell_seed(&format!("t3-{}-{k}", ds.name), rep))
                })
                .collect();
            for (vi, &variant) in variants.iter().enumerate() {
                let mut total_ms = 0.0;
                for initial in &initials {
                    let sw = crate::util::timer::Stopwatch::start();
                    let r = run_cell(&ds, variant, k, initial.clone(), opts);
                    total_ms += sw.ms();
                    std::hint::black_box(r.objective);
                }
                cells[vi][ki] = fmt_ms(total_ms / opts.reps as f64);
            }
            if extended {
                let mut total_ms = 0.0;
                for initial in &initials {
                    let sw = crate::util::timer::Stopwatch::start();
                    let r = run_cell_simd_standard(&ds, k, initial.clone(), opts);
                    total_ms += sw.ms();
                    std::hint::black_box(r.objective);
                }
                cells[variants.len()][ki] = fmt_ms(total_ms / opts.reps as f64);
            }
        }
        for (vi, &variant) in variants.iter().enumerate() {
            let mut row = vec![ds.name.clone(), variant.name().to_string()];
            row.extend(cells[vi].clone());
            t.row(row);
        }
        if extended {
            let mut row = vec![ds.name.clone(), "Standard+SIMD".to_string()];
            row.extend(cells[variants.len()].clone());
            t.row(row);
        }
        println!("  {} done", ds.name);
    }
    println!("{}", t.render());
    opts.save(&t, "table3.csv");
    t
}

// ---------------------------------------------------------------- Fig. 2

/// Fig. 2: run time vs k for the author-conference analogue (high N, low d)
/// and its transpose (low N, high d). The paper's headline contrast: the
/// `O(k²·d)` center–center cost makes full Elkan/Hamerly blow up on the
/// transposed data.
pub fn fig2(opts: &ExperimentOpts) -> Table {
    println!(
        "\n== Fig. 2: run time vs k, Author-Conf. vs Conf.-Author, scale={} ==",
        opts.scale.name()
    );
    let pair = [
        datasets::dblp_author_conf(opts.scale, opts.seed),
        datasets::dblp_conf_author(opts.scale, opts.seed),
    ];
    let mut t = Table::new(&["Data set", "Algorithm", "k", "ms", "total_sims", "iters"]);
    for ds in &pair {
        let mut series: Vec<Series> = Variant::PAPER_SET
            .iter()
            .map(|v| Series { name: v.name().into(), points: Vec::new() })
            .collect();
        for &k in &opts.ks {
            let k = k.min(ds.matrix.rows());
            let initials: Vec<DenseMatrix> = (0..opts.reps)
                .map(|rep| {
                    uniform_centers(ds, k, opts.cell_seed(&format!("f2-{}-{k}", ds.name), rep))
                })
                .collect();
            for (vi, variant) in Variant::PAPER_SET.into_iter().enumerate() {
                let mut total_ms = 0.0;
                let mut sims = 0u64;
                let mut iters = 0usize;
                for initial in &initials {
                    let sw = crate::util::timer::Stopwatch::start();
                    let r = run_cell(ds, variant, k, initial.clone(), opts);
                    total_ms += sw.ms();
                    sims = r.stats.total_sims();
                    iters = r.iterations;
                }
                let mean_ms = total_ms / opts.reps as f64;
                series[vi].points.push((k as f64, mean_ms.max(1e-3)));
                t.row(vec![
                    ds.name.clone(),
                    variant.name().into(),
                    k.to_string(),
                    fmt_ms(mean_ms),
                    sims.to_string(),
                    iters.to_string(),
                ]);
            }
        }
        println!(
            "\n{}",
            render(
                &series,
                &PlotCfg {
                    title: format!("Fig. 2: run time (ms, log y) vs k — {}", ds.name),
                    log_y: true,
                    ..Default::default()
                }
            )
        );
        println!("  {} done", ds.name);
    }
    println!("{}", t.render());
    opts.save(&t, "fig2.csv");
    t
}

// ------------------------------------------------------------- Ablations

/// Ablation: the cost of the center–center (`cc`/`s`) pruning machinery as
/// dimensionality grows — full vs simplified variants on synthetic corpora
/// of increasing vocabulary (DESIGN.md §5). Quantifies the Fig. 2 effect in
/// isolation.
pub fn ablation_cc(opts: &ExperimentOpts, k: usize) -> Table {
    println!("\n== Ablation: center-center bound cost vs dimensionality (k={k}) ==");
    let dims = [500usize, 2_000, 8_000, 32_000];
    let mut t = Table::new(&["d", "Algorithm", "ms", "cc_sims", "pc_sims"]);
    for &d in &dims {
        let ds = crate::data::synth::SynthConfig {
            name: format!("synth-d{d}"),
            n_docs: (opts.scale.factor() * 2000.0) as usize,
            vocab: d,
            topics: 16,
            doc_len_mean: 60.0,
            doc_len_sigma: 0.5,
            topic_strength: 0.6,
            shared_vocab_frac: 0.3,
            zipf_s: 1.1,
            anomaly_frac: 0.0,
            tfidf: Default::default(),
        }
        .generate(opts.seed);
        let k = k.min(ds.matrix.rows());
        let initial = uniform_centers(&ds, k, opts.cell_seed(&format!("cc-{d}"), 0));
        for variant in [
            Variant::Elkan,
            Variant::SimplifiedElkan,
            Variant::Hamerly,
            Variant::SimplifiedHamerly,
        ] {
            let sw = crate::util::timer::Stopwatch::start();
            let r = run_cell(&ds, variant, k, initial.clone(), opts);
            let ms = sw.ms();
            let cc: u64 = r.stats.iters.iter().map(|i| i.sims_center_center).sum();
            t.row(vec![
                d.to_string(),
                variant.name().into(),
                fmt_ms(ms),
                cc.to_string(),
                r.stats.total_point_center().to_string(),
            ]);
        }
    }
    println!("{}", t.render());
    opts.save(&t, "ablation_cc.csv");
    t
}

/// Ablation (beyond the paper, §7 synergy): k-means++ already computes all
/// point-to-seed similarities; pre-initializing the bound structures from
/// them removes the initial `O(N·k)` assignment pass. Compares plain vs
/// pre-initialized runs per variant.
pub fn ablation_preinit(opts: &ExperimentOpts, k: usize) -> Table {
    println!("\n== Ablation: bound pre-initialization from k-means++ (k={k}) ==");
    let mut t = Table::new(&[
        "Data set", "Variant", "mode", "ms", "pc sims", "iters",
    ]);
    for ds in [
        datasets::dblp_author_conf(opts.scale, opts.seed),
        datasets::rcv1(opts.scale, opts.seed ^ 4),
    ] {
        let k = k.min(ds.matrix.rows() / 2);
        let method = InitMethod::KMeansPP { alpha: 1.0 };
        for variant in [
            Variant::SimplifiedElkan,
            Variant::SimplifiedHamerly,
            Variant::Exponion,
        ] {
            for preinit in [false, true] {
                let mut ms = 0.0;
                let mut sims = 0u64;
                let mut iters = 0;
                for rep in 0..opts.reps {
                    let seed = opts.cell_seed(&format!("pre-{}-{k}", ds.name), rep);
                    let sw = crate::util::timer::Stopwatch::start();
                    // Seeding runs inside `fit` either way (same seed ⇒
                    // identical centers); `preinit` flips only the §7
                    // bound pre-initialization.
                    let r = SphericalKMeans::new(k)
                        .engine(Engine::Exact(ExactParams {
                            variant,
                            preinit,
                            ..Default::default()
                        }))
                        .init(method)
                        .seed(seed)
                        .threads(opts.threads)
                        .kernel(opts.kernel.unwrap_or(KernelChoice::Gather))
                        .max_iter(opts.max_iter)
                        .fit(&ds.matrix)
                        .expect("ablation cell configuration is valid");
                    ms += sw.ms();
                    sims = r.stats().total_point_center();
                    iters = r.iterations();
                }
                t.row(vec![
                    ds.name.clone(),
                    variant.name().into(),
                    if preinit { "preinit".into() } else { "plain".into() },
                    fmt_ms(ms / opts.reps as f64),
                    sims.to_string(),
                    iters.to_string(),
                ]);
            }
        }
        println!("  {} done", ds.name);
    }
    println!("{}", t.render());
    opts.save(&t, "ablation_preinit.csv");
    t
}

// ------------------------------------------------------------- Mini-batch

/// Mini-batch vs full-batch trade-off (beyond the paper; the ROADMAP's
/// large-corpus workload): objective gap and point–center similarity
/// counts against the full-batch Standard baseline on a synthetic Zipf
/// corpus, across batch sizes and center-truncation settings.
pub fn minibatch(opts: &ExperimentOpts, k: usize) -> Table {
    println!(
        "\n== Mini-batch trade-off: objective gap vs similarity count (k={k}, scale={}) ==",
        opts.scale.name()
    );
    let ds = crate::data::synth::SynthConfig {
        name: "mb-synth".into(),
        n_docs: (opts.scale.factor() * 4000.0) as usize,
        vocab: 5_000,
        topics: 16,
        doc_len_mean: 60.0,
        doc_len_sigma: 0.5,
        topic_strength: 0.7,
        shared_vocab_frac: 0.3,
        zipf_s: 1.1,
        anomaly_frac: 0.0,
        tfidf: Default::default(),
    }
    .generate(opts.seed);
    let k = k.min(ds.matrix.rows() / 2).max(2);
    let initial = uniform_centers(&ds, k, opts.cell_seed("mb", 0));
    let mut t = Table::new(&["mode", "ms", "pc_sims", "objective", "gap"]);

    let sw = crate::util::timer::Stopwatch::start();
    let full = run_cell(&ds, Variant::Standard, k, initial.clone(), opts);
    t.row(vec![
        "Standard (full batch)".into(),
        fmt_ms(sw.ms()),
        full.stats.total_point_center().to_string(),
        format!("{:.2}", full.objective),
        fmt_pct(0.0),
    ]);
    let sw = crate::util::timer::Stopwatch::start();
    let pruned = run_cell(&ds, Variant::SimplifiedHamerly, k, initial.clone(), opts);
    t.row(vec![
        "Simp.Hamerly (full batch)".into(),
        fmt_ms(sw.ms()),
        pruned.stats.total_point_center().to_string(),
        format!("{:.2}", pruned.objective),
        fmt_pct(crate::metrics::objective_gap(pruned.objective, full.objective)),
    ]);

    for &(batch, truncate) in &[(256usize, None), (1024, None), (1024, Some(128usize))] {
        let sw = crate::util::timer::Stopwatch::start();
        let r = SphericalKMeans::new(k)
            .engine(Engine::MiniBatch(MiniBatchParams {
                batch_size: batch,
                epochs: 8,
                tol: 1e-4,
                truncate,
            }))
            .seed(opts.seed)
            .threads(opts.threads)
            .kernel(opts.kernel.unwrap_or(KernelChoice::Gather))
            .warm_start_centers(initial.clone())
            .fit(&ds.matrix)
            .expect("mini-batch cell configuration is valid")
            .into_result();
        let label = match truncate {
            Some(m) => format!("MiniBatch b={batch} top-{m}"),
            None => format!("MiniBatch b={batch}"),
        };
        t.row(vec![
            label,
            fmt_ms(sw.ms()),
            r.stats.total_point_center().to_string(),
            format!("{:.2}", r.objective),
            fmt_pct(crate::metrics::objective_gap(r.objective, full.objective)),
        ]);
    }
    println!("{}", t.render());
    opts.save(&t, "minibatch.csv");
    t
}

/// `bench --exp serve`: the train → persist → serve pipeline measured
/// end-to-end. Trains a truncated mini-batch model on a sparse synthetic
/// text corpus, round-trips it through [`crate::model::Model`]
/// persistence, then queries the whole corpus through the
/// [`crate::serve::QueryEngine`] — pruned vs exhaustive traversals at
/// several top-p widths, reporting queries/sec and multiply-adds. The
/// traversals are asserted bit-identical on every cell.
pub fn serve(opts: &ExperimentOpts, k: usize) -> Table {
    use crate::model::Model;
    use crate::serve::{QueryEngine, ServeConfig, ServeMode};
    println!(
        "\n== Serving: pruned vs exhaustive top-p queries (k={k}, scale={}) ==",
        opts.scale.name()
    );
    let ds = crate::data::synth::SynthConfig {
        name: "serve-synth".into(),
        n_docs: (opts.scale.factor() * 6000.0) as usize,
        vocab: 20_000,
        topics: k.max(2),
        doc_len_mean: 60.0,
        doc_len_sigma: 0.4,
        topic_strength: 0.65,
        shared_vocab_frac: 0.2,
        zipf_s: 1.05,
        anomaly_frac: 0.0,
        tfidf: Default::default(),
    }
    .generate(opts.seed);
    let k = k.min(ds.matrix.rows() / 2).max(2);
    let fitted = SphericalKMeans::new(k)
        .engine(Engine::MiniBatch(MiniBatchParams {
            batch_size: 1024,
            epochs: 4,
            truncate: Some(64),
            ..Default::default()
        }))
        .seed(opts.seed)
        .threads(opts.threads)
        .kernel(opts.kernel.unwrap_or(KernelChoice::Inverted))
        .fit(&ds.matrix)
        .expect("serve experiment configuration is valid");
    // Persistence round trip: serve what was loaded, not what was trained.
    // Keyed by pid as well as seed: concurrent runs sharing a seed must
    // not race on the same save/load/remove cycle.
    let path = std::env::temp_dir()
        .join(format!("sphkm-serve-exp-{}-{}.spkm", std::process::id(), opts.seed));
    fitted.save(&path).expect("model save must succeed");
    let model = Model::load(&path).expect("just-saved model must load");
    let _ = std::fs::remove_file(&path);
    println!(
        "  model: k={k}, d={}, {} center nnz ({:.3}% dense)",
        model.d(),
        model.center_nnz(),
        model.center_density() * 100.0
    );
    let engine = QueryEngine::new(
        model,
        &ServeConfig { mode: ServeMode::Pruned, threads: opts.threads },
    );
    let mut t = Table::new(&["top-p", "mode", "ms", "qps", "madds/query", "pruned/query"]);
    for &p in &[1usize, 5, 10] {
        let sw = crate::util::timer::Stopwatch::start();
        let (ex, ex_stats) = engine.top_p_batch_exhaustive(&ds.matrix, p);
        let ex_ms = sw.ms();
        let sw = crate::util::timer::Stopwatch::start();
        let (pr, pr_stats) = engine.top_p_batch_pruned(&ds.matrix, p);
        let pr_ms = sw.ms();
        assert_eq!(ex, pr, "pruned top-{p} must equal exhaustive bitwise");
        let n = ex_stats.queries.max(1) as f64;
        for (mode, ms, stats) in [("exhaustive", ex_ms, ex_stats), ("pruned", pr_ms, pr_stats)] {
            t.row(vec![
                p.to_string(),
                mode.into(),
                fmt_ms(ms),
                format!("{:.0}", stats.queries as f64 / (ms / 1000.0).max(1e-9)),
                format!("{:.1}", stats.madds as f64 / n),
                format!("{:.1}", stats.centers_pruned as f64 / n),
            ]);
        }
    }
    println!("{}", t.render());
    opts.save(&t, "serve.csv");
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_opts() -> ExperimentOpts {
        ExperimentOpts {
            scale: Scale::Tiny,
            seed: 1,
            reps: 1,
            ks: vec![2, 5],
            max_iter: 30,
            threads: 1,
            kernel: None,
            out_dir: std::env::temp_dir().join("sphkm-exp-tests"),
        }
    }

    #[test]
    fn table1_has_six_rows() {
        let t = table1(&tiny_opts());
        assert_eq!(t.len(), 6);
    }

    #[test]
    fn fig1_produces_series_for_all_variants() {
        let mut o = tiny_opts();
        o.ks = vec![5];
        let t = fig1(&o, 5);
        // At least 2 iterations per variant (init + ≥1).
        assert!(t.len() >= 2 * Variant::PAPER_SET.len());
    }

    #[test]
    fn minibatch_driver_reports_all_modes() {
        let t = minibatch(&tiny_opts(), 8);
        // Two full-batch baselines + three mini-batch configurations.
        assert_eq!(t.len(), 5);
    }

    #[test]
    fn serve_driver_reports_both_traversals_per_p() {
        let t = serve(&tiny_opts(), 8);
        // Three top-p widths × (exhaustive, pruned).
        assert_eq!(t.len(), 6);
    }

    #[test]
    fn opts_from_args() {
        let args = crate::util::cli::Args::parse(
            ["--scale", "tiny", "--reps", "2", "--ks", "2,4", "--kernel", "inverted"]
                .iter()
                .map(|s| s.to_string()),
        );
        let o = ExperimentOpts::from_args(&args);
        assert_eq!(o.scale, Scale::Tiny);
        assert_eq!(o.reps, 2);
        assert_eq!(o.ks, vec![2, 4]);
        assert_eq!(o.kernel, Some(KernelChoice::Inverted));
        assert_eq!(ExperimentOpts::default().kernel, None, "driver default");
    }
}
