//! Spherical k-means and its accelerated variants (§5 of the paper).
//!
//! All variants share the alternating-optimization outline: assign every
//! point to the most-similar center, then recompute each center as the
//! unit-scaled sum of its points. They differ only in how many of the
//! point×center similarity computations they can *prove unnecessary*:
//!
//! | Variant | Bounds kept | Extra per-iteration cost |
//! |---|---|---|
//! | [`Variant::Standard`] | none | — |
//! | [`Variant::Elkan`] | `l(i)`, `u(i,j)` (N·k) | `k²/2` center–center sims |
//! | [`Variant::SimplifiedElkan`] | `l(i)`, `u(i,j)` (N·k) | — |
//! | [`Variant::Hamerly`] | `l(i)`, `u(i)` (2·N) | `k²/2` center–center sims |
//! | [`Variant::SimplifiedHamerly`] | `l(i)`, `u(i)` (2·N) | — |
//! | [`Variant::Yinyang`] | `l(i)`, `u(i,g)` (N·(G+1)) | `k²/2` (group ceilings) |
//! | [`Variant::Exponion`] | `l(i)`, `u(i)` (2·N) | `k²/2` sims + `k² log k` sort |
//!
//! Every accelerated variant is **exact**: given the same initial centers it
//! produces the same assignment sequence as [`Variant::Standard`] (this is
//! asserted by the `exactness` integration tests).

pub mod centers;
pub mod stats;

mod elkan;
mod exponion;
mod hamerly;
mod simplified_elkan;
mod simplified_hamerly;
mod standard;
mod yinyang;

use crate::data::Dataset;
use crate::init::InitMethod;
use crate::sparse::{CsrMatrix, DenseMatrix};
use crate::util::timer::Stopwatch;
pub use centers::Centers;
pub use stats::{IterStats, RunStats};

/// Which algorithm variant to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Variant {
    /// The baseline spherical k-means (Dhillon & Modha 2001) with the §5
    /// implementation optimizations but no pruning.
    Standard,
    /// Spherical Elkan (§5.2): per-center upper bounds + center–center
    /// pruning (`cc`/`s` tests).
    Elkan,
    /// Spherical Simplified Elkan (§5.1, after Newling & Fleuret): per-center
    /// upper bounds only.
    SimplifiedElkan,
    /// Spherical Hamerly (§5.3): one upper bound per point + `s` test.
    Hamerly,
    /// Spherical Simplified Hamerly (§5.4): one upper bound, no `s` test.
    SimplifiedHamerly,
    /// Spherical Yinyang (§5.5 — listed as future work in the paper;
    /// implemented here): group bounds between Elkan and Hamerly.
    Yinyang,
    /// Spherical Exponion (§5.5 — beyond the paper): Hamerly's bounds plus
    /// sorted center-neighbor annulus search instead of full re-scans.
    Exponion,
}

impl Variant {
    /// All variants evaluated in the paper's experiments (Table 3 order).
    pub const PAPER_SET: [Variant; 5] = [
        Variant::Standard,
        Variant::Elkan,
        Variant::SimplifiedElkan,
        Variant::Hamerly,
        Variant::SimplifiedHamerly,
    ];

    /// All implemented variants, including extensions.
    pub const ALL: [Variant; 7] = [
        Variant::Standard,
        Variant::Elkan,
        Variant::SimplifiedElkan,
        Variant::Hamerly,
        Variant::SimplifiedHamerly,
        Variant::Yinyang,
        Variant::Exponion,
    ];

    /// Display name matching the paper's tables.
    pub fn name(&self) -> &'static str {
        match self {
            Variant::Standard => "Standard",
            Variant::Elkan => "Elkan",
            Variant::SimplifiedElkan => "Simp.Elkan",
            Variant::Hamerly => "Hamerly",
            Variant::SimplifiedHamerly => "Simp.Hamerly",
            Variant::Yinyang => "Yinyang",
            Variant::Exponion => "Exponion",
        }
    }
}

impl std::str::FromStr for Variant {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().replace(['_', '.'], "-").as_str() {
            "standard" | "lloyd" => Ok(Variant::Standard),
            "elkan" => Ok(Variant::Elkan),
            "simplified-elkan" | "simp-elkan" | "selkan" => Ok(Variant::SimplifiedElkan),
            "hamerly" => Ok(Variant::Hamerly),
            "simplified-hamerly" | "simp-hamerly" | "shamerly" => Ok(Variant::SimplifiedHamerly),
            "yinyang" | "yin-yang" => Ok(Variant::Yinyang),
            "exponion" => Ok(Variant::Exponion),
            other => Err(format!("unknown variant: {other}")),
        }
    }
}

/// Configuration for one clustering run.
#[derive(Debug, Clone)]
pub struct KMeansConfig {
    /// Number of clusters.
    pub k: usize,
    /// Algorithm variant.
    pub variant: Variant,
    /// Seeding method.
    pub init: InitMethod,
    /// Maximum number of assignment iterations (safety cap; the paper runs
    /// to convergence, which all experiments here reach well before this).
    pub max_iter: usize,
    /// RNG seed for the seeding method.
    pub seed: u64,
    /// Number of center groups for [`Variant::Yinyang`]; defaults to
    /// `max(1, k/10)` as in Ding et al. (2015) when `None`.
    pub yinyang_groups: Option<usize>,
    /// Standard variant only: use the transposed-centers SIMD fast path
    /// for the all-k similarity pass (§Perf). `true` is fastest; `false`
    /// computes per-center gather dots — the **same per-similarity
    /// machinery the pruned variants use**, which is what the paper's
    /// Table 3/Fig. 1–2 comparisons assume (c.f. Kriegel et al., "are we
    /// comparing algorithms or implementations?"). The experiment drivers
    /// report both.
    pub fast_standard: bool,
    /// Use the guarded min-p single-bound update
    /// ([`crate::bounds::hamerly_bound::update_min_p_guarded`]) instead of
    /// the paper's Eq. 9 in the Hamerly and Yinyang variants. Exact either
    /// way; the guarded rule is provably the tightest single bound (an
    /// improvement over the paper — see `bench_bounds` for the ablation).
    pub tight_hamerly_bound: bool,
}

impl KMeansConfig {
    /// Config with defaults: Standard variant, uniform init, 200 iterations.
    pub fn new(k: usize) -> Self {
        Self {
            k,
            variant: Variant::Standard,
            init: InitMethod::Uniform,
            max_iter: 200,
            seed: 0,
            yinyang_groups: None,
            fast_standard: true,
            tight_hamerly_bound: false,
        }
    }

    /// Select the Standard variant's similarity path (see
    /// [`KMeansConfig::fast_standard`]).
    pub fn fast_standard(mut self, on: bool) -> Self {
        self.fast_standard = on;
        self
    }

    /// Enable the guarded min-p Hamerly bound (beyond-paper improvement).
    pub fn tight_bound(mut self, on: bool) -> Self {
        self.tight_hamerly_bound = on;
        self
    }

    /// Set the variant.
    pub fn variant(mut self, v: Variant) -> Self {
        self.variant = v;
        self
    }

    /// Set the seeding method.
    pub fn init(mut self, i: InitMethod) -> Self {
        self.init = i;
        self
    }

    /// Set the RNG seed.
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    /// Set the iteration cap.
    pub fn max_iter(mut self, m: usize) -> Self {
        self.max_iter = m;
        self
    }
}

/// The outcome of a clustering run.
#[derive(Debug, Clone)]
pub struct KMeansResult {
    /// Cluster assignment per row of the input.
    pub assignments: Vec<u32>,
    /// Final unit-normalized centers (k × d).
    pub centers: DenseMatrix,
    /// The spherical k-means objective `Σᵢ (1 − ⟨xᵢ, c(a(i))⟩)` —
    /// equal to half the within-cluster sum of squared Euclidean deviations
    /// on unit vectors; lower is better (Table 2 reports relative changes
    /// of this quantity).
    pub objective: f64,
    /// Mean cosine similarity of points to their centers (higher is better).
    pub mean_similarity: f64,
    /// Number of assignment iterations performed (excluding the initial
    /// full assignment pass).
    pub iterations: usize,
    /// True if the run converged (no reassignments) before `max_iter`.
    pub converged: bool,
    /// Per-iteration instrumentation.
    pub stats: RunStats,
}

/// Cluster `data` (rows must be unit-normalized — see
/// [`CsrMatrix::normalize_rows`]) according to `cfg`.
pub fn run(data: &CsrMatrix, cfg: &KMeansConfig) -> KMeansResult {
    let init = crate::init::seed_centers(data, cfg.k, &cfg.init, cfg.seed);
    run_with_centers(data, init.centers, cfg)
}

/// Cluster `data` from a seeding outcome, consuming the point-to-seed
/// similarity matrix (if the seeding collected one — see
/// [`crate::init::seed_centers_with_bounds`]) to **pre-initialize the
/// bounds** and skip the initial `O(N·k)` assignment pass entirely: the
/// paper's §7 synergy. A conservative margin (±1e-5) is applied to the
/// collected f32 similarities so they remain valid f64 bounds.
pub fn run_seeded(
    data: &CsrMatrix,
    init: crate::init::InitOutcome,
    cfg: &KMeansConfig,
) -> KMeansResult {
    assert_eq!(init.centers.rows(), cfg.k, "initial centers vs k");
    if let Some(m) = &init.sim_matrix {
        assert_eq!(m.len(), data.rows() * cfg.k, "sim matrix shape");
    }
    let mut ctx = Ctx::new(data, init.centers);
    ctx.preinit = init.sim_matrix;
    let converged = dispatch(&mut ctx, cfg);
    ctx.into_result(converged)
}

/// Cluster `data` starting from explicit initial centers (rows will be
/// normalized). This is the entry point the exactness tests and the
/// experiment drivers use so every variant sees identical initial centers.
pub fn run_with_centers(
    data: &CsrMatrix,
    initial_centers: DenseMatrix,
    cfg: &KMeansConfig,
) -> KMeansResult {
    assert_eq!(initial_centers.rows(), cfg.k, "initial centers vs k");
    assert_eq!(initial_centers.cols(), data.cols(), "center dimensionality");
    assert!(cfg.k >= 1, "need at least one cluster");
    let mut ctx = Ctx::new(data, initial_centers);
    let converged = dispatch(&mut ctx, cfg);
    ctx.into_result(converged)
}

fn dispatch(ctx: &mut Ctx<'_>, cfg: &KMeansConfig) -> bool {
    match cfg.variant {
        Variant::Standard => standard::run(ctx, cfg),
        Variant::Elkan => elkan::run(ctx, cfg),
        Variant::SimplifiedElkan => simplified_elkan::run(ctx, cfg),
        Variant::Hamerly => hamerly::run(ctx, cfg),
        Variant::SimplifiedHamerly => simplified_hamerly::run(ctx, cfg),
        Variant::Yinyang => yinyang::run(ctx, cfg),
        Variant::Exponion => exponion::run(ctx, cfg),
    }
}

/// Safety margin applied to f32 similarities collected during seeding so
/// they remain valid f64 bounds (f32 rounding + center renormalization).
const PREINIT_MARGIN: f64 = 1e-5;

/// `(argmax, max, second_max)` of a similarity row.
#[inline]
pub(crate) fn top2(sims: &[f64]) -> (usize, f64, f64) {
    let mut best = f64::MIN;
    let mut second = f64::MIN;
    let mut best_j = 0usize;
    for (j, &s) in sims.iter().enumerate() {
        if s > best {
            second = best;
            best = s;
            best_j = j;
        } else if s > second {
            second = s;
        }
    }
    (best_j, best, second)
}

/// Shared mutable state threaded through every algorithm implementation.
pub(crate) struct Ctx<'a> {
    pub data: &'a CsrMatrix,
    pub k: usize,
    pub assign: Vec<u32>,
    pub centers: Centers,
    pub stats: RunStats,
    /// Row-major N×k point-to-seed similarities from the seeding method
    /// (§7 synergy); consumed by [`Ctx::initial_assignment`].
    pub preinit: Option<Vec<f32>>,
}

impl<'a> Ctx<'a> {
    fn new(data: &'a CsrMatrix, initial_centers: DenseMatrix) -> Self {
        let k = initial_centers.rows();
        Self {
            data,
            k,
            assign: vec![0; data.rows()],
            centers: Centers::from_initial(initial_centers),
            stats: RunStats::default(),
            preinit: None,
        }
    }

    /// Compute similarities of row `i` to **all** centers into `scratch`
    /// (length k) via the transposed-centers fast path; returns
    /// `(argmax, best, second_best)`. Charges `k` similarity computations.
    #[inline]
    pub fn similarities_full(
        &self,
        i: usize,
        iter: &mut IterStats,
        scratch: &mut [f64],
    ) -> (usize, f64, f64) {
        let row = self.data.row(i);
        self.centers.sims_all(row, scratch);
        iter.sims_point_center += self.k as u64;
        top2(scratch)
    }

    /// Like [`Ctx::similarities_full`] but with per-center gather dots —
    /// the paper-faithful cost model (identical per-similarity work to the
    /// pruned variants' selective computations).
    #[inline]
    pub fn similarities_full_gather(
        &self,
        i: usize,
        iter: &mut IterStats,
        scratch: &mut [f64],
    ) -> (usize, f64, f64) {
        let row = self.data.row(i);
        for (j, o) in scratch.iter_mut().enumerate() {
            *o = row.dot_dense(self.centers.center(j));
        }
        iter.sims_point_center += self.k as u64;
        top2(scratch)
    }

    /// One point×center similarity, charged to `iter`.
    #[inline]
    pub fn similarity(&self, i: usize, j: usize, iter: &mut IterStats) -> f64 {
        iter.sims_point_center += 1;
        self.data.row(i).dot_dense(self.centers.center(j))
    }

    /// The initial full assignment pass shared by all variants: assigns
    /// every point to its most similar initial center, records an
    /// iteration-0 stats entry, and rebuilds the center sums.
    /// `on_point(i, best_j, best, second, sims_row)` lets each variant
    /// capture whatever bound state it needs.
    pub fn initial_assignment<F>(&mut self, want_sims_row: bool, mut on_point: F)
    where
        F: FnMut(usize, usize, f64, f64, &[f64]),
    {
        let sw = Stopwatch::start();
        let mut iter = IterStats::default();
        let mut sims_row = vec![0.0f64; self.k];
        if let Some(pre) = self.preinit.take() {
            // §7 synergy: bounds come from the seeding pass for free.
            // Margins keep the f32 values valid as f64 bounds; l gets a
            // downward margin, u values an upward one.
            for i in 0..self.data.rows() {
                let row = &pre[i * self.k..(i + 1) * self.k];
                let mut best = f64::MIN;
                let mut second = f64::MIN;
                let mut bj = 0usize;
                for (j, &s) in row.iter().enumerate() {
                    let s = s as f64;
                    if s > best {
                        second = best;
                        best = s;
                        bj = j;
                    } else if s > second {
                        second = s;
                    }
                }
                if want_sims_row {
                    for (o, &s) in sims_row.iter_mut().zip(row.iter()) {
                        *o = s as f64 + PREINIT_MARGIN;
                    }
                }
                self.assign[i] = bj as u32;
                on_point(
                    i,
                    bj,
                    best - PREINIT_MARGIN,
                    second + PREINIT_MARGIN,
                    &sims_row,
                );
            }
        } else {
            for i in 0..self.data.rows() {
                let (bj, b, s) = self.similarities_full(i, &mut iter, &mut sims_row);
                self.assign[i] = bj as u32;
                on_point(i, bj, b, s, &sims_row);
            }
        }
        let _ = want_sims_row;
        iter.reassignments = self.data.rows() as u64;
        // Build sums for the initial assignment and move centers once.
        self.centers.rebuild(self.data, &self.assign);
        iter.sims_center_center += self.centers.update();
        iter.wall_ms = sw.ms();
        self.stats.iters.push(iter);
    }

    /// Finalize: compute the objective and assemble the result.
    fn into_result(self, converged: bool) -> KMeansResult {
        let mut obj = 0.0f64;
        for i in 0..self.data.rows() {
            let s = self
                .data
                .row(i)
                .dot_dense(self.centers.center(self.assign[i] as usize));
            obj += 1.0 - s;
        }
        let n = self.data.rows().max(1) as f64;
        let iterations = self.stats.iters.len().saturating_sub(1);
        KMeansResult {
            mean_similarity: 1.0 - obj / n,
            objective: obj,
            assignments: self.assign,
            centers: self.centers.centers().clone(),
            iterations,
            converged,
            stats: self.stats,
        }
    }
}

/// Convenience: cluster a [`Dataset`] (which carries its matrix plus
/// metadata) and return the result.
pub fn run_dataset(ds: &Dataset, cfg: &KMeansConfig) -> KMeansResult {
    run(&ds.matrix, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_parsing_and_names() {
        assert_eq!("elkan".parse::<Variant>().unwrap(), Variant::Elkan);
        assert_eq!(
            "Simp_Elkan".parse::<Variant>().unwrap(),
            Variant::SimplifiedElkan
        );
        assert_eq!(
            "simplified-hamerly".parse::<Variant>().unwrap(),
            Variant::SimplifiedHamerly
        );
        assert_eq!("YinYang".parse::<Variant>().unwrap(), Variant::Yinyang);
        assert!("nope".parse::<Variant>().is_err());
        for v in Variant::ALL {
            assert!(!v.name().is_empty());
        }
    }

    #[test]
    fn config_builder() {
        let cfg = KMeansConfig::new(7)
            .variant(Variant::Hamerly)
            .seed(9)
            .max_iter(50);
        assert_eq!(cfg.k, 7);
        assert_eq!(cfg.variant, Variant::Hamerly);
        assert_eq!(cfg.seed, 9);
        assert_eq!(cfg.max_iter, 50);
    }
}
