//! # spherical-kmeans
//!
//! A production-quality reproduction of **"Accelerating Spherical k-Means"**
//! (Schubert, Lang, Feher; SISAP 2021, DOI 10.1007/978-3-030-89657-7_17).
//!
//! Spherical k-means clusters unit-normalized vectors by maximizing cosine
//! similarity. This crate implements the paper's contribution — adapting the
//! Elkan and Hamerly acceleration families to work *directly on cosine
//! similarities* via the cosine triangle inequality of Schubert (2021) —
//! plus every substrate it needs: sparse linear algebra, TF-IDF text
//! pipelines, synthetic corpus generators, seeding algorithms
//! (uniform, k-means++, AFK-MC²), cluster-quality metrics, a PJRT runtime
//! that executes AOT-compiled JAX/Pallas dense kernels, an experiment
//! coordinator that regenerates every table and figure of the paper, and a
//! train → persist → serve pipeline: bit-exact model persistence
//! ([`model`]) plus a high-throughput nearest-center query engine with a
//! MaxScore-pruned inverted-file traversal ([`serve`]).
//!
//! ## Layers
//!
//! * **L3 (this crate)** — the coordinator: sparse data structures, the five
//!   (plus extensions) k-means variants with cosine-bound pruning, seeding,
//!   experiment drivers, CLI. The assignment hot loop of every variant runs
//!   on the sharded parallel executor ([`runtime::parallel`]) with a
//!   bit-for-bit thread-count-invariance guarantee (see [`kmeans`]).
//! * **L2/L1 (python/, build time only)** — a JAX assignment-step graph
//!   calling a Pallas tiled similarity kernel, AOT-lowered to HLO text in
//!   `artifacts/`, loaded at runtime by [`runtime`] via the PJRT C API
//!   (behind the off-by-default `pjrt` cargo feature).
//!
//! ## Quickstart
//!
//! The front door is the [`SphericalKMeans`] estimator: one builder for
//! every engine (the seven exact accelerated variants and the mini-batch
//! optimizer), a fallible [`SphericalKMeans::fit`], and a [`FittedModel`]
//! that persists (`.spkm`), serves ([`FittedModel::query_engine`]), and
//! resumes ([`SphericalKMeans::warm_start`]).
//!
//! Corpora larger than memory train **out-of-core**: stream them into a
//! chunked on-disk shard store ([`sparse::ShardStore`], built by
//! [`data::convert`]) and fit through [`SphericalKMeans::fit_source`] —
//! bit-identical to the in-memory fit of the same rows, for every
//! thread count and chunk size. Models load back in a low-memory
//! streaming mode ([`model::Model::load_low_mem`]) for serving.
//!
//! ```no_run
//! use sphkm::data::synth::SynthConfig;
//! use sphkm::{Engine, ExactParams, SphericalKMeans};
//! use sphkm::kmeans::Variant;
//!
//! let ds = SynthConfig::small_demo().generate(42);
//! let fitted = SphericalKMeans::new(8)
//!     .engine(Engine::Exact(ExactParams {
//!         variant: Variant::SimplifiedElkan,
//!         ..Default::default()
//!     }))
//!     .seed(1)
//!     .fit(&ds.matrix)
//!     .expect("valid configuration");
//! println!("objective = {}", fitted.objective());
//! fitted.save(std::path::Path::new("model.spkm")).unwrap();
//! ```
#![deny(missing_docs)]
#![forbid(unsafe_code)]

// The workspace `[lints]` table keeps `clippy::cast_possible_truncation`
// and `clippy::float_cmp` live crate-wide (they guard the `model/` codec
// and every future ingestion path); the numeric kernel subtrees below
// carry documented allows instead: their index casts are bounded by the
// matrix shapes they were derived from, and exact float comparison
// against 0.0 / stored sentinels is the sparse-representation contract
// (a coordinate is present iff its bit pattern is non-zero).
#[allow(clippy::cast_possible_truncation, clippy::float_cmp)]
pub mod audit;
#[allow(clippy::cast_possible_truncation, clippy::float_cmp)]
pub mod bounds;
#[allow(clippy::cast_possible_truncation, clippy::float_cmp)]
pub mod coordinator;
#[allow(clippy::cast_possible_truncation, clippy::float_cmp)]
pub mod data;
#[allow(clippy::cast_possible_truncation, clippy::float_cmp)]
pub mod init;
#[allow(clippy::cast_possible_truncation, clippy::float_cmp)]
pub mod kmeans;
#[allow(clippy::cast_possible_truncation, clippy::float_cmp)]
pub mod metrics;
pub mod model;
#[allow(clippy::cast_possible_truncation, clippy::float_cmp)]
pub mod obs;
#[allow(clippy::cast_possible_truncation, clippy::float_cmp)]
pub mod runtime;
#[allow(clippy::cast_possible_truncation, clippy::float_cmp)]
pub mod serve;
#[allow(clippy::cast_possible_truncation, clippy::float_cmp)]
pub mod sparse;
#[allow(clippy::cast_possible_truncation, clippy::float_cmp)]
pub mod util;

pub use audit::AuditViolation;
pub use kmeans::{
    Engine, ExactParams, FitError, FittedModel, IterSnapshot, MiniBatchParams, Observer,
    SphericalKMeans,
};
