//! The crate's front door: the [`SphericalKMeans`] estimator and the
//! [`FittedModel`] it produces.
//!
//! The paper's central observation is that the exact accelerated variants
//! and their approximate mini-batch cousins are *interchangeable engines
//! over one similarity substrate* — so the API says exactly that: **one
//! estimator** owning the shared knobs (k, seed, init, threads, kernel,
//! iteration budget) plus an [`Engine`] selector whose typed payloads
//! ([`ExactParams`], [`MiniBatchParams`]) make invalid combinations —
//! `truncate` on Elkan, `tight_bound` on mini-batch — unrepresentable
//! instead of silently ignored.
//!
//! ```no_run
//! use sphkm::data::synth::SynthConfig;
//! use sphkm::kmeans::{Engine, ExactParams, SphericalKMeans, Variant};
//!
//! let ds = SynthConfig::small_demo().generate(42);
//! let fitted = SphericalKMeans::new(8)
//!     .engine(Engine::Exact(ExactParams {
//!         variant: Variant::SimplifiedElkan,
//!         ..Default::default()
//!     }))
//!     .seed(1)
//!     .fit(&ds.matrix)
//!     .expect("valid configuration");
//! println!("objective = {}", fitted.objective());
//! ```
//!
//! [`SphericalKMeans::fit`] is **fallible**: misconfigurations (k = 0,
//! k > n, `batch_size` = 0, negative `tol`, warm-start dimension
//! mismatches) return a typed [`FitError`] up front instead of panicking
//! deep inside an engine.
//!
//! # Train → persist → serve → resume
//!
//! A [`FittedModel`] unifies the training result and the persistence
//! artifact: it carries the centers, assignments, [`RunStats`], and
//! training metadata; [`FittedModel::save`] / [`FittedModel::load`]
//! round-trip it through the `.spkm` format **including the training
//! state** (the f64 center-sum accumulators, counts, and assignments), so
//! [`SphericalKMeans::warm_start`] can *resume* an interrupted run — the
//! resumed trajectory is bit-for-bit the one the uninterrupted run would
//! have taken, because the incremental-update accumulators are restored
//! exactly (asserted by the `warm_start` integration suite).
//! [`FittedModel::query_engine`] bridges straight into the serving layer.
//!
//! # Observers
//!
//! [`SphericalKMeans::fit_observed`] threads an [`Observer`] through the
//! exact iteration loop and the mini-batch epochs: after every iteration
//! it receives an [`IterSnapshot`] and can return
//! [`ControlFlow::Break`](std::ops::ControlFlow::Break) to stop training
//! within one iteration — user-side progress reporting and early stopping
//! without polling.

use std::ops::ControlFlow;
use std::path::Path;

use super::kernel::DataShape;
use super::{
    fit_exact, ExactStart, IterStats, KMeansConfig, KMeansResult, Kernel, KernelChoice, RunStats,
    Variant,
};
use crate::audit::AuditViolation;
use crate::data::Dataset;
use crate::init::InitMethod;
use crate::model::{Model, ModelError, TrainingMeta};
use crate::serve::{QueryEngine, ServeConfig, ServeMode};
use crate::sparse::{CsrMatrix, DenseMatrix, RowSource};

/// Engine name recorded as variant provenance for mini-batch runs (which
/// have no [`Variant`]).
pub(crate) const MINIBATCH_ENGINE: &str = "minibatch";

/// Parameters of the **exact** full-batch engines — the seven accelerated
/// variants sharing the exactness contract of [`crate::kmeans`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExactParams {
    /// Which accelerated variant runs the iteration loop.
    pub variant: Variant,
    /// Use the guarded min-p single-bound update instead of the paper's
    /// Eq. 9 in the Hamerly-bound family (beyond-paper improvement; exact
    /// either way).
    pub tight_bound: bool,
    /// Number of center groups for [`Variant::Yinyang`]; `None` defaults
    /// to `max(1, k/10)` as in Ding et al. (2015).
    pub yinyang_groups: Option<usize>,
    /// §7 synergy: seed with [`crate::init::seed_centers_with_bounds`] and
    /// pre-initialize the bound structures from the similarities the
    /// seeding already computed, skipping the initial `O(N·k)` assignment
    /// pass (only k-means++ collects them; other inits run plainly).
    pub preinit: bool,
}

impl Default for ExactParams {
    /// Simplified Hamerly — the paper's "reasonable default choice"
    /// across data-set shapes (§6) — with the paper-faithful Eq. 9 bound.
    fn default() -> Self {
        Self {
            variant: Variant::SimplifiedHamerly,
            tight_bound: false,
            yinyang_groups: None,
            preinit: false,
        }
    }
}

/// Parameters of the approximate **mini-batch** engine
/// ([`crate::kmeans::minibatch`]) for corpora too large for full passes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MiniBatchParams {
    /// Points sampled per batch (clamped to the row count at run time).
    pub batch_size: usize,
    /// Maximum epochs; each draws `ceil(n / batch_size)` batches.
    pub epochs: usize,
    /// Convergence tolerance on the largest per-epoch center movement in
    /// cosine distance (`1 − ⟨c, c'⟩`); must be ≥ 0.
    pub tol: f64,
    /// Optional Knittel-style sparse centroids: keep only the `m`
    /// largest-magnitude coordinates per center, renormalized.
    pub truncate: Option<usize>,
}

impl Default for MiniBatchParams {
    fn default() -> Self {
        Self {
            batch_size: 1024,
            epochs: 10,
            tol: 1e-4,
            truncate: None,
        }
    }
}

/// Which training engine a [`SphericalKMeans`] runs: the exact
/// full-batch family or the approximate mini-batch optimizer. The typed
/// payloads keep each engine's knobs where they apply — a `truncate` on
/// Elkan or a `tight_bound` on mini-batch cannot even be expressed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Engine {
    /// One of the seven exact accelerated variants.
    Exact(ExactParams),
    /// The deterministic sharded mini-batch engine.
    MiniBatch(MiniBatchParams),
}

impl Default for Engine {
    fn default() -> Self {
        Engine::Exact(ExactParams::default())
    }
}

/// Why [`SphericalKMeans::fit`] refused to run. Every rejection happens
/// **before** any engine starts: a `FitError` never leaves partial state
/// behind.
#[derive(Debug, Clone, PartialEq, thiserror::Error)]
pub enum FitError {
    /// A parameter combination that cannot produce a meaningful run
    /// (k = 0, k > n, `batch_size` = 0, negative or non-finite `tol`, …).
    #[error("invalid configuration: {0}")]
    InvalidConfig(String),
    /// Warm-start centers live in a different vector space than the data.
    #[error("dimension mismatch: warm-start centers have {found} columns but the data has {expected}")]
    DimensionMismatch {
        /// Columns of the data matrix.
        expected: usize,
        /// Columns of the warm-start centers.
        found: usize,
    },
    /// The warm-start model's cluster count disagrees with the
    /// estimator's `k`.
    #[error("warm-start k mismatch: the model has {model_k} centers but the estimator wants {k}")]
    KMismatch {
        /// Clusters in the warm-start model.
        model_k: usize,
        /// Clusters the estimator was configured for.
        k: usize,
    },
    /// The bound-certification audit ([`crate::audit`], `audit` feature
    /// only) caught a pruning decision or data-structure invariant the
    /// exact similarity contradicts. The fit still ran to completion —
    /// results are computed identically with auditing on — but the
    /// exactness contract is broken and the result must not be trusted.
    /// The payload is the **first** violation recorded, with full
    /// point/center/iteration/bound context.
    #[error("bound-certification audit failed: {0}")]
    AuditViolation(AuditViolation),
}

/// What an [`Observer`] sees after each iteration (exact engines) or
/// epoch (mini-batch): enough to report progress and decide on early
/// stopping, cheap enough to hand out unconditionally.
#[derive(Debug, Clone, Copy)]
pub struct IterSnapshot<'a> {
    /// Index of the completed iteration within this `fit` call. Exact
    /// engines: `0` is the initial full assignment pass (or the bound
    /// re-initialization pass of a resumed run). Mini-batch: epochs count
    /// from 0 and the final full assignment pass comes last.
    pub iteration: usize,
    /// The iteration's instrumentation counters.
    pub stats: &'a IterStats,
    /// True when this iteration concluded convergence (no reassignments /
    /// center movement under `tol`); the run stops after delivering it.
    pub converged: bool,
    /// Mini-batch epochs only: the largest per-center movement of the
    /// epoch in cosine distance (the quantity `tol` tests). `None` for
    /// exact iterations and the final mini-batch assignment pass.
    pub center_shift: Option<f64>,
    /// All audit violations recorded **so far** in this fit (the
    /// certification trail of [`crate::audit`]). Always empty without the
    /// `audit` feature; under it, an observer can stop the run on the
    /// first violation instead of waiting for the fit to finish and
    /// return [`FitError::AuditViolation`].
    pub audit_violations: &'a [AuditViolation],
    /// Wall-clock milliseconds since the engine started this fit,
    /// measured when the snapshot is delivered. Always populated (no
    /// feature gate) — one clock read per iteration barrier.
    pub elapsed_ms: f64,
    /// Wall-clock milliseconds of this iteration/epoch alone — a copy of
    /// [`IterStats::wall_ms`] for convenience.
    pub iter_ms: f64,
}

/// Per-iteration hook threaded through every engine's loop by
/// [`SphericalKMeans::fit_observed`]. Return
/// [`ControlFlow::Break`](std::ops::ControlFlow::Break) to stop training
/// after the current iteration — the fit still returns a complete
/// [`FittedModel`] (marked unconverged) that can be saved and resumed.
///
/// Any `FnMut(&IterSnapshot) -> ControlFlow<()>` closure is an observer.
pub trait Observer {
    /// Called once per completed iteration/epoch, in order.
    fn on_iteration(&mut self, snapshot: &IterSnapshot<'_>) -> ControlFlow<()>;
}

impl<F> Observer for F
where
    F: FnMut(&IterSnapshot<'_>) -> ControlFlow<()>,
{
    fn on_iteration(&mut self, snapshot: &IterSnapshot<'_>) -> ControlFlow<()> {
        self(snapshot)
    }
}

/// Resumable training state: the exact accumulators a run needs to
/// continue as if it had never stopped. The exact engines maintain center
/// sums *incrementally* (the paper's optimization iii), so the f32
/// centers alone cannot reproduce the trajectory — the f64 sums, counts,
/// and current assignments are what make a resumed run bit-identical to
/// an uninterrupted one. Persisted by [`FittedModel::save`] as the
/// version-2 `.spkm` training-state section.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainState {
    /// Cumulative optimization steps across all fits of this lineage:
    /// assignment iterations (exact) or epochs (mini-batch — what the
    /// resumed batch sampler fast-forwards past).
    pub steps_done: u64,
    /// Whether the last fit converged.
    pub converged: bool,
    /// Assignment per training row at capture time.
    pub assignments: Vec<u32>,
    /// Per-cluster point counts (exact: members; mini-batch: folds).
    pub counts: Vec<u64>,
    /// Unnormalized per-cluster coordinate sums (k×d, row-major f64) —
    /// the incremental-update accumulators.
    pub sums: Vec<f64>,
    /// The mini-batch hyperparameters the state was trained under
    /// (`None` for exact engines). A bit-identical continuation must use
    /// the same `batch_size` (the sampler fast-forward depends on it)
    /// and `truncate` (the sparse-centroid invariant); persisting them
    /// lets `cluster --resume` default to the original schedule instead
    /// of whatever the CLI defaults happen to be.
    pub minibatch: Option<MiniBatchParams>,
}

/// How a [`SphericalKMeans`] starts: from scratch, from explicit centers,
/// or from a prior fitted model (with resumable state when available).
#[derive(Debug, Clone)]
enum Start {
    /// Seed with the configured [`InitMethod`].
    Fresh,
    /// Explicit initial centers (rows are normalized) — a fresh run that
    /// skips seeding; what the exactness tests and experiment drivers use
    /// so every variant sees identical initial centers.
    Centers(DenseMatrix),
    /// Continue from a fitted model: its centers, plus its training state
    /// when the engines match (bit-identical resume).
    Warm {
        centers: DenseMatrix,
        engine: String,
        state: Option<TrainState>,
    },
}

/// The estimator: shared knobs + a typed [`Engine`]. Build with the
/// consuming `#[must_use]` setters, then call [`SphericalKMeans::fit`].
/// See the [module docs](self) for the design.
#[derive(Debug, Clone)]
pub struct SphericalKMeans {
    k: usize,
    engine: Engine,
    init: InitMethod,
    max_iter: usize,
    seed: u64,
    threads: usize,
    kernel: KernelChoice,
    start: Start,
}

impl SphericalKMeans {
    /// Estimator for `k` clusters with defaults: the exact Simplified
    /// Hamerly engine, uniform init, seed 0, 200-iteration cap, serial
    /// execution, auto kernel.
    pub fn new(k: usize) -> Self {
        Self {
            k,
            engine: Engine::default(),
            init: InitMethod::Uniform,
            max_iter: 200,
            seed: 0,
            threads: 1,
            kernel: KernelChoice::Auto,
            start: Start::Fresh,
        }
    }

    /// Select the training engine (see [`Engine`]).
    #[must_use]
    pub fn engine(mut self, engine: Engine) -> Self {
        self.engine = engine;
        self
    }

    /// Shorthand: the exact engine running `variant` with default
    /// [`ExactParams`] otherwise.
    #[must_use]
    pub fn variant(mut self, variant: Variant) -> Self {
        self.engine = Engine::Exact(ExactParams {
            variant,
            ..match self.engine {
                Engine::Exact(p) => p,
                Engine::MiniBatch(_) => ExactParams::default(),
            }
        });
        self
    }

    /// Set the seeding method.
    #[must_use]
    pub fn init(mut self, init: InitMethod) -> Self {
        self.init = init;
        self
    }

    /// Set the RNG seed (seeding and mini-batch sampling substreams).
    #[must_use]
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Iteration budget **per fit call**: assignment iterations for the
    /// exact engines. (The mini-batch engine's budget is
    /// [`MiniBatchParams::epochs`].) A resumed fit gets a fresh budget.
    #[must_use]
    pub fn max_iter(mut self, max_iter: usize) -> Self {
        self.max_iter = max_iter;
        self
    }

    /// Worker threads for the sharded phases: `0` = all cores, `1`
    /// (default) = serial. Results are bit-identical for every setting.
    #[must_use]
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Select the similarity-kernel backend
    /// (see [`crate::kmeans::kernel`]).
    #[must_use]
    pub fn kernel(mut self, kernel: KernelChoice) -> Self {
        self.kernel = kernel;
        self
    }

    /// Start from explicit initial centers instead of seeding (rows are
    /// normalized). Used wherever several runs must share identical
    /// starting points — the exactness tests, the experiment drivers.
    #[must_use]
    pub fn warm_start_centers(mut self, centers: DenseMatrix) -> Self {
        self.start = Start::Centers(centers);
        self
    }

    /// Continue from a prior [`FittedModel`] — persisted or in-memory.
    /// When the model carries training state for the *same engine kind*
    /// (exact ↔ exact, mini-batch ↔ mini-batch), the data has the same
    /// row count, and — for mini-batch — the configured `batch_size` and
    /// `truncate` match the persisted schedule, the fit **resumes**:
    /// accumulators are restored and the continued trajectory is
    /// bit-identical to an uninterrupted run. Otherwise the model's
    /// centers serve as plain initial centers (a legitimate transfer
    /// workflow onto new data or a new schedule).
    #[must_use]
    pub fn warm_start(mut self, model: &FittedModel) -> Self {
        self.start = Start::Warm {
            centers: model.centers().clone(),
            engine: model.meta().variant.clone(),
            state: model.state.clone(),
        };
        self
    }

    /// Validate the configuration against the data shape. Everything
    /// [`FitError`] documents is caught here, before any engine starts.
    fn validate(&self, data: RowSource<'_>) -> Result<(), FitError> {
        let n = data.rows();
        if self.k == 0 {
            return Err(FitError::InvalidConfig("k must be at least 1".into()));
        }
        if self.k > n {
            return Err(FitError::InvalidConfig(format!(
                "k = {} exceeds the {n} data rows",
                self.k
            )));
        }
        match &self.engine {
            Engine::Exact(p) => {
                if p.yinyang_groups == Some(0) {
                    return Err(FitError::InvalidConfig(
                        "yinyang_groups must be at least 1 when set".into(),
                    ));
                }
            }
            Engine::MiniBatch(p) => {
                if p.batch_size == 0 {
                    return Err(FitError::InvalidConfig("batch_size must be at least 1".into()));
                }
                if !p.tol.is_finite() || p.tol < 0.0 {
                    return Err(FitError::InvalidConfig(format!(
                        "tol must be finite and non-negative, got {}",
                        p.tol
                    )));
                }
                if p.truncate == Some(0) {
                    return Err(FitError::InvalidConfig(
                        "truncate must keep at least 1 coordinate (use None for dense centers)"
                            .into(),
                    ));
                }
            }
        }
        let centers = match &self.start {
            Start::Fresh => None,
            Start::Centers(c) => Some(c),
            Start::Warm { centers, .. } => Some(centers),
        };
        if let Some(c) = centers {
            if c.cols() != data.cols() {
                return Err(FitError::DimensionMismatch {
                    expected: data.cols(),
                    found: c.cols(),
                });
            }
            if c.rows() != self.k {
                return Err(FitError::KMismatch { model_k: c.rows(), k: self.k });
            }
        }
        Ok(())
    }

    /// The internal [`KMeansConfig`] every engine still consumes — the
    /// single place the typed estimator surface maps onto it.
    fn config(&self) -> KMeansConfig {
        let mut cfg = KMeansConfig::new(self.k)
            .init(self.init)
            .seed(self.seed)
            .max_iter(self.max_iter)
            .threads(self.threads)
            .kernel(self.kernel);
        match &self.engine {
            Engine::Exact(p) => {
                cfg = cfg.variant(p.variant).tight_bound(p.tight_bound);
                cfg.yinyang_groups = p.yinyang_groups;
            }
            Engine::MiniBatch(p) => {
                cfg = cfg
                    .batch_size(p.batch_size)
                    .epochs(p.epochs)
                    .tol(p.tol)
                    .truncate(p.truncate);
            }
        }
        cfg
    }

    /// Cluster `data` (rows must be unit-normalized — see
    /// [`CsrMatrix::normalize_rows`]). This is the **only** entry point
    /// to every engine: all seven exact variants and the mini-batch
    /// optimizer run behind it.
    pub fn fit(&self, data: &CsrMatrix) -> Result<FittedModel, FitError> {
        self.fit_inner(RowSource::Mem(data), None)
    }

    /// Like [`SphericalKMeans::fit`], with an [`Observer`] notified after
    /// every iteration/epoch (progress reporting, early stopping).
    pub fn fit_observed(
        &self,
        data: &CsrMatrix,
        observer: &mut dyn Observer,
    ) -> Result<FittedModel, FitError> {
        self.fit_inner(RowSource::Mem(data), Some(observer))
    }

    /// Cluster either row backend through the same validated path:
    /// [`RowSource::Mem`] behaves exactly like [`SphericalKMeans::fit`],
    /// and [`RowSource::Disk`] streams chunked shard reads (see
    /// [`crate::sparse::ShardStore`]) through every engine —
    /// **bit-identical** to the in-memory fit of the same rows, for every
    /// thread count and chunk size (the `out_of_core` suite asserts it).
    pub fn fit_source(&self, src: RowSource<'_>) -> Result<FittedModel, FitError> {
        self.fit_inner(src, None)
    }

    /// [`SphericalKMeans::fit_source`] with an [`Observer`] attached.
    pub fn fit_source_observed(
        &self,
        src: RowSource<'_>,
        observer: &mut dyn Observer,
    ) -> Result<FittedModel, FitError> {
        self.fit_inner(src, Some(observer))
    }

    fn fit_inner(
        &self,
        data: RowSource<'_>,
        obs: Option<&mut dyn Observer>,
    ) -> Result<FittedModel, FitError> {
        self.validate(data)?;
        let cfg = self.config();
        let is_minibatch = matches!(self.engine, Engine::MiniBatch(_));
        // Resolve the start into (initial centers, optional preinit
        // similarity matrix, optional resume state).
        let mut sim_matrix = None;
        let mut resume: Option<TrainState> = None;
        // Pre-loop spans: seeding wall-clock, and the shard-I/O delta the
        // fit accrues in the global registry (out-of-core runs under the
        // `trace` feature; both exactly zero without it).
        let seed_sp = crate::obs::span::span_start();
        let io_ms_before = crate::obs::metrics::global_shard_io_ms();
        let centers = match &self.start {
            Start::Fresh => match &self.engine {
                Engine::Exact(p) if p.preinit => {
                    let init = crate::init::seed_centers_with_bounds_source(
                        data, self.k, &self.init, self.seed,
                    );
                    sim_matrix = init.sim_matrix;
                    init.centers
                }
                _ => crate::init::seed_centers_source(data, self.k, &self.init, self.seed).centers,
            },
            Start::Centers(c) => c.clone(),
            Start::Warm { centers, engine, state } => {
                let engine_matches = (engine == MINIBATCH_ENGINE) == is_minibatch;
                if engine_matches {
                    // Resume only with state whose accumulators match this
                    // problem's shape exactly (rows, and k×d sums/counts —
                    // a hand-built model could carry anything), and — for
                    // mini-batch — whose persisted schedule agrees on the
                    // trajectory-defining knobs: the sampler fast-forward
                    // depends on `batch_size` and the sparse-centroid
                    // invariant on `truncate` (`epochs`/`tol` are stopping
                    // budgets and may differ). Everything else is a plain
                    // transfer warm start — engines never see state they
                    // cannot continue bit-identically.
                    // (`as_ref().filter(…).cloned()`: the k·d f64 sums are
                    // only copied when the state will actually be used.)
                    resume = state
                        .as_ref()
                        .filter(|s| {
                            let shape_ok = s.assignments.len() == data.rows()
                                && s.counts.len() == self.k
                                && s.sums.len() == self.k * data.cols();
                            let schedule_ok = match (&self.engine, s.minibatch) {
                                (Engine::MiniBatch(cur), Some(orig)) => {
                                    cur.batch_size == orig.batch_size
                                        && cur.truncate == orig.truncate
                                }
                                (Engine::MiniBatch(_), None) => false,
                                (Engine::Exact(_), _) => true,
                            };
                            shape_ok && schedule_ok
                        })
                        .cloned();
                }
                centers.clone()
            }
        };
        let prior_steps = resume.as_ref().map_or(0, |s| s.steps_done);
        // Seeding only happens on a fresh start; warm starts clone
        // existing centers, which is not seeding work.
        let seeding_ms = if matches!(self.start, Start::Fresh) {
            crate::obs::span::span_ms(seed_sp)
        } else {
            0.0
        };
        let (mut result, state, violations) = match &self.engine {
            Engine::Exact(_) => fit_exact(
                data,
                &cfg,
                ExactStart { centers, sim_matrix, resume, prior_steps, obs },
            ),
            Engine::MiniBatch(_) => {
                super::minibatch::fit_minibatch(data, &cfg, centers, resume, prior_steps, obs)
            }
        };
        // Under the `audit` feature a recorded certification failure makes
        // the whole fit an error: the engines computed the same result they
        // always would, but the exactness contract it rests on is broken.
        if let Some(v) = violations.into_iter().next() {
            return Err(FitError::AuditViolation(v));
        }
        if crate::obs::TRACE_ENABLED {
            result.stats.pre.add(crate::obs::Phase::Seeding, seeding_ms);
            let io_ms = crate::obs::metrics::global_shard_io_ms() - io_ms_before;
            if io_ms > 0.0 {
                result.stats.pre.add(crate::obs::Phase::ShardIo, io_ms);
            }
        }
        let meta = TrainingMeta {
            variant: if is_minibatch {
                MINIBATCH_ENGINE.to_string()
            } else {
                cfg.variant.name().to_string()
            },
            kernel: result.kernel.name().to_string(),
            iterations: state.steps_done,
            objective: result.objective,
            seed: self.seed,
        };
        Ok(FittedModel { result, meta, state: Some(state) })
    }

    /// Convenience: fit a [`Dataset`] (which carries its matrix plus
    /// metadata).
    pub fn fit_dataset(&self, ds: &Dataset) -> Result<FittedModel, FitError> {
        self.fit(&ds.matrix)
    }
}

/// A fitted spherical k-means model: the unified successor of the old
/// `KMeansResult` + `Model` pair. It carries the full training outcome
/// (centers, assignments, objective, [`RunStats`]), persists itself
/// bit-exactly — training state included, so a saved model can *resume*
/// — and opens directly into the serving layer. See the
/// [module docs](self).
#[derive(Debug, Clone)]
pub struct FittedModel {
    result: KMeansResult,
    meta: TrainingMeta,
    state: Option<TrainState>,
}

impl FittedModel {
    /// Number of clusters.
    #[inline]
    pub fn k(&self) -> usize {
        self.result.centers.rows()
    }

    /// Dimensionality (vocabulary size) the centers live in.
    #[inline]
    pub fn d(&self) -> usize {
        self.result.centers.cols()
    }

    /// The unit-normalized centers (k × d).
    #[inline]
    pub fn centers(&self) -> &DenseMatrix {
        &self.result.centers
    }

    /// Cluster assignment per training row. Empty for a model loaded
    /// from a file without training state.
    #[inline]
    pub fn assignments(&self) -> &[u32] {
        &self.result.assignments
    }

    /// The spherical k-means objective `Σᵢ (1 − ⟨xᵢ, c(a(i))⟩)` (lower is
    /// better).
    #[inline]
    pub fn objective(&self) -> f64 {
        self.result.objective
    }

    /// Mean cosine similarity of points to their centers (higher is
    /// better).
    #[inline]
    pub fn mean_similarity(&self) -> f64 {
        self.result.mean_similarity
    }

    /// Iterations (exact) or epochs (mini-batch) this fit performed.
    #[inline]
    pub fn iterations(&self) -> usize {
        self.result.iterations
    }

    /// True if the fit converged within its budget.
    #[inline]
    pub fn converged(&self) -> bool {
        self.result.converged
    }

    /// The similarity-kernel backend the run resolved and executed.
    #[inline]
    pub fn kernel(&self) -> Kernel {
        self.result.kernel
    }

    /// Per-iteration instrumentation of this fit. Empty for a model
    /// loaded from a file.
    #[inline]
    pub fn stats(&self) -> &RunStats {
        &self.result.stats
    }

    /// Training provenance (engine, kernel, cumulative steps, seed).
    #[inline]
    pub fn meta(&self) -> &TrainingMeta {
        &self.meta
    }

    /// The resumable training state, when this model carries one (fits
    /// always do; loads only from state-bearing files).
    #[inline]
    pub fn state(&self) -> Option<&TrainState> {
        self.state.as_ref()
    }

    /// The raw training result — the legacy `KMeansResult` view the
    /// deprecated `run*` shims return.
    #[inline]
    pub fn result(&self) -> &KMeansResult {
        &self.result
    }

    /// Unwrap into the legacy `KMeansResult`.
    pub fn into_result(self) -> KMeansResult {
        self.result
    }

    /// The persistence-layer [`Model`] view: centers + metadata +
    /// training state.
    pub fn to_model(&self) -> Model {
        Model::new(self.result.centers.clone(), self.meta.clone()).with_state(self.state.clone())
    }

    /// Serialize to `path` in the `.spkm` format, **training state
    /// included** (version-2 layout — see [`crate::model`]), so the file
    /// can be loaded and resumed via [`SphericalKMeans::warm_start`].
    pub fn save(&self, path: &Path) -> Result<(), ModelError> {
        self.to_model().save(path)
    }

    /// Load a model saved by [`FittedModel::save`] (or a legacy
    /// state-free [`Model::save`] file). Assignments and the resume
    /// state are restored when the file carries them; per-iteration
    /// [`RunStats`] are not persisted and come back empty.
    pub fn load(path: &Path) -> Result<Self, ModelError> {
        Ok(Self::from_model(Model::load(path)?))
    }

    /// Adopt a persistence-layer [`Model`] (e.g. one already loaded for
    /// serving) as a fitted model.
    pub fn from_model(model: Model) -> Self {
        let meta = model.meta().clone();
        let state = model.state().cloned();
        let centers = model.centers().clone();
        let n = state.as_ref().map_or(0, |s| s.assignments.len());
        // Reuse the shared kernel parser (aliases included); anything
        // unrecognized — or a hypothetical stored "auto" — reports the
        // zero-structure gather backend rather than guessing.
        let kernel = match meta.kernel.parse::<KernelChoice>() {
            Ok(KernelChoice::Dense) => Kernel::Dense,
            Ok(KernelChoice::Inverted) => Kernel::Inverted,
            Ok(KernelChoice::Pruned) => Kernel::Pruned,
            _ => Kernel::Gather,
        };
        let result = KMeansResult {
            assignments: state.as_ref().map(|s| s.assignments.clone()).unwrap_or_default(),
            mean_similarity: if n > 0 {
                1.0 - meta.objective / n as f64
            } else {
                0.0
            },
            objective: meta.objective,
            iterations: meta.iterations as usize,
            converged: state.as_ref().is_some_and(|s| s.converged),
            kernel,
            centers,
            stats: RunStats::default(),
        };
        Self { result, meta, state }
    }

    /// Open this model for serving: a [`QueryEngine`] answering top-p
    /// nearest-center queries against the frozen centers. `mode` picks
    /// the traversal ([`ServeMode::Auto`] resolves from the centers'
    /// density); batches shard across all cores.
    pub fn query_engine(&self, mode: ServeMode) -> QueryEngine {
        self.query_engine_with(mode, 0)
    }

    /// [`FittedModel::query_engine`] with an explicit worker-thread count
    /// (`0` = all cores, `1` = serial). The serving daemon uses this to
    /// keep every published epoch on the pool size the operator chose.
    pub fn query_engine_with(&self, mode: ServeMode, threads: usize) -> QueryEngine {
        // Serving needs no training state — hand over a stateless model.
        let model = Model::new(self.result.centers.clone(), self.meta.clone());
        QueryEngine::new(model, &ServeConfig { mode, threads })
    }

    /// The problem shape the serving Auto heuristic reads — exposed so
    /// callers can inspect what [`ServeMode::Auto`] would resolve to.
    pub fn serve_shape(&self) -> DataShape {
        let nnz = self
            .result
            .centers
            .data()
            .iter()
            .filter(|v| v.to_bits() != 0)
            .count();
        DataShape::of_centers(self.d(), self.k(), nnz)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthConfig;

    fn data() -> CsrMatrix {
        SynthConfig::small_demo().generate(3).matrix
    }

    #[test]
    fn rejects_invalid_configurations() {
        let m = data();
        let n = m.rows();
        // k = 0 and k > n are invalid for every engine.
        assert!(matches!(
            SphericalKMeans::new(0).fit(&m),
            Err(FitError::InvalidConfig(_))
        ));
        assert!(matches!(
            SphericalKMeans::new(n + 1).fit(&m),
            Err(FitError::InvalidConfig(_))
        ));
        // Mini-batch knobs.
        let mb = |p: MiniBatchParams| {
            SphericalKMeans::new(4)
                .engine(Engine::MiniBatch(p))
                .fit(&m)
        };
        assert!(matches!(
            mb(MiniBatchParams { batch_size: 0, ..Default::default() }),
            Err(FitError::InvalidConfig(_))
        ));
        assert!(matches!(
            mb(MiniBatchParams { tol: -1e-3, ..Default::default() }),
            Err(FitError::InvalidConfig(_))
        ));
        assert!(matches!(
            mb(MiniBatchParams { tol: f64::NAN, ..Default::default() }),
            Err(FitError::InvalidConfig(_))
        ));
        assert!(matches!(
            mb(MiniBatchParams { truncate: Some(0), ..Default::default() }),
            Err(FitError::InvalidConfig(_))
        ));
        // Exact knobs.
        assert!(matches!(
            SphericalKMeans::new(4)
                .engine(Engine::Exact(ExactParams {
                    yinyang_groups: Some(0),
                    ..Default::default()
                }))
                .fit(&m),
            Err(FitError::InvalidConfig(_))
        ));
        // Warm-start shape mismatches.
        let bad_d = DenseMatrix::zeros(4, m.cols() + 1);
        assert_eq!(
            SphericalKMeans::new(4).warm_start_centers(bad_d).fit(&m).unwrap_err(),
            FitError::DimensionMismatch { expected: m.cols(), found: m.cols() + 1 }
        );
        let bad_k = DenseMatrix::zeros(5, m.cols());
        assert_eq!(
            SphericalKMeans::new(4).warm_start_centers(bad_k).fit(&m).unwrap_err(),
            FitError::KMismatch { model_k: 5, k: 4 }
        );
    }

    #[test]
    fn fit_produces_consistent_model() {
        let m = data();
        let fitted = SphericalKMeans::new(6).seed(7).fit(&m).unwrap();
        assert_eq!(fitted.k(), 6);
        assert_eq!(fitted.d(), m.cols());
        assert_eq!(fitted.assignments().len(), m.rows());
        assert!(fitted.converged());
        assert_eq!(fitted.meta().variant, "Simp.Hamerly");
        let st = fitted.state().expect("fits carry state");
        assert_eq!(st.assignments, fitted.assignments());
        assert_eq!(st.steps_done as usize, fitted.iterations());
        assert_eq!(st.counts.iter().sum::<u64>(), m.rows() as u64);
        // The objective matches a recomputation from the artifacts.
        let recomputed =
            crate::metrics::objective(&m, fitted.assignments(), fitted.centers());
        assert!((recomputed - fitted.objective()).abs() < 1e-9 * (1.0 + fitted.objective()));
    }

    #[test]
    fn observer_sees_every_iteration_and_can_stop() {
        let m = data();
        // Count iterations of an unobserved run first.
        let full = SphericalKMeans::new(5).seed(11).fit(&m).unwrap();
        let total = full.stats().iters.len();
        assert!(total >= 3, "need a few iterations for the test");
        // A pass-through observer sees every iteration, in order.
        let mut seen = Vec::new();
        let mut obs = |s: &IterSnapshot<'_>| {
            seen.push(s.iteration);
            ControlFlow::Continue(())
        };
        let observed = SphericalKMeans::new(5)
            .seed(11)
            .fit_observed(&m, &mut obs)
            .unwrap();
        assert_eq!(seen, (0..total).collect::<Vec<_>>());
        assert_eq!(observed.assignments(), full.assignments());
        // Early stop: break after iteration 1 → at most 2 entries.
        let mut stopper = |s: &IterSnapshot<'_>| {
            if s.iteration >= 1 {
                ControlFlow::Break(())
            } else {
                ControlFlow::Continue(())
            }
        };
        let stopped = SphericalKMeans::new(5)
            .seed(11)
            .fit_observed(&m, &mut stopper)
            .unwrap();
        assert_eq!(stopped.stats().iters.len(), 2, "halts within one iteration");
        assert!(!stopped.converged());
    }
}
