//! Microbenchmarks of the hot-path primitives: sparse·sparse dot
//! (merge vs suffix-binary-search), sparse·dense dot, dense·dense dot,
//! and the center update. These are the innermost loops the §Perf pass
//! optimizes; see EXPERIMENTS.md §Perf for the recorded iterations.
//!
//! ```text
//! cargo bench --bench bench_sparse -- [--runs 20]
//! ```

// Bench and test targets favour readable literal casts and exact
// (bit-level) float assertions; the workspace clippy warnings on
// those patterns are aimed at library code.
#![allow(clippy::cast_possible_truncation, clippy::float_cmp)]

use sphkm::sparse::{CsrMatrix, DenseMatrix, SparseVec};
use sphkm::util::benchkit::{bench, black_box, BenchOpts};
use sphkm::util::cli::Args;
use sphkm::util::rng::Xoshiro256;

fn random_sparse(rng: &mut Xoshiro256, d: usize, nnz: usize) -> SparseVec {
    let mut idx = rng.sample_distinct(d, nnz);
    idx.sort_unstable();
    SparseVec::new(
        d,
        idx.iter().map(|&i| i as u32).collect(),
        idx.iter().map(|_| rng.next_f32() - 0.5).collect(),
    )
}

fn main() {
    let args = Args::from_env();
    let mut opts = BenchOpts::from_args(&args);
    if !args.has("runs") {
        opts.runs = 10;
    }
    let mut rng = Xoshiro256::seed_from_u64(7);

    // Sparse·sparse: balanced sizes (merge path).
    let d = 50_000;
    let a: Vec<SparseVec> = (0..64).map(|_| random_sparse(&mut rng, d, 80)).collect();
    let b: Vec<SparseVec> = (0..64).map(|_| random_sparse(&mut rng, d, 80)).collect();
    bench("sparse_dot/merge 80x80 nnz (64x64 pairs)", opts, || {
        let mut acc = 0.0;
        for x in &a {
            for y in &b {
                acc += x.dot(y);
            }
        }
        black_box(acc);
    });

    // Sparse·sparse: lopsided sizes (suffix binary search path).
    let tiny: Vec<SparseVec> = (0..64).map(|_| random_sparse(&mut rng, d, 3)).collect();
    let big: Vec<SparseVec> = (0..64).map(|_| random_sparse(&mut rng, d, 2000)).collect();
    bench("sparse_dot/gallop 3x2000 nnz (64x64 pairs)", opts, || {
        let mut acc = 0.0;
        for x in &tiny {
            for y in &big {
                acc += x.dot(y);
            }
        }
        black_box(acc);
    });

    // Sparse·dense: the assignment-loop hot path.
    let dense: Vec<f32> = (0..d).map(|_| rng.next_f32()).collect();
    let docs: Vec<SparseVec> = (0..2048).map(|_| random_sparse(&mut rng, d, 80)).collect();
    bench("sparse_dense_dot 80 nnz x 2048 rows", opts, || {
        let mut acc = 0.0;
        for x in &docs {
            acc += x.dot_dense(&dense);
        }
        black_box(acc);
    });

    // Dense·dense: the center–center (cc) cost that Fig. 2 hinges on.
    let k = 64;
    let dd = 8192;
    let mut centers = DenseMatrix::zeros(k, dd);
    for j in 0..k {
        for v in centers.row_mut(j) {
            *v = rng.next_f32();
        }
    }
    bench("dense_dot 8192-d centers (64x64/2 pairs)", opts, || {
        let mut acc = 0.0;
        for i in 0..k {
            for j in (i + 1)..k {
                acc += centers.row_dot(i, &centers, j);
            }
        }
        black_box(acc);
    });

    // Center rebuild + update (the O(nnz) per-iteration bookkeeping).
    let rows: Vec<SparseVec> = (0..4096).map(|_| random_sparse(&mut rng, 4096, 60)).collect();
    let m = CsrMatrix::from_rows(4096, &rows);
    let assign: Vec<u32> = (0..4096u32).map(|i| i % 32).collect();
    let mut cs = sphkm::kmeans::Centers::from_initial(DenseMatrix::zeros(32, 4096));
    bench("centers rebuild+update 4096 rows, k=32", opts, || {
        cs.rebuild(&m, &assign);
        black_box(cs.update());
    });
}
