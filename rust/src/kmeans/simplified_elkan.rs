//! Spherical Simplified Elkan's algorithm (§5.1, after Newling & Fleuret
//! 2016): keeps the full `u(i,j)` bound matrix and `l(i)`, but drops the
//! center–center (`cc`/`s`) pruning tests — saving the `O(k²)`
//! center–center similarities per iteration at the cost of having to scan
//! all k bounds for every point. The paper finds this trade favorable on
//! high-dimensional data (Fig. 2b) and unfavorable for large k on
//! low-dimensional data (Fig. 1c/d).
//!
//! Bound maintenance and the bound scan are fused into one sharded
//! per-point pass (see [`crate::kmeans`]'s parallel-execution docs).

use super::{
    audit_center_prune, bound_states, bound_works, Ctx, IterStats, KMeansConfig, Move, ShardOut,
    SimView,
};
use crate::audit::AUDIT_ENABLED;
use crate::bounds::{update_lower_pre, update_upper_pre};
use crate::obs::{span::span_start, Phase};
use crate::util::timer::Stopwatch;

pub(crate) fn run(ctx: &mut Ctx<'_, '_>, cfg: &KMeansConfig) -> bool {
    let n = ctx.src.rows();
    let k = ctx.k;
    let mut l = vec![0.0f64; n];
    let mut u = vec![0.0f64; n * k];

    let stop = {
        let states = bound_states(&ctx.plan, &mut l, 1, &mut u, k);
        ctx.initial_assignment(true, states, |(l, u), li, _bj, best, _second, sims| {
            l[li] = best;
            u[li * k..(li + 1) * k].copy_from_slice(sims);
        })
    };
    if stop {
        return false;
    }
    ctx.stats.bound_bytes = (n + n * k) * std::mem::size_of::<f64>();

    for _ in 0..cfg.max_iter {
        let sw = Stopwatch::start();
        let mut iter = IterStats::default();
        let iteration = ctx.stats.iters.len();

        let sp = span_start();
        let outs = {
            let src = ctx.src;
            let centers = &ctx.centers;
            let p = ctx.centers.p();
            let sin_p: Vec<f64> = p.iter().map(|&v| crate::bounds::sin_from_cos(v)).collect();
            let sin_p = &sin_p;
            let works = bound_works(&ctx.plan, &mut ctx.assign, &mut l, 1, &mut u, k);
            ctx.pool.run(works, |_, (range, assign, l, u)| {
                let mut out = ShardOut::default();
                let mut view = SimView::new(src, centers, k);
                for (li, i) in range.enumerate() {
                    let mut a = assign[li] as usize;
                    l[li] = update_lower_pre(l[li], p[a], sin_p[a]);
                    {
                        let urow = &mut u[li * k..(li + 1) * k];
                        for (j, uij) in urow.iter_mut().enumerate() {
                            *uij = update_upper_pre(*uij, p[j], sin_p[j]);
                        }
                    }
                    let mut tight = false;
                    for j in 0..k {
                        if j == a {
                            continue;
                        }
                        if u[li * k + j] <= l[li] {
                            out.iter.bound_skips += 1;
                            if AUDIT_ENABLED {
                                audit_center_prune(
                                    &mut view,
                                    &mut out.violations,
                                    "simplified-elkan",
                                    iteration,
                                    i,
                                    a,
                                    j,
                                    Some(u[li * k + j]),
                                    l[li],
                                );
                            }
                            continue;
                        }
                        if !tight {
                            l[li] = view.similarity(i, a, &mut out.iter);
                            tight = true;
                            if u[li * k + j] <= l[li] {
                                out.iter.bound_skips += 1;
                                if AUDIT_ENABLED {
                                    audit_center_prune(
                                        &mut view,
                                        &mut out.violations,
                                        "simplified-elkan",
                                        iteration,
                                        i,
                                        a,
                                        j,
                                        Some(u[li * k + j]),
                                        l[li],
                                    );
                                }
                                continue;
                            }
                        }
                        let s = view.similarity(i, j, &mut out.iter);
                        u[li * k + j] = s;
                        if s > l[li] {
                            u[li * k + a] = l[li];
                            assign[li] = j as u32;
                            out.moves.push(Move { i: i as u32, from: a as u32, to: j as u32 });
                            out.iter.reassignments += 1;
                            a = j;
                            l[li] = s;
                        }
                    }
                }
                out
            })
        };
        iter.phases.record(Phase::Assignment, sp);
        let sp = span_start();
        ctx.merge_shards(outs, &mut iter);

        if iter.reassignments == 0 {
            iter.phases.record(Phase::Update, sp);
            iter.wall_ms = sw.ms();
            ctx.push_iter(iter, true);
            return true;
        }
        iter.sims_center_center += ctx.centers.update();
        iter.phases.record(Phase::Update, sp);
        iter.phases
            .shift(Phase::Update, Phase::IndexRefresh, ctx.centers.take_refresh_ms());
        iter.wall_ms = sw.ms();
        if ctx.push_iter(iter, false) {
            return false;
        }
    }
    false
}
