//! Public-API surface snapshot: every `pub` item declaration line in
//! `src/` is recorded in the committed `rust/api-surface.txt`. A PR that
//! changes the public surface — adds, removes, renames, or re-signs an
//! item — fails this test until the snapshot is regenerated, which makes
//! API diffs explicit in review instead of buried in implementation
//! hunks.
//!
//! Regenerate after an intentional change with:
//!
//! ```text
//! UPDATE_API_SURFACE=1 cargo test -q --test api_surface
//! ```
//!
//! The scan is deliberately simple (first line of each `pub fn` /
//! `pub struct` / `pub enum` / `pub trait` / `pub const` / `pub type` /
//! `pub mod` / `pub use` declaration, path-sorted): it is a tripwire for
//! review, not a semantic API model. `pub(crate)` items are internal and
//! excluded.

// Bench and test targets favour readable literal casts and exact
// (bit-level) float assertions; the workspace clippy warnings on
// those patterns are aimed at library code.
#![allow(clippy::cast_possible_truncation, clippy::float_cmp)]

use std::path::{Path, PathBuf};

/// Declaration prefixes that constitute the public surface.
const KINDS: [&str; 8] = [
    "pub fn ", "pub struct ", "pub enum ", "pub trait ", "pub const ", "pub type ", "pub mod ",
    "pub use ",
];

fn collect(dir: &Path, base: &Path, out: &mut Vec<String>) {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)
        .unwrap_or_else(|e| panic!("read_dir {}: {e}", dir.display()))
        .map(|e| e.expect("dir entry").path())
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect(&p, base, out);
        } else if p.extension().is_some_and(|e| e == "rs") {
            let rel = p
                .strip_prefix(base)
                .expect("src-relative path")
                .to_string_lossy()
                .replace('\\', "/");
            let text = std::fs::read_to_string(&p)
                .unwrap_or_else(|e| panic!("read {}: {e}", p.display()));
            for line in text.lines() {
                let t = line.trim();
                if KINDS.iter().any(|k| t.starts_with(k)) {
                    out.push(format!("{rel}: {t}"));
                }
            }
        }
    }
}

#[test]
fn public_api_surface_matches_committed_snapshot() {
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let src = manifest.join("src");
    let snap_path = manifest.join("api-surface.txt");
    let mut lines = Vec::new();
    collect(&src, &src, &mut lines);
    let current = lines.join("\n") + "\n";
    if std::env::var("UPDATE_API_SURFACE").is_ok() {
        std::fs::write(&snap_path, &current).expect("write snapshot");
        return;
    }
    let committed = std::fs::read_to_string(&snap_path).unwrap_or_default();
    if committed == current {
        return;
    }
    // Readable failure: show what changed, not two multi-hundred-line
    // blobs.
    let old: std::collections::BTreeSet<&str> = committed.lines().collect();
    let new: std::collections::BTreeSet<&str> = current.lines().collect();
    let added: Vec<&&str> = new.difference(&old).collect();
    let removed: Vec<&&str> = old.difference(&new).collect();
    panic!(
        "public API surface changed ({} added, {} removed).\n\nAdded:\n{}\n\nRemoved:\n{}\n\n\
         If intentional, regenerate the snapshot:\n  UPDATE_API_SURFACE=1 cargo test -q --test api_surface\n",
        added.len(),
        removed.len(),
        added.iter().map(|s| format!("  + {s}")).collect::<Vec<_>>().join("\n"),
        removed.iter().map(|s| format!("  - {s}")).collect::<Vec<_>>().join("\n"),
    );
}
