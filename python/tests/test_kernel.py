"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes and data; assert_allclose against the reference —
the CORE correctness signal for the compiled artifacts.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bound_update as bu
from compile.kernels import ref
from compile.kernels import similarity as simk


def unit_rows(rng, n, d):
    x = rng.standard_normal((n, d)).astype(np.float32)
    norms = np.linalg.norm(x, axis=1, keepdims=True)
    return x / np.maximum(norms, 1e-9)


# ------------------------------------------------------------- similarity


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 40),
    k=st.integers(1, 24),
    d=st.integers(1, 80),
    seed=st.integers(0, 2**31 - 1),
)
def test_similarity_matches_ref(b, k, d, seed):
    rng = np.random.default_rng(seed)
    x = unit_rows(rng, b, d)
    c = unit_rows(rng, k, d)
    got = np.asarray(simk.similarity(x, c))
    want = np.asarray(ref.similarity_ref(x, c))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize(
    "block", [(8, 8, 16), (16, 4, 64), (128, 128, 512), (1, 1, 1)]
)
def test_similarity_block_shapes_agree(block):
    rng = np.random.default_rng(7)
    x = unit_rows(rng, 32, 64)
    c = unit_rows(rng, 16, 64)
    got = np.asarray(simk.similarity(x, c, block=block))
    want = np.asarray(ref.similarity_ref(x, c))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_similarity_non_divisible_shapes():
    # 37, 13, 71 are prime-ish: exercises the divisor-clamping logic.
    rng = np.random.default_rng(11)
    x = unit_rows(rng, 37, 71)
    c = unit_rows(rng, 13, 71)
    got = np.asarray(simk.similarity(x, c))
    want = np.asarray(ref.similarity_ref(x, c))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_vmem_estimate_is_positive_and_modest():
    vm = simk.vmem_bytes()
    assert 0 < vm < 16 * 2**20, "default blocks must fit VMEM"


# ------------------------------------------------------------- assign_step


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 32),
    k=st.integers(2, 24),
    d=st.integers(2, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_assign_matches_ref(b, k, d, seed):
    from compile import model

    rng = np.random.default_rng(seed)
    x = unit_rows(rng, b, d)
    c = unit_rows(rng, k, d)
    gi, gb, gs = (np.asarray(v) for v in model.assign_step(x, c))
    ri, rb, rs = (np.asarray(v) for v in ref.assign_ref(x, c))
    np.testing.assert_allclose(gb, rb, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(gs, rs, rtol=1e-5, atol=1e-5)
    # Index may differ only under (near-)ties of the top-2 values.
    differs = gi != ri
    if differs.any():
        np.testing.assert_allclose(gb[differs], gs[differs], rtol=1e-4, atol=1e-4)


def test_assign_against_numpy_bruteforce():
    from compile import model

    rng = np.random.default_rng(3)
    x = unit_rows(rng, 50, 30)
    c = unit_rows(rng, 8, 30)
    gi, gb, gs = (np.asarray(v) for v in model.assign_step(x, c))
    sims = x @ c.T
    np.testing.assert_array_equal(gi, sims.argmax(axis=1))
    np.testing.assert_allclose(gb, sims.max(axis=1), rtol=1e-5, atol=1e-6)
    part = np.partition(sims, -2, axis=1)
    np.testing.assert_allclose(gs, part[:, -2], rtol=1e-5, atol=1e-6)


def test_assign_k_equals_one():
    from compile import model

    rng = np.random.default_rng(5)
    x = unit_rows(rng, 9, 12)
    c = unit_rows(rng, 1, 12)
    gi, gb, gs = (np.asarray(v) for v in model.assign_step(x, c))
    assert (gi == 0).all()
    np.testing.assert_allclose(gb, (x @ c.T)[:, 0], rtol=1e-5, atol=1e-6)
    assert (gs == -1.0).all()


# ------------------------------------------------------------ bound_update


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 3000),
    seed=st.integers(0, 2**31 - 1),
)
def test_bound_update_matches_ref(n, seed):
    rng = np.random.default_rng(seed)
    l = rng.uniform(-1, 1, n).astype(np.float32)
    u = rng.uniform(-1, 1, n).astype(np.float32)
    pa = rng.uniform(-1, 1, n).astype(np.float32)
    pc = rng.uniform(0, 1, n).astype(np.float32)
    gl, gu = (np.asarray(v) for v in bu.bound_update(l, u, pa, pc))
    rl, ru = (np.asarray(v) for v in ref.bound_update_ref(l, u, pa, pc))
    np.testing.assert_allclose(gl, rl, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(gu, ru, rtol=1e-5, atol=1e-6)


def test_bound_update_guards():
    # Center moved past the bound angle: lower bound saturates to -1.
    l = np.array([0.9], dtype=np.float32)
    u = np.array([0.1], dtype=np.float32)
    pa = np.array([-0.95], dtype=np.float32)  # p <= -l
    pc = np.array([0.0], dtype=np.float32)
    gl, gu = (np.asarray(v) for v in bu.bound_update(l, u, pa, pc))
    assert gl[0] == -1.0
    np.testing.assert_allclose(gu[0], 0.1, atol=1e-6)  # pc=0 ⇒ no change


# ------------------------------------------------------------- cc bounds


def test_cc_bounds_ref_properties():
    rng = np.random.default_rng(13)
    c = unit_rows(rng, 10, 20)
    cc, s = (np.asarray(v) for v in ref.cc_bounds_ref(c))
    assert cc.shape == (10, 10)
    np.testing.assert_allclose(cc, cc.T, atol=1e-6)
    np.testing.assert_allclose(np.diag(cc), 1.0, atol=1e-6)
    for i in range(10):
        others = [cc[i, j] for j in range(10) if j != i]
        np.testing.assert_allclose(s[i], max(others), atol=1e-6)


def test_cc_step_matches_ref():
    from compile import model

    rng = np.random.default_rng(17)
    c = unit_rows(rng, 12, 24)
    gcc, gs = (np.asarray(v) for v in model.cc_step(c))
    rcc, rs = (np.asarray(v) for v in ref.cc_bounds_ref(c))
    np.testing.assert_allclose(gcc, rcc, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(gs, rs, rtol=1e-5, atol=1e-5)
