//! `RunReport`: the shared machine-readable result schema every bench
//! target emits through, and the validator CI runs against the committed
//! `BENCH_*.json` files.
//!
//! A report is a single JSON object:
//!
//! ```json
//! {
//!   "schema": "sphkm.report.v1",
//!   "bench": "kernel_crossover",
//!   "note": "optional free-form provenance",
//!   "config": {"rows": 8000, "k": 64, "runs": 5, "warmup": 1},
//!   "results": [ {"corpus": "kern-v1500", "dense_ms_mean": 41.2, ...}, ... ]
//! }
//! ```
//!
//! `config` holds the knobs the run was invoked with; `results` is a
//! flat array of measurement rows whose values are scalars (numbers,
//! strings, booleans, or `null` for not-yet-measured placeholders — the
//! committed placeholders regenerate in place when the benches run on a
//! machine with a toolchain). [`RunReport::validate`] enforces exactly
//! this shape, no more: rows are bench-specific, the envelope is not.

use std::path::Path;

use super::json::Json;
use super::timer::TimingStats;

/// Schema identifier stamped into every report; bump on envelope
/// changes.
pub const REPORT_SCHEMA: &str = "sphkm.report.v1";

/// A bench result document under construction (see module docs for the
/// serialized shape).
#[derive(Debug, Clone)]
pub struct RunReport {
    bench: String,
    note: Option<String>,
    config: Vec<(String, Json)>,
    results: Vec<Json>,
}

impl RunReport {
    /// Start an empty report for the named bench.
    pub fn new(bench: &str) -> Self {
        Self { bench: bench.to_string(), note: None, config: Vec::new(), results: Vec::new() }
    }

    /// Attach a free-form provenance note.
    pub fn note(&mut self, note: &str) {
        self.note = Some(note.to_string());
    }

    /// Record one configuration knob.
    pub fn config(&mut self, key: &str, value: Json) {
        self.config.push((key.to_string(), value));
    }

    /// Record one configuration knob as a number.
    pub fn config_num(&mut self, key: &str, value: f64) {
        self.config(key, Json::Num(value));
    }

    /// Record one configuration knob as a string.
    pub fn config_str(&mut self, key: &str, value: &str) {
        self.config(key, Json::Str(value.to_string()));
    }

    /// Append one measurement row (scalar values only; enforced by
    /// [`RunReport::validate`] on the way back in).
    pub fn push_result(&mut self, row: Vec<(String, Json)>) {
        self.results.push(Json::Obj(row));
    }

    /// Render to the serialized document.
    pub fn to_json(&self) -> Json {
        let mut members = vec![
            ("schema".to_string(), Json::Str(REPORT_SCHEMA.to_string())),
            ("bench".to_string(), Json::Str(self.bench.clone())),
        ];
        if let Some(n) = &self.note {
            members.push(("note".to_string(), Json::Str(n.clone())));
        }
        members.push(("config".to_string(), Json::Obj(self.config.clone())));
        members.push(("results".to_string(), Json::Arr(self.results.clone())));
        Json::Obj(members)
    }

    /// Pretty-render and write to `path` (trailing newline included).
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        let mut text = self.to_json().pretty(2);
        text.push('\n');
        std::fs::write(path, text)
    }

    /// Check that a parsed document is a well-formed v1 report:
    /// the envelope keys with their exact types, scalar config values,
    /// and an array of scalar-valued result rows.
    pub fn validate(doc: &Json) -> Result<(), String> {
        let obj = doc.as_obj().ok_or("report must be a JSON object")?;
        let schema = doc
            .get("schema")
            .and_then(Json::as_str)
            .ok_or("missing string field \"schema\"")?;
        if schema != REPORT_SCHEMA {
            return Err(format!("schema {schema:?}, expected {REPORT_SCHEMA:?}"));
        }
        doc.get("bench")
            .and_then(Json::as_str)
            .filter(|b| !b.is_empty())
            .ok_or("missing non-empty string field \"bench\"")?;
        if let Some(n) = doc.get("note") {
            n.as_str().ok_or("\"note\" must be a string")?;
        }
        let config = doc
            .get("config")
            .and_then(Json::as_obj)
            .ok_or("missing object field \"config\"")?;
        for (k, v) in config {
            if !v.is_scalar() {
                return Err(format!("config value {k:?} must be a scalar"));
            }
        }
        let results = doc
            .get("results")
            .and_then(Json::as_arr)
            .ok_or("missing array field \"results\"")?;
        for (i, row) in results.iter().enumerate() {
            let members = row
                .as_obj()
                .ok_or_else(|| format!("results[{i}] must be an object"))?;
            for (k, v) in members {
                if !v.is_scalar() {
                    return Err(format!("results[{i}].{k} must be a scalar"));
                }
            }
        }
        for (k, _) in obj {
            if !matches!(k.as_str(), "schema" | "bench" | "note" | "config" | "results") {
                return Err(format!("unknown top-level field {k:?}"));
            }
        }
        Ok(())
    }

    /// Parse and [`validate`](RunReport::validate) a serialized report.
    pub fn check_str(text: &str) -> Result<(), String> {
        let doc = Json::parse(text).map_err(|e| e.to_string())?;
        Self::validate(&doc)
    }
}

/// Flatten a [`TimingStats`] into prefixed measurement fields
/// (`<prefix>_mean_ms`, `_min_ms`, `_max_ms`, `_std_ms`, `_median_ms`,
/// `_runs`) for a result row.
pub fn timing_fields(prefix: &str, t: &TimingStats) -> Vec<(String, Json)> {
    vec![
        (format!("{prefix}_mean_ms"), Json::Num(t.mean_ms)),
        (format!("{prefix}_min_ms"), Json::Num(t.min_ms)),
        (format!("{prefix}_max_ms"), Json::Num(t.max_ms)),
        (format!("{prefix}_std_ms"), Json::Num(t.std_ms)),
        (format!("{prefix}_median_ms"), Json::Num(t.median_ms)),
        (format!("{prefix}_runs"), Json::Num(t.n as f64)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> RunReport {
        let mut r = RunReport::new("kernel_crossover");
        r.config_num("rows", 8000.0);
        r.config_str("variant", "Standard");
        r.push_result(vec![
            ("corpus".to_string(), Json::Str("kern-v1500".to_string())),
            ("dense_ms".to_string(), Json::Num(41.25)),
            ("pending".to_string(), Json::Null),
            ("ok".to_string(), Json::Bool(true)),
        ]);
        r
    }

    #[test]
    fn round_trip_validates() {
        let mut r = sample();
        r.note("test provenance");
        let text = r.to_json().pretty(2);
        RunReport::check_str(&text).expect("valid report");
        let doc = Json::parse(&text).unwrap();
        assert_eq!(doc.get("schema").and_then(Json::as_str), Some(REPORT_SCHEMA));
        assert_eq!(doc.get("bench").and_then(Json::as_str), Some("kernel_crossover"));
        let rows = doc.get("results").and_then(Json::as_arr).unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("dense_ms").and_then(Json::as_f64), Some(41.25));
        assert!(rows[0].get("pending").unwrap().is_null());
    }

    #[test]
    fn save_writes_parsable_pretty_json() {
        let dir = std::env::temp_dir().join("sphkm-report-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("r.json");
        sample().save(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.ends_with('\n'));
        RunReport::check_str(&text).expect("valid on disk");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn validation_names_the_defect() {
        let bad_schema = r#"{"schema": "other.v9", "bench": "x", "config": {}, "results": []}"#;
        assert!(RunReport::check_str(bad_schema).unwrap_err().contains("schema"));
        let no_bench = r#"{"schema": "sphkm.report.v1", "config": {}, "results": []}"#;
        assert!(RunReport::check_str(no_bench).unwrap_err().contains("bench"));
        let nested_row =
            r#"{"schema": "sphkm.report.v1", "bench": "x", "config": {}, "results": [{"a": []}]}"#;
        assert!(RunReport::check_str(nested_row).unwrap_err().contains("results[0]"));
        let unknown =
            r#"{"schema": "sphkm.report.v1", "bench": "x", "config": {}, "results": [], "extra": 1}"#;
        assert!(RunReport::check_str(unknown).unwrap_err().contains("extra"));
        assert!(RunReport::check_str("not json").is_err());
    }

    #[test]
    fn timing_fields_flatten_all_stats() {
        let t = TimingStats::from_ms(&[1.0, 3.0]);
        let fields = timing_fields("dense", &t);
        let keys: Vec<&str> = fields.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(
            keys,
            vec![
                "dense_mean_ms",
                "dense_min_ms",
                "dense_max_ms",
                "dense_std_ms",
                "dense_median_ms",
                "dense_runs"
            ]
        );
        assert_eq!(fields[5].1, Json::Num(2.0));
    }
}
