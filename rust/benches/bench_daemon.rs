//! Swap-under-load acceptance bench for the serving daemon: N client
//! threads sustain top-p queries over loopback TCP while the main thread
//! hot-swaps between two trained models through the `reload` RPC.
//!
//! Acceptance bars (asserted):
//! * every request through ≥ `--swaps` hot swaps completes — zero
//!   dropped or errored requests;
//! * every response is **bit-identical** to the one-shot
//!   `QueryEngine` answer of the model epoch that served it (even
//!   epochs serve model A, odd epochs model B);
//! * every answered query is attributed to exactly one epoch by the
//!   slot's per-epoch counters.
//!
//! ```text
//! cargo bench --bench bench_daemon -- [--rows 2000] [--k 16] [--top 3]
//!     [--clients 4] [--swaps 4] [--seed 42]
//! ```

// Bench and test targets favour readable literal casts and exact
// (bit-level) float assertions; the workspace clippy warnings on
// those patterns are aimed at library code.
#![allow(clippy::cast_possible_truncation, clippy::float_cmp)]

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use sphkm::data::synth::SynthConfig;
use sphkm::kmeans::{Engine, MiniBatchParams, SphericalKMeans};
use sphkm::model::Model;
use sphkm::serve::{Client, Daemon, DaemonConfig, ServeMode};
use sphkm::util::cli::Args;
use sphkm::util::timer::Stopwatch;

fn train(data: &sphkm::sparse::CsrMatrix, k: usize, seed: u64) -> sphkm::kmeans::FittedModel {
    SphericalKMeans::new(k)
        .engine(Engine::MiniBatch(MiniBatchParams {
            batch_size: 512,
            epochs: 2,
            truncate: Some(48),
            ..Default::default()
        }))
        .seed(seed)
        .threads(1)
        .fit(data)
        .expect("bench configuration is valid")
}

fn main() {
    let args = Args::from_env();
    let rows: usize = args.get_or("rows", 2_000).unwrap_or(2_000);
    let k: usize = args.get_or("k", 16).unwrap_or(16);
    let p: usize = args.get_or("top", 3).unwrap_or(3);
    let seed: u64 = args.get_or("seed", 42).unwrap_or(42);
    let clients: usize = args.get_or("clients", 4).unwrap_or(4).max(1);
    let swaps: u64 = args.get_or("swaps", 4).unwrap_or(4).max(3);

    let ds = SynthConfig {
        name: "daemon-bench".into(),
        n_docs: rows,
        vocab: 8_000,
        topics: k.max(2),
        doc_len_mean: 50.0,
        doc_len_sigma: 0.4,
        topic_strength: 0.6,
        shared_vocab_frac: 0.2,
        zipf_s: 1.05,
        anomaly_frac: 0.0,
        tfidf: Default::default(),
    }
    .generate(seed);
    println!(
        "# daemon bench — {} rows × {} dims, k={k}, top-{p}, {clients} clients, {swaps} swaps",
        ds.matrix.rows(),
        ds.matrix.cols(),
    );

    // Two distinct models, persisted like production would.
    let dir = std::env::temp_dir().join(format!("sphkm-bench-daemon-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp dir");
    let a_path = dir.join("a.spkm");
    let b_path = dir.join("b.spkm");
    train(&ds.matrix, k, seed).to_model().save(&a_path).expect("save A");
    train(&ds.matrix, k, seed ^ 0x5eed).to_model().save(&b_path).expect("save B");

    // The probe batch and the per-model one-shot oracle answers, computed
    // from the same persisted files the daemon serves (the engine is a
    // pure function of the frozen model, bit-identical at every thread
    // count, so one oracle covers every epoch of that model).
    let probe_rows = ds.matrix.rows().min(200);
    let probe: Vec<(Vec<u32>, Vec<f32>)> = (0..probe_rows)
        .map(|i| {
            let r = ds.matrix.row(i);
            (r.indices.to_vec(), r.values.to_vec())
        })
        .collect();
    let probe_csr = sphkm::sparse::CsrMatrix::from_rows(
        ds.matrix.cols(),
        &(0..probe_rows).map(|i| {
            sphkm::sparse::SparseVec::from_pairs(
                ds.matrix.cols(),
                ds.matrix.row(i)
                    .indices
                    .iter()
                    .zip(ds.matrix.row(i).values)
                    .map(|(&c, &v)| (c, v))
                    .collect(),
            )
        })
        .collect::<Vec<_>>(),
    );
    let mode = ServeMode::Pruned;
    let oracle = |path: &std::path::Path| -> Vec<Vec<(u32, f64)>> {
        let engine = sphkm::kmeans::FittedModel::from_model(Model::load(path).expect("load"))
            .query_engine_with(mode, 1);
        engine.top_p_batch(&probe_csr, p).0
    };
    let answers = [oracle(&a_path), oracle(&b_path)]; // [even epochs, odd epochs]

    let cfg = DaemonConfig {
        mode,
        threads: 1,
        ..DaemonConfig::default()
    };
    let handle =
        Daemon::start(Model::load(&a_path).expect("load A"), &cfg).expect("daemon starts");
    let addr = handle.local_addr().to_string();
    println!("# daemon on {addr}");

    let stop = Arc::new(AtomicBool::new(false));
    let completed = Arc::new(AtomicU64::new(0));
    let sw = Stopwatch::start();
    std::thread::scope(|s| {
        for _ in 0..clients {
            let addr = addr.clone();
            let probe = probe.clone();
            let answers = &answers;
            let stop = Arc::clone(&stop);
            let completed = Arc::clone(&completed);
            s.spawn(move || {
                let mut client = Client::connect(&addr).expect("client connects");
                let mut done = 0u64;
                // Keep querying until the swapper finishes, with a floor
                // so every client demonstrably runs through the swaps.
                while !stop.load(Ordering::SeqCst) || done < 8 {
                    let (epoch, got) = client.query(p, &probe).expect("zero errored requests");
                    let want = &answers[(epoch % 2) as usize];
                    assert_eq!(got.len(), want.len(), "epoch {epoch}: row count");
                    for (i, (g, w)) in got.iter().zip(want).enumerate() {
                        assert_eq!(g.len(), w.len(), "epoch {epoch} row {i}: rank count");
                        for (x, y) in g.iter().zip(w) {
                            assert_eq!(x.0, y.0, "epoch {epoch} row {i}: center ids");
                            assert_eq!(
                                x.1.to_bits(),
                                y.1.to_bits(),
                                "epoch {epoch} row {i}: similarities"
                            );
                        }
                    }
                    done += 1;
                }
                completed.fetch_add(done, Ordering::SeqCst);
            });
        }
        let addr = addr.clone();
        let stop = Arc::clone(&stop);
        let a = a_path.clone();
        let b = b_path.clone();
        s.spawn(move || {
            let mut client = Client::connect(&addr).expect("swapper connects");
            for swap in 1..=swaps {
                // Odd epochs serve B, even epochs serve A.
                let path = if swap % 2 == 0 { &a } else { &b };
                let epoch = client.reload(Some(path.to_str().expect("utf8 path"))).expect("reload");
                assert_eq!(epoch, swap, "swaps publish consecutive epochs");
                std::thread::sleep(std::time::Duration::from_millis(40));
            }
            stop.store(true, Ordering::SeqCst);
        });
    });
    let ms = sw.ms();

    let mut client = Client::connect(&addr).expect("stats client");
    let (epoch, swapped, per_epoch, _metrics) = client.stats().expect("stats");
    let total_requests = completed.load(Ordering::SeqCst);
    let attributed: u64 = per_epoch.iter().map(|&(_, n)| n).sum();
    client.shutdown().expect("shutdown ack");
    let metrics = handle.join();

    assert_eq!(epoch, swaps, "final epoch");
    assert_eq!(swapped, swaps, "swap counter");
    assert_eq!(
        attributed,
        total_requests * probe_rows as u64,
        "every answered query attributed to exactly one epoch"
    );
    assert_eq!(metrics.counter("daemon.errors"), 0, "zero errored requests");
    std::fs::remove_dir_all(&dir).ok();

    println!(
        "# {total_requests} batches × {probe_rows} queries from {clients} clients in {ms:.0} ms \
         ({:.0} queries/s) across {swaps} hot swaps; per-epoch queries: {per_epoch:?}",
        (total_requests * probe_rows as u64) as f64 / (ms / 1000.0).max(1e-9),
    );
    println!(
        "# acceptance: zero dropped or errored requests; every response bit-identical \
         to the one-shot QueryEngine answer for its serving epoch — OK"
    );
}
