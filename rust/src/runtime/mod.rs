//! Execution runtimes.
//!
//! * [`parallel`] — the sharded worker pool the k-means assignment phase
//!   runs on (always available; see the shard-determinism contract in
//!   [`crate::kmeans`]).
//! * [`AssignEngine`] (feature `pjrt`) — loads the AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`) and executes them via the `xla`
//!   crate's PJRT client. Gated off by default because the `xla` crate and
//!   its PJRT C library are unavailable on clean machines; the artifact
//!   [`Manifest`] helpers stay available regardless so tooling can inspect
//!   artifact directories without the heavyweight dependency.

mod engine;
pub mod parallel;

#[cfg(feature = "pjrt")]
pub use engine::AssignEngine;
pub use engine::{artifacts_available, AssignTile, EngineError, Manifest};
