//! Out-of-core training benchmark: fit a corpus from the chunked on-disk
//! shard store under a resident-memory budget far below the full matrix,
//! and prove the result is **bit-identical** to the in-memory fit.
//!
//! The corpus is written to a `.sks` shard file, reopened with a small
//! reader-side chunk budget, and trained with the same seeded estimator
//! as the in-memory reference. Hard assertions: (1) assignments,
//! objective bits, and every center coordinate agree across backends;
//! (2) the peak resident point data (tracked by the chunk cursors) stays
//! **strictly below** the full in-memory matrix footprint — i.e. the run
//! really was out-of-core, not a buffered copy.
//!
//! Results are appended to `BENCH_out_of_core.json` at the repository
//! root (schema documented in that file).
//!
//! ```text
//! cargo bench --bench bench_out_of_core -- [--rows 20000] [--k 16]
//!     [--vocab 30000] [--max-iter 6] [--chunk-rows 256] [--threads 0]
//!     [--seed 42] [--variant simp-elkan]
//! ```

// Bench and test targets favour readable literal casts and exact
// (bit-level) float assertions; the workspace clippy warnings on
// those patterns are aimed at library code.
#![allow(clippy::cast_possible_truncation, clippy::float_cmp)]

use sphkm::data::synth::SynthConfig;
use sphkm::kmeans::{SphericalKMeans, Variant};
use sphkm::sparse::chunked::{reset_resident_peak, resident_peak_bytes};
use sphkm::sparse::{RowSource, ShardStore};
use sphkm::util::cli::Args;
use sphkm::util::mem::peak_rss_bytes;
use sphkm::util::timer::Stopwatch;

fn corpus(vocab: usize, rows: usize, k: usize, seed: u64) -> sphkm::data::Dataset {
    SynthConfig {
        name: format!("ooc-v{vocab}"),
        n_docs: rows,
        vocab,
        topics: k.max(2),
        doc_len_mean: 60.0,
        doc_len_sigma: 0.4,
        topic_strength: 0.65,
        shared_vocab_frac: 0.2,
        zipf_s: 1.05,
        anomaly_frac: 0.0,
        tfidf: Default::default(),
    }
    .generate(seed)
}

fn main() {
    let args = Args::from_env();
    let rows: usize = args.get_or("rows", 20_000).unwrap_or(20_000);
    let k: usize = args.get_or("k", 16).unwrap_or(16);
    let vocab: usize = args.get_or("vocab", 30_000).unwrap_or(30_000);
    let max_iter: usize = args.get_or("max-iter", 6).unwrap_or(6);
    let chunk_rows: usize = args.get_or("chunk-rows", 256).unwrap_or(256);
    let threads: usize = args.get_or("threads", 0).unwrap_or(0);
    let seed: u64 = args.get_or("seed", 42).unwrap_or(42);
    let variant: Variant = args
        .get("variant")
        .map(|v| v.parse().expect("valid variant name"))
        .unwrap_or(Variant::SimplifiedElkan);

    println!(
        "# out-of-core bench — {}, k={k}, {rows} rows, vocab={vocab}, \
         chunk-rows={chunk_rows}, {max_iter}-iteration cap, threads={threads}",
        variant.name()
    );

    let ds = corpus(vocab, rows, k, seed);
    let shard_path = std::env::temp_dir().join(format!(
        "sphkm-bench-ooc-{}.sks",
        std::process::id()
    ));
    let sw = Stopwatch::start();
    ShardStore::write_from_matrix(&shard_path, &ds.matrix).expect("shard write");
    let convert_ms = sw.ms();
    let store = ShardStore::open(&shard_path)
        .expect("shard open")
        .with_chunk_rows(chunk_rows);

    let est = || {
        SphericalKMeans::new(k)
            .variant(variant)
            .seed(seed ^ 1)
            .threads(threads)
            .max_iter(max_iter)
    };

    let sw = Stopwatch::start();
    let mem = est().fit(&ds.matrix).expect("bench configuration is valid");
    let mem_ms = sw.ms();

    reset_resident_peak();
    let sw = Stopwatch::start();
    let disk = est()
        .fit_source(RowSource::Disk(&store))
        .expect("bench configuration is valid");
    let disk_ms = sw.ms();
    let peak_resident = resident_peak_bytes();
    let full_bytes = store.in_memory_bytes();
    std::fs::remove_file(&shard_path).ok();

    // Exactness across backends: bit for bit.
    assert_eq!(mem.assignments(), disk.assignments(), "assignments");
    assert_eq!(
        mem.objective().to_bits(),
        disk.objective().to_bits(),
        "objective"
    );
    for j in 0..k {
        for (x, y) in mem.centers().row(j).iter().zip(disk.centers().row(j)) {
            assert_eq!(x.to_bits(), y.to_bits(), "center {j}");
        }
    }
    // Out-of-core for real: resident point data strictly below the
    // full-matrix footprint (with room to spare at any sane chunk size).
    assert!(
        peak_resident < full_bytes,
        "peak resident point data {peak_resident} B must stay strictly below \
         the {full_bytes} B in-memory matrix"
    );

    let mib = |b: u64| b as f64 / (1024.0 * 1024.0);
    println!(
        "{:<26} {:>12} {:>12} {:>12}",
        "", "in-memory", "out-of-core", "ratio"
    );
    println!(
        "{:<26} {:>10.1}ms {:>10.1}ms {:>11.2}x",
        "train wall-clock", mem_ms, disk_ms, disk_ms / mem_ms.max(1e-9)
    );
    println!(
        "{:<26} {:>9.2}MiB {:>9.2}MiB {:>11.2}x",
        "resident point data",
        mib(full_bytes),
        mib(peak_resident),
        peak_resident as f64 / full_bytes.max(1) as f64
    );
    println!(
        "# convert {convert_ms:.1}ms, shard file {:.2}MiB, objective {:.6}, {} iterations",
        mib(store.file_len()),
        disk.objective(),
        disk.iterations()
    );

    let json_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_out_of_core.json");
    let rss = peak_rss_bytes().map_or("null".to_string(), |b| b.to_string());
    let json = format!(
        "{{\n  \"bench\": \"out_of_core\",\n  \"config\": {{\n    \"variant\": \"{}\",\n    \
         \"rows\": {rows},\n    \"vocab\": {vocab},\n    \"k\": {k},\n    \
         \"max_iter\": {max_iter},\n    \"chunk_rows\": {chunk_rows},\n    \
         \"threads\": {threads},\n    \"seed\": {seed}\n  }},\n  \"results\": {{\n    \
         \"convert_ms\": {convert_ms:.2},\n    \"mem_train_ms\": {mem_ms:.2},\n    \
         \"disk_train_ms\": {disk_ms:.2},\n    \"full_matrix_bytes\": {full_bytes},\n    \
         \"peak_resident_bytes\": {peak_resident},\n    \
         \"resident_ratio\": {:.6},\n    \"peak_rss_bytes\": {rss},\n    \
         \"objective\": {:.9},\n    \"iterations\": {},\n    \
         \"bit_identical_to_in_memory\": true\n  }}\n}}\n",
        variant.name(),
        peak_resident as f64 / full_bytes.max(1) as f64,
        disk.objective(),
        disk.iterations()
    );
    match std::fs::write(&json_path, &json) {
        Ok(()) => println!("# wrote {}", json_path.display()),
        Err(e) => println!("# could not write {}: {e}", json_path.display()),
    }

    println!(
        "# acceptance: bit-identical clustering from shards at {:.1}% of the \
         in-memory footprint — OK",
        100.0 * peak_resident as f64 / full_bytes.max(1) as f64
    );
}
