//! Observability subsystem: phase-scoped timing spans, a metrics registry
//! with exact-bucket latency histograms, and a structured JSONL trace
//! writer.
//!
//! The paper's evaluation rests on two axes — similarity computations
//! saved *and* wall-clock won — and the two routinely diverge: pruning
//! that wins in multiply-adds can lose in wall-clock when the memory
//! layout fights the cache. [`IterStats`](crate::kmeans::IterStats)
//! counts the first axis meticulously; this module instruments the
//! second. It answers *where the time goes*: which phase of each
//! iteration (seeding, sharded assignment, bounds maintenance, center
//! update, index refresh, shard I/O), with what serve-side latency
//! distribution, and how both evolve across a run.
//!
//! # The three instruments
//!
//! * **Spans** ([`span`]) — phase-scoped wall-clock timing aggregated
//!   per iteration into a [`PhaseTimes`] table, recorded at the existing
//!   iteration barriers of all seven exact engines and the mini-batch
//!   optimizer. Surfaced through
//!   [`IterStats::phases`](crate::kmeans::IterStats),
//!   [`RunStats::phase_totals`](crate::kmeans::RunStats), the
//!   [`IterSnapshot`](crate::kmeans::IterSnapshot) observer hook, and
//!   `cluster --stats`.
//! * **Metrics** ([`metrics`]) — a registry of named counters, gauges,
//!   and fixed-bucket log-scale latency histograms
//!   ([`LatencyHistogram`]: 4 sub-buckets per power-of-two octave, exact
//!   p50/p95/p99 up to ≤ 25% bucket resolution, mergeable across shards
//!   by element-wise addition). Wired into the serve-side
//!   [`QueryEngine`](crate::serve::QueryEngine) timed batch paths and
//!   the [`ShardStore`](crate::sparse::ShardStore) chunk loader.
//! * **Traces** ([`trace`]) — a JSONL writer emitting versioned,
//!   schema-stable records (`run_start` / `iter` / `run_end`, schema
//!   [`TRACE_SCHEMA`]) behind `cluster --trace-out`, plus the validator
//!   the test suite and `sphkm report --check` run against every line.
//!
//! Bench targets report through the shared
//! [`RunReport`](crate::util::report::RunReport) schema in
//! [`util::report`](crate::util::report), which is what populates the
//! committed `BENCH_*.json` files.
//!
//! # Zero cost when off
//!
//! Like the audit layer, instrumentation is gated on the compile-time
//! constant [`TRACE_ENABLED`] (`cfg!(feature = "trace")`) rather than on
//! `#[cfg]` blocks: the observability code type-checks in every build,
//! and when the feature is off [`span::span_start`] const-folds to
//! `None`, every `record` is a branch on a constant `false`, and the
//! compiled hot loops are bit-for-bit those of an uninstrumented build.
//! With the feature **on**, results stay bit-identical — spans only read
//! the monotonic clock at iteration barriers, outside every counted
//! similarity path; only wall-clock observation is added, never
//! arithmetic. The serve-side timed batch entry points
//! ([`QueryEngine::top_p_batch_timed`](crate::serve::QueryEngine::top_p_batch_timed))
//! are explicit opt-ins and therefore work in every build: calling them
//! is the gate, so the untimed paths stay untouched.

pub mod metrics;
pub mod span;
pub mod trace;

pub use metrics::{LatencyHistogram, Metrics};
pub use span::{Phase, PhaseTimes};
pub use trace::{TraceWriter, TRACE_SCHEMA};

/// True when the crate was compiled with the `trace` cargo feature —
/// the single gate every span and background-metric site branches on.
/// A constant, so disabled instrumentation is removed at compile time.
pub const TRACE_ENABLED: bool = cfg!(feature = "trace");
