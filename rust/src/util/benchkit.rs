//! A small benchmarking harness (the offline registry has no `criterion`).
//!
//! Provides warmup + repeated measurement with summary statistics, a
//! `black_box` to defeat dead-code elimination, and a uniform one-line
//! reporting format used by all `cargo bench` targets:
//!
//! ```text
//! bench <name> ... mean 12.345 ms  (min 11.9, max 13.1, std 0.4, n=10)
//! ```

use super::timer::{Stopwatch, TimingStats};

/// Re-exported std black_box for convenience in bench targets.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Benchmark configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchOpts {
    /// Number of un-measured warmup runs.
    pub warmup: usize,
    /// Number of measured runs.
    pub runs: usize,
}

impl Default for BenchOpts {
    fn default() -> Self {
        Self { warmup: 1, runs: 5 }
    }
}

impl BenchOpts {
    /// Read `--runs` / `--warmup` overrides from CLI args (for quick modes).
    pub fn from_args(args: &super::cli::Args) -> Self {
        let d = Self::default();
        Self {
            warmup: args.get_or("warmup", d.warmup).unwrap_or(d.warmup),
            runs: args.get_or("runs", d.runs).unwrap_or(d.runs),
        }
    }
}

/// Result of one benchmark: its name and timing statistics.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark name (dataset/algo/k triple etc.).
    pub name: String,
    /// Timing summary.
    pub stats: TimingStats,
}

impl BenchResult {
    /// criterion-like single line.
    pub fn line(&self) -> String {
        format!(
            "bench {:<48} mean {:>10.3} ms  (min {:.3}, max {:.3}, std {:.3}, n={})",
            self.name,
            self.stats.mean_ms,
            self.stats.min_ms,
            self.stats.max_ms,
            self.stats.std_ms,
            self.stats.n
        )
    }
}

/// Measure `f` (which should internally use [`black_box`]) under `opts`,
/// print the summary line, and return it.
pub fn bench<F: FnMut()>(name: &str, opts: BenchOpts, mut f: F) -> BenchResult {
    for _ in 0..opts.warmup {
        f();
    }
    let mut samples = Vec::with_capacity(opts.runs);
    for _ in 0..opts.runs.max(1) {
        let sw = Stopwatch::start();
        f();
        samples.push(sw.ms());
    }
    let result = BenchResult {
        name: name.to_string(),
        stats: TimingStats::from_ms(&samples),
    };
    println!("{}", result.line());
    result
}

/// Measure a function that returns its own elapsed milliseconds (used when
/// setup must be excluded from the measurement inside each run).
pub fn bench_with_inner_timing<F: FnMut() -> f64>(
    name: &str,
    opts: BenchOpts,
    mut f: F,
) -> BenchResult {
    for _ in 0..opts.warmup {
        f();
    }
    let mut samples = Vec::with_capacity(opts.runs);
    for _ in 0..opts.runs.max(1) {
        samples.push(f());
    }
    let result = BenchResult {
        name: name.to_string(),
        stats: TimingStats::from_ms(&samples),
    };
    println!("{}", result.line());
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_expected_times() {
        let mut count = 0;
        let opts = BenchOpts { warmup: 2, runs: 3 };
        let r = bench("unit-test", opts, || {
            count += 1;
            black_box(count);
        });
        assert_eq!(count, 5);
        assert_eq!(r.stats.n, 3);
    }

    #[test]
    fn inner_timing_passthrough() {
        let opts = BenchOpts { warmup: 0, runs: 4 };
        let mut i = 0.0;
        let r = bench_with_inner_timing("inner", opts, || {
            i += 1.0;
            i
        });
        assert_eq!(r.stats.n, 4);
        assert_eq!(r.stats.min_ms, 1.0);
        assert_eq!(r.stats.max_ms, 4.0);
    }
}
