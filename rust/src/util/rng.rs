//! Deterministic pseudo-random number generation.
//!
//! The `rand` crate is not available in the offline registry, so we provide
//! a small, well-tested PRNG stack of our own:
//!
//! * [`SplitMix64`] — seeding / stream splitting (Steele et al. 2014).
//! * [`Xoshiro256`] — xoshiro256** 1.0 (Blackman & Vigna 2018), the general
//!   purpose generator used throughout the crate.
//!
//! All experiment code takes explicit `u64` seeds so every table and figure
//! in EXPERIMENTS.md is exactly reproducible.

/// SplitMix64: a tiny, high-quality 64-bit generator used to seed
/// [`Xoshiro256`] and to derive independent substreams from one master seed.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** 1.0 — fast all-purpose generator with 256-bit state.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 as recommended by the xoshiro authors (avoids
    /// the all-zero state and decorrelates similar seeds).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent substream; `index` selects the stream.
    /// Used to give each (dataset, algorithm, k, repeat) cell its own RNG.
    pub fn substream(master_seed: u64, index: u64) -> Self {
        let mut sm = SplitMix64::new(master_seed ^ index.wrapping_mul(0xA24BAED4963EE407));
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Next 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform `f64` in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Unbiased uniform integer in `[0, bound)` (Lemire's method).
    #[inline]
    pub fn next_bounded(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform index in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.next_bounded(n as u64) as usize
    }

    /// Standard normal via Box–Muller (sufficient quality for data synthesis).
    pub fn next_gaussian(&mut self) -> f64 {
        // Avoid log(0) by nudging u1 away from zero.
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Sample `count` distinct indices in `[0, n)` (Floyd's algorithm).
    pub fn sample_distinct(&mut self, n: usize, count: usize) -> Vec<usize> {
        assert!(count <= n, "cannot sample {count} distinct from {n}");
        // For small count relative to n, Floyd's algorithm; otherwise a
        // partial Fisher-Yates over a materialized index range.
        if count * 8 <= n {
            let mut chosen = std::collections::HashSet::with_capacity(count * 2);
            let mut out = Vec::with_capacity(count);
            for j in (n - count)..n {
                let t = self.index(j + 1);
                let pick = if chosen.contains(&t) { j } else { t };
                chosen.insert(pick);
                out.push(pick);
            }
            out
        } else {
            let mut idx: Vec<usize> = (0..n).collect();
            for i in 0..count {
                let j = i + self.index(n - i);
                idx.swap(i, j);
            }
            idx.truncate(count);
            idx
        }
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from an (unnormalized, non-negative) weight slice.
    /// Returns `None` if the total weight is not positive and finite.
    pub fn weighted_index(&mut self, weights: &[f64]) -> Option<usize> {
        let total: f64 = weights.iter().sum();
        if !(total > 0.0) || !total.is_finite() {
            return None;
        }
        let mut target = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            target -= w;
            if target < 0.0 {
                return Some(i);
            }
        }
        // Floating-point slack: return the last positive-weight index.
        weights.iter().rposition(|&w| w > 0.0)
    }
}

/// A Zipf(s) sampler over `{0, …, n−1}` using precomputed cumulative weights
/// and binary search. Used by the synthetic corpus generator to produce
/// realistic token frequency distributions.
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// Build a sampler over `n` ranks with exponent `s` (s ≈ 1 for text).
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for rank in 1..=n {
            acc += 1.0 / (rank as f64).powf(s);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap();
        for v in &mut cdf {
            *v /= total;
        }
        Self { cdf }
    }

    /// Number of ranks.
    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    /// True if the support is empty (never; kept for API completeness).
    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }

    /// Draw a rank in `[0, n)`; rank 0 is the most frequent.
    #[inline]
    pub fn sample(&self, rng: &mut Xoshiro256) -> usize {
        let u = rng.next_f64();
        match self.cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 1234567 from the public-domain C code.
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism across instances.
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(a, sm2.next_u64());
        assert_eq!(b, sm2.next_u64());
    }

    #[test]
    fn xoshiro_determinism_and_spread() {
        let mut r1 = Xoshiro256::seed_from_u64(42);
        let mut r2 = Xoshiro256::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(r1.next_u64(), r2.next_u64());
        }
        let mut r3 = Xoshiro256::seed_from_u64(43);
        let same = (0..100).filter(|_| r1.next_u64() == r3.next_u64()).count();
        assert!(same < 3, "different seeds should decorrelate");
    }

    #[test]
    fn f64_in_unit_interval_and_uniform() {
        let mut rng = Xoshiro256::seed_from_u64(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean} far from 0.5");
    }

    #[test]
    fn bounded_is_unbiased_enough() {
        let mut rng = Xoshiro256::seed_from_u64(9);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[rng.next_bounded(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "count {c} too skewed");
        }
    }

    #[test]
    fn sample_distinct_is_distinct_and_in_range() {
        let mut rng = Xoshiro256::seed_from_u64(11);
        for &(n, k) in &[(100usize, 5usize), (50, 40), (10, 10), (1000, 3)] {
            let s = rng.sample_distinct(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k, "duplicates in sample");
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut rng = Xoshiro256::seed_from_u64(13);
        let w = [0.0, 3.0, 1.0, 0.0];
        let mut counts = [0usize; 4];
        for _ in 0..40_000 {
            counts[rng.weighted_index(&w).unwrap()] += 1;
        }
        assert_eq!(counts[0], 0);
        assert_eq!(counts[3], 0);
        let ratio = counts[1] as f64 / counts[2] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio} far from 3.0");
    }

    #[test]
    fn weighted_index_degenerate() {
        let mut rng = Xoshiro256::seed_from_u64(14);
        assert_eq!(rng.weighted_index(&[]), None);
        assert_eq!(rng.weighted_index(&[0.0, 0.0]), None);
        assert_eq!(rng.weighted_index(&[f64::NAN]), None);
        assert_eq!(rng.weighted_index(&[0.0, 5.0]), Some(1));
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Xoshiro256::seed_from_u64(15);
        let n = 50_000;
        let (mut m, mut v) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.next_gaussian();
            m += x;
            v += x * x;
        }
        m /= n as f64;
        v = v / n as f64 - m * m;
        assert!(m.abs() < 0.02, "gaussian mean {m}");
        assert!((v - 1.0).abs() < 0.05, "gaussian var {v}");
    }

    #[test]
    fn zipf_is_monotone_decreasing_in_rank() {
        let z = Zipf::new(1000, 1.07);
        let mut rng = Xoshiro256::seed_from_u64(17);
        let mut counts = vec![0usize; 1000];
        for _ in 0..200_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        // Head ranks should dominate tail ranks.
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[200]);
        let head: usize = counts[..10].iter().sum();
        assert!(head > 200_000 / 5, "Zipf head mass too small: {head}");
    }

    #[test]
    fn shuffle_permutes() {
        let mut rng = Xoshiro256::seed_from_u64(19);
        let mut v: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "shuffle did nothing");
    }
}
