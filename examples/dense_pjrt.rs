//! The three-layer path in isolation: the AOT-compiled JAX/Pallas
//! assignment kernel executed from Rust via PJRT, cross-checked against
//! the native sparse path on the same data, with throughput numbers.
//!
//! Requires `make artifacts` (skips gracefully otherwise).
//!
//! ```text
//! cargo run --release --example dense_pjrt
//! ```

// Example code favours readable literal casts; the workspace clippy
// warnings on those patterns are aimed at library code.
#![allow(clippy::cast_possible_truncation, clippy::float_cmp)]

use sphkm::data::synth::SynthConfig;
use sphkm::runtime::{artifacts_available, AssignEngine, Manifest};
use sphkm::util::timer::Stopwatch;
use std::path::Path;

fn main() {
    let dir = Path::new("artifacts");
    if !artifacts_available(dir) {
        eprintln!("no artifacts found — run `make artifacts` first");
        std::process::exit(0);
    }

    // Dataset matching the (B=256, K=16, D=512) artifact.
    let ds = SynthConfig {
        name: "pjrt-demo".into(),
        n_docs: 4096,
        vocab: 512,
        topics: 16,
        doc_len_mean: 40.0,
        doc_len_sigma: 0.4,
        topic_strength: 0.7,
        shared_vocab_frac: 0.25,
        zipf_s: 1.1,
        anomaly_frac: 0.0,
        tfidf: Default::default(),
    }
    .generate(3);
    let data = &ds.matrix;
    let k = 16;
    let d = 512;

    // Centers: 16 arbitrary unit rows.
    let mut centers = vec![0.0f32; k * d];
    for j in 0..k {
        let row = data.row(j * 11);
        for (t, &c) in row.indices.iter().enumerate() {
            centers[j * d + c as usize] = row.values[t];
        }
    }

    let mut engine = AssignEngine::load(dir, Manifest { batch: 256, k, dim: d })
        .expect("artifact load (make artifacts)");
    println!(
        "PJRT engine: platform={}, artifact={}",
        engine.platform(),
        engine.manifest().filename()
    );

    // PJRT dense path.
    let sw = Stopwatch::start();
    let tile = engine.assign_all(data, &centers).expect("execute");
    let pjrt_ms = sw.ms();

    // Native sparse path.
    let sw = Stopwatch::start();
    let mut native_best = vec![0u32; data.rows()];
    let mut native_sim = vec![0.0f64; data.rows()];
    for i in 0..data.rows() {
        let row = data.row(i);
        let (mut b, mut bj) = (f64::MIN, 0usize);
        for j in 0..k {
            let s = row.dot_dense(&centers[j * d..(j + 1) * d]);
            if s > b {
                b = s;
                bj = j;
            }
        }
        native_best[i] = bj as u32;
        native_sim[i] = b;
    }
    let native_ms = sw.ms();

    // Cross-check.
    let mut mismatches = 0;
    for i in 0..data.rows() {
        if tile.best[i] != native_best[i]
            && (tile.best_sim[i] as f64 - native_sim[i]).abs() > 1e-4
        {
            mismatches += 1;
        }
    }
    println!(
        "{} rows: PJRT {:.1} ms ({:.0} rows/s) vs native sparse {:.1} ms ({:.0} rows/s), {} mismatches",
        data.rows(),
        pjrt_ms,
        data.rows() as f64 / pjrt_ms * 1e3,
        native_ms,
        data.rows() as f64 / native_ms * 1e3,
        mismatches
    );
    assert_eq!(mismatches, 0, "PJRT and native paths disagree");
    println!("\nNote: on this sparse workload the native merge-dot path wins —");
    println!("exactly the paper's §2 point about sparse dot products. The PJRT");
    println!("path exists for dense/medium-dim data and as the TPU hook.");
}
