//! Uniform random seeding: k distinct rows, nearly free (§6 of the paper:
//! "The uniform initialization is nearly instantaneous"). Needs only the
//! row count, so it never touches the data — in-memory or on-disk.

use crate::util::rng::Xoshiro256;

pub(crate) fn choose(rows: usize, k: usize, rng: &mut Xoshiro256) -> Vec<usize> {
    rng.sample_distinct(rows, k)
}
