//! Per-iteration instrumentation. Fig. 1 of the paper plots the number of
//! similarity computations and the run time of every iteration; this module
//! records exactly those series for every algorithm run, plus (under the
//! `trace` feature) the per-phase wall-clock breakdown of every iteration
//! — see [`crate::obs`].

use crate::obs::PhaseTimes;

/// Counters for a single k-means iteration.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct IterStats {
    /// Point×center similarity computations (sparse·dense dots).
    pub sims_point_center: u64,
    /// Multiply-add operations spent inside point×center similarity
    /// computations — the kernel-layer cost model: the dense-transpose and
    /// gather backends charge `nnz(row)·k` per all-centers pass, the
    /// inverted-file backend only the postings actually walked (see
    /// [`crate::kmeans::kernel`]). `sims_point_center` counts similarities
    /// regardless of backend; this counter is what separates the backends'
    /// costs (`bench_kernel` plots the crossover).
    pub madds_point_center: u64,
    /// Center×center similarity computations (dense·dense dots), including
    /// the `p(j) = ⟨c, c'⟩` movement self-similarities.
    pub sims_center_center: u64,
    /// Points whose assignment changed this iteration.
    pub reassignments: u64,
    /// Points skipped entirely by the `l(i) ≥ s(a(i))` whole-loop test.
    pub loop_skips: u64,
    /// Per-center bound tests that pruned a similarity computation.
    pub bound_skips: u64,
    /// Query terms the bound-pruned kernel walked before its suffix bounds
    /// stopped the postings traversal. Zero unless the `Pruned` kernel ran;
    /// `prune_terms / sims_point_center · k` approximates the walked
    /// fraction of each query.
    pub prune_terms: u64,
    /// Centers the bound-pruned kernel re-scored exactly after the postings
    /// walk — every other center was eliminated by a MaxScore suffix upper
    /// bound. Zero unless the `Pruned` kernel ran.
    pub prune_survivors: u64,
    /// Wall time of the iteration in milliseconds.
    pub wall_ms: f64,
    /// Per-phase wall-clock breakdown of the iteration, recorded at the
    /// iteration barriers under the `trace` feature (all-zero without
    /// it). Like `wall_ms`, measured on the coordinating thread around
    /// the barriers — see [`crate::obs::span`].
    pub phases: PhaseTimes,
}

impl IterStats {
    /// Total similarity computations in this iteration.
    pub fn sims_total(&self) -> u64 {
        self.sims_point_center + self.sims_center_center
    }

    /// Fold another (shard-local) counter set into this one. All counters
    /// are exact integer sums, so the merged totals are identical for
    /// every shard grid and thread count. `wall_ms` is deliberately **not**
    /// summed: shard timings overlap under parallel execution, so the
    /// caller measures the iteration wall time around the whole barrier
    /// instead, and the same rule applies to the per-phase span table
    /// (`phases`), which is charged only by the coordinating thread.
    pub fn absorb(&mut self, shard: &IterStats) {
        self.sims_point_center += shard.sims_point_center;
        self.madds_point_center += shard.madds_point_center;
        self.sims_center_center += shard.sims_center_center;
        self.reassignments += shard.reassignments;
        self.loop_skips += shard.loop_skips;
        self.bound_skips += shard.bound_skips;
        self.prune_terms += shard.prune_terms;
        self.prune_survivors += shard.prune_survivors;
    }
}

/// Full instrumentation of one clustering run.
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    /// Per-iteration counters, index 0 = the initial full assignment pass.
    pub iters: Vec<IterStats>,
    /// Bytes of bound storage the algorithm allocated (paper §6 discusses
    /// the 2 GB Elkan bound matrix vs Hamerly's 44 MB).
    pub bound_bytes: usize,
    /// Phase time charged before the iteration loop: center seeding, and
    /// (out-of-core runs under `trace`) the run's shard-I/O total, which
    /// overlaps the assignment phases rather than adding to them. All-zero
    /// without the `trace` feature.
    pub pre: PhaseTimes,
}

impl RunStats {
    /// Total similarity computations across all iterations.
    pub fn total_sims(&self) -> u64 {
        self.iters.iter().map(|i| i.sims_total()).sum()
    }

    /// Total point×center similarity computations.
    pub fn total_point_center(&self) -> u64 {
        self.iters.iter().map(|i| i.sims_point_center).sum()
    }

    /// Total multiply-adds spent in point×center similarity kernels (the
    /// backend-sensitive cost — see [`IterStats::madds_point_center`]).
    pub fn total_madds(&self) -> u64 {
        self.iters.iter().map(|i| i.madds_point_center).sum()
    }

    /// Total query terms walked by the bound-pruned kernel (zero on the
    /// exhaustive backends) — see [`IterStats::prune_terms`].
    pub fn total_prune_terms(&self) -> u64 {
        self.iters.iter().map(|i| i.prune_terms).sum()
    }

    /// Total centers the bound-pruned kernel re-scored exactly (zero on
    /// the exhaustive backends) — see [`IterStats::prune_survivors`].
    pub fn total_prune_survivors(&self) -> u64 {
        self.iters.iter().map(|i| i.prune_survivors).sum()
    }

    /// Total wall time in milliseconds (sum of iteration laps).
    pub fn total_ms(&self) -> f64 {
        self.iters.iter().map(|i| i.wall_ms).sum()
    }

    /// Number of iterations recorded (including the initial pass).
    pub fn iterations(&self) -> usize {
        self.iters.len()
    }

    /// Run-level per-phase wall-clock totals: the pre-loop spans
    /// (seeding, shard I/O) plus every iteration's table. All-zero
    /// without the `trace` feature. The barrier phases
    /// ([`PhaseTimes::barrier_ms`]) are disjoint and account for fit
    /// wall-clock; [`crate::obs::Phase::ShardIo`] overlaps them (see
    /// [`crate::obs::span`]).
    pub fn phase_totals(&self) -> PhaseTimes {
        let mut total = self.pre;
        for it in &self.iters {
            total.merge(&it.phases);
        }
        total
    }

    /// Cumulative similarity-computation series (Fig. 1b).
    pub fn cumulative_sims(&self) -> Vec<u64> {
        let mut acc = 0;
        self.iters
            .iter()
            .map(|i| {
                acc += i.sims_total();
                acc
            })
            .collect()
    }

    /// Cumulative run-time series in ms (Fig. 1d).
    pub fn cumulative_ms(&self) -> Vec<f64> {
        let mut acc = 0.0;
        self.iters
            .iter()
            .map(|i| {
                acc += i.wall_ms;
                acc
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_and_cumulative() {
        let mut s = RunStats::default();
        s.iters.push(IterStats {
            sims_point_center: 10,
            sims_center_center: 2,
            wall_ms: 1.0,
            ..Default::default()
        });
        s.iters.push(IterStats {
            sims_point_center: 5,
            sims_center_center: 1,
            wall_ms: 0.5,
            ..Default::default()
        });
        assert_eq!(s.total_sims(), 18);
        assert_eq!(s.total_point_center(), 15);
        assert_eq!(s.cumulative_sims(), vec![12, 18]);
        let cm = s.cumulative_ms();
        assert!((cm[1] - 1.5).abs() < 1e-12);
        assert_eq!(s.iterations(), 2);
    }

    #[test]
    fn shard_merge_equals_serial_counts() {
        // Property: folding any split of per-point counter increments into
        // per-shard accumulators and absorbing them in shard order yields
        // exactly the counters a single serial accumulator would hold.
        crate::util::prop::forall(200, 0x57A7, |g| {
            let shards = g.usize_in(1, 9);
            let mut serial = IterStats::default();
            let mut merged = IterStats::default();
            for _ in 0..shards {
                let part = IterStats {
                    sims_point_center: g.usize_in(0, 10_000) as u64,
                    madds_point_center: g.usize_in(0, 100_000) as u64,
                    sims_center_center: g.usize_in(0, 1_000) as u64,
                    reassignments: g.usize_in(0, 500) as u64,
                    loop_skips: g.usize_in(0, 500) as u64,
                    bound_skips: g.usize_in(0, 500) as u64,
                    prune_terms: g.usize_in(0, 2_000) as u64,
                    prune_survivors: g.usize_in(0, 2_000) as u64,
                    wall_ms: g.f64_in(0.0, 5.0),
                    phases: PhaseTimes::default(),
                };
                serial.sims_point_center += part.sims_point_center;
                serial.madds_point_center += part.madds_point_center;
                serial.sims_center_center += part.sims_center_center;
                serial.reassignments += part.reassignments;
                serial.loop_skips += part.loop_skips;
                serial.bound_skips += part.bound_skips;
                serial.prune_terms += part.prune_terms;
                serial.prune_survivors += part.prune_survivors;
                merged.absorb(&part);
            }
            assert_eq!(merged.sims_point_center, serial.sims_point_center);
            assert_eq!(merged.madds_point_center, serial.madds_point_center);
            assert_eq!(merged.sims_center_center, serial.sims_center_center);
            assert_eq!(merged.reassignments, serial.reassignments);
            assert_eq!(merged.loop_skips, serial.loop_skips);
            assert_eq!(merged.bound_skips, serial.bound_skips);
            assert_eq!(merged.prune_terms, serial.prune_terms);
            assert_eq!(merged.prune_survivors, serial.prune_survivors);
            assert_eq!(merged.sims_total(), serial.sims_total());
            // Overlapping shard wall clocks must not leak into the merge.
            assert_eq!(merged.wall_ms, 0.0);
            // Same rule for the per-phase span table.
            assert!(merged.phases.is_zero());
        });
    }

    #[test]
    fn phase_totals_sum_pre_and_iters() {
        use crate::obs::Phase;
        let mut s = RunStats::default();
        s.pre.add(Phase::Seeding, 3.0);
        let mut a = IterStats::default();
        a.phases.add(Phase::Assignment, 2.0);
        a.phases.add(Phase::Update, 1.0);
        let mut b = IterStats::default();
        b.phases.add(Phase::Assignment, 4.0);
        s.iters.push(a);
        s.iters.push(b);
        let t = s.phase_totals();
        assert_eq!(t.get(Phase::Seeding), 3.0);
        assert_eq!(t.get(Phase::Assignment), 6.0);
        assert_eq!(t.get(Phase::Update), 1.0);
        assert_eq!(t.barrier_ms(), 10.0);
    }
}
