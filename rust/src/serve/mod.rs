//! High-throughput nearest-center query serving over a persisted
//! [`Model`](crate::model::Model).
//!
//! Training answers "where are the centers?" once; production serving
//! answers "which center is this new document nearest?" millions of times
//! against frozen centers. This module is that second half: load a model,
//! build a [`QueryEngine`], and stream top-p cosine queries through it —
//! single documents or whole corpora sharded across the
//! [`crate::runtime::parallel`] Plan/Pool executor.
//!
//! # Two traversals, one answer
//!
//! * **Exhaustive gather** — `k` sparse×dense dots per query
//!   (`nnz(q)·k` multiply-adds), the same machinery the training
//!   variants charge for selective similarities. Always correct; the
//!   reference the pruned path is tested against.
//! * **MaxScore-pruned** ([`QueryEngine::top_p_pruned`]) — walks the
//!   query's terms through the inverted-file postings index
//!   ([`crate::sparse::InvertedIndex`]) in *descending contribution-bound
//!   order*, where each term's bound is `|q_c| · maxw[c]` and `maxw` is
//!   the per-dimension maximum absolute center weight (Turtle & Flood's
//!   MaxScore idea, carried from document retrieval to center retrieval).
//!   The suffix sum of unprocessed bounds caps every center's remaining
//!   similarity, so the walk stops as soon as the top-p *set* is decided;
//!   centers whose upper bound falls below the p-th best lower bound are
//!   skipped without ever being touched. Survivors are then re-scored
//!   with the exact same gather dot the exhaustive path uses.
//!
//! That re-scoring step is what makes the pruned path **bit-identical**
//! to exhaustive gather: bounds only ever decide *which* centers get an
//! exact score (a provable superset of the true top-p, with a
//! [`float-safety margin`](engine::BOUND_MARGIN)), never what the score
//! is. The `serve` test suite asserts identical `(center, similarity)`
//! lists across both traversals, every thread count, and random sparse
//! problems; `bench_serve` additionally asserts the pruned path performs
//! strictly fewer multiply-adds on sparse text models.
//!
//! Pruning is a wager on sparsity: on a *dense* model the bound pass can
//! walk nearly every posting and then re-score nearly every center,
//! costing more than the exhaustive pass it tried to avoid — which is
//! exactly why [`ServeMode::Auto`] (the default) resolves through the
//! kernel layer's density heuristic and serves dense models exhaustively.
//!
//! ```no_run
//! use sphkm::model::Model;
//! use sphkm::serve::{QueryEngine, ServeConfig};
//!
//! let model = Model::load(std::path::Path::new("news.spkm")).unwrap();
//! let engine = QueryEngine::new(model, &ServeConfig { threads: 0, ..Default::default() });
//! # let corpus = sphkm::data::synth::SynthConfig::small_demo().generate(1).matrix;
//! let (top, stats) = engine.top_p_batch(&corpus, 3);
//! println!("{} queries, {} madds", stats.queries, stats.madds);
//! println!("doc 0 best center: {:?}", top[0][0]);
//! ```
//!
//! # The serving daemon
//!
//! One-shot batches ([`QueryEngine`] behind `sphkm assign`) cover
//! offline workloads; the **daemon** ([`Daemon`], `sphkm serve`) is the
//! persistent shape: a TCP process answering newline-delimited
//! `sphkm.rpc.v1` JSON frames ([`rpc`]), sharding every client batch
//! onto the same Plan/Pool executor, and serving through a versioned
//! [`ModelSlot`] so a freshly trained `.spkm` can be **hot-swapped**
//! (explicit `reload` RPC, watched model path, or the background
//! mini-batch refit loop) without dropping or corrupting one in-flight
//! query. [`Client`] is the matching blocking client (`sphkm query`).
//! Swap semantics, the protocol grammar, and a full train → serve →
//! refit → swap walkthrough live in the README's "Serving daemon"
//! section.

pub mod client;
pub mod daemon;
pub mod engine;
pub mod rpc;
pub mod slot;

pub use client::{Client, ClientError};
pub use daemon::{Daemon, DaemonConfig, DaemonHandle, RefitConfig};
pub use engine::{QueryEngine, ServeConfig, ServeMode, ServeStats};
pub use rpc::{FrameReader, Reply, Request, MAX_FRAME_BYTES, RPC_SCHEMA};
pub use slot::{EpochEngine, ModelSlot};
