//! Process-memory introspection for CLI reporting: the peak resident
//! set size (`VmHWM`) read from `/proc/self/status`. Linux-only by
//! nature — on platforms without procfs the probe returns `None` and
//! callers simply omit the figure instead of failing.

use std::path::Path;

/// Peak resident set size of this process in **bytes** (`VmHWM`, the
/// high-water mark the kernel tracks since process start), or `None`
/// when the platform does not expose `/proc/self/status` or the field
/// cannot be parsed.
pub fn peak_rss_bytes() -> Option<u64> {
    parse_vm_hwm(&std::fs::read_to_string(Path::new("/proc/self/status")).ok()?)
}

/// Extract `VmHWM` (reported by the kernel in kB) from the text of
/// `/proc/self/status`.
fn parse_vm_hwm(status: &str) -> Option<u64> {
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest.trim().trim_end_matches("kB").trim().parse().ok()?;
            return Some(kb.saturating_mul(1024));
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_vm_hwm_line() {
        let status = "Name:\tsphkm\nVmPeak:\t  999 kB\nVmHWM:\t    1234 kB\nThreads:\t1\n";
        assert_eq!(parse_vm_hwm(status), Some(1234 * 1024));
        assert_eq!(parse_vm_hwm("Name:\tx\n"), None);
        assert_eq!(parse_vm_hwm("VmHWM:\tgarbage kB\n"), None);
    }

    #[test]
    #[cfg(target_os = "linux")]
    fn probe_reports_a_positive_peak_on_linux() {
        let rss = peak_rss_bytes().expect("procfs available on linux");
        assert!(rss > 0);
    }
}
