//! The six benchmark datasets of Table 1, as synthetic analogues at
//! configurable scale (see DESIGN.md §4 for the substitution rationale).
//!
//! | Paper dataset | Shape (full paper scale) | Analogue |
//! |---|---|---|
//! | DBLP Author-Conference | 1.84M × 5.2k, 0.056% | power-law bipartite graph, planted communities |
//! | DBLP Conference-Author | 5.2k × 1.84M | transpose of the above **before** TF-IDF |
//! | DBLP Author-Venue | 2.7M × 7.2k, 0.099% | denser bipartite graph |
//! | Simpsons Wiki | 10.1k × 12.9k, 0.463% | Zipf corpus, strong topics |
//! | 20 Newsgroups | 11.3k × 101.6k, 0.096% | Zipf corpus + anomalous junk docs |
//! | Reuters RCV-1 | 804k × 47.2k, 0.160% | large Zipf corpus |
//!
//! The defining *characteristics* — the rows:columns ratio, non-zeros per
//! row, Zipfian frequencies, and community/topic structure — are preserved;
//! the absolute scale is divided down so experiments complete on one core.

use super::synth::SynthConfig;
use super::tfidf::TfIdf;
use super::Dataset;
use crate::sparse::{CsrMatrix, SparseVec};
use crate::util::rng::{Xoshiro256, Zipf};

/// Dataset scale presets. All benchmark tables record which scale was used.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    /// Minimal: unit/integration tests (seconds).
    Tiny,
    /// Default for `cargo bench` (minutes on one core).
    Small,
    /// Closer to paper shape (tens of minutes).
    Medium,
}

impl Scale {
    /// Multiplier applied to the Small preset's row counts.
    pub fn factor(&self) -> f64 {
        match self {
            Scale::Tiny => 0.12,
            Scale::Small => 1.0,
            Scale::Medium => 4.0,
        }
    }

    /// Name for reports.
    pub fn name(&self) -> &'static str {
        match self {
            Scale::Tiny => "tiny",
            Scale::Small => "small",
            Scale::Medium => "medium",
        }
    }
}

impl std::str::FromStr for Scale {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "tiny" => Ok(Scale::Tiny),
            "small" => Ok(Scale::Small),
            "medium" => Ok(Scale::Medium),
            other => Err(format!("unknown scale: {other} (tiny|small|medium)")),
        }
    }
}

fn scaled(n: usize, scale: Scale) -> usize {
    ((n as f64 * scale.factor()) as usize).max(8)
}

/// Configuration of the DBLP-like bipartite graph generator:
/// `authors × venues` publication-count matrix with power-law paper counts
/// and planted communities.
#[derive(Debug, Clone)]
pub struct BipartiteConfig {
    /// Number of authors (rows of the count matrix).
    pub authors: usize,
    /// Number of venues (columns).
    pub venues: usize,
    /// Number of planted communities.
    pub communities: usize,
    /// Power-law exponent for per-author paper counts (most authors have
    /// one paper — the paper notes DBLP is "very sparse" for this reason).
    pub papers_exponent: f64,
    /// Maximum papers for a single author.
    pub papers_max: usize,
    /// Probability a paper lands in the author's community venues.
    pub affinity: f64,
    /// Zipf exponent for venue popularity.
    pub zipf_s: f64,
}

impl BipartiteConfig {
    /// Generate the raw count matrix plus author community labels.
    pub fn generate_counts(&self, seed: u64) -> (CsrMatrix, Vec<u32>) {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let per_comm = (self.venues / self.communities).max(1);
        let comm_zipf = Zipf::new(per_comm, self.zipf_s);
        let global_zipf = Zipf::new(self.venues, self.zipf_s);
        // Power-law paper counts via inverse-CDF sampling on ranks.
        let paper_dist = Zipf::new(self.papers_max, self.papers_exponent);

        let mut rows = Vec::with_capacity(self.authors);
        let mut labels = Vec::with_capacity(self.authors);
        for _ in 0..self.authors {
            let comm = rng.index(self.communities);
            let papers = paper_dist.sample(&mut rng) + 1;
            let mut pairs: Vec<(u32, f32)> = Vec::with_capacity(papers);
            for _ in 0..papers {
                let venue = if rng.next_f64() < self.affinity {
                    (comm * per_comm + comm_zipf.sample(&mut rng)).min(self.venues - 1)
                } else {
                    global_zipf.sample(&mut rng)
                };
                pairs.push((venue as u32, 1.0));
            }
            rows.push(SparseVec::from_pairs(self.venues, pairs));
            labels.push(comm as u32);
        }
        (CsrMatrix::from_rows(self.venues, &rows), labels)
    }
}

fn dblp_config(venues: usize, papers_max: usize) -> BipartiteConfig {
    BipartiteConfig {
        authors: 0, // set by caller
        venues,
        communities: 40,
        papers_exponent: 2.2,
        papers_max,
        affinity: 0.8,
        zipf_s: 1.05,
    }
}

/// DBLP Author-Conference analogue: many rows, few columns, ~3 nnz/row.
pub fn dblp_author_conf(scale: Scale, seed: u64) -> Dataset {
    let mut cfg = dblp_config(scaled(1200, scale), 8);
    cfg.authors = scaled(40_000, scale);
    let (counts, labels) = cfg.generate_counts(seed);
    Dataset {
        name: "DBLP Author-Conf.".into(),
        matrix: TfIdf::default().apply(&counts),
        labels: Some(labels),
    }
}

/// DBLP Conference-Author analogue: the transpose of the author-conference
/// counts **before** TF-IDF (exactly as the paper constructs it — the
/// semantics differ because TF-IDF is applied after transposition).
pub fn dblp_conf_author(scale: Scale, seed: u64) -> Dataset {
    let mut cfg = dblp_config(scaled(1200, scale), 8);
    cfg.authors = scaled(40_000, scale);
    let (counts, _) = cfg.generate_counts(seed);
    // Venues with no papers at this scale cannot be normalized: drop them
    // (the paper's real data has no author-less conferences either).
    let (transposed, kept) = counts.transpose().drop_empty_rows();
    // Venue labels: the community block the venue belongs to.
    let per_comm = (cfg.venues / cfg.communities).max(1);
    let labels: Vec<u32> = kept
        .iter()
        .map(|&v| ((v / per_comm).min(cfg.communities - 1)) as u32)
        .collect();
    Dataset {
        name: "DBLP Conf.-Author".into(),
        matrix: TfIdf::default().apply(&transposed),
        labels: Some(labels),
    }
}

/// DBLP Author-Venue analogue: larger and denser (journals included).
pub fn dblp_author_venue(scale: Scale, seed: u64) -> Dataset {
    let mut cfg = dblp_config(scaled(1600, scale), 20);
    cfg.authors = scaled(55_000, scale);
    cfg.papers_exponent = 1.9; // more papers per author
    let (counts, labels) = cfg.generate_counts(seed);
    Dataset {
        name: "DBLP Author-Venue".into(),
        matrix: TfIdf::default().apply(&counts),
        labels: Some(labels),
    }
}

/// Simpsons Wiki analogue: small domain-specific corpus, relatively dense.
pub fn simpsons_wiki(scale: Scale, seed: u64) -> Dataset {
    SynthConfig {
        name: "Simpsons Wiki".into(),
        n_docs: scaled(2_000, scale),
        vocab: scaled(4_000, scale).max(1000),
        topics: 12,
        doc_len_mean: 80.0,
        doc_len_sigma: 0.6,
        topic_strength: 0.6,
        shared_vocab_frac: 0.3,
        zipf_s: 1.1,
        anomaly_frac: 0.0,
        tfidf: TfIdf::default(),
    }
    .generate(seed)
}

/// 20 Newsgroups analogue: high-dimensional, sparse, **with anomalous junk
/// documents** (the paper attributes k-means++'s poor Table 2 showing on
/// 20news to such anomalies, so the analogue plants them).
pub fn newsgroups(scale: Scale, seed: u64) -> Dataset {
    SynthConfig {
        name: "20 Newsgroups".into(),
        n_docs: scaled(2_200, scale),
        vocab: scaled(20_000, scale).max(4000),
        topics: 20,
        doc_len_mean: 120.0,
        doc_len_sigma: 0.8,
        topic_strength: 0.5,
        shared_vocab_frac: 0.25,
        zipf_s: 1.05,
        anomaly_frac: 0.04,
        tfidf: TfIdf::default(),
    }
    .generate(seed)
}

/// Reuters RCV-1 analogue: the largest corpus, density between Simpsons
/// and 20news.
pub fn rcv1(scale: Scale, seed: u64) -> Dataset {
    SynthConfig {
        name: "RCV-1".into(),
        n_docs: scaled(12_000, scale),
        vocab: scaled(10_000, scale).max(3000),
        topics: 30,
        doc_len_mean: 110.0,
        doc_len_sigma: 0.7,
        topic_strength: 0.55,
        shared_vocab_frac: 0.3,
        zipf_s: 1.08,
        anomaly_frac: 0.0,
        tfidf: TfIdf::default(),
    }
    .generate(seed)
}

/// All six Table 1 datasets in paper order.
pub fn paper_datasets(scale: Scale, seed: u64) -> Vec<Dataset> {
    vec![
        dblp_author_conf(scale, seed),
        dblp_conf_author(scale, seed),
        dblp_author_venue(scale, seed ^ 1),
        simpsons_wiki(scale, seed ^ 2),
        newsgroups(scale, seed ^ 3),
        rcv1(scale, seed ^ 4),
    ]
}

/// Look one dataset up by (fuzzy) name.
pub fn by_name(name: &str, scale: Scale, seed: u64) -> Option<Dataset> {
    let n = name.to_ascii_lowercase().replace(['-', '_', ' '], "");
    Some(match n.as_str() {
        "dblpauthorconf" | "authorconf" | "dblpac" => dblp_author_conf(scale, seed),
        "dblpconfauthor" | "confauthor" | "dblpca" => dblp_conf_author(scale, seed),
        "dblpauthorvenue" | "authorvenue" | "dblpav" => dblp_author_venue(scale, seed),
        "simpsons" | "simpsonswiki" => simpsons_wiki(scale, seed),
        "20news" | "newsgroups" | "20newsgroups" => newsgroups(scale, seed),
        "rcv1" | "reuters" => rcv1(scale, seed),
        "smalldemo" | "demo" => SynthConfig::small_demo().generate(seed),
        _ => return None,
    })
}

/// Names accepted by [`by_name`], for CLI help.
pub const DATASET_NAMES: [&str; 7] = [
    "author-conf",
    "conf-author",
    "author-venue",
    "simpsons",
    "20news",
    "rcv1",
    "demo",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bipartite_counts_shape_and_sparsity() {
        let cfg = BipartiteConfig {
            authors: 2000,
            venues: 120,
            communities: 10,
            papers_exponent: 2.2,
            papers_max: 8,
            affinity: 0.8,
            zipf_s: 1.05,
        };
        let (counts, labels) = cfg.generate_counts(1);
        assert_eq!(counts.rows(), 2000);
        assert_eq!(counts.cols(), 120);
        assert_eq!(labels.len(), 2000);
        let nnz_per_row = counts.nnz() as f64 / 2000.0;
        assert!(
            (1.0..5.0).contains(&nnz_per_row),
            "nnz/row {nnz_per_row} out of DBLP-like range"
        );
    }

    #[test]
    fn tiny_datasets_have_expected_shape_relations() {
        let seed = 3;
        let ac = dblp_author_conf(Scale::Tiny, seed);
        let ca = dblp_conf_author(Scale::Tiny, seed);
        // Transposed pair: dimensions swap (conf-author may drop a few
        // empty venue rows).
        assert_eq!(ac.matrix.rows(), ca.matrix.cols());
        assert!(ca.matrix.rows() <= ac.matrix.cols());
        assert!(ca.matrix.rows() >= ac.matrix.cols() / 2);
        assert!(ac.matrix.rows() > ac.matrix.cols(), "author-conf is tall");
        assert!(ca.matrix.cols() > ca.matrix.rows(), "conf-author is wide");
        let ng = newsgroups(Scale::Tiny, seed);
        assert!(ng.matrix.cols() > simpsons_wiki(Scale::Tiny, seed).matrix.cols());
    }

    #[test]
    fn all_rows_normalized_all_datasets() {
        for ds in paper_datasets(Scale::Tiny, 7) {
            let mut zero_rows = 0;
            for r in 0..ds.matrix.rows() {
                let n = ds.matrix.row(r).norm_sq();
                if n == 0.0 {
                    zero_rows += 1;
                } else {
                    assert!((n - 1.0).abs() < 1e-4, "{}: row {r} norm² {n}", ds.name);
                }
            }
            // TF-IDF can zero a row only if all its terms appear everywhere
            // (plain IDF); with smooth IDF this should never happen.
            assert_eq!(zero_rows, 0, "{} has zero rows", ds.name);
        }
    }

    #[test]
    fn by_name_resolves_all_aliases() {
        for name in DATASET_NAMES {
            assert!(
                by_name(name, Scale::Tiny, 1).is_some(),
                "unresolved dataset {name}"
            );
        }
        assert!(by_name("nope", Scale::Tiny, 1).is_none());
    }

    #[test]
    fn scale_ordering() {
        let t = dblp_author_conf(Scale::Tiny, 1);
        let s = dblp_author_conf(Scale::Small, 1);
        assert!(t.matrix.rows() < s.matrix.rows());
    }
}
