//! TF-IDF weighting. The paper applies TF-IDF to every dataset before
//! clustering (§6); the 20 Newsgroups analogue uses scikit-learn's default
//! smooth-IDF formula, so both variants are provided.

use crate::sparse::CsrMatrix;

/// IDF formula selection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IdfScheme {
    /// `ln(N / df)` — the classic formula.
    Plain,
    /// `ln((1 + N) / (1 + df)) + 1` — scikit-learn's default (`smooth_idf`),
    /// used for the 20 Newsgroups analogue.
    Smooth,
}

/// TF-IDF transformer configuration.
#[derive(Debug, Clone, Copy)]
pub struct TfIdf {
    /// IDF formula.
    pub scheme: IdfScheme,
    /// Use `1 + ln(tf)` instead of raw term frequency.
    pub sublinear_tf: bool,
    /// L2-normalize rows afterwards (required for spherical k-means).
    pub normalize: bool,
}

impl Default for TfIdf {
    fn default() -> Self {
        Self {
            scheme: IdfScheme::Smooth,
            sublinear_tf: false,
            normalize: true,
        }
    }
}

impl TfIdf {
    /// Compute document frequencies per column.
    pub fn document_frequencies(counts: &CsrMatrix) -> Vec<u32> {
        let mut df = vec![0u32; counts.cols()];
        for r in 0..counts.rows() {
            for &c in counts.row(r).indices {
                df[c as usize] += 1;
            }
        }
        df
    }

    /// IDF value for a document frequency.
    pub fn idf(&self, n_docs: usize, df: u32) -> f64 {
        match self.scheme {
            IdfScheme::Plain => {
                if df == 0 {
                    0.0
                } else {
                    (n_docs as f64 / df as f64).ln()
                }
            }
            IdfScheme::Smooth => ((1.0 + n_docs as f64) / (1.0 + df as f64)).ln() + 1.0,
        }
    }

    /// Apply TF-IDF (and row normalization) to a raw count matrix.
    pub fn apply(&self, counts: &CsrMatrix) -> CsrMatrix {
        let n = counts.rows();
        let df = Self::document_frequencies(counts);
        let idf: Vec<f64> = df.iter().map(|&d| self.idf(n, d)).collect();
        let mut rows = Vec::with_capacity(n);
        for r in 0..n {
            let view = counts.row(r);
            let mut idx = Vec::with_capacity(view.nnz());
            let mut val = Vec::with_capacity(view.nnz());
            for (t, &c) in view.indices.iter().enumerate() {
                let tf = view.values[t] as f64;
                let tf = if self.sublinear_tf && tf > 0.0 {
                    1.0 + tf.ln()
                } else {
                    tf
                };
                let w = tf * idf[c as usize];
                if w != 0.0 {
                    idx.push(c);
                    val.push(w as f32);
                }
            }
            rows.push(crate::sparse::SparseVec::new(counts.cols(), idx, val));
        }
        let mut out = CsrMatrix::from_rows(counts.cols(), &rows);
        if self.normalize {
            out.normalize_rows();
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::SparseVec;

    fn counts() -> CsrMatrix {
        // 3 docs, 4 terms. Term 0 appears in all docs, term 3 in one.
        let rows = vec![
            SparseVec::from_pairs(4, vec![(0, 2.0), (1, 1.0)]),
            SparseVec::from_pairs(4, vec![(0, 1.0), (2, 3.0)]),
            SparseVec::from_pairs(4, vec![(0, 1.0), (3, 5.0)]),
        ];
        CsrMatrix::from_rows(4, &rows)
    }

    #[test]
    fn document_frequencies_counted() {
        let df = TfIdf::document_frequencies(&counts());
        assert_eq!(df, vec![3, 1, 1, 1]);
    }

    #[test]
    fn plain_idf_zeroes_ubiquitous_terms() {
        let t = TfIdf { scheme: IdfScheme::Plain, sublinear_tf: false, normalize: false };
        let m = t.apply(&counts());
        // Term 0 appears in every doc: idf = ln(3/3) = 0 ⇒ weight dropped.
        for r in 0..3 {
            assert!(!m.row(r).indices.contains(&0), "row {r} kept a zero weight");
        }
        // Term 3 in doc 2: weight = 5 · ln 3.
        let w = m.row(2).values[0] as f64;
        assert!((w - 5.0 * 3f64.ln()).abs() < 1e-5);
    }

    #[test]
    fn smooth_idf_matches_sklearn_formula() {
        let t = TfIdf::default();
        assert!((t.idf(3, 1) - ((4.0f64 / 2.0).ln() + 1.0)).abs() < 1e-12);
        assert!((t.idf(3, 3) - ((4.0f64 / 4.0).ln() + 1.0)).abs() < 1e-12);
    }

    #[test]
    fn normalized_rows_are_unit() {
        let t = TfIdf::default();
        let m = t.apply(&counts());
        for r in 0..m.rows() {
            let n = m.row(r).norm_sq();
            assert!((n - 1.0).abs() < 1e-5, "row {r} norm² = {n}");
        }
    }

    #[test]
    fn sublinear_tf_dampens() {
        let lin = TfIdf { scheme: IdfScheme::Smooth, sublinear_tf: false, normalize: false };
        let sub = TfIdf { scheme: IdfScheme::Smooth, sublinear_tf: true, normalize: false };
        let a = lin.apply(&counts());
        let b = sub.apply(&counts());
        // tf=5 → 1+ln5 ≈ 2.61 < 5.
        let wa = a.row(2).values.iter().cloned().fold(f32::MIN, f32::max);
        let wb = b.row(2).values.iter().cloned().fold(f32::MIN, f32::max);
        assert!(wb < wa);
    }
}
