//! Bounded-memory libsvm → shard-store converter.
//!
//! [`convert_libsvm_to_shards`] streams an SVMlight/libsvm text file into
//! the binary CSR shard format of [`crate::sparse::chunked`] without ever
//! materializing the matrix: transient memory is one text line, one row of
//! `(index, value)` pairs, and the set of **distinct** label values — so a
//! corpus far larger than RAM converts in a single pass. The parse and
//! per-row validation are the exact helpers behind
//! [`read_libsvm`](crate::data::io::read_libsvm), so the converter accepts
//! and rejects exactly the same files, and the optional unit-normalization
//! shares its arithmetic with [`CsrMatrix::normalize_rows`] — the two
//! ingestion pipelines produce bit-identical rows.
//!
//! # How the single pass works
//!
//! The shard header needs `rows`/`cols`/`nnz`, and the 0-vs-1-based index
//! auto-detection needs the full file — both known only at the end. The
//! converter therefore streams three sibling temp files (running row
//! pointers, raw unshifted indices, values) plus the raw labels, then
//! assembles the final store in one buffered concatenation that applies
//! the index-base shift per `u32` and folds the FNV-1a checksum as it
//! copies. Temp files are deleted afterwards.
//!
//! If every row carried a label, a `<output>.labels` text sidecar is
//! written with one dense class id per line, remapped in ascending numeric
//! order — the same ids [`read_libsvm`](crate::data::io::read_libsvm)
//! returns — so quality metrics (NMI etc.) work on the out-of-core path.

use super::io::{parse_libsvm_line, validate_row_pairs, IoError, ParsedLine};
use crate::sparse::chunked::{HashWrite, SHARD_MAGIC, SHARD_VERSION};
use crate::sparse::normalize_row_values;
use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};

/// Summary of a completed conversion.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConvertReport {
    /// Rows written to the store.
    pub rows: usize,
    /// Column count (after 0/1-based auto-detection).
    pub cols: usize,
    /// Stored non-zeros.
    pub nnz: usize,
    /// True when every row carried a label and the `.labels` sidecar was
    /// written.
    pub labeled: bool,
    /// Rows that could not be unit-normalized (all-zero); 0 when
    /// `normalize` was off.
    pub normalize_failures: usize,
}

/// Path of the labels sidecar for a shard store at `output`.
pub fn labels_sidecar_path(output: &Path) -> PathBuf {
    let mut os = output.as_os_str().to_owned();
    os.push(".labels");
    PathBuf::from(os)
}

/// Read a `.labels` sidecar (one dense class id per line).
pub fn read_labels_sidecar(path: &Path) -> Result<Vec<u32>, IoError> {
    let reader = BufReader::new(File::open(path)?);
    let mut out = Vec::new();
    for (lno, line) in reader.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() {
            continue;
        }
        out.push(t.parse::<u32>().map_err(|_| IoError::Parse {
            line: lno + 1,
            msg: format!("bad label id {t:?}"),
        })?);
    }
    Ok(out)
}

/// Stream a libsvm file at `input` into a shard store at `output` in
/// bounded memory (see the [module docs](self)). With `normalize`, every
/// row is unit-normalized as it streams through — bit-identical to
/// loading with [`read_libsvm`](crate::data::io::read_libsvm) and calling
/// [`CsrMatrix::normalize_rows`].
///
/// [`CsrMatrix::normalize_rows`]: crate::sparse::CsrMatrix::normalize_rows
pub fn convert_libsvm_to_shards(
    input: &Path,
    output: &Path,
    normalize: bool,
) -> Result<ConvertReport, IoError> {
    let reader = BufReader::new(File::open(input)?);
    convert_libsvm_reader_to_shards(reader, output, normalize)
}

/// [`convert_libsvm_to_shards`] over any [`BufRead`] (the path-based entry
/// point opens the file and delegates here).
pub fn convert_libsvm_reader_to_shards<R: BufRead>(
    mut reader: R,
    output: &Path,
    normalize: bool,
) -> Result<ConvertReport, IoError> {
    let tmp = |suffix: &str| -> PathBuf {
        let mut os = output.as_os_str().to_owned();
        os.push(".tmp.");
        os.push(suffix);
        PathBuf::from(os)
    };
    let (t_indptr, t_indices, t_values, t_labels) =
        (tmp("indptr"), tmp("indices"), tmp("values"), tmp("labels"));
    let result = (|| -> Result<ConvertReport, IoError> {
        let mut w_indptr = BufWriter::new(File::create(&t_indptr)?);
        let mut w_indices = BufWriter::new(File::create(&t_indices)?);
        let mut w_values = BufWriter::new(File::create(&t_values)?);
        let mut w_labels = BufWriter::new(File::create(&t_labels)?);

        let mut rows = 0usize;
        let mut running = 0u64; // stored nnz so far
        let mut saw_zero = false;
        let mut max_idx = 0u32;
        let mut all_labeled = true;
        // Distinct label values, sorted — O(distinct classes) memory, the
        // only state that grows with content rather than line length.
        let mut distinct: Vec<f64> = Vec::new();
        let mut normalize_failures = 0usize;

        let mut line = String::new();
        let mut pairs: Vec<(u32, f32)> = Vec::new();
        let mut vals: Vec<f32> = Vec::new();
        let mut lno = 0usize;
        loop {
            line.clear();
            if reader.read_line(&mut line)? == 0 {
                break;
            }
            lno += 1;
            pairs.clear();
            let label = match parse_libsvm_line(&line, lno, &mut pairs)? {
                ParsedLine::Skip => continue,
                ParsedLine::Row { label } => label,
            };
            // Column-space detection over the raw pairs, before explicit
            // zeros are dropped — same rule as the in-memory reader.
            for &(i, _) in &pairs {
                saw_zero |= i == 0;
                max_idx = max_idx.max(i);
            }
            validate_row_pairs(&mut pairs, lno)?;
            vals.clear();
            vals.extend(pairs.iter().map(|p| p.1));
            if normalize && !normalize_row_values(&mut vals) {
                normalize_failures += 1;
            }
            for (&(i, _), &v) in pairs.iter().zip(&vals) {
                w_indices.write_all(&i.to_le_bytes())?;
                w_values.write_all(&v.to_le_bytes())?;
            }
            running += pairs.len() as u64;
            w_indptr.write_all(&running.to_le_bytes())?;
            all_labeled &= label.is_some();
            let l = label.unwrap_or(0.0);
            w_labels.write_all(&l.to_le_bytes())?;
            if all_labeled {
                if let Err(pos) = distinct.binary_search_by(|x| x.total_cmp(&l)) {
                    distinct.insert(pos, l);
                }
            }
            rows += 1;
        }
        w_indptr.flush()?;
        w_indices.flush()?;
        w_values.flush()?;
        w_labels.flush()?;
        drop((w_indptr, w_indices, w_values, w_labels));

        let nnz = usize::try_from(running).expect("nnz fits usize");
        let offset: u32 = if saw_zero { 0 } else { 1 };
        let cols = usize::try_from((max_idx as u64 + 1).saturating_sub(offset as u64))
            .expect("column count fits usize")
            .max(1);

        // Assemble the store: header, 0-prefixed row pointers, indices
        // (base-shifted per u32), values — all hashed as they stream.
        let mut out = HashWrite::new(BufWriter::new(File::create(output)?));
        out.put(&SHARD_MAGIC)?;
        out.put(&SHARD_VERSION.to_le_bytes())?;
        out.put(&0u32.to_le_bytes())?;
        out.put(&(rows as u64).to_le_bytes())?;
        out.put(&(cols as u64).to_le_bytes())?;
        out.put(&(nnz as u64).to_le_bytes())?;
        out.put(&0u64.to_le_bytes())?;
        copy_hashed(&t_indptr, &mut out, 8 * rows as u64, 0)?;
        copy_hashed(&t_indices, &mut out, 4 * nnz as u64, offset)?;
        copy_hashed(&t_values, &mut out, 4 * nnz as u64, 0)?;
        let hash = out.hash;
        let mut inner = out.w;
        inner.write_all(&hash.to_le_bytes())?;
        inner.flush()?;
        drop(inner);

        if all_labeled && rows > 0 {
            let mut r = BufReader::new(File::open(&t_labels)?);
            let mut w = BufWriter::new(File::create(labels_sidecar_path(output))?);
            let mut b = [0u8; 8];
            for _ in 0..rows {
                r.read_exact(&mut b)?;
                let l = f64::from_le_bytes(b);
                let id = distinct
                    .binary_search_by(|x| x.total_cmp(&l))
                    .expect("label seen during the pass");
                writeln!(w, "{id}")?;
            }
            w.flush()?;
        }

        Ok(ConvertReport {
            rows,
            cols,
            nnz,
            labeled: all_labeled && rows > 0,
            normalize_failures,
        })
    })();
    for t in [&t_indptr, &t_indices, &t_values, &t_labels] {
        let _ = std::fs::remove_file(t);
    }
    result
}

/// Stream `len` bytes from `src` into the hashing writer in 64 KiB
/// chunks; a nonzero `index_offset` reinterprets the stream as LE u32s
/// and subtracts the offset from each (the 1-based → 0-based shift).
fn copy_hashed<W: Write>(
    src: &Path,
    out: &mut HashWrite<W>,
    len: u64,
    index_offset: u32,
) -> Result<(), IoError> {
    let mut r = File::open(src)?;
    let mut buf = vec![0u8; 1 << 16];
    let mut remaining = len;
    while remaining > 0 {
        let take = (buf.len() as u64).min(remaining) as usize;
        r.read_exact(&mut buf[..take])?;
        if index_offset != 0 {
            for c in buf[..take].chunks_exact_mut(4) {
                let shifted =
                    u32::from_le_bytes(c.try_into().expect("4 bytes")) - index_offset;
                c.copy_from_slice(&shifted.to_le_bytes());
            }
        }
        out.put(&buf[..take])?;
        remaining -= take as u64;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::io::{read_libsvm, write_libsvm};
    use crate::data::synth::SynthConfig;
    use crate::sparse::{RowSource, ShardStore};

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("sphkm-convert-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn converted_store_matches_in_memory_reader_bit_for_bit() {
        let ds = SynthConfig::small_demo().generate(21);
        let svm = tmp("conv.svm");
        write_libsvm(&svm, &ds.matrix, ds.labels.as_deref()).unwrap();
        let sks = tmp("conv.sks");
        let report = convert_libsvm_to_shards(&svm, &sks, false).unwrap();
        let (m, labels) = read_libsvm(&svm).unwrap();
        assert_eq!(report.rows, m.rows());
        assert_eq!(report.cols, m.cols());
        assert_eq!(report.nnz, m.nnz());
        assert!(report.labeled);
        let store = ShardStore::open(&sks).unwrap().with_chunk_rows(7);
        store.verify().unwrap();
        let mut cur = RowSource::from(&store).cursor();
        for i in 0..m.rows() {
            assert_eq!(m.row(i).indices, cur.row(i).indices, "row {i}");
            assert_eq!(m.row(i).values, cur.row(i).values, "row {i}");
        }
        let sidecar = read_labels_sidecar(&labels_sidecar_path(&sks)).unwrap();
        assert_eq!(sidecar, labels.unwrap());
    }

    #[test]
    fn normalize_matches_in_memory_normalize_rows() {
        let ds = SynthConfig::small_demo().generate(22);
        let svm = tmp("norm.svm");
        write_libsvm(&svm, &ds.matrix, ds.labels.as_deref()).unwrap();
        let sks = tmp("norm.sks");
        let report = convert_libsvm_to_shards(&svm, &sks, true).unwrap();
        let (mut m, _) = read_libsvm(&svm).unwrap();
        let failures = m.normalize_rows();
        assert_eq!(report.normalize_failures, failures);
        let store = ShardStore::open(&sks).unwrap();
        let mut cur = RowSource::from(&store).cursor();
        for i in 0..m.rows() {
            assert_eq!(m.row(i).values, cur.row(i).values, "row {i}");
        }
    }

    #[test]
    fn unlabeled_input_writes_no_sidecar() {
        let sks = tmp("nolabel.sks");
        let text = "1:0.5 3:1.5\n2:2.0\n";
        let report =
            convert_libsvm_reader_to_shards(std::io::Cursor::new(text), &sks, false).unwrap();
        assert!(!report.labeled);
        assert_eq!(report.rows, 2);
        assert!(!labels_sidecar_path(&sks).exists());
        ShardStore::open(&sks).unwrap().verify().unwrap();
    }

    #[test]
    fn rejects_same_files_as_reader_and_cleans_temps() {
        let sks = tmp("bad.sks");
        for bad in ["1 3:1.0 3:2.0\n", "1 1:nan\n", "1 4294967296:1.0\n"] {
            assert!(
                convert_libsvm_reader_to_shards(std::io::Cursor::new(bad), &sks, false).is_err(),
                "{bad:?} must be rejected"
            );
        }
        let dir = sks.parent().unwrap();
        for e in std::fs::read_dir(dir).unwrap() {
            let name = e.unwrap().file_name().to_string_lossy().into_owned();
            assert!(!name.contains(".tmp."), "temp file {name} left behind");
        }
    }

    #[test]
    fn empty_input_yields_empty_store() {
        let sks = tmp("empty.sks");
        let report =
            convert_libsvm_reader_to_shards(std::io::Cursor::new(""), &sks, false).unwrap();
        assert_eq!(report.rows, 0);
        assert_eq!(report.nnz, 0);
        assert!(!report.labeled);
        let store = ShardStore::open(&sks).unwrap();
        assert_eq!(store.rows(), 0);
        store.verify().unwrap();
    }
}
