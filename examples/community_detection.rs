//! Community detection on a DBLP-like author–conference graph — the
//! workload the paper's §6 uses spherical k-means for ("Spherical k-means
//! clustering has been used successfully for community detection on such
//! data sets").
//!
//! Clusters authors by their conference profile, validates against the
//! planted communities, and shows the acceleration each variant achieves
//! over the standard algorithm on this tall-and-narrow matrix.
//!
//! ```text
//! cargo run --release --example community_detection -- [--scale small] [--k 40]
//! ```

// Example code favours readable literal casts; the workspace clippy
// warnings on those patterns are aimed at library code.
#![allow(clippy::cast_possible_truncation, clippy::float_cmp)]

use sphkm::data::datasets::{self, Scale};
use sphkm::init::{seed_centers, InitMethod};
use sphkm::kmeans::{SphericalKMeans, Variant};
use sphkm::metrics;
use sphkm::util::cli::Args;
use sphkm::util::timer::Stopwatch;

fn main() {
    let args = Args::from_env();
    let scale: Scale = args.get_or("scale", Scale::Small).unwrap_or(Scale::Small);
    let ds = datasets::dblp_author_conf(scale, 42);
    let k: usize = args.get_or("k", 40).unwrap_or(40);
    println!(
        "author–conference graph: {} authors × {} conferences, {:.3}% nnz, k={k}",
        ds.matrix.rows(),
        ds.matrix.cols(),
        ds.matrix.density() * 100.0
    );

    let init = seed_centers(&ds.matrix, k, &InitMethod::Uniform, 7);
    let mut standard_ms = 0.0;
    println!("\n{:<14} {:>9} {:>6} {:>14} {:>8}", "variant", "ms", "iters", "sims", "speedup");
    for variant in Variant::ALL {
        let sw = Stopwatch::start();
        let r = SphericalKMeans::new(k)
            .variant(variant)
            .warm_start_centers(init.centers.clone())
            .fit(&ds.matrix)
            .expect("valid configuration")
            .into_result();
        let ms = sw.ms();
        if variant == Variant::Standard {
            standard_ms = ms;
        }
        println!(
            "{:<14} {:>9.1} {:>6} {:>14} {:>7.2}x",
            variant.name(),
            ms,
            r.iterations,
            r.stats.total_point_center(),
            standard_ms / ms
        );
        if variant == Variant::Standard {
            if let Some(truth) = &ds.labels {
                println!(
                    "    community recovery: NMI={:.3} purity={:.3}",
                    metrics::nmi(&r.assignments, truth),
                    metrics::purity(&r.assignments, truth)
                );
            }
        }
    }
    println!("\n(all variants produce identical assignments — the speedup is free)");
}
