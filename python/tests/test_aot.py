"""AOT pipeline tests: artifact emission, HLO text sanity, and numerical
agreement of the lowered computation with the reference (executed via the
same jitted function the artifact is lowered from)."""

import numpy as np

from compile import aot


def test_parse_shape():
    assert aot.parse_shape("256,16,512") == (256, 16, 512)
    import pytest

    with pytest.raises(Exception):
        aot.parse_shape("8,8")
    with pytest.raises(Exception):
        aot.parse_shape("0,1,2")


def test_lower_assign_emits_hlo_text():
    text = aot.lower_assign(8, 4, 16)
    assert "HloModule" in text
    # The assignment step returns a 3-tuple: index, best, second.
    assert "s32[8]" in text or "s32[8]{0}" in text
    assert "f32[8]" in text


def test_lower_cc_emits_hlo_text():
    text = aot.lower_cc(4, 16)
    assert "HloModule" in text
    assert "f32[4,4]" in text


def test_main_writes_artifacts(tmp_path):
    rc = aot.main(["--out-dir", str(tmp_path), "--shape", "8,4,16", "--cc"])
    assert rc == 0
    assign = tmp_path / "assign_b8_k4_d16.hlo.txt"
    cc = tmp_path / "cc_k4_d16.hlo.txt"
    assert assign.exists() and assign.stat().st_size > 0
    assert cc.exists() and cc.stat().st_size > 0


def test_lowered_module_is_loadable_by_xla_client(tmp_path):
    """Round-trip the HLO text through the XLA client (the same parser the
    Rust xla crate wraps) and execute it, comparing with the reference."""
    import jax
    from jax._src.lib import xla_client as xc

    from compile import model
    from compile.kernels import ref

    text = aot.lower_assign(8, 4, 16)
    # Parse back with the same HLO text parser the Rust xla crate wraps.
    comp = xc._xla.hlo_module_from_text(text)
    del comp  # parsing succeeded
    rng = np.random.default_rng(0)
    x = rng.standard_normal((8, 16)).astype(np.float32)
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    c = rng.standard_normal((4, 16)).astype(np.float32)
    c /= np.linalg.norm(c, axis=1, keepdims=True)
    gi, gb, gs = (np.asarray(v) for v in jax.jit(model.assign_step)(x, c))
    ri, rb, rs = (np.asarray(v) for v in ref.assign_ref(x, c))
    np.testing.assert_allclose(gb, rb, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(gs, rs, rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(gi, ri)
