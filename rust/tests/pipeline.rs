//! Cross-module pipeline tests: data generation → seeding → clustering →
//! metrics → reporting, plus failure-injection on the I/O path.

// Bench and test targets favour readable literal casts and exact
// (bit-level) float assertions; the workspace clippy warnings on
// those patterns are aimed at library code.
#![allow(clippy::cast_possible_truncation, clippy::float_cmp)]

use sphkm::coordinator::report::Table;
use sphkm::data::datasets::{self, Scale};
use sphkm::data::synth::SynthConfig;
use sphkm::data::text::{demo_corpus, TextPipeline};
use sphkm::init::InitMethod;
use sphkm::kmeans::Variant;
use sphkm::metrics;
use sphkm::SphericalKMeans;

#[test]
fn clustering_recovers_planted_topics() {
    // Strong topic structure should be recoverable with NMI well above
    // chance by every variant.
    let mut cfg = SynthConfig::small_demo();
    cfg.topic_strength = 0.85;
    let ds = cfg.generate(3);
    let truth = ds.labels.as_ref().unwrap();
    for variant in [Variant::Standard, Variant::SimplifiedElkan, Variant::Yinyang] {
        let r = SphericalKMeans::new(8)
            .variant(variant)
            .init(InitMethod::KMeansPP { alpha: 1.0 })
            .seed(5)
            .fit(&ds.matrix)
            .unwrap();
        let nmi = metrics::nmi(r.assignments(), truth);
        assert!(
            nmi > 0.5,
            "{}: NMI {nmi} too low for strong planted topics",
            variant.name()
        );
    }
}

#[test]
fn text_pipeline_clusters_demo_corpus() {
    let docs = demo_corpus();
    let p = TextPipeline { min_df: 1, max_df_frac: 0.7, ..Default::default() };
    let (ds, vocab) = p.fit(&docs, "demo");
    assert!(!vocab.is_empty());
    // Three planted themes of six documents each. k-means is
    // init-sensitive on 18 points; take the best of a few seeds (what a
    // practitioner does) and require clean theme recovery.
    let truth: Vec<u32> = (0..18).map(|i| (i / 6) as u32).collect();
    let best_purity = (0..5)
        .map(|seed| {
            let r = SphericalKMeans::new(3)
                .variant(Variant::Elkan)
                .init(InitMethod::KMeansPP { alpha: 1.0 })
                .seed(seed)
                .fit(&ds.matrix)
                .unwrap();
            metrics::purity(r.assignments(), &truth)
        })
        .fold(0.0f64, f64::max);
    assert!(best_purity > 0.9, "theme purity {best_purity} too low");
}

#[test]
fn better_seeding_never_explodes_objective() {
    // k-means++/AFK-MC² objectives should be in the same ballpark as
    // uniform (Table 2: changes are a few percent).
    let ds = datasets::simpsons_wiki(Scale::Tiny, 9);
    let mut objectives = Vec::new();
    for init in InitMethod::paper_set() {
        let r = SphericalKMeans::new(10)
            .variant(Variant::SimplifiedHamerly)
            .init(init)
            .seed(13)
            .fit(&ds.matrix)
            .unwrap();
        objectives.push(r.objective());
    }
    let min = objectives.iter().cloned().fold(f64::MAX, f64::min);
    let max = objectives.iter().cloned().fold(f64::MIN, f64::max);
    assert!(
        max / min < 1.2,
        "objectives vary too much across seedings: {objectives:?}"
    );
}

#[test]
fn libsvm_round_trip_preserves_clustering() {
    let ds = SynthConfig::small_demo().generate(21);
    let dir = std::env::temp_dir().join("sphkm-pipe-tests");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("pipe.svm");
    sphkm::data::io::write_libsvm(&path, &ds.matrix, ds.labels.as_deref()).unwrap();
    let (mut loaded, labels) = sphkm::data::io::read_libsvm(&path).unwrap();
    loaded.normalize_rows();
    assert_eq!(labels.unwrap(), ds.labels.clone().unwrap());
    let est = SphericalKMeans::new(6).variant(Variant::SimplifiedElkan).seed(2);
    let a = est.fit(&ds.matrix).unwrap();
    // Column count may differ (trailing empty columns dropped) but the
    // geometry is identical, so the clustering must be too.
    let b = est.fit(&loaded).unwrap();
    assert_eq!(a.assignments(), b.assignments());
}

#[test]
fn io_failure_injection() {
    let dir = std::env::temp_dir().join("sphkm-pipe-tests");
    std::fs::create_dir_all(&dir).unwrap();
    // Truncated/corrupt files must error, not panic.
    let bad = dir.join("corrupt.svm");
    std::fs::write(&bad, "1 3:0.5 nonsense\n").unwrap();
    assert!(sphkm::data::io::read_libsvm(&bad).is_err());
    let bad_mtx = dir.join("corrupt.mtx");
    std::fs::write(&bad_mtx, "%%MatrixMarket matrix coordinate real general\n2 2 3\n1 1 1.0\n").unwrap();
    assert!(sphkm::data::io::read_matrix_market(&bad_mtx).is_err());
    // Nonexistent paths.
    assert!(sphkm::data::io::read_libsvm(std::path::Path::new("/no/such/file")).is_err());
}

#[test]
fn report_tables_render_all_experiments_shapes() {
    let mut t = Table::new(&["Data set", "Algorithm", "k=2"]);
    t.row(vec!["X".into(), "Standard".into(), "1,234".into()]);
    let rendered = t.render();
    assert!(rendered.contains("Standard"));
    let csv = t.to_csv();
    assert_eq!(csv.lines().count(), 2);
}

#[test]
fn max_iter_cap_reports_unconverged() {
    let ds = datasets::newsgroups(Scale::Tiny, 3);
    let r = SphericalKMeans::new(10)
        .variant(Variant::Standard)
        .seed(1)
        .max_iter(1)
        .fit(&ds.matrix)
        .unwrap();
    assert!(!r.converged());
    assert_eq!(r.iterations(), 1);
}

#[test]
fn objective_decreases_monotonically_iteration_to_iteration() {
    // Alternating optimization must never increase the objective: check by
    // capping max_iter progressively (each prefix of the run is a run).
    let ds = SynthConfig::small_demo().generate(33);
    let mut prev = f64::MAX;
    for cap in [1usize, 2, 4, 8, 32] {
        let r = SphericalKMeans::new(5)
            .variant(Variant::Standard)
            .seed(3)
            .max_iter(cap)
            .fit(&ds.matrix)
            .unwrap();
        assert!(
            r.objective() <= prev + 1e-9,
            "objective rose from {prev} to {} at cap {cap}",
            r.objective()
        );
        prev = r.objective();
        if r.converged() {
            break;
        }
    }
}
