//! CLI smoke tests: run the `sphkm` binary end-to-end as a subprocess.

// Bench and test targets favour readable literal casts and exact
// (bit-level) float assertions; the workspace clippy warnings on
// those patterns are aimed at library code.
#![allow(clippy::cast_possible_truncation, clippy::float_cmp)]

use std::process::Command;

fn sphkm() -> Command {
    Command::new(env!("CARGO_BIN_EXE_sphkm"))
}

#[test]
fn info_runs() {
    let out = sphkm().arg("info").output().expect("spawn");
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("Accelerating Spherical k-Means"));
    assert!(text.contains("Simp.Hamerly"));
}

#[test]
fn datasets_lists_table1() {
    let out = sphkm()
        .args(["datasets", "--scale", "tiny", "--seed", "1"])
        .output()
        .expect("spawn");
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for name in ["DBLP Author-Conf.", "Simpsons Wiki", "RCV-1"] {
        assert!(text.contains(name), "missing {name} in:\n{text}");
    }
}

#[test]
fn cluster_demo_with_stats_and_labels() {
    let out = sphkm()
        .args([
            "cluster", "--data", "demo", "--k", "6", "--algo", "hamerly",
            "--init", "kmeans++", "--seed", "3", "--stats", "--labels",
        ])
        .output()
        .expect("spawn");
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("converged=true"), "{text}");
    assert!(text.contains("NMI="), "{text}");
    assert!(text.contains("sims_pc"), "{text}");
}

#[test]
fn cluster_with_threads_flag() {
    // The sharded executor must plumb through the CLI; results are
    // thread-count invariant, so this only checks plumbing + convergence.
    let out = sphkm()
        .args([
            "cluster", "--data", "demo", "--k", "5", "--algo", "simp-hamerly",
            "--seed", "4", "--threads", "2",
        ])
        .output()
        .expect("spawn");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("threads=2"), "{text}");
    assert!(text.contains("converged=true"), "{text}");
}

#[test]
fn gen_then_cluster_file() {
    let dir = std::env::temp_dir().join("sphkm-cli-tests");
    std::fs::create_dir_all(&dir).unwrap();
    let file = dir.join("demo.svm");
    let out = sphkm()
        .args(["gen", "--data", "demo", "--out", file.to_str().unwrap(), "--seed", "5"])
        .output()
        .expect("spawn");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let out = sphkm()
        .args(["cluster", "--data", file.to_str().unwrap(), "--k", "4"])
        .output()
        .expect("spawn");
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("objective="));
}

#[test]
fn unknown_command_fails_with_usage() {
    let out = sphkm().arg("frobnicate").output().expect("spawn");
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("USAGE"));
}

#[test]
fn unknown_dataset_fails() {
    let out = sphkm()
        .args(["cluster", "--data", "not-a-dataset", "--k", "3"])
        .output()
        .expect("spawn");
    assert!(!out.status.success());
}

#[test]
fn cluster_with_minibatch_engine() {
    let out = sphkm()
        .args([
            "cluster", "--data", "demo", "--k", "5", "--seed", "2",
            "--minibatch", "--batch-size", "64", "--epochs", "4",
            "--truncate", "32", "--stats",
        ])
        .output()
        .expect("spawn");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("objective="), "{text}");
    assert!(text.contains("sims_pc"), "{text}");
}

#[test]
fn cluster_with_preinit_bounds() {
    let out = sphkm()
        .args([
            "cluster", "--data", "demo", "--k", "5", "--algo", "simp-elkan",
            "--init", "kmeans++", "--seed", "2", "--preinit",
        ])
        .output()
        .expect("spawn");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("converged=true"));
}

#[test]
fn cluster_with_kernel_flag() {
    // The similarity-kernel layer must plumb through the CLI; results are
    // kernel-invariant, so this checks plumbing, reporting, and rejection.
    for kernel in ["inverted", "dense", "gather", "auto"] {
        let out = sphkm()
            .args([
                "cluster", "--data", "demo", "--k", "5", "--algo", "standard",
                "--seed", "4", "--kernel", kernel,
            ])
            .output()
            .expect("spawn");
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        let text = String::from_utf8_lossy(&out.stdout);
        assert!(text.contains(&format!("kernel={kernel}")), "{text}");
        assert!(text.contains("kernel madds"), "{text}");
        assert!(text.contains("converged=true"), "{text}");
    }
    let out = sphkm()
        .args(["cluster", "--data", "demo", "--kernel", "bogus"])
        .output()
        .expect("spawn");
    assert!(!out.status.success(), "unknown kernel must be rejected");
}

#[test]
fn cluster_save_model_then_assign_end_to_end() {
    let dir = std::env::temp_dir().join("sphkm-cli-tests");
    std::fs::create_dir_all(&dir).unwrap();
    let data = dir.join("serve-corpus.svm");
    let model = dir.join("serve-corpus.spkm");
    let csv = dir.join("serve-top.csv");
    // gen (labeled) → cluster --save-model → assign, all as subprocesses.
    let out = sphkm()
        .args(["gen", "--data", "demo", "--out", data.to_str().unwrap(), "--seed", "8"])
        .output()
        .expect("spawn");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let out = sphkm()
        .args([
            "cluster", "--data", data.to_str().unwrap(), "--k", "6", "--algo",
            "standard", "--kernel", "gather", "--seed", "4",
            "--save-model", model.to_str().unwrap(),
        ])
        .output()
        .expect("spawn");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("[model]"), "{text}");
    assert!(
        text.contains("NMI="),
        "labeled input must report external quality unprompted: {text}"
    );
    let out = sphkm()
        .args([
            "assign", "--model", model.to_str().unwrap(), "--data",
            data.to_str().unwrap(), "--top", "3", "--threads", "2",
            "--out", csv.to_str().unwrap(),
        ])
        .output()
        .expect("spawn");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("queries/s"), "{text}");
    assert!(text.contains("NMI="), "labeled queries must report quality: {text}");
    let csv_text = std::fs::read_to_string(&csv).unwrap();
    assert!(csv_text.starts_with("row,rank,center,similarity"), "{csv_text}");
    assert!(csv_text.lines().count() > 3, "per-query top-p rows expected");
    // A corrupt model file must be rejected with a nonzero exit.
    let garbage = dir.join("garbage.spkm");
    std::fs::write(&garbage, b"not a model").unwrap();
    let out = sphkm()
        .args([
            "assign", "--model", garbage.to_str().unwrap(), "--data",
            data.to_str().unwrap(),
        ])
        .output()
        .expect("spawn");
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("error loading model"));
}

#[test]
fn cluster_resume_continues_a_saved_model() {
    let dir = std::env::temp_dir().join("sphkm-cli-tests");
    std::fs::create_dir_all(&dir).unwrap();
    let data = dir.join("resume-corpus.svm");
    let model = dir.join("resume-model.spkm");
    let out = sphkm()
        .args(["gen", "--data", "demo", "--out", data.to_str().unwrap(), "--seed", "6"])
        .output()
        .expect("spawn");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    // Train for only 1 iteration and persist the (unconverged) state.
    let out = sphkm()
        .args([
            "cluster", "--data", data.to_str().unwrap(), "--k", "5", "--algo",
            "simp-hamerly", "--seed", "4", "--max-iter", "1",
            "--save-model", model.to_str().unwrap(),
        ])
        .output()
        .expect("spawn");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("converged=false"), "{text}");
    // Resume from the file: k and engine come from the model; the run
    // finishes what the interrupted one started.
    let out = sphkm()
        .args([
            "cluster", "--data", data.to_str().unwrap(), "--seed", "4",
            "--resume", model.to_str().unwrap(),
        ])
        .output()
        .expect("spawn");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("resuming Simp.Hamerly model"), "{text}");
    assert!(text.contains("k=5"), "{text}");
    assert!(text.contains("converged=true"), "{text}");
    // Mini-batch models resume too, defaulting to the schedule persisted
    // in the file (batch size / truncation), with the engine inferred.
    let mb_model = dir.join("resume-mb.spkm");
    let out = sphkm()
        .args([
            "cluster", "--data", data.to_str().unwrap(), "--k", "4", "--seed", "9",
            "--minibatch", "--batch-size", "64", "--epochs", "2", "--truncate", "16",
            "--save-model", mb_model.to_str().unwrap(),
        ])
        .output()
        .expect("spawn");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let out = sphkm()
        .args([
            "cluster", "--data", data.to_str().unwrap(), "--seed", "9",
            "--resume", mb_model.to_str().unwrap(),
        ])
        .output()
        .expect("spawn");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("resuming minibatch model"), "{text}");
    assert!(text.contains("k=4"), "{text}");

    // A corrupt resume file is rejected with a nonzero exit.
    let garbage = dir.join("garbage-resume.spkm");
    std::fs::write(&garbage, b"not a model").unwrap();
    let out = sphkm()
        .args([
            "cluster", "--data", data.to_str().unwrap(),
            "--resume", garbage.to_str().unwrap(),
        ])
        .output()
        .expect("spawn");
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("error loading model"));
}

#[test]
fn sweep_runs_from_config_file() {
    let dir = std::env::temp_dir().join("sphkm-cli-tests");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("sweep.cfg");
    std::fs::write(
        &cfg,
        "dataset = demo\nscale = tiny\nks = 3\nvariants = standard, exponion\ninits = uniform\nreps = 1\nkernel = inverted\n",
    )
    .unwrap();
    let out = sphkm()
        .args(["sweep", "--config", cfg.to_str().unwrap()])
        .output()
        .expect("spawn");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("Exponion"), "{text}");
    assert!(text.contains("objective"), "{text}");
}

#[test]
fn sweep_rejects_bad_config() {
    let dir = std::env::temp_dir().join("sphkm-cli-tests");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("bad.cfg");
    std::fs::write(&cfg, "this is not a config\n").unwrap();
    let out = sphkm()
        .args(["sweep", "--config", cfg.to_str().unwrap()])
        .output()
        .expect("spawn");
    assert!(!out.status.success());
}
