//! Out-of-core integration: training from the chunked on-disk shard
//! store ([`ShardStore`]) must be **bit-for-bit identical** to training
//! from the in-memory [`CsrMatrix`] holding the same rows — assignments,
//! objective bits, and every center coordinate — for all seven exact
//! variants and the mini-batch engine, for thread counts {1, 0}, and for
//! chunk sizes from one row per chunk through the whole corpus in one
//! chunk. Save → resume round trips may *cross* backends freely: a run
//! interrupted in memory resumes from disk shards (and vice versa) onto
//! the uninterrupted trajectory.
//!
//! Why this holds by construction: the shard grid is a pure function of
//! the row count (never the backend or chunk size), rows are materialized
//! as identical index/value slices by both cursors, and every similarity
//! runs through the same kernels in the same order — see the
//! "Out-of-core data" section of the `sphkm::kmeans` module docs.

// Bench and test targets favour readable literal casts and exact
// (bit-level) float assertions; the workspace clippy warnings on
// those patterns are aimed at library code.
#![allow(clippy::cast_possible_truncation, clippy::float_cmp)]

use sphkm::data::synth::SynthConfig;
use sphkm::data::Dataset;
use sphkm::init::InitMethod;
use sphkm::kmeans::{Engine, ExactParams, MiniBatchParams, Variant};
use sphkm::sparse::{CsrMatrix, RowSource, ShardStore, SparseVec};
use sphkm::util::prop::forall;
use sphkm::{FittedModel, SphericalKMeans};

/// The resident-chunk accounting in `sphkm::sparse::chunked` is
/// process-global; serialize the tests in this binary so one test's live
/// cursors never pollute another's high-water mark (the budget test
/// compares that mark against a single corpus's footprint).
fn serial() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::OnceLock<std::sync::Mutex<()>> = std::sync::OnceLock::new();
    LOCK.get_or_init(|| std::sync::Mutex::new(()))
        .lock()
        .unwrap_or_else(std::sync::PoisonError::into_inner)
}

fn corpus(n_docs: usize, seed: u64) -> Dataset {
    let mut cfg = SynthConfig::small_demo();
    cfg.name = "ooc-synth".into();
    cfg.n_docs = n_docs;
    cfg.generate(seed)
}

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("sphkm-ooc-tests-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// Write `m` to a shard store at a fresh temp path and open it with the
/// given reader-side chunk budget.
fn store_for(m: &CsrMatrix, name: &str, chunk_rows: usize) -> (ShardStore, std::path::PathBuf) {
    let path = tmp(name);
    ShardStore::write_from_matrix(&path, m).unwrap();
    let store = ShardStore::open(&path).unwrap().with_chunk_rows(chunk_rows);
    (store, path)
}

fn assert_models_bit_identical(a: &FittedModel, b: &FittedModel, what: &str) {
    assert_eq!(a.assignments(), b.assignments(), "{what}: assignments");
    assert_eq!(
        a.objective().to_bits(),
        b.objective().to_bits(),
        "{what}: objective"
    );
    assert_eq!(a.converged(), b.converged(), "{what}: converged");
    for j in 0..a.k() {
        for (c, (x, y)) in a
            .centers()
            .row(j)
            .iter()
            .zip(b.centers().row(j))
            .enumerate()
        {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: center {j} dim {c}");
        }
    }
}

#[test]
fn exact_variants_bit_identical_across_backends() {
    let _serial = serial();
    let ds = corpus(450, 71);
    let n = ds.matrix.rows();
    let k = 7;
    // k-means++ seeding so the disk cursor also drives the init path.
    let init = InitMethod::KMeansPP { alpha: 1.0 };
    for variant in Variant::ALL {
        for threads in [1usize, 0] {
            // One row per chunk, a chunk size that does not divide the
            // row count, and the whole corpus in a single chunk.
            for chunk_rows in [1usize, 37, n] {
                let what =
                    format!("{} threads={threads} chunk_rows={chunk_rows}", variant.name());
                let est = || {
                    SphericalKMeans::new(k)
                        .variant(variant)
                        .init(init)
                        .seed(17)
                        .threads(threads)
                        .max_iter(60)
                };
                let mem = est().fit(&ds.matrix).unwrap();
                let (store, path) = store_for(
                    &ds.matrix,
                    &format!(
                        "exact-{}-{threads}-{chunk_rows}.sks",
                        variant.name().replace('.', "_")
                    ),
                    chunk_rows,
                );
                let disk = est().fit_source(RowSource::Disk(&store)).unwrap();
                std::fs::remove_file(&path).ok();
                assert_models_bit_identical(&mem, &disk, &what);
            }
        }
    }
}

#[test]
fn minibatch_bit_identical_across_backends() {
    let _serial = serial();
    let ds = corpus(500, 23);
    let n = ds.matrix.rows();
    let k = 6;
    for threads in [1usize, 0] {
        for chunk_rows in [1usize, 37, n] {
            let what = format!("minibatch threads={threads} chunk_rows={chunk_rows}");
            let est = || {
                SphericalKMeans::new(k)
                    .engine(Engine::MiniBatch(MiniBatchParams {
                        batch_size: 96,
                        epochs: 4,
                        tol: 0.0,
                        truncate: Some(24),
                    }))
                    .seed(29)
                    .threads(threads)
            };
            let mem = est().fit(&ds.matrix).unwrap();
            let (store, path) =
                store_for(&ds.matrix, &format!("mb-{threads}-{chunk_rows}.sks"), chunk_rows);
            let disk = est().fit_source(RowSource::Disk(&store)).unwrap();
            std::fs::remove_file(&path).ok();
            assert_models_bit_identical(&mem, &disk, &what);
        }
    }
}

#[test]
fn preinit_seeding_bit_identical_across_backends() {
    let _serial = serial();
    // The §7 preinit synergy runs the seeding similarity collection and
    // the bound initialization over the row source too.
    let ds = corpus(300, 41);
    let k = 5;
    for variant in [Variant::Elkan, Variant::Yinyang] {
        let est = || {
            SphericalKMeans::new(k)
                .engine(Engine::Exact(ExactParams {
                    variant,
                    preinit: true,
                    ..Default::default()
                }))
                .init(InitMethod::KMeansPP { alpha: 1.0 })
                .seed(3)
                .max_iter(60)
        };
        let mem = est().fit(&ds.matrix).unwrap();
        let (store, path) = store_for(
            &ds.matrix,
            &format!("preinit-{}.sks", variant.name().replace('.', "_")),
            19,
        );
        let disk = est().fit_source(RowSource::Disk(&store)).unwrap();
        std::fs::remove_file(&path).ok();
        assert_models_bit_identical(&mem, &disk, &format!("preinit {}", variant.name()));
    }
}

#[test]
fn randomized_backend_equivalence() {
    let _serial = serial();
    // Random corpora × random engine configurations: memory and disk
    // must agree bit-for-bit on every draw.
    forall(10, 0x00C_0FFE, |g| {
        let rows = g.usize_in(30, 160);
        let d = g.usize_in(20, 120);
        let k = g.usize_in(2, 8);
        let mut sv = Vec::with_capacity(rows);
        for _ in 0..rows {
            let nnz = g.usize_in(1, 12);
            let pattern = g.sparse_pattern(d, nnz);
            let pairs: Vec<(u32, f32)> = pattern
                .iter()
                .map(|&c| (c as u32, g.f64_in(0.05, 1.0) as f32))
                .collect();
            sv.push(SparseVec::from_pairs(d, pairs));
        }
        let mut m = CsrMatrix::from_rows(d, &sv);
        m.normalize_rows();
        let variant = Variant::ALL[g.usize_in(0, Variant::ALL.len())];
        let threads = [1usize, 0][g.usize_in(0, 2)];
        let chunk_rows = g.usize_in(1, rows + 1);
        let init = [
            InitMethod::Uniform,
            InitMethod::KMeansPP { alpha: 1.0 },
            InitMethod::AfkMc2 { alpha: 1.0, chain: 20 },
        ][g.usize_in(0, 3)];
        let seed = g.usize_in(0, 1 << 30) as u64;
        let est = || {
            SphericalKMeans::new(k)
                .variant(variant)
                .init(init)
                .seed(seed)
                .threads(threads)
                .max_iter(40)
        };
        let mem = est().fit(&m).unwrap();
        let (store, path) = store_for(&m, &format!("rand-{}.sks", g.case), chunk_rows);
        let disk = est().fit_source(RowSource::Disk(&store)).unwrap();
        std::fs::remove_file(&path).ok();
        assert_models_bit_identical(
            &mem,
            &disk,
            &format!(
                "case {}: {} init={init:?} threads={threads} chunk_rows={chunk_rows}",
                g.case,
                variant.name()
            ),
        );
    });
}

#[test]
fn resume_crosses_backends_bit_identically() {
    let _serial = serial();
    // Interrupt in one backend, save, resume in the other: the stitched
    // trajectory must equal the uninterrupted single-backend run.
    let ds = corpus(600, 77);
    let k = 8;
    let interrupt_at = 2usize;
    let (store, path) = store_for(&ds.matrix, "resume-cross.sks", 53);
    for variant in [Variant::Standard, Variant::SimplifiedElkan, Variant::Hamerly] {
        let est = || SphericalKMeans::new(k).variant(variant).seed(5);
        let what = |leg: &str| format!("{} {leg}", variant.name());
        let full = est().max_iter(200).fit(&ds.matrix).unwrap();
        assert!(full.converged() && full.iterations() > interrupt_at);

        // Memory → disk.
        let part = est().max_iter(interrupt_at).fit(&ds.matrix).unwrap();
        let spkm = tmp(&format!("cross-{}.spkm", variant.name().replace('.', "_")));
        part.save(&spkm).unwrap();
        let loaded = FittedModel::load(&spkm).unwrap();
        let resumed = est()
            .max_iter(200)
            .warm_start(&loaded)
            .fit_source(RowSource::Disk(&store))
            .unwrap();
        assert_models_bit_identical(&full, &resumed, &what("mem→disk"));

        // Disk → memory.
        let part = est()
            .max_iter(interrupt_at)
            .fit_source(RowSource::Disk(&store))
            .unwrap();
        part.save(&spkm).unwrap();
        let loaded = FittedModel::load(&spkm).unwrap();
        std::fs::remove_file(&spkm).ok();
        let resumed = est().max_iter(200).warm_start(&loaded).fit(&ds.matrix).unwrap();
        assert_models_bit_identical(&full, &resumed, &what("disk→mem"));
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn minibatch_resume_crosses_backends_bit_identically() {
    let _serial = serial();
    let ds = corpus(500, 13);
    let k = 6;
    let total_epochs = 6usize;
    let interrupt_at = 2usize;
    let mb = |epochs: usize| {
        SphericalKMeans::new(k)
            .engine(Engine::MiniBatch(MiniBatchParams {
                batch_size: 128,
                epochs,
                tol: 0.0,
                truncate: Some(16),
            }))
            .seed(31)
    };
    let (store, path) = store_for(&ds.matrix, "mb-resume-cross.sks", 41);
    let full = mb(total_epochs).fit(&ds.matrix).unwrap();
    let part = mb(interrupt_at).fit_source(RowSource::Disk(&store)).unwrap();
    let spkm = tmp("mb-cross.spkm");
    part.save(&spkm).unwrap();
    let loaded = FittedModel::load(&spkm).unwrap();
    std::fs::remove_file(&spkm).ok();
    let resumed = mb(total_epochs - interrupt_at)
        .warm_start(&loaded)
        .fit(&ds.matrix)
        .unwrap();
    std::fs::remove_file(&path).ok();
    assert_models_bit_identical(&full, &resumed, "minibatch disk→mem resume");
}

#[test]
fn chunked_reads_stay_within_their_budget() {
    let _serial = serial();
    // The resident-bytes accounting that the out-of-core bench asserts
    // against: a small chunk budget must keep the peak resident point
    // data strictly below the full-matrix footprint.
    let ds = corpus(800, 3);
    let (store, path) = store_for(&ds.matrix, "resident.sks", 32);
    sphkm::sparse::chunked::reset_resident_peak();
    let fitted = SphericalKMeans::new(6)
        .variant(Variant::SimplifiedHamerly)
        .seed(1)
        .max_iter(30)
        .fit_source(RowSource::Disk(&store))
        .unwrap();
    let peak = sphkm::sparse::chunked::resident_peak_bytes();
    std::fs::remove_file(&path).ok();
    assert!(fitted.iterations() > 0);
    assert!(peak > 0, "cursor accounting must observe the chunk buffers");
    assert!(
        peak < store.in_memory_bytes(),
        "peak resident {peak} must undercut the {}-byte full matrix",
        store.in_memory_bytes()
    );
}
