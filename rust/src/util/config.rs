//! Minimal key=value config-file parser (no `serde`/`toml` offline).
//!
//! Format: one `key = value` per line; `#` comments; values may be
//! comma-separated lists. Used by the `sphkm sweep` subcommand.
//!
//! ```text
//! # sweep.cfg
//! dataset  = rcv1
//! scale    = small
//! ks       = 10, 50
//! variants = standard, simp-elkan
//! inits    = uniform, kmeans++
//! reps     = 2
//! ```

use std::collections::BTreeMap;

/// A parsed config file.
#[derive(Debug, Default, Clone)]
pub struct Config {
    values: BTreeMap<String, String>,
}

/// Config parse/access errors.
#[derive(Debug, thiserror::Error)]
pub enum ConfigError {
    /// Filesystem error.
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    /// A line without `key = value` shape.
    #[error("line {0}: expected `key = value`, got {1:?}")]
    BadLine(usize, String),
    /// A value failed to parse as the requested type.
    #[error("key {0}: invalid value {1:?}")]
    BadValue(String, String),
}

impl Config {
    /// Parse from text.
    pub fn parse(text: &str) -> Result<Self, ConfigError> {
        let mut values = BTreeMap::new();
        for (lno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| ConfigError::BadLine(lno + 1, raw.to_string()))?;
            values.insert(k.trim().to_lowercase(), v.trim().to_string());
        }
        Ok(Self { values })
    }

    /// Parse from a file.
    pub fn load(path: &std::path::Path) -> Result<Self, ConfigError> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    /// Raw string value.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    /// Typed value with default.
    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, ConfigError> {
        match self.values.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| ConfigError::BadValue(key.into(), v.clone())),
        }
    }

    /// Comma-separated typed list (empty if absent).
    pub fn list<T: std::str::FromStr>(&self, key: &str) -> Result<Vec<T>, ConfigError> {
        match self.values.get(key) {
            None => Ok(Vec::new()),
            Some(v) => v
                .split(',')
                .map(|s| s.trim())
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.parse()
                        .map_err(|_| ConfigError::BadValue(key.into(), v.clone()))
                })
                .collect(),
        }
    }

    /// All keys (for unknown-key warnings).
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_keys_lists_comments() {
        let c = Config::parse(
            "# comment\n dataset = rcv1 \nks = 10, 50,200\nreps=3\nempty=\n",
        )
        .unwrap();
        assert_eq!(c.get("dataset"), Some("rcv1"));
        assert_eq!(c.list::<usize>("ks").unwrap(), vec![10, 50, 200]);
        assert_eq!(c.get_or("reps", 1usize).unwrap(), 3);
        assert_eq!(c.get_or("absent", 7usize).unwrap(), 7);
        assert!(c.list::<usize>("missing").unwrap().is_empty());
    }

    #[test]
    fn rejects_bad_lines_and_values() {
        assert!(Config::parse("not a kv line\n").is_err());
        let c = Config::parse("reps = abc\n").unwrap();
        assert!(c.get_or("reps", 1usize).is_err());
    }

    #[test]
    fn keys_are_case_insensitive_on_write() {
        let c = Config::parse("DataSet = demo\n").unwrap();
        assert_eq!(c.get("dataset"), Some("demo"));
    }
}
