//! Quickstart: generate a small synthetic corpus, cluster it with the
//! accelerated spherical k-means, and inspect the result.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

// Example code favours readable literal casts; the workspace clippy
// warnings on those patterns are aimed at library code.
#![allow(clippy::cast_possible_truncation, clippy::float_cmp)]

use sphkm::data::synth::SynthConfig;
use sphkm::init::InitMethod;
use sphkm::kmeans::{SphericalKMeans, Variant};
use sphkm::metrics;

fn main() {
    // 300 documents, 800-term vocabulary, 8 planted topics.
    let ds = SynthConfig::small_demo().generate(42);
    println!(
        "corpus: {} docs × {} terms, density {:.2}%",
        ds.matrix.rows(),
        ds.matrix.cols(),
        ds.matrix.density() * 100.0
    );

    // Cluster with the paper's recommended default (Simplified Elkan for
    // modest k) and k-means++ seeding.
    let result = SphericalKMeans::new(8)
        .variant(Variant::SimplifiedElkan)
        .init(InitMethod::KMeansPP { alpha: 1.0 })
        .seed(1)
        .fit(&ds.matrix)
        .expect("valid configuration");

    println!(
        "converged={} after {} iterations, objective={:.3}, mean cosine={:.3}",
        result.converged(),
        result.iterations(),
        result.objective(),
        result.mean_similarity()
    );
    println!(
        "similarity computations: {} (a standard run would need ~{})",
        result.stats().total_point_center(),
        (result.iterations() + 1) * ds.matrix.rows() * 8
    );

    if let Some(truth) = &ds.labels {
        println!(
            "vs planted topics: NMI={:.3} ARI={:.3} purity={:.3}",
            metrics::nmi(result.assignments(), truth),
            metrics::ari(result.assignments(), truth),
            metrics::purity(result.assignments(), truth)
        );
    }

    // Cluster sizes.
    let mut sizes = vec![0usize; 8];
    for &a in result.assignments() {
        sizes[a as usize] += 1;
    }
    println!("cluster sizes: {sizes:?}");
}
