//! Dense row-major matrix — used for cluster centers (which densify as they
//! aggregate many sparse rows, §5.2 of the paper) and for PJRT batch I/O.

use super::ops::{dense_dot, normalize_dense};

/// A dense row-major `rows × cols` matrix of `f32`.
#[derive(Debug, Clone, PartialEq)]
pub struct DenseMatrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl DenseMatrix {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Self { rows, cols, data }
    }

    /// Number of rows.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Borrow row `r`.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Mutably borrow row `r`.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Borrow the full row-major buffer.
    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutably borrow the full buffer.
    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Two disjoint mutable rows (for moving mass between centers).
    pub fn two_rows_mut(&mut self, a: usize, b: usize) -> (&mut [f32], &mut [f32]) {
        assert_ne!(a, b);
        let c = self.cols;
        if a < b {
            let (lo, hi) = self.data.split_at_mut(b * c);
            (&mut lo[a * c..(a + 1) * c], &mut hi[..c])
        } else {
            let (lo, hi) = self.data.split_at_mut(a * c);
            let (bl, al) = (&mut lo[b * c..(b + 1) * c], &mut hi[..c]);
            (al, bl)
        }
    }

    /// Dot product of rows `a` (of self) and `b` (of other).
    #[inline]
    pub fn row_dot(&self, a: usize, other: &DenseMatrix, b: usize) -> f64 {
        dense_dot(self.row(a), other.row(b))
    }

    /// Normalize every row to unit length; returns per-row original norms
    /// (0.0 for rows that were all-zero and left untouched).
    pub fn normalize_rows(&mut self) -> Vec<f64> {
        (0..self.rows)
            .map(|r| normalize_dense(&mut self.data[r * self.cols..(r + 1) * self.cols]))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_and_dots() {
        let m = DenseMatrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(m.row(0), &[1.0, 2.0, 3.0]);
        assert_eq!(m.row(1), &[4.0, 5.0, 6.0]);
        assert!((m.row_dot(0, &m, 1) - 32.0).abs() < 1e-12);
    }

    #[test]
    fn normalize_rows_reports_norms() {
        let mut m = DenseMatrix::from_vec(2, 2, vec![3.0, 4.0, 0.0, 0.0]);
        let norms = m.normalize_rows();
        assert!((norms[0] - 5.0).abs() < 1e-6);
        assert_eq!(norms[1], 0.0);
        assert!((m.row(0)[0] - 0.6).abs() < 1e-6);
        assert_eq!(m.row(1), &[0.0, 0.0]);
    }

    #[test]
    fn two_rows_mut_disjoint_both_orders() {
        let mut m = DenseMatrix::from_vec(3, 2, vec![1.0, 1.0, 2.0, 2.0, 3.0, 3.0]);
        {
            let (a, b) = m.two_rows_mut(0, 2);
            a[0] = 10.0;
            b[1] = 30.0;
        }
        {
            let (a, b) = m.two_rows_mut(2, 0);
            assert_eq!(a[1], 30.0);
            assert_eq!(b[0], 10.0);
        }
    }

    #[test]
    #[should_panic]
    fn two_rows_mut_same_row_panics() {
        let mut m = DenseMatrix::zeros(2, 2);
        let _ = m.two_rows_mut(1, 1);
    }
}
