//! Regenerates **Fig. 1** of the paper: per-iteration similarity
//! computations (1a cumulative: 1b) and per-iteration run time
//! (1c, cumulative: 1d) on the DBLP author-conference analogue with one
//! initialization and large k (paper: k=100).
//!
//! ```text
//! cargo bench --bench bench_fig1 -- [--scale S] [--k 100] [--reps 10]
//!     [--runs N] [--warmup W]
//! ```
//!
//! `--runs` is honored as an alias for `--reps` (the uniform bench-suite
//! spelling) when `--reps` is absent; `--warmup W` runs W untimed tiny
//! passes before the measured experiment.

// Bench and test targets favour readable literal casts and exact
// (bit-level) float assertions; the workspace clippy warnings on
// those patterns are aimed at library code.
#![allow(clippy::cast_possible_truncation, clippy::float_cmp)]

use sphkm::coordinator::experiments::{self, ExperimentOpts};
use sphkm::data::datasets::Scale;
use sphkm::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let mut opts = ExperimentOpts::from_args(&args);
    if args.has("runs") && !args.has("reps") {
        opts.reps = args.get_or("runs", opts.reps).unwrap_or(opts.reps).max(1);
    } else if !args.has("reps") {
        opts.reps = if args.flag("quick") { 2 } else { 10 }; // paper: 10 re-runs
    }
    let k = args.get_or("k", 100usize).unwrap_or(100);
    let warmup: usize = args.get_or("warmup", 0).unwrap_or(0);
    for _ in 0..warmup {
        println!("# warmup pass (untimed)");
        let mut w = opts.clone();
        w.scale = Scale::Tiny;
        w.reps = 1;
        experiments::fig1(&w, 2);
    }
    println!("# Fig. 1 bench — scale={}, k={k}, reps={}", opts.scale.name(), opts.reps);
    experiments::fig1(&opts, k);
}
