//! Sparse vector stored as parallel sorted arrays of indices and values —
//! the `(i, v)` pair encoding of the paper's §2.

use super::ops::{sparse_dense_dot, sparse_sparse_dot};

/// An immutable sparse vector with strictly increasing indices.
#[derive(Debug, Clone, PartialEq)]
pub struct SparseVec {
    /// Logical dimensionality (number of columns).
    pub dim: usize,
    idx: Vec<u32>,
    val: Vec<f32>,
}

impl SparseVec {
    /// Build from parallel index/value arrays. Indices must be strictly
    /// increasing and `< dim`; zero values are dropped.
    ///
    /// Invariants are `debug_assert`-checked only — this is the trusted
    /// hot-path constructor. Data arriving from **untrusted sources**
    /// (external files, network) must go through [`SparseVec::try_new`],
    /// which validates with real errors in every build profile: a
    /// violated invariant silently corrupts the sorted-merge dot products
    /// in release builds.
    pub fn new(dim: usize, idx: Vec<u32>, val: Vec<f32>) -> Self {
        assert_eq!(idx.len(), val.len(), "index/value length mismatch");
        debug_assert!(
            idx.windows(2).all(|w| w[0] < w[1]),
            "indices must be strictly increasing"
        );
        debug_assert!(idx.last().map(|&i| (i as usize) < dim).unwrap_or(true));
        // Drop explicit zeros to keep nnz meaningful.
        if val.iter().any(|&v| v == 0.0) {
            let (mut i2, mut v2) = (Vec::with_capacity(idx.len()), Vec::with_capacity(val.len()));
            for (i, v) in idx.into_iter().zip(val) {
                if v != 0.0 {
                    i2.push(i);
                    v2.push(v);
                }
            }
            return Self { dim, idx: i2, val: v2 };
        }
        Self { dim, idx, val }
    }

    /// Validating constructor for **untrusted** data (I/O ingestion
    /// paths): checks the invariants [`SparseVec::new`] only
    /// `debug_assert`s — equal lengths, strictly increasing indices (which
    /// also rules out duplicates), and indices `< dim` — plus value
    /// finiteness (a NaN/∞ would poison every downstream dot product and
    /// reduction), and reports the first violation as a descriptive error
    /// instead of corrupting the merge dot products downstream.
    pub fn try_new(dim: usize, idx: Vec<u32>, val: Vec<f32>) -> Result<Self, String> {
        if idx.len() != val.len() {
            return Err(format!(
                "index/value length mismatch: {} vs {}",
                idx.len(),
                val.len()
            ));
        }
        for w in idx.windows(2) {
            if w[0] >= w[1] {
                return Err(if w[0] == w[1] {
                    format!("duplicate index {}", w[0])
                } else {
                    format!("indices not sorted: {} before {}", w[0], w[1])
                });
            }
        }
        if let Some(&last) = idx.last() {
            if last as usize >= dim {
                return Err(format!("index {last} out of bounds for dimension {dim}"));
            }
        }
        if let Some(v) = val.iter().find(|v| !v.is_finite()) {
            return Err(format!("non-finite value {v}"));
        }
        Ok(Self::new(dim, idx, val))
    }

    /// Validating counterpart of [`SparseVec::from_pairs`] for
    /// **untrusted** `(index, value)` pairs: sorts by index, then applies
    /// every [`SparseVec::try_new`] check — in particular, duplicate
    /// indices are rejected with an error where the trusted constructor
    /// silently sums them. The single ingestion helper shared by the file
    /// readers.
    pub fn try_from_pairs(dim: usize, mut pairs: Vec<(u32, f32)>) -> Result<Self, String> {
        pairs.sort_unstable_by_key(|p| p.0);
        let (idx, val) = pairs.into_iter().unzip();
        Self::try_new(dim, idx, val)
    }

    /// Build from unsorted `(index, value)` pairs, summing duplicates.
    pub fn from_pairs(dim: usize, mut pairs: Vec<(u32, f32)>) -> Self {
        pairs.sort_unstable_by_key(|p| p.0);
        let mut idx = Vec::with_capacity(pairs.len());
        let mut val: Vec<f32> = Vec::with_capacity(pairs.len());
        for (i, v) in pairs {
            if Some(&i) == idx.last() {
                *val.last_mut().unwrap() += v;
            } else {
                idx.push(i);
                val.push(v);
            }
        }
        Self::new(dim, idx, val)
    }

    /// Build a dense vector's sparse view (dropping zeros). Panics if the
    /// vector is longer than the `u32` index space — a lossy cast here
    /// would silently alias distinct coordinates instead.
    pub fn from_dense(v: &[f32]) -> Self {
        let mut idx = Vec::new();
        let mut val = Vec::new();
        for (i, &x) in v.iter().enumerate() {
            if x != 0.0 {
                idx.push(u32::try_from(i).expect("dimension exceeds the u32 index space"));
                val.push(x);
            }
        }
        Self { dim: v.len(), idx, val }
    }

    /// The empty vector of a given dimension.
    pub fn zeros(dim: usize) -> Self {
        Self { dim, idx: Vec::new(), val: Vec::new() }
    }

    /// Number of stored non-zeros.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// True if there are no non-zeros.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.idx.is_empty()
    }

    /// Sorted indices of the non-zeros.
    #[inline]
    pub fn indices(&self) -> &[u32] {
        &self.idx
    }

    /// Values of the non-zeros (parallel to [`Self::indices`]).
    #[inline]
    pub fn values(&self) -> &[f32] {
        &self.val
    }

    /// Iterate `(index, value)` pairs.
    pub fn iter(&self) -> impl Iterator<Item = (u32, f32)> + '_ {
        self.idx.iter().copied().zip(self.val.iter().copied())
    }

    /// Value at logical position `i` (O(log nnz)).
    pub fn get(&self, i: usize) -> f32 {
        match self.idx.binary_search(&(i as u32)) {
            Ok(p) => self.val[p],
            Err(_) => 0.0,
        }
    }

    /// Squared Euclidean norm.
    pub fn norm_sq(&self) -> f64 {
        self.val.iter().map(|&v| (v as f64) * (v as f64)).sum()
    }

    /// Euclidean norm.
    pub fn norm(&self) -> f64 {
        self.norm_sq().sqrt()
    }

    /// Scale all values in place.
    pub fn scale(&mut self, s: f32) {
        for v in &mut self.val {
            *v *= s;
        }
    }

    /// Return a unit-normalized copy; `None` if the vector is all-zero.
    pub fn normalized(&self) -> Option<Self> {
        let n = self.norm();
        if n <= 0.0 {
            return None;
        }
        let inv = (1.0 / n) as f32;
        let mut out = self.clone();
        out.scale(inv);
        Some(out)
    }

    /// Dot product with another sparse vector (sorted merge, §2).
    #[inline]
    pub fn dot(&self, other: &SparseVec) -> f64 {
        sparse_sparse_dot(&self.idx, &self.val, &other.idx, &other.val)
    }

    /// Dot product with a dense vector.
    #[inline]
    pub fn dot_dense(&self, dense: &[f32]) -> f64 {
        debug_assert_eq!(dense.len(), self.dim);
        sparse_dense_dot(&self.idx, &self.val, dense)
    }

    /// Materialize as a dense `Vec<f32>`.
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.dim];
        for (i, v) in self.iter() {
            out[i as usize] = v;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    fn sv(dim: usize, pairs: &[(u32, f32)]) -> SparseVec {
        SparseVec::from_pairs(dim, pairs.to_vec())
    }

    #[test]
    fn construction_drops_zeros_and_sums_duplicates() {
        let v = sv(10, &[(3, 1.0), (1, 2.0), (3, 2.0), (5, 0.0)]);
        assert_eq!(v.nnz(), 2);
        assert_eq!(v.get(3), 3.0);
        assert_eq!(v.get(1), 2.0);
        assert_eq!(v.get(5), 0.0);
        assert_eq!(v.get(0), 0.0);
    }

    #[test]
    fn try_new_validates_untrusted_input() {
        // Valid input passes through (zeros still dropped).
        let v = SparseVec::try_new(5, vec![0, 3], vec![1.0, 0.0]).unwrap();
        assert_eq!(v.nnz(), 1);
        // Duplicate, unsorted, out-of-bounds, and ragged inputs all error
        // with a message (instead of debug-only assertions).
        assert!(SparseVec::try_new(5, vec![2, 2], vec![1.0, 1.0])
            .unwrap_err()
            .contains("duplicate"));
        assert!(SparseVec::try_new(5, vec![3, 1], vec![1.0, 1.0])
            .unwrap_err()
            .contains("sorted"));
        assert!(SparseVec::try_new(5, vec![1, 5], vec![1.0, 1.0])
            .unwrap_err()
            .contains("out of bounds"));
        assert!(SparseVec::try_new(5, vec![1], vec![1.0, 2.0])
            .unwrap_err()
            .contains("length mismatch"));
        assert!(SparseVec::try_new(5, vec![1], vec![f32::NAN])
            .unwrap_err()
            .contains("non-finite"));
        assert!(SparseVec::try_new(5, vec![1], vec![f32::INFINITY])
            .unwrap_err()
            .contains("non-finite"));
    }

    #[test]
    fn try_from_pairs_sorts_and_rejects_duplicates() {
        let v = SparseVec::try_from_pairs(6, vec![(4, 1.0), (1, 2.0)]).unwrap();
        assert_eq!(v.indices(), &[1, 4]);
        assert_eq!(v.values(), &[2.0, 1.0]);
        assert!(SparseVec::try_from_pairs(6, vec![(3, 1.0), (3, 2.0)])
            .unwrap_err()
            .contains("duplicate"));
        assert!(SparseVec::try_from_pairs(2, vec![(5, 1.0)])
            .unwrap_err()
            .contains("out of bounds"));
    }

    #[test]
    fn dot_merge_matches_dense() {
        let a = sv(8, &[(0, 1.0), (3, 2.0), (7, -1.0)]);
        let b = sv(8, &[(3, 4.0), (5, 1.0), (7, 2.0)]);
        assert!((a.dot(&b) - (8.0 - 2.0)).abs() < 1e-12);
        let bd = b.to_dense();
        assert!((a.dot_dense(&bd) - 6.0).abs() < 1e-12);
    }

    #[test]
    fn dot_empty_and_disjoint() {
        let a = sv(5, &[(0, 1.0), (1, 1.0)]);
        let b = sv(5, &[(3, 1.0), (4, 1.0)]);
        let z = SparseVec::zeros(5);
        assert_eq!(a.dot(&b), 0.0);
        assert_eq!(a.dot(&z), 0.0);
        assert_eq!(z.dot(&z), 0.0);
    }

    #[test]
    fn normalization() {
        let v = sv(4, &[(0, 3.0), (2, 4.0)]);
        let n = v.normalized().unwrap();
        assert!((n.norm() - 1.0).abs() < 1e-6);
        assert!((n.get(0) - 0.6).abs() < 1e-6);
        assert!((n.get(2) - 0.8).abs() < 1e-6);
        assert!(SparseVec::zeros(4).normalized().is_none());
    }

    #[test]
    fn prop_sparse_dot_equals_dense_dot() {
        forall(200, 0x5EED, |g| {
            let d = g.usize_in(1, 200);
            let nnz_a = g.usize_in(0, d + 1);
            let nnz_b = g.usize_in(0, d + 1);
            let pa = g.sparse_pattern(d, nnz_a);
            let pb = g.sparse_pattern(d, nnz_b);
            let a = SparseVec::new(
                d,
                pa.iter().map(|&i| i as u32).collect(),
                pa.iter().map(|_| g.f64_in(-2.0, 2.0) as f32).collect(),
            );
            let b = SparseVec::new(
                d,
                pb.iter().map(|&i| i as u32).collect(),
                pb.iter().map(|_| g.f64_in(-2.0, 2.0) as f32).collect(),
            );
            let ad = a.to_dense();
            let bd = b.to_dense();
            let reference: f64 = ad
                .iter()
                .zip(&bd)
                .map(|(&x, &y)| x as f64 * y as f64)
                .sum();
            assert!(
                (a.dot(&b) - reference).abs() < 1e-6,
                "merge dot {} vs dense {}",
                a.dot(&b),
                reference
            );
            assert!((a.dot_dense(&bd) - reference).abs() < 1e-6);
        });
    }

    #[test]
    fn prop_normalized_is_unit() {
        forall(100, 0xBEEF, |g| {
            let d = g.usize_in(2, 100);
            let nnz = g.usize_in(1, d);
            let p = g.sparse_pattern(d, nnz);
            let v = SparseVec::new(
                d,
                p.iter().map(|&i| i as u32).collect(),
                p.iter().map(|_| g.f64_in(0.1, 3.0) as f32).collect(),
            );
            let n = v.normalized().unwrap();
            assert!((n.norm() - 1.0).abs() < 1e-5);
        });
    }
}
