//! AFK-MC² seeding (Bachem et al., NeurIPS 2016) adapted to spherical
//! k-means with the `α − sim` dissimilarity (Pratap et al. 2018, §5.6 of
//! the paper).
//!
//! k-means++ needs a full pass over the data per center; AFK-MC² replaces
//! it with a Metropolis–Hastings chain of length `m` whose stationary
//! distribution is the k-means++ distribution. The proposal is the
//! assumption-free mixture
//!
//! ```text
//! q(x) = ½ · dis(x, c₁)/Σ_y dis(y, c₁)  +  ½ · 1/N
//! ```
//!
//! built once from the first (uniform) seed; each subsequent center costs
//! `O(m · k)` similarities instead of `O(N)`.

use crate::sparse::csr::RowView;
use crate::sparse::{RowCursor, RowSource, SparseVec};
use crate::util::rng::Xoshiro256;

/// `dis(x, C) = α − max_{c∈C} sim(x, c)` against the materialized chosen
/// seeds, charged to `sims`. A free function (not a closure) so the row
/// cursor can also be used directly between chain steps.
fn dis_to_set(
    rows: &mut RowCursor<'_>,
    i: usize,
    chosen: &[SparseVec],
    alpha: f64,
    sims: &mut u64,
) -> f64 {
    let row = rows.row(i);
    let mut best = f64::MIN;
    for c in chosen {
        let s = row.dot(&RowView { indices: c.indices(), values: c.values() });
        if s > best {
            best = s;
        }
    }
    *sims += chosen.len() as u64;
    (alpha - best).max(0.0)
}

pub(crate) fn choose(
    src: RowSource<'_>,
    k: usize,
    alpha: f64,
    chain: usize,
    rng: &mut Xoshiro256,
) -> (Vec<usize>, u64) {
    let n = src.rows();
    let mut rows = src.cursor();
    let chain = chain.max(1);
    let mut sims = 0u64;
    let mut chosen = Vec::with_capacity(k);
    let first = rng.index(n);
    chosen.push(first);
    let mut is_chosen = vec![false; n];
    is_chosen[first] = true;
    // Chosen seed rows, materialized as owned sparse vectors: the MCMC
    // chain reads them against random rows, which a single chunked cursor
    // could not serve for both sides at once. Same sorted-merge dot as the
    // in-memory path, so the walk is bit-identical between backends.
    let mut seeds: Vec<SparseVec> = Vec::with_capacity(k);
    seeds.push(rows.row_vec(first));

    // Proposal distribution q from the first seed (one full pass).
    let c1 = &seeds[0];
    let c1v = RowView { indices: c1.indices(), values: c1.values() };
    let mut q = vec![0.0f64; n];
    let mut total = 0.0f64;
    for i in 0..n {
        let dis = (alpha - rows.row(i).dot(&c1v)).max(0.0);
        q[i] = dis;
        total += dis;
    }
    sims += n as u64;
    for qi in &mut q {
        *qi = if total > 0.0 { 0.5 * *qi / total } else { 0.0 };
        *qi += 0.5 / n as f64;
    }

    for _ in 1..k {
        // Initialize the chain at a proposal draw.
        let mut x = sample_q(&q, rng);
        let mut dx = dis_to_set(&mut rows, x, &seeds, alpha, &mut sims);
        for _ in 1..chain {
            let y = sample_q(&q, rng);
            let dy = dis_to_set(&mut rows, y, &seeds, alpha, &mut sims);
            // Metropolis–Hastings acceptance for target ∝ dis(·, C).
            let accept = if dx * q[y] <= 0.0 {
                // Current state has zero mass (e.g. x already chosen):
                // always move.
                true
            } else {
                let ratio = (dy * q[x]) / (dx * q[y]);
                rng.next_f64() < ratio
            };
            if accept {
                x = y;
                dx = dy;
            }
        }
        // Guarantee distinctness (duplicates would crash k-means later):
        // if the chain landed on a chosen point (possible when α > 1),
        // fall back to the best unchosen proposal draw.
        let mut guard = 0;
        while is_chosen[x] {
            x = sample_q(&q, rng);
            guard += 1;
            if guard > 16 * n {
                x = (0..n).find(|&i| !is_chosen[i]).expect("k ≤ rows");
                break;
            }
        }
        is_chosen[x] = true;
        chosen.push(x);
        seeds.push(rows.row_vec(x));
    }
    (chosen, sims)
}

/// Draw an index from the (normalized) proposal distribution.
fn sample_q(q: &[f64], rng: &mut Xoshiro256) -> usize {
    let mut target = rng.next_f64();
    for (i, &w) in q.iter().enumerate() {
        target -= w;
        if target < 0.0 {
            return i;
        }
    }
    q.len() - 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::CsrMatrix;

    fn orthogonal_groups() -> CsrMatrix {
        let mut rows = Vec::new();
        for g in 0..3u32 {
            for t in 0..30u32 {
                rows.push(SparseVec::from_pairs(
                    100,
                    vec![(g, 1.0), (10 + g * 30 + t, 0.05)],
                ));
            }
        }
        let mut m = CsrMatrix::from_rows(100, &rows);
        m.normalize_rows();
        m
    }

    #[test]
    fn afkmc2_spreads_across_groups() {
        let data = orthogonal_groups();
        let mut hits = 0;
        let trials = 40;
        for seed in 0..trials {
            let mut rng = Xoshiro256::seed_from_u64(seed);
            let (chosen, _) = choose(RowSource::Mem(&data), 3, 1.0, 50, &mut rng);
            let groups: std::collections::HashSet<usize> =
                chosen.iter().map(|&i| i / 30).collect();
            if groups.len() == 3 {
                hits += 1;
            }
        }
        assert!(hits >= trials * 7 / 10, "only {hits}/{trials} spread runs");
    }

    #[test]
    fn proposal_distribution_is_normalized() {
        let data = orthogonal_groups();
        let n = data.rows();
        // Build q exactly as `choose` does.
        let first = 0usize;
        let c1 = data.row(first);
        let mut q = vec![0.0f64; n];
        let mut total = 0.0;
        for i in 0..n {
            q[i] = (1.0 - data.row(i).dot(&c1)).max(0.0);
            total += q[i];
        }
        for qi in &mut q {
            *qi = 0.5 * *qi / total + 0.5 / n as f64;
        }
        let sum: f64 = q.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9, "q sums to {sum}");
        assert!(q.iter().all(|&w| w > 0.0), "assumption-free term keeps q positive");
    }

    #[test]
    fn distinct_even_with_alpha_15() {
        let data = orthogonal_groups();
        for seed in 0..10 {
            let mut rng = Xoshiro256::seed_from_u64(seed);
            let (chosen, _) = choose(RowSource::Mem(&data), 12, 1.5, 30, &mut rng);
            let set: std::collections::HashSet<_> = chosen.iter().collect();
            assert_eq!(set.len(), 12);
        }
    }
}
