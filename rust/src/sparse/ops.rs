//! Low-level kernels shared by the sparse/dense containers. These are the
//! innermost loops of the whole system — every similarity the clustering
//! algorithms cannot prune lands in one of these functions.

/// Merge-based dot product of two sorted sparse vectors (§2 of the paper).
///
/// Uses a galloping step when one vector is much sparser than the other,
/// which matters for document × center-as-sparse cases.
#[inline]
pub fn sparse_sparse_dot(ai: &[u32], av: &[f32], bi: &[u32], bv: &[f32]) -> f64 {
    debug_assert_eq!(ai.len(), av.len());
    debug_assert_eq!(bi.len(), bv.len());
    // Ensure `a` is the shorter vector so galloping helps.
    if ai.len() > bi.len() {
        return sparse_sparse_dot(bi, bv, ai, av);
    }
    if ai.is_empty() || bi.is_empty() {
        return 0.0;
    }
    // Size ratio heuristic: plain merge for similar sizes, gallop otherwise.
    if bi.len() / ai.len().max(1) < 16 {
        merge_dot(ai, av, bi, bv)
    } else {
        gallop_dot(ai, av, bi, bv)
    }
}

#[inline]
fn merge_dot(ai: &[u32], av: &[f32], bi: &[u32], bv: &[f32]) -> f64 {
    let (mut p, mut q) = (0usize, 0usize);
    let mut acc = 0.0f64;
    while p < ai.len() && q < bi.len() {
        let (x, y) = (ai[p], bi[q]);
        if x == y {
            acc += av[p] as f64 * bv[q] as f64;
            p += 1;
            q += 1;
        } else if x < y {
            p += 1;
        } else {
            q += 1;
        }
    }
    acc
}

/// For each element of the short vector, binary-search the remaining
/// suffix of the long vector — `O(nnz_short · log nnz_long)`, a large win
/// when one operand is much sparser (e.g. a 3-nnz DBLP author row against
/// a 1000-nnz one).
#[inline]
fn gallop_dot(ai: &[u32], av: &[f32], bi: &[u32], bv: &[f32]) -> f64 {
    let mut acc = 0.0f64;
    let mut lo = 0usize;
    for (p, &x) in ai.iter().enumerate() {
        if lo >= bi.len() {
            break;
        }
        match bi[lo..].binary_search(&x) {
            Ok(off) => {
                acc += av[p] as f64 * bv[lo + off] as f64;
                lo += off + 1;
            }
            Err(off) => {
                lo += off;
            }
        }
    }
    acc
}

/// Sparse · dense dot product — the hot path when comparing a document
/// against a (dense) cluster center. Indexed gathers, accumulated in f64
/// to avoid cancellation issues the paper warns about.
#[inline]
pub fn sparse_dense_dot(idx: &[u32], val: &[f32], dense: &[f32]) -> f64 {
    debug_assert_eq!(idx.len(), val.len());
    // Manually 4-way unrolled: the gather-dominated loop pipelines better.
    let n = idx.len();
    let mut acc0 = 0.0f64;
    let mut acc1 = 0.0f64;
    let mut acc2 = 0.0f64;
    let mut acc3 = 0.0f64;
    let chunks = n / 4;
    // SAFETY-free fast loop via iterators over exact chunks.
    for c in 0..chunks {
        let b = c * 4;
        acc0 += val[b] as f64 * dense[idx[b] as usize] as f64;
        acc1 += val[b + 1] as f64 * dense[idx[b + 1] as usize] as f64;
        acc2 += val[b + 2] as f64 * dense[idx[b + 2] as usize] as f64;
        acc3 += val[b + 3] as f64 * dense[idx[b + 3] as usize] as f64;
    }
    for b in chunks * 4..n {
        acc0 += val[b] as f64 * dense[idx[b] as usize] as f64;
    }
    acc0 + acc1 + acc2 + acc3
}

/// Dense · dense dot product in f64 accumulation.
#[inline]
pub fn dense_dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc0 = 0.0f64;
    let mut acc1 = 0.0f64;
    let n = a.len();
    let half = n / 2 * 2;
    let mut i = 0;
    while i < half {
        acc0 += a[i] as f64 * b[i] as f64;
        acc1 += a[i + 1] as f64 * b[i + 1] as f64;
        i += 2;
    }
    if half < n {
        acc0 += a[half] as f64 * b[half] as f64;
    }
    acc0 + acc1
}

/// Normalize one sparse row's stored values to unit L2 norm in place.
///
/// Returns `false` (leaving the values untouched) when the norm is not
/// strictly positive — an all-zero row. The arithmetic (f64 sum of
/// squares, `sqrt`, one f32 reciprocal multiplied through) is the single
/// definition shared by [`CsrMatrix::normalize_rows`] and the streaming
/// shard converter, so in-memory and out-of-core pipelines produce
/// bit-identical unit rows.
///
/// [`CsrMatrix::normalize_rows`]: crate::sparse::CsrMatrix::normalize_rows
pub fn normalize_row_values(vals: &mut [f32]) -> bool {
    let norm: f64 = vals.iter().map(|&v| v as f64 * v as f64).sum::<f64>().sqrt();
    if norm > 0.0 {
        let inv = (1.0 / norm) as f32;
        for v in vals.iter_mut() {
            *v *= inv;
        }
        true
    } else {
        false
    }
}

/// Normalize a dense vector to unit length in place; returns the original
/// norm, or 0.0 (leaving the vector untouched) if it was all-zero.
pub fn normalize_dense(v: &mut [f32]) -> f64 {
    let norm = dense_dot(v, v).sqrt();
    if norm > 0.0 {
        let inv = (1.0 / norm) as f32;
        for x in v.iter_mut() {
            *x *= inv;
        }
    }
    norm
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn merge_and_gallop_agree() {
        forall(300, 0xD07, |g| {
            let d = g.usize_in(1, 2000);
            // Deliberately lopsided sizes to hit the gallop path.
            let na = g.usize_in(0, 8.min(d) + 1);
            let nb = g.usize_in(0, d + 1);
            let pa = g.sparse_pattern(d, na);
            let pb = g.sparse_pattern(d, nb);
            let ai: Vec<u32> = pa.iter().map(|&i| i as u32).collect();
            let bi: Vec<u32> = pb.iter().map(|&i| i as u32).collect();
            let av: Vec<f32> = pa.iter().map(|_| g.f64_in(-1.0, 1.0) as f32).collect();
            let bv: Vec<f32> = pb.iter().map(|_| g.f64_in(-1.0, 1.0) as f32).collect();
            let m = merge_dot(&ai, &av, &bi, &bv);
            let ga = gallop_dot(&ai, &av, &bi, &bv);
            let s = sparse_sparse_dot(&ai, &av, &bi, &bv);
            assert!((m - ga).abs() < 1e-9, "merge {m} vs gallop {ga}");
            assert!((m - s).abs() < 1e-9);
        });
    }

    #[test]
    fn sparse_dense_matches_naive() {
        forall(200, 0xD08, |g| {
            let d = g.usize_in(1, 300);
            let nnz = g.usize_in(0, d + 1);
            let p = g.sparse_pattern(d, nnz);
            let idx: Vec<u32> = p.iter().map(|&i| i as u32).collect();
            let val: Vec<f32> = p.iter().map(|_| g.f64_in(-1.0, 1.0) as f32).collect();
            let dense: Vec<f32> = (0..d).map(|_| g.f64_in(-1.0, 1.0) as f32).collect();
            let fast = sparse_dense_dot(&idx, &val, &dense);
            let naive: f64 = idx
                .iter()
                .zip(&val)
                .map(|(&i, &v)| v as f64 * dense[i as usize] as f64)
                .sum();
            assert!((fast - naive).abs() < 1e-9);
        });
    }

    #[test]
    fn dense_dot_matches_naive() {
        forall(100, 0xD09, |g| {
            let d = g.usize_in(0, 257);
            let a: Vec<f32> = (0..d).map(|_| g.f64_in(-1.0, 1.0) as f32).collect();
            let b: Vec<f32> = (0..d).map(|_| g.f64_in(-1.0, 1.0) as f32).collect();
            let naive: f64 = a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
            assert!((dense_dot(&a, &b) - naive).abs() < 1e-9);
        });
    }

    #[test]
    fn normalize_dense_unit_and_zero() {
        let mut v = vec![3.0f32, 0.0, 4.0];
        let n = normalize_dense(&mut v);
        assert!((n - 5.0).abs() < 1e-6);
        assert!((dense_dot(&v, &v) - 1.0).abs() < 1e-6);
        let mut z = vec![0.0f32; 3];
        assert_eq!(normalize_dense(&mut z), 0.0);
        assert_eq!(z, vec![0.0; 3]);
    }
}
