//! Spherical Yinyang k-means (Ding et al. 2015, adapted to cosine
//! similarity). The paper lists this as the obvious extension (§5.5):
//! centers are partitioned into `G` groups, and one upper bound `u(i,g)`
//! is kept per (point, group) — a memory/pruning compromise between
//! Elkan (`G = k`) and Hamerly (`G = 1`). Group bounds are maintained with
//! the same Eq. 9 / safe-interval machinery as Hamerly's single bound,
//! using per-group movement extremes.
//!
//! Grouping: a lightweight spherical k-means over the *initial centers*
//! (G groups, a few refinement rounds) — the grouping only affects pruning
//! power, never correctness, which the exactness tests assert.
//!
//! Group-bound maintenance and the group scan are fused into one sharded
//! per-point pass (see [`crate::kmeans`]'s parallel-execution docs); the
//! per-group movement extremes are computed serially (`O(k)`) before it.

use super::{
    audit_set_prune, bound_states, bound_works, Ctx, IterStats, KMeansConfig, Move, ShardOut,
    SimView,
};
use crate::audit::AUDIT_ENABLED;
use crate::bounds::hamerly_bound::{update_eq9_pre, update_min_p_guarded, update_safe};
use crate::bounds::update_lower;
use crate::obs::{span::span_start, Phase};
use crate::sparse::DenseMatrix;
use crate::util::timer::Stopwatch;

/// Assign each of the k centers to one of `g` groups by a few rounds of
/// spherical k-means over the centers themselves (deterministic seeding:
/// evenly spaced centers).
fn group_centers(centers: &DenseMatrix, g: usize) -> Vec<Vec<usize>> {
    let k = centers.rows();
    let g = g.clamp(1, k);
    let d = centers.cols();
    // Seeds: evenly spaced center indices.
    let mut seeds = DenseMatrix::zeros(g, d);
    for gi in 0..g {
        let src = gi * k / g;
        seeds.row_mut(gi).copy_from_slice(centers.row(src));
    }
    let mut assign = vec![0usize; k];
    for _round in 0..4 {
        // Assign.
        for j in 0..k {
            let mut best = f64::MIN;
            let mut bg = 0;
            for gi in 0..g {
                let s = centers.row_dot(j, &seeds, gi);
                if s > best {
                    best = s;
                    bg = gi;
                }
            }
            assign[j] = bg;
        }
        // Update seeds = normalized group sums.
        let mut sums = vec![0.0f64; g * d];
        for j in 0..k {
            let base = assign[j] * d;
            for (t, &v) in centers.row(j).iter().enumerate() {
                sums[base + t] += v as f64;
            }
        }
        for gi in 0..g {
            let s = &sums[gi * d..(gi + 1) * d];
            let norm = s.iter().map(|&v| v * v).sum::<f64>().sqrt();
            if norm > 0.0 {
                for (o, &v) in seeds.row_mut(gi).iter_mut().zip(s) {
                    *o = (v / norm) as f32;
                }
            }
        }
    }
    let mut groups = vec![Vec::new(); g];
    for (j, &gi) in assign.iter().enumerate() {
        groups[gi].push(j);
    }
    // Drop empty groups (possible with degenerate geometry).
    groups.retain(|v| !v.is_empty());
    groups
}

pub(crate) fn run(ctx: &mut Ctx<'_, '_>, cfg: &KMeansConfig) -> bool {
    let n = ctx.src.rows();
    let k = ctx.k;
    let groups = group_centers(
        ctx.centers.centers(),
        cfg.yinyang_groups.unwrap_or_else(|| (k / 10).max(1)),
    );
    let ng = groups.len();
    // group_of[j] = group index of center j.
    let mut group_of = vec![0usize; k];
    for (gi, members) in groups.iter().enumerate() {
        for &j in members {
            group_of[j] = gi;
        }
    }

    let mut l = vec![0.0f64; n];
    let mut ug = vec![0.0f64; n * ng]; // u(i, g)

    let stop = {
        let groups = &groups;
        let states = bound_states(&ctx.plan, &mut l, 1, &mut ug, ng);
        ctx.initial_assignment(true, states, |(l, ug), li, bj, best, _second, sims| {
            l[li] = best;
            let row = &mut ug[li * ng..(li + 1) * ng];
            for (gi, members) in groups.iter().enumerate() {
                let mut m = -1.0f64;
                for &j in members {
                    if j != bj && sims[j] > m {
                        m = sims[j];
                    }
                }
                row[gi] = m;
            }
        })
    };
    if stop {
        return false;
    }
    ctx.stats.bound_bytes = (n + n * ng) * std::mem::size_of::<f64>();

    // Per-group movement extremes.
    let mut gp_min = vec![1.0f64; ng];
    let mut gp_max = vec![1.0f64; ng];
    let mut gp_one_minus_min_sq = vec![0.0f64; ng];

    for _ in 0..cfg.max_iter {
        let sw = Stopwatch::start();
        let mut iter = IterStats::default();
        let iteration = ctx.stats.iters.len();

        let sp = span_start();
        {
            let p = ctx.centers.p();
            for (gi, members) in groups.iter().enumerate() {
                let mut mn = f64::MAX;
                let mut mx = f64::MIN;
                for &j in members {
                    mn = mn.min(p[j]);
                    mx = mx.max(p[j]);
                }
                gp_min[gi] = mn;
                gp_max[gi] = mx;
                gp_one_minus_min_sq[gi] = (1.0 - mn * mn).max(0.0);
            }
        }
        iter.phases.record(Phase::Bounds, sp);

        let sp = span_start();
        let outs = {
            let src = ctx.src;
            let centers = &ctx.centers;
            let p = ctx.centers.p();
            let tight = cfg.tight_hamerly_bound;
            let groups = &groups;
            let group_of = &group_of;
            let gp_min = &gp_min;
            let gp_max = &gp_max;
            let gp_one_minus_min_sq = &gp_one_minus_min_sq;
            let works = bound_works(&ctx.plan, &mut ctx.assign, &mut l, 1, &mut ug, ng);
            ctx.pool.run(works, |_, (range, assign, l, ug)| {
                let mut out = ShardOut::default();
                let mut view = SimView::new(src, centers, k);
                // Per-group scan temporaries.
                let mut gmax1 = vec![f64::MIN; ng];
                let mut gmax2 = vec![f64::MIN; ng];
                let mut scanned = vec![false; ng];
                for (li, i) in range.enumerate() {
                    let a = assign[li] as usize;
                    let urow = &mut ug[li * ng..(li + 1) * ng];
                    // Maintain bounds across the last center movement.
                    l[li] = update_lower(l[li], p[a]);
                    for (gi, u) in urow.iter_mut().enumerate() {
                        *u = if tight {
                            update_min_p_guarded(*u, gp_min[gi])
                        } else if *u >= 0.0 && gp_min[gi] >= 0.0 {
                            update_eq9_pre(*u, gp_one_minus_min_sq[gi])
                        } else {
                            update_safe(*u, gp_min[gi], gp_max[gi])
                        };
                    }
                    let global_u = urow.iter().cloned().fold(f64::MIN, f64::max);
                    if l[li] >= global_u {
                        out.iter.bound_skips += 1;
                        if AUDIT_ENABLED {
                            // max over group bounds upper-bounds every
                            // other center.
                            audit_set_prune(
                                &mut view,
                                &mut out.violations,
                                "yinyang",
                                iteration,
                                i,
                                a,
                                0..k,
                                Some(global_u),
                                Some(l[li]),
                            );
                        }
                        continue;
                    }
                    // Tighten l(i) and re-test.
                    l[li] = view.similarity(i, a, &mut out.iter);
                    if l[li] >= global_u {
                        out.iter.bound_skips += 1;
                        if AUDIT_ENABLED {
                            audit_set_prune(
                                &mut view,
                                &mut out.violations,
                                "yinyang",
                                iteration,
                                i,
                                a,
                                0..k,
                                Some(global_u),
                                Some(l[li]),
                            );
                        }
                        continue;
                    }
                    // Scan failing groups.
                    let l_old = l[li];
                    let mut best = f64::MIN;
                    let mut best_j = a;
                    for gi in 0..ng {
                        scanned[gi] = false;
                        gmax1[gi] = f64::MIN;
                        gmax2[gi] = f64::MIN;
                    }
                    for (gi, members) in groups.iter().enumerate() {
                        if urow[gi] <= l[li] {
                            out.iter.bound_skips += 1;
                            if AUDIT_ENABLED {
                                // l(i) is exact here (tightened above), so
                                // only the group bound's validity and the
                                // decision itself need certifying.
                                audit_set_prune(
                                    &mut view,
                                    &mut out.violations,
                                    "yinyang",
                                    iteration,
                                    i,
                                    a,
                                    members.iter().copied(),
                                    Some(urow[gi]),
                                    None,
                                );
                            }
                            continue;
                        }
                        scanned[gi] = true;
                        for &j in members {
                            if j == a {
                                continue;
                            }
                            let s = view.similarity(i, j, &mut out.iter);
                            if s > gmax1[gi] {
                                gmax2[gi] = gmax1[gi];
                                gmax1[gi] = s;
                            } else if s > gmax2[gi] {
                                gmax2[gi] = s;
                            }
                            if s > best {
                                best = s;
                                best_j = j;
                            }
                        }
                    }
                    if best > l[li] {
                        // Reassign a → best_j; repair the scanned group
                        // bounds.
                        let ga = group_of[a];
                        let gb = group_of[best_j];
                        assign[li] = best_j as u32;
                        out.moves.push(Move { i: i as u32, from: a as u32, to: best_j as u32 });
                        out.iter.reassignments += 1;
                        l[li] = best;
                        for gi in 0..ng {
                            if !scanned[gi] {
                                if gi == ga {
                                    // The old center joins the "others" of
                                    // its group; its (tight) similarity
                                    // l_old may exceed the stale group
                                    // bound.
                                    urow[gi] = urow[gi].max(l_old);
                                }
                                continue; // otherwise the stale bound remains valid
                            }
                            let mut b = gmax1[gi];
                            if gi == gb {
                                // Exclude the new assigned center: use the
                                // runner-up.
                                b = gmax2[gi];
                            }
                            if gi == ga {
                                // The old center joins the "others" of its
                                // group.
                                b = b.max(l_old);
                            }
                            urow[gi] = b.max(-1.0);
                        }
                    } else {
                        for gi in 0..ng {
                            if scanned[gi] {
                                urow[gi] = gmax1[gi].max(-1.0);
                            }
                        }
                    }
                }
                out
            })
        };
        iter.phases.record(Phase::Assignment, sp);
        let sp = span_start();
        ctx.merge_shards(outs, &mut iter);

        if iter.reassignments == 0 {
            iter.phases.record(Phase::Update, sp);
            iter.wall_ms = sw.ms();
            ctx.push_iter(iter, true);
            return true;
        }
        iter.sims_center_center += ctx.centers.update();
        iter.phases.record(Phase::Update, sp);
        iter.phases
            .shift(Phase::Update, Phase::IndexRefresh, ctx.centers.take_refresh_ms());
        iter.wall_ms = sw.ms();
        if ctx.push_iter(iter, false) {
            return false;
        }
    }
    false
}
