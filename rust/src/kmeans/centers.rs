//! Cluster-center bookkeeping shared by all algorithm variants.
//!
//! Implements the paper's baseline optimizations (§5): centers are stored
//! **dense** (sparse rows aggregate into nearly-dense sums, §5.2), the
//! per-cluster **sums are cached** and updated incrementally when a point
//! changes assignment (optimization iii), and the center is the sum scaled
//! to unit length (not the arithmetic mean).
//!
//! Sums are accumulated in `f64`: the experiment drivers run thousands of
//! incremental ± updates per cluster, and `f32` drift would break the
//! "accelerated variants produce identical assignments" exactness tests.
//!
//! All-centers similarity passes go through the pluggable kernel layer
//! ([`crate::kmeans::kernel`]): alongside the dense centers, `Centers`
//! maintains exactly the derived structure its resolved
//! [`Kernel`](crate::kmeans::kernel::Kernel) backend reads — the d×k
//! transpose, the inverted-file postings index, or nothing — refreshing
//! **only the centers that actually moved** at each update barrier (the
//! same dirty-flag discipline the `p(j)` accounting uses).

use super::kernel::{self, Kernel};
use crate::audit::AuditViolation;
use crate::runtime::parallel::{Plan, Pool, SHARD_ROWS};
use crate::sparse::csr::RowView;
use crate::sparse::{CsrMatrix, DenseMatrix, InvertedIndex, RowSource};

/// The derived structure backing the active similarity kernel — see
/// [`crate::kmeans::kernel`] for the backend trade-offs.
#[derive(Debug, Clone)]
enum CenterStore {
    /// Transposed copy of the centers (d×k, f32): the all-centers
    /// similarity pass reads `t[idx·k .. idx·k+k]` contiguously per
    /// non-zero, which vectorizes — the §Perf transposed-gather
    /// optimization (see EXPERIMENTS.md).
    Dense(DenseMatrix),
    /// No derived structure: per-center gather dots against the dense
    /// center rows (the paper-faithful cost model).
    Gather,
    /// Inverted-file postings over the center non-zeros — skips every
    /// (point, center) pair sharing no term and avoids the d×k footprint.
    Inverted(InvertedIndex),
    /// The same postings index, but assignment walks it MaxScore-style
    /// (descending `|q_c|·maxw[c]` term order with suffix upper bounds)
    /// and re-scores the surviving centers exactly — see
    /// `crate::kmeans::pruned`. The full-row `sims_all` path is identical
    /// to [`CenterStore::Inverted`].
    Pruned(InvertedIndex),
}

/// Cluster centers plus the cached unnormalized sums behind them.
#[derive(Debug, Clone)]
pub struct Centers {
    k: usize,
    d: usize,
    /// Unnormalized per-cluster sums (k×d, row-major, f64).
    sums: Vec<f64>,
    /// Points per cluster.
    counts: Vec<u64>,
    /// Current unit-normalized centers (k×d, f32).
    centers: DenseMatrix,
    /// Kernel-specific derived structure over `centers`, kept in sync per
    /// dirty center by [`Centers::update`] / [`Centers::update_partial`].
    store: CenterStore,
    /// Centers of the previous iteration (for `p(j)`).
    prev: DenseMatrix,
    /// `p(j) = ⟨c(j), c'(j)⟩`: self-similarity of each center's last move.
    p: Vec<f64>,
    /// Per-center "sums changed since the last update" flags, maintained by
    /// every sums mutation ([`Centers::rebuild`], [`Centers::apply_move`],
    /// [`Centers::fold_point`]). [`Centers::update`] and
    /// [`Centers::update_partial`] recompute (and charge a `p(j)` dot for)
    /// **only** dirty centers — a clean center provably did not move, so
    /// its `p(j)` is exactly 1 with no computation, and its column of the
    /// kernel store needs no rewrite.
    dirty: Vec<bool>,
    /// Wall-clock spent rewriting the kernel store (transpose columns /
    /// postings) at the update barriers since the last
    /// [`Centers::take_refresh_ms`] drain. Accumulated only under the
    /// `trace` feature (always exactly 0.0 otherwise — the spans
    /// const-fold away, see [`crate::obs::span`]).
    refresh_ms: f64,
}

impl Centers {
    /// Start from initial (unit-normalized) centers produced by a seeding
    /// method, using the default dense-transpose kernel. Sums start at
    /// zero; call [`Centers::rebuild`] once the first assignment exists.
    pub fn from_initial(initial: DenseMatrix) -> Self {
        Self::from_initial_for(initial, Kernel::Dense)
    }

    /// Like [`Centers::from_initial`], but backing the given (resolved)
    /// similarity kernel — only the structure that backend reads is built
    /// and maintained.
    pub fn from_initial_for(initial: DenseMatrix, kernel: Kernel) -> Self {
        let k = initial.rows();
        let d = initial.cols();
        let mut centers = initial;
        centers.normalize_rows();
        let store = match kernel {
            Kernel::Dense => CenterStore::Dense(DenseMatrix::zeros(d, k)),
            Kernel::Gather => CenterStore::Gather,
            Kernel::Inverted => CenterStore::Inverted(InvertedIndex::new(d, k)),
            Kernel::Pruned => CenterStore::Pruned(InvertedIndex::new(d, k)),
        };
        let mut me = Self {
            k,
            d,
            sums: vec![0.0; k * d],
            counts: vec![0; k],
            prev: centers.clone(),
            store,
            centers,
            p: vec![1.0; k],
            dirty: vec![false; k],
            refresh_ms: 0.0,
        };
        me.refresh_store_all();
        me
    }

    /// Restore an instance mid-run from persisted training state: the
    /// centers are adopted **bit-for-bit** (no renormalization — a resumed
    /// run must see exactly the coordinates the interrupted run saved) and
    /// the cached f64 sums / counts are the interrupted run's accumulator
    /// state, so subsequent incremental updates replay the exact
    /// floating-point sequence an uninterrupted run would have produced.
    /// All centers start clean with `p(j) = 1` (they have not moved since
    /// the state was captured).
    pub(crate) fn restore(
        centers: DenseMatrix,
        sums: Vec<f64>,
        counts: Vec<u64>,
        kernel: Kernel,
    ) -> Self {
        let k = centers.rows();
        let d = centers.cols();
        debug_assert_eq!(sums.len(), k * d);
        debug_assert_eq!(counts.len(), k);
        let store = match kernel {
            Kernel::Dense => CenterStore::Dense(DenseMatrix::zeros(d, k)),
            Kernel::Gather => CenterStore::Gather,
            Kernel::Inverted => CenterStore::Inverted(InvertedIndex::new(d, k)),
            Kernel::Pruned => CenterStore::Pruned(InvertedIndex::new(d, k)),
        };
        let mut me = Self {
            k,
            d,
            sums,
            counts,
            prev: centers.clone(),
            store,
            centers,
            p: vec![1.0; k],
            dirty: vec![false; k],
            refresh_ms: 0.0,
        };
        me.refresh_store_all();
        me
    }

    /// The cached unnormalized per-cluster sums (k×d, row-major) — the
    /// incremental-update accumulator state a resumable run persists.
    #[inline]
    pub(crate) fn sums(&self) -> &[f64] {
        &self.sums
    }

    /// Per-cluster point counts, all clusters at once (see
    /// [`Centers::count`] for a single one).
    #[inline]
    pub(crate) fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// The similarity kernel this instance is backing.
    pub fn kernel(&self) -> Kernel {
        match &self.store {
            CenterStore::Dense(_) => Kernel::Dense,
            CenterStore::Gather => Kernel::Gather,
            CenterStore::Inverted(_) => Kernel::Inverted,
            CenterStore::Pruned(_) => Kernel::Pruned,
        }
    }

    /// The inverted-file index, when that backend is active — diagnostic
    /// introspection (the equivalence suite inspects it; report surfaces
    /// can read its [`InvertedIndex::density`]).
    pub fn inverted(&self) -> Option<&InvertedIndex> {
        match &self.store {
            CenterStore::Inverted(idx) | CenterStore::Pruned(idx) => Some(idx),
            _ => None,
        }
    }

    /// Rewrite center `j`'s slice of the kernel store (its transpose
    /// column or its postings) from the current center row. Clean centers
    /// provably did not move, so the update barriers call this for dirty
    /// centers only.
    fn refresh_store_center(&mut self, j: usize) {
        let row = self.centers.row(j);
        match &mut self.store {
            CenterStore::Dense(t) => {
                let k = self.k;
                let t = t.data_mut();
                for (c, &v) in row.iter().enumerate() {
                    t[c * k + j] = v;
                }
            }
            CenterStore::Gather => {}
            CenterStore::Inverted(idx) | CenterStore::Pruned(idx) => idx.refresh_center(j, row),
        }
    }

    /// Rewrite the whole kernel store (construction and full-truncation
    /// barriers, where every center changed). The inverted index rebuilds
    /// from scratch — pure pushes, no per-posting list shifts — which is
    /// bit-identical to k incremental refreshes.
    fn refresh_store_all(&mut self) {
        if let CenterStore::Inverted(idx) | CenterStore::Pruned(idx) = &mut self.store {
            *idx = InvertedIndex::from_centers(&self.centers);
            return;
        }
        for j in 0..self.k {
            self.refresh_store_center(j);
        }
    }

    /// Similarities of one sparse row to **all** centers at once, written
    /// into `out[0..k]` through the active kernel backend; returns the
    /// multiply-adds performed (the kernel-layer cost model — see
    /// [`crate::kmeans::kernel`]). The Dense and Inverted backends are
    /// bit-identical; Gather agrees to summation-order rounding.
    #[inline]
    pub fn sims_all(&self, row: RowView<'_>, out: &mut [f64]) -> u64 {
        debug_assert_eq!(out.len(), self.k);
        match &self.store {
            CenterStore::Dense(t) => kernel::sims_transposed(t, self.k, row, out),
            CenterStore::Gather => kernel::sims_gather(&self.centers, row, out),
            CenterStore::Inverted(idx) | CenterStore::Pruned(idx) => idx.sims_into(row, out),
        }
    }

    /// Number of clusters.
    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    /// Dimensionality.
    #[inline]
    pub fn d(&self) -> usize {
        self.d
    }

    /// The current unit-normalized centers.
    #[inline]
    pub fn centers(&self) -> &DenseMatrix {
        &self.centers
    }

    /// Row `j` of the current centers.
    #[inline]
    pub fn center(&self, j: usize) -> &[f32] {
        self.centers.row(j)
    }

    /// `p(j)` of the most recent [`Centers::update`].
    #[inline]
    pub fn p(&self) -> &[f64] {
        &self.p
    }

    /// Points currently assigned to cluster `j`.
    #[inline]
    pub fn count(&self, j: usize) -> u64 {
        self.counts[j]
    }

    /// Rebuild sums and counts from scratch for a full assignment
    /// (deterministic order: ascending point index).
    pub fn rebuild(&mut self, data: &CsrMatrix, assign: &[u32]) {
        self.rebuild_source(RowSource::Mem(data), assign);
    }

    /// [`Centers::rebuild`] over either row backend: ascending-index
    /// accumulation through a row cursor, so the floating-point sequence —
    /// and therefore every downstream center coordinate — is bit-identical
    /// whether the rows come from memory or from disk shards.
    pub fn rebuild_source(&mut self, src: RowSource<'_>, assign: &[u32]) {
        debug_assert_eq!(assign.len(), src.rows());
        self.sums.fill(0.0);
        self.counts.fill(0);
        self.dirty.fill(true);
        let mut rows = src.cursor();
        for (i, &a) in assign.iter().enumerate() {
            let a = a as usize;
            self.counts[a] += 1;
            let row = rows.row(i);
            let base = a * self.d;
            for (t, &c) in row.indices.iter().enumerate() {
                self.sums[base + c as usize] += row.values[t] as f64;
            }
        }
    }

    /// Like [`Centers::rebuild`], but accumulating per-band partial sums on
    /// `pool`'s workers and reducing them once, in band order.
    ///
    /// The band grid is a pure function of the problem shape (`rows`,
    /// `k·d`) — never of the thread count — so the floating-point reduction
    /// tree, and therefore every downstream center coordinate, is identical
    /// for every `threads` setting (the shard-determinism contract of
    /// [`crate::kmeans`]). Band count is additionally capped by a memory
    /// budget on the `k×d` f64 partials, degenerating to the plain serial
    /// rebuild when even two partials would be too large to be worth it.
    pub fn rebuild_sharded(&mut self, data: &CsrMatrix, assign: &[u32], pool: &Pool) {
        self.rebuild_sharded_source(RowSource::Mem(data), assign, pool);
    }

    /// [`Centers::rebuild_sharded`] over either row backend. The band grid
    /// is the same pure function of the problem shape for both backends
    /// (and each band opens its own cursor), so the reduction tree — hence
    /// every center coordinate — is bit-identical between memory and disk
    /// shards at every thread count.
    pub fn rebuild_sharded_source(&mut self, src: RowSource<'_>, assign: &[u32], pool: &Pool) {
        debug_assert_eq!(assign.len(), src.rows());
        let bands = rebuild_bands(src.rows(), self.k * self.d);
        if bands <= 1 {
            self.rebuild_source(src, assign);
            return;
        }
        let plan = Plan::with_parts(src.rows(), bands);
        let (k, d) = (self.k, self.d);
        let parts: Vec<(Vec<f64>, Vec<u64>)> = pool.run(plan.ranges().to_vec(), |_, range| {
            let mut sums = vec![0.0f64; k * d];
            let mut counts = vec![0u64; k];
            let mut rows = src.cursor();
            for i in range {
                let a = assign[i] as usize;
                counts[a] += 1;
                let row = rows.row(i);
                let base = a * d;
                for (t, &c) in row.indices.iter().enumerate() {
                    sums[base + c as usize] += row.values[t] as f64;
                }
            }
            (sums, counts)
        });
        self.sums.fill(0.0);
        self.counts.fill(0);
        self.dirty.fill(true);
        for (ps, pc) in parts {
            for (o, v) in self.sums.iter_mut().zip(ps) {
                *o += v;
            }
            for (o, v) in self.counts.iter_mut().zip(pc) {
                *o += v;
            }
        }
    }

    /// Incrementally move one point's mass from cluster `from` to `to`
    /// (the paper's optimization iii).
    pub fn apply_move(&mut self, row: RowView<'_>, from: usize, to: usize) {
        debug_assert_ne!(from, to);
        self.counts[from] -= 1;
        self.counts[to] += 1;
        self.dirty[from] = true;
        self.dirty[to] = true;
        let (bf, bt) = (from * self.d, to * self.d);
        for (t, &c) in row.indices.iter().enumerate() {
            let v = row.values[t] as f64;
            self.sums[bf + c as usize] -= v;
            self.sums[bt + c as usize] += v;
        }
    }

    /// Fold one point into cluster `j`'s cached sum and count **without
    /// removing it anywhere** — the mini-batch accumulation step. With
    /// `n_j` points folded so far, the unit-scaled sum equals the running
    /// mean updated at the decayed per-center learning rate `η = 1/n_j`
    /// (Sculley 2010), renormalized to the sphere at the next
    /// [`Centers::update_partial`].
    pub fn fold_point(&mut self, row: RowView<'_>, j: usize) {
        self.counts[j] += 1;
        self.dirty[j] = true;
        let base = j * self.d;
        for (t, &c) in row.indices.iter().enumerate() {
            self.sums[base + c as usize] += row.values[t] as f64;
        }
    }

    /// Recompute unit centers from the cached sums, leaving empty clusters
    /// at their previous position (`p = 1`). Only centers whose sums
    /// actually changed since the last update (per-center dirty flags) are
    /// recomputed; a clean center keeps its exact position and reports
    /// `p(j) = 1` for free — and its slice of the kernel store (transpose
    /// column / postings) is left untouched, so store maintenance costs
    /// `O(moved · d)` instead of `O(k · d)` per barrier. Returns the
    /// number of center·center dot products spent computing `p(j)` —
    /// exactly one per recomputed center — so the `sims_center_center`
    /// counter (Fig. 1) reflects work actually performed.
    pub fn update(&mut self) -> u64 {
        std::mem::swap(&mut self.centers, &mut self.prev);
        // Incremental postings maintenance pays a list-shift per posting;
        // when most centers moved (early iterations reassign nearly
        // everything) a from-scratch rebuild — pure pushes in ascending
        // center order, the same structure the incremental path keeps — is
        // strictly cheaper. Bit-identical either way.
        let bulk_inverted = matches!(
            self.store,
            CenterStore::Inverted(_) | CenterStore::Pruned(_)
        )
            && 2 * self.dirty.iter().filter(|&&d| d).count() > self.k;
        let mut dots = 0u64;
        for j in 0..self.k {
            if !self.dirty[j] || self.counts[j] == 0 {
                // Clean center (sums untouched) or empty cluster: the
                // center does not move. After the swap its position lives
                // in `prev`; restore it (disjoint-field copy, no
                // allocation) without charging a recomputation.
                let (dst, src) = (self.centers.row_mut(j), self.prev.row(j));
                dst.copy_from_slice(src);
                self.p[j] = 1.0;
                self.dirty[j] = false;
                continue;
            }
            self.dirty[j] = false;
            let base = j * self.d;
            let sum = &self.sums[base..base + self.d];
            let norm = sum.iter().map(|&v| v * v).sum::<f64>().sqrt();
            let dst = self.centers.row_mut(j);
            if norm > 0.0 {
                let inv = 1.0 / norm;
                for (o, &s) in dst.iter_mut().zip(sum.iter()) {
                    *o = (s * inv) as f32;
                }
            } else {
                // Degenerate (sum cancelled to zero): keep previous center
                // — position unchanged, so the store needs no rewrite.
                dst.copy_from_slice(self.prev.row(j));
                self.p[j] = 1.0;
                continue;
            }
            self.p[j] = crate::bounds::clamp_sim(self.centers.row_dot(j, &self.prev, j));
            dots += 1;
            if !bulk_inverted {
                let sp = crate::obs::span::span_start();
                self.refresh_store_center(j);
                self.refresh_ms += crate::obs::span::span_ms(sp);
            }
        }
        if bulk_inverted {
            let sp = crate::obs::span::span_start();
            if let CenterStore::Inverted(idx) | CenterStore::Pruned(idx) = &mut self.store {
                *idx = InvertedIndex::from_centers(&self.centers);
            }
            self.refresh_ms += crate::obs::span::span_ms(sp);
        }
        dots
    }

    /// Mini-batch barrier: like [`Centers::update`] but touching only the
    /// dirty centers — recompute each from its sums, optionally truncate it
    /// to its `m` largest-magnitude coordinates (renormalized; Knittel
    /// et al. 2021's sparse centroids), record `p(j)` against its previous
    /// position, and refresh just its slice of the kernel store.
    /// Untouched centers keep position and report `p(j) = 1`. Cost is
    /// `O(touched · d)` instead of `O(k · d)`, which is what makes small
    /// batches cheap. Returns the `p(j)` dot count, as [`Centers::update`].
    pub fn update_partial(&mut self, truncate: Option<usize>) -> u64 {
        let k = self.k;
        let mut dots = 0u64;
        for j in 0..k {
            if !self.dirty[j] {
                self.p[j] = 1.0;
                continue;
            }
            self.dirty[j] = false;
            if self.counts[j] == 0 {
                self.p[j] = 1.0;
                continue;
            }
            let base = j * self.d;
            let norm = self.sums[base..base + self.d]
                .iter()
                .map(|&v| v * v)
                .sum::<f64>()
                .sqrt();
            if norm <= 0.0 {
                // Degenerate sum: the center stays where it is.
                self.p[j] = 1.0;
                continue;
            }
            // Current position becomes the "before" for p(j)…
            let (dst, src) = (self.prev.row_mut(j), self.centers.row(j));
            dst.copy_from_slice(src);
            // …then recompute (and optionally truncate) the center.
            let inv = 1.0 / norm;
            {
                let dst = self.centers.row_mut(j);
                for (o, &s) in dst.iter_mut().zip(self.sums[base..base + self.d].iter()) {
                    *o = (s * inv) as f32;
                }
            }
            if let Some(m) = truncate {
                truncate_unit_row(self.centers.row_mut(j), m);
            }
            self.p[j] = crate::bounds::clamp_sim(self.centers.row_dot(j, &self.prev, j));
            dots += 1;
            let sp = crate::obs::span::span_start();
            self.refresh_store_center(j);
            self.refresh_ms += crate::obs::span::span_ms(sp);
        }
        dots
    }

    /// Drain the kernel-store refresh wall-clock accumulated by the
    /// update barriers since the last call. The engines shift this slice
    /// of their update span into the index-refresh phase
    /// ([`crate::obs::Phase::IndexRefresh`]); always exactly 0.0 without
    /// the `trace` feature.
    pub(crate) fn take_refresh_ms(&mut self) -> f64 {
        std::mem::take(&mut self.refresh_ms)
    }

    /// Truncate every current center to its `m` largest-magnitude
    /// coordinates and renormalize (no-ops on centers that are already
    /// `m`-sparse). Establishes the sparse-centroid invariant on initial
    /// centers before a truncated mini-batch run.
    pub fn truncate_centers(&mut self, m: usize) {
        for j in 0..self.k {
            truncate_unit_row(self.centers.row_mut(j), m);
        }
        self.refresh_store_all();
    }

    /// Min and max of `p(j)` over `j ≠ excluded`, plus the same over all j.
    /// Used by the Hamerly single-bound update (Eq. 8/9): for the points of
    /// cluster `a`, the relevant movement is `p'(a) = min_{j≠a} p(j)`.
    /// Computing (min, second-min, max, second-max) once per iteration
    /// yields all k per-cluster values in O(k).
    pub fn p_extremes(&self) -> PExtremes {
        PExtremes::from_p(&self.p)
    }

    /// Deep invariant check for the audit layer ([`crate::audit`]): the
    /// coherence chain f64 sums ↔ f32 centers ↔ unit norms ↔ kernel store
    /// (transpose columns / postings) that every bound computation
    /// silently relies on. Checked: buffer shapes, `p(j) ∈ [−1, 1]`,
    /// non-zero centers unit-normalized, every *clean* non-empty center
    /// bit-coherent with its normalized f64 sum (skipped with
    /// `truncated = true` — a Knittel-truncated center is deliberately
    /// not the normalized sum), and the derived kernel structure exactly
    /// mirroring the dense centers. Run at iteration barriers under audit
    /// and callable from tests; returns the first broken invariant.
    pub fn check_invariants(&self, truncated: bool) -> Result<(), AuditViolation> {
        let fail = |check: &'static str, detail: String| {
            Err(AuditViolation::invariant("centers", check, detail))
        };
        let (k, d) = (self.k, self.d);
        if self.sums.len() != k * d
            || self.counts.len() != k
            || self.p.len() != k
            || self.dirty.len() != k
            || self.centers.rows() != k
            || self.centers.cols() != d
            || self.prev.rows() != k
            || self.prev.cols() != d
        {
            return fail(
                "shape",
                format!(
                    "k={k} d={d}: sums {}, counts {}, p {}, dirty {}, centers {}×{}, prev {}×{}",
                    self.sums.len(),
                    self.counts.len(),
                    self.p.len(),
                    self.dirty.len(),
                    self.centers.rows(),
                    self.centers.cols(),
                    self.prev.rows(),
                    self.prev.cols()
                ),
            );
        }
        for (j, &p) in self.p.iter().enumerate() {
            if !(-1.0..=1.0).contains(&p) {
                return fail("p-range", format!("p[{j}] = {p} outside [-1, 1]"));
            }
        }
        for j in 0..k {
            let row = self.centers.row(j);
            let norm_sq: f64 = row.iter().map(|&v| v as f64 * v as f64).sum();
            if norm_sq == 0.0 {
                continue; // all-zero centers are legal (zero seed rows)
            }
            // f32 per-coordinate rounding bounds the norm deviation.
            if (norm_sq.sqrt() - 1.0).abs() > 1e-3 {
                return fail("unit-norm", format!("center {j}: ‖c‖ = {}", norm_sq.sqrt()));
            }
            // A clean non-empty, non-degenerate center must be exactly the
            // f32 cast of its normalized f64 sum — the recomputation below
            // replays `update`'s arithmetic, so bit-equality is expected.
            if truncated || self.dirty[j] || self.counts[j] == 0 {
                continue;
            }
            let base = j * d;
            let sum = &self.sums[base..base + d];
            let snorm = sum.iter().map(|&v| v * v).sum::<f64>().sqrt();
            if snorm <= 0.0 {
                continue; // degenerate sum: the center legitimately held position
            }
            let inv = 1.0 / snorm;
            for (c, (&cv, &sv)) in row.iter().zip(sum.iter()).enumerate() {
                let expect = (sv * inv) as f32;
                if (cv - expect).abs() > 1e-6 {
                    return fail(
                        "sums-centers-coherence",
                        format!("center {j}, dim {c}: center {cv} vs normalized sum {expect}"),
                    );
                }
            }
        }
        match &self.store {
            CenterStore::Dense(t) => {
                if t.rows() != d || t.cols() != k {
                    return fail(
                        "store-coherence",
                        format!("transpose is {}×{}, want {d}×{k}", t.rows(), t.cols()),
                    );
                }
                for j in 0..k {
                    for (c, &v) in self.centers.row(j).iter().enumerate() {
                        let tv = t.row(c)[j];
                        if tv.to_bits() != v.to_bits() {
                            return fail(
                                "store-coherence",
                                format!("transpose[{c}][{j}] = {tv} vs center {v}"),
                            );
                        }
                    }
                }
            }
            CenterStore::Gather => {}
            CenterStore::Inverted(idx) | CenterStore::Pruned(idx) => {
                idx.check_invariants(&self.centers)?
            }
        }
        Ok(())
    }
}

/// Truncate one unit row to its `m` largest-magnitude coordinates and
/// re-scale the survivors back to unit length (the Knittel-style sparse
/// centroid). Deterministic: ties at the threshold magnitude keep the
/// lowest column indices. No-op when the row already has ≤ `m` non-zeros,
/// is all-zero, or `m == 0` (treated as "no truncation").
fn truncate_unit_row(row: &mut [f32], m: usize) {
    if m == 0 {
        return;
    }
    let nnz = row.iter().filter(|&&v| v != 0.0).count();
    if nnz <= m {
        return;
    }
    // Select the m-th largest magnitude in O(d).
    let mut mags: Vec<f32> = row.iter().filter(|&&v| v != 0.0).map(|v| v.abs()).collect();
    let cut = mags.len() - m;
    let (_, thr, _) = mags.select_nth_unstable_by(cut, |a, b| a.partial_cmp(b).unwrap());
    let thr = *thr;
    // Keep everything strictly above the threshold, then fill the quota
    // among threshold-magnitude entries in ascending index order.
    let greater = row.iter().filter(|&&v| v.abs() > thr).count();
    let mut quota_eq = m - greater;
    let mut norm_sq = 0.0f64;
    for v in row.iter_mut() {
        let a = v.abs();
        let keep = a > thr || (a == thr && quota_eq > 0);
        if keep {
            if a == thr {
                quota_eq -= 1;
            }
            norm_sq += (*v as f64) * (*v as f64);
        } else {
            *v = 0.0;
        }
    }
    if norm_sq > 0.0 {
        let inv = (1.0 / norm_sq.sqrt()) as f32;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// Number of parallel accumulation bands for a sharded rebuild: a function
/// of the problem shape only (never the thread count), bounded by a
/// ~128 MiB budget on the f64 partial-sum copies and by the row count.
fn rebuild_bands(rows: usize, kd: usize) -> usize {
    const MAX_BANDS: usize = 8;
    const BUDGET_BYTES: usize = 128 << 20;
    if rows < 2 * SHARD_ROWS || kd == 0 {
        return 1;
    }
    let mem_cap = (BUDGET_BYTES / (8 * kd)).max(1);
    let row_cap = rows / SHARD_ROWS;
    mem_cap.min(MAX_BANDS).min(row_cap).max(1)
}

/// Minimum/maximum structure over `p(j)` with exclusion support.
#[derive(Debug, Clone, Copy)]
pub struct PExtremes {
    min1: f64,
    min1_at: usize,
    min2: f64,
    max1: f64,
    max1_at: usize,
    max2: f64,
}

impl PExtremes {
    /// Build from the `p` vector.
    pub fn from_p(p: &[f64]) -> Self {
        let mut e = PExtremes {
            min1: f64::MAX,
            min1_at: usize::MAX,
            min2: f64::MAX,
            max1: f64::MIN,
            max1_at: usize::MAX,
            max2: f64::MIN,
        };
        for (j, &v) in p.iter().enumerate() {
            if v < e.min1 {
                e.min2 = e.min1;
                e.min1 = v;
                e.min1_at = j;
            } else if v < e.min2 {
                e.min2 = v;
            }
            if v > e.max1 {
                e.max2 = e.max1;
                e.max1 = v;
                e.max1_at = j;
            } else if v > e.max2 {
                e.max2 = v;
            }
        }
        e
    }

    /// `min_{j≠a} p(j)`.
    #[inline]
    pub fn min_excluding(&self, a: usize) -> f64 {
        if a == self.min1_at { self.min2 } else { self.min1 }
    }

    /// `max_{j≠a} p(j)`.
    #[inline]
    pub fn max_excluding(&self, a: usize) -> f64 {
        if a == self.max1_at { self.max2 } else { self.max1 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::SparseVec;

    fn toy_data() -> CsrMatrix {
        // Four unit-ish rows in 3D.
        let rows = vec![
            SparseVec::from_pairs(3, vec![(0, 1.0)]),
            SparseVec::from_pairs(3, vec![(0, 0.8), (1, 0.6)]),
            SparseVec::from_pairs(3, vec![(2, 1.0)]),
            SparseVec::from_pairs(3, vec![(1, 0.6), (2, 0.8)]),
        ];
        CsrMatrix::from_rows(3, &rows)
    }

    fn initial_centers() -> DenseMatrix {
        DenseMatrix::from_vec(2, 3, vec![1.0, 0.0, 0.0, 0.0, 0.0, 1.0])
    }

    #[test]
    fn rebuild_and_update_normalizes() {
        let data = toy_data();
        let mut c = Centers::from_initial(initial_centers());
        c.rebuild(&data, &[0, 0, 1, 1]);
        assert_eq!(c.count(0), 2);
        assert_eq!(c.count(1), 2);
        c.update();
        // Center 0 = normalize([1.8, 0.6, 0]).
        let n = (1.8f64 * 1.8 + 0.6 * 0.6).sqrt();
        assert!((c.center(0)[0] as f64 - 1.8 / n).abs() < 1e-6);
        assert!((c.center(0)[1] as f64 - 0.6 / n).abs() < 1e-6);
        // p(j) in [−1, 1] and meaningful.
        assert!(c.p().iter().all(|&p| (-1.0..=1.0).contains(&p)));
    }

    #[test]
    fn apply_move_matches_rebuild() {
        let data = toy_data();
        let mut a = Centers::from_initial(initial_centers());
        a.rebuild(&data, &[0, 0, 1, 1]);
        // Move point 1 from cluster 0 to 1 incrementally…
        a.apply_move(data.row(1), 0, 1);
        a.update();
        // …and compare with a from-scratch rebuild of the same assignment.
        let mut b = Centers::from_initial(initial_centers());
        b.rebuild(&data, &[0, 1, 1, 1]);
        b.update();
        for j in 0..2 {
            for (x, y) in a.center(j).iter().zip(b.center(j)) {
                assert!((x - y).abs() < 1e-6);
            }
        }
        assert_eq!(a.count(0), 1);
        assert_eq!(a.count(1), 3);
    }

    #[test]
    fn empty_cluster_keeps_previous_center() {
        let data = toy_data();
        let mut c = Centers::from_initial(initial_centers());
        c.rebuild(&data, &[0, 0, 0, 0]);
        c.update();
        let kept = c.center(1).to_vec();
        assert_eq!(kept, vec![0.0, 0.0, 1.0]);
        assert_eq!(c.p()[1], 1.0);
    }

    #[test]
    fn p_is_one_when_center_static() {
        let data = toy_data();
        let mut c = Centers::from_initial(initial_centers());
        c.rebuild(&data, &[0, 0, 1, 1]);
        c.update();
        let p1 = c.p().to_vec();
        // No moves: second update from identical sums ⇒ p = 1.
        c.update();
        for &p in c.p() {
            assert!((p - 1.0).abs() < 1e-6);
        }
        drop(p1);
    }

    #[test]
    fn update_charges_only_changed_centers() {
        // Three centers so an untouched one exists alongside a moved pair.
        let data = toy_data();
        let initial = DenseMatrix::from_vec(
            3,
            3,
            vec![1.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 1.0],
        );
        let mut c = Centers::from_initial(initial);
        c.rebuild(&data, &[0, 0, 1, 2]);
        // Rebuild dirties everything: all three non-empty centers charge.
        assert_eq!(c.update(), 3);
        // Nothing changed since: no p(j) recomputation, p exactly 1.
        assert_eq!(c.update(), 0);
        assert!(c.p().iter().all(|&p| p == 1.0));
        // One move touches exactly two centers; the third stays clean.
        let before = c.center(2).to_vec();
        c.apply_move(data.row(1), 0, 1);
        assert_eq!(c.update(), 2);
        assert_eq!(c.p()[2], 1.0);
        assert_eq!(c.center(2), &before[..], "clean center must not move");
    }

    #[test]
    fn fold_point_and_update_partial_match_full_update() {
        let data = toy_data();
        let mut a = Centers::from_initial(initial_centers());
        a.rebuild(&data, &[0, 0, 1, 1]);
        a.update();
        // Fold a batch point into cluster 0 and update partially…
        a.fold_point(data.row(2), 0);
        let dots = a.update_partial(None);
        assert_eq!(dots, 1, "only the folded center recomputes");
        assert_eq!(a.count(0), 3);
        // …the untouched center reports p = 1, the folded one moved.
        assert_eq!(a.p()[1], 1.0);
        assert!(a.p()[0] < 1.0);
        // The folded center matches a full update from the same sums.
        let mut b = Centers::from_initial(initial_centers());
        b.rebuild(&data, &[0, 0, 1, 1]);
        b.update();
        b.fold_point(data.row(2), 0);
        b.update();
        for (x, y) in a.center(0).iter().zip(b.center(0)) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        // And the transposed copy stayed consistent: sims_all must agree
        // with per-center gather dots.
        let mut out = vec![0.0f64; 2];
        a.sims_all(data.row(3), &mut out);
        for (j, &s) in out.iter().enumerate() {
            let direct = data.row(3).dot_dense(a.center(j));
            assert!((s - direct).abs() < 1e-9, "center {j}: {s} vs {direct}");
        }
    }

    #[test]
    fn truncation_keeps_top_m_and_unit_norm() {
        let mut row = vec![0.1f32, -0.5, 0.2, 0.0, 0.4, -0.1, 0.3];
        truncate_unit_row(&mut row, 3);
        // Survivors: |−0.5|, |0.4|, |0.3|.
        assert_eq!(row.iter().filter(|&&v| v != 0.0).count(), 3);
        assert_eq!(row[3], 0.0);
        assert_eq!(row[0], 0.0);
        assert_eq!(row[2], 0.0);
        assert_eq!(row[5], 0.0);
        let norm: f64 = row.iter().map(|&v| (v as f64) * (v as f64)).sum();
        assert!((norm - 1.0).abs() < 1e-6, "norm² = {norm}");
        assert!(row[1] < 0.0, "signs survive truncation");
        // Ties keep the lowest indices, deterministically.
        let mut tied = vec![0.5f32, 0.5, 0.5, 0.5];
        truncate_unit_row(&mut tied, 2);
        assert!(tied[0] > 0.0 && tied[1] > 0.0);
        assert_eq!(&tied[2..], &[0.0, 0.0]);
        // m ≥ nnz and m = 0 are no-ops.
        let mut short = vec![0.6f32, 0.8];
        let copy = short.clone();
        truncate_unit_row(&mut short, 5);
        assert_eq!(short, copy);
        truncate_unit_row(&mut short, 0);
        assert_eq!(short, copy);
    }

    #[test]
    fn truncate_centers_preserves_unit_norm_and_transpose() {
        let data = toy_data();
        let mut c = Centers::from_initial(initial_centers());
        c.rebuild(&data, &[0, 0, 1, 1]);
        c.update();
        c.truncate_centers(1);
        for j in 0..2 {
            let norm: f64 = c
                .center(j)
                .iter()
                .map(|&v| (v as f64) * (v as f64))
                .sum();
            assert!((norm - 1.0).abs() < 1e-6);
            assert!(c.center(j).iter().filter(|&&v| v != 0.0).count() <= 1);
            // Transposed copy refreshed.
            let mut out = vec![0.0f64; 2];
            c.sims_all(data.row(0), &mut out);
            assert!((out[j] - data.row(0).dot_dense(c.center(j))).abs() < 1e-9);
        }
    }

    #[test]
    fn rebuild_sharded_is_thread_count_invariant() {
        use crate::runtime::parallel::Pool;
        use crate::util::rng::Xoshiro256;
        // Enough rows for several bands (row_cap = rows / SHARD_ROWS).
        let (rows, d, k) = (1200usize, 8usize, 3usize);
        let mut rng = Xoshiro256::seed_from_u64(77);
        let data: Vec<SparseVec> = (0..rows)
            .map(|_| {
                let c = rng.index(d);
                SparseVec::from_pairs(d, vec![(c as u32, 0.25 + rng.next_f64() as f32)])
            })
            .collect();
        let data = CsrMatrix::from_rows(d, &data);
        let assign: Vec<u32> = (0..rows).map(|i| (i % k) as u32).collect();
        let initial = DenseMatrix::from_vec(
            k,
            d,
            (0..k * d).map(|i| if i % (d + 1) == 0 { 1.0 } else { 0.0 }).collect(),
        );

        let mut serial = Centers::from_initial(initial.clone());
        serial.rebuild_sharded(&data, &assign, &Pool::new(1));
        serial.update();
        for threads in [2usize, 4, 0] {
            let mut par = Centers::from_initial(initial.clone());
            par.rebuild_sharded(&data, &assign, &Pool::new(threads));
            par.update();
            for j in 0..k {
                assert_eq!(par.count(j), serial.count(j));
                // Bit-identical: the band grid never depends on threads.
                for (a, b) in par.center(j).iter().zip(serial.center(j)) {
                    assert_eq!(a.to_bits(), b.to_bits(), "threads={threads}");
                }
            }
        }
        // And the sharded path agrees with the plain serial rebuild up to
        // reduction-order rounding.
        let mut plain = Centers::from_initial(initial);
        plain.rebuild(&data, &assign);
        plain.update();
        for j in 0..k {
            assert_eq!(plain.count(j), serial.count(j));
            for (a, b) in plain.center(j).iter().zip(serial.center(j)) {
                assert!((a - b).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn kernel_backends_stay_bit_identical_through_updates() {
        // Drive each backend through the same rebuild → move → update →
        // partial-update sequence; Dense and Inverted sims must match
        // bitwise at every barrier (the kernel exactness contract), Gather
        // to rounding.
        let data = toy_data();
        let mk = |kernel: Kernel| {
            let mut c = Centers::from_initial_for(initial_centers(), kernel);
            c.rebuild(&data, &[0, 0, 1, 1]);
            c.update();
            c.apply_move(data.row(1), 0, 1);
            c.update();
            c.fold_point(data.row(2), 0);
            c.update_partial(Some(2));
            c
        };
        let dense = mk(Kernel::Dense);
        let gather = mk(Kernel::Gather);
        let inverted = mk(Kernel::Inverted);
        let pruned = mk(Kernel::Pruned);
        assert_eq!(dense.kernel(), Kernel::Dense);
        assert_eq!(gather.kernel(), Kernel::Gather);
        assert_eq!(inverted.kernel(), Kernel::Inverted);
        assert_eq!(pruned.kernel(), Kernel::Pruned);
        assert!(inverted.inverted().is_some());
        assert!(pruned.inverted().is_some());
        assert!(dense.inverted().is_none());
        let mut sd = vec![0.0f64; 2];
        let mut sg = vec![0.0f64; 2];
        let mut si = vec![0.0f64; 2];
        let mut sp = vec![0.0f64; 2];
        for i in 0..data.rows() {
            let md = dense.sims_all(data.row(i), &mut sd);
            let mg = gather.sims_all(data.row(i), &mut sg);
            let mi = inverted.sims_all(data.row(i), &mut si);
            let mp = pruned.sims_all(data.row(i), &mut sp);
            assert_eq!(md, mg, "row {i}: dense/gather madd counts");
            assert!(mi <= md, "row {i}: inverted must not do more madds");
            assert_eq!(mi, mp, "row {i}: pruned sims_all is the inverted pass");
            for j in 0..2 {
                assert_eq!(
                    sd[j].to_bits(),
                    si[j].to_bits(),
                    "row {i} center {j}: dense vs inverted"
                );
                assert_eq!(
                    sd[j].to_bits(),
                    sp[j].to_bits(),
                    "row {i} center {j}: dense vs pruned"
                );
                assert!((sd[j] - sg[j]).abs() < 1e-12, "row {i} center {j}");
            }
        }
    }

    #[test]
    fn store_refresh_touches_only_moved_centers() {
        // Three centers; move mass between two of them. The clean third
        // center must keep its exact transpose column (dirty-column-only
        // refresh), which sims_all would expose if it went stale.
        let data = toy_data();
        let initial = DenseMatrix::from_vec(
            3,
            3,
            vec![1.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 1.0],
        );
        for kernel in [Kernel::Dense, Kernel::Inverted, Kernel::Pruned] {
            let mut c = Centers::from_initial_for(initial.clone(), kernel);
            c.rebuild(&data, &[0, 0, 1, 2]);
            c.update();
            c.apply_move(data.row(1), 0, 1);
            c.update();
            let mut out = vec![0.0f64; 3];
            for i in 0..data.rows() {
                c.sims_all(data.row(i), &mut out);
                for j in 0..3 {
                    let direct = data.row(i).dot_dense(c.center(j));
                    assert!(
                        (out[j] - direct).abs() < 1e-9,
                        "{kernel:?} row {i} center {j}: {} vs {direct}",
                        out[j]
                    );
                }
            }
        }
    }

    #[test]
    fn p_extremes_exclusion() {
        let p = [0.9, 0.5, 0.7, 0.99];
        let e = PExtremes::from_p(&p);
        assert_eq!(e.min_excluding(0), 0.5);
        assert_eq!(e.min_excluding(1), 0.7);
        assert_eq!(e.max_excluding(3), 0.9);
        assert_eq!(e.max_excluding(0), 0.99);
    }

    #[test]
    fn check_invariants_accepts_valid_states() {
        let data = toy_data();
        let mut c = Centers::from_initial(initial_centers());
        assert!(c.check_invariants(false).is_ok());
        c.rebuild(&data, &[0, 0, 1, 1]);
        c.update();
        assert!(c.check_invariants(false).is_ok());
        c.apply_move(data.row(1), 0, 1);
        c.update();
        assert!(c.check_invariants(false).is_ok());
    }

    #[test]
    fn check_invariants_names_broken_coherence() {
        let data = toy_data();
        let mut c = Centers::from_initial(initial_centers());
        c.rebuild(&data, &[0, 0, 1, 1]);
        c.update();

        // Drifted sums no longer normalize to the stored center. The
        // truncated relaxation skips exactly this check — a truncated
        // center is *intentionally* not the normalized sum.
        c.sums[0] += 0.5;
        assert_eq!(
            c.check_invariants(false).unwrap_err().check,
            "sums-centers-coherence"
        );
        assert!(c.check_invariants(true).is_ok());

        // A denormalized center row is caught regardless of truncation.
        c.centers.row_mut(0)[0] = 2.0;
        assert_eq!(c.check_invariants(true).unwrap_err().check, "unit-norm");
    }
}
