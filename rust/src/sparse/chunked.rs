//! Chunked on-disk CSR shard store — the out-of-core backend behind
//! [`RowSource`].
//!
//! Every training engine in this crate consumes point data through
//! [`RowSource`], an abstraction with two backends:
//!
//! * [`RowSource::Mem`] — the existing in-memory [`CsrMatrix`]; and
//! * [`RowSource::Disk`] — a [`ShardStore`]: the same CSR arrays laid out
//!   in one binary file, read back **chunk-at-a-time** through a
//!   [`ChunkCursor`] that keeps only `chunk_rows` rows resident.
//!
//! The hot loops never see the difference: a [`RowCursor`] yields the
//! same [`RowView`] borrow either way, and the shard/band grids of the
//! parallel executor ([`crate::runtime::parallel::Plan`]) are pure
//! functions of the row count — never of the storage backend or the chunk
//! size — so results are **bit-identical** between backends for every
//! thread count and chunk size (asserted by the `out_of_core` integration
//! suite).
//!
//! # On-disk format (version 1, little-endian)
//!
//! | section  | bytes          | contents                                  |
//! |----------|----------------|-------------------------------------------|
//! | magic    | 8              | `SPHKSHD\0`                               |
//! | version  | 4              | format version (`1`)                      |
//! | flags    | 4              | reserved, must be `0`                     |
//! | rows     | 8              | row count (u64)                           |
//! | cols     | 8              | column count (u64)                        |
//! | nnz      | 8              | total stored non-zeros (u64)              |
//! | indptr   | 8·(rows+1)     | row pointers (u64, cumulative)            |
//! | indices  | 4·nnz          | column indices (u32, sorted per row)      |
//! | values   | 4·nnz          | values (f32 bit patterns)                 |
//! | checksum | 8              | FNV-1a-64 over every preceding byte       |
//!
//! [`ShardStore::open`] validates the header and the exact file length
//! (fully determined by `rows` and `nnz`); [`ShardStore::verify`] streams
//! the full checksum. The layout is produced either by
//! [`ShardStore::write_from_matrix`] (from an in-memory matrix) or by the
//! bounded-memory libsvm converter
//! ([`crate::data::convert::convert_libsvm_to_shards`]), which never
//! materializes the matrix at all.
//!
//! # Memory model
//!
//! A cursor's resident footprint is one chunk: `O(chunk_rows ·
//! avg_row_nnz)` plus the `(chunk_rows + 1)` row pointers. Each shard of a
//! parallel assignment pass owns its own cursor, so a training run keeps
//! at most `threads × chunk_rows` rows of point data resident — the rest
//! lives in the OS page cache at the kernel's discretion. The module
//! tracks the high-water mark of all live chunk buffers
//! ([`resident_peak_bytes`]) so benches and the CI smoke job can assert
//! the out-of-core path really stays under its budget.
//!
//! I/O errors *after* open (a file truncated or deleted mid-training)
//! panic with a contextful message: the hot loops return borrowed
//! [`RowView`]s and have no error channel, and a half-read chunk must
//! never silently feed the similarity kernels.

use super::csr::{CsrMatrix, RowView};
use super::vec::SparseVec;
use std::fs::File;
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// File magic of the shard-store format.
pub const SHARD_MAGIC: [u8; 8] = *b"SPHKSHD\0";
/// Current shard-store format version.
pub const SHARD_VERSION: u32 = 1;
/// Header size in bytes (magic + version + flags + rows + cols + nnz).
pub const SHARD_HEADER_BYTES: u64 = 40;
/// Default rows kept resident per cursor chunk
/// (see [`ShardStore::with_chunk_rows`]).
pub const DEFAULT_CHUNK_ROWS: usize = 4096;

/// FNV-1a 64-bit offset basis (same constants as the `.spkm` codec).
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a 64-bit prime.
const FNV_PRIME: u64 = 0x1_0000_0000_01b3;

#[inline]
fn fnv1a_update(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

// Aggregate resident-chunk accounting across every live cursor: the
// current total and its high-water mark since the last reset. Plain
// atomics — cursors live on worker threads.
static RESIDENT_NOW: AtomicU64 = AtomicU64::new(0);
static RESIDENT_PEAK: AtomicU64 = AtomicU64::new(0);

/// Bytes of shard-chunk buffers currently resident across all live
/// [`ChunkCursor`]s.
pub fn resident_bytes_now() -> u64 {
    RESIDENT_NOW.load(Ordering::SeqCst)
}

/// High-water mark of [`resident_bytes_now`] since the last
/// [`reset_resident_peak`] — what the out-of-core benches assert against
/// their memory budget.
pub fn resident_peak_bytes() -> u64 {
    RESIDENT_PEAK.load(Ordering::SeqCst)
}

/// Reset the resident high-water mark to the current resident total.
pub fn reset_resident_peak() {
    RESIDENT_PEAK.store(RESIDENT_NOW.load(Ordering::SeqCst), Ordering::SeqCst);
}

fn recharge(old: u64, new: u64) {
    if new >= old {
        let cur = RESIDENT_NOW.fetch_add(new - old, Ordering::SeqCst) + (new - old);
        RESIDENT_PEAK.fetch_max(cur, Ordering::SeqCst);
    } else {
        RESIDENT_NOW.fetch_sub(old - new, Ordering::SeqCst);
    }
}

/// Errors opening, writing, or verifying a shard store.
#[derive(Debug, thiserror::Error)]
pub enum ShardError {
    /// Underlying filesystem error.
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    /// The file does not start with [`SHARD_MAGIC`].
    #[error("not a shard store (bad magic)")]
    BadMagic,
    /// The file's format version is newer than this build understands.
    #[error("unsupported shard-store version {found} (this build reads {SHARD_VERSION})")]
    UnsupportedVersion {
        /// Version field found in the header.
        found: u32,
    },
    /// Structurally invalid contents (size mismatch, bad checksum, …).
    #[error("corrupt shard store: {0}")]
    Corrupt(String),
}

/// Handle to an on-disk CSR shard store (see the [module docs](self)).
///
/// The handle itself holds only the validated header fields and the path
/// — `O(1)` memory. Row data is read through [`ShardStore::cursor`], one
/// bounded chunk at a time. Cloning the handle is cheap; the clone shares
/// nothing but the path.
#[derive(Debug, Clone)]
pub struct ShardStore {
    path: PathBuf,
    rows: usize,
    cols: usize,
    nnz: usize,
    chunk_rows: usize,
}

impl ShardStore {
    /// Open and validate a shard-store file: magic, version, flags, and
    /// the exact file length implied by the header's `rows`/`nnz` (the
    /// layout has no variable-length sections). Does **not** stream the
    /// checksum — call [`ShardStore::verify`] for full integrity.
    pub fn open(path: &Path) -> Result<Self, ShardError> {
        let mut file = File::open(path)?;
        let mut header = [0u8; SHARD_HEADER_BYTES as usize];
        file.read_exact(&mut header)
            .map_err(|_| ShardError::Corrupt("file shorter than the 40-byte header".into()))?;
        if header[..8] != SHARD_MAGIC {
            return Err(ShardError::BadMagic);
        }
        let version = u32::from_le_bytes(header[8..12].try_into().expect("4 bytes"));
        if version != SHARD_VERSION {
            return Err(ShardError::UnsupportedVersion { found: version });
        }
        let flags = u32::from_le_bytes(header[12..16].try_into().expect("4 bytes"));
        if flags != 0 {
            return Err(ShardError::Corrupt(format!("unknown flags {flags:#x}")));
        }
        let rows_u = u64::from_le_bytes(header[16..24].try_into().expect("8 bytes"));
        let cols_u = u64::from_le_bytes(header[24..32].try_into().expect("8 bytes"));
        let nnz_u = u64::from_le_bytes(header[32..40].try_into().expect("8 bytes"));
        // Column ids are stored as u32, so a valid store cannot name more
        // than 2^32 columns; the cast guards also keep usize conversions
        // honest on 32-bit targets.
        if cols_u > 1 << 32 {
            return Err(ShardError::Corrupt(format!(
                "cols {cols_u} exceeds the u32 index space"
            )));
        }
        let rows = usize::try_from(rows_u)
            .map_err(|_| ShardError::Corrupt(format!("rows {rows_u} exceeds usize")))?;
        let cols = usize::try_from(cols_u)
            .map_err(|_| ShardError::Corrupt(format!("cols {cols_u} exceeds usize")))?;
        let nnz = usize::try_from(nnz_u)
            .map_err(|_| ShardError::Corrupt(format!("nnz {nnz_u} exceeds usize")))?;
        let expected = Self::expected_len(rows, nnz);
        let actual = file.metadata()?.len() as u128;
        if actual != expected {
            return Err(ShardError::Corrupt(format!(
                "file length {actual} does not match header (rows {rows}, nnz {nnz} \
                 imply {expected} bytes)"
            )));
        }
        Ok(Self {
            path: path.to_path_buf(),
            rows,
            cols,
            nnz,
            chunk_rows: DEFAULT_CHUNK_ROWS,
        })
    }

    fn expected_len(rows: usize, nnz: usize) -> u128 {
        SHARD_HEADER_BYTES as u128
            + 8 * (rows as u128 + 1)
            + 4 * nnz as u128
            + 4 * nnz as u128
            + 8
    }

    /// Set the cursor chunk size: how many rows each [`ChunkCursor`] keeps
    /// resident at a time (clamped to at least 1). Smaller chunks mean a
    /// smaller memory footprint and more seeks; results are bit-identical
    /// for every setting.
    #[must_use]
    pub fn with_chunk_rows(mut self, chunk_rows: usize) -> Self {
        self.chunk_rows = chunk_rows.max(1);
        self
    }

    /// Number of rows (samples).
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns (features).
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Total stored non-zeros.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// Rows per resident cursor chunk (see [`ShardStore::with_chunk_rows`]).
    #[inline]
    pub fn chunk_rows(&self) -> usize {
        self.chunk_rows
    }

    /// Path of the backing file.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Total on-disk size in bytes (header + arrays + checksum) — the
    /// "bytes mapped" figure the CLI reports for out-of-core runs.
    pub fn file_len(&self) -> u64 {
        Self::expected_len(self.rows, self.nnz) as u64
    }

    /// The full-matrix resident footprint this store avoids: what the
    /// CSR arrays would occupy decoded in memory (`usize` indptr entries,
    /// u32 indices, f32 values).
    pub fn in_memory_bytes(&self) -> u64 {
        (self.rows as u64 + 1) * std::mem::size_of::<usize>() as u64 + 8 * self.nnz as u64
    }

    fn indptr_off(&self) -> u64 {
        SHARD_HEADER_BYTES
    }

    fn indices_off(&self) -> u64 {
        SHARD_HEADER_BYTES + 8 * (self.rows as u64 + 1)
    }

    fn values_off(&self) -> u64 {
        self.indices_off() + 4 * self.nnz as u64
    }

    /// Open a cursor over this store. Each cursor opens its own file
    /// handle (seek positions are per-handle, so concurrent shard workers
    /// never interfere) and owns one chunk's worth of decode buffers.
    pub fn cursor(&self) -> Result<ChunkCursor<'_>, ShardError> {
        let file = File::open(&self.path)?;
        Ok(ChunkCursor {
            store: self,
            file,
            start: 0,
            end: 0,
            base: 0,
            indptr: Vec::new(),
            indices: Vec::new(),
            values: Vec::new(),
            buf: Vec::new(),
            charged: 0,
        })
    }

    /// Stream the whole file and check the trailing FNV-1a-64 checksum.
    pub fn verify(&self) -> Result<(), ShardError> {
        let mut file = File::open(&self.path)?;
        let total = self.file_len();
        let body = total - 8;
        let mut hash = FNV_OFFSET;
        let mut remaining = body;
        let mut buf = vec![0u8; 1 << 16];
        while remaining > 0 {
            let take = (buf.len() as u64).min(remaining) as usize;
            file.read_exact(&mut buf[..take])?;
            hash = fnv1a_update(hash, &buf[..take]);
            remaining -= take as u64;
        }
        let mut trailer = [0u8; 8];
        file.read_exact(&mut trailer)?;
        let stored = u64::from_le_bytes(trailer);
        if stored != hash {
            return Err(ShardError::Corrupt(format!(
                "checksum mismatch: stored {stored:#018x}, computed {hash:#018x}"
            )));
        }
        Ok(())
    }

    /// Write `m` to `path` in shard-store format (streaming; the only
    /// full-size buffer is the matrix itself, which the caller already
    /// holds). For corpora that do not fit in memory, use the libsvm
    /// converter ([`crate::data::convert::convert_libsvm_to_shards`])
    /// instead — it never materializes the matrix.
    pub fn write_from_matrix(path: &Path, m: &CsrMatrix) -> Result<(), ShardError> {
        let mut w = HashWrite::new(BufWriter::new(File::create(path)?));
        w.put(&SHARD_MAGIC)?;
        w.put(&SHARD_VERSION.to_le_bytes())?;
        w.put(&0u32.to_le_bytes())?;
        w.put(&(m.rows() as u64).to_le_bytes())?;
        w.put(&(m.cols() as u64).to_le_bytes())?;
        w.put(&(m.nnz() as u64).to_le_bytes())?;
        let mut running = 0u64;
        w.put(&running.to_le_bytes())?;
        for r in 0..m.rows() {
            running += m.row(r).nnz() as u64;
            w.put(&running.to_le_bytes())?;
        }
        for r in 0..m.rows() {
            for &c in m.row(r).indices {
                w.put(&c.to_le_bytes())?;
            }
        }
        for r in 0..m.rows() {
            for &v in m.row(r).values {
                w.put(&v.to_le_bytes())?;
            }
        }
        let hash = w.hash;
        let mut inner = w.w;
        inner.write_all(&hash.to_le_bytes())?;
        inner.flush()?;
        Ok(())
    }
}

/// A [`Write`] adapter that folds every byte into a running FNV-1a-64
/// hash before forwarding — how the writer and converter produce the
/// trailing checksum in one pass.
pub(crate) struct HashWrite<W: Write> {
    pub(crate) w: W,
    pub(crate) hash: u64,
}

impl<W: Write> HashWrite<W> {
    pub(crate) fn new(w: W) -> Self {
        Self { w, hash: FNV_OFFSET }
    }

    pub(crate) fn put(&mut self, bytes: &[u8]) -> std::io::Result<()> {
        self.hash = fnv1a_update(self.hash, bytes);
        self.w.write_all(bytes)
    }
}

/// Bounded-memory reader over a [`ShardStore`]: keeps one
/// `chunk_rows`-row chunk of the CSR arrays resident and reloads on
/// demand. Supports both the ascending scans of the assignment hot loops
/// (each chunk is loaded exactly once per pass) and the random accesses
/// of mini-batch sampling and AFK-MC² seeding (the chunk containing the
/// requested row is loaded).
///
/// # Panics
///
/// [`ChunkCursor::row`] panics if the backing file fails to read or its
/// contents went structurally invalid after [`ShardStore::open`]
/// validated it — the hot loops return borrowed views and have no error
/// channel (see the [module docs](self)).
pub struct ChunkCursor<'a> {
    store: &'a ShardStore,
    file: File,
    /// First row of the loaded chunk.
    start: usize,
    /// One past the last loaded row (`start == end` ⇒ nothing loaded).
    end: usize,
    /// nnz offset of the loaded chunk within the store.
    base: u64,
    indptr: Vec<u64>,
    indices: Vec<u32>,
    values: Vec<f32>,
    buf: Vec<u8>,
    /// Bytes charged to the global resident accounting.
    charged: u64,
}

impl ChunkCursor<'_> {
    /// Borrow row `i`, loading the chunk that contains it if needed.
    #[inline]
    pub fn row(&mut self, i: usize) -> RowView<'_> {
        assert!(i < self.store.rows, "row {i} out of {} rows", self.store.rows);
        if i < self.start || i >= self.end {
            let chunk = i / self.store.chunk_rows;
            if let Err(e) = self.load_chunk(chunk) {
                panic!(
                    "shard store {}: chunk read failed mid-run: {e}",
                    self.store.path.display()
                );
            }
        }
        let local = i - self.start;
        let s = (self.indptr[local] - self.base) as usize;
        let e = (self.indptr[local + 1] - self.base) as usize;
        RowView { indices: &self.indices[s..e], values: &self.values[s..e] }
    }

    /// Copy row `i` into an owned [`SparseVec`] (mirrors
    /// [`CsrMatrix::row_vec`]).
    pub fn row_vec(&mut self, i: usize) -> SparseVec {
        let cols = self.store.cols;
        let v = self.row(i);
        SparseVec::new(cols, v.indices.to_vec(), v.values.to_vec())
    }

    fn load_chunk(&mut self, chunk: usize) -> std::io::Result<()> {
        // Under the `trace` feature every chunk load charges its latency
        // and byte count to the global obs registry (span is `None` and
        // the record call compiles out otherwise).
        let sp = crate::obs::span::span_start();
        let corrupt = |msg: String| std::io::Error::new(std::io::ErrorKind::InvalidData, msg);
        let start = chunk * self.store.chunk_rows;
        let end = (start + self.store.chunk_rows).min(self.store.rows);
        let nrows = end - start;
        // Row pointers for the chunk (one extra to close the last row).
        self.buf.resize((nrows + 1) * 8, 0);
        self.file
            .seek(SeekFrom::Start(self.store.indptr_off() + 8 * start as u64))?;
        self.file.read_exact(&mut self.buf)?;
        self.indptr.clear();
        for c in self.buf.chunks_exact(8) {
            self.indptr.push(u64::from_le_bytes(c.try_into().expect("8 bytes")));
        }
        let base = self.indptr[0];
        let last = self.indptr[nrows];
        if last < base
            || last > self.store.nnz as u64
            || self.indptr.windows(2).any(|w| w[0] > w[1])
        {
            return Err(corrupt(format!(
                "non-monotone row pointers in chunk {chunk} (rows {start}..{end})"
            )));
        }
        let cnnz = (last - base) as usize;
        // Column indices.
        self.buf.resize(cnnz * 4, 0);
        self.file
            .seek(SeekFrom::Start(self.store.indices_off() + 4 * base))?;
        self.file.read_exact(&mut self.buf)?;
        self.indices.clear();
        for c in self.buf.chunks_exact(4) {
            self.indices.push(u32::from_le_bytes(c.try_into().expect("4 bytes")));
        }
        // Values.
        self.file
            .seek(SeekFrom::Start(self.store.values_off() + 4 * base))?;
        self.file.read_exact(&mut self.buf)?;
        self.values.clear();
        for c in self.buf.chunks_exact(4) {
            self.values.push(f32::from_le_bytes(c.try_into().expect("4 bytes")));
        }
        self.start = start;
        self.end = end;
        self.base = base;
        let charge = (self.indptr.capacity() as u64) * 8
            + (self.indices.capacity() as u64) * 4
            + (self.values.capacity() as u64) * 4
            + self.buf.capacity() as u64;
        recharge(self.charged, charge);
        self.charged = charge;
        crate::obs::metrics::record_shard_io(sp, ((nrows + 1) * 8 + cnnz * 8) as u64);
        Ok(())
    }
}

impl Drop for ChunkCursor<'_> {
    fn drop(&mut self) {
        recharge(self.charged, 0);
    }
}

/// A borrowed handle to point data, abstracting over the in-memory and
/// on-disk backends. `Copy` by design: every shard of a parallel pass
/// copies the source and opens its own [`RowCursor`] inside its worker
/// closure.
#[derive(Clone, Copy)]
pub enum RowSource<'a> {
    /// In-memory CSR matrix.
    Mem(&'a CsrMatrix),
    /// Chunked on-disk shard store.
    Disk(&'a ShardStore),
}

impl<'a> RowSource<'a> {
    /// Number of rows (samples).
    #[inline]
    pub fn rows(&self) -> usize {
        match self {
            RowSource::Mem(m) => m.rows(),
            RowSource::Disk(s) => s.rows(),
        }
    }

    /// Number of columns (features).
    #[inline]
    pub fn cols(&self) -> usize {
        match self {
            RowSource::Mem(m) => m.cols(),
            RowSource::Disk(s) => s.cols(),
        }
    }

    /// Total stored non-zeros.
    #[inline]
    pub fn nnz(&self) -> usize {
        match self {
            RowSource::Mem(m) => m.nnz(),
            RowSource::Disk(s) => s.nnz(),
        }
    }

    /// True when this source reads from disk.
    pub fn is_disk(&self) -> bool {
        matches!(self, RowSource::Disk(_))
    }

    /// Open a row cursor. For the in-memory backend this is free; for the
    /// disk backend it opens a file handle and allocates chunk buffers
    /// lazily on first access.
    ///
    /// # Panics
    ///
    /// Panics if the disk backend's file cannot be reopened — consistent
    /// with the mid-run I/O contract of [`ChunkCursor::row`]; the store
    /// was validated at [`ShardStore::open`] time.
    pub fn cursor(self) -> RowCursor<'a> {
        match self {
            RowSource::Mem(m) => RowCursor::Mem(m),
            RowSource::Disk(s) => RowCursor::Disk(s.cursor().unwrap_or_else(|e| {
                panic!("shard store {}: reopen failed: {e}", s.path().display())
            })),
        }
    }
}

impl<'a> From<&'a CsrMatrix> for RowSource<'a> {
    fn from(m: &'a CsrMatrix) -> Self {
        RowSource::Mem(m)
    }
}

impl<'a> From<&'a ShardStore> for RowSource<'a> {
    fn from(s: &'a ShardStore) -> Self {
        RowSource::Disk(s)
    }
}

/// A row reader over either backend (see [`RowSource::cursor`]). Mutable
/// because the disk backend reloads its chunk buffers on access; the
/// in-memory arm borrows rows directly with zero cost.
pub enum RowCursor<'a> {
    /// Zero-cost views into an in-memory matrix.
    Mem(&'a CsrMatrix),
    /// Chunk-buffered reads from a shard store.
    Disk(ChunkCursor<'a>),
}

impl RowCursor<'_> {
    /// Borrow row `i`. Disk-backed cursors load the containing chunk on
    /// demand (and panic on mid-run I/O failure — see [`ChunkCursor::row`]).
    #[inline]
    pub fn row(&mut self, i: usize) -> RowView<'_> {
        match self {
            RowCursor::Mem(m) => m.row(i),
            RowCursor::Disk(c) => c.row(i),
        }
    }

    /// Copy row `i` into an owned [`SparseVec`].
    pub fn row_vec(&mut self, i: usize) -> SparseVec {
        match self {
            RowCursor::Mem(m) => m.row_vec(i),
            RowCursor::Disk(c) => c.row_vec(i),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthConfig;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("sphkm-chunked-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn demo_matrix() -> CsrMatrix {
        SynthConfig::small_demo().generate(11).matrix
    }

    #[test]
    fn round_trip_matches_matrix_for_every_chunk_size() {
        let m = demo_matrix();
        let path = tmp("rt.sks");
        ShardStore::write_from_matrix(&path, &m).unwrap();
        let store = ShardStore::open(&path).unwrap();
        assert_eq!(store.rows(), m.rows());
        assert_eq!(store.cols(), m.cols());
        assert_eq!(store.nnz(), m.nnz());
        store.verify().unwrap();
        for chunk in [1usize, 7, 64, m.rows(), m.rows() + 100] {
            let s = store.clone().with_chunk_rows(chunk);
            let mut cur = s.cursor().unwrap();
            // Ascending scan plus a few random revisits.
            for i in 0..m.rows() {
                let a = m.row(i);
                let b = cur.row(i);
                assert_eq!(a.indices, b.indices, "chunk {chunk} row {i}");
                assert_eq!(a.values, b.values, "chunk {chunk} row {i}");
            }
            for &i in &[m.rows() - 1, 0, m.rows() / 2, 1 % m.rows()] {
                assert_eq!(m.row(i).indices, cur.row(i).indices);
            }
        }
    }

    #[test]
    fn row_source_uniform_over_backends() {
        let m = demo_matrix();
        let path = tmp("src.sks");
        ShardStore::write_from_matrix(&path, &m).unwrap();
        let store = ShardStore::open(&path).unwrap().with_chunk_rows(13);
        let mem = RowSource::Mem(&m);
        let disk = RowSource::Disk(&store);
        assert_eq!(mem.rows(), disk.rows());
        assert_eq!(mem.cols(), disk.cols());
        assert_eq!(mem.nnz(), disk.nnz());
        assert!(!mem.is_disk());
        assert!(disk.is_disk());
        let mut cm = mem.cursor();
        let mut cd = disk.cursor();
        for i in (0..m.rows()).rev() {
            assert_eq!(cm.row(i).values, cd.row(i).values);
            assert_eq!(cm.row_vec(i), cd.row_vec(i));
        }
    }

    #[test]
    fn open_rejects_bad_magic_version_and_length() {
        let m = demo_matrix();
        let path = tmp("bad.sks");
        ShardStore::write_from_matrix(&path, &m).unwrap();
        let good = std::fs::read(&path).unwrap();

        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        std::fs::write(&path, &bad).unwrap();
        assert!(matches!(ShardStore::open(&path), Err(ShardError::BadMagic)));

        let mut bad = good.clone();
        bad[8] = 9; // version
        std::fs::write(&path, &bad).unwrap();
        assert!(matches!(
            ShardStore::open(&path),
            Err(ShardError::UnsupportedVersion { found: 9 })
        ));

        std::fs::write(&path, &good[..good.len() - 3]).unwrap();
        assert!(matches!(ShardStore::open(&path), Err(ShardError::Corrupt(_))));
    }

    #[test]
    fn verify_catches_flipped_payload_byte() {
        let m = demo_matrix();
        let path = tmp("flip.sks");
        ShardStore::write_from_matrix(&path, &m).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        let store = ShardStore::open(&path).unwrap();
        assert!(matches!(store.verify(), Err(ShardError::Corrupt(_))));
    }

    #[test]
    fn resident_accounting_tracks_live_cursors() {
        let m = demo_matrix();
        let path = tmp("resident.sks");
        ShardStore::write_from_matrix(&path, &m).unwrap();
        let store = ShardStore::open(&path).unwrap().with_chunk_rows(8);
        let before = resident_bytes_now();
        {
            let mut cur = store.cursor().unwrap();
            let _ = cur.row(0);
            assert!(resident_bytes_now() > before, "chunk load must charge");
            assert!(resident_peak_bytes() >= resident_bytes_now());
        }
        // Cursor dropped: its charge is released.
        assert_eq!(resident_bytes_now(), before);
    }

    #[test]
    fn empty_matrix_round_trips() {
        let m = CsrMatrix::from_parts(0, 4, vec![0], vec![], vec![]);
        let path = tmp("empty.sks");
        ShardStore::write_from_matrix(&path, &m).unwrap();
        let store = ShardStore::open(&path).unwrap();
        assert_eq!(store.rows(), 0);
        assert_eq!(store.nnz(), 0);
        store.verify().unwrap();
    }
}
