"""AOT lowering: JAX/Pallas model → HLO text artifacts for the Rust runtime.

HLO *text* (not ``.serialize()``) is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which the published `xla`
crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Usage (normally via `make artifacts`):

    python -m compile.aot --out-dir ../artifacts \
        --shape 256,16,512 --shape 512,64,1024

Each `--shape B,K,D` emits `assign_b{B}_k{K}_d{D}.hlo.txt` (the assignment
step) — the filename doubles as the manifest the Rust side parses.
"""

import argparse
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


DEFAULT_SHAPES = [(256, 16, 512)]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (with return_tuple=True, so the
    Rust side unwraps one tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_assign(batch: int, k: int, dim: int) -> str:
    x = jax.ShapeDtypeStruct((batch, dim), jnp.float32)
    c = jax.ShapeDtypeStruct((k, dim), jnp.float32)
    return to_hlo_text(jax.jit(model.assign_step).lower(x, c))


def lower_cc(k: int, dim: int) -> str:
    c = jax.ShapeDtypeStruct((k, dim), jnp.float32)
    return to_hlo_text(jax.jit(model.cc_step).lower(c))


def parse_shape(text: str):
    parts = tuple(int(p) for p in text.split(","))
    if len(parts) != 3 or any(p <= 0 for p in parts):
        raise argparse.ArgumentTypeError(f"bad shape {text!r}, want B,K,D")
    return parts


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", type=Path)
    ap.add_argument(
        "--shape",
        action="append",
        type=parse_shape,
        help="B,K,D assignment-step shape (repeatable)",
    )
    ap.add_argument("--cc", action="store_true", help="also emit cc_step artifacts")
    args = ap.parse_args(argv)

    shapes = args.shape or DEFAULT_SHAPES
    args.out_dir.mkdir(parents=True, exist_ok=True)
    for batch, k, dim in shapes:
        text = lower_assign(batch, k, dim)
        path = args.out_dir / f"assign_b{batch}_k{k}_d{dim}.hlo.txt"
        path.write_text(text)
        print(f"wrote {path} ({len(text)} chars)")
        if args.cc:
            text = lower_cc(k, dim)
            path = args.out_dir / f"cc_k{k}_d{dim}.hlo.txt"
            path.write_text(text)
            print(f"wrote {path} ({len(text)} chars)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
